"""Paper Figs. 5, 7, 8: sparsity cycles vs on-chip memory, storage, and
block-size sweeps."""
from __future__ import annotations

from repro.api import Simulator
from repro.core.accelerator import SparsityConfig
from repro.core.sparsity import storage_report
from repro.core.workloads import resnet18, vit_ffn_only
from .common import timed


def run(smoke: bool = False):
    rows = []
    mbs = (0.25, 1.0, 3.0) if smoke else (0.25, 0.5, 1.0, 2.0, 3.0)

    # Fig. 5: total cycles (incl. stalls) vs SRAM for 1:4 / 2:4 / 4:4
    def fig5():
        out = {}
        for nm in ((1, 4), (2, 4), (4, 4)):
            for mb in mbs:
                sim = Simulator.from_preset("tpu-like", array=32, sram_mb=mb)
                if nm != (4, 4):
                    sim = sim.with_(sparsity=SparsityConfig(
                        enabled=True, n=nm[0], m=nm[1]))
                out[(nm, mb)] = sim.run(resnet18()).total_cycles
        return out

    out, us = timed(fig5, repeat=1)
    c14 = out[((1, 4), 1.0)]
    c24 = out[((2, 4), 1.0)]
    c44 = out[((4, 4), 1.0)]
    rows.append(("fig5_sparsity_cycles_vs_sram", us,
                 f"cycles@1MB 1:4={c14:.3e};2:4={c24:.3e};4:4={c44:.3e};"
                 f"mono={'yes' if c14 < c24 < c44 else 'NO'}"))

    # latency-constrained design point (Sec. IX-B "Sparsity")
    budget = 1.5 * c24
    dense_mb = min((mb for nm, mb in out if nm == (4, 4)
                    and out[(nm, mb)] < budget), default=None)
    sparse_mb = min((mb for nm, mb in out if nm == (2, 4)
                     and out[(nm, mb)] < budget), default=None)
    rows.append(("sec9b_sparse_sram_saving", 0.0,
                 f"dense_needs_MB={dense_mb};sparse24_needs_MB={sparse_mb}"))

    # Fig. 7: storage by ratio
    def fig7():
        res = {}
        for nm in (None, (3, 4), (2, 4), (1, 4)):
            sp = SparsityConfig(enabled=bool(nm), n=nm[0] if nm else 2,
                                m=4)
            tot = sum(storage_report(512, 4608, sp)["total_bytes"]
                      for _ in range(1))
            res[nm] = tot
        return res

    st, us7 = timed(fig7, repeat=3)
    rows.append(("fig7_storage_bytes", us7,
                 ";".join(f"{k}={v:.2e}" for k, v in st.items())))

    # Fig. 8: block-size sweep on ViT FFN layers — larger M exposes a finer
    # N:M spectrum whose lower end (N=1) gets faster with block size
    def fig8():
        res = {}
        for m in (4, 8, 16, 32):
            sim = Simulator("paper-32").with_(
                sparsity=SparsityConfig(enabled=True, n=1, m=m))
            res[m] = sim.run(vit_ffn_only()).total_cycles
        return res

    bs, us8 = timed(fig8, repeat=1)
    mono = all(bs[a] >= bs[b] for a, b in ((4, 8), (8, 16), (16, 32)))
    rows.append(("fig8_blocksize_sweep", us8,
                 "finer_low_end_faster=" + ("yes" if mono else "NO") + ";"
                 + ";".join(f"1:{k}cyc={v:.3e}" for k, v in bs.items())))
    return rows
