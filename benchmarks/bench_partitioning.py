"""Paper Fig. 3: spatial vs spatio-temporal partitioning tradeoff.

27 GEMMs (M,N,K in {1000,5000,10000}) x arrays {8,16,32} x cores {16,32,64};
reports how often each scheme wins under compute- and footprint-optimized
selection, and the mean footprint saving of ST at near-equal cycles.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.partition import enumerate_plans
from .common import timed


def run(smoke: bool = False):
    dims = [1000, 10000] if smoke else [1000, 5000, 10000]
    arrays = [8, 32] if smoke else [8, 16, 32]
    cores = [16, 64] if smoke else [16, 32, 64]
    st_cycle_wins = 0
    st_fp_wins_at_eq = 0
    spatial_fp_wins = 0
    total = 0
    savings = []

    def sweep():
        nonlocal st_cycle_wins, st_fp_wins_at_eq, spatial_fp_wins, total
        st_cycle_wins = st_fp_wins_at_eq = spatial_fp_wins = total = 0
        savings.clear()
        for (M, N, K), a, nc in itertools.product(
                itertools.product(dims, dims, dims), arrays, cores):
            plans = enumerate_plans("ws", M, N, K, a, a, nc)
            sp = [p for p in plans if p.scheme == "spatial"]
            st = [p for p in plans if p.scheme != "spatial"
                  and not (p.scheme == "st1" and p.Pc == 1)
                  and not (p.scheme == "st2" and p.Pr == 1)]
            sp_best = min(sp, key=lambda p: (p.cycles, p.footprint))
            st_best = min(st, key=lambda p: (p.cycles, p.footprint))
            total += 1
            if st_best.cycles < sp_best.cycles:
                st_cycle_wins += 1
            near = [p for p in st if p.cycles <= 1.05 * sp_best.cycles]
            if near:
                fp = min(near, key=lambda p: p.footprint)
                if fp.footprint < sp_best.footprint:
                    st_fp_wins_at_eq += 1
                    savings.append(1 - fp.footprint / sp_best.footprint)
            if min(plans, key=lambda p: (p.footprint, p.cycles)
                   ).scheme == "spatial":
                spatial_fp_wins += 1
        return total

    _, us = timed(sweep, repeat=1)
    mean_save = float(np.mean(savings)) if savings else 0.0
    return [
        ("fig3_partitioning_sweep", us,
         f"configs={total};st_cycle_wins={st_cycle_wins};"
         f"st_fp_wins_at_eq_cycles={st_fp_wins_at_eq};"
         f"spatial_fp_wins={spatial_fp_wins};"
         f"mean_st_fp_saving={mean_save:.2f}"),
    ]
