"""Paper Figs. 9-10 + Sec. IX-B DRAM study: channels vs throughput,
request-queue stalls, WS/OS flip."""
from __future__ import annotations

from repro.api import Simulator
from repro.core.accelerator import DramConfig
from repro.core.dram import linear_trace, simulate_dram, tile_prefetch_trace
from repro.core.workloads import resnet18_six_layers
from .common import timed


def run(smoke: bool = False):
    rows = []
    n_req = 2048 if smoke else 8192

    # Fig. 9: channels 1..8 vs throughput (streaming resnet-like traffic)
    def fig9():
        t, a, w = linear_trace(n_req, issue_gap=0.25)
        return {ch: float(simulate_dram(t, a, w,
                                        DramConfig(channels=ch)).throughput)
                for ch in (1, 2, 4, 8)}

    th, us = timed(fig9, repeat=1)
    rows.append(("fig9_dram_channels_throughput", us,
                 ";".join(f"ch{c}={v:.1f}B/cyc" for c, v in th.items())))

    # Fig. 10: request queue 32/128/512
    def fig10():
        t, a, w = tile_prefetch_trace(tile_bytes=20 * 1024,
                                      n_tiles=16 if smoke else 64,
                                      compute_per_tile=400, gran_bytes=64)
        return {q: float(simulate_dram(
            t, a, w, DramConfig(channels=2, read_queue=q,
                                write_queue=q)).total_cycles)
            for q in (32, 128, 512)}

    tot, us10 = timed(fig10, repeat=1)
    r32 = tot[32] / tot[128]
    r128 = (tot[128] - tot[512]) / tot[128] * 100
    rows.append(("fig10_request_queue_stalls", us10,
                 f"total32={tot[32]:.0f};total128={tot[128]:.0f};"
                 f"total512={tot[512]:.0f};x32to128={r32:.2f};"
                 f"pct128to512={r128:.1f}%"))

    # Sec. IX-B: WS vs OS with and without DRAM stalls (six ResNet18 layers)
    def flip():
        out = {}
        for df in ("ws", "os"):
            rep = Simulator.from_preset("tpu-like", array=32, dataflow=df,
                                        sram_mb=0.4).run(resnet18_six_layers())
            out[df] = (rep.compute_cycles, rep.total_cycles)
        return out

    fl, usf = timed(flip, repeat=1)
    ws_gain = (1 - fl["ws"][0] / fl["os"][0]) * 100
    os_gain = (1 - fl["os"][1] / fl["ws"][1]) * 100
    rows.append(("sec9b_ws_os_dram_flip", usf,
                 f"ws_compute_better={ws_gain:.1f}%(paper:21%);"
                 f"os_total_better={os_gain:.1f}%(paper:30.1%)"))
    return rows
