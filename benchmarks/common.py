"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6                 # us per call


def emit(rows: List[Tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
