"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    """Best-of-`repeat` wall time (us per call).

    Min, not mean: scheduler preemption and cache-cold hiccups only ever
    add time, so the minimum is the low-noise estimate of the true cost.
    Averaging let runner jitter both hide real regressions (a slow
    baseline run raises the floor) and cry wolf on healthy code — the
    regression gate needs the repeatable number.
    """
    fn(*args, **kw)                      # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6               # us per call


def emit(rows: List[Tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
