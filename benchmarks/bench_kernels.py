"""Pallas kernel microbenchmarks (interpret mode on CPU): systolic fold
simulation + bank-conflict histogram vs their jnp oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conflict import (conflict_slowdown,
                                    conflict_slowdown_reference)
from repro.kernels.systolic import simulate_fold, systolic_ws_reference
from .common import timed


def run(smoke: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    T, R, C = (64, 16, 16) if smoke else (128, 32, 32)
    x = jax.random.normal(key, (T, R), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (R, C), jnp.float32)

    _, us_k = timed(lambda: jax.block_until_ready(
        simulate_fold(x, w, interpret=True)), repeat=3)
    _, us_r = timed(lambda: jax.block_until_ready(
        systolic_ws_reference(x, w)), repeat=3)
    rows.append(("systolic_fold_sim", us_k,
                 f"ref_scan_us={us_r:.0f};kernel_vs_scan={us_r / us_k:.1f}x"))

    line = jax.random.randint(key, (256, 64), 0, 17)
    bank = jax.random.randint(jax.random.fold_in(key, 2), (256, 64), 0, 16)
    _, us_ck = timed(lambda: jax.block_until_ready(conflict_slowdown(
        line, bank, num_banks=16, ports=1, interpret=True)), repeat=3)
    _, us_cr = timed(lambda: jax.block_until_ready(
        conflict_slowdown_reference(line, bank, num_banks=16, ports=1)),
        repeat=3)
    rows.append(("conflict_histogram", us_ck, f"oracle_us={us_cr:.0f}"))
    return rows
