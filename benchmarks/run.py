"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  bench_partitioning   Fig. 3    spatial vs spatio-temporal tradeoff
  bench_sparsity       Figs. 5/7/8 + Sec. IX-B sparsity design point
  bench_dram           Figs. 9/10 + Sec. IX-B WS/OS DRAM flip
  bench_layout         Figs. 12/13 bank-conflict slowdown grid
  bench_energy         Fig. 15 + Table V latency/energy/EdP
  bench_multicore      Table VI iso-compute + heterogeneous cores
  bench_sim_throughput Table IV analog + DSE fast path
  bench_kernels        Pallas kernel microbenchmarks
  bench_roofline       dry-run roofline table (EXPERIMENTS.md source)
"""
from __future__ import annotations

import sys
import traceback

from .common import emit


def main() -> None:
    from . import (bench_partitioning, bench_sparsity, bench_dram,
                   bench_layout, bench_energy, bench_multicore,
                   bench_sim_throughput, bench_kernels, bench_roofline)
    mods = [bench_partitioning, bench_sparsity, bench_dram, bench_layout,
            bench_energy, bench_multicore, bench_sim_throughput,
            bench_kernels, bench_roofline]
    print("name,us_per_call,derived")
    failed = 0
    for m in mods:
        try:
            emit(m.run())
        except Exception:
            failed += 1
            print(f"{m.__name__},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
