"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  bench_partitioning   Fig. 3    spatial vs spatio-temporal tradeoff
  bench_sparsity       Figs. 5/7/8 + Sec. IX-B sparsity design point
  bench_dram           Figs. 9/10 + Sec. IX-B WS/OS DRAM flip
  bench_layout         Figs. 12/13 bank-conflict slowdown grid
  bench_energy         Fig. 15 + Table V latency/energy/EdP
  bench_multicore      Table VI iso-compute + heterogeneous cores
  bench_sim_throughput Table IV analog + batched Simulator.sweep path
  bench_kernels        Pallas kernel microbenchmarks
  bench_roofline       dry-run roofline table (EXPERIMENTS.md source)

``--smoke`` runs every module on reduced grids (CI / quick sanity);
``--only mod1,mod2`` restricts the module list.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from .common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grids for CI")
    ap.add_argument("--only", default="",
                    help="comma-separated bench module suffixes")
    args = ap.parse_args()

    from . import (bench_partitioning, bench_sparsity, bench_dram,
                   bench_layout, bench_energy, bench_multicore,
                   bench_sim_throughput, bench_kernels, bench_roofline)
    mods = [bench_partitioning, bench_sparsity, bench_dram, bench_layout,
            bench_energy, bench_multicore, bench_sim_throughput,
            bench_kernels, bench_roofline]
    if args.only:
        want = {w.strip() for w in args.only.split(",") if w.strip()}
        known = {m.__name__.split("bench_")[-1] for m in mods}
        unknown = want - known
        if unknown:
            sys.exit(f"--only: unknown module(s) {sorted(unknown)}; "
                     f"available: {sorted(known)}")
        mods = [m for m in mods
                if m.__name__.split("bench_")[-1] in want]
    print("name,us_per_call,derived")
    failed = 0
    for m in mods:
        try:
            emit(m.run(smoke=args.smoke))
        except Exception:
            failed += 1
            print(f"{m.__name__},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
