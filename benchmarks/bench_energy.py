"""Paper Fig. 15 + Table V: energy by dataflow x array size; the
latency/energy/EdP table for ResNet-50, RCNN, ViT-base. All points run
through the unified `Simulator` facade."""
from __future__ import annotations

from repro.api import Simulator
from repro.core.workloads import rcnn, resnet50, vit_base_linear
from .common import timed


def run(smoke: bool = False):
    rows = []
    arrays15 = (32, 128) if smoke else (8, 16, 32, 64, 128)
    workloads = (("resnet50", resnet50()), ("vitb", vit_base_linear()))

    def fig15():
        out = {}
        for wl_name, wl in workloads:
            for arr in arrays15:
                for df in ("ws", "is", "os"):
                    sim = Simulator.from_preset("tpu-like", array=arr,
                                                dataflow=df)
                    out[(wl_name, arr, df)] = sim.run(wl).energy_pj * 1e-9
        return out

    e, us = timed(fig15, repeat=1)
    os_wins = sum(1 for (w, a, d) in e if d == "os" and
                  e[(w, a, "os")] <= min(e[(w, a, "ws")], e[(w, a, "is")]))
    rows.append(("fig15_energy_dataflow_grid", us,
                 f"os_wins={os_wins}/{2 * len(arrays15)};"
                 f"vitb32_ws={e[('vitb', 32, 'ws')]:.1f}mJ;"
                 f"vitb128_ws={e[('vitb', 128, 'ws')]:.1f}mJ"))

    t5_wl = workloads if smoke else workloads + (("rcnn", rcnn()),)

    def table5():
        out = {}
        for wl_name, wl in t5_wl:
            for arr in (32, 64, 128):
                rep = Simulator.from_preset("tpu-like", array=arr).run(wl)
                out[(wl_name, arr)] = (rep.total_cycles,
                                       rep.energy_pj * 1e-9, rep.edp)
        return out

    t5, us5 = timed(table5, repeat=1)
    lat_ratio = t5[("vitb", 32)][0] / t5[("vitb", 128)][0]
    e_ratio = t5[("vitb", 128)][1] / t5[("vitb", 32)][1]
    edp = {a: t5[("vitb", a)][2] for a in (32, 64, 128)}
    edp_best = min(edp, key=edp.get)
    rows.append(("table5_latency_energy_edp", us5,
                 f"vitb_lat32/128={lat_ratio:.2f}(paper:6.53);"
                 f"vitb_E128/E32={e_ratio:.2f}(paper:2.86);"
                 f"edp_best={edp_best}x{edp_best}(paper:64x64)"))
    for wl_name, _ in t5_wl:
        rows.append((f"table5_{wl_name}", 0.0,
                     ";".join(f"{a}:lat={t5[(wl_name, a)][0]:.3e},"
                              f"E={t5[(wl_name, a)][1]:.2f}mJ,"
                              f"EdP={t5[(wl_name, a)][2]:.3e}"
                              for a in (32, 64, 128))))
    return rows
