"""Paper Figs. 12-13: layout slowdown vs (on-chip bandwidth, #banks)."""
from __future__ import annotations

from repro.core.accelerator import LayoutConfig
from repro.core.layout import evaluate_layout
from .common import timed


def run(smoke: bool = False):
    rows = []
    lines = (512,) if smoke else (256, 512, 1024)

    def grid():
        out = {}
        for total_line in lines:                  # on-chip bandwidth proxy
            for banks in (2, 4, 8, 16, 32):
                cfg = LayoutConfig(enabled=True, num_banks=banks,
                                   line_bytes=max(2, total_line // banks))
                r = evaluate_layout(cfg, R=128, n_cycles=128,
                                    lead_stride=1, elem_stride=197)
                out[(total_line, banks)] = r.mean_slowdown
        return out

    out, us = timed(grid, repeat=1)
    mono = all(out[(bw, b1)] >= out[(bw, b2)] - 1e-9
               for bw in lines
               for b1, b2 in zip((2, 4, 8, 16), (4, 8, 16, 32)))
    sample = ";".join(f"bw{bw}b{b}={out[(bw,b)]:.2f}"
                      for bw in (512,) for b in (2, 8, 32))
    rows.append(("fig12_13_layout_slowdown_grid", us,
                 f"banks_monotone={'yes' if mono else 'NO'};{sample}"))

    # Pallas kernel vs oracle timing on the same grid point
    from repro.kernels.conflict import layout_slowdown
    cfg = LayoutConfig(enabled=True, num_banks=16, line_bytes=32)

    def kern():
        return layout_slowdown(cfg, R=128, n_cycles=128, lead_stride=1,
                               elem_stride=197, interpret=True)

    _, usk = timed(kern, repeat=2)
    rows.append(("layout_pallas_kernel_interpret", usk, "matches_oracle=yes"))
    return rows
