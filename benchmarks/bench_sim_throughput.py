"""Paper Table IV analog: simulation throughput of the JAX-native engine.

The paper reports per-feature simulation-time overheads of v3 vs v2 (Python
event loop). Our adaptation's claim is different: features cost little
because everything is vectorized/jit-compiled — and the batched
`Simulator.sweep` path simulates thousands of designs per second (the
reason to put a simulator on a TPU pod in the first place). Both are
measured here, plus the trace-fidelity path (dataflow-generated demand
traces through the cycle-accurate DRAM scan, batched via vmap).

Also emits `BENCH_sim_throughput.json` (sweep points/sec, trace-fidelity
cycles) so CI can track the perf trajectory across PRs.
"""
from __future__ import annotations

import json
import os

from repro.api import Simulator, preset_grid
from repro.core.accelerator import LayoutConfig, SparsityConfig
from repro.core.workloads import Op, resnet18
from .common import timed

ARTIFACT = os.environ.get("BENCH_ARTIFACT", "BENCH_sim_throughput.json")


def run(smoke: bool = False):
    rows = []
    artifact = {"smoke": bool(smoke)}
    wl = resnet18()
    base = Simulator("paper-32")

    _, us_base = timed(lambda: base.run(wl), repeat=3)
    feats = {}
    feats["multicore"] = timed(
        lambda: Simulator.from_preset("tpu-like", array=32,
                                      cores=16).run(wl), repeat=3)[1]
    feats["sparsity24"] = timed(
        lambda: base.with_(sparsity=SparsityConfig(
            enabled=True, n=2, m=4)).run(wl), repeat=3)[1]
    feats["layout"] = timed(
        lambda: base.with_(layout=LayoutConfig(enabled=True)).run(wl),
        repeat=3)[1]
    feats["dram_cycle"] = timed(
        lambda: Simulator("paper-32", fidelity="cycle").run(wl[:6]),
        repeat=1)[1]
    over = ";".join(f"{k}={v / us_base:.2f}x" for k, v in feats.items())
    rows.append(("table4_feature_overhead", us_base,
                 f"base_us={us_base:.0f};{over}"))

    # DSE fast path: a (array x sram) grid through one vmapped sweep call
    n_arr = (8, 16) if smoke else (8, 16, 32, 64)
    n_sram = (0.5, 1.0) if smoke else (0.25, 0.5, 1.0, 2.0, 4.0, 8.0,
                                       12.0, 16.0)
    grid = preset_grid(array=list(n_arr), sram_mb=list(n_sram),
                       dataflow=["ws"])
    big = grid * (4 if smoke else 128)          # thousands of design points
    op = [Op("g", 512, 4096, 1024)]

    sweep_res, us_dse = timed(lambda: base.sweep(big, op), repeat=3)
    assert sweep_res.batched
    dps = len(big) / (us_dse / 1e6)
    rows.append((f"dse_sweep_{len(big)}_designs", us_dse,
                 f"designs_per_sec={dps:.0f}"))
    artifact["sweep_designs"] = len(big)
    artifact["sweep_designs_per_sec"] = dps
    artifact["base_run_us"] = us_base

    # trace fidelity: one op through the generated-trace DRAM path, and a
    # batched (vmapped — no per-op fallback) trace-fidelity sweep
    tsim = Simulator("paper-32", fidelity="trace")
    trace_rep, us_trace = timed(lambda: tsim.run_op(wl[1]), repeat=3)
    rows.append(("trace_fidelity_op", us_trace,
                 f"total_cycles={trace_rep.total_cycles:.0f};"
                 f"stall={trace_rep.stall_cycles:.0f}"))
    artifact["trace_op_total_cycles"] = trace_rep.total_cycles
    artifact["trace_op_stall_cycles"] = trace_rep.stall_cycles
    artifact["trace_op_us"] = us_trace

    tgrid = big                # same design points as the fast-path sweep,
    #                            so the two designs_per_sec are comparable
    tres, us_tsweep = timed(lambda: tsim.sweep(tgrid, op), repeat=5)
    assert tres.batched, "trace-fidelity sweep must not fall back"
    tdps = len(tgrid) / (us_tsweep / 1e6)
    rows.append((f"trace_sweep_{len(tgrid)}_designs", us_tsweep,
                 f"designs_per_sec={tdps:.0f}"))
    artifact["trace_sweep_designs"] = len(tgrid)
    artifact["trace_sweep_designs_per_sec"] = tdps
    artifact["trace_engine"] = tres.engine

    # the fused-megakernel engine on the same grid ("pallas": one kernel
    # launch with designs batched along the Pallas grid on TPU; its XLA
    # twin off-TPU — the resolved label is recorded with the number so
    # CI always knows which form it gated). Must match the default
    # engine's stalls bit-for-bit off-TPU (same math by construction).
    psim = Simulator("paper-32", fidelity="trace", engine="pallas")
    pres, us_psweep = timed(lambda: psim.sweep(tgrid, op), repeat=5)
    assert pres.batched, "megakernel trace sweep must not fall back"
    assert pres.engine.startswith("pallas"), \
        f"'pallas' silently resolved to {pres.engine!r}"
    pdps = len(tgrid) / (us_psweep / 1e6)
    rows.append((f"trace_megakernel_{len(tgrid)}_designs", us_psweep,
                 f"designs_per_sec={pdps:.0f};engine={pres.engine}"))
    artifact["trace_megakernel_designs"] = len(tgrid)
    artifact["trace_megakernel_designs_per_sec"] = pdps
    artifact["trace_megakernel_engine"] = pres.engine

    # mixed sparse+dense sweep (ISSUE 5): a 32-design grid crossing
    # {dense, 2:4, 1:4, 1:4 row-wise} sparsity with array/SRAM sizes —
    # every cell batches (no per-op fallback since sparsity became a
    # traced kernel axis); CI gates sparse_sweep_designs_per_sec
    sgrid = preset_grid(array=[8, 16, 32, 64], sram_mb=[0.5, 1.0],
                        sparsity=[None, "2:4", "1:4", "1:4-rw"])
    assert len(sgrid) == 32
    spres, us_sp = timed(lambda: base.sweep(sgrid, op), repeat=3)
    assert spres.batched, "sparse sweep cells must batch"
    spdps = len(sgrid) / (us_sp / 1e6)
    rows.append((f"sparse_sweep_{len(sgrid)}_designs", us_sp,
                 f"designs_per_sec={spdps:.0f}"))
    artifact["sparse_sweep_designs"] = len(sgrid)
    artifact["sparse_sweep_designs_per_sec"] = spdps

    # Study layer: designs x 2 workloads x {fast, trace} compiled into
    # batched groups — the cross-product path CI gates via
    # study_cells_per_sec (benchmarks/baseline.json)
    from repro.api import Study
    study = (Study("bench")
             .designs(grid)
             .workloads({"g": op, "g2": [Op("g2", 256, 2048, 512)]})
             .fidelity("fast", "trace"))
    sres, us_study = timed(lambda: study.run(), repeat=3)
    assert (sres["batched"] == 1.0).all(), \
        "study cells must run through the batched plan"
    cps = len(sres) / (us_study / 1e6)
    rows.append((f"study_{len(sres)}_cells", us_study,
                 f"cells_per_sec={cps:.0f}"))
    artifact["study_cells"] = len(sres)
    artifact["study_cells_per_sec"] = cps

    # pod-scale routed NoC sweep (ISSUE 7): 1024-core mesh pods crossing
    # link bandwidth x DRAM channels through one batched kernel (the
    # topology is the static flavor; link params are traced columns).
    # CI gates noc_sweep_designs_per_sec.
    ngrid = preset_grid("pod-mesh", pods=[1024],
                        link_bw=[4.0, 32.0, 256.0], channels=[2, 8])
    nres, us_noc = timed(lambda: base.sweep(ngrid, op), repeat=3)
    assert nres.batched, "pod NoC sweep cells must batch"
    ndps = len(ngrid) / (us_noc / 1e6)
    rows.append((f"noc_sweep_{len(ngrid)}_pods_1024c", us_noc,
                 f"designs_per_sec={ndps:.0f}"))
    artifact["noc_sweep_designs"] = len(ngrid)
    artifact["noc_sweep_cores"] = 1024
    artifact["noc_sweep_designs_per_sec"] = ndps

    # run-farm (ISSUE 6): the same 16-cell study pushed through a broker
    # and 2 workers (in-process, driven synchronously, dedup cache off so
    # every repeat pays the full cold cost). farm_cells_per_sec tracks
    # the service overhead on top of the batched kernels; CI gates it.
    import tempfile
    from repro.farm import Broker, FarmClient, Worker

    fgrid = preset_grid(array=[8, 16], sram_mb=[0.5, 1.0], dataflow=["ws"])
    fstudy = lambda: (Study("bench-farm")
                      .designs(fgrid)
                      .workloads({"g": op, "g2": [Op("g2", 256, 2048, 512)]})
                      .fidelity("fast", "trace"))
    assert len(fstudy().plan().cells) == 16

    def farm_run():
        with tempfile.TemporaryDirectory() as root:
            client = FarmClient(root)
            broker = Broker(root, max_shard_cells=4)
            workers = [Worker(root, f"bw{i}", cache=None) for i in range(2)]
            sid = client.submit(fstudy())
            broker.step()
            while client.status(sid).get("state") == "running":
                for w in workers:
                    w.step()
                broker.step()
            return client.result(sid, timeout=5)

    fres, us_farm = timed(farm_run, repeat=3)
    assert len(fres) == 16 and fres.executed_cells == 16
    fcps = len(fres) / (us_farm / 1e6)
    rows.append((f"farm_{len(fres)}_cells_2_workers", us_farm,
                 f"cells_per_sec={fcps:.0f}"))
    artifact["farm_cells"] = len(fres)
    artifact["farm_workers"] = 2
    artifact["farm_cells_per_sec"] = fcps

    # search layer (ISSUE 9): a seeded smoke search (screen + one propose
    # round, fast fidelity, cold cache per repeat) over a 96-cell space.
    # search_evals_per_sec tracks the driver's scheduling overhead on top
    # of the batched round studies; CI gates it. The exhaustive fraction
    # is recorded so the budget trajectory is visible across PRs (the
    # flagship search_edp gates <= 5% in its own claims).
    import dataclasses as _dc
    from repro.api import get_preset
    from repro.core.accelerator import CoreConfig
    from repro.search import SearchDriver, SearchSpace, choice, \
        int_log_range

    def _sram(cfg, kb):
        s = int(kb) * 1024 // 3
        return cfg.with_(memory=_dc.replace(
            cfg.memory, ifmap_sram_bytes=s, filter_sram_bytes=s,
            ofmap_sram_bytes=s))

    sspace = SearchSpace("bench-search", get_preset("edge-8"), [
        choice("array", (8, 16, 32),
               lambda c, v: c.with_(cores=(CoreConfig(rows=v, cols=v),)),
               short="a"),
        int_log_range("sram_kb", 64, 1024, 16, _sram, short="s"),
        choice("dataflow", ("ws", "os"),
               lambda c, v: c.with_(dataflow=v), short=""),
    ])

    def search_run():
        with tempfile.TemporaryDirectory() as cdir:
            return SearchDriver(sspace, {"g": op}, seed=0, metric="edp",
                                ladder=("fast",), screen=24, eta=4.0,
                                explore_rounds=1, cache=cdir).run()

    sres2, us_search = timed(search_run, repeat=3)
    assert sres2.executed_cells == sres2.spent_evals, \
        "cold-cache search must execute every requested eval"
    seps = sres2.spent_evals / (us_search / 1e6)
    sfrac = sres2.spent_evals / sres2.exhaustive_cells
    rows.append((f"search_{sres2.spent_evals}_evals", us_search,
                 f"evals_per_sec={seps:.0f};vs_exhaustive={sfrac:.3f};"
                 f"winner={sres2.winner['design']}"))
    artifact["search_evals"] = sres2.spent_evals
    artifact["search_evals_per_sec"] = seps
    artifact["search_evals_vs_exhaustive"] = sfrac

    # the retained reference scan on the same grid, for the ISSUE 3
    # chunked-vs-reference engine comparison (single repeat: it is slow)
    rsim = Simulator("paper-32", fidelity="trace", engine="reference")
    _, us_ref = timed(lambda: rsim.sweep(tgrid, op), repeat=1)
    rdps = len(tgrid) / (us_ref / 1e6)
    rows.append((f"trace_sweep_reference_{len(tgrid)}_designs", us_ref,
                 f"designs_per_sec={rdps:.0f};"
                 f"chunked_speedup={tdps / rdps:.2f}x"))
    artifact["trace_sweep_reference_designs_per_sec"] = rdps

    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
    return rows
