"""Paper Table IV analog: simulation throughput of the JAX-native engine.

The paper reports per-feature simulation-time overheads of v3 vs v2 (Python
event loop). Our adaptation's claim is different: features cost little
because everything is vectorized/jit-compiled — and the DSE fast path
simulates thousands of designs per second (the reason to put a simulator on
a TPU pod in the first place). Both are measured here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import simulate_network, tpu_like_config
from repro.core.accelerator import LayoutConfig, SparsityConfig
from repro.core.engine import gemm_summary_traced
from repro.core.topology import resnet18
from .common import timed


def run():
    rows = []
    wl = resnet18()
    base_cfg = tpu_like_config(array=32)

    _, us_base = timed(lambda: simulate_network(base_cfg, wl), repeat=3)
    feats = {}
    feats["multicore"] = timed(lambda: simulate_network(
        tpu_like_config(array=32, cores=16), wl), repeat=3)[1]
    feats["sparsity24"] = timed(lambda: simulate_network(
        base_cfg.with_(sparsity=SparsityConfig(enabled=True, n=2, m=4)),
        wl), repeat=3)[1]
    feats["layout"] = timed(lambda: simulate_network(
        base_cfg.with_(layout=LayoutConfig(enabled=True)), wl), repeat=3)[1]
    feats["dram_cycle"] = timed(lambda: simulate_network(
        base_cfg, wl[:6], dram_fidelity="cycle"), repeat=1)[1]
    over = ";".join(f"{k}={v / us_base:.2f}x" for k, v in feats.items())
    rows.append(("table4_feature_overhead", us_base,
                 f"base_us={us_base:.0f};{over}"))

    # DSE fast path: vmap over 4096 (R, C) designs in one jit
    Rs = jnp.tile(jnp.array([8, 16, 32, 64]), 1024)
    Cs = jnp.repeat(jnp.array([8, 16, 32, 64]), 1024)

    @jax.jit
    def dse(Rs, Cs):
        f = jax.vmap(lambda r, c: gemm_summary_traced(
            "ws", 512, 4096, 1024, r, c, sram_elems=1 << 19,
            bw_bytes_per_cycle=38.4)["total_cycles"])
        return f(Rs, Cs)

    out, us_dse = timed(lambda: dse(Rs, Cs).block_until_ready(), repeat=3)
    rows.append(("dse_vmap_4096_designs", us_dse,
                 f"designs_per_sec={4096 / (us_dse / 1e6):.0f}"))
    return rows
