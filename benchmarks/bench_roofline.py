"""Roofline report: reads the dry-run JSONs (experiments/dryrun/) and emits
the per-(arch x shape x mesh) three-term roofline table (deliverable g)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "experiments", "dryrun"))


def load_cells():
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def run(smoke: bool = False):
    rows = []
    cells = load_cells()
    ok = [c for c in cells if c.get("ok")]
    if not ok:
        rows.append(("roofline", 0.0,
                     "no dry-run artifacts; run python -m repro.launch.dryrun --all"))
        return rows
    n_fit = sum(1 for c in ok if c.get("fits_hbm"))
    rows.append(("dryrun_summary", 0.0,
                 f"cells_ok={len(ok)};fits_hbm={n_fit}/{len(ok)};"
                 f"meshes=pod(256)+multipod(512)"))
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        t = c["terms"]
        rows.append((
            f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}", 0.0,
            f"compute_s={t['compute_s']:.3e};memory_s={t['memory_s']:.3e};"
            f"collective_s={t['collective_s']:.3e};dom={c['dominant'][:-2]};"
            f"useful={c['useful_flops_ratio']:.2f};"
            f"mfu_vs_roofline={c['mfu_vs_roofline']:.3f};"
            f"peakGB={c['peak_bytes_per_device'] / 2**30:.2f}"))
    return rows
