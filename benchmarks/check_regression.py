"""CI benchmark regression gate (ISSUE 3 satellite).

Compares a fresh `BENCH_sim_throughput.json` against the committed
`benchmarks/baseline.json` and fails (exit 1) if a tracked throughput
metric regressed by more than the allowed fraction.  Throughput gains
never fail; the gate only guards the floor.

    python -m benchmarks.check_regression \
        [--bench BENCH_sim_throughput.json] \
        [--baseline benchmarks/baseline.json] [--tolerance 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys

# metric -> max allowed regression fraction vs baseline
GATES = {
    # tight gates: `common.timed` is best-of-repeats now, so the bench
    # number is the low-noise floor estimate — the old 0.2 tolerance let
    # a 7% real decay (718 -> 664 designs/s) hide inside run jitter
    "trace_sweep_designs_per_sec": 0.1,
    "trace_megakernel_designs_per_sec": 0.1,
    "sweep_designs_per_sec": 0.2,
    "study_cells_per_sec": 0.2,
    "sparse_sweep_designs_per_sec": 0.2,
    # 1024-core pod kernels are compile-heavy relative to their 6-design
    # grid, so per-run timing is noisier: wider gate like the farm's
    "noc_sweep_designs_per_sec": 0.3,
    # farm throughput folds in service overhead (spool I/O, broker
    # scheduling), which is noisier than pure kernel time: wider gate
    "farm_cells_per_sec": 0.3,
    # search folds in per-round study compilation + cell-cache I/O on
    # top of the batched kernels: wider gate like the farm's
    "search_evals_per_sec": 0.3,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_sim_throughput.json")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override the per-metric regression tolerance")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        bench = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures = []
    for metric, tol in GATES.items():
        tol = args.tolerance if args.tolerance is not None else tol
        if metric not in base:
            continue
        if metric not in bench:
            failures.append(f"{metric}: missing from {args.bench}")
            continue
        got, floor = float(bench[metric]), float(base[metric]) * (1.0 - tol)
        ratio = float(bench[metric]) / max(float(base[metric]), 1e-9)
        status = "FAIL" if got < floor else "ok"
        print(f"{status}: {metric} = {got:.1f} "
              f"(baseline {float(base[metric]):.1f}, x{ratio:.2f}, "
              f"floor {floor:.1f})")
        if got < floor:
            failures.append(
                f"{metric} regressed: {got:.1f} < floor {floor:.1f}")
    if failures:
        print("benchmark regression gate FAILED:", "; ".join(failures))
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
