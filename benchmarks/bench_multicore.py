"""Paper Table VI: iso-compute single 128x128 vs 16x 32x32 multi-core,
plus heterogeneous/non-uniform partitioning demonstrations."""
from __future__ import annotations

from repro.api import Simulator
from repro.core.accelerator import AcceleratorConfig, CoreConfig
from repro.core.multicore import simulate_multicore
from repro.core.workloads import vit_base_linear
from .common import timed


def run(smoke: bool = False):
    rows = []
    points = ((1, 128), (16, 32))

    def table6():
        out = {}
        for cores, arr in points:
            for df in ("ws", "is"):
                sim = Simulator.from_preset("tpu-like", array=arr,
                                            cores=cores, dataflow=df)
                rep = sim.run(vit_base_linear())
                out[(cores, df)] = (rep.compute_cycles, rep.energy_pj * 1e-9,
                                    rep.edp)
        return out

    t6, us = timed(table6, repeat=1)
    single = t6[(1, "is")][0] / t6[(1, "ws")][0]
    multi = t6[(16, "is")][0] / t6[(16, "ws")][0]
    edp_is = t6[(16, "ws")][2] / t6[(16, "is")][2]
    rows.append(("table6_iso_compute", us,
                 f"is/ws_single={single:.2f};is/ws_multi={multi:.2f};"
                 f"gap_narrowing={abs(1 - single):.2f}->{abs(1 - multi):.2f}"
                 f"(paper:1.87->1.14);"
                 f"multi_edp_ws/is={edp_is:.2f}(paper IS 1.31x better)"))

    # heterogeneous cores + non-uniform NoP split (Sec. III-C/D)
    def hetero():
        cores = tuple([CoreConfig(rows=64, cols=64, nop_hops=0)] * 2
                      + [CoreConfig(rows=32, cols=32, nop_hops=4)] * 2)
        cfg = AcceleratorConfig(cores=cores, mesh_rows=4, mesh_cols=1)
        r = simulate_multicore(cfg, 2048, 4096, 4096, "spatial")
        return r

    r, ush = timed(hetero, repeat=1 if smoke else 3)
    spread = max(r.per_core_cycles) / min(r.per_core_cycles)
    rows.append(("sec3_heterogeneous_nonuniform", ush,
                 f"shares={list(r.per_core_share)};makespan={r.cycles:.3e};"
                 f"imbalance={spread:.2f}"))
    return rows
