from .manager import CheckpointManager, PreemptionHandler
