"""Fault-tolerant checkpointing: async, atomic, elastic-restorable.

Design for 1000+ nodes (single-host mechanics here, semantics preserved):
  - *atomic*: writes go to  step_XXXX.tmp/  then os.replace() to step_XXXX/;
    a crash mid-write never corrupts the latest valid checkpoint;
  - *async*: device->host transfer happens on the caller thread (cheap),
    serialization + fsync on a background thread so the train loop keeps
    stepping; `wait()` joins before the next save or at exit;
  - *elastic*: arrays are saved logically-unsharded (np arrays per leaf) with
    a manifest of tree structure; restore takes target shardings for any
    mesh shape and uses jax.device_put per leaf — a 512-chip checkpoint
    restores onto 256 or 64 chips unchanged (dist/elastic.py picks the mesh);
  - *retention*: keep_last N checkpoints, garbage-collect older;
  - *preemption*: PreemptionHandler turns SIGTERM into save-and-exit.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [v for _, v in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        self.wait()
        names, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(v)) for v in leaves]

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            # npz can't roundtrip ml_dtypes (bfloat16 etc.): store raw bits,
            # the manifest carries the true dtype for restore
            store = [a if a.dtype.kind in "biufc"
                     else a.view(np.uint16 if a.dtype.itemsize == 2
                                 else np.uint8) for a in host]
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(store)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "names": names,
                           "dtypes": [str(a.dtype) for a in host],
                           "shapes": [list(a.shape) for a in host]}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of `tree_like`; placement follows
        `shardings` (any mesh — elastic restore) or stays host-local."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        z = np.load(os.path.join(d, "arrays.npz"))
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        def _restore_dtype(a, name):
            if str(a.dtype) == name:
                return a
            try:
                dt = np.dtype(name)
            except TypeError:
                import ml_dtypes
                dt = np.dtype(getattr(ml_dtypes, name))
            return a.view(dt)

        arrays = [_restore_dtype(z[f"a{i}"], manifest["dtypes"][i])
                  for i in range(len(z.files))]
        _, leaves_like, treedef = _flatten_with_paths(tree_like)
        assert len(arrays) == len(leaves_like), "tree structure changed"
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings)
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
        return jax.tree_util.tree_unflatten(treedef, arrays)


class PreemptionHandler:
    """SIGTERM/SIGINT -> save once at the next step boundary, then exit."""

    def __init__(self, save_fn: Callable[[], None]):
        self._requested = False
        self._save_fn = save_fn
        for sig in (signal.SIGTERM,):
            try:
                signal.signal(sig, self._handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def checkpoint_if_preempted(self) -> bool:
        if self._requested:
            self._save_fn()
            return True
        return False
