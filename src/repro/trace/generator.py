"""Dataflow-aware DRAM demand-trace synthesis (SCALE-Sim's defining output).

Where `core.dram.tile_prefetch_trace` drives the cycle-accurate DRAM scan
with a *synthetic* linear stream, this module synthesizes the demand
request stream (issue cycle, address, is_write) directly from the mapping:

  1. the tile schedule — `map_gemm`/`fold_counts` give the fold grid
     (fr x fc tiles) and the per-tile compute window `comp / (fr * fc)`;
  2. a double-buffered prefetch scheduler — reads for tile t are posted in
     a burst at the start of tile t-1's compute window (both buffers are
     filled up front for tiles 0/1), so small request queues block the
     producer immediately while large queues absorb the burst (Fig. 10);
  3. per-dataflow operand walks — the order each operand region is
     traversed (stationary loads are sequential, streaming operands walk
     the reduction dim fastest, psum drains differ between OS and WS/IS);
  4. layout-aware addressing — `core.layout.operand_linear_index` maps
     walk coordinates through row/column-major or tiled DRAM layouts, so
     the same dataflow produces genuinely different row-buffer behavior
     per layout (the SCALE-Sim TPU validation axis).

Everything is fixed-shape and traced: a `TraceSpec.cap`-sized request
buffer with a `valid` mask and a real-valued `scale` (fold + scale beyond
the cap, the same trick `CycleDramStage` uses) makes the generators
vmappable, which is what lets `Simulator.sweep` batch trace-fidelity
design points instead of falling back to the per-op Python loop.

Conservation contract: `sum(valid) * gran_bytes * scale` equals the
capacity-model byte total from `dataflow.dram_traffic` exactly — for
self-scaled streams. A caller-supplied common scale (the contention
path) quantizes each region's bytes to whole model requests, so tiny
cores sharing a big core's scale carry up to one request's worth
(`scale * gran_bytes`) of over-modeling per region.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core import dataflow as dfm
from ..core.accelerator import AcceleratorConfig, DramConfig
from ..core.dram import simulate_dram
from ..core.layout import operand_linear_index
from ..core.workloads import Op

# One address region per operand (ifmap / filter / ofmap). 32 MiB spacing
# keeps regions in disjoint DRAM rows while staying inside int32 with the
# per-core offsets of the contention path (which guards the <= 16-core
# limit of the 2^31 shared address space explicitly).
REGION_SPAN = 1 << 25
_BIG_T = jnp.float32(1e15)          # sort key for invalid (masked) slots
# Compressed streams are sampled in contiguous runs of this many granules
# (64 granules x 64 B = two 2 KiB DRAM rows) so layout-driven row-buffer
# locality survives stream compression.
_SAMPLE_RUN = 64


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Static knobs of the trace generator (hashable: jit/vmap-safe).

    cap:          fixed request-buffer size; streams beyond it are folded
                  and the resulting stall rescaled (`scale`).
    gran_bytes:   bytes per demand request (DRAM burst granularity).
    layout:       DRAM-side operand layout — 'row' | 'col' | 'tiled'
                  (see core.layout.operand_linear_index) or 'strided'
                  (address = stream position * stride_elems, the
                  row-thrash stress pattern).
    """
    cap: int = 4096
    gran_bytes: int = 64
    layout: str = "row"
    tile_r: int = 32
    tile_c: int = 32
    stride_elems: int = 1

    def __post_init__(self):
        if self.cap < 1:
            raise ValueError(f"trace cap must be >= 1, got {self.cap}")
        if self.gran_bytes < 1:
            raise ValueError(
                f"gran_bytes must be >= 1, got {self.gran_bytes}")
        if self.layout not in ("row", "col", "tiled", "strided"):
            raise ValueError(
                "trace layout must be one of "
                f"('row', 'col', 'tiled', 'strided'), got {self.layout!r}")
        if self.tile_r < 1 or self.tile_c < 1:
            raise ValueError(
                f"trace tile must be >= 1x1, got "
                f"{self.tile_r}x{self.tile_c}")
        if self.stride_elems < 1:
            raise ValueError(
                f"stride_elems must be >= 1, got {self.stride_elems}")


# The one default spec shared by every entry point (per-op stage, batched
# sweep, contention) so spec=None means the same stream everywhere.
DEFAULT_SPEC = TraceSpec()

# Regions along the request-index axis (interleaving in *time* is done by
# the issue schedule + sort, not by this ordering).
R_IFMAP, R_FILTER, R_OFMAP_RD, R_OFMAP_WR = 0, 1, 2, 3

# Per (dataflow, region): does the fast (innermost) walk dim run down the
# operand's rows?  Operand shapes: X = K x N, W = M x K, O = M x N.
#   ws: X streams a column per cycle (k fast); W loads are sequential per
#       stationary fold (k fast along W's columns); psums drain m-fast.
#   is: X stationary load (k fast); W streams k-fast; outputs drain n-fast.
#   os: both operands stream k-fast; the stationary O drains n-fast
#       (row-major) at tile end.
_FAST_IS_ROW = {
    ("ws", R_IFMAP): True, ("ws", R_FILTER): False, ("ws", R_OFMAP_WR): True,
    ("is", R_IFMAP): True, ("is", R_FILTER): False, ("is", R_OFMAP_WR): False,
    ("os", R_IFMAP): True, ("os", R_FILTER): False, ("os", R_OFMAP_WR): False,
}


def _merge_sort_order(key, region):
    """Permutation that stably sorts `key`, given that `key` is
    nondecreasing within each contiguous `region` segment.

    The issue schedule is monotone per region (tau/frac only ever grow
    with the within-region index, and masked slots get `_BIG_T`), so the
    global sort is a 4-way stable merge of sorted runs: each element's
    sorted position is its own within-region offset plus, per other
    region, a binary-search count — ties resolved exactly as a stable
    argsort would (earlier stream position first: `<=` against earlier
    regions, `<` against later ones).  O(n log n) thin gather steps
    instead of a full comparison sort, which dominates stream
    generation time at sweep scale.
    """
    cap = key.shape[-1]
    ii = jnp.arange(cap, dtype=jnp.int32)
    rank = jnp.zeros(key.shape, jnp.int32)
    for r in range(4):
        # integer segment bounds of region r (`region` is nondecreasing)
        s = jnp.searchsorted(region, r, side="left").astype(jnp.int32)
        e = jnp.searchsorted(region, r + 1, side="left").astype(jnp.int32)
        # pad outside the segment so the whole array is sorted: the
        # -inf prefix keeps searchsorted counts offset by exactly `s`
        seg = jnp.where(ii < s, -jnp.inf, jnp.where(ii >= e, jnp.inf, key))
        lo = jnp.searchsorted(seg, key, side="left").astype(jnp.int32) - s
        hi = jnp.searchsorted(seg, key, side="right").astype(jnp.int32) - s
        n_r = e - s
        contrib = jnp.where(region == r, ii - s,
                            jnp.where(region > r, jnp.clip(hi, 0, n_r),
                                      jnp.clip(lo, 0, n_r)))
        rank = rank + contrib
    return jnp.zeros(key.shape, jnp.int32).at[rank].set(ii,
                                                        unique_indices=True)


def _modmul(j, a, L):
    """mod(j * a, L) without forming the full product.

    Large-GEMM streams push j * a past 1e11, where float32's integer
    resolution (2^24) exceeds coordinate-sized moduli and a direct
    jnp.mod collapses the operand walk (inverting the layout-sensitive
    row-buffer statistics this module exists to produce). Splitting the
    exact small integer j into 6-bit halves keeps every intermediate
    near 64 * L, where f32 arithmetic is exact for dimension-sized L
    (< 2^18). For the strided layout's span-sized modulus (2^24) the
    residual rounding is up to ~64 elements of address noise — below
    the burst-count scale the stride statistics are measured at.
    """
    j_hi = jnp.floor(j / 64.0)
    j_lo = j - 64.0 * j_hi
    a1 = jnp.mod(a, L)
    a64 = jnp.mod(64.0 * a1, L)
    return jnp.mod(j_lo * a1 + j_hi * a64, L)


@partial(jax.jit, static_argnames=("dataflow", "word_bytes", "spec"))
def gemm_request_stream(dataflow: str, M, N, K, R, C, comp,
                        ifmap_elems, filter_elems, ofmap_write_elems,
                        ofmap_read_elems, word_bytes: int = 2,
                        spec: TraceSpec = TraceSpec(), scale=None):
    """Synthesize the demand-request stream for one GEMM op.

    M/N/K/R/C/comp and the four region element counts (from
    `dataflow.dram_traffic`, after any sparsity shrink) may be traced
    arrays; `dataflow`, `word_bytes` and `spec` are static.

    scale: optional stream-compression factor override. The multi-core
    contention path passes one common scale so every core's stream is
    compressed coherently; by default the op picks its own.

    Returns (t_issue, addr, is_write, valid, scale) — arrays of shape
    (spec.cap,), sorted by issue time, plus the scalar compression factor
    (model stall * scale estimates the real stall).
    """
    f32 = jnp.float32
    wb = word_bytes
    gran = spec.gran_bytes
    cap = spec.cap

    region_bytes = jnp.stack([f32(1.0) * ifmap_elems * wb,
                              f32(1.0) * filter_elems * wb,
                              f32(1.0) * ofmap_read_elems * wb,
                              f32(1.0) * ofmap_write_elems * wb])
    total_bytes = jnp.sum(region_bytes)
    n_total = total_bytes / gran                      # fractional requests
    if scale is None:
        n_model = jnp.minimum(f32(cap), jnp.maximum(1.0, jnp.ceil(n_total)))
        scale = n_total / n_model
    else:
        scale = f32(1.0) * scale
        n_model = jnp.minimum(
            f32(cap), jnp.maximum(1.0, jnp.ceil(
                n_total / jnp.maximum(scale, 1e-9))))

    # region boundaries in model-request units (sum == n_model when the
    # op picked its own scale)
    safe_scale = jnp.maximum(scale, 1e-9)
    r_model = region_bytes / gran / safe_scale        # (4,)
    edges = jnp.cumsum(r_model)
    starts = jnp.concatenate([jnp.zeros(1, f32), edges[:-1]])

    i = jnp.arange(cap, dtype=f32)
    valid = i < n_model
    region = jnp.sum((i[:, None] >= edges[None, :]).astype(jnp.int32),
                     axis=1)
    region = jnp.clip(region, 0, 3)
    j = jnp.maximum(0.0, i - starts[region])          # index within region

    # ---- operand walk -> coordinates -> layout -> address ------------------
    Mf, Nf, Kf = f32(1.0) * M, f32(1.0) * N, f32(1.0) * K
    rows_of = jnp.stack([Kf, Mf, Mf, Mf])             # X:KxN W:MxK O:MxN
    cols_of = jnp.stack([Nf, Kf, Nf, Nf])
    fast_is_row = jnp.asarray(
        [_FAST_IS_ROW[(dataflow, R_IFMAP)],
         _FAST_IS_ROW[(dataflow, R_FILTER)],
         _FAST_IS_ROW[(dataflow, R_OFMAP_WR)],       # spill reads walk like
         _FAST_IS_ROW[(dataflow, R_OFMAP_WR)]])      # the write-back stream

    rows_r = rows_of[region]
    cols_r = cols_of[region]
    fr_row = fast_is_row[region]
    fast_len = jnp.maximum(1.0, jnp.where(fr_row, rows_r, cols_r))
    slow_len = jnp.maximum(1.0, jnp.where(fr_row, cols_r, rows_r))

    # stream element position. The stream is compressed by `scale`; so
    # that row-buffer statistics stay meaningful under compression, the
    # model requests sample the real stream in contiguous runs of
    # _SAMPLE_RUN granules (run starts stride by step * _SAMPLE_RUN) —
    # the local DRAM-row locality the layout determines survives even
    # when one model request stands in for megabytes of real traffic.
    # At scale == 1 this degenerates to the exact uncompressed walk.
    # Coordinates are modular products via _modmul (a plain j * step
    # product overflows f32 integer resolution at LM scale).
    step = safe_scale * gran / wb                     # elements/request
    run = f32(_SAMPLE_RUN)
    j_b = jnp.floor(j / run)                          # run id
    j_i = j - run * j_b                               # granule within run
    g_el = f32(gran) / wb                             # elements/granule
    f = jnp.mod(_modmul(j_b, step * run, fast_len) + j_i * g_el, fast_len)
    lines = (_modmul(j_b, step * run / fast_len, slow_len)
             + j_i * g_el / fast_len)
    s = jnp.mod(jnp.floor(lines), slow_len)           # refetches wrap
    row = jnp.where(fr_row, f, s)
    col = jnp.where(fr_row, s, f)

    if spec.layout == "strided":
        # defined directly on the stream position (no run-sampling): the
        # stress pattern's contract is hit rate monotone in the stride,
        # which run-local contiguity would wash out
        idx = _modmul(j, step * spec.stride_elems, f32(REGION_SPAN // wb))
    else:
        idx = operand_linear_index(row, col, rows_r, cols_r,
                                   order=spec.layout,
                                   tile_r=spec.tile_r, tile_c=spec.tile_c)
        idx = jnp.mod(idx, f32(REGION_SPAN // wb))
    # exact integer address math from here on (channel/bank/row decode in
    # simulate_dram must not see float rounding). Spill reads share the
    # write-back stream's region — they read the same ofmap buffer, so a
    # spilled psum can row-hit the row its own write-back opened.
    addr_region = jnp.minimum(region, R_OFMAP_RD).astype(jnp.int32)
    addr = (addr_region * jnp.int32(REGION_SPAN)
            + jnp.floor(idx).astype(jnp.int32) * jnp.int32(wb))

    # ---- double-buffered prefetch schedule ---------------------------------
    Sr, Sc, T = dfm.map_gemm(dataflow, M, N, K)
    fr, fc = dfm.fold_counts(Sr, Sc, R, C)
    n_tiles = jnp.maximum(1.0, f32(1.0) * fr * fc)
    tile_cyc = jnp.maximum(1.0, f32(1.0) * comp / n_tiles / safe_scale)

    q = jnp.maximum(r_model[region] / n_tiles, 1e-9)  # requests/tile/region
    pos = j / q
    tau = jnp.clip(jnp.floor(pos), 0.0, n_tiles - 1.0)
    frac = jnp.clip(pos - tau, 0.0, 1.0)

    is_write = region == R_OFMAP_WR
    t_read = jnp.maximum(0.0, tau - 1.0) * tile_cyc   # prefetch burst at
    #                                                   window start
    if dataflow == "os":
        # stationary outputs drain in a burst when the tile retires
        t_write = (tau + 1.0) * tile_cyc
    else:
        # ws/is psum write-backs interleave with the streaming compute
        t_write = (tau + frac) * tile_cyc
    t_spill = (tau + frac) * tile_cyc                 # psum read-backs
    t = jnp.where(is_write, t_write,
                  jnp.where(region == R_OFMAP_RD, t_spill, t_read))

    # ---- sort by issue time (invalid slots last) ---------------------------
    # stable 4-way merge, not a full argsort: t is monotone per region
    order = _merge_sort_order(jnp.where(valid, t, _BIG_T),
                              region.astype(jnp.int32))
    return (t[order], addr[order], is_write[order], valid[order], scale)


@partial(jax.jit, static_argnames=("dataflow", "dram_cfg", "word_bytes",
                                   "spec", "engine"))
def gemm_trace_stats(dataflow: str, M, N, K, R, C, comp,
                     ifmap_elems, filter_elems, ofmap_write_elems,
                     ofmap_read_elems, dram_cfg: DramConfig,
                     word_bytes: int = 2,
                     spec: TraceSpec = TraceSpec(),
                     engine: str = None) -> Dict[str, jnp.ndarray]:
    """Generate the op's trace and run it through the cycle-accurate DRAM
    replay. Fully traced (vmappable over ops and design points). engine
    selects the replay engine (`core.replay.ENGINES`; None = default)."""
    t, addr, w, valid, scale = gemm_request_stream(
        dataflow, M, N, K, R, C, comp, ifmap_elems, filter_elems,
        ofmap_write_elems, ofmap_read_elems, word_bytes, spec)
    res = simulate_dram(t, addr, w, dram_cfg, spec.gran_bytes, valid=valid,
                        engine=engine)
    nval = jnp.maximum(1.0, jnp.sum(valid).astype(jnp.float32))
    refs = jnp.maximum(1, res.row_hits + res.row_misses + res.row_conflicts)
    return dict(
        stall_cycles=res.stall_cycles * scale,
        row_hits=res.row_hits, row_misses=res.row_misses,
        row_conflicts=res.row_conflicts,
        row_hit_rate=res.row_hits / refs,
        mean_latency=jnp.sum(res.latency) / nval,
        throughput_Bpc=res.throughput,
        bytes_modeled=res.bytes_moved * scale,
        scaled_by=scale)


# --------------------------------------------------------------------------
# Convenience (eager) entry points over an AcceleratorConfig
# --------------------------------------------------------------------------

def _op_regions(cfg: AcceleratorConfig, op: Op, core_index: int = 0):
    core = cfg.cores[core_index]
    dram = dfm.dram_traffic(cfg.dataflow, op.M, op.N, op.K,
                            core.rows, core.cols, cfg.memory)
    comp = dfm.compute_cycles(cfg.dataflow, op.M, op.N, op.K,
                              core.rows, core.cols)
    return core, comp, dram


def trace_op(cfg: AcceleratorConfig, op: Op, spec: TraceSpec = TraceSpec(),
             core_index: int = 0) -> Tuple[jnp.ndarray, ...]:
    """(t_issue, addr, is_write, valid, scale) for one op on `cfg`."""
    core, comp, dram = _op_regions(cfg, op, core_index)
    return gemm_request_stream(
        cfg.dataflow, op.M, op.N, op.K, core.rows, core.cols, comp,
        dram["dram_ifmap"], dram["dram_filter"], dram["dram_ofmap_writes"],
        dram["dram_ofmap_reads"], cfg.memory.word_bytes, spec)


def trace_op_stats(cfg: AcceleratorConfig, op: Op,
                   spec: TraceSpec = TraceSpec(),
                   core_index: int = 0,
                   engine: str = None) -> Dict[str, jnp.ndarray]:
    """Row-buffer / stall statistics of one op's generated trace."""
    core, comp, dram = _op_regions(cfg, op, core_index)
    return gemm_trace_stats(
        cfg.dataflow, op.M, op.N, op.K, core.rows, core.cols, comp,
        dram["dram_ifmap"], dram["dram_filter"], dram["dram_ofmap_writes"],
        dram["dram_ofmap_reads"], cfg.dram, cfg.memory.word_bytes, spec,
        engine=engine)
