"""repro.trace: dataflow-aware DRAM demand-trace generation.

The subsystem behind `fidelity="trace"`: per-dataflow (OS/WS/IS) request
generators that walk the tile schedule, a double-buffered prefetch
scheduler that turns tile deadlines into issue times, layout-aware
address mapping (composing with `core.layout`), and a shared-DRAM
multi-core contention path over merged per-core traces.

Request-stream contract: fixed-shape (TraceSpec.cap) buffers of
(t_issue, addr, is_write, valid) sorted by issue time, plus a real-valued
`scale` such that sum(valid) * gran_bytes * scale equals the
`dataflow.dram_traffic` byte total exactly (conservation; with a
caller-supplied common scale, per-region bytes quantize to whole model
requests instead — see generator.py). Everything is traced, so
generators vmap over ops and design points.
"""
from .generator import (DEFAULT_SPEC, REGION_SPAN, TraceSpec,
                        gemm_request_stream, gemm_trace_stats, trace_op,
                        trace_op_stats)
from .contention import (ContentionResult, SharedDramResult, core_subgemm,
                         multicore_contention, simulate_shared_dram)

__all__ = [
    "DEFAULT_SPEC", "REGION_SPAN", "TraceSpec", "gemm_request_stream",
    "gemm_trace_stats", "trace_op", "trace_op_stats", "ContentionResult",
    "SharedDramResult", "core_subgemm", "multicore_contention",
    "simulate_shared_dram",
]
