"""Shared-DRAM multi-core contention over merged per-core traces.

`simulate_multicore` balances compute + NoP offsets but models each core's
memory as free. Here every core's share of the GEMM becomes its own
generated demand trace (offset in time by its NoP hop latency, offset in
address space so cores occupy disjoint DRAM regions), the traces are
merged into one stream, and a banked-channel scan with *per-channel*
request queues and *per-core* backpressure shifts times the whole thing.

Two routing modes:
  - shared (default): every core's bursts interleave over all channels —
    cores contend for channel buses, banks and queue slots.
  - private_channels: core c's bursts are pinned to channel `c % channels`
    (burst-index transform `b -> b * channels + c`). With one core per
    channel the merged scan decomposes *exactly* into the isolated
    per-core runs — the contention path then equals the isolated model,
    which is the invariant `tests/test_trace.py` checks.

Per-core stall inflation (shared stall / isolated stall) is the quantity
the paper's end-to-end system analysis needs: how much of the partition's
balance survives a real memory system.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import dataflow as dfm
from ..core import replay as rp
from ..core.accelerator import AcceleratorConfig, DramConfig
from ..core.dram import check_addresses, decode_requests, row_buffer_latency
from .generator import (_BIG_T, DEFAULT_SPEC, REGION_SPAN, TraceSpec,
                        gemm_request_stream)

_CORE_SPAN = 4 * REGION_SPAN      # address space per core (shared routing)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SharedDramResult:
    per_core_stall: jnp.ndarray     # (n_cores,)
    per_core_last: jnp.ndarray      # (n_cores,) last completion time
    row_hits: jnp.ndarray
    row_misses: jnp.ndarray
    row_conflicts: jnp.ndarray
    total_cycles: jnp.ndarray


def simulate_shared_dram(t_issue: jnp.ndarray, addr: jnp.ndarray,
                         is_write: jnp.ndarray, core_id: jnp.ndarray,
                         valid: jnp.ndarray, n_cores: int, cfg: DramConfig,
                         gran_bytes: int = 64,
                         engine: Optional[str] = None,
                         chunk: Optional[int] = None,
                         max_passes: Optional[int] = None,
                         tol: Optional[float] = None) -> SharedDramResult:
    """The `simulate_dram` model generalized to a merged multi-core stream.

    Differences from the single-stream model (both matter for contention):
    - request queues are per *channel* (a core hammering channel 0 cannot
      exhaust channel 1's in-flight window), and
    - the backpressure `shift` is per *core* — one core's queue stalls
      delay that core's later requests, not its neighbors' issue times
      (their delay comes physically, through bus/bank/queue occupancy).

    With disjoint channel pinning the per-core state never couples, so
    the model decomposes exactly into per-core isolated runs.

    engine: None -> `replay.DEFAULT_ENGINE`; "xla" | "pallas" run the
    chunked bank-parallel replay with per-channel queues and per-core
    shift folded into the chunk carry; "reference" keeps the original
    per-request scan.
    """
    engine = rp.resolve_engine(engine)
    check_addresses(addr)
    return _simulate_shared_dram(t_issue, addr, is_write, core_id, valid,
                                 n_cores, cfg, gran_bytes, engine, chunk,
                                 max_passes, tol)


@partial(jax.jit, static_argnames=("n_cores", "cfg", "gran_bytes", "engine",
                                   "chunk", "max_passes", "tol"))
def _simulate_shared_dram(t_issue, addr, is_write, core_id, valid,
                          n_cores: int, cfg: DramConfig, gran_bytes: int,
                          engine: str, chunk, max_passes,
                          tol) -> SharedDramResult:
    busy = jnp.maximum(1.0, gran_bytes / cfg.bandwidth_bytes_per_cycle)
    flat_bank, ch, row = decode_requests(addr, cfg)
    if engine == "reference":
        done, shift, hits, misses, conflicts = _reference_shared_scan(
            t_issue, flat_bank, ch, row, is_write, valid, core_id,
            n_cores, cfg, busy)
    else:
        out = rp.replay_decoded(
            t_issue.astype(jnp.float32), flat_bank, ch, row, is_write,
            valid, cfg, gran_bytes, engine=engine, chunk=chunk,
            max_passes=max_passes,
            **({} if tol is None else dict(tol=tol)),
            n_cores=n_cores, core_id=core_id.astype(jnp.int32),
            per_channel_queues=True)
        done = jnp.where(valid, out["done"], 0.0)
        shift = out["shift"]
        hits, misses, conflicts = out["hits"], out["misses"], out["conflicts"]

    nominal = cfg.tRCD + cfg.tCAS + busy
    ti = t_issue.astype(jnp.float32)
    onehot = (core_id[None, :] == jnp.arange(n_cores)[:, None]) & valid
    last_done = jnp.max(jnp.where(onehot, done[None, :], 0.0), axis=1)
    last_issue = jnp.max(jnp.where(onehot, ti[None, :], 0.0), axis=1)
    tail = jnp.maximum(0.0, last_done - (last_issue + shift + nominal))
    return SharedDramResult(
        per_core_stall=shift + tail,
        per_core_last=last_done,
        row_hits=hits, row_misses=misses, row_conflicts=conflicts,
        total_cycles=jnp.max(jnp.where(valid, done, 0.0)))


def _reference_shared_scan(t_issue, flat_bank, ch, row, is_write, valid,
                           core_id, n_cores: int, cfg: DramConfig, busy):
    """Original per-request shared-stream scan (engine='reference')."""
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel

    Qr, Qw = cfg.read_queue, cfg.write_queue

    def step(carry, x):
        (bank_free, open_row, bus_free, ring_r, ring_w, ir, iw, shift,
         hits, misses, conflicts) = carry
        t, fb, c, rw, w, v, cid = x
        t_eff = t + shift[cid]
        head_r = ring_r[c, ir[c] % Qr]
        head_w = ring_w[c, iw[c] % Qw]
        issue_ok = jnp.maximum(t_eff, jnp.where(w, head_w, head_r))
        ready = jnp.maximum(issue_ok, bank_free[fb])
        lat, hit, empty = row_buffer_latency(cfg, open_row[fb], rw)
        done = jnp.maximum(ready + lat, bus_free[c]) + busy
        bank_free = jnp.where(v, bank_free.at[fb].set(done), bank_free)
        bus_free = jnp.where(v, bus_free.at[c].set(done), bus_free)
        open_row = jnp.where(v, open_row.at[fb].set(rw), open_row)
        ring_r = jnp.where(v & ~w, ring_r.at[c, ir[c] % Qr].set(done), ring_r)
        ring_w = jnp.where(v & w, ring_w.at[c, iw[c] % Qw].set(done), ring_w)
        ir = jnp.where(v & ~w, ir.at[c].add(1), ir)
        iw = jnp.where(v & w, iw.at[c].add(1), iw)
        shift = jnp.where(
            v, shift.at[cid].add(jnp.maximum(0.0, issue_ok - t_eff)), shift)
        hits += hit & v
        misses += empty & v
        conflicts += (~hit) & (~empty) & v
        return ((bank_free, open_row, bus_free, ring_r, ring_w, ir, iw,
                 shift, hits, misses, conflicts),
                jnp.where(v, done, 0.0))

    carry0 = (jnp.zeros(ch_n * bk_n), -jnp.ones(ch_n * bk_n, jnp.int32),
              jnp.zeros(ch_n), jnp.zeros((ch_n, Qr)), jnp.zeros((ch_n, Qw)),
              jnp.zeros(ch_n, jnp.int32), jnp.zeros(ch_n, jnp.int32),
              jnp.zeros(n_cores, jnp.float32),
              jnp.int32(0), jnp.int32(0), jnp.int32(0))
    xs = (t_issue.astype(jnp.float32), flat_bank, ch, row, is_write, valid,
          core_id.astype(jnp.int32))
    carry, done = jax.lax.scan(step, carry0, xs)
    return done, carry[7], carry[8], carry[9], carry[10]


# --------------------------------------------------------------------------
# Per-core sub-problems and the end-to-end contention report
# --------------------------------------------------------------------------

def core_subgemm(dataflow: str, M: int, N: int, K: int, share: int,
                 scheme: str, Pr: int, Pc: int) -> Tuple[int, int, int]:
    """(M, N, K) of the sub-GEMM a core with `share` units of the split
    dimension executes under a partition scheme (mirrors the per-core
    cycle formulas in `simulate_multicore`)."""
    Sr, Sc, T = dfm.map_gemm(dataflow, M, N, K)
    if scheme == "spatial":
        sub = (share, -(-Sc // Pc), T)
    elif scheme == "st1":
        sub = (share, Sc, -(-T // Pc))
    elif scheme == "st2":
        sub = (Sr, share, -(-T // Pr))
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    m, n, k = dfm.unmap_gemm(dataflow, *sub)
    return max(1, int(m)), max(1, int(n)), max(1, int(k))


def _route(addr: jnp.ndarray, core: int, channels: int, burst: int,
           private: bool) -> jnp.ndarray:
    """Place core `core`'s local addresses in the shared address space."""
    if private:
        b = addr // burst
        # Cores pinned to the same channel (when num_cores > channels)
        # get disjoint row regions — without this, cores 0 and `channels`
        # would alias onto byte-identical banks/rows and harvest spurious
        # row hits from each other's streams.
        b = b + (core // channels) * (_CORE_SPAN // burst)
        return (b * channels + core % channels) * burst + addr % burst
    return addr + core * _CORE_SPAN


@dataclasses.dataclass(frozen=True)
class ContentionResult:
    """Isolated vs shared-DRAM stalls per core (+ merged row stats)."""
    per_core_stall_isolated: Tuple[float, ...]
    per_core_stall_shared: Tuple[float, ...]
    per_core_compute: Tuple[float, ...]
    scheme: str
    private_channels: bool
    row_hits: int
    row_misses: int
    row_conflicts: int
    makespan_isolated: float          # max over cores: compute + NoP + stall
    makespan_shared: float
    # row stats count the scale-compressed merged stream (they saturate
    # near spec.cap * n_cores); multiply by this factor for absolute-scale
    # estimates, as with gemm_trace_stats' scaled_by
    scaled_by: float = 1.0

    @property
    def stall_inflation(self) -> Tuple[float, ...]:
        """Shared / isolated stall per core (1.0 = no contention; inf when
        a core that never stalled alone is delayed by neighbors)."""
        return tuple(s / i if i > 0 else
                     (float("inf") if s > 1e-9 else 1.0)
                     for s, i in zip(self.per_core_stall_shared,
                                     self.per_core_stall_isolated))


def multicore_contention(cfg: AcceleratorConfig, M: int, N: int, K: int,
                         scheme: str = "spatial",
                         private_channels: bool = False,
                         spec: Optional[TraceSpec] = None,
                         engine: Optional[str] = None) -> ContentionResult:
    """Generate per-core traces for one partitioned GEMM and compare the
    isolated DRAM model against the merged shared-channel model.

    Both the isolated and the shared numbers come from the same
    per-channel-queue scan (`simulate_shared_dram`), so the comparison is
    apples-to-apples; absolute stall values are not directly comparable
    with `simulate_dram`'s single global-queue model (TraceDramStage),
    which bounds in-flight requests across all channels together.
    """
    from ..core.multicore import simulate_multicore
    spec = spec or DEFAULT_SPEC
    mc = simulate_multicore(cfg, M, N, K, scheme)
    df = cfg.dataflow
    wb = cfg.memory.word_bytes
    n_cores = cfg.num_cores

    # trace addresses are int32; fail loudly instead of silently wrapping
    # core regions onto each other (shared routing spans n_cores regions,
    # private routing spans ceil(n_cores/channels) * channels)
    ch = cfg.dram.channels
    groups = (n_cores - 1) // ch + 1
    span_factor = groups * ch if private_channels else n_cores
    if span_factor * _CORE_SPAN > 2 ** 31:
        raise ValueError(
            f"{n_cores} cores over {ch} channels needs "
            f"{span_factor} x {_CORE_SPAN} bytes of shared address space, "
            "which overflows the int32 trace addresses; reduce the core "
            "count (<= 16 cores fit)")

    # per-core sub-GEMMs, traffic and compute windows --------------------
    subs, comps, regions = [], [], []
    for idx, core in enumerate(cfg.cores):
        m, n, k = core_subgemm(df, M, N, K, mc.per_core_share[idx],
                               scheme, mc.Pr, mc.Pc)
        subs.append((m, n, k))
        comps.append(float(dfm.compute_cycles(df, m, n, k,
                                              core.rows, core.cols)))
        dram = dfm.dram_traffic(df, m, n, k, core.rows, core.cols,
                                cfg.memory)
        regions.append(tuple(float(dram[key]) for key in
                             ("dram_ifmap", "dram_filter",
                              "dram_ofmap_writes", "dram_ofmap_reads")))

    # one common compression factor so every core's stream (and compute
    # window) is squeezed coherently before merging
    n_totals = [sum(r) * wb / spec.gran_bytes for r in regions]
    common_scale = max(1.0, max(n_totals) / spec.cap)

    # per-core arrival skew over the NoP: the legacy hop offset when the
    # NoC plane is disabled (bit-identical to the old inline expression),
    # or routed zero-load latency + router queueing when enabled — the
    # repro.noc plane feeding the shared-DRAM queues
    from ..noc.stage import noc_arrival_skew
    skew = noc_arrival_skew(
        cfg, [sum(r) * wb for r in regions], max(comps) if comps else 0.0)

    per_core = []
    for idx, core in enumerate(cfg.cores):
        m, n, k = subs[idx]
        t, addr, w, valid, _ = gemm_request_stream(
            df, m, n, k, core.rows, core.cols, comps[idx],
            *regions[idx], wb, spec, scale=common_scale)
        # issue times live on the scale-compressed axis; the real-cycle
        # NoP offset must be compressed the same way or it decorrelates
        # the cores by cap-dependent amounts after the final rescale
        t = jnp.where(valid, t + float(skew[idx]) / common_scale, _BIG_T)
        addr = _route(addr, idx, cfg.dram.channels,
                      cfg.dram.burst_bytes, private_channels)
        per_core.append((t, addr, w, valid))

    def run(t, a, w, v, cid, nc):
        order = jnp.argsort(jnp.where(v, t, _BIG_T))
        # The isolated-vs-shared comparison (and the exact private-channel
        # decomposition invariant) needs both runs at the true fixed point,
        # not the sweep default's tolerance-bounded relaxation: this is an
        # eager analysis path, so iterate the adaptive escape to tol=0.
        return simulate_shared_dram(t[order], a[order], w[order],
                                    cid[order], v[order], nc, cfg.dram,
                                    spec.gran_bytes, engine=engine,
                                    tol=0.0)

    # isolated: each core alone on the (same-routed) memory system
    iso = []
    for idx, (t, a, w, v) in enumerate(per_core):
        res = run(t, a, w, v, jnp.zeros(spec.cap, jnp.int32), 1)
        iso.append(float(res.per_core_stall[0]) * common_scale)

    # shared: merged stream, per-core attribution
    t = jnp.concatenate([pc[0] for pc in per_core])
    a = jnp.concatenate([pc[1] for pc in per_core])
    w = jnp.concatenate([pc[2] for pc in per_core])
    v = jnp.concatenate([pc[3] for pc in per_core])
    cid = jnp.concatenate([jnp.full(spec.cap, i, jnp.int32)
                           for i in range(n_cores)])
    shared = run(t, a, w, v, cid, n_cores)
    shared_stalls = [float(s) * common_scale for s in shared.per_core_stall]

    nop = [float(s) for s in skew]
    return ContentionResult(
        per_core_stall_isolated=tuple(iso),
        per_core_stall_shared=tuple(shared_stalls),
        per_core_compute=tuple(comps),
        scheme=scheme, private_channels=private_channels,
        row_hits=int(shared.row_hits), row_misses=int(shared.row_misses),
        row_conflicts=int(shared.row_conflicts),
        makespan_isolated=max(c + o + s for c, o, s in
                              zip(comps, nop, iso)),
        makespan_shared=max(c + o + s for c, o, s in
                            zip(comps, nop, shared_stalls)),
        scaled_by=common_scale)
