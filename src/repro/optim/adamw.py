"""AdamW with global-norm clipping and cosine schedule (pure pytree ops).

Moments are f32 regardless of param dtype (bf16 params update through an
f32 cast); state shards exactly like the parameters, so FSDP sharding of
params automatically ZeRO-shards the optimizer (the dominant memory term).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr_t * (u + weight_decay * pf)
        return pf.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step, new_m, new_v)
