"""Gradient compression for the cross-pod DP all-reduce.

Per-tensor symmetric int8 quantization with error feedback: the residual
(g - dequant(quant(g))) is carried to the next step, so compression bias
vanishes in expectation (Seide et al. / 1-bit Adam lineage). Intended for
the `pod` axis where links are slowest; 4x traffic reduction on bf16 grads.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def int8_compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_decompress(grads: PyTree, residuals: Optional[PyTree] = None
                        ) -> Tuple[PyTree, PyTree]:
    """Quantize+dequantize each leaf with error feedback; returns
    (compressed-equivalent grads, new residuals). On hardware the int8
    payload is what crosses the pod axis (psum of int32 accumulators)."""
    if residuals is None:
        residuals = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = int8_compress(gf)
        deq = int8_decompress(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, residuals)
    newg = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    newr = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newg, newr
