"""Production mesh construction (multi-pod dry-run spec).

Functions, not module-level constants: importing this module never touches
jax device state (device count locks on first backend init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(tp: int = 1):
    """Whatever this host has (smoke tests / examples)."""
    n = len(jax.devices())
    tp = min(tp, n)
    return jax.make_mesh((n // tp, tp), ("data", "model"))
