"""Loop-aware cost extraction from post-SPMD optimized HLO text.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` reports) counts each
`while` body ONCE — a scan-over-80-layers model reports ~1/80th of its real
FLOPs. This module walks the computation graph with loop-trip multiplicities
(XLA conveniently emits `backend_config={"known_trip_count":{"n":...}}` for
counted loops) and produces per-chip totals:

  flops       : 2*M*N*K for every dot (operand shapes resolved through a
                per-computation symbol table) + convolutions, x trip counts
  hbm_bytes   : result + operand bytes of compute instructions (fusion
                bodies excluded: a fusion reads its operands and writes its
                result once — exactly the HBM traffic model we want)
  collectives : per-kind traffic with ring-algorithm factors per
                replica-group size

Validated against unrolled references in tests/test_hlocost.py.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = dict(pred=1, s8=1, u8=1, s4=1, u4=1, s16=2, u16=2, bf16=2,
                    f16=2, s32=4, u32=4, f32=4, s64=8, u64=8, f64=8, c64=8,
                    c128=16)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)")
# Perfect-fusion HBM model: on TPU every elementwise op fuses into its
# producer/consumer, so HBM traffic is carried by data-moving ops only.
# The CPU backend we compile on fuses far less, so counting every
# instruction would inflate the memory term ~30x (each unfused tanh/add
# would "re-read" the activations). We therefore count bytes only for ops
# that necessarily touch HBM on TPU:
_COUNT_BYTES_OPS = {"dot", "convolution", "gather", "scatter",
                    "dynamic-slice", "dynamic-update-slice", "reduce",
                    "reduce-window", "sort", "copy", "copy-start",
                    "concatenate", "pad", "transpose", "select-and-scatter"}


def _parse_shapes(sig: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(sig):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) \
            else ()
        out.append((dt, dims))
    return out


def _prod(dims) -> float:
    n = 1.0
    for d in dims:
        n *= d
    return n


def _nbytes(shapes) -> float:
    return sum(_prod(dims) * _DTYPE_BYTES[dt] for dt, dims in shapes)


def _ring_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return (g - 1) / g
    if kind == "reduce-scatter":
        return float(g - 1)          # printed shape is the scattered shard
    if kind == "all-to-all":
        return (g - 1) / g
    return 1.0                       # collective-permute


def _opcode(rhs: str) -> str:
    """'f32[1,2]{1,0} dot(%a, %b), ...' -> 'dot'."""
    m = re.match(r"(?:\([^)]*\)|\S+)\s+([\w\-\$]+)", rhs)
    return m.group(1) if m else ""


def _operands(rhs: str) -> List[str]:
    m = re.search(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", rhs)
    if not m:
        return []
    return re.findall(r"%([\w\.\-]+)", m.group(1))


class HloCost:
    def __init__(self, hlo: str):
        self.comps: Dict[str, List[Tuple[str, str, str]]] = {}
        self.shapes: Dict[str, Dict[str, List]] = {}
        self.entry: Optional[str] = None
        cur = None
        for raw in hlo.splitlines():
            line = raw.strip()
            mh = re.match(
                r"(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{", line)
            if mh and " = " not in line:
                cur = mh.group(2)
                self.comps[cur] = []
                self.shapes[cur] = {}
                if mh.group(1):
                    self.entry = cur
                # parameter shapes from the signature
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,)]+)", mh.group(3)):
                    self.shapes[cur][pm.group(1)] = _parse_shapes(pm.group(2))
                continue
            if line == "}":
                cur = None
                continue
            if cur is None or not line or line.startswith("//"):
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, rhs = mi.group(1), mi.group(2)
            self.shapes[cur][name] = _parse_shapes(rhs.split("(", 1)[0])
            self.comps[cur].append((name, _opcode(rhs), rhs))
        self._analyze()

    # -- per-instruction costs ------------------------------------------------
    def _dot_flops(self, comp: str, rhs: str) -> float:
        res = _parse_shapes(rhs.split("(", 1)[0])
        ops = _operands(rhs)
        if not res or not ops:
            return 0.0
        lhs_shape = self.shapes[comp].get(ops[0], [])
        contract = 1.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
        if m and m.group(1) and lhs_shape:
            dims = lhs_shape[0][1]
            for i in m.group(1).split(","):
                idx = int(i)
                if idx < len(dims):
                    contract *= dims[idx]
        return 2.0 * _prod(res[0][1]) * contract

    def _conv_flops(self, comp: str, rhs: str) -> float:
        res = _parse_shapes(rhs.split("(", 1)[0])
        ops = _operands(rhs)
        if not res or len(ops) < 2:
            return 0.0
        rhs_shape = self.shapes[comp].get(ops[1], [])
        if not rhs_shape:
            return 0.0
        kernel = _prod(rhs_shape[0][1])
        out_feat = res[0][1][-1] if res[0][1] else 1
        return 2.0 * _prod(res[0][1]) * kernel / max(out_feat, 1)

    def _inst_bytes(self, comp: str, op: str, rhs: str) -> float:
        if op not in _COUNT_BYTES_OPS:
            return 0.0
        total = _nbytes(_parse_shapes(rhs.split("(", 1)[0]))
        for ref in _operands(rhs):
            total += _nbytes(self.shapes[comp].get(ref, []))
        return total

    # -- graph ------------------------------------------------------------
    def _analyze(self):
        self.local: Dict[str, Dict] = {}
        self.edges: Dict[str, List[Tuple[str, float, str]]] = {}
        for name, instrs in self.comps.items():
            flops = 0.0
            bytes_ = 0.0
            colls: List[Dict] = []
            edges: List[Tuple[str, float, str]] = []
            for iname, op, rhs in instrs:
                if op == "dot":
                    flops += self._dot_flops(name, rhs)
                elif op == "convolution":
                    flops += self._conv_flops(name, rhs)
                handled = False
                base = op.split("-start")[0]
                if base in COLLECTIVES:
                    b = _nbytes(_parse_shapes(rhs.split("(", 1)[0]))
                    gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
                    g = int(gm.group(2)) if gm else 0
                    if not g:
                        gm2 = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
                        g = len(gm2.group(1).split(",")) if gm2 else 1
                    colls.append(dict(kind=base, bytes=b, group=g,
                                      traffic=b * _ring_factor(base, g)))
                    handled = True
                if not handled:
                    bytes_ += self._inst_bytes(name, op, rhs)
                if op == "while":
                    mb = re.search(r"body=%?([\w\.\-]+)", rhs)
                    mc = re.search(r"condition=%?([\w\.\-]+)", rhs)
                    trip = 1.0
                    mt = re.search(r'known_trip_count[^0-9]*(\d+)', rhs)
                    if mt:
                        trip = float(mt.group(1))
                    elif mc and mc.group(1) in self.comps:
                        consts = [int(x) for x in re.findall(
                            r"constant\((\d+)\)",
                            "\n".join(r for _, _, r in
                                      self.comps[mc.group(1)]))]
                        trip = float(max(consts)) if consts else 1.0
                    if mb:
                        edges.append((mb.group(1), trip, "loop"))
                    if mc:
                        edges.append((mc.group(1), trip, "cond"))
                elif "calls=" in rhs:
                    kind = "fusion" if op == "fusion" else "call"
                    for mm in re.finditer(r"calls=%?([\w\.\-]+)", rhs):
                        edges.append((mm.group(1), 1.0, kind))
                elif op == "conditional":
                    for mm in re.finditer(
                            r"(?:true_computation|false_computation)="
                            r"%?([\w\.\-]+)", rhs):
                        edges.append((mm.group(1), 1.0, "call"))
            self.local[name] = dict(flops=flops, bytes=bytes_, colls=colls)
            self.edges[name] = edges

    def totals(self) -> Dict:
        flops = 0.0
        hbm = 0.0
        coll: Dict[str, Dict] = {}
        stack = set()

        def visit(name: str, mult: float, in_fusion: bool):
            nonlocal flops, hbm
            if name not in self.comps or name in stack:
                return
            stack.add(name)
            loc = self.local[name]
            flops += loc["flops"] * mult
            if not in_fusion:
                hbm += loc["bytes"] * mult
            for c in loc["colls"]:
                a = coll.setdefault(c["kind"], dict(kind=c["kind"], count=0.0,
                                                    bytes=0.0))
                a["count"] += mult
                a["bytes"] += c["traffic"] * mult
            for child, m, kind in self.edges[name]:
                visit(child, mult * m, in_fusion or kind == "fusion")
            stack.discard(name)

        visit(self.entry or next(iter(self.comps), ""), 1.0, False)
        return dict(
            flops=flops, hbm_bytes=hbm,
            collective_bytes=sum(a["bytes"] for a in coll.values()),
            collectives=sorted(coll.values(), key=lambda a: -a["bytes"]))
