"""Training driver: fault-tolerant loop usable from one host to a pod.

Wires together every substrate: model zoo, synthetic data pipeline, AdamW,
async/atomic checkpointing with preemption handling, straggler detection,
elastic remesh planning, and optional cross-pod gradient compression.

On this CPU container it runs real (small) configs end-to-end; on hardware
the same file drives the production mesh (the jit'd step is identical —
only the mesh changes).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--sim-accel", default="",
                    help="accelerator preset (repro.api): report the modeled"
                         " per-step hardware cost before training")
    args = ap.parse_args()

    from ..checkpoint import CheckpointManager, PreemptionHandler
    from ..configs import get_config
    from ..data.pipeline import DataConfig, SyntheticLMDataset
    from ..dist.sharding import make_mesh_ctx
    from ..dist.straggler import StragglerDetector
    from ..models.zoo import ModelBundle
    from ..optim import adamw_init, cosine_schedule
    from .mesh import make_host_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = ModelBundle(cfg)
    mesh = make_host_mesh(tp=args.tp)
    ctx = make_mesh_ctx(mesh) if mesh.size > 1 else None

    if args.sim_accel:
        # co-simulation (unified Simulator API): modeled cost of one train
        # step of the FULL-SIZE arch on the chosen accelerator preset
        from ..api import Simulator
        sim = Simulator(args.sim_accel)
        rep = sim.run_lm(get_config(args.arch), seq=args.seq,
                         batch=args.batch, mode="train")
        print(f"[sim:{args.sim_accel}] modeled train step: "
              f"{sim.seconds(rep.total_cycles) * 1e3:.2f} ms"
              f", {rep.energy_pj * 1e-9:.1f} mJ, "
              f"util={rep.utilization:.2f}", flush=True)

    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    opt = adamw_init(params)
    lr = cosine_schedule(args.lr, warmup=max(5, args.steps // 20),
                         total=args.steps)

    data = SyntheticLMDataset(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                         global_batch=args.batch))
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    def full_step(params, opt_state, batch):
        from ..optim import adamw_update, clip_by_global_norm
        loss_fn = bundle.loss_fn(ctx)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if args.compress_pod_grads:
            from ..optim.compress import compress_decompress
            grads, _ = compress_decompress(grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    step_fn = jax.jit(full_step, donate_argnums=(0, 1))
    pre = PreemptionHandler(lambda: ckpt.save(step, {"params": params,
                                                     "opt": opt},
                                              blocking=True))
    det = StragglerDetector()

    losses = []
    for step in range(start, args.steps):
        t0 = time.time()
        np_batch = data.global_batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                        jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        params, opt, metrics = step_fn(params, opt, batch)
        dt = time.time() - t0
        det.record(0, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step}: loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s",
                  flush=True)
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt})
        if pre.checkpoint_if_preempted():
            print("preempted: checkpoint saved, exiting cleanly")
            return
    ckpt.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"done. loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
