import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first backend init). 512 placeholder host devices let
# jax.make_mesh build the production meshes; nothing is ever allocated —
# every input is a ShapeDtypeStruct.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell:
  jit(step, in_shardings=..., donate...).lower(**ShapeDtypeStructs).compile()
  -> memory_analysis()   (per-device bytes: args / temp / peak)
  -> cost_analysis()     (per-device HLO FLOPs + bytes accessed)
  -> post-SPMD HLO text  -> per-chip collective bytes (while-loop trip counts
     multiply collectives inside scanned layer bodies; ring-algorithm
     factors per replica-group size)
  -> roofline terms (TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM,
     50 GB/s/link ICI) -> JSON in experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --jobs 2        # orchestrate subprocesses
"""
import argparse
import json
import re
import subprocess
import sys
import time
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
HBM_BYTES = 16 * 1024**3     # v5e-class capacity

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

def model_flops(cfg, *, seq: int, batch: int, mode: str) -> float:
    n = cfg.active_param_count()
    if mode == "train":
        return 6.0 * n * seq * batch
    if mode == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch           # decode: one token per sequence


def run_cell(arch: str, shape: str, mesh_kind: str,
             sp_mode: str = "megatron", serve_params: bool = False,
             accum: int = 1, sim_accel: str = "") -> Dict:
    import dataclasses
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..configs import get_config
    from ..configs.shapes import SHAPES, skip_reason
    from ..dist.sharding import make_mesh_ctx
    from ..models.zoo import ModelBundle
    from .mesh import make_production_mesh

    cfg = dataclasses.replace(get_config(arch), sp_mode=sp_mode)
    reason = skip_reason(cfg, shape)
    if reason:
        return dict(arch=arch, shape=shape, mesh=mesh_kind, skipped=reason)
    spec = SHAPES[shape]
    seq, batch, mode = spec["seq"], spec["batch"], spec["mode"]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    ctx = make_mesh_ctx(mesh)
    chips = mesh.size
    bundle = ModelBundle(cfg)

    t0 = time.time()
    with jax.set_mesh(mesh):
        param_sds = bundle.param_sds()
        param_sh = bundle.param_shardings(
            ctx, serve=serve_params and mode != "train")
        if mode == "train":
            opt_sds = bundle.opt_sds()
            opt_sh = bundle.opt_shardings(ctx)
            batch_sds = bundle.batch_sds(seq=seq, batch=batch, mode="train")
            batch_sh = bundle.batch_shardings(ctx, seq=seq, batch=batch,
                                              mode="train")
            fn = bundle.train_step(ctx, accum=accum)
            jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                             out_shardings=(param_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(param_sds, opt_sds, batch_sds)
        elif mode == "prefill":
            batch_sds = bundle.batch_sds(seq=seq, batch=batch, mode="prefill")
            batch_sh = bundle.batch_shardings(ctx, seq=seq, batch=batch,
                                              mode="prefill")
            fn = bundle.prefill_step(ctx)
            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(param_sds, batch_sds)
        else:                         # decode
            cache_sds = bundle.cache_sds(batch=batch, cache_len=seq)
            cache_sh = bundle.cache_shardings(ctx, batch=batch, cache_len=seq)
            tok_sds = jax.ShapeDtypeStruct((batch, 1), jax.numpy.int32)
            dp = ctx.dp_axes if batch % ctx.dp == 0 else None
            tok_sh = NamedSharding(mesh, P(dp, None))
            len_sds = jax.ShapeDtypeStruct((), jax.numpy.int32)
            fn = bundle.decode_step(ctx)
            jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh, tok_sh,
                                               NamedSharding(mesh, P())),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(param_sds, cache_sds, tok_sds, len_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # old jax: list of per-exec dicts
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    from .hlocost import HloCost
    hc = HloCost(hlo).totals()
    per_chip_coll, coll_detail = hc["collective_bytes"], hc["collectives"]

    # loop-aware per-device costs (XLA's cost_analysis counts while bodies
    # once; see launch/hlocost.py) — raw XLA numbers kept for reference.
    flops_dev = float(hc["flops"])
    bytes_dev = float(hc["hbm_bytes"])
    mf = model_flops(cfg, seq=seq, batch=batch, mode=mode)
    terms = dict(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=per_chip_coll / LINK_BW,
    )
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    step_flops_total = flops_dev * chips
    result = dict(
        arch=arch, shape=shape, mesh=mesh_kind, chips=chips, mode=mode,
        sp_mode=sp_mode, serve_params=serve_params, accum=accum,
        seq=seq, batch=batch,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        peak_bytes_per_device=int(getattr(ma, "peak_memory_in_bytes", 0)
                                  or (ma.argument_size_in_bytes
                                      + ma.temp_size_in_bytes)),
        arg_bytes_per_device=int(ma.argument_size_in_bytes),
        temp_bytes_per_device=int(ma.temp_size_in_bytes),
        out_bytes_per_device=int(ma.output_size_in_bytes),
        fits_hbm=bool((ma.argument_size_in_bytes + ma.temp_size_in_bytes)
                      < HBM_BYTES),
        hlo_flops_per_device=flops_dev,
        hlo_bytes_per_device=bytes_dev,
        xla_flops_once=float(cost.get("flops", 0.0)),
        xla_bytes_once=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_chip=per_chip_coll,
        collectives=coll_detail[:8],
        model_flops=mf,
        useful_flops_ratio=mf / max(step_flops_total, 1.0),
        terms=terms, dominant=dominant,
        roofline_bound_s=bound,
        mfu_vs_roofline=terms["compute_s"] / max(bound, 1e-12),
        ok=True,
    )
    if sim_accel:
        # attach the simulation plane's view of the same cell (unified
        # Simulator API) next to the XLA roofline terms
        from ..api import Simulator
        sim = Simulator(sim_accel)
        rep = sim.run_lm(cfg, seq=seq, batch=batch, mode=mode)
        result["sim_accel"] = dict(
            preset=sim_accel,
            total_cycles=rep.total_cycles,
            stall_cycles=rep.stall_cycles,
            energy_pj=rep.energy_pj,
            utilization=rep.utilization,
            modeled_s=sim.seconds(rep.total_cycles))
    return result


def cell_list() -> List[Tuple[str, str, str]]:
    from ..configs import list_archs, get_config
    from ..configs.shapes import SHAPES, skip_reason
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(cfg, shape):
                continue
            for mesh in ("pod", "multipod"):
                cells.append((arch, shape, mesh))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--missing-only", action="store_true")
    ap.add_argument("--sp-mode", default="megatron",
                    choices=["megatron", "weightgather"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--serve-params", action="store_true",
                    help="decode/prefill: TP-resident weights (no FSDP gather)")
    ap.add_argument("--accum", type=int, default=1,
                    help="train: gradient-accumulation microbatches")
    ap.add_argument("--sim-accel", default="",
                    help="accelerator preset (repro.api): attach the "
                         "simulation plane's cost model to each cell")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = cell_list()
        if args.missing_only:
            cells = [(a, s, m) for a, s, m in cells if not os.path.exists(
                os.path.join(args.out, f"{a}__{s}__{m}.json"))]
        procs: List = []
        for a, s, m in cells:
            while len(procs) >= args.jobs:
                for p in list(procs):
                    if p.poll() is not None:
                        procs.remove(p)
                time.sleep(1)
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                   "--shape", s, "--mesh", m, "--out", args.out]
            if args.sim_accel:
                cmd += ["--sim-accel", args.sim_accel]
            print("launch:", a, s, m, flush=True)
            procs.append(subprocess.Popen(cmd))
        for p in procs:
            p.wait()
        return

    res = run_cell(args.arch, args.shape, args.mesh, sp_mode=args.sp_mode,
                   serve_params=args.serve_params, accum=args.accum,
                   sim_accel=args.sim_accel)
    tag = f"__{args.tag}" if args.tag else ""
    path = os.path.join(args.out,
                        f"{args.arch}__{args.shape}__{args.mesh}{tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("collectives",)}, indent=1, default=float))


if __name__ == "__main__":
    main()
