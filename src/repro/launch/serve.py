"""Serving driver: batched prefill + decode with continuous batching.

A minimal production-shaped server loop: requests arrive with prompts,
are prefetched into a batch, prefilled once, then decoded step-by-step;
finished sequences free their batch slots for queued requests (continuous
batching). On CPU it runs the reduced configs; the jit'd prefill/decode
steps are the same ones the multi-pod dry-run lowers at scale.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--sim-accel", default="",
                    help="accelerator preset (repro.api): report the modeled"
                         " hardware cost of the served traffic")
    args = ap.parse_args()

    from ..configs import get_config
    from ..models.zoo import ModelBundle

    sim = None
    if args.sim_accel:
        from ..api import Simulator
        sim = Simulator(args.sim_accel)      # fail fast on unknown presets

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = ModelBundle(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)

    B = args.batch
    max_len = args.prompt_len + args.gen_len
    prefill = jax.jit(bundle.prefill_step(None))
    decode = jax.jit(bundle.decode_step(None), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    queue: List[np.ndarray] = [
        rng.integers(1, min(cfg.vocab, 1000), size=args.prompt_len,
                     dtype=np.int32)
        for _ in range(args.requests)]
    done = 0
    t0 = time.time()
    tokens_out = 0

    while queue or done < args.requests:
        wave = [queue.pop(0) for _ in range(min(B, len(queue)))]
        if not wave:
            break
        while len(wave) < B:                     # pad the batch
            wave.append(np.zeros(args.prompt_len, np.int32))
        batch = {"tokens": jnp.asarray(np.stack(wave))}
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((B, args.prompt_len, cfg.d_model),
                                        jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        logits, _ = prefill(params, batch)
        # decode against a fresh fixed-size cache (prefill cache is sized to
        # the prompt; serving uses max_len slots)
        cache = bundle.init_cache(batch=B, cache_len=max_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        generated = [tok]
        for i in range(args.gen_len - 1):
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(args.prompt_len + i))
            if args.temperature > 0:
                key2 = jax.random.fold_in(key, i)
                tok = jax.random.categorical(
                    key2, logits / args.temperature, -1)[:, None]
            else:
                tok = jnp.argmax(logits, -1)[:, None]
            tok = tok.astype(jnp.int32)
            generated.append(tok)
        out = jnp.concatenate(generated, 1)
        done += len([w for w in wave if w.any()])
        tokens_out += int(out.size)
        print(f"wave done: {out.shape[0]} seqs x {out.shape[1]} tokens; "
              f"sample: {np.asarray(out[0, :8]).tolist()}", flush=True)

    dt = time.time() - t0
    print(f"served {done} requests, {tokens_out} tokens in {dt:.2f}s "
          f"({tokens_out / dt:.1f} tok/s)")

    if sim is not None:
        # co-simulation: what the same traffic costs on modeled silicon
        # (one Simulator session; full-size arch, not the smoke config)
        full_cfg = get_config(args.arch)
        pre = sim.run_lm(full_cfg, seq=args.prompt_len, batch=B,
                         mode="prefill")
        dec = sim.run_lm(full_cfg, seq=args.prompt_len, batch=B,
                         mode="decode", cache_len=max_len)
        per_wave, e_wave = sim.wave_cost(pre, dec, args.gen_len)
        print(f"[sim:{args.sim_accel}] modeled wave: "
              f"{sim.seconds(per_wave) * 1e3:.2f} ms, "
              f"{e_wave * 1e-9:.1f} mJ "
              f"({e_wave * 1e-12 / max(B * args.gen_len, 1) * 1e3:.3f} "
              f"mJ/token)")


if __name__ == "__main__":
    main()
