"""The `Simulator` facade: one session object over the stage pipeline.

    sim = Simulator("paper-32", fidelity="fast")
    report = sim.run(resnet18())            # NetworkReport
    res = sim.sweep(configs, ops)           # batched DSE over a config grid

A Simulator binds (config, fidelity, ERT) once; every entrypoint then runs
the same stage pipeline (`core/stages.py`). `sweep` is the batched path:
it stacks per-config scalars into arrays, vmaps the *traced* stage twins
over the design axis inside a single jit, and optionally shards the design
axis over a device mesh (reusing `launch/mesh.py` meshes) — this is how
thousands of design points per second are served from one process or a pod.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dataflow as dfm
from ..core import stages as st
from ..core.accelerator import (AcceleratorConfig, DramConfig, MemoryConfig,
                                SparsityConfig)
from ..core.energy import DEFAULT_ERT, ERT, energy_pj
from ..core.engine import (_ENERGY_GROUPS, NetworkReport, OpResult,
                           simulate_network, simulate_op)
from ..core.workloads import PAPER_WORKLOADS, Op
from .presets import get_preset

ConfigLike = Union[AcceleratorConfig, dict, str]
WorkloadLike = Union[Sequence[Op], str]


def as_config(c: ConfigLike) -> AcceleratorConfig:
    """Preset name | nested dict | AcceleratorConfig -> AcceleratorConfig."""
    if isinstance(c, AcceleratorConfig):
        return c
    if isinstance(c, str):
        return get_preset(c)
    if isinstance(c, dict):
        return AcceleratorConfig.from_dict(c)
    raise TypeError(f"cannot build AcceleratorConfig from {type(c)!r}")


def as_workload(w: WorkloadLike) -> List[Op]:
    """Op sequence or paper-workload name ('resnet18', 'vit_base', ...)."""
    if isinstance(w, str):
        if w not in PAPER_WORKLOADS:
            raise KeyError(f"unknown workload {w!r}; "
                           f"available: {sorted(PAPER_WORKLOADS)}")
        return PAPER_WORKLOADS[w]()
    return list(w)


@dataclasses.dataclass
class SweepResult:
    """Per-design-point totals over one workload (arrays of shape (n,))."""
    configs: List[AcceleratorConfig]
    total_cycles: np.ndarray
    compute_cycles: np.ndarray
    stall_cycles: np.ndarray
    dram_bytes: np.ndarray
    energy_pj: np.ndarray
    utilization: np.ndarray
    batched: bool = True          # False when the python fallback ran
    # resolved runtime replay-engine label of the sweep's DRAM replay
    # ('' for fidelities that replay nothing) — see NetworkReport.engine
    engine: str = ""

    @property
    def edp(self) -> np.ndarray:
        return self.energy_pj * 1e-9 * self.total_cycles

    def __len__(self) -> int:
        return len(self.configs)

    def argbest(self, objective: str = "edp") -> int:
        key = dict(edp=self.edp, latency=self.total_cycles,
                   cycles=self.total_cycles, energy=self.energy_pj)
        return int(np.argmin(key[objective]))

    def best(self, objective: str = "edp") -> AcceleratorConfig:
        return self.configs[self.argbest(objective)]


# Every AcceleratorConfig is traceable: sparsity (layer-wise and expected
# row-wise), layout bank-conflict slowdown and the multi-core partition all
# run inside the sweep kernel (core/stages.py traced twins), with the core
# grid shape / layout fields / sparse representation as static kernel
# flavors. The per-op engine remains reachable for 'cycle' fidelity,
# custom evaluators, and the Study `force_fallback` oracle mode that the
# differential parity suite exercises (tests/test_sweep_parity.py).


class Simulator:
    """Unified simulation session: config + fidelity + ERT, one pipeline.

    fidelity: 'fast' (first-order DRAM stalls, traceable/batchable),
    'cycle' (lax.scan DRAM timing over a synthetic prefetch stream) or
    'trace' (dataflow-aware generated demand traces through the same
    timing model — batchable like 'fast': the `repro.trace` generators
    are fixed-shape and vmappable).

    trace_spec: optional `repro.trace.TraceSpec` shared by the per-op
    pipeline and the batched sweep (so both paths agree bit-for-bit on
    the generated streams).

    core_index: the core a heterogeneous mesh is analyzed through — every
    core-dependent stage (mapping, sparsity, sram, dram, layout) models
    this member.

    engine: DRAM replay engine for the cycle/trace fidelities —
    None (default: the chunked bank-parallel replay, `core.replay`),
    "xla", "pallas", or "reference" (the original per-request scan).
    """

    def __init__(self, config: ConfigLike = "paper-32", *,
                 fidelity: str = "fast", ert: ERT = DEFAULT_ERT,
                 trace_spec=None, core_index: int = 0,
                 engine: Optional[str] = None):
        from ..core import replay as _rp
        if fidelity not in st.FIDELITIES:
            raise ValueError(f"fidelity must be one of {st.FIDELITIES}")
        self.config = as_config(config)
        self.fidelity = fidelity
        self.ert = ert
        self.core_index = core_index
        self.engine = _rp.resolve_engine(engine)
        if trace_spec is None and fidelity == "trace":
            from ..trace.generator import DEFAULT_SPEC
            trace_spec = DEFAULT_SPEC
        self.trace_spec = trace_spec
        self.pipeline = st.build_pipeline(fidelity, core_index=core_index,
                                          trace_spec=trace_spec,
                                          engine=self.engine)

    @classmethod
    def from_preset(cls, name: str, *, fidelity: str = "fast",
                    ert: ERT = DEFAULT_ERT, trace_spec=None,
                    core_index: int = 0, engine: Optional[str] = None,
                    **kw) -> "Simulator":
        return cls(get_preset(name, **kw), fidelity=fidelity, ert=ert,
                   trace_spec=trace_spec, core_index=core_index,
                   engine=engine)

    def with_(self, **config_fields) -> "Simulator":
        """New session with dataclass fields replaced on the config."""
        return Simulator(self.config.with_(**config_fields),
                         fidelity=self.fidelity, ert=self.ert,
                         trace_spec=self.trace_spec,
                         core_index=self.core_index,
                         engine=self.engine)

    def stage_names(self) -> List[str]:
        return [s.name for s in self.pipeline]

    # ---- single-config entrypoints ----------------------------------------
    def run_op(self, op: Op) -> OpResult:
        return simulate_op(self.config, op, dram_fidelity=self.fidelity,
                           ert=self.ert, pipeline=self.pipeline)

    def run(self, workload: WorkloadLike) -> NetworkReport:
        return simulate_network(self.config, as_workload(workload),
                                dram_fidelity=self.fidelity, ert=self.ert,
                                pipeline=self.pipeline)

    def run_lm(self, model_cfg, *, seq: int, batch: int, mode: str,
               cache_len: Optional[int] = None) -> NetworkReport:
        """Model one step of an LM architecture (repro.configs ModelConfig)
        on this accelerator — the co-simulation entrypoint shared by the
        train/serve/dryrun drivers and examples."""
        from ..core.workloads import lm_ops
        return self.run(lm_ops(model_cfg, seq=seq, batch=batch, mode=mode,
                               cache_len=cache_len))

    def seconds(self, cycles: float) -> float:
        """Accelerator cycles -> wall seconds at this config's clock."""
        return cycles / (self.config.clock_ghz * 1e9)

    @staticmethod
    def wave_cost(prefill_rep: NetworkReport, decode_rep: NetworkReport,
                  gen_len: int) -> tuple:
        """(cycles, pJ) for one serving wave: a prefill plus gen_len - 1
        decode steps (the first generated token comes out of prefill)."""
        steps = max(gen_len - 1, 0)
        return (prefill_rep.total_cycles + decode_rep.total_cycles * steps,
                prefill_rep.energy_pj + decode_rep.energy_pj * steps)

    # ---- batched sweep -----------------------------------------------------
    def sweep(self, configs: Sequence[ConfigLike], workload: WorkloadLike,
              *, mesh: Optional[jax.sharding.Mesh] = None,
              force_fallback: bool = False) -> SweepResult:
        """Simulate `workload` on every config; one jitted/vmapped call per
        static kernel flavor (dataflow, word_bytes, core grid, layout,
        sparse representation[, dram]) group.

        .. deprecated:: `sweep` is now a thin wrapper over a one-workload
           `repro.api.study.Study` — the one execution path for
           designs x workloads x fidelity studies. Prefer building a
           `Study` for new code (cross-product axes, columnar result
           frame, on-disk cell cache); this wrapper stays so existing
           call sites keep working (parity: tests/test_api.py).

        mesh: shard the design axis over a device mesh (launch/mesh.py);
        the grid is padded to a multiple of mesh.size.
        Every config batches at 'fast' and 'trace' fidelity — sparsity,
        layout and multi-core partitioning are evaluated inside the
        kernel; only 'cycle' fidelity runs through the per-op engine.
        force_fallback: run every cell through the per-op engine oracle
        instead (the differential-parity reference; tests only).
        """
        from .study import Study
        cfgs = [as_config(c) for c in configs]
        if not cfgs:                     # pre-Study contract: empty grid
            empty = np.zeros(0)          # -> empty result, not an error
            return SweepResult(configs=[], batched=True,
                               **{k: empty for k in
                                  ("total_cycles", "compute_cycles",
                                   "stall_cycles", "dram_bytes",
                                   "energy_pj", "utilization")})
        frame = (Study()
                 .designs(cfgs)
                 .workloads({"workload": as_workload(workload)})
                 .fidelity(self.fidelity)
                 .options(ert=self.ert, engine=self.engine,
                          trace_spec=self.trace_spec,
                          core_index=self.core_index,
                          force_fallback=force_fallback)
                 .run(mesh=mesh))
        return SweepResult(
            configs=cfgs,
            batched=bool(np.all(frame["batched"] > 0)),
            engine=str(frame.meta.get("engine", "")),
            **{k: frame[k] for k in ("total_cycles", "compute_cycles",
                                     "stall_cycles", "dram_bytes",
                                     "energy_pj", "utilization")})


# Compiled sweep kernels persist for the life of the process, keyed by the
# static pipeline flavor (dataflow, word size, ERT, DramConfig, TraceSpec,
# replay engine, stream sharing) — NOT per Simulator instance, so a fresh
# `Simulator(...)` rerunning the same grid reuses the jitted executable
# instead of re-tracing. Unbounded on purpose: entries are tiny relative
# to their retrace cost and the key space is the set of distinct pipeline
# flavors a process actually sweeps.
_SWEEP_FN_CACHE: Dict[tuple, object] = {}


def _batched_design_fn(dataflow: str, word_bytes: int, ert: ERT,
                       dram: Optional[DramConfig] = None, spec=None,
                       engine: Optional[str] = None,
                       mesh_shape: tuple = (1, 1),
                       layout=None, r_cap: int = 0,
                       representation: str = "ellpack_block",
                       with_sparsity: bool = False,
                       noc: Optional[str] = None):
    """Jitted (vmap over designs) sweep kernel, cached module-wide (see
    `_SWEEP_FN_CACHE`) so repeated sweeps — benchmark loops, serving
    traffic, new Simulator sessions — reuse the compiled executable.

    Every config feature is either data (sparsity n/m/row-wise/enabled,
    per-core geometry and NoP hops) vmapped over the design axis, or a
    static kernel flavor baked into the cache key: `mesh_shape` (the
    core grid — sweeps group by core count the way they group by
    dataflow), `layout` (on/off plus the LayoutConfig bank/port/step
    fields shaping the conflict model; None skips the layout math
    entirely — the plan groups enabled and disabled cells separately),
    `r_cap` (static bound on array rows for the layout window) and the
    sparse metadata `representation`.

    With `dram` set (trace fidelity), the first-order stall is replaced by
    the cycle-accurate stall of each op's generated demand trace.  The
    demand stream of a design is fully determined by (array geometry,
    memory sizing, sparsity, core grid) — the *effective* compute window
    and the compressed filter traffic feed the prefetch scheduler — so
    the sweep generates and replays one stream per unique `sdesign` row
    and gathers per-design stalls through `smap` (designs that differ
    only in bandwidth/SIMD/energy terms share the replay).  The address
    decode (`decode_requests`) is hoisted out of the per-design closure:
    the grouped sweep guarantees a common (streams, ops, cap) shape, so
    the whole address batch decodes in one call before the replay vmap.
    """
    from ..core import replay as _rp
    engine = _rp.resolve_engine(engine)
    # key on the *runtime-resolved* label ("pallas" -> "pallas:twin" /
    # "pallas:interpret" off-TPU), not the requested name: a "pallas"
    # sweep must never alias an "xla" cache entry, and the label in the
    # key matches what result metadata reports
    key = (dataflow, word_bytes, ert, dram, spec,
           _rp.resolve_engine_runtime(engine), mesh_shape,
           layout, r_cap, representation, with_sparsity, noc)
    cached = _SWEEP_FN_CACHE.get(key)
    if cached is not None:
        return cached
    if dram is not None:
        from ..core.dram import decode_requests, replay_requests
        from ..trace.generator import DEFAULT_SPEC, gemm_request_stream
        spec = spec or DEFAULT_SPEC
    Pr, Pc = mesh_shape
    num_cores = Pr * Pc

    def _mem(d):
        return MemoryConfig(ifmap_sram_bytes=d["if_b"],
                            filter_sram_bytes=d["f_b"],
                            ofmap_sram_bytes=d["o_b"],
                            l2_sram_bytes=d["l2_b"], word_bytes=word_bytes)

    def _features(d, ov, on, om):
        """The traced feature dicts of one design (static structure,
        traced values) for `stages.traced_comp_traffic`. Per-op N:M
        overrides (`Op.sparsity_nm`) mirror `stages.resolve_sparsity`:
        the op's n:m wins and forces the sparsity stage on."""
        sp = mc = None
        if with_sparsity:
            sp = dict(en=jnp.maximum(d["sp_en"], ov),
                      n=jnp.where(ov > 0, on, d["sp_n"]),
                      m=jnp.where(ov > 0, om, d["sp_m"]),
                      rw=d["sp_rw"], representation=representation)
        if num_cores > 1:
            mc = dict(rows=d["mc_R"], cols=d["mc_C"], hops=d["mc_hops"],
                      nop=d["nop"], Pr=Pr, Pc=Pc)
        return sp, mc

    def _op_streams(d, M, N, K, ov, on, om):
        """Generated demand streams for every gemm op of one design,
        driven by the *effective* compute window and the sparsity-shrunk
        DRAM traffic (what the per-op TraceDramStage sees)."""
        mem, R, C = _mem(d), d["R"], d["C"]
        sp, mc = _features(d, ov, on, om)
        comp, _, dr, _ = st.traced_comp_traffic(
            dataflow, M, N, K, R, C, mem, sparsity=sp, multicore=mc)

        def per_op(m, n, k, comp_, di, dfl, dow, dor):
            return gemm_request_stream(dataflow, m, n, k, R, C, comp_,
                                       di, dfl, dow, dor, word_bytes, spec)

        return jax.vmap(per_op)(M, N, K, comp, dr["dram_ifmap"],
                                dr["dram_filter"], dr["dram_ofmap_writes"],
                                dr["dram_ofmap_reads"])

    def _trace_stalls(sdesign, smap, M, N, K, ov, on, om):
        """(designs, ops) cycle-accurate stalls: one replay per unique
        stream design, decode hoisted out of the per-design closure."""

        def _replay(t, fb, ch, row, wbit, val):
            return replay_requests(t, fb, ch, row, wbit, val, dram,
                                   spec.gran_bytes, engine=engine,
                                   ).stall_cycles

        t, addr, wbit, val, scale = jax.vmap(
            _op_streams, in_axes=(0,) + (None,) * 6)(
                sdesign, M, N, K, ov, on, om)
        fb, ch, row = decode_requests(addr, dram)   # one flat decode
        if engine in ("xla", "pallas"):
            # batch-native: the whole (streams, ops) batch goes through
            # one chunk scan ("xla") or one megakernel launch with the
            # batch flattened onto the Pallas grid ("pallas") — never a
            # vmapped per-stream replay, and "pallas" never silently
            # rides the "xla" driver (replay_decoded resolves it to the
            # megakernel on TPU or its interpret/twin form off-TPU)
            stall = _replay(t, fb, ch, row, wbit, val)
        else:
            stall = jax.vmap(jax.vmap(_replay))(t, fb, ch, row, wbit, val)
        return (stall * scale)[smap]

    def one_design(d, M, N, K, cnt, ov, on, om, velems, vcnt, trace_stall):
        mem = _mem(d)
        R, C = d["R"], d["C"]
        sp, mc = _features(d, ov, on, om)
        lay = None if layout is None else dict(cfg=layout, r_cap=r_cap)
        s = st.traced_op_stats(dataflow, M, N, K, R, C, mem, d["bw"],
                               sparsity=sp, multicore=mc, layout=lay)
        stall_per_op = s["stall_cycles"] if trace_stall is None else \
            trace_stall
        comp_t = s["compute_cycles"] * cnt
        stall_t = stall_per_op * cnt
        lay_t = s["layout_extra_cycles"] * cnt
        dram_t = s["dram_bytes"] * cnt
        macs = M * N * K * cnt
        if num_cores > 1:
            pes = jnp.sum(d["mc_R"] * d["mc_C"])
            dim32 = jnp.max(jnp.maximum(d["mc_R"], d["mc_C"])) / 32.0
        else:
            pes = R * C
            dim32 = jnp.maximum(R, C) / 32.0
        counts = st.traced_energy_counts(
            R=R, C=C, mem=mem, cycles=comp_t, macs=macs,
            ifmap_reads=s["ifmap_reads"] * cnt,
            filter_reads=s["filter_reads"] * cnt,
            ofmap_writes=s["ofmap_writes"] * cnt,
            ofmap_reads=s["ofmap_reads"] * cnt,
            dram_bytes=dram_t,
            l2_reads=jnp.where(d["l2_b"] > 0, s["dram_elems"] * cnt, 0.0),
            pes=pes, dim32=dim32)
        e = energy_pj(counts, ert)

        # SIMD sidecar (empty arrays contribute zero); like run_vector,
        # every component scales with count
        v = st.traced_vector_stats(velems, d["lanes"], d["lat"], word_bytes)
        vcyc = v["compute_cycles"] * vcnt
        vdram = v["dram_bytes"] * vcnt
        vel_t = velems * vcnt
        vcounts = st.traced_energy_counts(
            R=R, C=C, mem=mem, cycles=vcyc, macs=jnp.zeros_like(vcyc),
            ifmap_reads=vel_t, filter_reads=jnp.zeros_like(vel_t),
            ofmap_writes=vel_t, ofmap_reads=jnp.zeros_like(vel_t),
            dram_bytes=vdram, pes=pes, dim32=dim32)
        ve = energy_pj(vcounts, ert)
        energy = jnp.sum(e["total"]) + jnp.sum(ve["total"])
        # the grouped-energy column schema shared with NetworkReport
        # (engine._ENERGY_GROUPS) — the Study frame reports these per cell
        groups = {g: sum(jnp.sum(e[a]) + jnp.sum(ve[a]) for a in acts)
                  for g, acts in _ENERGY_GROUPS.items()}

        # routed-NoP plane (repro.noc): flit/credit contention on each
        # op's memory traffic toward the MC at core 0. `noc` (the
        # topology kind) is a static flavor fixing the routing tree; the
        # link parameters are traced design columns. Sparse ops gate to
        # zero like the partition stage (single-core compressed stream).
        noc_cols = {}
        noc_stall_sum = 0.0
        if noc is not None and num_cores > 1:
            from ..noc.router import noc_delay_model
            from ..noc.traffic import allreduce_cycles, memory_flits
            gate = ((1.0 - jnp.maximum(d["sp_en"], ov)) if with_sparsity
                    else jnp.ones_like(M))
            flits = (memory_flits(s["dram_bytes"], num_cores,
                                  d["noc_flit"])[..., None]
                     * jnp.ones(num_cores, jnp.float32))   # (ops, cores)
            ns = noc_delay_model(noc, Pr, Pc, flits, d["noc_bw"],
                                 d["noc_flit"], d["noc_buf"], d["nop"],
                                 s["compute_cycles"])
            ar = allreduce_cycles(noc, Pr, Pc, M * N * word_bytes,
                                  d["noc_bw"], d["noc_flit"], d["noc_buf"],
                                  d["nop"])
            noc_stall_sum = jnp.sum(ns["stall"] * gate * cnt)
            noc_cols = dict(
                noc_stall_cycles=noc_stall_sum,
                noc_link_util=jnp.max(ns["link_util"] * gate),
                allreduce_cycles=jnp.sum(ar * gate * cnt))

        comp = jnp.sum(comp_t) + jnp.sum(vcyc)
        stall = jnp.sum(stall_t)
        lay_sum = jnp.sum(lay_t)
        dram_b = jnp.sum(dram_t) + jnp.sum(vdram)
        total = comp + stall + lay_sum + noc_stall_sum
        util = jnp.minimum(1.0, jnp.sum(macs)
                           / jnp.maximum(1.0, pes * total))
        return dict(total_cycles=total, compute_cycles=comp,
                    stall_cycles=stall, dram_bytes=dram_b,
                    energy_pj=energy, utilization=util, **groups,
                    **noc_cols)

    def fn(design, sdesign, smap, M, N, K, cnt, ov, on, om, velems, vcnt):
        if dram is not None:
            stall = _trace_stalls(sdesign, smap, M, N, K,
                                  ov, on, om)          # (designs, ops)
            return jax.vmap(one_design,
                            in_axes=(0,) + (None,) * 9 + (0,))(
                design, M, N, K, cnt, ov, on, om, velems, vcnt, stall)
        return jax.vmap(
            functools.partial(one_design, trace_stall=None),
            in_axes=(0,) + (None,) * 9)(
                design, M, N, K, cnt, ov, on, om, velems, vcnt)

    return _SWEEP_FN_CACHE.setdefault(key, jax.jit(fn))


def _pow2_cap(n: int) -> int:
    """Smallest power of two >= n (static layout-window row bound —
    bucketed so similar grids share one compiled kernel)."""
    cap = 1
    while cap < n:
        cap *= 2
    return cap


def _sweep_batched(cfgs: Sequence[AcceleratorConfig], ops: Sequence[Op],
                   dataflow: str, word_bytes: int, ert: ERT,
                   mesh: Optional[jax.sharding.Mesh],
                   dram: Optional[DramConfig] = None,
                   spec=None, engine: Optional[str] = None,
                   core_index: int = 0) -> Dict[str, np.ndarray]:
    """Stack config scalars, vmap the traced stages over the design axis.

    The caller (Study.plan) guarantees group-static flavor uniformity:
    every config shares dataflow, word_bytes, the core grid shape, the
    layout fields (when enabled) and the sparse representation.
    """
    n = len(cfgs)
    f32 = np.float32
    ci = core_index
    Pr, Pc = cfgs[0].mesh_rows, cfgs[0].mesh_cols
    num_cores = Pr * Pc
    if any((c.mesh_rows, c.mesh_cols) != (Pr, Pc) for c in cfgs):
        raise ValueError("sweep group mixes core-grid shapes")

    gemms = [o for o in ops if o.kind == "gemm"]
    vecs = [o for o in ops if o.kind == "vector"]
    with_sparsity = (any(c.sparsity.enabled for c in cfgs)
                     or any(o.sparsity_nm is not None for o in gemms))
    # layout on/off is a static kernel flavor: the plan key puts enabled
    # and disabled cells in different groups, so a group is all-or-none
    with_layout = cfgs[0].layout.enabled
    if any(c.layout.enabled != with_layout for c in cfgs):
        raise ValueError(
            "sweep group mixes layout-enabled and -disabled designs")
    layout_key = (dataclasses.replace(cfgs[0].layout, enabled=True)
                  if with_layout else None)
    representation = cfgs[0].sparsity.representation
    r_cap = (_pow2_cap(max(c.cores[ci].rows for c in cfgs))
             if with_layout else 0)

    # Per-op N:M overrides must form a valid SparsityConfig with every
    # design's row_wise flag — mirrors stages.resolve_sparsity, which
    # raises on the per-op oracle path; without this the batched kernel
    # would silently compute what the oracle refuses (e.g. row-wise with
    # n > m/2, or an m past the expected-max grid bound).
    for o in gemms:
        if o.sparsity_nm is not None:
            for rw in {c.sparsity.row_wise for c in cfgs}:
                SparsityConfig(enabled=True, n=o.sparsity_nm[0],
                               m=o.sparsity_nm[1], row_wise=rw)

    # A design's demand stream is fully determined by (array geometry,
    # memory sizing, sparsity, core grid): replay one stream per unique
    # combination and let designs that differ only in bandwidth/SIMD/
    # energy/layout terms share it. The key carries only the fields that
    # feed the stream (not whole CoreConfig/SparsityConfig objects, whose
    # SIMD/seed fields would needlessly fragment the dedup).
    seen: Dict[tuple, int] = {}
    sidx: List[int] = []        # design index of each unique stream
    smap: List[int] = []        # design -> unique stream id
    for i, c in enumerate(cfgs):
        k = (tuple((k_.rows, k_.cols, k_.nop_hops) for k_ in c.cores),
             c.mesh_rows, c.mesh_cols, c.memory,
             (c.sparsity.enabled, c.sparsity.n, c.sparsity.m,
              c.sparsity.row_wise, c.sparsity.representation),
             c.nop_cycles_per_hop)
        if k not in seen:
            seen[k] = len(sidx)
            sidx.append(i)
        smap.append(seen[k])

    M = jnp.asarray([o.M for o in gemms], f32)
    N = jnp.asarray([o.N for o in gemms], f32)
    K = jnp.asarray([o.K for o in gemms], f32)
    cnt = jnp.asarray([o.count for o in gemms], f32)
    ov = jnp.asarray([0.0 if o.sparsity_nm is None else 1.0
                      for o in gemms], f32)
    on = jnp.asarray([1.0 if o.sparsity_nm is None else o.sparsity_nm[0]
                      for o in gemms], f32)
    om = jnp.asarray([1.0 if o.sparsity_nm is None else o.sparsity_nm[1]
                      for o in gemms], f32)
    velems = jnp.asarray([o.vector_elems for o in vecs], f32)
    vcnt = jnp.asarray([o.count for o in vecs], f32)

    cols = {
        "R": [c.cores[ci].rows for c in cfgs],
        "C": [c.cores[ci].cols for c in cfgs],
        "lanes": [c.cores[0].simd_lanes for c in cfgs],
        "lat": [c.cores[0].simd_latency for c in cfgs],
        "if_b": [c.memory.ifmap_sram_bytes for c in cfgs],
        "f_b": [c.memory.filter_sram_bytes for c in cfgs],
        "o_b": [c.memory.ofmap_sram_bytes for c in cfgs],
        "l2_b": [c.memory.l2_sram_bytes for c in cfgs],
        "bw": [c.dram.bandwidth_bytes_per_cycle * c.dram.channels
               for c in cfgs],
    }
    stream_keys = ["R", "C", "if_b", "f_b", "o_b", "l2_b"]
    if with_sparsity:
        cols["sp_en"] = [1.0 if c.sparsity.enabled else 0.0 for c in cfgs]
        cols["sp_n"] = [c.sparsity.n for c in cfgs]
        cols["sp_m"] = [c.sparsity.m for c in cfgs]
        cols["sp_rw"] = [1.0 if c.sparsity.row_wise else 0.0 for c in cfgs]
        stream_keys += ["sp_en", "sp_n", "sp_m", "sp_rw"]
    # routed-NoC flavor: the Study plan key groups by (enabled, topology),
    # so a group is uniform; validate against direct callers anyway
    noc_kind = (cfgs[0].noc.topology
                if cfgs[0].noc.enabled and num_cores > 1 else None)
    if any((c.noc.enabled and num_cores > 1, c.noc.topology if c.noc.enabled
            else None) != (noc_kind is not None, noc_kind) for c in cfgs):
        raise ValueError("sweep group mixes NoC topologies/enablement")
    if num_cores > 1:
        cols["mc_R"] = [[k.rows for k in c.cores] for c in cfgs]
        cols["mc_C"] = [[k.cols for k in c.cores] for c in cfgs]
        if noc_kind is not None:
            # per-core hop columns become routed latencies: dimension-
            # ordered hops to the MC at (0,0) replace the config offsets
            from ..noc.topology import routed_hop_counts
            routed = [float(h) for h in
                      routed_hop_counts(noc_kind, Pr, Pc)]
            cols["mc_hops"] = [list(routed) for _ in cfgs]
        else:
            cols["mc_hops"] = [[k.nop_hops for k in c.cores] for c in cfgs]
        cols["nop"] = [c.nop_cycles_per_hop for c in cfgs]
        stream_keys += ["mc_R", "mc_C", "mc_hops", "nop"]
    if noc_kind is not None:
        cols["noc_bw"] = [c.noc.link_bandwidth_bytes_per_cycle for c in cfgs]
        cols["noc_flit"] = [c.noc.flit_bytes for c in cfgs]
        cols["noc_buf"] = [c.noc.buffer_flits for c in cfgs]
    sdesign = smap_arr = None
    if dram is not None:
        sdesign = {k: jnp.asarray([cols[k][i] for i in sidx], f32)
                   for k in stream_keys}
    pad = 0
    if mesh is not None and mesh.size > 1:
        pad = (-n) % mesh.size
        for v in cols.values():
            v.extend([v[-1]] * pad)
        smap.extend([smap[-1]] * pad)
    if dram is not None:
        smap_arr = jnp.asarray(smap, jnp.int32)
    design = {k: jnp.asarray(v, f32) for k, v in cols.items()}
    if mesh is not None and mesh.size > 1:
        sharding = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(tuple(mesh.axis_names)))
        design = {k: jax.device_put(v, sharding) for k, v in design.items()}

    fn = _batched_design_fn(dataflow, word_bytes, ert, dram, spec,
                            engine=engine, mesh_shape=(Pr, Pc),
                            layout=layout_key, r_cap=r_cap,
                            representation=representation,
                            with_sparsity=with_sparsity, noc=noc_kind)
    res = fn(design, sdesign, smap_arr, M, N, K, cnt, ov, on, om,
             velems, vcnt)
    return {k: np.asarray(v, np.float64)[:n] for k, v in res.items()}
