"""Accelerator preset registry: the one way to construct configs.

Benchmarks, examples, launchers and serving all build `AcceleratorConfig`s
through `get_preset` (or `Simulator(...)` which accepts a preset name), so
a new accelerator model is registered once and becomes available
everywhere — including `Simulator.sweep` grids.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List

from ..core.accelerator import (AcceleratorConfig, CoreConfig, MemoryConfig,
                                tpu_like_config)

_PRESETS: Dict[str, Callable[..., AcceleratorConfig]] = {}


def register_preset(name: str):
    """Decorator: register a config factory under `name`. Factories may
    take keyword arguments (forwarded from `get_preset`)."""
    def deco(fn: Callable[..., AcceleratorConfig]):
        if name in _PRESETS:
            raise ValueError(f"preset {name!r} already registered")
        _PRESETS[name] = fn
        return fn
    return deco


def get_preset(name: str, **kw) -> AcceleratorConfig:
    if name not in _PRESETS:
        raise KeyError(f"unknown preset {name!r}; "
                       f"available: {sorted(_PRESETS)}")
    return _PRESETS[name](**kw)


def list_presets() -> List[str]:
    return sorted(_PRESETS)


def preset_grid(name: str = "tpu-like", *, preset=None, dataflow=None,
                **axes) -> List[AcceleratorConfig]:
    """Cartesian product of preset kwargs -> list of configs for
    `Study.designs` / `Simulator.sweep`, e.g.
    `preset_grid(array=[8, 16], sram_mb=[1, 8])`.

    Two first-class axes beyond factory kwargs, so study grids span
    presets and dataflows without manual list building:

    - `preset=[...]` crosses preset *names* (outermost axis), replacing
      the single `name`;
    - `dataflow=[...]` (innermost axis) is applied to the built config
      via `with_(dataflow=...)`, so it works for every preset whether or
      not its factory takes a dataflow kwarg.
    """
    presets = list(preset) if preset is not None else [name]
    dataflows = list(dataflow) if dataflow is not None else [None]
    keys = list(axes)
    out = []
    for pname in presets:
        for combo in itertools.product(*(axes[k] for k in keys)):
            cfg = get_preset(pname, **dict(zip(keys, combo)))
            for df in dataflows:
                out.append(cfg if df is None else cfg.with_(dataflow=df))
    return out


# --- built-ins --------------------------------------------------------------

register_preset("tpu-like")(tpu_like_config)


@register_preset("paper-32")
def _paper_32(**kw) -> AcceleratorConfig:
    """The paper's default single-core 32x32 WS array."""
    return tpu_like_config(array=32, **kw)


@register_preset("paper-64")
def _paper_64(**kw) -> AcceleratorConfig:
    return tpu_like_config(array=64, **kw)


@register_preset("paper-128")
def _paper_128(**kw) -> AcceleratorConfig:
    """TPU-class 128x128 MXU (Table V's big design point)."""
    return tpu_like_config(array=128, **kw)


@register_preset("multicore-16x32")
def _multicore(**kw) -> AcceleratorConfig:
    """Table VI iso-compute partner: 16 cores of 32x32."""
    kw.setdefault("array", 32)
    kw.setdefault("cores", 16)
    return tpu_like_config(**kw)


@register_preset("mcm-4x32")
def _mcm(channels: int = 4, dataflow: str = "ws") -> AcceleratorConfig:
    """MCM-style package for the shared-DRAM contention study: four 32x32
    cores at increasing NoP hop distance from main memory, sharing
    `channels` DRAM channels (channels == cores supports the
    private-channel routing mode of `simulate_multicore_contention`)."""
    from ..core.accelerator import DramConfig
    sram = 128 * 1024
    return AcceleratorConfig(
        cores=tuple(CoreConfig(rows=32, cols=32, nop_hops=h)
                    for h in (0, 1, 1, 2)),
        mesh_rows=2, mesh_cols=2, dataflow=dataflow,
        memory=MemoryConfig(ifmap_sram_bytes=sram, filter_sram_bytes=sram,
                            ofmap_sram_bytes=sram),
        dram=DramConfig(channels=channels))


@register_preset("edge-8")
def _edge(dataflow: str = "ws") -> AcceleratorConfig:
    """A small edge-class design: 8x8 array, 192 KiB of operand SRAM."""
    sram = 64 * 1024
    return AcceleratorConfig(
        cores=(CoreConfig(rows=8, cols=8, simd_lanes=32),),
        dataflow=dataflow,
        memory=MemoryConfig(ifmap_sram_bytes=sram, filter_sram_bytes=sram,
                            ofmap_sram_bytes=sram))
