"""Accelerator preset registry: the one way to construct configs.

Benchmarks, examples, launchers and serving all build `AcceleratorConfig`s
through `get_preset` (or `Simulator(...)` which accepts a preset name), so
a new accelerator model is registered once and becomes available
everywhere — including `Simulator.sweep` grids.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Union

from ..core.accelerator import (AcceleratorConfig, CoreConfig, MemoryConfig,
                                NocConfig, SparsityConfig, near_square_grid,
                                tpu_like_config)

_PRESETS: Dict[str, Callable[..., AcceleratorConfig]] = {}


def register_preset(name: str):
    """Decorator: register a config factory under `name`. Factories may
    take keyword arguments (forwarded from `get_preset`)."""
    def deco(fn: Callable[..., AcceleratorConfig]):
        if name in _PRESETS:
            raise ValueError(f"preset {name!r} already registered")
        _PRESETS[name] = fn
        return fn
    return deco


def get_preset(name: str, **kw) -> AcceleratorConfig:
    if name not in _PRESETS:
        raise KeyError(f"unknown preset {name!r}; "
                       f"available: {sorted(_PRESETS)}")
    return _PRESETS[name](**kw)


def list_presets() -> List[str]:
    return sorted(_PRESETS)


SparsityLike = Union[None, str, tuple, SparsityConfig]


def as_sparsity(v: SparsityLike) -> SparsityConfig:
    """Sparsity-axis value -> SparsityConfig.

    Accepted forms: None or 'dense' (disabled), 'N:M' (layer-wise),
    'N:M-rw' (row-wise), an (n, m) tuple (layer-wise), an
    (n, m, 'rw') tuple, or a SparsityConfig passed through.
    """
    if v is None or v == "dense":
        return SparsityConfig()
    if isinstance(v, SparsityConfig):
        return v
    if isinstance(v, str):
        row_wise = v.endswith("-rw")
        body = v[:-3] if row_wise else v
        try:
            n, m = (int(x) for x in body.split(":"))
        except ValueError:
            raise ValueError(
                f"cannot parse sparsity {v!r}; expected 'dense', 'N:M' or "
                f"'N:M-rw'") from None
        return SparsityConfig(enabled=True, n=n, m=m, row_wise=row_wise)
    if isinstance(v, tuple):
        if len(v) == 2:
            return SparsityConfig(enabled=True, n=v[0], m=v[1])
        if len(v) == 3 and v[2] == "rw":
            return SparsityConfig(enabled=True, n=v[0], m=v[1],
                                  row_wise=True)
    raise TypeError(f"cannot build SparsityConfig from {v!r}")


def with_cores(cfg: AcceleratorConfig, cores: int) -> AcceleratorConfig:
    """Re-mesh a config onto `cores` cores (near-square grid, the
    prototype core replicated) — the `cores=` axis of `preset_grid`."""
    pr, pc = near_square_grid(cores)
    return cfg.with_(cores=(cfg.cores[0],), mesh_rows=pr, mesh_cols=pc)


def with_pod(cfg: AcceleratorConfig, cores: int,
             topology: str = "mesh") -> AcceleratorConfig:
    """Re-mesh a config onto a `cores`-core pod with the routed NoC plane
    enabled (`repro.noc`) — the `pods=` axis of `preset_grid`. Keeps the
    config's NoC link parameters if the plane is already enabled, else
    enables it with defaults on `topology`."""
    import dataclasses
    noc = (dataclasses.replace(cfg.noc, topology=topology)
           if cfg.noc.enabled
           else NocConfig(enabled=True, topology=topology))
    return with_cores(cfg, cores).with_(noc=noc)


def preset_grid(name: str = "tpu-like", *, preset=None, dataflow=None,
                sparsity=None, cores=None, pods=None,
                **axes) -> List[AcceleratorConfig]:
    """Cartesian product of preset kwargs -> list of configs for
    `Study.designs` / `Simulator.sweep`, e.g.
    `preset_grid(array=[8, 16], sram_mb=[1, 8])`.

    Five first-class axes beyond factory kwargs, so study grids span
    presets, core counts, sparsity regimes and dataflows without manual
    list building:

    - `preset=[...]` crosses preset *names* (outermost axis), replacing
      the single `name`;
    - `cores=[...]` re-meshes the built config onto each core count via
      `with_cores` (near-square grid of the prototype core);
    - `pods=[...]` re-meshes onto each core count like `cores` but with
      the routed NoC plane enabled (`with_pod`; mesh by default) —
      pod-scale interconnect sweeps (256/1024/4096 cores);
    - `sparsity=[...]` applies each `as_sparsity` value ('dense',
      '2:4', '1:4-rw', (n, m) tuples, SparsityConfig) via `with_`;
    - `dataflow=[...]` (innermost axis) is applied to the built config
      via `with_(dataflow=...)`, so it works for every preset whether or
      not its factory takes a dataflow kwarg.

    Every cell of the resulting grid — sparse, multi-core, layout- or
    NoC-enabled alike — runs through the batched sweep kernels
    (`fraction_batched == 1.0`; see tests/test_sweep_parity.py).
    """
    if cores is not None and pods is not None:
        raise ValueError("pass either cores= or pods=, not both")
    presets = list(preset) if preset is not None else [name]
    dataflows = list(dataflow) if dataflow is not None else [None]
    sparsities = list(sparsity) if sparsity is not None else [None]
    core_counts = list(cores) if cores is not None else [None]
    remesh = with_cores
    if pods is not None:
        core_counts = list(pods)
        remesh = with_pod
    keys = list(axes)
    out = []
    for pname in presets:
        for combo in itertools.product(*(axes[k] for k in keys)):
            cfg0 = get_preset(pname, **dict(zip(keys, combo)))
            for nc in core_counts:
                cfg1 = cfg0 if nc is None else remesh(cfg0, nc)
                for sp in sparsities:
                    cfg2 = (cfg1 if sp is None
                            else cfg1.with_(sparsity=as_sparsity(sp)))
                    for df in dataflows:
                        out.append(cfg2 if df is None
                                   else cfg2.with_(dataflow=df))
    return out


# --- built-ins --------------------------------------------------------------

register_preset("tpu-like")(tpu_like_config)


@register_preset("paper-32")
def _paper_32(**kw) -> AcceleratorConfig:
    """The paper's default single-core 32x32 WS array."""
    return tpu_like_config(array=32, **kw)


@register_preset("paper-64")
def _paper_64(**kw) -> AcceleratorConfig:
    return tpu_like_config(array=64, **kw)


@register_preset("paper-128")
def _paper_128(**kw) -> AcceleratorConfig:
    """TPU-class 128x128 MXU (Table V's big design point)."""
    return tpu_like_config(array=128, **kw)


@register_preset("multicore-16x32")
def _multicore(**kw) -> AcceleratorConfig:
    """Table VI iso-compute partner: 16 cores of 32x32."""
    kw.setdefault("array", 32)
    kw.setdefault("cores", 16)
    return tpu_like_config(**kw)


@register_preset("mcm-4x32")
def _mcm(channels: int = 4, dataflow: str = "ws") -> AcceleratorConfig:
    """MCM-style package for the shared-DRAM contention study: four 32x32
    cores at increasing NoP hop distance from main memory, sharing
    `channels` DRAM channels (channels == cores supports the
    private-channel routing mode of `simulate_multicore_contention`)."""
    from ..core.accelerator import DramConfig
    sram = 128 * 1024
    return AcceleratorConfig(
        cores=tuple(CoreConfig(rows=32, cols=32, nop_hops=h)
                    for h in (0, 1, 1, 2)),
        mesh_rows=2, mesh_cols=2, dataflow=dataflow,
        memory=MemoryConfig(ifmap_sram_bytes=sram, filter_sram_bytes=sram,
                            ofmap_sram_bytes=sram),
        dram=DramConfig(channels=channels))


@register_preset("pod-mesh")
def _pod_mesh(cores: int = 256, topology: str = "mesh", array: int = 32,
              link_bw: float = 32.0, flit_bytes: int = 32,
              buffer_flits: int = 8, channels: int = 8,
              dataflow: str = "ws") -> AcceleratorConfig:
    """Pod-scale package (256/1024/4096 cores) with the routed NoC plane
    enabled: `array`x`array` cores on a near-square `topology` grid, all
    DRAM traffic routed over flit/credit links to the memory controller
    at core (0, 0). `link_bw` is bytes/cycle per link; sweep it (and
    `channels`) to locate the NoP-bound regime (studies.nop_bound)."""
    from ..core.accelerator import DramConfig
    cfg = tpu_like_config(array=array, cores=cores, dataflow=dataflow)
    return cfg.with_(
        noc=NocConfig(enabled=True, topology=topology,
                      link_bandwidth_bytes_per_cycle=link_bw,
                      flit_bytes=flit_bytes, buffer_flits=buffer_flits),
        dram=DramConfig(channels=channels))


@register_preset("ws-64-sparse-2:4")
def _ws64_sparse(n: int = 2, m: int = 4,
                 row_wise: bool = False) -> AcceleratorConfig:
    """Paper Sec. IV SpMM reference design: a 64x64 weight-stationary
    array streaming 2:4 layer-wise compressed weights (the Ampere-class
    ratio); `n`/`m`/`row_wise` kwargs open the full N:M family."""
    return tpu_like_config(array=64, dataflow="ws").with_(
        sparsity=SparsityConfig(enabled=True, n=n, m=m, row_wise=row_wise))


@register_preset("table-v-corner")
def _table_v_corner(array: int = 64, sram_kb: int = 8192,
                    dataflow: str = "ws", channels: int = 2,
                    bandwidth: float = 19.2,
                    layout_banks: int = 0) -> AcceleratorConfig:
    """One cell of the Table-V design-space search (`repro.search`,
    studies.search_edp): a single-core `array`x`array` systolic core with
    `sram_kb` KiB of operand SRAM split evenly across the three operand
    buffers, DRAM capped at paper-class provisioning (`channels` channels
    of `bandwidth` bytes/cycle), optionally the data-layout stage on
    `layout_banks` banks. Defaults are the paper's EdP winner; the
    search space's axes perturb exactly these kwargs."""
    from ..core.accelerator import DramConfig, LayoutConfig
    sram = int(sram_kb) * 1024 // 3
    cfg = AcceleratorConfig(
        cores=(CoreConfig(rows=array, cols=array),),
        dataflow=dataflow,
        memory=MemoryConfig(ifmap_sram_bytes=sram, filter_sram_bytes=sram,
                            ofmap_sram_bytes=sram),
        dram=DramConfig(channels=channels,
                        bandwidth_bytes_per_cycle=bandwidth))
    if layout_banks:
        cfg = cfg.with_(layout=LayoutConfig(enabled=True,
                                            num_banks=layout_banks))
    return cfg


@register_preset("edge-8")
def _edge(dataflow: str = "ws") -> AcceleratorConfig:
    """A small edge-class design: 8x8 array, 192 KiB of operand SRAM."""
    sram = 64 * 1024
    return AcceleratorConfig(
        cores=(CoreConfig(rows=8, cols=8, simd_lanes=32),),
        dataflow=dataflow,
        memory=MemoryConfig(ifmap_sram_bytes=sram, filter_sram_bytes=sram,
                            ofmap_sram_bytes=sram))
