"""Declarative Study API: cross-product experiment plans over
designs x workloads x fidelities, reduced to a columnar result frame.

The headline deliverables of SCALE-Sim v3 are *studies*, not single runs
("32x32 is ~2.86x more energy-efficient than 128x128 for ViT-base",
"WS wins compute cycles but OS wins end-to-end once DRAM stalls are
modeled") — each a cross-product of axes reduced to a comparison. A
`Study` makes that experiment the API object:

    res = (Study()
           .designs({"32": "paper-32", "64": "paper-64"})
           .workloads({"vit-base": vit_base_linear()})
           .fidelity("fast", "trace")
           .run())
    res.best("edp")                      # winning row (dict)
    res.filter(fidelity="trace").compare("total_cycles",
                                         axis="design", baseline="32")

`Study.run` compiles the full cross-product into an execution plan,
partitions it into batchable groups (reusing the jitted/vmapped
`_sweep_batched` kernels and the module-wide `_SWEEP_FN_CACHE` from
`simulator.py`; per-op engine fallback for non-traceable cells; optional
mesh sharding over the flattened plan axis) and returns a `StudyResult`
— a pandas-free columnar frame (numpy columns + axis metadata) with
`filter/group/pareto/best/compare`, `to_csv`/`to_json` round-trips
(shared column schema with `NetworkReport`, see `core/engine.py`), and a
content-hash keyed on-disk cache so re-running a study only executes
changed cells.

The paper's analyses ship as named studies: `studies.edp_array_size`,
`studies.dataflow_dram_flip`, `studies.multicore_contention` — each a
single `Study.run()` away, with machine-checkable claims
(`StudyResult.check_claims`). CLI (see `repro/api/__main__.py`):

    PYTHONPATH=src python -m repro.api --study edp_array_size \
        --smoke --csv STUDY_edp_array_size.csv
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import (Callable, Dict, List, Optional, Sequence, Tuple, Union)

import numpy as np

from ..core import stages as st
from ..core.accelerator import AcceleratorConfig, DramConfig
from ..core.energy import DEFAULT_ERT, ERT, edp as _edp
from ..core.engine import (ENERGY_GROUP_COLUMNS, RESULT_SCHEMA_VERSION,
                           energy_group_totals, simulate_network,
                           write_csv_table)
from ..core.workloads import Op
from ..faults import fs as _fs
from .simulator import _sweep_batched, as_config, as_workload

AXIS_COLUMNS = ("design", "workload", "fidelity")

# Canonical metric columns of the default (Simulator-backed) evaluator,
# grouped-energy columns included — the same schema NetworkReport.write_csv
# emits per op. Custom evaluators may add columns; these stay first.
METRIC_COLUMNS = ("total_cycles", "compute_cycles", "stall_cycles",
                  "dram_bytes", "energy_pj", "utilization",
                  "edp") + ENERGY_GROUP_COLUMNS

_METRIC_ALIASES = {"latency": "total_cycles", "cycles": "total_cycles",
                   "energy": "energy_pj"}

# evaluator: (config, ops, fidelity) -> {metric: float}
Evaluator = Callable[[AcceleratorConfig, Sequence[Op], str],
                     Dict[str, float]]


def _flag_non_finite(metrics: Dict[str, float]) -> None:
    """Sentinel a sick cell in place: NaN anywhere, or ±Inf on a
    *canonical* metric column, sets `cell_status = 1.0` (failed).
    ±Inf on custom-evaluator columns is legitimate output (e.g.
    `contention_summary`'s stall_inflation on a zero-stall baseline)
    and is left alone."""
    for k, v in metrics.items():
        if k in ("batched", "cell_status"):
            continue
        bad = v != v or (k in METRIC_COLUMNS
                         and (v == float("inf") or v == float("-inf")))
        if bad:
            metrics["cell_status"] = 1.0
            return


def _code_digest(code) -> str:
    """Process-stable digest of a code object: bytecode + literal
    constants (recursing into nested code objects, whose default reprs
    embed memory addresses) + referenced names."""
    h = hashlib.sha256(code.co_code)
    for const in code.co_consts:
        if hasattr(const, "co_code"):
            h.update(_code_digest(const).encode())
        else:
            h.update(repr(const).encode())
    h.update(repr(code.co_names).encode())
    return h.hexdigest()


# --------------------------------------------------------------------------
# Execution plan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StudyCell:
    """One point of the cross-product: frame row `index`."""
    index: int
    design: str
    workload: str
    fidelity: str
    config: AcceleratorConfig


@dataclasses.dataclass
class BatchGroup:
    """Cells that execute as ONE jitted/vmapped `_sweep_batched` call:
    same workload + fidelity, and the static pipeline flavor the sweep
    kernels specialize on — (dataflow, word_bytes[, DramConfig]) plus the
    core-grid shape, layout fields and sparse metadata representation
    (derived from the member configs inside `_sweep_batched`)."""
    workload: str
    fidelity: str
    dataflow: str
    word_bytes: int
    dram: Optional[DramConfig]
    cells: List[int]


@dataclasses.dataclass
class StudyPlan:
    cells: List[StudyCell]
    groups: List[BatchGroup]          # batched cells, by kernel flavor
    fallback: List[int]               # per-op engine cells

    @property
    def n_batched(self) -> int:
        return sum(len(g.cells) for g in self.groups)

    def __len__(self) -> int:
        return len(self.cells)


# --------------------------------------------------------------------------
# Columnar result frame
# --------------------------------------------------------------------------

class StudyResult:
    """Pandas-free columnar frame: numpy columns + axis metadata.

    Axis columns (`design`, `workload`, `fidelity`) are object arrays of
    labels; metric columns are float64; `batched` is 1.0 for cells that
    ran through a vmapped sweep kernel (0.0 = per-op engine fallback);
    `cell_status` is 1.0 for *failed* cells (evaluator raised,
    non-finite canonical metrics, or a quarantined farm shard) whose
    metric columns read NaN — `ok()` drops them, `failed_cells` lists
    them, and `argbest`/`pareto` never pick them.
    """

    # every in-process frame speaks the current schema; concat() checks
    # it so frames from a future/foreign schema can never silently mix
    schema_version = RESULT_SCHEMA_VERSION

    def __init__(self, columns: Dict[str, np.ndarray],
                 axes: Dict[str, List[str]], *,
                 executed_cells: int = 0, cache_hits: int = 0,
                 claims: Optional[List[Tuple[str, Callable]]] = None):
        self.columns = columns
        self.axes = axes
        self.executed_cells = executed_cells
        self.cache_hits = cache_hits
        self._claims = list(claims or [])
        # run-time annotations (e.g. the search layer's accounting);
        # like claims, meta does not survive to_json/to_csv round-trips
        self.meta: Dict[str, object] = {}

    # ---- basic access ------------------------------------------------------
    def __len__(self) -> int:
        return 0 if not self.columns else len(next(iter(self.columns.values())))

    @property
    def fraction_batched(self) -> float:
        """Fraction of cells that executed through a vmapped sweep kernel
        (1.0 = the whole study ran batched; the acceptance bar for
        arbitrary mixed sparsity/layout/multicore grids)."""
        if not len(self) or "batched" not in self.columns:
            return 1.0
        return float(np.mean(self.columns["batched"]))

    def __getitem__(self, name: str) -> np.ndarray:
        return self.columns[_METRIC_ALIASES.get(name, name)]

    def column_names(self) -> List[str]:
        return list(self.columns)

    def row(self, i: int) -> Dict[str, object]:
        return {k: (str(v[i]) if k in AXIS_COLUMNS else float(v[i]))
                for k, v in self.columns.items()}

    def rows(self) -> List[Dict[str, object]]:
        return [self.row(i) for i in range(len(self))]

    def equals(self, other: "StudyResult") -> bool:
        # positional NaN counts as equal: a failed cell round-trips as
        # the same failed cell, and replay identity must not break on it
        def _eq(a: np.ndarray, b: np.ndarray) -> bool:
            if a.dtype.kind == "f" and b.dtype.kind == "f":
                return np.array_equal(a, b, equal_nan=True)
            return np.array_equal(a, b)
        return (list(self.columns) == list(other.columns)
                and self.axes == other.axes
                and all(_eq(self.columns[k], other.columns[k])
                        for k in self.columns))

    # ---- relational ops ----------------------------------------------------
    def _subset(self, mask: np.ndarray) -> "StudyResult":
        # claims are scoped to the full frame (they reference its axes)
        # and deliberately do NOT propagate into subframes
        cols = {k: v[mask] for k, v in self.columns.items()}
        axes = {a: [x for x in self.axes[a] if x in set(cols[a])]
                for a in self.axes}
        return StudyResult(cols, axes)

    def filter(self, pred: Optional[Callable[[Dict], bool]] = None,
               **eq) -> "StudyResult":
        """Row subset: keyword equality (scalar or collection of allowed
        values per column) and/or a row-dict predicate."""
        mask = np.ones(len(self), dtype=bool)
        for k, want in eq.items():
            col = self[k]
            if isinstance(want, (list, tuple, set, frozenset)):
                mask &= np.isin(col, list(want))
            else:
                mask &= (col == want)
        if pred is not None:
            mask &= np.array([bool(pred(self.row(i)))
                              for i in range(len(self))], dtype=bool)
        return self._subset(mask)

    def group(self, by: Union[str, Sequence[str]]
              ) -> Dict[object, "StudyResult"]:
        """Split into sub-frames keyed by the value(s) of `by`."""
        keys = (by,) if isinstance(by, str) else tuple(by)
        out: Dict[object, StudyResult] = {}
        seen: List[object] = []
        cols = [self[k] for k in keys]
        for i in range(len(self)):
            key = tuple(c[i] for c in cols)
            key = key[0] if len(keys) == 1 else key
            if key not in out:
                out[key] = None  # placeholder to keep insertion order
                seen.append(key)
        for key in seen:
            if isinstance(key, tuple):
                eq = dict(zip(keys, key))
            else:
                eq = {keys[0]: key}
            out[key] = self.filter(**eq)
        return out

    @property
    def failed_cells(self) -> List[int]:
        """Row indices of failed cells (`cell_status == 1`): evaluator
        raised, non-finite canonical metrics, or quarantined shard."""
        if "cell_status" not in self.columns:
            return []
        return [int(i) for i in
                np.nonzero(self.columns["cell_status"] == 1.0)[0]]

    def ok(self) -> "StudyResult":
        """Subframe of the healthy rows only (drops failed cells)."""
        if "cell_status" not in self.columns:
            return self
        return self._subset(self.columns["cell_status"] != 1.0)

    def argbest(self, metric: str = "edp") -> int:
        """Row index minimizing `metric`. NaN rows (failed cells) never
        win; an all-NaN column raises instead of returning garbage."""
        vals = np.asarray(self[metric], dtype=float)
        masked = np.where(np.isnan(vals), np.inf, vals)
        if not len(masked) or not np.isfinite(masked).any():
            raise ValueError(
                f"argbest({metric!r}): no finite values "
                f"({len(self.failed_cells)} failed cells of {len(self)})")
        return int(np.argmin(masked))

    def best(self, metric: str = "edp",
             by: Optional[Union[str, Sequence[str]]] = None):
        """Row (dict) minimizing `metric`; with `by`, the winner per group."""
        if by is None:
            return self.row(self.argbest(metric))
        return {k: sub.row(sub.argbest(metric))
                for k, sub in self.group(by).items()}

    def pareto(self, *objectives: str) -> "StudyResult":
        """Non-dominated rows, minimizing every objective. Rows with a
        non-finite objective value (failed cells' NaNs, ±Inf) are
        excluded — NaN compares false against everything, so without
        this a failed cell would always survive as "non-dominated"."""
        if not objectives:
            objectives = ("total_cycles", "energy_pj")
        vals = np.stack([np.asarray(self[m], dtype=float)
                         for m in objectives], axis=1)
        keep = np.isfinite(vals).all(axis=1)
        for i in np.nonzero(keep)[0]:
            dominated = (keep & (vals <= vals[i]).all(axis=1)
                         & (vals < vals[i]).any(axis=1))
            if dominated.any():
                keep[i] = False
        return self._subset(keep)

    def topk(self, metric: str, k: int) -> "StudyResult":
        """The `k` lowest-`metric` rows as a subframe, sorted ascending
        (stable: original row order breaks ties). NaN-safe — rows with a
        non-finite metric value (failed cells) never place, so the
        subframe may hold fewer than `k` rows."""
        if k < 0:
            raise ValueError(f"topk k must be >= 0, got {k}")
        vals = np.asarray(self[metric], dtype=float)
        finite = np.isfinite(vals)
        order = np.argsort(np.where(finite, vals, np.inf), kind="stable")
        return self._subset(order[:min(int(k), int(finite.sum()))])

    @staticmethod
    def concat(frames: Sequence["StudyResult"]) -> "StudyResult":
        """Row-concatenate frames (the search layer's round folding).

        Columns are the union in first-seen order: a metric missing from
        a frame fills with NaN (NaN-safe consumers — topk/pareto/argbest
        — already ignore it); axis columns must be present in every
        frame. Axis vocabularies merge in first-seen order. Every frame
        must carry the current result schema version — mixing schemas
        silently is exactly the bug this check exists for. Claims and
        meta do not propagate; executed/cache-hit counts sum.
        """
        frames = list(frames)
        if not frames:
            raise ValueError("concat() needs at least one frame")
        for f in frames:
            if getattr(f, "schema_version", None) != RESULT_SCHEMA_VERSION:
                raise ValueError(
                    f"cannot concat frame with schema_version "
                    f"{getattr(f, 'schema_version', None)!r} != supported "
                    f"{RESULT_SCHEMA_VERSION}")
        names: List[str] = []
        for f in frames:
            for c in f.column_names():
                if c not in names:
                    names.append(c)
        cols: Dict[str, np.ndarray] = {}
        for c in names:
            if c in AXIS_COLUMNS:
                missing = [i for i, f in enumerate(frames)
                           if c not in f.columns]
                if missing:
                    raise ValueError(
                        f"axis column {c!r} missing from concat frame(s) "
                        f"{missing}")
                cols[c] = np.concatenate(
                    [np.asarray(f.columns[c], dtype=object)
                     for f in frames])
            else:
                cols[c] = np.concatenate(
                    [np.asarray(f.columns[c], dtype=np.float64)
                     if c in f.columns
                     else np.full(len(f), np.nan) for f in frames])
        axes: Dict[str, List[str]] = {}
        for f in frames:
            for a, vocab in f.axes.items():
                dst = axes.setdefault(a, [])
                for v in vocab:
                    if v not in dst:
                        dst.append(v)
        return StudyResult(
            cols, axes,
            executed_cells=sum(f.executed_cells for f in frames),
            cache_hits=sum(f.cache_hits for f in frames))

    def compare(self, metric: str, *, axis: str,
                baseline: str) -> Dict[str, np.ndarray]:
        """Ratio of `metric` against the `baseline` value along one axis.

        Returns {other_axis_value: ratios} where ratios are row-aligned
        with `self.filter(**{axis: baseline})` — cells are matched on the
        remaining axis columns. ratio > 1 means that value is worse
        (higher metric) than the baseline for the matched cell.
        """
        other = [a for a in AXIS_COLUMNS if a != axis]
        base = self.filter(**{axis: baseline})
        if not len(base):
            raise KeyError(f"no rows with {axis}={baseline!r}")
        base_keys = list(zip(*(base[a] for a in other)))
        base_vals = np.asarray(base[metric], dtype=float)
        out: Dict[str, np.ndarray] = {}
        for v in self.axes[axis]:
            if v == baseline:
                continue
            sub = self.filter(**{axis: v})
            lut = {k: float(m) for k, m in
                   zip(zip(*(sub[a] for a in other)), sub[metric])}
            out[v] = np.array([lut[k] for k in base_keys]) / base_vals
        return out

    # ---- claims ------------------------------------------------------------
    def check_claims(self) -> Dict[str, bool]:
        """Evaluate the study's registered paper claims on this frame.
        Claims are run-time attachments — they do not survive
        to_json/to_csv round-trips (a deserialized frame has none)."""
        return {name: bool(fn(self)) for name, fn in self._claims}

    def claims_ok(self) -> bool:
        """True iff every registered claim holds. Raises on a frame with
        no claims (e.g. one rebuilt via from_json/from_csv) instead of
        returning a vacuous True."""
        claims = self.check_claims()
        if not claims:
            raise ValueError(
                "no claims registered on this frame (claims do not "
                "survive serialization); gate on check_claims() of the "
                "original Study.run() result")
        return all(claims.values())

    # ---- serialization (schema shared with NetworkReport, engine.py) ------
    def to_json(self) -> str:
        cols = {k: ([str(x) for x in v] if k in AXIS_COLUMNS
                    else [float(x) for x in v])
                for k, v in self.columns.items()}
        return json.dumps({"schema_version": RESULT_SCHEMA_VERSION,
                           "axes": self.axes, "columns": cols}, indent=1)

    @classmethod
    def from_json(cls, s: str) -> "StudyResult":
        d = json.loads(s)
        if d.get("schema_version") != RESULT_SCHEMA_VERSION:
            raise ValueError(
                f"study frame schema_version {d.get('schema_version')!r} "
                f"!= supported {RESULT_SCHEMA_VERSION}")
        cols = {k: (np.array(v, dtype=object) if k in AXIS_COLUMNS
                    else np.asarray(v, dtype=np.float64))
                for k, v in d["columns"].items()}
        return cls(cols, {a: list(v) for a, v in d["axes"].items()})

    def to_csv(self, path: str) -> None:
        names = list(self.columns)
        rows = [[(str(self.columns[c][i]) if c in AXIS_COLUMNS
                  else float(self.columns[c][i])) for c in names]
                for i in range(len(self))]
        write_csv_table(path, names, rows)

    @classmethod
    def from_csv(cls, path: str) -> "StudyResult":
        import csv
        with open(path, newline="") as f:
            reader = csv.reader(f)
            header = next(reader)
            raw = [r for r in reader if r]
        cols: Dict[str, np.ndarray] = {}
        for j, name in enumerate(header):
            vals = [r[j] for r in raw]
            cols[name] = (np.array(vals, dtype=object)
                          if name in AXIS_COLUMNS
                          else np.array([float(v) for v in vals]))
        axes = {a: list(dict.fromkeys(cols[a])) for a in AXIS_COLUMNS
                if a in cols}
        return cls(cols, axes)

    def summary(self) -> str:
        lines = [f"{len(self)} cells | axes: "
                 + "; ".join(f"{a}={list(v)}" for a, v in self.axes.items())]
        metrics = [c for c in self.columns
                   if c not in AXIS_COLUMNS
                   and c not in ("batched", "cell_status")]
        failed = set(self.failed_cells)
        for i in range(len(self)):
            tag = " ".join(str(self.columns[a][i]) for a in AXIS_COLUMNS
                           if a in self.columns)
            if i in failed:
                lines.append(f"  {tag}: FAILED")
                continue
            vals = " ".join(f"{m}={float(self.columns[m][i]):.4g}"
                            for m in metrics[:6])
            lines.append(f"  {tag}: {vals}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# The Study builder
# --------------------------------------------------------------------------

class Study:
    """Declarative cross-product experiment plan (builder pattern).

    Every setter returns `self` so studies read as one expression; `run`
    compiles the plan, executes it (batched where possible) and returns a
    `StudyResult`.
    """

    def __init__(self, name: str = "study"):
        self.name = name
        self._designs: List[Tuple[str, AcceleratorConfig]] = []
        self._workloads: Dict[str, List[Op]] = {}
        self._fidelities: Tuple[str, ...] = ("fast",)
        self._metrics: Optional[Tuple[str, ...]] = None
        self._ert: ERT = DEFAULT_ERT
        self._engine: Optional[str] = None
        self._spec = None
        self._core_index: int = 0
        self._force_fallback: bool = False
        self._cache_dir: Optional[str] = None
        self._evaluator: Optional[Evaluator] = None
        self._claims: List[Tuple[str, Callable]] = []
        # registry provenance ({"study": name, "kwargs": {...}}), set by
        # get_study: lets to_spec serialize by reference so claims and
        # custom evaluators survive a farm round-trip
        self._ref: Optional[Dict[str, object]] = None

    # ---- axes --------------------------------------------------------------
    def designs(self, configs, labels: Optional[Sequence[str]] = None
                ) -> "Study":
        """Design axis: dict {label: ConfigLike} or a sequence (e.g. a
        `preset_grid`) — sequence entries are auto-labeled
        `{rows}x{cols}-{dataflow}` with `#k` de-duplication suffixes."""
        out: List[Tuple[str, AcceleratorConfig]] = []
        if isinstance(configs, dict):
            out = [(str(k), as_config(v)) for k, v in configs.items()]
        else:
            cfgs = [as_config(c) for c in configs]
            if labels is not None:
                if len(labels) != len(cfgs):
                    raise ValueError("labels/configs length mismatch")
                out = list(zip([str(x) for x in labels], cfgs))
            else:
                def auto(c: AcceleratorConfig) -> str:
                    b = f"{c.cores[0].rows}x{c.cores[0].cols}-{c.dataflow}"
                    if c.num_cores > 1:
                        b += f"-{c.num_cores}c"
                    if c.sparsity.enabled:
                        b += (f"-{c.sparsity.n}:{c.sparsity.m}"
                              + ("rw" if c.sparsity.row_wise else ""))
                    if c.layout.enabled:
                        b += "-lay"
                    return b
                base = [auto(c) for c in cfgs]
                counts: Dict[str, int] = {}
                for b in base:
                    counts[b] = counts.get(b, 0) + 1
                # geometry collisions (e.g. an array x sram grid) get the
                # operand-SRAM size appended before falling back to #k
                labeled = []
                for b, c in zip(base, cfgs):
                    if counts[b] > 1:
                        mb = (c.memory.ifmap_sram_bytes
                              + c.memory.filter_sram_bytes
                              + c.memory.ofmap_sram_bytes) / (1 << 20)
                        b = f"{b}@{mb:.3g}MB"
                    labeled.append(b)
                seen: Dict[str, int] = {}
                for b, c in zip(labeled, cfgs):
                    k = seen.get(b, 0)
                    seen[b] = k + 1
                    out.append((b if k == 0 else f"{b}#{k}", c))
        if len({l for l, _ in out}) != len(out):
            raise ValueError("design labels must be unique")
        self._designs = out
        return self

    def workloads(self, *wls) -> "Study":
        """Workload axis: dicts {name: ops-or-paper-workload-name} and/or
        bare paper-workload names ('resnet18', 'vit_base', ...)."""
        m: Dict[str, List[Op]] = {}
        for w in wls:
            if isinstance(w, dict):
                for k, v in w.items():
                    m[str(k)] = as_workload(v)
            elif isinstance(w, str):
                m[w] = as_workload(w)
            else:
                raise TypeError(f"workloads() takes dicts or names, "
                                f"got {type(w)!r}")
        if not m:
            raise ValueError("workloads() needs at least one workload")
        self._workloads = m
        return self

    def fidelity(self, *fids: str) -> "Study":
        for f in fids:
            if f not in st.FIDELITIES:
                raise ValueError(f"fidelity must be one of {st.FIDELITIES}, "
                                 f"got {f!r}")
        if not fids:
            raise ValueError("fidelity() needs at least one level")
        self._fidelities = tuple(fids)
        return self

    # ---- options -----------------------------------------------------------
    def metrics(self, *names: str) -> "Study":
        """Restrict the frame's metric columns (axis + `batched` always
        kept). Aliases: latency/cycles -> total_cycles, energy ->
        energy_pj."""
        self._metrics = tuple(_METRIC_ALIASES.get(n, n) for n in names)
        return self

    def options(self, *, ert: Optional[ERT] = None,
                engine: Optional[str] = None, trace_spec=None,
                core_index: Optional[int] = None,
                force_fallback: Optional[bool] = None) -> "Study":
        """Execution knobs shared by every cell (see `Simulator`).

        force_fallback: run every cell through the per-op engine oracle
        instead of the batched sweep kernels — the differential-parity
        reference path (tests/test_sweep_parity.py); identical result
        contract, no batching.
        """
        from ..core import replay as _rp
        if ert is not None:
            self._ert = ert
        if engine is not None:
            self._engine = _rp.resolve_engine(engine)
        if trace_spec is not None:
            self._spec = trace_spec
        if core_index is not None:
            self._core_index = core_index
        if force_fallback is not None:
            self._force_fallback = bool(force_fallback)
        return self

    def cache(self, path: str) -> "Study":
        """Content-hash keyed on-disk cell cache: re-running a study only
        executes cells whose (config, ops, fidelity, ERT, engine, spec)
        content changed."""
        self._cache_dir = path
        return self

    def evaluator(self, fn: Evaluator) -> "Study":
        """Custom per-cell evaluator `(config, ops, fidelity) -> metric
        dict` replacing the Simulator pipeline (e.g. the multi-core
        contention study). Cells run per-op (no batching) but still
        cache — keyed by the study name + the evaluator's qualname and
        bytecode hash. Captured closure *state* is not hashed: if two
        evaluators share bytecode but behave differently through their
        closures, give the studies distinct names (or distinct cache
        dirs) so cells never alias."""
        self._evaluator = fn
        return self

    def claim(self, name: str, fn: Callable[[StudyResult], bool]) -> "Study":
        """Attach a machine-checkable paper claim, evaluated on the frame
        via `StudyResult.check_claims()`."""
        self._claims.append((name, fn))
        return self

    # ---- wire format (the farm's job payload) -------------------------------
    def to_spec(self) -> dict:
        """JSON-serializable description of this study — the farm's wire
        format (`repro.farm`). A registry study (built via `get_study` or
        the `studies.*` namespace) serializes as a *reference*: both ends
        rebuild it through the registry, so claims and custom evaluators
        survive. An ad-hoc study serializes *inline* (designs, workloads,
        fidelities, options); claims and evaluators are run-time python
        objects and do not survive an inline spec."""
        if self._ref is not None:
            try:
                json.dumps(self._ref["kwargs"])
            except TypeError as e:
                raise ValueError(
                    "registry study kwargs must be JSON-serializable to "
                    "travel as a spec; rebuild the study with plain "
                    "kwargs or submit an inline (non-registry) study"
                ) from e
            return {"kind": "study_spec",
                    "schema_version": RESULT_SCHEMA_VERSION,
                    "ref": {"study": self._ref["study"],
                            "kwargs": dict(self._ref["kwargs"])}}
        if self._evaluator is not None:
            raise ValueError(
                "a custom evaluator is not serializable; register the "
                "study (register_study) and submit it by name so the "
                "farm rebuilds it from the registry")
        return {
            "kind": "study_spec",
            "schema_version": RESULT_SCHEMA_VERSION,
            "ref": None,
            "name": self.name,
            "designs": [[label, cfg.to_dict()]
                        for label, cfg in self._designs],
            "workloads": {
                name: [[o.name, o.M, o.N, o.K, o.count, o.kind,
                        o.vector_elems,
                        list(o.sparsity_nm) if o.sparsity_nm else None]
                       for o in ops]
                for name, ops in self._workloads.items()},
            "fidelities": list(self._fidelities),
            "metrics": (list(self._metrics)
                        if self._metrics is not None else None),
            "ert": dataclasses.asdict(self._ert),
            "engine": self._engine,
            "trace_spec": (dataclasses.asdict(self._spec)
                           if self._spec is not None else None),
            "core_index": self._core_index,
            "force_fallback": self._force_fallback,
        }

    @classmethod
    def from_spec(cls, d: dict) -> "Study":
        """Rebuild a study from `to_spec()` output. Reference specs go
        through the registry (claims/evaluators intact); inline specs
        reconstruct designs/workloads/options field by field. Cell hashes
        — and therefore shared-cache identity — are preserved across the
        round-trip."""
        if not isinstance(d, dict) or d.get("kind") != "study_spec":
            raise ValueError("not a study spec (missing kind=study_spec)")
        if d.get("schema_version") != RESULT_SCHEMA_VERSION:
            raise ValueError(
                f"study spec schema_version {d.get('schema_version')!r} "
                f"!= supported {RESULT_SCHEMA_VERSION}")
        if d.get("ref"):
            return get_study(d["ref"]["study"], **d["ref"].get("kwargs", {}))
        s = cls(d.get("name", "study"))
        s._designs = [(str(label), AcceleratorConfig.from_dict(cfg))
                      for label, cfg in d["designs"]]
        s._workloads = {
            name: [Op(o[0], int(o[1]), int(o[2]), int(o[3]), float(o[4]),
                      o[5], float(o[6]),
                      tuple(int(x) for x in o[7]) if o[7] else None)
                   for o in ops]
            for name, ops in d["workloads"].items()}
        s._fidelities = tuple(d["fidelities"])
        if d.get("metrics") is not None:
            s._metrics = tuple(d["metrics"])
        s._ert = ERT(**d["ert"])
        s._engine = d.get("engine")
        if d.get("trace_spec") is not None:
            from ..trace.generator import TraceSpec
            s._spec = TraceSpec(**d["trace_spec"])
        s._core_index = int(d.get("core_index", 0))
        s._force_fallback = bool(d.get("force_fallback", False))
        return s

    # ---- plan + run --------------------------------------------------------
    def _spec_for(self, fidelity: str):
        if fidelity != "trace":
            return None
        if self._spec is None:
            from ..trace.generator import DEFAULT_SPEC
            return DEFAULT_SPEC
        return self._spec

    def plan(self) -> StudyPlan:
        """Compile the cross-product into cells + batchable groups.

        Cell order (= frame row order): fidelity-major, then workload,
        design fastest — a one-workload/one-fidelity study's rows are its
        designs in order (the `Simulator.sweep` contract).
        """
        if not self._designs:
            raise ValueError("Study has no designs; call .designs(...)")
        if not self._workloads:
            raise ValueError("Study has no workloads; call .workloads(...)")
        cells: List[StudyCell] = []
        for fid in self._fidelities:
            for wname in self._workloads:
                for label, cfg in self._designs:
                    cells.append(StudyCell(len(cells), label, wname, fid,
                                           cfg))
        by_key: Dict[tuple, List[int]] = {}
        fallback: List[int] = []
        for c in cells:
            # every AcceleratorConfig is traceable — sparsity, layout and
            # multi-core partitioning run inside the sweep kernel; only
            # 'cycle' fidelity, custom evaluators and the force_fallback
            # oracle mode (the parity suite's reference) stay per-op
            batchable = (self._evaluator is None
                         and not self._force_fallback
                         and c.fidelity in ("fast", "trace"))
            if batchable:
                cfg = c.config
                key = (c.workload, c.fidelity, cfg.dataflow,
                       cfg.memory.word_bytes,
                       cfg.dram if c.fidelity == "trace" else None,
                       (cfg.mesh_rows, cfg.mesh_cols),
                       # layout fields only matter when enabled: disabled
                       # cells share one flavor (and skip the layout math)
                       cfg.layout if cfg.layout.enabled else None,
                       cfg.sparsity.representation,
                       # NoC topology fixes the static routing tree; link
                       # parameters stay traced columns inside the group
                       (cfg.noc.topology if cfg.noc.enabled
                        and cfg.num_cores > 1 else None))
                by_key.setdefault(key, []).append(c.index)
            else:
                fallback.append(c.index)
        groups = [BatchGroup(*key[:5], cells=idxs)
                  for key, idxs in by_key.items()]
        return StudyPlan(cells=cells, groups=groups, fallback=fallback)

    def _cell_hash(self, cell: StudyCell) -> str:
        spec = self._spec_for(cell.fidelity)
        from ..core import replay as _rp
        payload = {
            "schema_version": RESULT_SCHEMA_VERSION,
            "config": cell.config.to_dict(),
            "ops": [(o.name, o.M, o.N, o.K, o.count, o.kind,
                     o.vector_elems, o.sparsity_nm)
                    for o in self._workloads[cell.workload]],
            "fidelity": cell.fidelity,
            "ert": dataclasses.asdict(self._ert),
            "engine": _rp.resolve_engine(self._engine),
            "spec": dataclasses.asdict(spec) if spec is not None else None,
            "core_index": self._core_index,
            # the oracle and the batched kernel agree only to ~1e-3: their
            # cells must never alias in the on-disk cache
            "force_fallback": self._force_fallback,
            "evaluator": self._evaluator_key(),
        }
        blob = json.dumps(payload, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()

    def _evaluator_key(self):
        """Cache identity of a custom evaluator: study name + qualname +
        a digest of the code object (bytecode, constants, names — so two
        different lambdas with the same qualname never share cache
        cells). Closure contents are deliberately not hashed (their
        reprs are process-dependent) — see `evaluator()`."""
        fn = self._evaluator
        if fn is None:
            return None
        code = getattr(fn, "__code__", None)
        return [self.name, getattr(fn, "__qualname__", repr(fn)),
                _code_digest(code) if code is not None else None]

    def _cache_load(self, cache_dir: str, h: str
                    ) -> Optional[Dict[str, float]]:
        """Load one cached cell; anything unreadable is a miss.

        Corrupt/truncated/wrong-shaped files (an interrupted pre-atomic
        run, a torn copy, a foreign file landing in the cache dir) must
        degrade to re-execution, never crash the study — the farm shares
        this directory across concurrent writer processes."""
        path = os.path.join(cache_dir, h + ".json")
        try:
            with open(path) as f:
                d = json.load(f)
            if d.get("schema_version") != RESULT_SCHEMA_VERSION:
                return None
            return {k: float(v) for k, v in d["metrics"].items()}
        except (OSError, ValueError, KeyError, TypeError, AttributeError):
            return None

    def _cache_store(self, cache_dir: str, h: str,
                     metrics: Dict[str, float]) -> None:
        """Multi-process-safe store: write a private temp file in the
        cache dir, then `os.replace` it into place — a reader (or a farm
        worker racing on the same cell) sees either no file or a complete
        one, never a torn write. Racing writers both produce the same
        deterministic content, so last-replace-wins is harmless.

        Routed through the fault shim (`site="cache.store"`) so the
        chaos schedules can land corrupt cache entries — which
        `_cache_load` must degrade to misses, never crashes."""
        path = os.path.join(cache_dir, h + ".json")
        _fs.atomic_write_json(
            path, {"schema_version": RESULT_SCHEMA_VERSION,
                   "study": self.name, "metrics": metrics},
            site="cache.store", indent=None)

    def run(self, *, mesh=None, cache: Optional[str] = None) -> StudyResult:
        """Execute the plan and return the columnar frame.

        mesh: shard each batched group's flattened design axis over a
        device mesh (see `Simulator.sweep`). cache: overrides the
        builder's cache directory for this run only (the builder's
        setting is untouched).
        """
        cache_dir = cache if cache is not None else self._cache_dir
        plan = self.plan()
        results, executed, hits = self._execute_cells(
            plan, cache_dir=cache_dir, mesh=mesh)
        return self._frame(plan.cells,
                           [results[i] for i in range(len(plan.cells))],
                           executed, hits)

    def _execute_cells(self, plan: StudyPlan,
                       indices: Optional[Sequence[int]] = None, *,
                       cache_dir: Optional[str] = None, mesh=None
                       ) -> Tuple[Dict[int, Dict[str, float]], int, int]:
        """Execute a subset of the plan's cells (default: all of them).

        Returns ({cell_index: metrics}, executed_cells, cache_hits).
        This is the farm's unit of work: a worker calls it with one
        shard's cell indices against the fleet-shared cache directory.
        Cells of a batched group still execute as ONE vmapped sweep call
        (restricted to the selected, cache-missing members); per-design
        results are bit-identical regardless of how the group was sliced
        into shards, because vmap maps designs independently.

        Failure semantics: a cell whose evaluation raises, or whose
        canonical metrics come back NaN (or ±Inf on a canonical column),
        degrades to a *failed cell* — `cell_status == 1.0`, NaN metrics
        in the frame — instead of poisoning the whole study/shard.
        `ValueError` is the deliberate exception: it marks an invalid
        configuration (validation is loud and early), so it propagates
        rather than silently degrading.
        Completed cells checkpoint to the cache as they finish, so a
        killed long run resumes from its last completed cell on re-run.
        """
        if indices is None:
            sel = set(range(len(plan.cells)))
        else:
            sel = {int(i) for i in indices}
            bad = sel - set(range(len(plan.cells)))
            if bad:
                raise IndexError(f"cell indices {sorted(bad)} outside the "
                                 f"{len(plan.cells)}-cell plan")
        results: Dict[int, Dict[str, float]] = {}
        hashes: Dict[int, str] = {}
        hits = executed = 0

        if cache_dir is not None:
            for i in sorted(sel):
                hashes[i] = self._cell_hash(plan.cells[i])
                got = self._cache_load(cache_dir, hashes[i])
                if got is not None:
                    results[i] = got
                    hits += 1
        loaded = set(results)

        def checkpoint(i: int) -> None:
            # incremental resume point: a completed cell lands in the
            # cache the moment it exists, so a killed run re-started
            # later skips straight past it. Best-effort (a full disk
            # must not fail a computed cell), loaded cells are never
            # rewritten (pure I/O churn), failed cells are never cached
            # (a transient failure must re-execute next run).
            if (cache_dir is None or i in loaded
                    or results[i].get("cell_status")):
                return
            try:
                self._cache_store(cache_dir, hashes[i], results[i])
            except OSError:
                pass

        # batched groups: one vmapped sweep kernel per flavor, executing
        # only the selected, cache-missing cells of each group
        for grp in plan.groups:
            miss = [i for i in grp.cells if i in sel and i not in results]
            if not miss:
                continue
            ops = self._workloads[grp.workload]
            try:
                vals = _sweep_batched(
                    [plan.cells[i].config for i in miss], ops,
                    grp.dataflow, grp.word_bytes, self._ert, mesh,
                    dram=grp.dram, spec=self._spec_for(grp.fidelity),
                    engine=self._engine, core_index=self._core_index)
                vals["edp"] = _edp(vals["energy_pj"],
                                   vals["total_cycles"])
            except ValueError:
                raise    # invalid configuration: loud, never a failed cell
            except Exception:  # noqa: BLE001 — group fails, study lives
                for i in miss:
                    results[i] = {"batched": 1.0, "cell_status": 1.0}
                continue
            for j, i in enumerate(miss):
                results[i] = {k: float(v[j]) for k, v in vals.items()}
                results[i]["batched"] = 1.0
                _flag_non_finite(results[i])
                executed += 1
                checkpoint(i)

        # per-op engine fallback (and custom evaluators)
        pipelines: Dict[str, tuple] = {}
        for i in plan.fallback:
            if i not in sel or i in results:
                continue
            cell = plan.cells[i]
            ops = self._workloads[cell.workload]
            try:
                if self._evaluator is not None:
                    m = {k: float(v) for k, v in
                         self._evaluator(cell.config, ops,
                                         cell.fidelity).items()}
                else:
                    if cell.fidelity not in pipelines:
                        pipelines[cell.fidelity] = st.build_pipeline(
                            cell.fidelity, core_index=self._core_index,
                            trace_spec=self._spec_for(cell.fidelity),
                            engine=self._engine)
                    rep = simulate_network(
                        cell.config, ops, dram_fidelity=cell.fidelity,
                        ert=self._ert,
                        pipeline=pipelines[cell.fidelity])
                    m = dict(total_cycles=rep.total_cycles,
                             compute_cycles=rep.compute_cycles,
                             stall_cycles=rep.stall_cycles,
                             dram_bytes=rep.dram_bytes,
                             energy_pj=rep.energy_pj,
                             utilization=rep.utilization, edp=rep.edp,
                             **energy_group_totals(rep.energy_breakdown))
                    if (cell.config.noc.enabled
                            and cell.config.num_cores > 1):
                        m["noc_stall_cycles"] = rep.noc_stall_cycles
                        m["noc_link_util"] = max(
                            (o.noc_stats or {}).get("noc_link_util", 0.0)
                            for o in rep.ops)
                        m["allreduce_cycles"] = sum(
                            (o.noc_stats or {}).get(
                                "allreduce_cycles", 0.0)
                            * o_count for o, o_count in
                            zip(rep.ops, (op.count for op in ops)))
            except ValueError:
                raise    # invalid configuration: loud, never a failed cell
            except Exception:  # noqa: BLE001 — one bad cell, study lives
                results[i] = {"batched": 0.0, "cell_status": 1.0}
                continue
            m["batched"] = 0.0
            results[i] = m
            _flag_non_finite(results[i])
            executed += 1
            checkpoint(i)

        return results, executed, hits

    def assemble_frame(self, results: Dict[int, Dict[str, float]], *,
                       executed_cells: int = 0, cache_hits: int = 0,
                       plan: Optional[StudyPlan] = None,
                       partial: bool = False) -> StudyResult:
        """Build the StudyResult frame from per-cell metric dicts keyed
        by plan index — the farm client's reassembly path. With every
        cell present this runs the exact `_frame` code path `run()` uses,
        so a farm-reassembled frame is bit-identical to a local run of
        the same plan. `partial=True` permits missing cells and returns
        a frame over the completed rows only (incremental streaming);
        claims attached to this study carry over either way."""
        plan = self.plan() if plan is None else plan
        have = sorted(int(i) for i in results)
        if not partial:
            missing = sorted(set(range(len(plan.cells))) - set(have))
            if missing:
                raise ValueError(
                    f"{len(missing)} cells missing (e.g. {missing[:4]}); "
                    f"pass partial=True for an incremental frame")
        return self._frame([plan.cells[i] for i in have],
                           [results[i] for i in have],
                           executed_cells, cache_hits)

    def _frame(self, cells: Sequence[StudyCell],
               results: List[Dict[str, float]],
               executed: int, hits: int) -> StudyResult:
        metric_names: List[str] = [m for m in METRIC_COLUMNS
                                   if any(m in r for r in results)]
        extra = sorted({k for r in results for k in r}
                       - set(metric_names) - {"batched", "cell_status"})
        metric_names += extra
        if self._metrics is not None:
            missing = set(self._metrics) - set(metric_names)
            if missing:
                raise KeyError(f"metrics not produced by this study: "
                               f"{sorted(missing)}")
            metric_names = [m for m in metric_names if m in self._metrics]
        cols: Dict[str, np.ndarray] = {
            "design": np.array([c.design for c in cells], dtype=object),
            "workload": np.array([c.workload for c in cells],
                                 dtype=object),
            "fidelity": np.array([c.fidelity for c in cells],
                                 dtype=object),
        }
        for m in metric_names:
            cols[m] = np.array([r.get(m, np.nan) for r in results],
                               dtype=np.float64)
        cols["batched"] = np.array([r.get("batched", 0.0) for r in results],
                                   dtype=np.float64)
        # 1.0 = the cell failed (evaluator raised, non-finite canonical
        # metrics, or quarantined shard); its metric columns read NaN
        cols["cell_status"] = np.array(
            [r.get("cell_status", 0.0) for r in results],
            dtype=np.float64)
        axes = {"design": [l for l, _ in self._designs],
                "workload": list(self._workloads),
                "fidelity": list(self._fidelities)}
        res = StudyResult(cols, axes, executed_cells=executed,
                          cache_hits=hits, claims=self._claims)
        # surface the *resolved* replay engine ("pallas" -> its runtime
        # twin/interpret form off-TPU) when any fidelity of this study
        # replays a DRAM stream — result consumers must never have to
        # guess whether "pallas" actually ran or quietly became "xla"
        if any(f in ("trace", "cycle") for f in self._fidelities):
            from ..core import replay as _rp
            res.meta["engine"] = _rp.resolve_engine_runtime(self._engine)
        return res


# --------------------------------------------------------------------------
# Named studies: the paper's analyses as first-class objects
# --------------------------------------------------------------------------

_STUDIES: Dict[str, Callable[..., Study]] = {}


def register_study(name: str):
    """Decorator: register a Study factory under `name` (factories may
    take keyword arguments, e.g. `smoke=True`)."""
    def deco(fn: Callable[..., Study]):
        if name in _STUDIES:
            raise ValueError(f"study {name!r} already registered")
        _STUDIES[name] = fn
        return fn
    return deco


def get_study(name: str, **kw) -> Study:
    if name not in _STUDIES:
        raise KeyError(f"unknown study {name!r}; "
                       f"available: {sorted(_STUDIES)}")
    s = _STUDIES[name](**kw)
    # registry provenance: lets Study.to_spec serialize by reference, so
    # a farm submission of a named study keeps its claims + evaluator
    s._ref = {"study": name, "kwargs": dict(kw)}
    return s


def list_studies() -> List[str]:
    return sorted(_STUDIES)


class _StudyNamespace:
    """`studies.edp_array_size(...)` attribute access over the registry."""

    def __getattr__(self, name: str) -> Callable[..., Study]:
        if name in _STUDIES:
            # route through get_study so the built study carries its
            # registry provenance (serializable as a farm spec)
            import functools

            @functools.wraps(_STUDIES[name])
            def factory(**kw) -> Study:
                return get_study(name, **kw)
            return factory
        raise AttributeError(f"no study {name!r}; "
                             f"available: {sorted(_STUDIES)}")

    def __dir__(self):
        return sorted(_STUDIES)


studies = _StudyNamespace()


@register_study("edp_array_size")
def edp_array_size(smoke: bool = False) -> Study:
    """Paper Table V: array-size sweep on ViT-base linear layers.
    32x32 wins energy (~2.86x vs 128x128), 128x128 wins latency, and
    64x64 wins EdP — the optimum sits between the single-metric winners.
    `smoke` shrinks to 2 transformer layers (identical per-layer shapes,
    so every ratio/winner claim is layer-count invariant)."""
    from ..core.workloads import vit_linear
    wl = vit_linear(768, 2 if smoke else 12, 3072, prefix="vitb")
    s = (Study("edp_array_size")
         .designs({"32": "paper-32", "64": "paper-64", "128": "paper-128"})
         .workloads({"vit-base": wl})
         .fidelity("fast"))
    s.claim("latency_winner_is_128",
            lambda r: r.best("total_cycles")["design"] == "128")
    s.claim("energy_winner_is_32",
            lambda r: r.best("energy_pj")["design"] == "32")
    s.claim("edp_winner_64_between_extremes",
            lambda r: r.best("edp")["design"] == "64")
    s.claim("energy_ratio_128_vs_32_in_band",
            lambda r: 2.3 < float(r.compare("energy_pj", axis="design",
                                            baseline="32")["128"][0]) < 3.4)
    return s


@register_study("dataflow_dram_flip")
def dataflow_dram_flip() -> Study:
    """Paper Sec. IX-B: WS beats OS on compute cycles, but OS wins
    end-to-end once DRAM stalls are modeled — and the OS advantage grows
    at trace fidelity, where the stall model sees the *address stream*
    each dataflow emits (WS's streaming pattern row-thrashes harder than
    the first-order byte-count model predicts)."""
    from ..core.accelerator import tpu_like_config
    from ..core.workloads import resnet18_six_layers
    designs = {df: tpu_like_config(array=32, dataflow=df, sram_mb=0.4)
               for df in ("ws", "os")}
    s = (Study("dataflow_dram_flip")
         .designs(designs)
         .workloads({"resnet18-6": resnet18_six_layers()})
         .fidelity("fast", "trace"))
    s.claim("ws_wins_compute_cycles",
            lambda r: all(
                r.filter(fidelity=f).best("compute_cycles")["design"] == "ws"
                for f in r.axes["fidelity"]))
    s.claim("os_wins_total_once_stalls_modeled",
            lambda r: r.filter(fidelity="trace")
                       .best("total_cycles")["design"] == "os")
    s.claim("os_margin_at_least_20pct",
            lambda r: float(
                r.filter(fidelity="trace").compare(
                    "total_cycles", axis="design", baseline="ws")["os"][0])
            < 0.8)
    s.claim("trace_fidelity_amplifies_flip",
            lambda r: float(r.filter(fidelity="trace").compare(
                "total_cycles", axis="design", baseline="os")["ws"][0])
            > float(r.filter(fidelity="fast").compare(
                "total_cycles", axis="design", baseline="os")["ws"][0]))
    return s


@register_study("multicore_contention")
def multicore_contention_study(channels: Sequence[int] = (1, 2, 4),
                               gemm: Tuple[int, int, int] = (512, 2048, 1024),
                               spec=None) -> Study:
    """Shared-DRAM contention across channel counts on the MCM package:
    per-core demand traces merged through shared channels vs each core
    alone (`simulate_multicore_contention`). The shared run never beats
    isolation, contention is material (>10% makespan inflation), and
    adding channels relieves the shared makespan."""
    from ..core.multicore import contention_summary
    from .presets import get_preset
    M, N, K = gemm

    def cell(cfg: AcceleratorConfig, ops: Sequence[Op],
             fidelity: str) -> Dict[str, float]:
        o = ops[0]
        return contention_summary(cfg, o.M, o.N, o.K, spec=spec)

    s = (Study("multicore_contention")
         .designs({f"ch{c}": get_preset("mcm-4x32", channels=c)
                   for c in channels})
         .workloads({f"gemm-{M}x{N}x{K}": [Op("gemm", M, N, K)]})
         .fidelity("trace")
         # register the spec as the study trace_spec too, so it enters
         # the content hash and distinct specs never share cache cells
         .options(trace_spec=spec)
         .evaluator(cell))
    s.claim("shared_never_beats_isolated",
            lambda r: bool((r["makespan_shared"]
                            >= r["makespan_isolated"] - 1e-6).all()))
    s.claim("contention_is_material",
            lambda r: bool((r["contention_slowdown"] > 1.1).all()))
    s.claim("more_channels_relieve_shared_makespan",
            lambda r: bool(np.all(np.diff(
                r["makespan_shared"][np.argsort(r["channels"])]) <= 0.0)))
    return s


@register_study("sparse_speedup")
def sparse_speedup(smoke: bool = False) -> Study:
    """Paper Sec. IV SpMM claim: on a weight-stationary array streaming
    compressed weights, layer-wise N:M sparsity shrinks compute cycles by
    ~m/n (2:4 halves them, 1:4 quarters them), while row-wise N:M — whose
    per-(row, block) nonzero count is Uniform{1..m/2} and whose fold
    length is the lockstep max over the fold's columns (expected-K model,
    `core.sparsity.effective_K_model`) — lands strictly between dense and
    the matched layer-wise ratio. Every cell, sparse included, executes
    through the batched sweep kernels (`fraction_batched == 1.0`).
    `smoke` shrinks the token dimension; the fold-count ratios the claims
    test are token-count invariant."""
    from .presets import get_preset
    n_tok = 128 if smoke else 1024
    wl = [Op("spmm-ffn1", 4096, n_tok, 1024),
          Op("spmm-ffn2", 1024, n_tok, 4096)]
    s = (Study("sparse_speedup")
         .designs({
             "dense": get_preset("paper-64"),
             "lw-2:4": get_preset("ws-64-sparse-2:4"),
             "lw-1:4": get_preset("ws-64-sparse-2:4", n=1),
             "rw-1:4": get_preset("ws-64-sparse-2:4", n=1, row_wise=True),
         })
         .workloads({"spmm-ffn": wl})
         .fidelity("fast"))

    def speedup(r: StudyResult, design: str) -> float:
        return 1.0 / float(r.compare("compute_cycles", axis="design",
                                     baseline="dense")[design][0])

    s.claim("layerwise_2to4_speedup_near_2x",
            lambda r: 1.9 < speedup(r, "lw-2:4") <= 2.05)
    s.claim("layerwise_1to4_speedup_near_4x",
            lambda r: 3.6 < speedup(r, "lw-1:4") <= 4.1)
    s.claim("rowwise_lands_between_dense_and_layerwise",
            lambda r: float(r.filter(design="lw-1:4")["compute_cycles"][0])
            < float(r.filter(design="rw-1:4")["compute_cycles"][0])
            < float(r.filter(design="dense")["compute_cycles"][0]))
    s.claim("compressed_weights_cut_dram_traffic",
            lambda r: float(r.filter(design="lw-2:4")["dram_bytes"][0])
            < float(r.filter(design="dense")["dram_bytes"][0]))
    s.claim("all_cells_batched",
            lambda r: r.fraction_batched == 1.0)
    return s


@register_study("nop_bound")
def nop_bound(smoke: bool = False) -> Study:
    """Pod-scale NoP study (repro.noc): sweep cores x link bandwidth x
    DRAM channels on routed-mesh pods and machine-check where the
    interconnect — not DRAM bandwidth — bounds the design:

    (a) with contention removed (huge link bandwidth + credit depth) the
        routed NoC reproduces the legacy hop-offset multicore cycles
        *exactly* (the zero-load contract, bit-for-bit);
    (b) beyond a core count, NoP link utilization (> 1: offered load
        exceeds link capacity) — not DRAM bandwidth — dominates stall
        cycles: routed queueing overtakes DRAM stalls at the largest
        pod, and adding DRAM channels stops helping there while it still
        relieves the smallest pod;
    (c) a torus beats a mesh on ring all-reduce makespan at fixed link
        budget (the mesh serpentine must close over already-used links).

    Every cell — 16 to 4096 cores — runs through the batched sweep
    kernels (`fraction_batched == 1.0`); the eager per-core router stays
    available as the `force_fallback` differential oracle.
    """
    from ..noc.topology import routed_hop_counts
    from .presets import get_preset

    pods = (16, 64, 256) if smoke else (64, 256, 1024)
    bw_lo, bw_hi = 4.0, 256.0
    ch_lo, ch_hi = 1, 8
    mm = 512 if smoke else 2048
    wl = [Op("mm1", mm, mm, mm), Op("mm2", 2 * mm, mm // 2, mm)]

    designs: Dict[str, AcceleratorConfig] = {}
    for p in pods:
        for bw in (bw_lo, bw_hi):
            for ch in (ch_lo, ch_hi):
                # scale credit depth with link bandwidth so the fast-link
                # corner is genuinely fast (with a fixed shallow buffer,
                # the credit round-trip s = 2*hop/buffer caps throughput
                # no matter how wide the link is)
                designs[f"mesh-{p}c-bw{int(bw)}-ch{ch}"] = get_preset(
                    "pod-mesh", cores=p, link_bw=bw, channels=ch,
                    buffer_flits=max(8, int(bw)))
        designs[f"torus-{p}c"] = get_preset(
            "pod-mesh", cores=p, topology="torus", link_bw=bw_lo,
            channels=ch_hi, buffer_flits=max(8, int(bw_lo)))

    # the exact zero-load parity pair: legacy per-core hop offsets set to
    # the routed mesh hop counts vs the NoC plane at effectively infinite
    # link bandwidth and credit depth (claim a is bit-for-bit equality)
    legacy = get_preset("pod-mesh", cores=16)
    legacy = legacy.with_(
        cores=tuple(dataclasses.replace(c, nop_hops=int(h))
                    for c, h in zip(legacy.cores,
                                    routed_hop_counts("mesh", 4, 4))),
        noc=dataclasses.replace(legacy.noc, enabled=False))
    designs["legacy-hops"] = legacy
    designs["noc-zero-load"] = get_preset(
        "pod-mesh", cores=16, link_bw=1e9, buffer_flits=1 << 20)

    s = (Study("nop_bound")
         .designs(designs)
         .workloads({f"mm-{mm}": wl})
         .fidelity("fast"))

    def cell(r: StudyResult, design: str, metric: str) -> float:
        return float(r.filter(design=design)[metric][0])

    big, small = pods[-1], pods[0]
    bound = f"mesh-{big}c-bw{int(bw_lo)}-ch{ch_hi}"      # NoP-bound corner
    free = f"mesh-{small}c-bw{int(bw_hi)}-ch{ch_hi}"     # DRAM-bound corner
    s.claim("zero_load_matches_legacy_exactly",
            lambda r: cell(r, "noc-zero-load", "total_cycles")
            == cell(r, "legacy-hops", "total_cycles"))
    s.claim("nop_overtakes_dram_stalls_at_scale",
            lambda r: cell(r, bound, "noc_stall_cycles")
            > cell(r, bound, "stall_cycles")
            and cell(r, free, "noc_stall_cycles")
            < cell(r, free, "stall_cycles"))
    s.claim("link_utilization_scales_with_cores",
            lambda r: cell(r, bound, "noc_link_util") > 1.0
            and all(
                cell(r, f"mesh-{a}c-bw{int(bw_lo)}-ch{ch_hi}",
                     "noc_link_util")
                < cell(r, f"mesh-{b}c-bw{int(bw_lo)}-ch{ch_hi}",
                       "noc_link_util")
                for a, b in zip(pods, pods[1:]))
            and cell(r, free, "noc_stall_cycles")
            < 0.1 * cell(r, free, "total_cycles"))
    s.claim("channels_relieve_dram_bound_not_nop_bound",
            lambda r: (cell(r, f"mesh-{small}c-bw{int(bw_hi)}-ch{ch_lo}",
                            "total_cycles")
                       / cell(r, free, "total_cycles")) > 2.0
            and (cell(r, f"mesh-{big}c-bw{int(bw_lo)}-ch{ch_lo}",
                      "total_cycles")
                 / cell(r, bound, "total_cycles")) < 1.2)
    s.claim("torus_beats_mesh_allreduce_at_fixed_budget",
            lambda r: all(
                cell(r, f"torus-{p}c", "allreduce_cycles")
                < cell(r, f"mesh-{p}c-bw{int(bw_lo)}-ch{ch_hi}",
                       "allreduce_cycles")
                for p in pods))
    s.claim("all_cells_batched",
            lambda r: r.fraction_batched == 1.0)
    return s


# --------------------------------------------------------------------------
# CLI: run a named study, print the frame + claims, emit CSV/JSON
# --------------------------------------------------------------------------

def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import inspect
    ap = argparse.ArgumentParser(
        description="Run a named study (repro.api.study registry)")
    ap.add_argument("--study", required=True, choices=list_studies())
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the study where the factory supports it")
    ap.add_argument("--csv", help="write the result frame as CSV")
    ap.add_argument("--json", dest="json_out",
                    help="write the result frame as JSON")
    ap.add_argument("--cache", help="on-disk cell-cache directory")
    ap.add_argument("--search-log", dest="search_log",
                    help="write the SearchLog JSON artifact "
                         "(search studies only)")
    args = ap.parse_args(argv)

    factory = _STUDIES[args.study]
    kw = {}
    if args.smoke and "smoke" in inspect.signature(factory).parameters:
        kw["smoke"] = True
    study = get_study(args.study, **kw)
    if args.cache:
        study.cache(args.cache)
    res = study.run()
    print(f"study {args.study}: executed {res.executed_cells} cells "
          f"({res.cache_hits} cache hits)")
    if len(res) <= 200:
        print(res.summary())
    else:
        # a search frame holds thousands of rows; print its accounting
        # instead and leave the rows to --csv/--json
        print(f"{len(res)} rows (row dump suppressed; use --csv/--json)")
        for k, v in sorted(res.meta.items()):
            if k != "search_log":
                print(f"  {k} = {v}")
    claims = res.check_claims()
    for name, ok in claims.items():
        print(f"claim {'PASS' if ok else 'FAIL'}: {name}")
    if args.csv:
        res.to_csv(args.csv)
        print(f"wrote {args.csv}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(res.to_json())
        print(f"wrote {args.json_out}")
    if args.search_log:
        blob = res.meta.get("search_log")
        if blob is None:
            print(f"--search-log: {args.study} is not a search study "
                  f"(no log on its result)")
            return 1
        with open(args.search_log, "w") as f:
            f.write(str(blob))
        print(f"wrote {args.search_log}")
    return 0 if all(claims.values()) else 1


if __name__ == "__main__":
    # prefer `python -m repro.api` (repro/api/__main__.py): running this
    # file as __main__ re-executes the module runpy already imported
    import sys
    sys.exit(_main())
