"""Public simulation API: the `Simulator` session facade over the stage
pipeline, the accelerator preset registry, and the batched sweep path.

    from repro.api import Simulator, get_preset, preset_grid

    Simulator("paper-32").run("resnet18")               # one config
    Simulator(fidelity="cycle").run_op(op)              # cycle-accurate DRAM
    Simulator().sweep(preset_grid(array=[16, 32, 64],
                                  sram_mb=[1, 8]), ops) # batched DSE

See DESIGN.md for the stage pipeline and fidelity levels.
"""
from ..core.accelerator import AcceleratorConfig
from ..core.engine import NetworkReport, OpResult
from ..core.stages import FIDELITIES, build_pipeline
from .presets import get_preset, list_presets, preset_grid, register_preset
from .simulator import (Simulator, SweepResult, as_config, as_workload)

__all__ = [
    "AcceleratorConfig", "FIDELITIES", "NetworkReport", "OpResult",
    "Simulator", "SweepResult", "as_config", "as_workload",
    "build_pipeline", "get_preset", "list_presets", "preset_grid",
    "register_preset",
]
