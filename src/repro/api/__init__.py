"""Public simulation API: the `Simulator` session facade over the stage
pipeline, the accelerator preset registry, and the declarative Study
layer (cross-product experiment plans -> columnar result frames).

    from repro.api import Simulator, Study, preset_grid, studies

    Simulator("paper-32").run("resnet18")               # one config
    Simulator(fidelity="cycle").run_op(op)              # cycle-accurate DRAM

    res = (Study()                                      # batched DSE study
           .designs(preset_grid(array=[16, 32, 64], sram_mb=[1, 8]))
           .workloads("resnet18")
           .fidelity("fast", "trace")
           .run())
    res.best("edp")

    studies.edp_array_size().run().check_claims()       # paper claims

See DESIGN.md for the stage pipeline, fidelity levels and the Study
layer (plan -> groups -> frame).
"""
from ..core.accelerator import AcceleratorConfig
from ..core.engine import NetworkReport, OpResult
from ..core.stages import FIDELITIES, build_pipeline
from .presets import (as_sparsity, get_preset, list_presets, preset_grid,
                      register_preset, with_cores)
from .simulator import (Simulator, SweepResult, as_config, as_workload)
from .study import (Study, StudyPlan, StudyResult, get_study, list_studies,
                    register_study, studies)
# the search layer registers its studies (studies.search_edp) on import;
# imported last so repro.search's own imports of repro.api.* submodules
# find them already initialized
from .. import search as _search  # noqa: E402,F401

__all__ = [
    "AcceleratorConfig", "FIDELITIES", "NetworkReport", "OpResult",
    "Simulator", "Study", "StudyPlan", "StudyResult", "SweepResult",
    "as_config", "as_sparsity", "as_workload", "build_pipeline",
    "get_preset", "get_study", "list_presets", "list_studies",
    "preset_grid", "register_preset", "register_study", "studies",
    "with_cores",
]
