"""`python -m repro.api`: run a named study from the registry.

    PYTHONPATH=src python -m repro.api --study edp_array_size --smoke \
        --csv STUDY_edp_array_size.csv

A thin delegate to `repro.api.study._main` — running the package module
(rather than `-m repro.api.study`) avoids runpy re-executing study.py as
`__main__` on top of the copy the package import already registered.
"""
import sys

from .study import _main

sys.exit(_main())
