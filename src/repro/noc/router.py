"""Flit/credit-level link contention model over the static routing tree.

Same shape as `core/replay.py`: an *order-only precompute* (the static
(core, ancestor-link) route pairs from topology.py) turns the contention
fixed point into closed-form scatter reductions, so the whole model is
jit+vmap traceable with no data-dependent control flow.

Model, per design and per op:

  load[l]   = sum of flits injected by cores whose route crosses link l
              (one scatter-add over the route pairs; flit conservation
              load[l] = flits[l] + sum_children load[c] holds by
              construction and is asserted in tests/test_noc.py)
  s         = per-flit service interval = max(flit_bytes / link_bw,
              2 * hop_cycles / buffer_flits) -- a link is either
              bandwidth-limited or credit-round-trip-limited: with B
              credits in flight over a 2*hop_cycles loop, a flit cannot
              be accepted faster than every 2*hop/B cycles
  busy[l]   = load[l] * s            (link serialization time)
  route[u]  = max busy over links on u's route       (bottleneck closure)
  tree[u]   = max busy over the whole subtree hanging off u's route
              (full head-of-line coupling when buffers cannot decouple
              neighbors).  Both closures are the fixed point of the
              monotone relaxation C <- max(busy, max_child C); on a tree
              it has a closed form as one scatter-max over the same
              static pairs -- the replay.py prefix-closure trick.
  eff[u]    = route[u] + kappa * relu(tree[u] - route[u]),
              kappa = s_credit / s in (0, 1]: deep buffers (s dominated
              by bandwidth) decouple neighbors, shallow buffers couple
              the whole subtree.
  extra[u]  = relu(eff[u] - window)  -- queueing delay past the
              injection window (the op's compute makespan).  At zero
              load this is *exactly* 0.0, which is what makes the routed
              model reproduce the legacy hop-offset cycles bit-for-bit.

`windowed_link_sim` is a plain-numpy per-window flit/credit simulation
(bounded buffers, credit back-pressure, one hop per window) used by the
invariant tests; `eager_noc_delay` is the numpy twin of the traced model
and backs the `force_fallback` differential oracle.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from .topology import link_fanin, parent_links, route_pairs


def service_interval(link_bw, flit_bytes, buffer_flits, hop_cycles, xp=jnp):
    """Per-flit acceptance interval: bandwidth- or credit-limited."""
    s_bw = flit_bytes / link_bw
    s_credit = 2.0 * hop_cycles / buffer_flits
    return xp.maximum(s_bw, s_credit), s_credit


def link_loads(topology: str, pr: int, pc: int, flits, xp=jnp):
    """Flits crossing each link (scatter-add over the static route pairs).

    `flits` has shape (..., n_cores); returns (..., n_links) with
    n_links == n_cores (link l = core l's outgoing link; load[0] == 0).
    """
    pair_core, pair_link = route_pairs(topology, pr, pc)
    n = pr * pc
    if xp is jnp:
        zeros = jnp.zeros(flits.shape[:-1] + (n,), flits.dtype)
        return zeros.at[..., pair_link].add(flits[..., pair_core])
    load = np.zeros(flits.shape[:-1] + (n,), dtype=np.float64)
    np.add.at(load, (..., pair_link), np.asarray(flits)[..., pair_core])
    return load


def noc_delay_model(topology: str, pr: int, pc: int, flits, link_bw,
                    flit_bytes, buffer_flits, hop_cycles, window
                    ) -> Dict[str, jnp.ndarray]:
    """Traced contention closure. flits: (..., n); scalars broadcast (...,).

    Returns per-core `extra` (..., n), design-level `stall` = max extra,
    `max_busy` (busiest-link serialization time) and `link_util`
    (demand utilization max_busy / window; > 1 means the NoP is the
    binding constraint).
    """
    pair_core, pair_link = route_pairs(topology, pr, pc)
    n = pr * pc
    flits = jnp.asarray(flits, jnp.float32)
    window = jnp.asarray(window, jnp.float32)
    s, s_credit = service_interval(
        jnp.asarray(link_bw, jnp.float32), jnp.asarray(flit_bytes, jnp.float32),
        jnp.asarray(buffer_flits, jnp.float32),
        jnp.asarray(hop_cycles, jnp.float32))
    busy = link_loads(topology, pr, pc, flits) * s[..., None]
    zeros = jnp.zeros_like(busy)
    # bottleneck closure: busiest link on each core's own route
    route = zeros.at[..., pair_core].max(busy[..., pair_link])
    # subtree closure: busiest link anywhere under each route link, then
    # max over the route -- full head-of-line coupling
    sub = zeros.at[..., pair_link].max(busy[..., pair_core])
    tree = zeros.at[..., pair_core].max(sub[..., pair_link])
    kappa = (s_credit / s)[..., None]
    eff = route + kappa * jnp.maximum(tree - route, 0.0)
    extra = jnp.maximum(eff - window[..., None], 0.0)
    max_busy = jnp.max(busy, axis=-1)
    return dict(
        extra=extra,
        stall=jnp.max(extra, axis=-1),
        max_busy=max_busy,
        link_util=max_busy / jnp.maximum(window, 1.0),
    )


def eager_noc_delay(topology: str, pr: int, pc: int, flits, link_bw,
                    flit_bytes, buffer_flits, hop_cycles, window
                    ) -> Dict[str, np.ndarray]:
    """Pure-numpy float64 twin of `noc_delay_model` (differential oracle)."""
    pair_core, pair_link = route_pairs(topology, pr, pc)
    n = pr * pc
    flits = np.asarray(flits, dtype=np.float64)
    s_bw = float(flit_bytes) / float(link_bw)
    s_credit = 2.0 * float(hop_cycles) / float(buffer_flits)
    s = max(s_bw, s_credit)
    busy = link_loads(topology, pr, pc, flits, xp=np) * s
    route = np.zeros_like(busy)
    np.maximum.at(route, (..., pair_core), busy[..., pair_link])
    sub = np.zeros_like(busy)
    np.maximum.at(sub, (..., pair_link), busy[..., pair_core])
    tree = np.zeros_like(busy)
    np.maximum.at(tree, (..., pair_core), sub[..., pair_link])
    kappa = s_credit / s
    eff = route + kappa * np.maximum(tree - route, 0.0)
    extra = np.maximum(eff - np.asarray(window, np.float64)[..., None], 0.0)
    max_busy = busy.max(axis=-1)
    return dict(
        extra=extra,
        stall=extra.max(axis=-1),
        max_busy=max_busy,
        link_util=max_busy / np.maximum(np.asarray(window, np.float64), 1.0),
    )


def windowed_link_sim(topology: str, pr: int, pc: int, flits, *,
                      cap_per_window: float, buffer_flits: int,
                      windows: int) -> Dict[str, np.ndarray]:
    """Reference per-window flit/credit simulation (numpy, test-only).

    Every link has a `buffer_flits`-deep input buffer at its parent
    router; a link may forward at most `cap_per_window` flits per window
    and only into remaining parent credits (children share the parent's
    free space by its static fan-in, so occupancy can never exceed the
    buffer -- the credit non-negativity invariant).  Source cores inject
    their whole payload into an unbounded local queue up front; flits
    advance one hop per window.

    Returns per-window histories for the invariant tests:
      occupancy (W, n), credits (W, n), sink_served (W,), source_left (W,).
    """
    parent = parent_links(topology, pr, pc)
    fanin = link_fanin(topology, pr, pc)
    n = pr * pc
    B = float(buffer_flits)
    q = np.zeros(n)                       # buffer occupancy per link
    u = np.asarray(flits, dtype=np.float64).copy()  # source backlog
    u[0] = 0.0                            # core 0 sits at the MC: free
    occ, cred, sink, left = [], [], [], []
    sink_total = 0.0
    for _ in range(windows):
        # serve from pre-window state: into parent credits (root -> MC sink
        # is unbounded), children share parent space by fan-in
        space = np.maximum(B - q[parent], 0.0) / np.maximum(fanin[parent], 1)
        space[parent == 0] = np.inf
        srv = np.minimum(np.minimum(q, cap_per_window), space)
        srv[0] = 0.0
        entered = np.zeros(n)
        np.add.at(entered, parent[1:], srv[1:])
        entered[0] = 0.0                  # flits reaching core 0 hit the MC
        sink_total += srv[(parent == 0) & (np.arange(n) > 0)].sum()
        q = q - srv + entered
        # source admission into own link's buffer, after children landed
        adm = np.minimum(u, np.maximum(B - q, 0.0))
        adm = np.minimum(adm, cap_per_window)
        adm[0] = 0.0
        q += adm
        u -= adm
        occ.append(q.copy())
        cred.append(B - q)
        sink.append(sink_total)
        left.append(u.sum())
    return dict(occupancy=np.asarray(occ), credits=np.asarray(cred),
                sink_served=np.asarray(sink), source_left=np.asarray(left))
