"""Interconnect coordinate maps and dimension-ordered routing tables.

Everything here is *static* (plain numpy, hashable inputs): the topology
kind and mesh shape are part of the batched kernel's flavor key, so routing
tables are order-only precompute shared by every design in a sweep group.

The memory controller sits at core 0 (grid position (0, 0)).  Dimension-
ordered (XY) routing gives every core a unique next hop toward the MC, so
the union of all routes is a *tree* rooted at the MC: link `l` is core
`l`'s single outgoing link toward its parent.  That tree structure is what
makes the router's contention closure a single scatter over static
(core, ancestor-link) pairs -- see router.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

from ..core.accelerator import NOC_TOPOLOGIES


def _check(topology: str, pr: int, pc: int) -> None:
    if topology not in NOC_TOPOLOGIES:
        raise ValueError(
            f"topology must be one of {NOC_TOPOLOGIES}, got {topology!r}")
    if pr < 1 or pc < 1:
        raise ValueError(f"mesh shape must be >= 1x1, got {pr}x{pc}")


def _frozen(a: np.ndarray) -> np.ndarray:
    a.setflags(write=False)
    return a


@functools.lru_cache(maxsize=None)
def parent_links(topology: str, pr: int, pc: int) -> np.ndarray:
    """Next-hop core index toward the MC at core 0, per core. parent[0] = 0.

    mesh:  XY order -- retire the column offset first, then the row.
    torus: XY order with wraparound, always stepping along the shorter arc
           (ties break toward decreasing index, so routes stay acyclic).
    ring:  cores form an N-ring regardless of (pr, pc); shorter arc wins.
    """
    _check(topology, pr, pc)
    n = pr * pc
    parent = np.zeros(n, dtype=np.int64)
    if topology == "ring":
        for i in range(1, n):
            parent[i] = i - 1 if i <= n // 2 else (i + 1) % n
        return _frozen(parent)
    for i in range(1, n):
        r, c = divmod(i, pc)
        if c > 0:
            if topology == "torus" and c > pc // 2:
                nr, nc = r, (c + 1) % pc
            else:
                nr, nc = r, c - 1
        else:
            if topology == "torus" and r > pr // 2:
                nr, nc = (r + 1) % pr, 0
            else:
                nr, nc = r - 1, 0
        parent[i] = nr * pc + nc
    return _frozen(parent)


@functools.lru_cache(maxsize=None)
def routed_hop_counts(topology: str, pr: int, pc: int) -> np.ndarray:
    """Hops from each core to the MC along the dimension-ordered route.

    mesh: r + c; torus: min(c, Pc-c) + min(r, Pr-r); ring: min(i, N-i).
    """
    parent = parent_links(topology, pr, pc)
    n = pr * pc
    hops = np.zeros(n, dtype=np.int64)
    # walk parents; tree depth <= pr + pc so this terminates
    order = np.argsort(_depth_key(topology, pr, pc))
    for i in order:
        if i:
            hops[i] = hops[parent[i]] + 1
    return _frozen(hops)


def _depth_key(topology: str, pr: int, pc: int) -> np.ndarray:
    """A key that sorts parents before children (distance lower bound)."""
    n = pr * pc
    i = np.arange(n)
    if topology == "ring":
        return np.minimum(i, n - i)
    r, c = np.divmod(i, pc)
    if topology == "torus":
        return np.minimum(r, pr - r) + np.minimum(c, pc - c)
    return r + c


@functools.lru_cache(maxsize=None)
def route_pairs(topology: str, pr: int, pc: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Static (core, link) pairs: core u crosses link l on its route to MC.

    Link l is core l's outgoing link, so core u's route = [u, parent(u),
    parent^2(u), ...] stopping before core 0 (the MC has no outgoing link).
    The pair list has sum(hops) entries -- the router's order-only
    precompute, analogous to replay.py's per-bank sort permutation.
    """
    parent = parent_links(topology, pr, pc)
    cores, links = [], []
    for u in range(1, pr * pc):
        v = u
        while v != 0:
            cores.append(u)
            links.append(v)
            v = int(parent[v])
    return (_frozen(np.asarray(cores, dtype=np.int64)),
            _frozen(np.asarray(links, dtype=np.int64)))


@functools.lru_cache(maxsize=None)
def subtree_sizes(topology: str, pr: int, pc: int) -> np.ndarray:
    """Cores whose route crosses link l (= size of the subtree under l)."""
    pc_, pl_ = route_pairs(topology, pr, pc)
    sizes = np.zeros(pr * pc, dtype=np.int64)
    np.add.at(sizes, pl_, 1)
    return _frozen(sizes)


@functools.lru_cache(maxsize=None)
def link_fanin(topology: str, pr: int, pc: int) -> np.ndarray:
    """Child links feeding each core's router (for credit sharing)."""
    parent = parent_links(topology, pr, pc)
    fanin = np.zeros(pr * pc, dtype=np.int64)
    for i in range(1, pr * pc):
        fanin[parent[i]] += 1
    return _frozen(fanin)
