"""NocStage: the routed-interconnect stage of the eager pipeline, plus the
per-core arrival-skew feed for the shared-DRAM contention queues.

The stage sits between partition and dram in `core.stages.build_pipeline`:
the partition's compute makespan defines the injection window, and the
op's DRAM demand (the same capacity-based traffic the dram stage computes
right after) defines the payload each core pushes over the NoP toward the
memory controller.  The stage runs the *eager numpy router*
(`router.eager_noc_delay`) so `force_fallback=True` studies act as a
differential oracle against the batched jnp model.

Zero-load contract: when links are fast enough that no queueing occurs,
the stage contributes exactly 0.0 extra cycles, and the partition layer
already uses the routed hop counts (`multicore.effective_nop_hops`) — so
a NoC-enabled design at zero load reproduces the legacy hop-offset
multicore cycles bit-for-bit.

`allreduce_cycles` / `noc_link_util` are *reported* metrics (for
studies.nop_bound claims), not folded into total cycles: the hop offsets
in the partition solve already account for output return latency, and an
explicit collective is workload-dependent.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core import dataflow as dfm
from ..core.accelerator import AcceleratorConfig
from ..core.multicore import effective_nop_hops
from ..core.stages import CoreStage, OpContext
from .router import eager_noc_delay
from .traffic import allreduce_cycles, memory_flits


def _noc_active(cfg: AcceleratorConfig) -> bool:
    return cfg.noc.enabled and cfg.num_cores > 1


class NocStage(CoreStage):
    """Routed NoP contention on the op's memory traffic (eager path)."""
    name = "noc"

    def apply(self, ctx: OpContext) -> None:
        cfg = ctx.cfg
        # sparsity composes like the partition stage: sparse runs model the
        # single-core compressed stream, so there is no multi-core NoP plane
        if not _noc_active(cfg) or ctx.sp.enabled:
            return
        op, core, noc = ctx.op, self.core(ctx), cfg.noc
        n = cfg.num_cores
        # same capacity-based demand the dram stage derives right after
        # (per instance, filter stream shrunk by upstream sparsity)
        dram = dfm.dram_traffic(cfg.dataflow, op.M, op.N, op.K,
                                core.rows, core.cols, cfg.memory)
        wb = cfg.memory.word_bytes
        dram_bytes = float(dram["dram_ifmap"]
                           + dram["dram_filter"] * ctx.filter_shrink
                           + dram["dram_ofmap_writes"]
                           + dram["dram_ofmap_reads"]) * wb
        flits = np.full(n, float(memory_flits(dram_bytes, n, noc.flit_bytes)))
        stats = eager_noc_delay(
            noc.topology, cfg.mesh_rows, cfg.mesh_cols, flits,
            noc.link_bandwidth_bytes_per_cycle, noc.flit_bytes,
            noc.buffer_flits, cfg.nop_cycles_per_hop, ctx.comp)
        ctx.noc_extra = float(stats["stall"])
        # all-reduce of the op's output matrix (per instance) -- same
        # payload convention as the batched kernel's allreduce column
        ar = allreduce_cycles(
            noc.topology, cfg.mesh_rows, cfg.mesh_cols,
            float(op.M) * float(op.N) * wb,
            noc.link_bandwidth_bytes_per_cycle, noc.flit_bytes,
            noc.buffer_flits, cfg.nop_cycles_per_hop)
        ctx.noc_stats = dict(
            noc_link_util=float(stats["link_util"]),
            noc_max_busy=float(stats["max_busy"]),
            allreduce_cycles=float(ar))


def noc_arrival_skew(cfg: AcceleratorConfig, per_core_bytes,
                     window: float) -> np.ndarray:
    """Per-core DRAM arrival offset (cycles): zero-load routed latency plus
    router queueing extra. Feeds `trace.contention.simulate_shared_dram`'s
    request timestamps so NoP skew spreads the shared-queue burst.

    With the NoC plane disabled this is exactly the legacy
    `nop_hops * nop_cycles_per_hop` offset (zero extra), keeping the
    contention path bit-identical to pre-NoC behavior.
    """
    hops = effective_nop_hops(cfg)
    zero_load = hops * cfg.nop_cycles_per_hop
    if not _noc_active(cfg):
        return zero_load
    noc = cfg.noc
    flits = np.asarray(per_core_bytes, dtype=np.float64) / noc.flit_bytes
    stats = eager_noc_delay(
        noc.topology, cfg.mesh_rows, cfg.mesh_cols, flits,
        noc.link_bandwidth_bytes_per_cycle, noc.flit_bytes,
        noc.buffer_flits, cfg.nop_cycles_per_hop, float(window))
    return zero_load + stats["extra"]
