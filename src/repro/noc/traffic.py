"""Injection synthesis: turn the tile schedule into NoP traffic.

Three traffic classes, all traced-friendly (topology and mesh shape are
static; byte counts and link parameters are traced):

  memory_flits          memory-bound NoP traffic: each core's share of the
                        op's DRAM demand, serialized into flits toward the
                        memory controller at core 0.  The partition layer
                        splits work ~evenly (theta-equalization), so the
                        per-core split is uniform -- which also keeps the
                        eager and batched routers numerically identical.
  halo_exchange_cycles  nearest-neighbor exchange (spatial partitions share
                        ifmap halos); gated by the busiest router degree.
  allreduce_cycles      ring all-reduce makespan for output reduction
                        (st1/st2 partials): 2(N-1) steps of payload/N
                        chunks over an embedded ring.  torus/ring embed
                        with unit-hop edges; a mesh serpentine must close
                        with a multi-hop return path that doubles up on
                        serpentine links -- which is exactly why torus
                        beats mesh at fixed link budget (studies.nop_bound
                        claim c).
"""
from __future__ import annotations

import jax.numpy as jnp

from .router import service_interval


def memory_flits(dram_bytes, num_cores: int, flit_bytes):
    """Per-core flits toward the MC for an op's DRAM demand (uniform split)."""
    return dram_bytes / (num_cores * flit_bytes)


def _degree(topology: str, pr: int, pc: int) -> int:
    """Max router degree for neighbor exchange (static)."""
    n = pr * pc
    if topology == "ring":
        return 2 if n >= 3 else max(n - 1, 0)

    def axis_deg(p: int, wrap: bool) -> int:
        if p <= 1:
            return 0
        if p == 2:
            return 1
        return 2 if (wrap or p >= 3) else 1

    return axis_deg(pr, topology == "torus") + axis_deg(pc, topology == "torus")


def halo_exchange_cycles(topology: str, pr: int, pc: int, halo_bytes,
                         link_bw, flit_bytes, buffer_flits, hop_cycles):
    """Makespan of one nearest-neighbor halo exchange round."""
    deg = _degree(topology, pr, pc)
    if deg == 0:
        return jnp.zeros_like(jnp.asarray(halo_bytes, jnp.float32))
    s, _ = service_interval(link_bw, flit_bytes, buffer_flits, hop_cycles)
    flits = halo_bytes / flit_bytes
    return deg * flits * s + hop_cycles


def _ring_embedding(topology: str, pr: int, pc: int):
    """(max_edge_hops, congestion) of the N-ring embedded in the topology.

    torus/ring: every ring edge is a physical link (1 hop, no sharing).
    mesh: serpentine rows give unit edges, but the ring must close from
    the serpentine's last cell back to (0,0); that return path is
    (pr-1) hops (+ pc-1 when pr is odd) and runs over links the
    serpentine already uses, so contended links carry two chunks/step.
    """
    n = pr * pc
    if topology in ("torus", "ring") or n <= 2:
        return 1, 1.0
    closing = (pr - 1) + ((pc - 1) if pr % 2 else 0)
    closing = max(closing, 1)
    return closing, (2.0 if closing > 1 else 1.0)


def allreduce_cycles(topology: str, pr: int, pc: int, payload_bytes,
                     link_bw, flit_bytes, buffer_flits, hop_cycles):
    """Ring all-reduce makespan (reduce-scatter + all-gather)."""
    n = pr * pc
    payload = jnp.asarray(payload_bytes, jnp.float32)
    if n == 1:
        return jnp.zeros_like(payload)
    s, _ = service_interval(link_bw, flit_bytes, buffer_flits, hop_cycles)
    chunk_flits = payload / (n * flit_bytes)
    edge_hops, congestion = _ring_embedding(topology, pr, pc)
    step = congestion * chunk_flits * s + edge_hops * hop_cycles
    return 2.0 * (n - 1) * step
