"""Routed NoC/NoP interconnect plane (ROADMAP item: thousand-core scale-out).

Four layers, mirroring the simulation-plane split elsewhere in the repo:

  topology.py  static coordinate maps + dimension-ordered routing trees
               (numpy, order-only precompute -- lives in the kernel flavor)
  router.py    flit/credit link model (traced jnp: scatter-add loads,
               closed-form max-plus backpressure closure, credit-limited
               service intervals) + the eager numpy twin and a windowed
               reference simulation for invariant tests
  traffic.py   injection synthesis from the tile schedule: memory-bound NoP
               flits per core, halo exchange, ring all-reduce makespans
  stage.py     NocStage for the eager pipeline + the arrival-skew feed into
               trace/contention.py shared-DRAM queues

Config lives in `repro.core.accelerator.NocConfig`; `repro.noc` depends on
`repro.core` but never the reverse (core modules import lazily).
"""
from ..core.accelerator import NOC_TOPOLOGIES, NocConfig
from .router import (eager_noc_delay, link_loads, noc_delay_model,
                     service_interval, windowed_link_sim)
from .stage import NocStage, noc_arrival_skew
from .topology import (parent_links, route_pairs, routed_hop_counts,
                       subtree_sizes)
from .traffic import allreduce_cycles, halo_exchange_cycles, memory_flits

__all__ = [
    "NOC_TOPOLOGIES", "NocConfig", "NocStage", "allreduce_cycles",
    "eager_noc_delay", "halo_exchange_cycles", "link_loads", "memory_flits",
    "noc_arrival_skew", "noc_delay_model", "parent_links", "route_pairs",
    "routed_hop_counts", "service_interval", "subtree_sizes",
    "windowed_link_sim",
]
