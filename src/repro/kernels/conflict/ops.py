"""Jit'd wrapper: layout slowdown for a streaming access pattern."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.accelerator import LayoutConfig
from ...core.layout import flat_ids, streaming_access_pattern
from .conflict import conflict_slowdown


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("cfg", "R", "n_cycles",
                                             "word_bytes", "interpret"))
def layout_slowdown(cfg: LayoutConfig, *, R: int, n_cycles: int,
                    lead_stride: int, elem_stride: int, word_bytes: int = 2,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Per-cycle slowdown of a systolic streaming pattern (Pallas path)."""
    interpret = _default_interpret() if interpret is None else interpret
    idx = streaming_access_pattern(R, n_cycles, lead_stride, elem_stride)
    line, _, bank = flat_ids(idx, cfg, word_bytes)
    return conflict_slowdown(line, bank, num_banks=cfg.num_banks,
                             ports=cfg.ports_per_bank, interpret=interpret)
