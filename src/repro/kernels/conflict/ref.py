"""Pure-jnp oracle for the bank-conflict kernel: core.layout's sort-based
distinct counting (the vectorized form of the paper's Sec. VI equations)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.layout import slowdown_per_cycle


def conflict_slowdown_reference(line: jnp.ndarray, bank: jnp.ndarray, *,
                                num_banks: int, ports: int = 1) -> jnp.ndarray:
    return slowdown_per_cycle(line, bank, num_banks, ports).astype(jnp.int32)
