"""Pallas kernel: per-cycle SRAM bank-conflict slowdown (paper Sec. VI).

Input: the (line_id, bank_id) of each of the k elements a cycle requests
from the multi-bank on-chip memory. Output per cycle:

    slowdown = max_b ceil(distinct_lines(bank b) / ports_per_bank)

Distinct counting inside the kernel avoids sorts (not VPU-friendly): access
j is "first" iff no j' < j shares its (bank, line); per-bank counts then come
from a one-hot contraction — O(k^2) in VREGs, with k = array rows + cols
(small). Grid tiles the cycle axis; each block holds (blk, k) ids in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conflict_kernel(line_ref, bank_ref, o_ref, *, num_banks: int,
                     ports: int):
    line = line_ref[...]                       # (blk, k)
    bank = bank_ref[...]
    blk, k = line.shape
    same = (line[:, :, None] == line[:, None, :]) & \
           (bank[:, :, None] == bank[:, None, :])        # (blk, k, k)
    j = jax.lax.broadcasted_iota(jnp.int32, (blk, k, k), 1)
    jp = jax.lax.broadcasted_iota(jnp.int32, (blk, k, k), 2)
    earlier = same & (jp < j)
    is_first = ~jnp.any(earlier, axis=2)                 # (blk, k)
    onehot = (bank[:, :, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, num_banks), 2))
    counts = jnp.sum(is_first[:, :, None] & onehot, axis=1)   # (blk, banks)
    per_bank = -(-counts // ports)
    o_ref[...] = jnp.maximum(1, jnp.max(per_bank, axis=1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_banks", "ports", "blk",
                                             "interpret"))
def conflict_slowdown(line: jnp.ndarray, bank: jnp.ndarray, *,
                      num_banks: int, ports: int = 1, blk: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """(cycles, k) line/bank ids -> (cycles,) int slowdown, >= 1."""
    cycles, k = line.shape
    blk = min(blk, cycles)
    grid = (pl.cdiv(cycles, blk),)
    return pl.pallas_call(
        functools.partial(_conflict_kernel, num_banks=num_banks, ports=ports),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, k), lambda i: (i, 0)),
                  pl.BlockSpec((blk, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cycles,), jnp.int32),
        interpret=interpret,
    )(line.astype(jnp.int32), bank.astype(jnp.int32))
