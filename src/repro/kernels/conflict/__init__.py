from .conflict import conflict_slowdown
from .ops import layout_slowdown
from .ref import conflict_slowdown_reference
