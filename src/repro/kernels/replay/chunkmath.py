"""Shared per-chunk replay math for the trace-fidelity DRAM engines.

One implementation of the chunked bank-parallel replay step, written so
the *same functions* execute in two worlds:

  - inside the Pallas trace-replay megakernel
    (`kernels.replay.megakernel`), on VMEM-resident chunk slices, and
  - inside the XLA `lax.scan` twin (`core.replay.replay_decoded`), on
    jnp arrays with arbitrary leading batch dims.

That is the CPU-CI story: the twin is not a reimplementation, it is the
kernel body traced by XLA instead of Mosaic, so a divergence between
"what CI tested" and "what the TPU runs" cannot hide in duplicated math.

Everything here is expressed in the `kernels.conflict` idiom — masked
(C, C) / (B, C) / (Q, C) one-hot contractions built from
`broadcasted_iota` compares — because that is the intersection of what
Mosaic lowers well (no gathers, no scatters, no sorts, reductions over
a minor/sublane axis) and what XLA-CPU fuses well.  All shapes are
static; every input is `(..., C)` with optional leading batch dims.

Semantics (the reference per-request scan, `core.dram._reference_scan`):

  head      = ring[dir_idx % Q]       (in-flight window, per direction
                                       and — shared-DRAM — per channel)
  issue_ok  = max(t + shift, head)
  ready     = max(issue_ok, bank_free[bank])
  done      = max(ready + lat, bus_free[channel]) + busy
  shift    += max(0, issue_ok - (t + shift))   == running max of head - t

Within a chunk the serial recurrences are closed per fixed-point pass:
the channel chain as a weighted max-plus prefix (a masked row-sum
builds the inclusive weight prefix W; the chain closes as
`rowmax(mchan, s - W) + W`), the same-bank chain as a masked row
reduction over the bank-latency prefix V, queue heads and previous
same-bank completions as one-hot gathers of the previous iterate.  The
pass operator is monotone from below and finalizes at least the first
not-yet-exact request per pass, so its least fixed point is the serial
result; `iterate_fixed_point` seeds two passes and escapes into a
capped while_loop only if the second pass still moved a completion by
more than `tol` cycles.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ...core.accelerator import DramConfig
from ...core.dram import row_buffer_latency

# A plain Python float: module import may first happen inside a jit
# trace (lazy imports in core.replay), where creating a jnp scalar at
# module scope would leak a tracer into this global.
_NEG = float("-inf")


def _iota(shape, dim):
    """broadcasted_iota everywhere — 1-D iota does not lower on TPU."""
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def rowmax(mask, x, fill=_NEG):
    """max over the last axis of `x` broadcast against `mask`'s rows."""
    return jnp.max(jnp.where(mask, x[..., None, :], fill), axis=-1)


def rowsum(mask, x):
    return jnp.sum(jnp.where(mask, x[..., None, :], 0), axis=-1)


def onehot_pick(oh, x, fill):
    """Value of `x` at the (at most one) set column per row of `oh`."""
    return jnp.max(jnp.where(oh, x[..., None, :], fill), axis=-1)


class ChunkTables(NamedTuple):
    """Order-only per-chunk tables (no carried state involved).

    All masks follow the row = consumer / column = producer convention:
    `mask[..., i, j]` is True when request j (column) feeds request i.
    """
    mbank: jnp.ndarray      # (..., C, C) same-bank & valid-j & j <= i
    mchan: jnp.ndarray      # (..., C, C) same-channel & valid-j & j <= i
    mshift: jnp.ndarray     # (..., C, C) same-core & valid-j & j < i
    gprev: jnp.ndarray      # (..., C, C) one-hot pruned prev same-bank
    ghead: jnp.ndarray      # (..., C, C) one-hot in-chunk queue head src
    intra: jnp.ndarray      # (..., C)    has a same-bank predecessor here
    row_prev: jnp.ndarray   # (..., C)    its row (undefined where ~intra)
    lat_intra: jnp.ndarray  # (..., C)    its row-buffer latency, else 0
    we: jnp.ndarray         # (..., C)    channel max-plus edge weight
    W: jnp.ndarray          # (..., C)    inclusive channel weight prefix
    bank_oh: jnp.ndarray    # (..., B, C) bank one-hot (valid only)
    chan_oh: jnp.ndarray    # (..., ch_n, C)
    core_oh: jnp.ndarray    # (..., n_cores, C)
    g_oh: jnp.ndarray       # (..., n_qg, C) queue-group one-hot
    qg: jnp.ndarray         # (..., C)    queue group id
    rdx: jnp.ndarray        # (..., C)    read index within (chunk, group)
    wdx: jnp.ndarray        # (..., C)
    nr: jnp.ndarray         # (..., n_qg) reads per group in this chunk
    nw: jnp.ndarray         # (..., n_qg)
    surv_r: jnp.ndarray     # (..., C)    last writer of its ring slot
    surv_w: jnp.ndarray     # (..., C)
    last_b: jnp.ndarray     # (..., B)    chunk-local last request per bank
    last_c: jnp.ndarray     # (..., ch_n)


def chunk_tables(fb, ch, row, w, v, cid, *, cfg: DramConfig, busy: float,
                 n_cores: int, n_qg: int) -> ChunkTables:
    """Everything about one chunk that depends only on stream order.

    Runs per chunk step — inside the megakernel's chunk loop and inside
    the twin's scan body.  The (C, C) masks stay register/VMEM resident
    either way; hoisting them would stream (chunks, C, C) tensors
    through HBM instead.
    """
    C = fb.shape[-1]
    sq = fb.shape + (C,)
    ii = _iota(sq, fb.ndim - 1)          # row index i (consumer)
    jj = _iota(sq, fb.ndim)              # col index j (producer)
    idx = _iota(fb.shape, fb.ndim - 1)
    vj = v[..., None, :]
    low = jj <= ii
    strict = jj < ii

    same_bank = fb[..., None, :] == fb[..., :, None]
    mbank = same_bank & vj & low
    prev = rowmax(same_bank & vj & strict, idx, -1)
    intra = prev >= 0
    prev_oh = (jj == prev[..., :, None]) & intra[..., :, None]
    row_prev = onehot_pick(prev_oh, row, -1)
    lat_intra, _, _ = row_buffer_latency(
        cfg, jnp.where(intra, row_prev, -1), row)
    lat_intra = jnp.where(intra, lat_intra, 0).astype(jnp.float32)

    same_ch = ch[..., None, :] == ch[..., :, None]
    mchan = same_ch & vj & low
    # channel max-plus edge: the bus burst, plus the row latency folded
    # in when the previous channel request sits on the same bank (bank
    # chains are subsequences of a channel chain, so contiguous
    # same-bank runs ride the channel closure)
    pin = rowmax(same_ch & vj & strict, idx, -1)
    pin_oh = (jj == pin[..., :, None]) & (pin >= 0)[..., :, None]
    linked = intra & (onehot_pick(pin_oh, fb, -1) == fb)
    we = jnp.where(v, busy + jnp.where(linked, lat_intra, 0.0), 0.0)
    W = rowsum(mchan, we).astype(jnp.float32)
    # prune the iterated same-bank gather: links whose channel path
    # already outweighs their latency are provably dominated
    W_prev = onehot_pick(prev_oh, W, 0.0)
    prev_link = jnp.where(intra & (lat_intra + busy > W - W_prev),
                          prev, -1)
    gprev = (jj == prev_link[..., :, None]) & (prev_link >= 0)[..., :, None]

    same_core = cid[..., None, :] == cid[..., :, None]
    mshift = same_core & vj & strict

    # queue groups + per-direction indices within (chunk, group)
    qg = ch if n_qg > 1 else jnp.zeros_like(fb)
    same_g = qg[..., None, :] == qg[..., :, None]
    rm = v & ~w
    wm = v & w
    rdx = rowsum(same_g & rm[..., None, :] & strict,
                 jnp.ones_like(fb)).astype(jnp.int32)
    wdx = rowsum(same_g & wm[..., None, :] & strict,
                 jnp.ones_like(fb)).astype(jnp.int32)
    g_oh = (_iota(qg.shape[:-1] + (n_qg, C), qg.ndim - 1) ==
            qg[..., None, :]) & vj
    nr = jnp.sum(g_oh & rm[..., None, :], axis=-1).astype(jnp.int32)
    nw = jnp.sum(g_oh & wm[..., None, :], axis=-1).astype(jnp.int32)

    # in-chunk queue-head source: the same-(group, direction) request
    # exactly Q back, when it falls inside this chunk
    Qr, Qw = cfg.read_queue, cfg.write_queue
    if Qr < C or Qw < C:
        eq_r = (rdx[..., None, :] == rdx[..., :, None] - Qr) & \
            rm[..., None, :] & rm[..., :, None] & same_g
        eq_w = (wdx[..., None, :] == wdx[..., :, None] - Qw) & \
            wm[..., None, :] & wm[..., :, None] & same_g
        ghead = jnp.where(w[..., :, None], eq_w, eq_r)
    else:
        ghead = jnp.zeros(sq, bool)

    # ring survivors: a request is the last writer of its slot iff it is
    # among the last Q of its (group, direction) in the chunk
    nr_at = jnp.sum(jnp.where(g_oh, nr[..., :, None], 0), axis=-2)
    nw_at = jnp.sum(jnp.where(g_oh, nw[..., :, None], 0), axis=-2)
    surv_r = rm & (rdx + Qr >= nr_at)
    surv_w = wm & (wdx + Qw >= nw_at)

    ch_n = cfg.channels
    n_banks = ch_n * cfg.banks_per_channel
    bank_oh = (_iota(fb.shape[:-1] + (n_banks, C), fb.ndim - 1) ==
               fb[..., None, :]) & vj
    chan_oh = (_iota(ch.shape[:-1] + (ch_n, C), ch.ndim - 1) ==
               ch[..., None, :]) & vj
    core_oh = (_iota(cid.shape[:-1] + (n_cores, C), cid.ndim - 1) ==
               cid[..., None, :]) & vj
    last_b = jnp.max(jnp.where(bank_oh, idx[..., None, :], -1), axis=-1)
    last_c = jnp.max(jnp.where(chan_oh, idx[..., None, :], -1), axis=-1)

    return ChunkTables(
        mbank=mbank, mchan=mchan, mshift=mshift, gprev=gprev, ghead=ghead,
        intra=intra, row_prev=row_prev, lat_intra=lat_intra, we=we, W=W,
        bank_oh=bank_oh, chan_oh=chan_oh, core_oh=core_oh, g_oh=g_oh,
        qg=qg, rdx=rdx, wdx=wdx, nr=nr, nw=nw,
        surv_r=surv_r, surv_w=surv_w, last_b=last_b, last_c=last_c)


def iterate_fixed_point(one_pass, zero, *, cap: int, tol: float,
                        use_cond: bool):
    """The unified fixed-point contract, shared by every engine:

    seed `min(2, cap)` statically-unrolled passes; if the second pass
    still moved any completion by more than `tol` cycles, iterate a
    while_loop until converged, hard-capped at `cap` total passes
    (`max_passes` when the caller gave one, else C + 2 — each pass
    finalizes at least one request, so C passes always suffice).

    `use_cond=True` keeps the while_loop off the hot path behind a
    lax.cond (the twin); the megakernel enters the while_loop directly
    (it runs zero iterations when converged — same semantics, and
    Mosaic prefers the single loop over a branched body).
    """
    if cap <= 1:
        return one_pass(zero)
    d0 = one_pass(zero)
    d1 = one_pass(d0)
    if cap <= 2:
        return d1

    def cond_f(s):
        return jnp.logical_and(s[2] < cap, jnp.any(s[1] - s[0] > tol))

    def body_f(s):
        return (s[1], one_pass(s[1]), s[2] + 1)

    def _loop(dd):
        _, dn, _ = jax.lax.while_loop(cond_f, body_f,
                                      (dd[0], dd[1], jnp.int32(2)))
        return dn

    if not use_cond:
        return _loop((d0, d1))
    return jax.lax.cond(jnp.any(d1 - d0 > tol), _loop,
                        lambda dd: dd[1], (d0, d1))


class ChunkState(NamedTuple):
    """Architectural state carried across chunks (per stream)."""
    bank_free: jnp.ndarray   # (..., B)
    bus_free: jnp.ndarray    # (..., ch_n)
    ring_r: jnp.ndarray      # (..., n_qg, Qr) in-flight read completions
    ring_w: jnp.ndarray      # (..., n_qg, Qw)
    ir: jnp.ndarray          # (..., n_qg) reads admitted so far
    iw: jnp.ndarray          # (..., n_qg)
    shift: jnp.ndarray       # (..., n_cores) queue backpressure


def init_state(batch, *, n_banks: int, ch_n: int, n_qg: int, Qr: int,
               Qw: int, n_cores: int) -> ChunkState:
    f32 = jnp.float32
    return ChunkState(
        bank_free=jnp.zeros(batch + (n_banks,), f32),
        bus_free=jnp.zeros(batch + (ch_n,), f32),
        ring_r=jnp.zeros(batch + (n_qg, Qr), f32),
        ring_w=jnp.zeros(batch + (n_qg, Qw), f32),
        ir=jnp.zeros(batch + (n_qg,), jnp.int32),
        iw=jnp.zeros(batch + (n_qg,), jnp.int32),
        shift=jnp.zeros(batch + (n_cores,), f32))


def chunk_resolve(state: ChunkState, tab: ChunkTables, t, lat, w, v, *,
                  cfg: DramConfig, busy: float, max_passes: Optional[int],
                  tol: float, use_cond: bool):
    """Resolve one chunk's completion times against the carried state and
    advance the state.  `lat` is the full per-request row-buffer latency
    (the caller classifies first-per-bank-in-chunk requests against its
    open-row view; intra-chunk requests use `tab.lat_intra`).

    Returns (new_state, done, head) — `done` is 0 where ~valid, `head`
    is the final queue-head time (for the caller's shift bookkeeping).
    """
    Qr, Qw = cfg.read_queue, cfg.write_queue
    C = t.shape[-1]
    f32 = jnp.float32
    lat = lat.astype(f32)

    # carried-state gathers as one-hot contractions
    bank0 = jnp.sum(jnp.where(tab.bank_oh,
                              state.bank_free[..., :, None], 0.0), axis=-2)
    bus0 = jnp.sum(jnp.where(tab.chan_oh,
                             state.bus_free[..., :, None], 0.0), axis=-2)
    shift0 = jnp.sum(jnp.where(tab.core_oh,
                               state.shift[..., :, None], 0.0), axis=-2)
    ir_i = jnp.sum(jnp.where(tab.g_oh, state.ir[..., :, None], 0), axis=-2)
    iw_i = jnp.sum(jnp.where(tab.g_oh, state.iw[..., :, None], 0), axis=-2)
    sl_r = (tab.rdx + ir_i) % Qr
    sl_w = (tab.wdx + iw_i) % Qw

    def ring_read(ring, sl, Q):
        # head_i = ring[group_i, slot_i] via a (C, n_qg, Q) one-hot
        n_qg = ring.shape[-2]
        shp = sl.shape + (n_qg, Q)
        oh = (_iota(shp, sl.ndim) == tab.qg[..., :, None, None]) & \
            (_iota(shp, sl.ndim + 1) == sl[..., :, None, None])
        return jnp.sum(jnp.where(oh, ring[..., None, :, :], 0.0),
                       axis=(-2, -1))

    head0 = jnp.where(w, ring_read(state.ring_w, sl_w, Qw),
                      ring_read(state.ring_r, sl_r, Qr))
    intra_heads = Qr < C or Qw < C
    W = tab.W
    V = rowsum(tab.mbank, jnp.where(v, lat + busy, 0.0))

    def one_pass(done):
        if intra_heads:
            head = jnp.maximum(head0, rowmax(tab.ghead, done))
        else:
            head = head0
        g = jnp.where(v, head - t, _NEG)
        ss = jnp.maximum(shift0, rowmax(tab.mshift, g))
        issue_ok = jnp.maximum(t + ss, head)
        bankp = jnp.maximum(bank0, rowmax(tab.gprev, done))
        # seed with the previous iterate so bank-raised completions of
        # other banks propagate down the channel chain across passes
        s = jnp.maximum(jnp.maximum(issue_ok, bankp) + lat + busy, done)
        u = jnp.maximum(rowmax(tab.mchan, jnp.where(v, s - W, _NEG)) + W,
                        bus0 + W)
        d = rowmax(tab.mbank, jnp.where(v, u - V, _NEG)) + V
        return jnp.where(v, d, 0.0)

    cap = (C + 2) if max_passes is None else max_passes
    done = iterate_fixed_point(one_pass, jnp.zeros(t.shape, f32),
                               cap=cap, tol=tol, use_cond=use_cond)

    # final derived state
    if intra_heads:
        head = jnp.maximum(head0, rowmax(tab.ghead, done))
    else:
        head = head0
    g = jnp.where(v, head - t, _NEG)
    shift = jnp.maximum(
        state.shift,
        jnp.max(jnp.where(tab.core_oh, g[..., None, :], _NEG), axis=-1))

    idx = _iota(t.shape, t.ndim - 1)
    upd_b = tab.bank_oh & (idx[..., None, :] == tab.last_b[..., :, None])
    bank_free = jnp.where(tab.last_b >= 0,
                          rowmax(upd_b, done, 0.0), state.bank_free)
    upd_c = tab.chan_oh & (idx[..., None, :] == tab.last_c[..., :, None])
    bus_free = jnp.where(tab.last_c >= 0,
                         rowmax(upd_c, done, 0.0), state.bus_free)

    def ring_write(ring, sl, surv, Q):
        # slot s of group g takes done of its surviving writer, if any
        n_qg = ring.shape[-2]
        shp = sl.shape[:-1] + (n_qg, Q, C)
        oh = (_iota(shp, sl.ndim - 1) == tab.qg[..., None, None, :]) & \
            (_iota(shp, sl.ndim) == sl[..., None, None, :]) & \
            surv[..., None, None, :]
        got = jnp.max(jnp.where(oh, done[..., None, None, :], _NEG),
                      axis=-1)
        return jnp.where(jnp.any(oh, axis=-1), got, ring)

    ring_r = ring_write(state.ring_r, sl_r, tab.surv_r, Qr)
    ring_w = ring_write(state.ring_w, sl_w, tab.surv_w, Qw)

    new_state = ChunkState(
        bank_free=bank_free, bus_free=bus_free, ring_r=ring_r,
        ring_w=ring_w, ir=state.ir + tab.nr, iw=state.iw + tab.nw,
        shift=shift)
    return new_state, done, head
