"""Fused trace-replay kernels: shared chunk math + the Pallas megakernel.

`chunkmath` is the single implementation of the chunked bank-parallel
replay step; `megakernel` wraps it in one `pallas_call` over a grid of
streams, and `core.replay.replay_decoded` traces the same functions
through XLA as the CPU twin.
"""
from .chunkmath import (ChunkState, ChunkTables, chunk_resolve,
                        chunk_tables, init_state, iterate_fixed_point)
from .megakernel import replay_megakernel

__all__ = [
    "ChunkState", "ChunkTables", "chunk_resolve", "chunk_tables",
    "init_state", "iterate_fixed_point", "replay_megakernel",
]
