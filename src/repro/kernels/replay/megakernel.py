"""Fused Pallas trace-replay megakernel.

One `pallas_call` replays a whole batch of decoded DRAM request streams:
designs/ops are flattened along the Pallas grid (one stream per grid
step), each stream's request arrays are staged into VMEM as a single
block, and a `fori_loop` walks the stream in `chunk`-sized windows —
per-chunk order-only tables, the fixed-point resolve, and the
architectural state (bank free/open-row, channel bus, in-flight rings,
queue counters, per-core shift) all live in registers/VMEM for the whole
stream.  This replaces the XLA driver's hoisted precompute + `lax.scan`
(hundreds of small dispatches per stream batch) with one kernel launch.

The chunk math is not duplicated here: `_megakernel_body` calls the very
same `chunk_tables` / `chunk_resolve` that `core.replay.replay_decoded`
traces through XLA (`kernels.replay.chunkmath`).  Off-TPU, CI exercises
this kernel through `interpret=True`; the compiled CPU path resolves to
the XLA twin, which is the same math by construction.

Everything inside the kernel is masked one-hot contractions over static
shapes — no gathers, scatters, or sorts — per the conflict-kernel idiom
that Mosaic lowers cleanly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.accelerator import DramConfig
from ...core.dram import row_buffer_latency
from . import chunkmath as cm


def _megakernel_body(t_ref, fb_ref, ch_ref, row_ref, w_ref, v_ref, cid_ref,
                     done_ref, shift_ref, cnt_ref, *, cfg: DramConfig,
                     busy: float, C: int, nc: int,
                     max_passes: Optional[int], tol: float, n_cores: int,
                     n_qg: int):
    n_banks = cfg.channels * cfg.banks_per_channel
    Qr, Qw = cfg.read_queue, cfg.write_queue
    state0 = cm.init_state((), n_banks=n_banks, ch_n=cfg.channels,
                           n_qg=n_qg, Qr=Qr, Qw=Qw, n_cores=n_cores)
    open0 = -jnp.ones((n_banks,), jnp.int32)
    zero = jnp.int32(0)

    def chunk(i, carry):
        state, open_row, hits, misses, conflicts = carry
        sl = pl.ds(i * C, C)
        t = t_ref[0, sl]
        fb = fb_ref[0, sl]
        ch = ch_ref[0, sl]
        row = row_ref[0, sl]
        w = w_ref[0, sl] != 0
        v = v_ref[0, sl] != 0
        cid = cid_ref[0, sl]

        tab = cm.chunk_tables(fb, ch, row, w, v, cid, cfg=cfg, busy=busy,
                              n_cores=n_cores, n_qg=n_qg)
        # classify: intra-chunk links are order-only; first-per-bank
        # requests consult the carried open-row view
        open_at = jnp.sum(jnp.where(tab.bank_oh, open_row[..., :, None],
                                    0), axis=-2)
        seen = jnp.where(tab.intra, tab.row_prev, open_at)
        lat, hit, empty = row_buffer_latency(cfg, seen, row)
        hits = hits + jnp.sum((hit & v).astype(jnp.int32))
        misses = misses + jnp.sum((empty & v).astype(jnp.int32))
        conflicts = conflicts + jnp.sum(
            ((~hit) & (~empty) & v).astype(jnp.int32))

        state, done, _ = cm.chunk_resolve(
            state, tab, t, lat, w, v, cfg=cfg, busy=busy,
            max_passes=max_passes, tol=tol, use_cond=False)

        idx = cm._iota(row.shape, row.ndim - 1)
        upd = tab.bank_oh & (idx[..., None, :] == tab.last_b[..., :, None])
        open_row = jnp.where(
            tab.last_b >= 0,
            jnp.max(jnp.where(upd, row[..., None, :], -1), axis=-1),
            open_row)

        done_ref[0, sl] = done
        return (state, open_row, hits, misses, conflicts)

    state, _, hits, misses, conflicts = jax.lax.fori_loop(
        0, nc, chunk, (state0, open0, zero, zero, zero))
    shift_ref[0, :] = state.shift
    cnt_ref[0, 0] = hits
    cnt_ref[0, 1] = misses
    cnt_ref[0, 2] = conflicts
    cnt_ref[0, 3] = zero


def replay_megakernel(t_issue, flat_bank, ch, row, is_write, valid,
                      cfg: DramConfig, gran_bytes: int = 64, *,
                      chunk: Optional[int] = None,
                      max_passes: Optional[int] = None,
                      tol: float = 0.25, n_cores: int = 1, core_id=None,
                      per_channel_queues: bool = False,
                      interpret: bool = False):
    """Replay a (batched) decoded request stream in one fused kernel.

    Same contract and return dict as `core.replay.replay_decoded`:
    inputs are `(..., n)` with arbitrary leading batch dims (flattened
    onto the Pallas grid — one stream per grid step), `done` is raw
    per-request completion (0 where ~valid), plus per-request `latency`,
    per-core `shift`, and exact hit/miss/conflict counters.
    """
    n = t_issue.shape[-1]
    batch = t_issue.shape[:-1]
    C = 64 if chunk is None else int(chunk)
    C = max(1, min(C, max(n, 1)))
    n_qg = cfg.channels if per_channel_queues else 1
    busy = float(max(1.0, gran_bytes / cfg.bandwidth_bytes_per_cycle))
    passes = None if max_passes is None else max(1, int(max_passes))
    f32 = jnp.float32

    if core_id is None:
        core_id = jnp.zeros(t_issue.shape, jnp.int32)

    pad = (-n) % C
    nc = (n + pad) // C
    npad = nc * C
    S = 1
    for b in batch:
        S *= int(b)

    def _prep(x, fill, dtype):
        x = jnp.broadcast_to(jnp.asarray(x).astype(dtype), batch + (n,))
        if pad:
            x = jnp.concatenate(
                [x, jnp.full(batch + (pad,), fill, dtype)], axis=-1)
        return x.reshape((S, npad))

    ins = (_prep(t_issue, 0.0, f32), _prep(flat_bank, 0, jnp.int32),
           _prep(ch, 0, jnp.int32), _prep(row, 0, jnp.int32),
           _prep(is_write, 0, jnp.int32), _prep(valid, 0, jnp.int32),
           _prep(core_id, 0, jnp.int32))

    kern = functools.partial(
        _megakernel_body, cfg=cfg, busy=busy, C=C, nc=nc,
        max_passes=passes, tol=float(tol), n_cores=n_cores, n_qg=n_qg)
    stream_spec = pl.BlockSpec((1, npad), lambda s: (s, 0))
    done, shift, cnt = pl.pallas_call(
        kern,
        grid=(S,),
        in_specs=[stream_spec] * 7,
        out_specs=[stream_spec,
                   pl.BlockSpec((1, n_cores), lambda s: (s, 0)),
                   pl.BlockSpec((1, 4), lambda s: (s, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, npad), f32),
                   jax.ShapeDtypeStruct((S, n_cores), f32),
                   jax.ShapeDtypeStruct((S, 4), jnp.int32)],
        interpret=interpret,
    )(*ins)

    def _unflat(y, tail):
        return y.reshape(batch + tail)

    done = _unflat(done, (npad,))[..., :n]
    vmask = jnp.broadcast_to(jnp.asarray(valid, bool), batch + (n,))
    ti = jnp.broadcast_to(jnp.asarray(t_issue).astype(f32), batch + (n,))
    rt = jnp.where(vmask, done - ti, 0.0)
    return dict(done=done, latency=rt,
                shift=_unflat(shift, (n_cores,)),
                hits=_unflat(cnt, (4,))[..., 0],
                misses=_unflat(cnt, (4,))[..., 1],
                conflicts=_unflat(cnt, (4,))[..., 2])
