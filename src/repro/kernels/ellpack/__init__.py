from .ellpack import ellpack_pack
from .ops import pack_with_report
from .ref import ellpack_pack_reference
