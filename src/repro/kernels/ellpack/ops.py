"""Jit'd wrapper + storage accounting for the ELLPACK packer."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.sparsity import metadata_bits
from .ellpack import ellpack_pack


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_with_report(w: jnp.ndarray, *, m: int, keep: int = 0,
                     interpret: bool | None = None):
    """Returns (vals, idx, report) — report mirrors SPARSE_REPORT.csv."""
    interpret = _default_interpret() if interpret is None else interpret
    keep = keep or max(1, m // 2)
    vals, idx = ellpack_pack(w, m=m, keep=keep, interpret=interpret)
    nnz = int(jnp.sum(idx >= 0))
    wb = jnp.dtype(w.dtype).itemsize
    report = dict(
        representation="ellpack_block",
        original_bytes=float(w.size * wb),
        values_bytes=float(nnz * wb),
        metadata_bytes=float(nnz * metadata_bits(m) / 8.0),
    )
    report["total_bytes"] = report["values_bytes"] + report["metadata_bytes"]
    return vals, idx, report
