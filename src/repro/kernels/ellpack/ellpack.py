"""Pallas kernel: blocked-ELLPACK packing (paper Fig. 6).

Compresses an N:M-sparse weight matrix into (values, intra-block indices):
for every block of `m` consecutive elements in a row, the <= keep nonzeros
are moved to the front with their log2(m)-bit positions. Sort-free
formulation (TPU has no in-kernel sort): the j-th output slot selects the
element whose nonzero-rank (exclusive cumsum of the nonzero mask) equals j —
a one-hot contraction over the block, pure VPU work.

Grid tiles the row axis; each block holds (rows_blk, K) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(w_ref, vals_ref, idx_ref, *, m: int, keep: int):
    w = w_ref[...]                                 # (rb, K)
    rb, K = w.shape
    blocks = K // m
    wb = w.reshape(rb, blocks, m)
    nz = wb != 0
    rank = jnp.cumsum(nz.astype(jnp.int32), axis=-1) - nz.astype(jnp.int32)
    j = jax.lax.broadcasted_iota(jnp.int32, (rb, blocks, m, keep), 3)
    sel = (rank[..., None] == j) & nz[..., None]   # (rb, blocks, m, keep)
    vals_ref[...] = jnp.einsum("rbmk,rbm->rbk", sel.astype(w.dtype), wb)
    pos = jax.lax.broadcasted_iota(jnp.int32, (rb, blocks, m, keep), 2)
    idx = jnp.sum(jnp.where(sel, pos, 0), axis=2)
    idx_ref[...] = jnp.where(jnp.any(sel, axis=2), idx, -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m", "keep", "rows_blk",
                                             "interpret"))
def ellpack_pack(w: jnp.ndarray, *, m: int, keep: int = 0,
                 rows_blk: int = 64, interpret: bool = False):
    """w: (rows, K), K % m == 0 -> (vals (rows, K//m, keep),
    idx (rows, K//m, keep) with -1 padding). keep defaults to m // 2
    (the paper's N <= M/2 constraint)."""
    rows, K = w.shape
    assert K % m == 0, (K, m)
    keep = keep or max(1, m // 2)
    rows_blk = min(rows_blk, rows)
    blocks = K // m
    grid = (pl.cdiv(rows, rows_blk),)
    return pl.pallas_call(
        functools.partial(_pack_kernel, m=m, keep=keep),
        grid=grid,
        in_specs=[pl.BlockSpec((rows_blk, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows_blk, blocks, keep), lambda i: (i, 0, 0)),
                   pl.BlockSpec((rows_blk, blocks, keep), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, blocks, keep), w.dtype),
                   jax.ShapeDtypeStruct((rows, blocks, keep), jnp.int32)],
        interpret=interpret,
    )(w)
