"""Pure-jnp oracle: core.sparsity.pack_ellpack_block truncated/padded to the
kernel's fixed `keep` slots."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.sparsity import pack_ellpack_block


def ellpack_pack_reference(w: jnp.ndarray, *, m: int, keep: int = 0):
    keep = keep or max(1, m // 2)
    vals, idx, _ = pack_ellpack_block(w, m)
    cur = vals.shape[-1]
    if cur >= keep:
        return vals[..., :keep], idx[..., :keep]
    pad = ((0, 0), (0, 0), (0, keep - cur))
    return jnp.pad(vals, pad), jnp.pad(idx, pad, constant_values=-1)
