from .ops import FoldSim, batched_fold_activity, simulate_fold
from .ref import (systolic_ws_reference, total_cycles_ws,
                  wavefront_activity_reference)
from .systolic import systolic_matmul, wavefront_activity
