"""Jit'd public wrappers for the systolic tile simulator kernels.

`simulate_fold` is what core/engine + benchmarks call: one weight-stationary
fold -> (functional output, per-cycle active-PE counts incl. the R-cycle
weight preload, total cycles, utilization). Matches
core.dataflow.compute_cycles (= 2R + C + T - 2) by construction and the
ref.py scan oracle elementwise.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .systolic import systolic_matmul, wavefront_activity


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


class FoldSim(NamedTuple):
    out: jnp.ndarray            # (T, C) functional result
    active: jnp.ndarray         # (2R + C + T - 2,) active PEs per cycle
    cycles: int
    utilization: jnp.ndarray    # scalar in [0, 1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def simulate_fold(x: jnp.ndarray, w: jnp.ndarray, *,
                  interpret: bool | None = None) -> FoldSim:
    """Simulate one WS fold: x (T, R) streamed, w (R, C) stationary."""
    interpret = _default_interpret() if interpret is None else interpret
    T, R = x.shape
    C = w.shape[1]
    out = systolic_matmul(x, w, interpret=interpret)
    wave = wavefront_activity(jnp.int32(T), R=R, C=C,
                              n_cycles=T + R + C - 2, interpret=interpret)
    preload = jnp.full((R,), C, jnp.int32)     # weight rows shifting in
    active = jnp.concatenate([preload, wave])
    cycles = 2 * R + C + T - 2
    util = jnp.sum(active) / (R * C * cycles)
    return FoldSim(out, active, cycles, util)


@functools.partial(jax.jit, static_argnames=("R", "C", "n_cycles", "interpret"))
def batched_fold_activity(Ts: jnp.ndarray, *, R: int, C: int,
                          n_cycles: int, interpret: bool | None = None):
    """vmap'd wavefront activity for a batch of folds with varying T —
    the DSE fast path (one compile, thousands of folds)."""
    interpret = _default_interpret() if interpret is None else interpret
    fn = functools.partial(wavefront_activity, R=R, C=C, n_cycles=n_cycles,
                           interpret=interpret)
    return jax.vmap(fn)(Ts.astype(jnp.int32))
