"""Pallas TPU kernels for cycle-accurate systolic-array tile simulation.

The paper's inner loop — simulating an R x C weight-stationary systolic array
executing one GEMM fold — is split into its two physical components, both as
Pallas kernels with explicit VMEM BlockSpecs:

  1. `matmul_kernel`: the *functional* result the PE grid produces
     (O = X @ W). On TPU this IS the hardware being simulated, so it runs
     on the MXU with 128-aligned blocks.
  2. `wavefront_kernel`: the *cycle model* — active-PE counts per cycle of
     the skewed wavefront. PE(r, c) fires for stream element t at cycle
     t + r + c, so active(n) = |{(t,r,c) : t+r+c = n}|, a separable
     clamp-sum evaluated in VREGs (no TPU analogue of the paper's per-PE
     Python event loop exists; this index algebra is the TPU-native form).

kernels/systolic/ref.py holds the pure-jnp oracle: an explicit per-cycle
`lax.scan` that shifts operands through PE registers exactly like the paper's
simulator, against which both kernels are validated elementwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_BLK_T = 128
DEF_BLK_C = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    # x: (T_blk, R), w: (R, C_blk) resident in VMEM; MXU matmul.
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_t", "blk_c", "interpret"))
def systolic_matmul(x: jnp.ndarray, w: jnp.ndarray, *, blk_t: int = DEF_BLK_T,
                    blk_c: int = DEF_BLK_C, interpret: bool = False):
    """O = X @ W with explicit (blk_t, R) x (R, blk_c) VMEM tiling.

    x: (T, R) streamed operand, w: (R, C) stationary operand.
    """
    T, R = x.shape
    R2, C = w.shape
    assert R == R2, (x.shape, w.shape)
    blk_t = min(blk_t, T)
    blk_c = min(blk_c, C)
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    grid = (pl.cdiv(T, blk_t), pl.cdiv(C, blk_c))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk_t, R), lambda i, j: (i, 0)),
                  pl.BlockSpec((R, blk_c), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((blk_t, blk_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, C), out_dtype),
        interpret=interpret,
    )(x, w)


def _wavefront_kernel(meta_ref, o_ref, *, blk_n: int, R: int, C: int):
    # meta: (1,) = [T]. Block i covers cycles [i*blk_n, (i+1)*blk_n).
    T = meta_ref[0]
    i = pl.program_id(0)
    n = i * blk_n + jax.lax.iota(jnp.int32, blk_n)          # global cycle ids
    r = jax.lax.broadcasted_iota(jnp.int32, (blk_n, R), 1)
    nn = n[:, None]
    # #{t in [0,T) : max(0, n-r-(C-1)) <= t <= min(T-1, n-r)}
    lo = jnp.maximum(0, nn - r - (C - 1))
    hi = jnp.minimum(T - 1, nn - r)
    o_ref[...] = jnp.sum(jnp.maximum(0, hi - lo + 1), axis=1)


@functools.partial(jax.jit, static_argnames=("R", "C", "n_cycles", "blk_n",
                                             "interpret"))
def wavefront_activity(T: jnp.ndarray, *, R: int, C: int, n_cycles: int,
                       blk_n: int = 256, interpret: bool = False):
    """Active-PE count per wavefront cycle (length n_cycles >= T+R+C-2).

    T is a traced scalar so one compiled kernel serves every stream length
    within a padded cycle budget.
    """
    blk_n = min(blk_n, n_cycles)
    meta = jnp.asarray([T], jnp.int32)
    grid = (pl.cdiv(n_cycles, blk_n),)
    return pl.pallas_call(
        functools.partial(_wavefront_kernel, blk_n=blk_n, R=R, C=C),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)
                  if False else pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_cycles,), jnp.int32),
        interpret=interpret,
    )(meta)
