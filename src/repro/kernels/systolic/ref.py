"""Pure-jnp cycle-accurate oracle for the weight-stationary systolic tile.

An explicit `lax.scan` over cycles moves data exactly like the paper's
per-PE event loop:

  - weights W[r, c] are stationary in PE(r, c);
  - stream element x[t, r] enters row r (column 0) at cycle t + r (input
    skew) and shifts one column right per cycle;
  - each PE multiplies its resident x by W and adds the psum arriving from
    the PE above; psums shift one row down per cycle;
  - output o[t, c] leaves the bottom of column c at cycle t + (R-1) + c.

Returns both the functional result (T, C) and the per-cycle active-PE count
of the wavefront phase (length T + R + C - 2), the oracle for
kernels/systolic/systolic.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def systolic_ws_reference(x: jnp.ndarray, w: jnp.ndarray
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    T, R = x.shape
    R2, C = w.shape
    assert R == R2
    n_cycles = T + R + C - 2
    acc_dtype = jnp.promote_types(jnp.promote_types(x.dtype, w.dtype),
                                  jnp.float32)
    wf = w.astype(acc_dtype)

    def cycle(carry, n):
        x_buf, v_buf, psum = carry
        # skewed injection at column 0: row r receives x[n - r, r]
        t_idx = n - jnp.arange(R)
        valid_in = (t_idx >= 0) & (t_idx < T)
        x_in = jnp.where(valid_in,
                         x[jnp.clip(t_idx, 0, T - 1), jnp.arange(R)], 0)
        # shift right one column
        x_buf = jnp.concatenate([x_in[:, None], x_buf[:, :-1]], axis=1)
        v_buf = jnp.concatenate([valid_in[:, None], v_buf[:, :-1]], axis=1)
        prod = x_buf.astype(acc_dtype) * wf * v_buf
        # psums shift down one row, accumulating this cycle's products
        psum = jnp.concatenate(
            [jnp.zeros((1, C), acc_dtype), psum[:-1, :]], axis=0) + prod
        bottom = psum[-1, :]                    # emerges next cycle boundary
        active = jnp.sum(v_buf)
        return (x_buf, v_buf, psum), (bottom, active)

    carry0 = (jnp.zeros((R, C), x.dtype), jnp.zeros((R, C), bool),
              jnp.zeros((R, C), acc_dtype))
    _, (bottoms, active) = jax.lax.scan(cycle, carry0,
                                        jnp.arange(n_cycles))
    # o[t, c] left the array at cycle t + (R-1) + c
    t = jnp.arange(T)[:, None]
    c = jnp.arange(C)[None, :]
    out = bottoms[t + (R - 1) + c, c]
    return out.astype(jnp.promote_types(x.dtype, w.dtype)), active


def wavefront_activity_reference(T: int, R: int, C: int) -> jnp.ndarray:
    """Closed-form oracle for active(n) = |{(t,r,c): t+r+c=n}| (numpy-style)."""
    n = jnp.arange(T + R + C - 2)[:, None]
    r = jnp.arange(R)[None, :]
    lo = jnp.maximum(0, n - r - (C - 1))
    hi = jnp.minimum(T - 1, n - r)
    return jnp.sum(jnp.maximum(0, hi - lo + 1), axis=1).astype(jnp.int32)


def total_cycles_ws(T: int, R: int, C: int) -> int:
    """Fold runtime incl. R preload cycles: 2R + C + T - 2 (paper Eq. 1)."""
    return 2 * R + C + T - 2
