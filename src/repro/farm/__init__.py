"""repro.farm — the Study run-farm: a persistent, multi-worker
simulation service with a fleet-shared dedup cache.

The Study layer compiles design-space experiments into batched kernel
groups; the farm makes that a *service* (the FireSim manager/run-farm
shape): N clients submit serialized `StudyPlan`s over a file-spool job
queue, a **broker** shards them across M **worker** processes with
per-study priorities, cancellation, lease-based re-delivery of a dead
worker's shards, and straggler detection — and every worker writes
through one content-hash dedup cache, so across all clients and all
studies no cell is ever computed twice fleet-wide.

    python -m repro.farm serve  --root farm &          # broker
    python -m repro.farm worker --root farm &          # any number
    python -m repro.farm submit studies.edp_array_size --root farm --wait

    # or in-process:
    from repro.farm import Broker, FarmClient, Worker
    sid = FarmClient(root).submit(studies.edp_array_size())
    ...
    res = FarmClient(root).result(sid)   # bit-identical to Study.run()

Transport is a lock-free file spool (atomic temp+rename writes, atomic
rename claims, at-least-once delivery) — no sockets, no daemons, works
anywhere a shared directory does. See DESIGN.md "The run-farm".
"""
from .broker import Broker
from .client import FarmClient
from .queue import FarmDirs, FileSpool, QueueItem
from .worker import Worker

__all__ = ["Broker", "FarmClient", "FarmDirs", "FileSpool", "QueueItem",
           "Worker"]
