"""Broker: the farm's manager process (FireSim manager / run-farm shape).

One scheduling pass (`step`) does, in order:

1. **ingest** — claim submitted jobs from the `jobs` spool, rebuild each
   study from its spec (`Study.from_spec`), compile the plan, split it
   into **cell-group shards** and enqueue them on the `shards` spool at
   the study's priority. Shard sizing reuses `repro.dist`'s elastic
   planner: the group's cell count is the "global batch" spread over the
   currently-alive worker fleet, capped at `max_shard_cells` per shard —
   so a fleet of M workers gets ≥ M concurrently-claimable slices of any
   non-trivial group, and the split re-plans as workers join or leave.
2. **collect** — fold worker-written shard results into each study's
   `status.json` (cells done, executed vs cache-hit counts, per-worker
   stats); a study whose every shard reported flips to `done`.
3. **cancel** — apply `control/<sid>.cancel` requests: pending shards
   are dropped from the spool, the status flips to `canceled` (claimed
   shards finish idempotently; their results are simply ignored).
4. **requeue** — move claimed shards whose lease expired back to
   pending (`FileSpool.requeue_stale`): a killed worker's shard is
   re-executed by the next free worker. At-least-once delivery is safe
   because cells are deterministic and the shared cache dedups re-runs.

Per-worker shard wall times feed a `StragglerDetector`
(median-of-means, see repro.dist.straggler); flagged workers are
surfaced in `metrics()` so an operator (or the CI smoke gate) can see a
sick host without grepping logs.

The broker holds no authoritative state: everything lives in the spool
and the per-study JSON files, so a restarted broker resumes where the
old one died (in-flight studies are re-discovered from `status.json`).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

from ..api.study import Study, StudyPlan
from ..dist import StragglerDetector, plan_elastic_remesh
from .queue import (JOBS_TOPIC, SHARDS_TOPIC, FarmDirs, FileSpool,
                    read_json, write_json_atomic)

__all__ = ["Broker"]

# states a study's status.json can be in
ACTIVE, DONE, CANCELED, ERROR = "running", "done", "canceled", "error"


class Broker:
    def __init__(self, root: str, *, lease_seconds: float = 120.0,
                 max_shard_cells: int = 8,
                 heartbeat_timeout: float = 30.0,
                 straggler: Optional[StragglerDetector] = None):
        self.dirs = FarmDirs(root)
        self.spool = FileSpool(root)
        self.lease_seconds = float(lease_seconds)
        self.max_shard_cells = int(max_shard_cells)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.straggler = straggler or StragglerDetector(threshold=3.0,
                                                        patience=2)
        self._t0 = time.time()
        self._status: Dict[str, dict] = {}       # sid -> status dict
        self._seen_shards: Dict[str, set] = {}   # sid -> collected shard ids
        self._worker_stats: Dict[str, dict] = {}
        self._worker_hosts: Dict[str, int] = {}  # wid -> straggler host int
        self._requeued_total = 0
        # a restarted broker re-adopts in-flight studies from disk
        for sid in self.dirs.study_ids():
            st = read_json(self.dirs.status_path(sid))
            if st and st.get("state") == ACTIVE:
                self._status[sid] = st
                self._seen_shards[sid] = set(st.get("shards_done", []))

    # ---- one scheduling pass -------------------------------------------------
    def step(self) -> Dict[str, object]:
        ingested = self._ingest_jobs()
        collected = self._collect_results()
        canceled = self._apply_cancels()
        requeued = self.spool.requeue_stale(SHARDS_TOPIC,
                                            self.lease_seconds)
        self._requeued_total += len(requeued)
        if requeued:
            # a lease-expired shard of an already-canceled study must not
            # come back from the dead
            self._drop_canceled_pending()
        return {"ingested": ingested, "collected": collected,
                "canceled": canceled, "requeued": len(requeued),
                "queue_depth": self.spool.depth(SHARDS_TOPIC)}

    def serve(self, *, poll: float = 0.5, stop_event=None,
              max_steps: Optional[int] = None,
              metrics_path: Optional[str] = None) -> None:
        """Run `step` in a loop (the `python -m repro.farm serve` body)."""
        steps = 0
        while True:
            self.step()
            if metrics_path:
                write_json_atomic(metrics_path, self.metrics())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                return
            if stop_event is not None and stop_event.wait(poll):
                return
            if stop_event is None:
                time.sleep(poll)

    # ---- 1. ingest -------------------------------------------------------------
    def _ingest_jobs(self) -> List[str]:
        out: List[str] = []
        while True:
            item = self.spool.claim(JOBS_TOPIC, "broker")
            if item is None:
                return out
            sid = str(item.payload.get("study_id", item.item_id))
            priority = int(item.payload.get("priority", 100))
            existing = read_json(self.dirs.status_path(sid))
            if existing is not None:
                # duplicate submission, or canceled before ingest: the
                # job is dropped, the existing status stands
                self.spool.ack(item)
                continue
            try:
                study = Study.from_spec(item.payload["spec"])
                plan = study.plan()
            except Exception as e:  # noqa: BLE001 — bad spec = study error
                self._write_status(sid, {
                    "study_id": sid, "state": ERROR, "priority": priority,
                    "error": f"{type(e).__name__}: {e}",
                    "ingested_at": time.time()})
                self.spool.ack(item)
                out.append(sid)
                continue
            # spec lands on disk BEFORE any shard is claimable: a worker
            # that can claim a shard can always rebuild the study
            write_json_atomic(self.dirs.spec_path(sid),
                              item.payload["spec"])
            shards = self._split(plan)
            for k, cells in enumerate(shards):
                self.spool.put(SHARDS_TOPIC,
                               {"study_id": sid, "shard": k,
                                "cells": [int(i) for i in cells]},
                               priority=priority)
            self._write_status(sid, {
                "study_id": sid, "state": ACTIVE, "priority": priority,
                "shards_total": len(shards),
                "cells_total": len(plan.cells),
                "shards_done": [], "cells_done": 0,
                "executed_cells": 0, "cache_hits": 0,
                "ingested_at": time.time()})
            self._seen_shards[sid] = set()
            self.spool.ack(item)
            out.append(sid)

    def _split(self, plan: StudyPlan) -> List[List[int]]:
        """Slice the plan into shards: whole-group slices sized by the
        elastic planner over the live worker fleet. A slice of a batched
        group still executes as one vmapped call on the worker; fallback
        (per-op) cells are chunked the same way."""
        n_workers = max(1, len(self.active_workers()))
        shards: List[List[int]] = []

        def slices(cells: List[int]) -> None:
            if not cells:
                return
            ep = plan_elastic_remesh(
                n_workers, global_batch=len(cells),
                max_per_device_batch=self.max_shard_cells)
            size = max(1, ep.per_device_batch)
            shards.extend(cells[i:i + size]
                          for i in range(0, len(cells), size))

        for grp in plan.groups:
            slices(list(grp.cells))
        slices(list(plan.fallback))
        return shards

    # ---- 2. collect -------------------------------------------------------------
    def _collect_results(self) -> int:
        new = 0
        for sid in [s for s, st in self._status.items()
                    if st.get("state") == ACTIVE]:
            rdir = self.dirs.results_dir(sid)
            if not os.path.isdir(rdir):
                continue
            status = self._status[sid]
            seen = self._seen_shards.setdefault(sid, set())
            changed = False
            for name in sorted(os.listdir(rdir)):
                if not (name.startswith("shard-")
                        and name.endswith(".json")):
                    continue
                payload = read_json(os.path.join(rdir, name))
                if payload is None:
                    continue                     # still being written
                shard = int(payload.get("shard", -1))
                if shard in seen:
                    continue
                seen.add(shard)
                changed = True
                new += 1
                wid = str(payload.get("worker", "?"))
                if "error" in payload:
                    status["state"] = ERROR
                    status["error"] = (f"shard {shard} on {wid}: "
                                       f"{payload['error']}")
                    continue
                status["cells_done"] += len(payload.get("cells", {}))
                status["executed_cells"] += int(
                    payload.get("executed_cells", 0))
                status["cache_hits"] += int(payload.get("cache_hits", 0))
                status["shards_done"] = sorted(seen)
                self._record_worker(wid, payload)
            if changed:
                if (status["state"] == ACTIVE
                        and len(seen) >= status["shards_total"]):
                    status["state"] = DONE
                    status["done_at"] = time.time()
                self._write_status(sid, status)
        return new

    def _record_worker(self, wid: str, payload: dict) -> None:
        s = self._worker_stats.setdefault(
            wid, {"shards_done": 0, "cells_done": 0, "executed_cells": 0,
                  "cache_hits": 0, "busy_seconds": 0.0})
        s["shards_done"] += 1
        s["cells_done"] += len(payload.get("cells", {}))
        s["executed_cells"] += int(payload.get("executed_cells", 0))
        s["cache_hits"] += int(payload.get("cache_hits", 0))
        s["busy_seconds"] += float(payload.get("seconds", 0.0))
        host = self._worker_hosts.setdefault(wid, len(self._worker_hosts))
        self.straggler.record(host, float(payload.get("seconds", 0.0)))

    # ---- 3. cancel -------------------------------------------------------------
    def _apply_cancels(self) -> List[str]:
        cdir = self.dirs.control_dir()
        if not os.path.isdir(cdir):
            return []
        out: List[str] = []
        for name in sorted(os.listdir(cdir)):
            if not name.endswith(".cancel"):
                continue
            sid = name[:-len(".cancel")]
            status = self._status.get(sid) or read_json(
                self.dirs.status_path(sid))
            if status is None:
                # canceled before ingest: park a canceled status so the
                # job is dropped when (if) it arrives
                status = {"study_id": sid, "state": CANCELED,
                          "canceled_at": time.time()}
            elif status.get("state") == ACTIVE:
                status["state"] = CANCELED
                status["canceled_at"] = time.time()
            self._write_status(sid, status)
            self.spool.drop_pending(
                SHARDS_TOPIC, lambda p, s=sid: p.get("study_id") == s)
            try:
                os.unlink(os.path.join(cdir, name))
            except OSError:
                pass
            out.append(sid)
        return out

    def _drop_canceled_pending(self) -> int:
        dead = {s for s, st in self._status.items()
                if st.get("state") in (CANCELED, ERROR)}
        if not dead:
            return 0
        return self.spool.drop_pending(
            SHARDS_TOPIC, lambda p: p.get("study_id") in dead)

    # ---- bookkeeping -------------------------------------------------------------
    def _write_status(self, sid: str, status: dict) -> None:
        self._status[sid] = status
        write_json_atomic(self.dirs.status_path(sid), status)

    def active_workers(self) -> List[str]:
        """Worker ids with a fresh heartbeat."""
        wdir = self.dirs.workers_dir()
        if not os.path.isdir(wdir):
            return []
        now = time.time()
        out = []
        for name in sorted(os.listdir(wdir)):
            if not name.endswith(".json"):
                continue
            hb = read_json(os.path.join(wdir, name))
            if hb and now - float(hb.get("time", 0)) < \
                    self.heartbeat_timeout:
                out.append(str(hb.get("worker", name[:-len(".json")])))
        return out

    def metrics(self) -> dict:
        """Fleet metrics: per-worker work done + cache hits, queue depth,
        straggler flags, study states — the CI smoke job's artifact."""
        host_to_wid = {h: w for w, h in self._worker_hosts.items()}
        workers = {}
        for wid, s in self._worker_stats.items():
            workers[wid] = dict(s)
        for wid in self.active_workers():
            workers.setdefault(wid, {})["alive"] = True
        return {
            "wall_seconds": time.time() - self._t0,
            "queue_depth": self.spool.depth(SHARDS_TOPIC),
            "claimed_shards": len(self.spool.claimed_items(SHARDS_TOPIC)),
            "requeued_shards": self._requeued_total,
            "workers": workers,
            "stragglers": [host_to_wid[h]
                           for h in self.straggler.stragglers()
                           if h in host_to_wid],
            "studies": {sid: st.get("state", "?")
                        for sid, st in self._status.items()},
        }
