"""Broker: the farm's manager process (FireSim manager / run-farm shape).

One scheduling pass (`step`) does, in order:

1. **ingest** — claim submitted jobs from the `jobs` spool, rebuild each
   study from its spec (`Study.from_spec`), compile the plan, split it
   into **cell-group shards** and enqueue them on the `shards` spool at
   the study's priority. Shard sizing reuses `repro.dist`'s elastic
   planner: the group's cell count is the "global batch" spread over the
   currently-alive worker fleet, capped at `max_shard_cells` per shard —
   so a fleet of M workers gets ≥ M concurrently-claimable slices of any
   non-trivial group, and the split re-plans as workers join or leave.
   An immutable `manifest.json` (shard -> cell indices) lands on disk
   before any shard is claimable — it is the recovery root for every
   failure path below.
2. **collect** — fold worker-written shard results into each study's
   `status.json` (cells done/failed, executed vs cache-hit counts,
   per-worker stats); a study whose every shard reported flips to
   `done`. Unreadable result files are tolerated for `result_patience`
   passes (a mid-write race), then deleted so the reconcile pass
   re-enqueues the shard. A worker-reported shard *error* is re-enqueued
   (bounded by the attempts budget), not allowed to poison the study.
3. **cancel** — apply `control/<sid>.cancel` requests: pending shards
   are dropped from the spool, the status flips to `canceled` (claimed
   shards finish idempotently; their results are simply ignored).
4. **requeue** — move claimed shards whose lease expired back to
   pending, **budgeted**: every requeue/re-enqueue/error counts against
   the shard's attempts; a shard that exceeds `max_shard_attempts` is
   *quarantined* — the broker writes a shard result marking its cells
   failed (they surface as `cell_status == 1` frame rows), so a poison
   shard degrades to failed cells instead of an infinite requeue loop.

Per-worker shard wall times feed a `StragglerDetector`
(median-of-means, see repro.dist.straggler); flagged workers are
surfaced in `metrics()` so an operator (or the CI smoke gate) can see a
sick host without grepping logs.

The broker holds no authoritative state: everything lives in the spool
and the per-study JSON files, so a restarted broker resumes where the
old one died — in-flight studies are re-discovered from `status.json`,
and a *corrupt or missing* status is rebuilt from `manifest.json` by
re-folding the shard results on disk.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

from ..api.study import Study, StudyPlan
from ..dist import StragglerDetector, plan_elastic_remesh
from .queue import (JOBS_TOPIC, SHARDS_TOPIC, FarmDirs, FileSpool,
                    read_json, write_json_atomic)

__all__ = ["Broker"]

# states a study's status.json can be in
ACTIVE, DONE, CANCELED, ERROR = "running", "done", "canceled", "error"


class Broker:
    def __init__(self, root: str, *, lease_seconds: float = 120.0,
                 max_shard_cells: int = 8,
                 heartbeat_timeout: float = 30.0,
                 max_shard_attempts: int = 5,
                 result_patience: int = 3,
                 straggler: Optional[StragglerDetector] = None):
        self.dirs = FarmDirs(root)
        self.spool = FileSpool(root)
        self.lease_seconds = float(lease_seconds)
        self.max_shard_cells = int(max_shard_cells)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.max_shard_attempts = int(max_shard_attempts)
        self.result_patience = int(result_patience)
        self.straggler = straggler or StragglerDetector(threshold=3.0,
                                                        patience=2)
        self._t0 = time.time()
        self._status: Dict[str, dict] = {}       # sid -> status dict
        self._seen_shards: Dict[str, set] = {}   # sid -> collected shard ids
        self._shards: Dict[str, List[List[int]]] = {}  # manifest cache
        self._bad_results: Dict[tuple, int] = {}  # (sid, file) -> passes
        self._worker_stats: Dict[str, dict] = {}
        self._worker_hosts: Dict[str, int] = {}  # wid -> straggler host int
        self._requeued_total = 0
        self._quarantined_total = 0
        # a restarted broker re-adopts in-flight studies from disk; a
        # corrupt/missing status.json with an intact manifest is rebuilt
        # (the shard results on disk re-fold on the next collect pass)
        for sid in self.dirs.study_ids():
            st = read_json(self.dirs.status_path(sid))
            if isinstance(st, dict) and st.get("state") == ACTIVE:
                self._status[sid] = st
                self._seen_shards[sid] = set(st.get("shards_done", []))
            elif not isinstance(st, dict):
                recovered = self._recover_status(sid)
                if recovered is not None:
                    self._write_status(sid, recovered)
                    self._seen_shards[sid] = set()

    # ---- one scheduling pass -------------------------------------------------
    def step(self) -> Dict[str, object]:
        ingested = self._ingest_jobs()
        collected = self._collect_results()
        canceled = self._apply_cancels()
        self._repair_statuses()
        # a broker that died mid-ingest leaves its job claim leased;
        # the successor (or a later pass) re-delivers it
        self.spool.requeue_stale(JOBS_TOPIC, self.lease_seconds)
        requeued = self._requeue_stale_budgeted()
        self._requeued_total += requeued
        if requeued:
            # a lease-expired shard of an already-canceled study must not
            # come back from the dead
            self._drop_canceled_pending()
        return {"ingested": ingested, "collected": collected,
                "canceled": canceled, "requeued": requeued,
                "queue_depth": self.spool.depth(SHARDS_TOPIC)}

    def serve(self, *, poll: float = 0.5, stop_event=None,
              max_steps: Optional[int] = None,
              metrics_path: Optional[str] = None) -> None:
        """Run `step` in a loop (the `python -m repro.farm serve` body)."""
        steps = 0
        while True:
            self.step()
            if metrics_path:
                write_json_atomic(metrics_path, self.metrics())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                return
            if stop_event is not None and stop_event.wait(poll):
                return
            if stop_event is None:
                time.sleep(poll)

    # ---- 1. ingest -------------------------------------------------------------
    def _ingest_jobs(self) -> List[str]:
        out: List[str] = []
        while True:
            item = self.spool.claim(JOBS_TOPIC, "broker")
            if item is None:
                return out
            sid = str(item.payload.get("study_id", item.item_id))
            priority = int(item.payload.get("priority", 100))
            existing = read_json(self.dirs.status_path(sid))
            if existing is not None:
                # duplicate submission, or canceled before ingest: the
                # job is dropped, the existing status stands
                self.spool.ack(item)
                continue
            try:
                study = Study.from_spec(item.payload["spec"])
                plan = study.plan()
            except Exception as e:  # noqa: BLE001 — bad spec = study error
                self._write_status(sid, {
                    "study_id": sid, "state": ERROR, "priority": priority,
                    "error": f"{type(e).__name__}: {e}",
                    "ingested_at": time.time()})
                self.spool.ack(item)
                out.append(sid)
                continue
            # a predecessor broker that died mid-ingest left a manifest:
            # reuse ITS split (re-enqueued duplicates execute to
            # identical bytes and fold once), never re-split — two
            # different splits under one study id would collide
            shards = self._manifest_shards(sid)
            if shards is None:
                # spec lands on disk BEFORE any shard is claimable: a
                # worker that can claim a shard can always rebuild the
                # study; the manifest lands before the shards for the
                # same reason (recovery needs it)
                write_json_atomic(self.dirs.spec_path(sid),
                                  item.payload["spec"], site="broker.spec")
                shards = self._split(plan)
                write_json_atomic(
                    self.dirs.manifest_path(sid),
                    {"study_id": sid, "priority": priority,
                     "cells_total": len(plan.cells),
                     "shards": [[int(i) for i in cells]
                                for cells in shards]},
                    site="broker.manifest")
                self._shards[sid] = [list(c) for c in shards]
            for k, cells in enumerate(shards):
                self.spool.put(SHARDS_TOPIC,
                               {"study_id": sid, "shard": k,
                                "cells": [int(i) for i in cells]},
                               priority=priority)
            self._write_status(sid, {
                "study_id": sid, "state": ACTIVE, "priority": priority,
                "shards_total": len(shards),
                "cells_total": len(plan.cells),
                "shards_done": [], "cells_done": 0, "cells_failed": 0,
                "executed_cells": 0, "cache_hits": 0,
                "attempts": {},
                "ingested_at": time.time()})
            self._seen_shards[sid] = set()
            self.spool.ack(item)
            out.append(sid)

    def _split(self, plan: StudyPlan) -> List[List[int]]:
        """Slice the plan into shards: whole-group slices sized by the
        elastic planner over the live worker fleet. A slice of a batched
        group still executes as one vmapped call on the worker; fallback
        (per-op) cells are chunked the same way."""
        n_workers = max(1, len(self.active_workers()))
        shards: List[List[int]] = []

        def slices(cells: List[int]) -> None:
            if not cells:
                return
            ep = plan_elastic_remesh(
                n_workers, global_batch=len(cells),
                max_per_device_batch=self.max_shard_cells)
            size = max(1, ep.per_device_batch)
            shards.extend(cells[i:i + size]
                          for i in range(0, len(cells), size))

        for grp in plan.groups:
            slices(list(grp.cells))
        slices(list(plan.fallback))
        return shards

    # ---- recovery helpers -------------------------------------------------------
    def _manifest_shards(self, sid: str) -> Optional[List[List[int]]]:
        """The ingest-time shard -> cells split, from cache or disk."""
        if sid in self._shards:
            return self._shards[sid]
        m = read_json(self.dirs.manifest_path(sid))
        if isinstance(m, dict) and isinstance(m.get("shards"), list):
            self._shards[sid] = [[int(i) for i in cells]
                                 for cells in m["shards"]]
            return self._shards[sid]
        return None

    def _recover_status(self, sid: str) -> Optional[dict]:
        """Rebuild a corrupt/missing status.json from the manifest.
        Counts restart at zero; the next collect pass re-folds every
        shard result on disk, so a recovered study converges to the
        same terminal state it was heading for."""
        shards = self._manifest_shards(sid)
        if shards is None:
            return None
        m = read_json(self.dirs.manifest_path(sid), {})
        return {"study_id": sid, "state": ACTIVE,
                "priority": int(m.get("priority", 100)),
                "shards_total": len(shards),
                "cells_total": int(m.get("cells_total",
                                         sum(len(c) for c in shards))),
                "shards_done": [], "cells_done": 0, "cells_failed": 0,
                "executed_cells": 0, "cache_hits": 0,
                "attempts": {}, "recovered_at": time.time()}

    def _bump_attempts(self, status: dict, shard: int) -> int:
        att = status.setdefault("attempts", {})
        key = str(int(shard))
        att[key] = int(att.get(key, 0)) + 1
        return att[key]

    def _quarantine(self, sid: str, shard: int, status: dict, *,
                    reason: str) -> None:
        """Fail a shard permanently: write a quarantine result marking
        its manifest cells failed. The normal collect pass folds it —
        the study completes with `cell_status == 1` rows instead of
        looping on a poison shard forever."""
        shards = self._manifest_shards(sid) or []
        cells = shards[shard] if 0 <= shard < len(shards) else []
        write_json_atomic(
            self.dirs.shard_result_path(sid, shard),
            {"study_id": sid, "shard": int(shard), "worker": "broker",
             "quarantined": True, "reason": reason,
             "failed_cells": [int(i) for i in cells]},
            site="broker.quarantine")
        self._quarantined_total += 1

    def _reconcile(self, sid: str, status: dict) -> int:
        """Re-enqueue shards that vanished: not folded, no result file,
        and (the caller guarantees) nothing pending or claimed in the
        spool — e.g. a result file deleted after `result_patience`
        unreadable passes, or a shard lost to a broker crash between
        manifest write and enqueue. Bounded by the attempts budget."""
        shards = self._manifest_shards(sid)
        if shards is None:
            return 0
        seen = self._seen_shards.get(sid, set())
        n = 0
        for k in range(len(shards)):
            if k in seen:
                continue
            if os.path.exists(self.dirs.shard_result_path(sid, k)):
                continue              # written (or under patience)
            attempts = self._bump_attempts(status, k)
            if attempts > self.max_shard_attempts:
                self._quarantine(sid, k, status,
                                 reason=f"lost {attempts}x")
            else:
                self.spool.put(SHARDS_TOPIC,
                               {"study_id": sid, "shard": k,
                                "cells": [int(i) for i in shards[k]]},
                               priority=int(status.get("priority", 100)))
            n += 1
        if n:
            self._write_status(sid, status)
        return n

    # ---- 2. collect -------------------------------------------------------------
    def _collect_results(self) -> int:
        new = 0
        spool_empty = None               # lazily computed, once per pass
        for sid in [s for s, st in self._status.items()
                    if st.get("state") == ACTIVE]:
            status = self._status[sid]
            seen = self._seen_shards.setdefault(sid, set())
            changed = False
            rdir = self.dirs.results_dir(sid)
            for name in (sorted(os.listdir(rdir))
                         if os.path.isdir(rdir) else []):
                if not (name.startswith("shard-")
                        and name.endswith(".json")):
                    continue
                path = os.path.join(rdir, name)
                payload = read_json(path)
                if not isinstance(payload, dict):
                    # mid-write — or torn for good. Tolerate it for
                    # `result_patience` passes, then delete so the
                    # reconcile pass re-enqueues the shard.
                    key = (sid, name)
                    self._bad_results[key] = \
                        self._bad_results.get(key, 0) + 1
                    if self._bad_results[key] > self.result_patience:
                        try:
                            os.unlink(path)
                        except OSError:
                            pass
                        del self._bad_results[key]
                    continue
                self._bad_results.pop((sid, name), None)
                shard = int(payload.get("shard", -1))
                if shard in seen:
                    continue
                wid = str(payload.get("worker", "?"))
                if payload.get("quarantined"):
                    seen.add(shard)
                    changed = True
                    new += 1
                    failed = payload.get("failed_cells", [])
                    status["cells_done"] += len(failed)
                    status["cells_failed"] = (
                        int(status.get("cells_failed", 0)) + len(failed))
                    status["shards_done"] = sorted(seen)
                    continue
                if "error" in payload:
                    # a worker exception is a failed ATTEMPT, not a
                    # poisoned study: re-enqueue within the budget,
                    # quarantine past it (legacy dirs without a
                    # manifest keep the old whole-study error)
                    shards = self._manifest_shards(sid)
                    if shards is None:
                        status["state"] = ERROR
                        status["error"] = (f"shard {shard} on {wid}: "
                                           f"{payload['error']}")
                        changed = True
                        continue
                    attempts = self._bump_attempts(status, shard)
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
                    if attempts > self.max_shard_attempts:
                        self._quarantine(
                            sid, shard, status,
                            reason=f"failed {attempts}x, last: "
                                   f"{payload['error']}")
                    elif 0 <= shard < len(shards):
                        self.spool.put(
                            SHARDS_TOPIC,
                            {"study_id": sid, "shard": shard,
                             "cells": [int(i) for i in shards[shard]]},
                            priority=int(status.get("priority", 100)))
                    changed = True
                    continue
                seen.add(shard)
                changed = True
                new += 1
                status["cells_done"] += len(payload.get("cells", {}))
                status["executed_cells"] += int(
                    payload.get("executed_cells", 0))
                status["cache_hits"] += int(payload.get("cache_hits", 0))
                status["shards_done"] = sorted(seen)
                self._record_worker(wid, payload)
            if (status["state"] == ACTIVE
                    and len(seen) < status.get("shards_total", 0)):
                # shards unaccounted for: if the whole spool is idle,
                # they are lost (deleted-after-patience, crashed mid-
                # enqueue) — re-enqueue them from the manifest
                if spool_empty is None:
                    spool_empty = (
                        self.spool.depth(SHARDS_TOPIC) == 0
                        and not self.spool.claimed_items(SHARDS_TOPIC))
                if spool_empty:
                    if self._reconcile(sid, status):
                        spool_empty = None       # queue refilled
            if changed:
                if (status["state"] == ACTIVE
                        and len(seen) >= status["shards_total"]):
                    status["state"] = DONE
                    status["done_at"] = time.time()
                self._write_status(sid, status)
        return new

    def _record_worker(self, wid: str, payload: dict) -> None:
        s = self._worker_stats.setdefault(
            wid, {"shards_done": 0, "cells_done": 0, "executed_cells": 0,
                  "cache_hits": 0, "busy_seconds": 0.0})
        s["shards_done"] += 1
        s["cells_done"] += len(payload.get("cells", {}))
        s["executed_cells"] += int(payload.get("executed_cells", 0))
        s["cache_hits"] += int(payload.get("cache_hits", 0))
        s["busy_seconds"] += float(payload.get("seconds", 0.0))
        host = self._worker_hosts.setdefault(wid, len(self._worker_hosts))
        self.straggler.record(host, float(payload.get("seconds", 0.0)))

    # ---- 3. cancel -------------------------------------------------------------
    def _apply_cancels(self) -> List[str]:
        cdir = self.dirs.control_dir()
        if not os.path.isdir(cdir):
            return []
        out: List[str] = []
        for name in sorted(os.listdir(cdir)):
            if not name.endswith(".cancel"):
                continue
            sid = name[:-len(".cancel")]
            status = self._status.get(sid) or read_json(
                self.dirs.status_path(sid))
            if not isinstance(status, dict):
                # canceled before ingest (or over a corrupt status):
                # park a canceled status so the job is dropped when
                # (if) it arrives
                status = {"study_id": sid, "state": CANCELED,
                          "canceled_at": time.time()}
            elif status.get("state") == ACTIVE:
                status["state"] = CANCELED
                status["canceled_at"] = time.time()
            self._write_status(sid, status)
            self.spool.drop_pending(
                SHARDS_TOPIC, lambda p, s=sid: p.get("study_id") == s)
            try:
                os.unlink(os.path.join(cdir, name))
            except OSError:
                pass
            out.append(sid)
        return out

    def _drop_canceled_pending(self) -> int:
        dead = {s for s, st in self._status.items()
                if st.get("state") in (CANCELED, ERROR)}
        if not dead:
            return 0
        return self.spool.drop_pending(
            SHARDS_TOPIC, lambda p: p.get("study_id") in dead)

    # ---- 4. budgeted requeue -----------------------------------------------------
    def _requeue_stale_budgeted(self) -> int:
        """Expired-lease shards go back to pending — each requeue is an
        attempt, and a shard past the budget is quarantined instead
        (the infinite-requeue-loop breaker for poison shards)."""
        requeued = 0
        touched: Dict[str, dict] = {}
        for item_id, _owner, _age, path in self.spool.stale_claims(
                SHARDS_TOPIC, self.lease_seconds):
            payload = read_json(path)
            if not isinstance(payload, dict):
                # unreadable claimed shard: drop the lease; reconcile
                # re-enqueues it from the manifest once the spool idles
                try:
                    os.unlink(path)
                except OSError:
                    pass
                continue
            sid = str(payload.get("study_id", "?"))
            shard = int(payload.get("shard", -1))
            status = self._status.get(sid)
            if status is None or status.get("state") != ACTIVE:
                try:
                    os.unlink(path)   # canceled/unknown: the lease dies
                except OSError:
                    pass
                continue
            attempts = self._bump_attempts(status, shard)
            touched[sid] = status
            if attempts > self.max_shard_attempts:
                self._quarantine(sid, shard, status,
                                 reason=f"lease expired {attempts}x")
                try:
                    os.unlink(path)
                except OSError:
                    pass
            elif self.spool.requeue(SHARDS_TOPIC, item_id, path):
                requeued += 1
        for sid, status in touched.items():
            self._write_status(sid, status)
        return requeued

    # ---- bookkeeping -------------------------------------------------------------
    def _repair_statuses(self) -> int:
        """Self-heal torn status files. The broker's in-memory copy is
        authoritative while it lives, and status is only written on
        change — so a torn write landing on a study's *terminal*
        transition would otherwise leave it unobservable to clients
        forever (the chaos torn-writes schedule catches exactly this)."""
        n = 0
        for sid, status in self._status.items():
            if not isinstance(read_json(self.dirs.status_path(sid)),
                              dict):
                self._write_status(sid, status)
                n += 1
        return n

    def _write_status(self, sid: str, status: dict) -> None:
        self._status[sid] = status
        write_json_atomic(self.dirs.status_path(sid), status,
                          site="broker.status")

    def active_workers(self) -> List[str]:
        """Worker ids with a fresh, *readable* heartbeat — a torn or
        garbage heartbeat file means dead worker, never a crash."""
        wdir = self.dirs.workers_dir()
        if not os.path.isdir(wdir):
            return []
        now = time.time()
        out = []
        for name in sorted(os.listdir(wdir)):
            if not name.endswith(".json"):
                continue
            hb = read_json(os.path.join(wdir, name))
            if not isinstance(hb, dict):
                continue
            try:
                t = float(hb.get("time", 0))
            except (TypeError, ValueError):
                continue
            if now - t < self.heartbeat_timeout:
                out.append(str(hb.get("worker", name[:-len(".json")])))
        return out

    def metrics(self) -> dict:
        """Fleet metrics: per-worker work done + cache hits, queue depth,
        straggler flags, study states — the CI smoke job's artifact."""
        host_to_wid = {h: w for w, h in self._worker_hosts.items()}
        workers = {}
        for wid, s in self._worker_stats.items():
            workers[wid] = dict(s)
        for wid in self.active_workers():
            workers.setdefault(wid, {})["alive"] = True
        return {
            "wall_seconds": time.time() - self._t0,
            "queue_depth": self.spool.depth(SHARDS_TOPIC),
            "claimed_shards": len(self.spool.claimed_items(SHARDS_TOPIC)),
            "requeued_shards": self._requeued_total,
            "quarantined_shards": self._quarantined_total,
            "workers": workers,
            "stragglers": [host_to_wid[h]
                           for h in self.straggler.stragglers()
                           if h in host_to_wid],
            "studies": {sid: st.get("state", "?")
                        for sid, st in self._status.items()},
        }
