"""Client: submit studies to the farm, stream results back.

`submit` serializes a `Study` to its spec (`Study.to_spec`) and drops it
on the `jobs` spool; the broker shards it, workers fill in cell metrics,
and the client reassembles frames straight from the worker-written shard
files — the broker is a scheduler, not a data plane, so result bytes
flow client <- worker with no middleman copy.

`stream` yields *partial* `StudyResult` frames as shards complete
(monotonically growing row counts, rows in plan order); `result` blocks
for the final frame, which is **bit-identical** to a local
`Study.run()` of the same plan: reassembly rebuilds the study from the
same spec, re-derives the same deterministic plan, and routes the
collected per-cell metrics through the exact `_frame` code path `run()`
uses. Registry-submitted studies (`get_study` / `studies.*`) keep their
machine-checkable claims across the round-trip.
"""
from __future__ import annotations

import os
import time
import uuid
from typing import Dict, Iterator, List, Optional, Tuple

from ..api.study import Study, StudyPlan, StudyResult
from .queue import (JOBS_TOPIC, FarmDirs, FileSpool, read_json,
                    write_json_atomic)

__all__ = ["FarmClient"]

_FINAL = ("done", "canceled", "error")


class FarmClient:
    def __init__(self, root: str):
        self.dirs = FarmDirs(root)
        self.spool = FileSpool(root)
        self._studies: Dict[str, Tuple[Study, StudyPlan]] = {}

    # ---- submission -----------------------------------------------------------
    def submit(self, study, *, priority: int = 100,
               study_id: Optional[str] = None) -> str:
        """Submit a `Study` (or an already-serialized spec dict).
        Lower `priority` values are scheduled first. Returns the study
        id used for status/stream/result/cancel."""
        spec = study.to_spec() if isinstance(study, Study) else dict(study)
        base = (spec["ref"]["study"] if spec.get("ref")
                else spec.get("name", "study"))
        sid = study_id or (f"{FileSpool._safe(base)}"
                           f"-{time.time_ns():x}-{uuid.uuid4().hex[:4]}")
        self.spool.put(JOBS_TOPIC,
                       {"study_id": sid, "spec": spec,
                        "priority": int(priority),
                        "submitted_at": time.time()},
                       priority=priority)
        return sid

    def cancel(self, study_id: str) -> None:
        """Request cancellation: pending shards are dropped on the
        broker's next pass; in-flight shards finish idempotently."""
        write_json_atomic(self.dirs.cancel_path(study_id),
                          {"requested_at": time.time()})

    # ---- status -----------------------------------------------------------------
    def status(self, study_id: str) -> dict:
        return read_json(self.dirs.status_path(study_id),
                         {"study_id": study_id, "state": "queued"})

    def list_studies(self) -> Dict[str, str]:
        return {sid: self.status(sid).get("state", "?")
                for sid in self.dirs.study_ids()}

    # ---- result collection --------------------------------------------------------
    def _study(self, study_id: str) -> Optional[Tuple[Study, StudyPlan]]:
        """The rebuilt study + plan (None until the broker ingested it)."""
        if study_id not in self._studies:
            spec = read_json(self.dirs.spec_path(study_id))
            if spec is None:
                return None
            study = Study.from_spec(spec)
            self._studies[study_id] = (study, study.plan())
        return self._studies[study_id]

    def _collect(self, study_id: str
                 ) -> Tuple[Dict[int, Dict[str, float]], int, int,
                            List[str]]:
        """Fold worker shard files into ({cell: metrics}, executed,
        hits, errors). Shard results are keyed by shard id, so a
        requeued shard that ran twice counts once."""
        rdir = self.dirs.results_dir(study_id)
        results: Dict[int, Dict[str, float]] = {}
        executed = hits = 0
        errors: List[str] = []
        if not os.path.isdir(rdir):
            return results, executed, hits, errors
        for name in sorted(os.listdir(rdir)):
            if not (name.startswith("shard-") and name.endswith(".json")):
                continue
            payload = read_json(os.path.join(rdir, name))
            if not isinstance(payload, dict):
                continue                      # mid-write; next poll sees it
            if payload.get("quarantined"):
                # broker gave up on this shard: its cells surface as
                # failed frame rows (cell_status == 1), not an exception
                for i in payload.get("failed_cells", []):
                    results[int(i)] = {"cell_status": 1.0}
                continue
            if "error" in payload:
                errors.append(f"shard {payload.get('shard')}: "
                              f"{payload['error']}")
                continue
            for i, m in payload.get("cells", {}).items():
                results[int(i)] = {k: float(v) for k, v in m.items()}
            executed += int(payload.get("executed_cells", 0))
            hits += int(payload.get("cache_hits", 0))
        return results, executed, hits, errors

    def partial_result(self, study_id: str) -> Optional[StudyResult]:
        """Frame over the cells completed so far (rows in plan order),
        or None before the broker has ingested the study."""
        built = self._study(study_id)
        if built is None:
            return None
        study, plan = built
        results, executed, hits, _ = self._collect(study_id)
        return study.assemble_frame(results, executed_cells=executed,
                                    cache_hits=hits, plan=plan,
                                    partial=True)

    def stream(self, study_id: str, *, poll: float = 0.2,
               timeout: float = 300.0) -> Iterator[StudyResult]:
        """Yield partial frames as their row count grows; the last yield
        is the complete frame. Raises on study error; a canceled study
        ends the stream after its final partial frame."""
        t0 = time.time()
        seen_rows = -1
        while True:
            state = self.status(study_id).get("state")
            frame = self.partial_result(study_id)
            if frame is not None and len(frame) > seen_rows:
                seen_rows = len(frame)
                yield frame
            if state == "error":
                raise RuntimeError(
                    f"study {study_id} failed: "
                    f"{self.status(study_id).get('error')}")
            if state in ("done", "canceled"):
                return
            if time.time() - t0 > timeout:
                raise TimeoutError(
                    f"study {study_id} still {state!r} after {timeout}s "
                    f"({seen_rows} rows streamed)")
            time.sleep(poll)

    def wait(self, study_id: str, *, poll: float = 0.1,
             timeout: float = 300.0) -> dict:
        """Block until the study reaches a final state; returns status."""
        t0 = time.time()
        while True:
            st = self.status(study_id)
            if st.get("state") in _FINAL:
                return st
            if time.time() - t0 > timeout:
                raise TimeoutError(f"study {study_id} still "
                                   f"{st.get('state')!r} after {timeout}s")
            time.sleep(poll)

    def result(self, study_id: str, *, poll: float = 0.1,
               timeout: float = 300.0) -> StudyResult:
        """Block for the final frame (bit-identical to a local
        `Study.run()` of the same plan). Raises RuntimeError on a failed
        or canceled study."""
        st = self.wait(study_id, poll=poll, timeout=timeout)
        if st.get("state") == "error":
            raise RuntimeError(f"study {study_id} failed: "
                               f"{st.get('error')}")
        if st.get("state") == "canceled":
            raise RuntimeError(f"study {study_id} was canceled")
        study, plan = self._study(study_id)
        results, executed, hits, errors = self._collect(study_id)
        if errors:
            raise RuntimeError(f"study {study_id} shard errors: "
                               + "; ".join(errors))
        return study.assemble_frame(results, executed_cells=executed,
                                    cache_hits=hits, plan=plan)
