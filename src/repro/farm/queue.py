"""File-spool job queue: the farm's lock-free, daemon-free transport.

Every message is one JSON file. The two primitives the whole farm rests
on are both single-syscall-atomic on POSIX:

  * **put** writes a private temp file, then `os.replace`s it into
    `pending/` — a consumer never observes a torn write;
  * **claim** `os.rename`s `pending/<item>` into `claimed/` — when N
    consumers race on one item, exactly one rename succeeds and the
    rest get `FileNotFoundError` and move on.

Delivery is **at-least-once**: a claimed item whose owner dies is moved
back to `pending/` once its lease expires (`requeue_stale`, driven by
the broker). Consumers must therefore be idempotent — farm workers are,
because simulation cells are deterministic and the shared dedup cache
absorbs re-execution.

Spool layout (per topic)::

    <root>/<topic>/tmp/       in-flight writes (never read)
    <root>/<topic>/pending/   claimable items, name-ordered
    <root>/<topic>/claimed/   leased items; claim time = file mtime

Item names are ``p{priority:04d}-{t_ns:020d}-{uid}`` so a plain sorted
directory listing *is* the schedule: lower priority value first, FIFO
within a priority class.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import uuid
from typing import Callable, Dict, List, Optional, Tuple

from ..faults import fs as _fs
from ..faults.retry import with_retries

__all__ = ["FarmDirs", "FileSpool", "JOBS_TOPIC", "QueueItem",
           "SHARDS_TOPIC", "read_json", "write_json_atomic"]

# the two spool topics: study submissions (client -> broker) and cell
# shards (broker -> workers)
JOBS_TOPIC = "jobs"
SHARDS_TOPIC = "shards"


def write_json_atomic(path: str, obj, *, site: str = "fs.write") -> None:
    """Temp-file + `os.replace` JSON write (readers see all or nothing),
    with bounded retries on transient `OSError`. `site` names the write
    for the fault-injection plane (`repro.faults`) — a no-op unless a
    `FaultPlan` is active."""
    _fs.atomic_write_json(path, obj, site=site)


def read_json(path: str, default=None):
    """Tolerant JSON read: missing/corrupt/in-flight files -> default."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return default


@dataclasses.dataclass(frozen=True)
class QueueItem:
    """A claimed message: ack it (delete) when the work is durable."""
    item_id: str
    payload: dict
    path: str                 # current location (claimed/ file)
    owner: str


class FileSpool:
    def __init__(self, root: str):
        self.root = os.path.abspath(root)

    # ---- layout -------------------------------------------------------------
    def _dirs(self, topic: str) -> Tuple[str, str, str]:
        base = os.path.join(self.root, topic)
        dirs = tuple(os.path.join(base, d)
                     for d in ("tmp", "pending", "claimed"))
        for d in dirs:
            os.makedirs(d, exist_ok=True)
        return dirs

    @staticmethod
    def _safe(name: str) -> str:
        return "".join(c if (c.isalnum() or c in "-.") else "-"
                       for c in str(name))

    # ---- producer -------------------------------------------------------------
    def put(self, topic: str, payload: dict, *, priority: int = 100) -> str:
        """Enqueue one message; lower `priority` values are claimed
        first (FIFO within a priority class). Returns the item id.

        Hardened against transient I/O and torn staging writes: the
        staging file must parse back to JSON before it is renamed into
        `pending/` (a torn write would otherwise become a poison
        message, silently dropped by `claim` — a lost shard), and the
        whole write retries with backoff on `OSError`."""
        if not 0 <= int(priority) <= 9999:
            raise ValueError("priority must be in [0, 9999]")
        tmp, pending, _ = self._dirs(topic)
        item_id = (f"p{int(priority):04d}-{time.time_ns():020d}"
                   f"-{uuid.uuid4().hex[:8]}")
        staging = os.path.join(tmp, item_id + ".json")
        text = json.dumps(payload)

        def _write() -> None:
            _fs.crash_point("spool.put")
            try:
                _fs.write_text(staging, text, site="spool.put")
                with open(staging) as f:   # torn-write read-back check
                    json.load(f)
            except ValueError as e:
                raise OSError(f"torn staging write for {item_id}: {e}") \
                    from e
            _fs.replace(staging, os.path.join(pending, item_id + ".json"),
                        site="spool.put")

        try:
            # 9 attempts: a put must outlast a worst-case burst of
            # transient errors AND torn stagings back to back (the
            # chaos torn-writes schedule injects up to 6 in a row)
            with_retries(_write, retries=8)
        finally:
            if os.path.exists(staging):
                os.unlink(staging)
        return item_id

    # ---- consumer -------------------------------------------------------------
    def claim(self, topic: str, owner: str) -> Optional[QueueItem]:
        """Atomically claim the schedulable head of the queue (or None).

        The rename into `claimed/` is the mutual exclusion: concurrent
        claimants racing on one item see exactly one winner. The claimed
        file's mtime is reset to *now* — it is the lease clock that
        `requeue_stale` reads.
        """
        _, pending, claimed = self._dirs(topic)
        owner = self._safe(owner)
        for name in sorted(os.listdir(pending)):
            if not name.endswith(".json"):
                continue
            item_id = name[:-len(".json")]
            dst = os.path.join(claimed, f"{item_id}__{owner}.json")
            try:
                os.rename(os.path.join(pending, name), dst)
            except OSError:
                continue              # another claimant won this item
            os.utime(dst)             # lease starts now, not at put()
            payload = read_json(dst)
            if not isinstance(payload, dict):
                # poison message (torn, or valid JSON of the wrong
                # shape): drop it, keep going — never crash a consumer
                try:
                    os.unlink(dst)
                except OSError:
                    pass
                continue
            return QueueItem(item_id=item_id, payload=payload, path=dst,
                             owner=owner)
        return None

    def ack(self, item: QueueItem) -> None:
        """Delete a claimed item — the work it described is durable.
        A lost race against `requeue_stale` (file already moved back to
        pending) is fine: at-least-once delivery, idempotent consumers."""
        try:
            os.unlink(item.path)
        except OSError:
            pass

    # ---- broker-side maintenance ----------------------------------------------
    def stale_claims(self, topic: str, lease_seconds: float
                     ) -> List[Tuple[str, str, float, str]]:
        """[(item_id, owner, age, path)] for claimed items whose lease
        expired. Ages are measured against the *fault clock*
        (`faults.fs.now`), so an injected skew turns every claim stale
        at once — the lease-storm schedule. Read-only: the broker
        decides per item whether to requeue or quarantine."""
        _, _, claimed = self._dirs(topic)
        now = _fs.now("clock")
        out: List[Tuple[str, str, float, str]] = []
        for name in sorted(os.listdir(claimed)):
            if not name.endswith(".json") or "__" not in name:
                continue
            src = os.path.join(claimed, name)
            try:
                age = now - os.path.getmtime(src)
            except OSError:
                continue              # owner acked while we listed
            if age < lease_seconds:
                continue
            item_id, owner = name[:-len(".json")].split("__", 1)
            out.append((item_id, owner, age, src))
        return out

    def requeue(self, topic: str, item_id: str, path: str) -> bool:
        """Move one claimed item back to pending/ (its owner is presumed
        dead). False if it was acked or re-claimed under us."""
        _, pending, _ = self._dirs(topic)
        try:
            os.rename(path, os.path.join(pending, item_id + ".json"))
            return True
        except OSError:
            return False

    def requeue_stale(self, topic: str, lease_seconds: float) -> List[str]:
        """Move every claimed item older than the lease back to
        pending/. Returns the requeued item ids. (The broker uses the
        budgeted per-item path via `stale_claims`; this convenience
        wrapper is the unbudgeted whole-topic sweep.)"""
        out: List[str] = []
        for item_id, _owner, _age, path in self.stale_claims(
                topic, lease_seconds):
            if self.requeue(topic, item_id, path):
                out.append(item_id)
        return out

    def drop_pending(self, topic: str,
                     pred: Callable[[dict], bool]) -> int:
        """Remove pending items whose payload satisfies `pred`
        (cancellation). Items claimed mid-scan are simply skipped."""
        _, pending, _ = self._dirs(topic)
        dropped = 0
        for name in sorted(os.listdir(pending)):
            if not name.endswith(".json"):
                continue
            path = os.path.join(pending, name)
            payload = read_json(path)
            if payload is None or not pred(payload):
                continue
            try:
                os.unlink(path)
                dropped += 1
            except OSError:
                pass
        return dropped

    # ---- introspection ----------------------------------------------------------
    def depth(self, topic: str) -> int:
        _, pending, _ = self._dirs(topic)
        return sum(1 for n in os.listdir(pending) if n.endswith(".json"))

    def pending_ids(self, topic: str) -> List[str]:
        _, pending, _ = self._dirs(topic)
        return sorted(n[:-len(".json")] for n in os.listdir(pending)
                      if n.endswith(".json"))

    def claimed_items(self, topic: str) -> List[Tuple[str, str, float]]:
        """[(item_id, owner, lease_age_seconds)] for leased items."""
        _, _, claimed = self._dirs(topic)
        now = time.time()        # introspection only: the real clock
        out = []
        for name in sorted(os.listdir(claimed)):
            if not name.endswith(".json") or "__" not in name:
                continue
            item_id, owner = name[:-len(".json")].split("__", 1)
            try:
                age = now - os.path.getmtime(os.path.join(claimed, name))
            except OSError:
                continue
            out.append((item_id, owner, age))
        return out

    def stats(self, topic: str) -> Dict[str, int]:
        return {"pending": self.depth(topic),
                "claimed": len(self.claimed_items(topic))}


class FarmDirs:
    """The farm root's on-disk layout, shared by broker/worker/client.

    Everything outside the two spool topics is plain last-write-wins
    state written with `write_json_atomic`::

        <root>/studies/<sid>/spec.json     the submitted study spec
        <root>/studies/<sid>/manifest.json immutable shard->cells map
        <root>/studies/<sid>/status.json   broker-owned progress/state
        <root>/results/<sid>/shard-*.json  worker-written shard results
        <root>/control/<sid>.cancel        client cancellation requests
        <root>/workers/<wid>.json          worker heartbeats
        <root>/cache/                      fleet-shared dedup cell cache
                                           (Study._cache_* format)
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(root)

    def study_dir(self, study_id: str) -> str:
        return os.path.join(self.root, "studies", FileSpool._safe(study_id))

    def spec_path(self, study_id: str) -> str:
        return os.path.join(self.study_dir(study_id), "spec.json")

    def status_path(self, study_id: str) -> str:
        return os.path.join(self.study_dir(study_id), "status.json")

    def manifest_path(self, study_id: str) -> str:
        """Immutable ingest-time record (shard -> cell indices, totals,
        priority): written once before any shard is claimable, it is
        what lets a broker rebuild a corrupt/missing `status.json` by
        re-folding shard results, re-enqueue lost or unreadable shards,
        and quarantine a shard into its exact failed cells."""
        return os.path.join(self.study_dir(study_id), "manifest.json")

    def results_dir(self, study_id: str) -> str:
        return os.path.join(self.root, "results",
                            FileSpool._safe(study_id))

    def shard_result_path(self, study_id: str, shard: int) -> str:
        return os.path.join(self.results_dir(study_id),
                            f"shard-{int(shard):05d}.json")

    def control_dir(self) -> str:
        return os.path.join(self.root, "control")

    def cancel_path(self, study_id: str) -> str:
        return os.path.join(self.control_dir(),
                            FileSpool._safe(study_id) + ".cancel")

    def workers_dir(self) -> str:
        return os.path.join(self.root, "workers")

    def worker_path(self, worker_id: str) -> str:
        return os.path.join(self.workers_dir(),
                            FileSpool._safe(worker_id) + ".json")

    def cache_dir(self) -> str:
        return os.path.join(self.root, "cache")

    def study_ids(self) -> List[str]:
        base = os.path.join(self.root, "studies")
        if not os.path.isdir(base):
            return []
        return sorted(os.listdir(base))
