"""Worker: one simulation-executing process of the farm.

A worker owns a process (and optionally a device mesh over its local
JAX devices, shaped by `repro.dist.plan_elastic_remesh`) and loops:

    claim shard -> rebuild study (cached per study id) -> execute the
    shard's cells through `Study._execute_cells` -> write the shard
    result atomically -> ack the shard.

Execution reuses the exact machinery of a local `Study.run()` — the
jitted/vmapped `_sweep_batched` kernels for group shards and the per-op
engine for fallback cells — against the **fleet-shared dedup cache**
(`<root>/cache/`, same content-hash format as `Study.cache(...)`, so a
warm single-process cache carries straight over and no cell is computed
twice fleet-wide). Results are bit-identical to a local run regardless
of how the broker sliced the groups, because vmap maps designs
independently.

Crash safety: the shard result is written *before* the ack, so a worker
dying anywhere in the loop leaves either a claimable lease (broker
requeues it) or a durable result — never a lost shard. Re-execution
after a requeue race is harmless: cells are deterministic and results
are keyed by shard id (last atomic write wins, same bytes).

Heartbeats (`<root>/workers/<wid>.json`) tell the broker the live fleet
size, which feeds elastic shard sizing for subsequently-ingested
studies.
"""
from __future__ import annotations

import os
import time
import uuid
from typing import Dict, Optional, Tuple

from ..api.study import Study, StudyPlan
from ..dist import ElasticPlan, plan_elastic_remesh
from ..faults import fs as _fs
from .queue import SHARDS_TOPIC, FarmDirs, FileSpool, read_json, \
    write_json_atomic

__all__ = ["Worker"]


class Worker:
    def __init__(self, root: str, worker_id: Optional[str] = None, *,
                 cache: Optional[str] = "auto", use_mesh: bool = False):
        """cache: "auto" = the farm root's shared dedup cache; a path =
        use that directory; None = no caching (every cell executes —
        used by throughput benchmarks to measure cold cost)."""
        self.dirs = FarmDirs(root)
        self.spool = FileSpool(root)
        self.worker_id = worker_id or \
            f"w-{os.getpid()}-{uuid.uuid4().hex[:4]}"
        self.cache_dir = (self.dirs.cache_dir() if cache == "auto"
                          else cache)
        self.shards_done = 0
        self.cells_done = 0
        self.cache_hits = 0
        self._studies: Dict[str, Tuple[Study, StudyPlan]] = {}
        self._mesh = None
        self._mesh_plan: Optional[ElasticPlan] = None
        if use_mesh:
            self._build_mesh()

    def _build_mesh(self) -> None:
        """Shape a data mesh over this process's devices via the elastic
        planner (batched groups shard their design axis over it)."""
        import jax
        n = len(jax.devices())
        self._mesh_plan = plan_elastic_remesh(n, global_batch=n)
        self._mesh = jax.make_mesh((self._mesh_plan.dp,), ("data",))

    # ---- the work loop -------------------------------------------------------
    def step(self) -> bool:
        """Claim and execute at most one shard. Returns True if a shard
        was processed (work may remain), False if the queue was empty."""
        item = self.spool.claim(SHARDS_TOPIC, self.worker_id)
        if item is not None:
            # kill-point: died holding a fresh claim — the lease expires
            # and the broker re-delivers (a budgeted attempt)
            _fs.crash_point("worker.claimed")
        self._heartbeat(current=item.item_id if item else None)
        if item is None:
            return False
        p = item.payload
        sid = str(p.get("study_id", "?"))
        shard = int(p.get("shard", -1))
        t0 = time.perf_counter()
        try:
            study, plan = self._study(sid)
            results, executed, hits = study._execute_cells(
                plan, p["cells"], cache_dir=self.cache_dir,
                mesh=self._mesh)
            out = {"study_id": sid, "shard": shard,
                   "worker": self.worker_id,
                   "cells": {str(i): m for i, m in results.items()},
                   "executed_cells": executed, "cache_hits": hits,
                   "seconds": time.perf_counter() - t0,
                   "mesh": (list(self._mesh_plan.mesh_shape)
                            if self._mesh_plan else None)}
            self.cells_done += len(results)
            self.cache_hits += hits
        except Exception as e:  # noqa: BLE001 — report, don't poison-loop
            out = {"study_id": sid, "shard": shard,
                   "worker": self.worker_id,
                   "error": f"{type(e).__name__}: {e}",
                   "seconds": time.perf_counter() - t0}
        # result BEFORE ack: a crash in between re-delivers the shard,
        # and the duplicate result is byte-identical (deterministic cells)
        write_json_atomic(self.dirs.shard_result_path(sid, shard), out,
                          site="worker.result")
        # kill-point: result durable, shard still leased — the broker
        # requeues it and the re-executed duplicate folds once
        _fs.crash_point("worker.pre_ack")
        self.spool.ack(item)
        self.shards_done += 1
        self._heartbeat(current=None)
        return True

    def serve(self, *, poll: float = 0.2, stop_event=None,
              idle_exit: Optional[float] = None) -> None:
        """Loop `step` (the `python -m repro.farm worker` body).
        idle_exit: exit after this many seconds without claiming work
        (lets CI/bench fleets drain and terminate themselves)."""
        idle_since = time.time()
        while True:
            if self.step():
                idle_since = time.time()
                continue
            if idle_exit is not None and \
                    time.time() - idle_since > idle_exit:
                return
            if stop_event is not None:
                if stop_event.wait(poll):
                    return
            else:
                time.sleep(poll)

    # ---- internals -------------------------------------------------------------
    def _study(self, sid: str) -> Tuple[Study, StudyPlan]:
        """Rebuild (once per study id) the study + plan from the spec
        the broker parked on disk before enqueueing any shard."""
        if sid not in self._studies:
            spec = read_json(self.dirs.spec_path(sid))
            if spec is None:
                raise FileNotFoundError(
                    f"no spec on disk for study {sid!r}")
            study = Study.from_spec(spec)
            self._studies[sid] = (study, study.plan())
        return self._studies[sid]

    def _heartbeat(self, current: Optional[str]) -> None:
        """Advisory liveness ping. A failing heartbeat write (disk
        hiccup) must never kill a worker mid-shard — the broker treats
        a stale/unreadable heartbeat as dead-worker, which is exactly
        the degradation we want."""
        try:
            write_json_atomic(self.dirs.worker_path(self.worker_id), {
                "worker": self.worker_id, "time": time.time(),
                "pid": os.getpid(), "shards_done": self.shards_done,
                "cells_done": self.cells_done,
                "cache_hits": self.cache_hits,
                "current_shard": current,
                "mesh": (list(self._mesh_plan.mesh_shape)
                         if self._mesh_plan else None)},
                site="worker.heartbeat")
        except OSError:
            pass
