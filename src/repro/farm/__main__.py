"""`python -m repro.farm`: the run-farm CLI.

    # one broker, any number of workers, then submit studies:
    PYTHONPATH=src python -m repro.farm serve  --root farm &
    PYTHONPATH=src python -m repro.farm worker --root farm &
    PYTHONPATH=src python -m repro.farm submit studies.edp_array_size \
        --root farm --smoke --wait --csv FRAME.csv

    PYTHONPATH=src python -m repro.farm status --root farm [STUDY_ID]
    PYTHONPATH=src python -m repro.farm cancel --root farm STUDY_ID

    # self-contained end-to-end pass (CI): broker thread + N worker
    # subprocesses + one submission, gated on the study's claims
    PYTHONPATH=src python -m repro.farm smoke --root /tmp/farm \
        --workers 2 --study edp_array_size --smoke \
        --metrics FARM_metrics.json
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import subprocess
import sys
import threading
import time
from typing import Optional, Sequence

from .broker import Broker
from .client import FarmClient
from .queue import write_json_atomic
from .worker import Worker


def _study_kwargs(name: str, smoke: bool) -> dict:
    from ..api.study import _STUDIES
    factory = _STUDIES.get(name)
    kw = {}
    if smoke and factory is not None \
            and "smoke" in inspect.signature(factory).parameters:
        kw["smoke"] = True
    return kw


def _build_study(name: str, smoke: bool):
    from ..api.study import get_study
    name = name[len("studies."):] if name.startswith("studies.") else name
    return get_study(name, **_study_kwargs(name, smoke))


# ---- subcommands ------------------------------------------------------------

def _cmd_serve(args) -> int:
    broker = Broker(args.root, lease_seconds=args.lease,
                    max_shard_cells=args.max_shard_cells)
    print(f"farm broker serving root={broker.dirs.root} "
          f"(lease={args.lease}s, poll={args.poll}s)", flush=True)
    broker.serve(poll=args.poll,
                 max_steps=1 if args.once else None,
                 metrics_path=args.metrics)
    return 0


def _cmd_worker(args) -> int:
    worker = Worker(args.root, args.id, use_mesh=args.mesh,
                    cache=None if args.no_cache else "auto")
    print(f"farm worker {worker.worker_id} serving "
          f"root={worker.dirs.root}", flush=True)
    if args.once:
        worker.step()
    else:
        worker.serve(poll=args.poll, idle_exit=args.idle_exit)
    print(f"farm worker {worker.worker_id} exiting: "
          f"{worker.shards_done} shards, {worker.cells_done} cells "
          f"({worker.cache_hits} cache hits)", flush=True)
    return 0


def _cmd_submit(args) -> int:
    study = _build_study(args.study, args.smoke)
    client = FarmClient(args.root)
    sid = client.submit(study, priority=args.priority)
    print(f"submitted {sid} (priority {args.priority})")
    if not args.wait:
        return 0
    last = 0
    res = None
    for frame in client.stream(sid, timeout=args.timeout):
        if len(frame) > last:
            print(f"  {len(frame)} cells complete", flush=True)
            last = len(frame)
        res = frame
    st = client.status(sid)
    if st.get("state") != "done":
        print(f"study ended {st.get('state')!r}")
        return 1
    res = client.result(sid, timeout=args.timeout)
    print(f"study {sid}: done, executed {res.executed_cells} cells "
          f"({res.cache_hits} cache hits)")
    print(res.summary())
    if args.csv:
        res.to_csv(args.csv)
        print(f"wrote {args.csv}")
    claims = res.check_claims()
    for name, ok in claims.items():
        print(f"claim {'PASS' if ok else 'FAIL'}: {name}")
    return 0 if all(claims.values()) else 1


def _cmd_status(args) -> int:
    client = FarmClient(args.root)
    if args.study_id:
        print(json.dumps(client.status(args.study_id), indent=1))
    else:
        studies = client.list_studies()
        if not studies:
            print("no studies submitted")
        for sid, state in studies.items():
            print(f"{state:>9}  {sid}")
    return 0


def _cmd_cancel(args) -> int:
    FarmClient(args.root).cancel(args.study_id)
    print(f"cancel requested for {args.study_id}")
    return 0


def _cmd_smoke(args) -> int:
    """End-to-end farm pass: broker thread + N worker subprocesses,
    one named-study submission, claims gating the exit code, and the
    broker's per-worker metrics written as a JSON artifact."""
    root = args.root
    stop = threading.Event()
    broker = Broker(root, lease_seconds=args.lease,
                    max_shard_cells=args.max_shard_cells)
    thread = threading.Thread(
        target=broker.serve, kwargs=dict(poll=0.1, stop_event=stop),
        daemon=True)
    thread.start()
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.farm", "worker", "--root", root,
         "--id", f"smoke-w{i}", "--poll", "0.1",
         "--idle-exit", str(args.timeout)],
        env=dict(os.environ)) for i in range(args.workers)]
    rc = 1
    try:
        client = FarmClient(root)
        study = _build_study(args.study, args.smoke)
        t0 = time.time()
        sid = client.submit(study)
        print(f"smoke: submitted {sid} to {args.workers} workers")
        res = client.result(sid, timeout=args.timeout)
        dt = time.time() - t0
        claims = res.check_claims()
        print(f"smoke: {len(res)} cells in {dt:.1f}s "
              f"(executed {res.executed_cells}, "
              f"{res.cache_hits} cache hits)")
        for name, ok in claims.items():
            print(f"claim {'PASS' if ok else 'FAIL'}: {name}")
        rc = 0 if (claims and all(claims.values())) else 1
    finally:
        stop.set()
        thread.join(timeout=10)
        for p in procs:
            p.terminate()
        for p in procs:
            try:
                p.wait(timeout=15)
            except subprocess.TimeoutExpired:
                p.kill()
        metrics = broker.metrics()
        write_json_atomic(args.metrics, metrics)
        print(f"smoke: wrote {args.metrics} "
              f"(queue_depth={metrics['queue_depth']}, "
              f"requeued={metrics['requeued_shards']})")
    return rc


def _cmd_chaos(args) -> int:
    """CI chaos soak: run one study through the farm under each seeded
    fault schedule and require (a) termination, (b) a frame whose every
    column is bit-identical to a fault-free local `Study.run()`, and
    (c) the study's claims. One process, synchronous deterministic
    driver: the broker and an N-worker pool are stepped round-robin, an
    `InjectedCrash` kills a worker mid-protocol and a fresh one is
    spawned (exactly what a process kill + respawn does, minus the
    fork cost and flakiness). Real fleets get the same schedules via
    the REPRO_FAULTS env var (see repro.faults)."""
    import numpy as np

    from ..faults import CHAOS_SCHEDULES, InjectedCrash, chaos_schedule

    names = args.schedules or sorted(CHAOS_SCHEDULES)
    study = _build_study(args.study, args.smoke)
    print(f"chaos: fault-free reference run of {args.study}"
          f"{' --smoke' if args.smoke else ''}", flush=True)
    ref = study.run()

    report, ok_all = {}, True
    for name in names:
        plan = chaos_schedule(name, args.seed)
        root = os.path.join(args.root, name)
        t0 = time.time()
        kills = rounds = 0
        res = None
        with plan.active():
            # short lease so crashed claims re-deliver within the soak;
            # a raised attempts budget keeps bounded injection bursts
            # from quarantining healthy shards (quarantine semantics
            # have their own unit tests)
            broker = Broker(root, lease_seconds=0.2, max_shard_cells=2,
                            max_shard_attempts=8)
            client = FarmClient(root)
            workers = [Worker(root, f"chaos-w{i}")
                       for i in range(args.workers)]
            sid = client.submit(study)
            state = "running"
            while time.time() - t0 < args.timeout:
                rounds += 1
                broker.step()
                for i, w in enumerate(workers):
                    try:
                        while w.step():
                            pass
                    except InjectedCrash:
                        kills += 1           # respawn, like a supervisor
                        workers[i] = Worker(root, f"chaos-w{i}r{kills}")
                    except OSError:
                        pass                 # injected I/O at claim time
                state = client.status(sid).get("state")
                if state in ("done", "canceled", "error"):
                    break
                time.sleep(0.02)             # age the short leases
            broker.step()                    # final fold
            state = client.status(sid).get("state")
            if state == "done":
                res = client.result(sid, timeout=30)
        m = broker.metrics()
        bad_cols = ([] if res is None else
                    [c for c in ref.columns
                     if not np.array_equal(ref.columns[c],
                                           res.columns.get(
                                               c, np.array([])))])
        claims = res.check_claims() if res is not None else {}
        entry = {
            "ok": state == "done",
            "bit_identical": res is not None and res.equals(ref)
            and not bad_cols,
            "claims_ok": bool(claims) and all(claims.values()),
            "state": state, "seconds": round(time.time() - t0, 2),
            "rounds": rounds, "worker_kills": kills,
            "requeued_shards": m["requeued_shards"],
            "quarantined_shards": m["quarantined_shards"],
            "mismatched_columns": bad_cols,
            "faults": plan.report(),
        }
        report[name] = entry
        good = (entry["ok"] and entry["bit_identical"]
                and entry["claims_ok"])
        ok_all = ok_all and good
        print(f"chaos[{name}]: {'PASS' if good else 'FAIL'} "
              f"state={state} kills={kills} "
              f"requeued={entry['requeued_shards']} "
              f"injected={entry['faults']['total_injected']} "
              f"bit_identical={entry['bit_identical']} "
              f"({entry['seconds']}s)", flush=True)
    write_json_atomic(args.report, report)
    print(f"chaos: wrote {args.report}; "
          f"{'all schedules PASS' if ok_all else 'FAILURES above'}")
    return 0 if ok_all else 1


# ---- argument plumbing --------------------------------------------------------

def _main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.farm",
        description="Study run-farm: broker, workers, submissions")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--root", default=os.environ.get("FARM_ROOT",
                                                        "farm"),
                       help="farm root directory (spool + state + cache)")

    p = sub.add_parser("serve", help="run the broker")
    common(p)
    p.add_argument("--poll", type=float, default=0.5)
    p.add_argument("--lease", type=float, default=120.0,
                   help="seconds before a claimed shard is re-queued")
    p.add_argument("--max-shard-cells", type=int, default=8)
    p.add_argument("--once", action="store_true",
                   help="one scheduling pass, then exit")
    p.add_argument("--metrics", default=None,
                   help="write broker metrics JSON here every pass")
    p.set_defaults(fn=_cmd_serve)

    p = sub.add_parser("worker", help="run one worker")
    common(p)
    p.add_argument("--id", default=None, help="worker id (default: pid)")
    p.add_argument("--poll", type=float, default=0.2)
    p.add_argument("--idle-exit", type=float, default=None,
                   help="exit after this many idle seconds")
    p.add_argument("--mesh", action="store_true",
                   help="shard batched groups over the local device mesh")
    p.add_argument("--no-cache", action="store_true",
                   help="skip the shared dedup cache (bench cold runs)")
    p.add_argument("--once", action="store_true")
    p.set_defaults(fn=_cmd_worker)

    p = sub.add_parser("submit", help="submit a named study")
    common(p)
    p.add_argument("study",
                   help="registry study, e.g. studies.edp_array_size")
    p.add_argument("--smoke", action="store_true",
                   help="shrink the study where the factory supports it")
    p.add_argument("--priority", type=int, default=100,
                   help="lower = scheduled first")
    p.add_argument("--wait", action="store_true",
                   help="stream until done; exit code gates the claims")
    p.add_argument("--timeout", type=float, default=600.0)
    p.add_argument("--csv", help="write the final frame as CSV")
    p.set_defaults(fn=_cmd_submit)

    p = sub.add_parser("status", help="show study states")
    common(p)
    p.add_argument("study_id", nargs="?", default=None)
    p.set_defaults(fn=_cmd_status)

    p = sub.add_parser("cancel", help="cancel a study")
    common(p)
    p.add_argument("study_id")
    p.set_defaults(fn=_cmd_cancel)

    p = sub.add_parser("smoke",
                       help="self-contained broker+workers+submit pass")
    common(p)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--study", default="edp_array_size")
    p.add_argument("--smoke", action="store_true",
                   help="use the study factory's smoke variant")
    p.add_argument("--timeout", type=float, default=480.0)
    p.add_argument("--lease", type=float, default=120.0)
    p.add_argument("--max-shard-cells", type=int, default=2,
                   help="small shards so every worker sees work")
    p.add_argument("--metrics", default="FARM_metrics.json")
    p.set_defaults(fn=_cmd_smoke)

    p = sub.add_parser(
        "chaos",
        help="CI chaos soak: seeded fault schedules, bit-identity gated")
    common(p)
    p.add_argument("--study", default="edp_array_size")
    p.add_argument("--smoke", action="store_true",
                   help="use the study factory's smoke variant")
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--timeout", type=float, default=300.0,
                   help="per-schedule wall ceiling (seconds)")
    p.add_argument("--schedules", nargs="*", default=None,
                   help="subset of schedules (default: all three)")
    p.add_argument("--report", default="FAULTS_report.json")
    p.set_defaults(fn=_cmd_chaos)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(_main())
