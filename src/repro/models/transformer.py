"""Model assembly for all 10 assigned architectures.

Every family is built from the same pieces:
  - per-block ParamDef trees with leaves stacked over the layer axis,
    consumed by a remat'd lax.scan (one block body in HLO regardless of
    depth — 80-layer models compile as fast as 6-layer ones);
  - families with heterogeneous blocks (zamba2 hybrid, xlstm) scan over
    repeating *groups* (e.g. 5 mamba + 1 shared-attention) so each distinct
    block body appears once in the HLO;
  - decode threads a cache pytree through the same scans.

Layout: decoder-only (dense/moe/vlm), enc-dec (audio), hybrid, ssm.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..dist.sharding import MeshCtx
from .attention import attention, decode_attention
from .common import chunked_cross_entropy, rms_norm
from .config import ModelConfig
from .ffn import dense_ffn, moe_ffn
from .params import ParamDef
from .ssm import (mamba2_decode, mamba2_forward, mlstm_decode, mlstm_forward,
                  slstm_decode, slstm_forward)

PyTree = Any
CONV_K = 4


def _pd(shape, logical, **kw):
    return ParamDef(tuple(int(s) for s in shape), tuple(logical), **kw)


def _stack(defs: PyTree, n: int) -> PyTree:
    """Prepend a scanned layer axis (replicated) to every leaf."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.logical, d.init,
                           d.scale, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# --------------------------------------------------------------------------
# per-block ParamDefs
# --------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, dt: str) -> Dict[str, ParamDef]:
    d, H, KV, hd = cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim
    defs = {
        "wq": _pd((d, H, hd), ("fsdp", "tp", None), dtype=dt),
        "wk": _pd((d, KV, hd), ("fsdp", "tp", None), dtype=dt),
        "wv": _pd((d, KV, hd), ("fsdp", "tp", None), dtype=dt),
        "wo": _pd((H, hd, d), ("tp", None, "fsdp"), dtype=dt),
    }
    if cfg.qkv_bias:
        defs.update(bq=_pd((H, hd), ("tp", None), init="zeros", dtype=dt),
                    bk=_pd((KV, hd), ("tp", None), init="zeros", dtype=dt),
                    bv=_pd((KV, hd), ("tp", None), init="zeros", dtype=dt))
    return defs


def ffn_defs(cfg: ModelConfig, dt: str) -> Dict[str, ParamDef]:
    d, F = cfg.d_model, cfg.d_ff
    if cfg.num_experts > 1:
        return {
            "wr": _pd((d, cfg.num_experts), (None, None), dtype=dt),
            "w_up": _pd((cfg.num_experts, d, 2 * F), (None, "fsdp", "tp"),
                        dtype=dt),
            "w_down": _pd((cfg.num_experts, F, d), (None, "tp", "fsdp"),
                          dtype=dt),
        }
    return {"w_up": _pd((d, 2 * F), ("fsdp", "tp"), dtype=dt),
            "w_down": _pd((F, d), ("tp", "fsdp"), dtype=dt)}


def norm_defs(cfg, dt, names=("ln1", "ln2")):
    return {n: _pd((cfg.d_model,), (None,), init="ones", dtype=dt)
            for n in names}


def mamba_defs(cfg: ModelConfig, dt: str) -> Dict[str, ParamDef]:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "in_proj": _pd((d, 2 * di), ("fsdp", "tp"), dtype=dt),
        "bc_proj": _pd((d, 2 * N), ("fsdp", None), dtype=dt),
        "dt_proj": _pd((d, H), ("fsdp", None), dtype=dt),
        "dt_bias": _pd((H,), (None,), init="zeros", dtype="float32"),
        "A_log": _pd((H,), (None,), init="zeros", dtype="float32"),
        "D": _pd((H,), (None,), init="ones", dtype="float32"),
        "conv_w": _pd((CONV_K, di + 2 * N), (None, None), dtype=dt),
        "gate_norm": _pd((di,), (None,), init="ones", dtype=dt),
        "out_proj": _pd((di, d), ("tp", "fsdp"), dtype=dt),
        "ln": _pd((d,), (None,), init="ones", dtype=dt),
    }


def mlstm_defs(cfg: ModelConfig, dt: str) -> Dict[str, ParamDef]:
    d, di, H = cfg.d_model, cfg.d_inner, cfg.heads
    return {
        "up_proj": _pd((d, 2 * di), ("fsdp", "tp"), dtype=dt),
        "w_qkv": _pd((di, 3 * di), ("fsdp", "tp"), dtype=dt),
        "w_gates": _pd((di, 2 * H), ("fsdp", None), dtype=dt),
        "down_proj": _pd((di, d), ("tp", "fsdp"), dtype=dt),
        "ln": _pd((d,), (None,), init="ones", dtype=dt),
    }


def slstm_defs(cfg: ModelConfig, dt: str) -> Dict[str, ParamDef]:
    d = cfg.d_model
    return {
        "w_in": _pd((d, 4 * d), ("fsdp", "tp"), dtype=dt),
        "w_rec": _pd((d, 4 * d), ("fsdp", None), dtype=dt, scale=0.002),
        "w_out": _pd((d, d), ("fsdp", "tp"), dtype=dt),
        "ln": _pd((d,), (None,), init="ones", dtype=dt),
    }


def model_defs(cfg: ModelConfig) -> PyTree:
    dt = cfg.param_dtype
    d, Vp = cfg.d_model, cfg.vocab_padded
    defs: Dict[str, Any] = {
        "embed": _pd((Vp, d), ("tp", "fsdp"), scale=1.0, dtype=dt),
        "final_norm": _pd((d,), (None,), init="ones", dtype=dt),
        "unembed": _pd((d, Vp), ("fsdp", "tp"), dtype=dt),
    }
    block = lambda: {**attn_defs(cfg, dt), **ffn_defs(cfg, dt),
                     **norm_defs(cfg, dt)}
    if cfg.family in ("dense", "moe", "vlm"):
        defs["blocks"] = _stack(block(), cfg.layers)
    elif cfg.family == "audio":
        defs["enc_blocks"] = _stack(block(), cfg.encoder_layers)
        dec = {**block(),
               **{f"x_{k}": v for k, v in attn_defs(cfg, dt).items()},
               "ln3": _pd((d,), (None,), init="ones", dtype=dt)}
        defs["dec_blocks"] = _stack(dec, cfg.decoder_layers)
        defs["enc_norm"] = _pd((d,), (None,), init="ones", dtype=dt)
    elif cfg.family == "hybrid":
        g = cfg.attn_every
        groups = cfg.layers // g
        tail = cfg.layers - groups * g
        defs["mamba_groups"] = _stack(_stack(mamba_defs(cfg, dt), g - 1),
                                      groups)
        defs["mamba_tail"] = _stack(mamba_defs(cfg, dt), max(tail, 1))
        defs["shared_attn"] = block()              # one shared block
    elif cfg.family == "ssm":
        g = cfg.slstm_every or 8
        groups = cfg.layers // g
        defs["mlstm_groups"] = _stack(_stack(mlstm_defs(cfg, dt), g - 1),
                                      groups)
        defs["slstm_blocks"] = _stack(slstm_defs(cfg, dt), groups)
    else:
        raise ValueError(cfg.family)
    return defs


# --------------------------------------------------------------------------
# block forward functions
# --------------------------------------------------------------------------

def res_shard(x, ctx: Optional[MeshCtx]):
    """Sequence parallelism (Korthikanti et al.): the residual stream lives
    sharded along L over the model axis between blocks. The layer scan then
    saves (B, L/tp, d) per layer instead of (B, L, d) — 16x less activation
    memory. Sublayers gather explicitly (res_gather) at their input and
    scatter back at their output; forcing both boundaries keeps SPMD from
    replicating the projections."""
    if ctx is None or x.ndim != 3 or x.shape[1] % ctx.tp or x.shape[1] == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(P(ctx.dp_axes, "model", None)))


def melt_batch(x, ctx: Optional[MeshCtx]):
    """For blocks whose inner structure cannot TP-shard (mLSTM/sLSTM with
    heads < tp): spread the batch over BOTH mesh axes so the model axis
    does useful work instead of replicating compute 16x. Requires
    B %% (dp*tp) == 0 (train_4k: 256 = 16x16)."""
    if ctx is None or x.ndim != 3 or x.shape[0] % (ctx.dp * ctx.tp):
        return None
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(P((*ctx.dp_axes, "model"), None, None)))


def res_gather(x, ctx: Optional[MeshCtx], sp_mode: str = "megatron"):
    """all-gather the L-sharded residual for a TP sublayer's matmuls
    (megatron mode); weightgather mode keeps it L-sharded and lets the
    layer's weights gather instead (2D FSDP)."""
    if ctx is None or x.ndim != 3 or x.shape[1] % ctx.tp or x.shape[1] == 1:
        return x
    if sp_mode == "weightgather":
        return res_shard(x, ctx)
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(P(ctx.dp_axes, None, None)))


def _ffn_apply(pl, x, cfg, ctx):
    if cfg.num_experts > 1:
        return moe_ffn(pl, x, cfg=cfg, ctx=ctx)
    return dense_ffn(pl, x, ctx, cfg.sp_mode)


def transformer_block(pl, x, *, cfg, ctx, causal=True, cross=None,
                      positions=None):
    h, _ = attention(pl, res_gather(rms_norm(x, pl["ln1"], cfg.norm_eps),
                                    ctx, cfg.sp_mode), cfg=cfg,
                     ctx=ctx, causal=causal, positions=positions)
    x = x + res_shard(h, ctx)
    if cross is not None:
        xp = {k[2:]: v for k, v in pl.items() if k.startswith("x_")}
        h, _ = attention(xp, res_gather(rms_norm(x, pl["ln3"], cfg.norm_eps),
                                        ctx, cfg.sp_mode), cfg=cfg,
                         ctx=ctx, causal=False, kv_x=cross, use_rope=False)
        x = x + res_shard(h, ctx)
    h = _ffn_apply(pl, res_gather(rms_norm(x, pl["ln2"], cfg.norm_eps), ctx,
                                  cfg.sp_mode),
                   cfg, ctx)
    return x + res_shard(h, ctx)


def transformer_block_decode(pl, x, cache_l, cache_len, *, cfg, ctx,
                             cross=None):
    h, kv = decode_attention(pl, rms_norm(x, pl["ln1"], cfg.norm_eps),
                             cache_l["k"], cache_l["v"], cache_len,
                             cfg=cfg, ctx=ctx)
    x = x + h
    new_cache = dict(cache_l, k=kv[0], v=kv[1])
    if cross is not None:
        xp = {k[2:]: v for k, v in pl.items() if k.startswith("x_")}
        h, _ = attention(xp, rms_norm(x, pl["ln3"], cfg.norm_eps), cfg=cfg,
                         ctx=ctx, causal=False, kv_x=cross, use_rope=False)
        x = x + h
    x = x + _ffn_apply(pl, rms_norm(x, pl["ln2"], cfg.norm_eps), cfg, ctx)
    return x, new_cache


def mamba_block(pl, x, *, cfg, ctx, state=None, decode=False):
    h = res_gather(rms_norm(x, pl["ln"], cfg.norm_eps), ctx, cfg.sp_mode)
    if decode:
        y, s = mamba2_decode(pl, h, state, cfg=cfg)
    else:
        y, s = mamba2_forward(pl, h, cfg=cfg, state=state)
    return x + res_shard(y, ctx), s


def mlstm_block(pl, x, *, cfg, ctx, state=None, decode=False):
    h = res_gather(rms_norm(x, pl["ln"], cfg.norm_eps), ctx, cfg.sp_mode)
    if decode:
        y, s = mlstm_decode(pl, h, state, cfg=cfg)
        return x + y, s
    y, s = mlstm_forward(pl, h, cfg=cfg, state=state)
    return x + res_shard(y, ctx), s


def slstm_block(pl, x, *, cfg, ctx, state=None, decode=False):
    h = res_gather(rms_norm(x, pl["ln"], cfg.norm_eps), ctx, cfg.sp_mode)
    if decode:
        y, s = slstm_decode(pl, h, state, cfg=cfg)
        return x + y, s
    y, s = slstm_forward(pl, h, cfg=cfg, state=state)
    return x + res_shard(y, ctx), s


# --------------------------------------------------------------------------
# stacks (scan over layers / groups)
# --------------------------------------------------------------------------

def _scan_blocks(body, x, stacked, remat=True):
    inner = body

    def barriered(h, pl):
        # keeps XLA from hoisting dtype converts of the saved residuals out
        # of the backward loop (which would materialize the whole
        # (layers, B, L_loc, d) stack in f32 — 2x activation memory)
        return inner(jax.lax.optimization_barrier(h), pl)

    b = jax.checkpoint(barriered) if remat else barriered
    x, _ = jax.lax.scan(b, x, stacked)
    return x


def decoder_stack(params, x, *, cfg, ctx, causal=True, cross=None,
                  positions=None, remat=True):
    def body(h, pl):
        h = transformer_block(pl, h, cfg=cfg, ctx=ctx, causal=causal,
                              cross=cross, positions=positions)
        return res_shard(h, ctx), None
    return _scan_blocks(body, res_shard(x, ctx), params, remat)


def hybrid_stack(params, x, *, cfg, ctx, remat=True):
    """zamba2: groups of (attn_every - 1) mamba blocks + 1 shared attn."""
    shared = params["shared_attn"]

    def group_body(h, group_params):
        def mbody(hh, pl):
            out, _ = mamba_block(pl, hh, cfg=cfg, ctx=ctx)
            return res_shard(out, ctx), None
        h, _ = jax.lax.scan(mbody, h, group_params)
        h = transformer_block(shared, h, cfg=cfg, ctx=ctx)
        return res_shard(h, ctx), None

    gb = jax.checkpoint(group_body) if remat else group_body
    x, _ = jax.lax.scan(gb, res_shard(x, ctx), params["mamba_groups"])

    def tbody(h, pl):
        out, _ = mamba_block(pl, h, cfg=cfg, ctx=ctx)
        return res_shard(out, ctx), None
    x = _scan_blocks(tbody, x, params["mamba_tail"], remat)
    return x


def xlstm_stack(params, x, *, cfg, ctx, remat=True):
    def group_body(h, gp):
        mg, sp = gp

        def mbody(hh, pl):
            out, _ = mlstm_block(pl, hh, cfg=cfg, ctx=ctx)
            return res_shard(out, ctx), None
        h, _ = jax.lax.scan(mbody, h, mg)
        h, _ = slstm_block(sp, h, cfg=cfg, ctx=ctx)
        return res_shard(h, ctx), None

    gb = jax.checkpoint(group_body) if remat else group_body
    x, _ = jax.lax.scan(gb, res_shard(x, ctx), (params["mlstm_groups"],
                                params["slstm_blocks"]))
    return x


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------

def embed_tokens(params, tokens, ctx: Optional[MeshCtx]):
    x = jnp.take(params["embed"], tokens, axis=0)
    if ctx is not None:
        x = jax.lax.with_sharding_constraint(
            x, ctx.sharding(P(ctx.dp_axes, None, None)))
    return x


def backbone(params, batch, *, cfg: ModelConfig, ctx: Optional[MeshCtx],
             remat: bool = True) -> jnp.ndarray:
    """Full forward to final hidden states (B, L, d)."""
    fam = cfg.family
    if fam == "audio":
        frames = batch["frames"]                    # stub conv frontend
        enc = decoder_stack(params["enc_blocks"], frames, cfg=cfg, ctx=ctx,
                            causal=False, remat=remat)
        enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)
        x = embed_tokens(params, batch["tokens"], ctx)
        x = decoder_stack(params["dec_blocks"], x, cfg=cfg, ctx=ctx,
                          causal=True, cross=enc, remat=remat)
    elif fam == "vlm":
        x = embed_tokens(params, batch["tokens"], ctx)
        patches = batch.get("patches")
        if patches is not None:                     # stub ViT frontend
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        x = decoder_stack(params["blocks"], x, cfg=cfg, ctx=ctx, remat=remat)
        if patches is not None:
            x = x[:, patches.shape[1]:]
    elif fam in ("dense", "moe"):
        x = embed_tokens(params, batch["tokens"], ctx)
        x = decoder_stack(params["blocks"], x, cfg=cfg, ctx=ctx, remat=remat)
    elif fam == "hybrid":
        x = embed_tokens(params, batch["tokens"], ctx)
        x = hybrid_stack(params, x, cfg=cfg, ctx=ctx, remat=remat)
    elif fam == "ssm":
        x = embed_tokens(params, batch["tokens"], ctx)
        x = xlstm_stack(params, x, cfg=cfg, ctx=ctx, remat=remat)
    else:
        raise ValueError(fam)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def lm_loss(params, batch, *, cfg, ctx, remat=True):
    h = res_gather(backbone(params, batch, cfg=cfg, ctx=ctx, remat=remat),
                   ctx)
    return chunked_cross_entropy(h, params["unembed"], batch["labels"],
                                 true_vocab=cfg.vocab,
                                 mask=batch.get("loss_mask"))
