"""Shared model pieces: norms, RoPE, activations, chunked cross-entropy."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * gamma


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e6):
    """x: (..., L, H, hd); positions: (..., L) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # angles: (..., L, 1, half) — broadcast over the heads axis
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def swiglu(gate_up: jnp.ndarray):
    g, u = jnp.split(gate_up, 2, axis=-1)
    return jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


def chunked_cross_entropy(x: jnp.ndarray, unembed: jnp.ndarray,
                          labels: jnp.ndarray, *, true_vocab: int,
                          chunk: int = 512,
                          mask: Optional[jnp.ndarray] = None):
    """Mean CE without materializing (B, L, V) logits.

    x: (B, L, d) final hidden; unembed: (d, Vpad); labels: (B, L) int32.
    A lax.scan over L-chunks keeps peak memory at (B, chunk, Vpad); padded
    vocab entries are masked to -inf. mask: (B, L) 1.0 = count this token.
    """
    B, L, d = x.shape
    V = unembed.shape[1]
    chunk = min(chunk, L)
    n = L // chunk
    xs = x[:, :n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
    ys = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = (mask[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
          if mask is not None else jnp.ones_like(ys, jnp.float32))
    vocab_ok = (jnp.arange(V) < true_vocab)

    @jax.checkpoint          # recompute logits in backward: peak = 1 chunk
    def body(carry, inp):
        xc, yc, mc = inp
        logits = jnp.einsum("bld,dv->blv", xc, unembed,
                            preferred_element_type=jnp.float32)
        logits = jnp.where(vocab_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * mc)
        return (carry[0] + loss, carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xs, ys, ms))
    return tot / jnp.maximum(cnt, 1.0)
