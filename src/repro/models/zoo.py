"""ModelBundle: one object per architecture exposing everything the
launchers, tests and the simulation plane need."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..data.pipeline import make_batch_specs
from ..dist.sharding import MeshCtx
from ..optim import adamw_init, adamw_update, clip_by_global_norm
from . import decode as decode_mod
from . import params as pm
from .config import ModelConfig
from .transformer import lm_loss, model_defs

PyTree = Any


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig

    def __post_init__(self):
        self.defs = model_defs(self.cfg)

    # ---- parameters --------------------------------------------------------
    def init(self, key) -> PyTree:
        return pm.init_params(self.defs, key)

    def param_sds(self) -> PyTree:
        return pm.tree_sds(self.defs)

    def param_shardings(self, ctx: MeshCtx, *, serve: bool = False) -> PyTree:
        """serve=True drops the FSDP axis (weights TP-resident, replicated
        over data): serving must not re-gather weights per decoded token —
        see EXPERIMENTS.md §Perf (mixtral decode hillclimb)."""
        defs = self.defs
        if serve:
            attn_keys = {"wq", "wk", "wv", "wo", "bq", "bk", "bv",
                         "x_wq", "x_wk", "x_wv", "x_wo"}

            heads_tp = self.cfg.heads % ctx.tp == 0

            def remap(path, d):
                leaf = str(getattr(path[-1], "key", ""))
                if leaf in attn_keys and not heads_tp:
                    # odd head counts: replicate attention weights so the
                    # S-sharded cache never moves during decode
                    logical = (None,) * len(d.logical)
                else:                          # weights TP-resident
                    logical = tuple(None if a == "fsdp" else a
                                    for a in d.logical)
                return pm.ParamDef(d.shape, logical, d.init, d.scale, d.dtype)

            defs = jax.tree_util.tree_map_with_path(
                remap, defs, is_leaf=lambda x: isinstance(x, pm.ParamDef))
        return pm.tree_shardings(defs, ctx)

    def opt_sds(self) -> PyTree:
        sds = self.param_sds()
        f32 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), sds)
        from ..optim.adamw import AdamWState
        return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), f32, f32)

    def opt_shardings(self, ctx: MeshCtx) -> PyTree:
        sh = self.param_shardings(ctx)
        from ..optim.adamw import AdamWState
        from jax.sharding import NamedSharding, PartitionSpec as P
        return AdamWState(NamedSharding(ctx.mesh, P()), sh, sh)

    # ---- steps -------------------------------------------------------------
    def loss_fn(self, ctx: Optional[MeshCtx]):
        cfg = self.cfg

        def f(params, batch):
            return lm_loss(params, batch, cfg=cfg, ctx=ctx)
        return f

    def train_step(self, ctx: Optional[MeshCtx], *, lr=3e-4,
                   max_grad_norm: float = 1.0, accum: int = 1) -> Callable:
        """accum > 1: gradient accumulation over microbatches — activation
        stacks shrink by `accum` at the cost of re-gathering weights per
        microbatch (see EXPERIMENTS.md §Perf)."""
        loss_fn = self.loss_fn(ctx)

        def step(params, opt_state, batch):
            if accum == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)
                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def micro(carry, b):
                    acc_l, acc_g = carry
                    l, g = jax.value_and_grad(loss_fn)(params, b)
                    acc_g = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                    return (acc_l + l, acc_g), None

                (loss, grads), _ = jax.lax.scan(
                    micro, (jnp.float32(0), zero), mb)
                loss = loss / accum
                grads = jax.tree.map(lambda g: g / accum, grads)
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
            params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}
        return step

    def prefill_step(self, ctx: Optional[MeshCtx]) -> Callable:
        cfg = self.cfg

        def step(params, batch):
            return decode_mod.prefill(params, batch, cfg=cfg, ctx=ctx)
        return step

    def decode_step(self, ctx: Optional[MeshCtx]) -> Callable:
        cfg = self.cfg

        def step(params, cache, token, cache_len):
            return decode_mod.decode(params, cache, token, cache_len,
                                     cfg=cfg, ctx=ctx)
        return step

    # ---- specs (dry-run path, zero allocation) ------------------------------
    def batch_sds(self, *, seq: int, batch: int, mode: str) -> Dict:
        return make_batch_specs(self.cfg, seq=seq, batch=batch, mode=mode)

    def batch_shardings(self, ctx: MeshCtx, *, seq: int, batch: int,
                        mode: str):
        from jax.sharding import NamedSharding, PartitionSpec as P
        sds = self.batch_sds(seq=seq, batch=batch, mode=mode)
        dp = ctx.dp_axes if batch % ctx.dp == 0 else None

        def spec(name, s):
            lead = dp
            return NamedSharding(ctx.mesh, P(lead, *([None] * (len(s.shape) - 1))))
        return {k: spec(k, v) for k, v in sds.items()}

    def cache_defs(self, *, batch: int, cache_len: int):
        return decode_mod.cache_defs(self.cfg, batch, cache_len)

    def cache_sds(self, *, batch: int, cache_len: int):
        return pm.tree_sds(self.cache_defs(batch=batch, cache_len=cache_len))

    def cache_shardings(self, ctx: MeshCtx, *, batch: int, cache_len: int):
        defs = self.cache_defs(batch=batch, cache_len=cache_len)
        if batch % ctx.dp != 0:
            # long_500k: batch 1 — drop batch sharding, keep kv_len on model
            defs = jax.tree.map(
                lambda d: pm.ParamDef(
                    d.shape,
                    tuple(None if a == "batch" else a for a in d.logical),
                    d.init, d.scale, d.dtype),
                defs, is_leaf=lambda x: isinstance(x, pm.ParamDef))
        return pm.tree_shardings(defs, ctx)

    def init_cache(self, *, batch: int, cache_len: int) -> PyTree:
        sds = self.cache_sds(batch=batch, cache_len=cache_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def param_count(self) -> int:
        return pm.param_count(self.defs)


@functools.lru_cache(maxsize=None)
def get_bundle(arch_id: str, smoke: bool = False) -> ModelBundle:
    from ..configs import get_config
    return ModelBundle(get_config(arch_id, smoke=smoke))
