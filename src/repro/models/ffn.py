"""Dense SwiGLU FFN + Mixture-of-Experts with capacity-based dispatch.

MoE (GShard/Switch-style, TPU-native):
  - tokens stay data-parallel (sharded over pod x data); each data shard
    dispatches its local tokens into an (E, C_local, d) buffer via a
    collision-free scatter (position-in-expert from a one-hot cumsum);
  - expert weights are FSDP-sharded on d over `data` and tensor-parallel on
    d_ff over `model`; the per-layer all_gather over `data` inside the layer
    scan is the ZeRO-3 gather (its transpose in backward is the
    reduce-scatter), overlapping with compute;
  - the down-projection contracts the model-sharded d_ff, so the combine is
    followed by one psum over `model` — the only TP collective per block.

Implemented once as a local function; `moe_ffn` wraps it in jax.shard_map
when a mesh is present (collectives become no-ops on a single device).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import MeshCtx
from .common import swiglu


def dense_ffn(p, x, ctx: Optional[MeshCtx], sp_mode: str = "megatron"):
    """x: (B, L, d); p.w_up: (d, 2*dff) [gate|up], p.w_down: (dff, d)."""
    h = jnp.einsum("bld,df->blf", x, p["w_up"])
    h = swiglu(h)
    y = jnp.einsum("blf,fd->bld", h, p["w_down"])
    if ctx is not None:
        L = x.shape[1]
        seq = (sp_mode == "weightgather" and L % ctx.tp == 0 and L > 1)
        y = jax.lax.with_sharding_constraint(
            y, ctx.sharding(P(ctx.dp_axes, "model" if seq else None, None)))
    return y


def _moe_local(x, wr, w_up, w_down, *, top_k: int, capacity: int,
               fsdp_axis: Optional[str], tp_axis: Optional[str]):
    """Per-device MoE block. x: (T, d); wr: (d, E);
    w_up: (E, d_shard, 2*F_loc); w_down: (E, F_loc, d_shard)."""
    T, d = x.shape
    E = wr.shape[1]
    if fsdp_axis is not None:                       # ZeRO-3 gather
        w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", x, wr,
                   preferred_element_type=jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, top_k)        # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                       # (T*k,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh               # position within expert
    pos = (pos * oh).sum(-1)                        # (T*k,)
    keep = pos < capacity
    tok = jnp.repeat(jnp.arange(T), top_k)
    # collision-free scatter: kept (e, pos) pairs are unique; dropped add 0
    buf = jnp.zeros((E, capacity, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[tok], 0)
    buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(contrib)

    h = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = swiglu(h)
    y_e = jnp.einsum("ecf,efd->ecd", h, w_down)     # partial over F_loc
    gathered = y_e[flat_e, jnp.where(keep, pos, 0)]            # (T*k, d)
    w = jnp.where(keep, topv.reshape(-1), 0.0).astype(y_e.dtype)
    y = jnp.zeros((T, d), y_e.dtype).at[tok].add(gathered * w[:, None])
    if tp_axis is not None:                          # TP combine
        y = jax.lax.psum(y, tp_axis)
    return y


def moe_ffn(p, x, *, cfg, ctx: Optional[MeshCtx]):
    """x: (B, L, d) -> (B, L, d). p: wr (d,E), w_up (E,d,2F), w_down (E,F,d)."""
    B, L, d = x.shape
    xt = x.reshape(B * L, d)
    if ctx is None or (B * L) % ctx.dp != 0 or (B * L) <= 4096:
        # single host, tiny token counts (decode steps), or token count not
        # divisible by the DP width: local-dispatch path — weights stay
        # wherever their specs put them (TP psum comes out of the einsums)
        cap = max(1, int(B * L * cfg.top_k / cfg.num_experts
                         * cfg.moe_capacity_factor))
        y = _moe_local(xt, p["wr"], p["w_up"], p["w_down"], top_k=cfg.top_k,
                       capacity=cap, fsdp_axis=None, tp_axis=None)
        return y.reshape(B, L, d).astype(x.dtype)

    dp = ctx.dp_axes
    t_loc = B * L // ctx.dp
    cap = max(1, int(t_loc * cfg.top_k / cfg.num_experts
                     * cfg.moe_capacity_factor))
    fn = functools.partial(_moe_local, top_k=cfg.top_k, capacity=cap,
                           fsdp_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis)
    y = jax.shard_map(
        fn, mesh=ctx.mesh,
        in_specs=(P(dp, None), P(None, None),
                  P(None, "data", "model"), P(None, "model", "data")),
        out_specs=P(dp, None),
        check_vma=False,
    )(xt, p["wr"], p["w_up"], p["w_down"])
    return y.reshape(B, L, d).astype(x.dtype)
