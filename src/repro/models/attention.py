"""GQA attention: flash-chunked training/prefill, cached decode, windows.

Sharding strategy (see dist/sharding.py):
  - heads % tp == 0: head tensor-parallelism — q/k/v weights sharded on the
    head axis, attention computed locally per model rank.
  - otherwise: sequence-sharded attention — weights replicated on `model`,
    queries re-sharded along L over the model axis (each rank computes full
    softmax for its query rows), output re-gathered. Works for any head
    count (whisper 8H, qwen2-1.5b 12H, internvl2 14H, yi-34b 56H...).
  - decode: the KV cache shards its length axis over `model`; softmax and
    the context contraction reduce over a sharded axis, which SPMD lowers
    to small (B, H) all-reduces — flash-decode's combine, for free.

The flash pass is a lax.scan over query chunks with the full K/V per chunk
(peak memory chunk x L instead of L x L); causal/window masks are applied
per chunk from absolute positions.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import MeshCtx
from .common import rope

NEG = -1e30


def _with_sharding(x, ctx: Optional[MeshCtx], spec):
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(spec))


def gqa_scores_ctx(q, k, v, *, causal: bool, window: int,
                   q_offset, chunk: int = 256):
    """q: (B, Lq, H, hd), k/v: (B, S, KV, hd) -> (B, Lq, H, hd).

    Scan over query chunks; memory peak (B, chunk, H, S).
    q_offset: absolute position of q[0] (prefill: 0; decode: cache length).
    """
    B, Lq, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = hd ** -0.5
    chunk = min(chunk, Lq)
    kpos = jnp.arange(S)

    qg = q.reshape(B, Lq, KV, group, hd)

    def one_chunk(qc, qpos):
        # qc: (B, nq, KV, group, hd)
        s = jnp.einsum("bqkgh,bskh->bqkgs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((qc.shape[1], S), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bqkgs,bskh->bqkgh", p, v)

    if Lq <= chunk:
        qpos = q_offset + jnp.arange(Lq)
        return one_chunk(qg, qpos).reshape(B, Lq, H, hd)

    n = -(-Lq // chunk)
    pad = n * chunk - Lq
    if pad:                          # ragged tail: pad, compute, slice
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qs = qg.reshape(B, n, chunk, KV, group, hd).swapaxes(0, 1)

    @jax.checkpoint          # recompute probs in backward: peak = 1 chunk
    def body(_, inp):
        qc, i = inp
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        return None, one_chunk(qc, qpos)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n)))
    out = outs.swapaxes(0, 1).reshape(B, n * chunk, KV, group, hd)
    return out[:, :Lq].reshape(B, Lq, H, hd)


class AttnParams(NamedTuple):
    wq: jnp.ndarray          # (d, H, hd)
    wk: jnp.ndarray          # (d, KV, hd)
    wv: jnp.ndarray          # (d, KV, hd)
    wo: jnp.ndarray          # (H, hd, d)
    bq: Optional[jnp.ndarray] = None
    bk: Optional[jnp.ndarray] = None
    bv: Optional[jnp.ndarray] = None


def attention(p, x, *, cfg, ctx: Optional[MeshCtx], causal: bool = True,
              kv_x: Optional[jnp.ndarray] = None, use_rope: bool = True,
              positions: Optional[jnp.ndarray] = None,
              head_tp: Optional[bool] = None):
    """Full-sequence attention (train / prefill). x: (B, L, d)."""
    B, L, d = x.shape
    H, KV, hd = cfg.heads, cfg.kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bld,dnh->blnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", src, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", src, p["wv"])
    if p.get("bq") is not None:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope and kv_x is None:
        pos = positions if positions is not None else jnp.arange(L)
        q = rope(q, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
        k = rope(k, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)

    is_causal = causal and kv_x is None
    if ctx is None:
        out = gqa_scores_ctx(q, k, v, causal=is_causal,
                             window=cfg.attn_window, q_offset=0)
    else:
        if head_tp is None:
            head_tp = (H % ctx.tp == 0
                       and getattr(cfg, "sp_mode", "megatron") != "weightgather")
        dp = ctx.dp_axes
        if head_tp:
            # Megatron-style GQA TP: KV heads repeated to H so the head axis
            # shards evenly; each rank's q heads see their own kv copy.
            group = H // KV
            kr = jnp.repeat(k, group, axis=2) if group > 1 else k
            vr = jnp.repeat(v, group, axis=2) if group > 1 else v
            q = _with_sharding(q, ctx, P(dp, None, "model", None))
            kr = _with_sharding(kr, ctx, P(dp, None, "model", None))
            vr = _with_sharding(vr, ctx, P(dp, None, "model", None))
            out = gqa_scores_ctx(q, kr, vr, causal=is_causal,
                                 window=cfg.attn_window, q_offset=0)
        elif q.shape[1] % ctx.tp == 0 and q.shape[1] > 1:
            # sequence-parallel fallback (odd head counts): each model rank
            # owns L/tp query rows and the full K/V; masks use the rank's
            # absolute query offset. shard_map keeps the chunked scan local
            # so SPMD never slices across the sharded L axis.
            out = _seq_sharded_attention(q, k, v, ctx=ctx, causal=is_causal,
                                         window=cfg.attn_window)
        else:
            # tiny L (cross-attention during decode): replicated compute
            out = gqa_scores_ctx(q, k, v, causal=is_causal,
                                 window=cfg.attn_window, q_offset=0)
    y = jnp.einsum("blnh,nhd->bld", out, p["wo"])
    if ctx is not None:
        seq_out = (getattr(cfg, "sp_mode", "megatron") == "weightgather"
                   and L % ctx.tp == 0 and L > 1)
        y = _with_sharding(y, ctx, P(ctx.dp_axes,
                                     "model" if seq_out else None, None))
    return y, (k, v)


def _seq_sharded_attention(q, k, v, *, ctx: MeshCtx, causal: bool,
                           window: int):
    B, L, H, hd = q.shape
    tp = ctx.tp
    dp = ctx.dp_axes
    l_loc = L // tp

    def local_fn(q_blk, k_full, v_full):
        r = jax.lax.axis_index("model")
        # bound the f32 score buffer (B_loc, chunk, H, S) to ~256 MB
        b_loc, _, hh, _ = q_blk[0].shape
        s_full = k_full.shape[2]
        budget = max(16, (1 << 28) // max(b_loc * hh * s_full * 4, 1))
        chunk = 1 << max(4, budget.bit_length() - 1)
        return gqa_scores_ctx(q_blk[0], k_full[0], v_full[0], causal=causal,
                              window=window, q_offset=r * l_loc,
                              chunk=min(chunk, l_loc))[None]

    # dummy leading axis keeps shard_map specs rank-stable for dp tuples
    out = jax.shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(None, dp, "model", None, None),
                  P(None, dp, None, None, None),
                  P(None, dp, None, None, None)),
        out_specs=P(None, dp, "model", None, None),
        check_vma=False,
    )(q[None], k[None], v[None])
    return out[0]


def decode_attention(p, x, cache_k, cache_v, cache_len, *, cfg,
                     ctx: Optional[MeshCtx], use_rope: bool = True):
    """One-token decode. x: (B, 1, d); cache: (B, S, KV, hd) (len axis may be
    sharded over `model`). Returns y, (new_k, new_v) cache tensors."""
    B = x.shape[0]
    H, KV, hd = cfg.heads, cfg.kv_heads, cfg.head_dim
    S = cache_k.shape[1]
    q = jnp.einsum("bld,dnh->blnh", x, p["wq"])
    k = jnp.einsum("bld,dnh->blnh", x, p["wk"])
    v = jnp.einsum("bld,dnh->blnh", x, p["wv"])
    if p.get("bq") is not None:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope:
        pos = jnp.full((B, 1), cache_len, jnp.int32)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    # ring-buffer insert for windowed caches, plain insert otherwise
    slot = cache_len % S if cfg.attn_window else jnp.minimum(cache_len, S - 1)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, slot, 0, 0))
    group = H // KV
    qg = q.reshape(B, 1, KV, group, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qg, ck,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    valid = jnp.arange(S) <= jnp.minimum(cache_len, S - 1)
    s = jnp.where(valid[None, None, None, None, :], s, NEG)
    pattn = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bqkgs,bskh->bqkgh", pattn, cv).reshape(B, 1, H, hd)
    y = jnp.einsum("blnh,nhd->bld", out, p["wo"])
    if ctx is not None:
        y = _with_sharding(y, ctx, P(ctx.dp_axes, None, None))
    return y, (ck, cv)
