"""State-space blocks: Mamba2 (chunked SSD), mLSTM (chunked matrix memory),
sLSTM (scanned scalar memory with exponential gating).

All three expose a parallel train/prefill form (lax.scan over sequence
chunks carrying O(1) state — the sub-quadratic property long_500k relies on)
and a single-token decode form carrying explicit recurrent state.

Faithfulness notes (DESIGN.md §5): Mamba2 follows the SSD chunked algorithm
with shared B/C across heads and a width-4 causal depthwise conv; mLSTM uses
log-sigmoid forget gates with a chunkwise decay matrix (the published
stabilizer `m` is carried across chunks but not within-chunk re-normalized);
sLSTM uses the stabilized exponential-gating update with a dense recurrent
matrix (the paper's block-diagonal per-head variant is a sparsity pattern of
the same computation).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import rms_norm

F32 = jnp.float32


def causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds. x: (B, L, D), w: (K, D)."""
    K = w.shape[0]
    y = x * w[K - 1]
    for k in range(1, K):
        y = y + jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :-k] * w[K - 1 - k]
    return y


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_forward(p, x, *, cfg, chunk: int = 128,
                   state: Optional[Tuple] = None):
    """x: (B, L, d) -> (y, final_state). O(L * chunk) memory, O(1) state.

    state: (S (B,H,hd,N), conv_buf (B,K-1,di+2N)) for streaming prefill.
    """
    B, L, d = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = di // H
    chunk = max(1, min(chunk, L))

    zx = jnp.einsum("bld,dk->blk", x, p["in_proj"])
    z, xin = zx[..., :di], zx[..., di:]
    bc_dt = jnp.einsum("bld,dk->blk", x, p["bc_proj"])
    conv_in = jnp.concatenate([xin, bc_dt[..., :2 * N]], -1)
    conv_out = causal_conv(conv_in, p["conv_w"])
    conv_out = jax.nn.silu(conv_out.astype(F32)).astype(x.dtype)
    xin = conv_out[..., :di]
    Bm = conv_out[..., di:di + N].astype(F32)
    Cm = conv_out[..., di + N:].astype(F32)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, p["dt_proj"]).astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(F32))                      # (H,)

    nc = L // chunk
    xh = xin.reshape(B, nc, chunk, H, hd)
    dtc = dt.reshape(B, nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    S0 = (jnp.zeros((B, H, hd, N), F32) if state is None else state[0])

    @jax.checkpoint          # recompute chunk internals in backward
    def per_chunk(S, inp):
        xq, dq, bq, cq = inp          # (B,Q,H,hd) (B,Q,H) (B,Q,N) (B,Q,N)
        dA = dq * A                                            # (B,Q,H)
        cums = jnp.cumsum(dA, axis=1)
        seg = jnp.exp(cums[:, :, None, :] - cums[:, None, :, :])  # (B,i,j,H)
        Q = xq.shape[1]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        scores = jnp.einsum("bin,bjn->bij", cq, bq)            # shared heads
        w = jnp.where(mask[None, :, :, None], seg, 0.0) \
            * scores[..., None] * dq[:, None, :, :]            # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq.astype(F32))
        decay_out = jnp.exp(cums)                              # (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, S, decay_out)
        tail = jnp.exp(cums[:, -1:, :] - cums)                 # (B,Q,H)
        contrib = jnp.einsum("bjn,bjh,bjhp->bhpn",
                             bq, tail * dq, xq.astype(F32))
        S_new = S * jnp.exp(cums[:, -1])[:, :, None, None] + contrib
        return S_new, y_intra + y_inter

    S, ys = jax.lax.scan(per_chunk, S0,
                         (xh.swapaxes(0, 1), dtc.swapaxes(0, 1),
                          Bc.swapaxes(0, 1), Cc.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, L, H, hd)
    y = y + p["D"][None, None, :, None].astype(F32) \
        * xin.reshape(B, L, H, hd).astype(F32)
    y = y.reshape(B, L, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                 p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"])
    K = p["conv_w"].shape[0]
    conv_buf = conv_in[:, -(K - 1):, :]
    return out, (S, conv_buf)


def mamba2_decode(p, x, state, *, cfg):
    """Single token: x (B, 1, d); state = (S, conv_buf)."""
    B = x.shape[0]
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = di // H
    S, conv_buf = state
    zx = jnp.einsum("bld,dk->blk", x, p["in_proj"])
    z, xin = zx[..., :di], zx[..., di:]
    bc_dt = jnp.einsum("bld,dk->blk", x, p["bc_proj"])
    conv_in = jnp.concatenate([xin, bc_dt[..., :2 * N]], -1)   # (B,1,ch)
    window = jnp.concatenate([conv_buf, conv_in], axis=1)      # (B,K,ch)
    w = p["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    conv_out = jax.nn.silu(conv_out.astype(F32)).astype(x.dtype)
    xin = conv_out[..., :di]
    Bm = conv_out[..., di:di + N].astype(F32)
    Cm = conv_out[..., di + N:].astype(F32)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, p["dt_proj"]).astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(F32))
    dA = jnp.exp(dt[:, 0] * A)                                 # (B,H)
    xh = xin.reshape(B, H, hd).astype(F32)
    S = S * dA[:, :, None, None] \
        + jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0], dt[:, 0], xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], S) \
        + p["D"][None, :, None].astype(F32) * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                 p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"])
    return out, (S, window[:, 1:], )


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, chunkwise)
# ---------------------------------------------------------------------------

def mlstm_forward(p, x, *, cfg, chunk: int = 128,
                  state: Optional[Tuple] = None):
    """x: (B, L, d) -> (y, (S, n)). Matrix state per head (hd x hd)."""
    B, L, d = x.shape
    di = cfg.d_inner
    H = cfg.heads
    hd = di // H
    chunk = max(1, min(chunk, L))
    up = jnp.einsum("bld,dk->blk", x, p["up_proj"])
    z, xin = up[..., :di], up[..., di:]
    qkv = jnp.einsum("blk,kj->blj", xin, p["w_qkv"])
    q, k, v = [t.reshape(B, L, H, hd) for t in jnp.split(qkv, 3, -1)]
    gates = jnp.einsum("blk,kg->blg", xin, p["w_gates"]).astype(F32)
    logi = jax.nn.log_sigmoid(gates[..., :H])                  # (B,L,H)
    logf = jax.nn.log_sigmoid(gates[..., H:])
    scale = hd ** -0.5

    nc = L // chunk
    sw = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    S0 = jnp.zeros((B, H, hd, hd), F32) if state is None else state[0]
    n0 = jnp.zeros((B, H, hd), F32) if state is None else state[1]

    @jax.checkpoint          # recompute chunk internals in backward
    def per_chunk(carry, inp):
        S, n = carry
        qc, kc, vc, lic, lfc = inp
        cums = jnp.cumsum(lfc, axis=1)                         # (B,Q,H)
        dmat = jnp.exp(cums[:, :, None, :] - cums[:, None, :, :]
                       + lic[:, None, :, :])                   # (B,i,j,H)
        Q = qc.shape[1]
        mask = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        dmat = jnp.where(mask, dmat, 0.0)
        scores = jnp.einsum("bihp,bjhp->bijh", qc.astype(F32),
                            kc.astype(F32)) * scale
        w = scores * dmat
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, vc.astype(F32))
        dec = jnp.exp(cums)
        y_inter = jnp.einsum("bihp,bhpk,bih->bihk",
                             qc.astype(F32), S, dec) * scale
        n_inter = jnp.einsum("bihp,bhp,bih->bih",
                             qc.astype(F32), n, dec) * scale
        n_intra = jnp.einsum("bijh,bjhp,bihp->bih", w,
                             kc.astype(F32), qc.astype(F32)) * scale
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)[..., None]
        y = (y_intra + y_inter) / denom
        tail = jnp.exp(cums[:, -1:, :] - cums + lic)
        S = S * jnp.exp(cums[:, -1])[..., None, None] \
            + jnp.einsum("bjh,bjhp,bjhk->bhpk", tail, kc.astype(F32),
                         vc.astype(F32))
        n = n * jnp.exp(cums[:, -1])[..., None] \
            + jnp.einsum("bjh,bjhp->bhp", tail, kc.astype(F32))
        return (S, n), y

    (S, n), ys = jax.lax.scan(per_chunk, (S0, n0),
                              (sw(q), sw(k), sw(v), sw(logi), sw(logf)))
    y = ys.swapaxes(0, 1).reshape(B, L, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    out = jnp.einsum("blk,kd->bld", y, p["down_proj"])
    return out, (S, n)


def mlstm_decode(p, x, state, *, cfg):
    B = x.shape[0]
    di, H = cfg.d_inner, cfg.heads
    hd = di // H
    S, n = state
    up = jnp.einsum("bld,dk->blk", x, p["up_proj"])
    z, xin = up[..., :di], up[..., di:]
    qkv = jnp.einsum("blk,kj->blj", xin, p["w_qkv"])
    q, k, v = [t.reshape(B, H, hd) for t in jnp.split(qkv[:, 0], 3, -1)]
    gates = jnp.einsum("bk,kg->bg", xin[:, 0], p["w_gates"]).astype(F32)
    i = jnp.exp(jax.nn.log_sigmoid(gates[..., :H]))
    f = jnp.exp(jax.nn.log_sigmoid(gates[..., H:]))
    S = S * f[..., None, None] + i[..., None, None] \
        * jnp.einsum("bhp,bhk->bhpk", k.astype(F32), v.astype(F32))
    n = n * f[..., None] + i[..., None] * k.astype(F32)
    scale = hd ** -0.5
    y = jnp.einsum("bhp,bhpk->bhk", q.astype(F32), S) * scale
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhp,bhp->bh", q.astype(F32), n) * scale), 1.0)
    y = (y / denom[..., None]).reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    return jnp.einsum("blk,kd->bld", y, p["down_proj"]), (S, n)


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, scanned)
# ---------------------------------------------------------------------------

def slstm_forward(p, x, *, cfg, state: Optional[Tuple] = None):
    """x: (B, L, d). Stabilized exponential gating; recurrent h feedback."""
    B, L, d = x.shape
    gx = jnp.einsum("bld,dg->blg", x, p["w_in"]).astype(F32)   # (B,L,4d)

    def step(carry, g_t):
        h, c, n, m = carry
        g = g_t + jnp.einsum("bd,dg->bg", h, p["w_rec"].astype(F32))
        ii, ff, zz, oo = jnp.split(g, 4, -1)
        m_new = jnp.maximum(ff + m, ii)
        i_t = jnp.exp(ii - m_new)
        f_t = jnp.exp(ff + m - m_new)
        c = f_t * c + i_t * jnp.tanh(zz)
        n = f_t * n + i_t
        h = jax.nn.sigmoid(oo) * c / jnp.maximum(n, 1.0)
        return (h, c, n, m_new), h

    z0 = jnp.zeros((B, d), F32)
    carry0 = (z0, z0, z0, z0) if state is None else state
    carry, hs = jax.lax.scan(step, carry0, gx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    return jnp.einsum("bld,dk->blk", y, p["w_out"]), carry


def slstm_decode(p, x, state, *, cfg):
    y, carry = slstm_forward(p, x, cfg=cfg, state=state)
    return y, carry
