"""Spec-driven parameters: one definition serves dry-run (ShapeDtypeStruct,
zero allocation), smoke tests (real init) and sharding trees."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..dist.sharding import MeshCtx, logical_to_spec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]     # logical axis per dim
    init: str = "normal"                   # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "bfloat16"

    def spec(self, ctx: MeshCtx) -> P:
        """PartitionSpec with automatic replication of non-divisible dims
        (e.g. 8 KV heads over a 16-way model axis)."""
        full = logical_to_spec(ctx, *self.logical)
        out = []
        for dim, axes in zip(self.shape, full):
            if axes is None:
                out.append(None)
                continue
            names = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for n in names:
                size *= ctx.mesh.shape[n]
            out.append(axes if dim % size == 0 else None)
        return P(*out)


def tree_sds(defs: PyTree) -> PyTree:
    """ShapeDtypeStruct tree (dry-run path: no device allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def tree_specs(defs: PyTree, ctx: MeshCtx) -> PyTree:
    return jax.tree.map(lambda d: d.spec(ctx), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def tree_shardings(defs: PyTree, ctx: MeshCtx) -> PyTree:
    return jax.tree.map(lambda d: NamedSharding(ctx.mesh, d.spec(ctx)), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(defs: PyTree, key: jax.Array) -> PyTree:
    """Real initialization (smoke tests / the train example)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan = d.shape[0] if d.shape else 1
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * d.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def param_bytes(defs: PyTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(np.prod(d.shape) * jnp.dtype(d.dtype).itemsize
                   for d in leaves))


def param_count(defs: PyTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(np.prod(d.shape) for d in leaves))
