"""Serving paths: prefill (build cache) and decode (one token, cached).

Cache layout (leaves stacked over the scanned layer axis, mirroring params):
  dense/moe/vlm : {"k","v"}: (L, B, S, KV, hd) — S sharded over `model`
  audio         : decoder self-attn cache + precomputed encoder states
  hybrid        : mamba (S, conv) states per block + shared-attn K/V per group
  ssm           : mLSTM (S, n) + sLSTM (h, c, n, m) states

Windowed attention (mixtral, zamba2 shared blocks) allocates S = window and
decode_attention ring-buffers into it — the reason long_500k stays O(window).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import MeshCtx
from .config import ModelConfig
from .params import ParamDef
from .common import rms_norm
from .transformer import (CONV_K, embed_tokens, mamba_block, mlstm_block,
                          slstm_block, transformer_block,
                          transformer_block_decode)

PyTree = Any


def _pd(shape, logical, dtype):
    return ParamDef(tuple(int(s) for s in shape), tuple(logical), dtype=dtype)


def cache_defs(cfg: ModelConfig, batch: int, cache_len: int) -> PyTree:
    """ParamDef tree for the decode cache (SDS + shardings derive from it)."""
    dt = cfg.param_dtype
    B, d = batch, cfg.d_model
    S = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    KV, hd = cfg.kv_heads, cfg.head_dim
    kv = lambda L: {"k": _pd((L, B, S, KV, hd),
                             (None, "batch", "kv_len", None, None), dt),
                    "v": _pd((L, B, S, KV, hd),
                             (None, "batch", "kv_len", None, None), dt)}
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = di // H
    mamba = lambda *lead: {
        "S": _pd((*lead, B, H, p, N), (*(None,) * len(lead), "batch",
                                       None, None, None), "float32"),
        "conv": _pd((*lead, B, CONV_K - 1, di + 2 * N),
                    (*(None,) * len(lead), "batch", None, None), dt)}
    if cfg.family in ("dense", "moe", "vlm"):
        return kv(cfg.layers)
    if cfg.family == "audio":
        enc_len = cache_len                     # encoder frames
        return {"self": kv(cfg.decoder_layers),
                "enc": _pd((B, enc_len, d), ("batch", None, None), dt)}
    if cfg.family == "hybrid":
        g = cfg.attn_every
        groups = cfg.layers // g
        tail = cfg.layers - groups * g
        Sw = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        return {"mamba_groups": mamba(groups, g - 1),
                "mamba_tail": mamba(max(tail, 1)),
                "attn": {"k": _pd((groups, B, Sw, KV, hd),
                                  (None, "batch", "kv_len", None, None), dt),
                         "v": _pd((groups, B, Sw, KV, hd),
                                  (None, "batch", "kv_len", None, None), dt)}}
    if cfg.family == "ssm":
        g = cfg.slstm_every or 8
        groups = cfg.layers // g
        H2 = cfg.heads
        p2 = di // H2
        return {"mlstm": {
                    "S": _pd((groups, g - 1, B, H2, p2, p2),
                             (None, None, "batch", None, "tp", None), "float32"),
                    "n": _pd((groups, g - 1, B, H2, p2),
                             (None, None, "batch", None, "tp"), "float32")},
                "slstm": {k: _pd((groups, B, d), (None, "batch", None),
                                 "float32") for k in ("h", "c", "n", "m")}}
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def _prefill_kv_stack(params, x, *, cfg, ctx, S, causal=True, cross=None):
    """Run blocks, returning hidden + per-layer (k, v) padded to S."""
    from .attention import attention

    def body(h, pl):
        hn = rms_norm(h, pl["ln1"], cfg.norm_eps)
        a, (k, v) = attention(pl, hn, cfg=cfg, ctx=ctx, causal=causal)
        h = h + a
        if cross is not None:
            xp = {kk[2:]: vv for kk, vv in pl.items() if kk.startswith("x_")}
            a2, _ = attention(xp, rms_norm(h, pl["ln3"], cfg.norm_eps),
                              cfg=cfg, ctx=ctx, causal=False, kv_x=cross,
                              use_rope=False)
            h = h + a2
        from .transformer import _ffn_apply
        h = h + _ffn_apply(pl, rms_norm(h, pl["ln2"], cfg.norm_eps), cfg, ctx)
        L = k.shape[1]
        if cfg.attn_window and L > S:               # keep last `window`
            k, v = k[:, L - S:], v[:, L - S:]
        elif L < S:
            pad = ((0, 0), (0, S - L), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return h, {"k": k.astype(jnp.dtype(cfg.param_dtype)),
                   "v": v.astype(jnp.dtype(cfg.param_dtype))}

    body = jax.checkpoint(body)
    return jax.lax.scan(body, x, params)


def prefill(params, batch, *, cfg: ModelConfig, ctx: Optional[MeshCtx]
            ) -> Tuple[jnp.ndarray, PyTree]:
    """Returns (last-position logits (B, Vpad), cache)."""
    fam = cfg.family
    tokens = batch["tokens"]
    B, L = tokens.shape
    S = min(L, cfg.attn_window) if cfg.attn_window else L
    if fam in ("dense", "moe", "vlm"):
        x = embed_tokens(params, tokens, ctx)
        if fam == "vlm" and batch.get("patches") is not None:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], 1)
        x, cache = _prefill_kv_stack(params["blocks"], x, cfg=cfg, ctx=ctx,
                                     S=x.shape[1] if not cfg.attn_window
                                     else S)
    elif fam == "audio":
        from .transformer import decoder_stack
        enc = decoder_stack(params["enc_blocks"], batch["frames"], cfg=cfg,
                            ctx=ctx, causal=False)
        enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)
        x = embed_tokens(params, tokens, ctx)
        x, kvc = _prefill_kv_stack(params["dec_blocks"], x, cfg=cfg, ctx=ctx,
                                   S=S, cross=enc)
        cache = {"self": kvc, "enc": enc}
    elif fam == "hybrid":
        x = embed_tokens(params, tokens, ctx)
        shared = params["shared_attn"]

        def group_body(h, gp):
            def mbody(hh, pl):
                out, st = mamba_block(pl, hh, cfg=cfg, ctx=ctx)
                return out, {"S": st[0], "conv": st[1]}
            h, mstates = jax.lax.scan(mbody, h, gp)
            from .attention import attention
            a, (k, v) = attention(shared, rms_norm(h, shared["ln1"],
                                                   cfg.norm_eps),
                                  cfg=cfg, ctx=ctx, causal=True)
            h = h + a
            from .transformer import _ffn_apply
            h = h + _ffn_apply(shared, rms_norm(h, shared["ln2"],
                                                cfg.norm_eps), cfg, ctx)
            Lk = k.shape[1]
            if Lk > S:
                k, v = k[:, Lk - S:], v[:, Lk - S:]
            dt = jnp.dtype(cfg.param_dtype)
            return h, (mstates, {"k": k.astype(dt), "v": v.astype(dt)})

        group_body = jax.checkpoint(group_body)
        x, (mg, attn_c) = jax.lax.scan(group_body, x, params["mamba_groups"])

        def tbody(h, pl):
            out, st = mamba_block(pl, h, cfg=cfg, ctx=ctx)
            return out, {"S": st[0], "conv": st[1]}
        x, mt = jax.lax.scan(jax.checkpoint(tbody), x, params["mamba_tail"])
        cache = {"mamba_groups": mg, "mamba_tail": mt, "attn": attn_c}
    elif fam == "ssm":
        x = embed_tokens(params, tokens, ctx)

        def group_body(h, gp):
            mgp, sp = gp

            def mbody(hh, pl):
                out, st = mlstm_block(pl, hh, cfg=cfg, ctx=ctx)
                return out, {"S": st[0], "n": st[1]}
            h, ms = jax.lax.scan(mbody, h, mgp)
            h, ss = slstm_block(sp, h, cfg=cfg, ctx=ctx)
            return h, (ms, dict(zip(("h", "c", "n", "m"), ss)))

        group_body = jax.checkpoint(group_body)
        x, (ms, ss) = jax.lax.scan(group_body, x,
                                   (params["mlstm_groups"],
                                    params["slstm_blocks"]))
        cache = {"mlstm": ms, "slstm": ss}
    else:
        raise ValueError(fam)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"],
                        preferred_element_type=jnp.float32)
    return logits, cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def decode(params, cache, token, cache_len, *, cfg: ModelConfig,
           ctx: Optional[MeshCtx]) -> Tuple[jnp.ndarray, PyTree]:
    """One-token step. token: (B, 1) int32; returns (logits (B, Vpad), cache)."""
    fam = cfg.family
    x = embed_tokens(params, token, ctx)
    if fam in ("dense", "moe", "vlm"):
        def body(h, pc):
            pl, cl = pc
            h, cn = transformer_block_decode(pl, h, cl, cache_len, cfg=cfg,
                                             ctx=ctx)
            return h, cn
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif fam == "audio":
        enc = cache["enc"]

        def body(h, pc):
            pl, cl = pc
            h, cn = transformer_block_decode(pl, h, cl, cache_len, cfg=cfg,
                                             ctx=ctx, cross=enc)
            return h, cn
        x, kvc = jax.lax.scan(body, x, (params["dec_blocks"], cache["self"]))
        new_cache = {"self": kvc, "enc": enc}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, gpc):
            gp, (mst, ac) = gpc

            def mbody(hh, pst):
                pl, st = pst
                out, stn = mamba_block(pl, hh, cfg=cfg, ctx=ctx,
                                       state=(st["S"], st["conv"]),
                                       decode=True)
                return out, {"S": stn[0], "conv": stn[1]}
            h, ms = jax.lax.scan(mbody, h, (gp, mst))
            h, acn = transformer_block_decode(shared, h, ac, cache_len,
                                              cfg=cfg, ctx=ctx)
            return h, (ms, acn)

        x, (mg, ac) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"],
             (cache["mamba_groups"], cache["attn"])))

        def tbody(h, pst):
            pl, st = pst
            out, stn = mamba_block(pl, h, cfg=cfg, ctx=ctx,
                                   state=(st["S"], st["conv"]), decode=True)
            return out, {"S": stn[0], "conv": stn[1]}
        x, mt = jax.lax.scan(tbody, x,
                             (params["mamba_tail"], cache["mamba_tail"]))
        new_cache = {"mamba_groups": mg, "mamba_tail": mt, "attn": ac}
    elif fam == "ssm":
        def group_body(h, gpc):
            (mgp, sp), (mst, sst) = gpc

            def mbody(hh, pst):
                pl, st = pst
                out, stn = mlstm_block(pl, hh, cfg=cfg, ctx=ctx,
                                       state=(st["S"], st["n"]), decode=True)
                return out, {"S": stn[0], "n": stn[1]}
            h, ms = jax.lax.scan(mbody, h, (mgp, mst))
            h, ss = slstm_block(sp, h, cfg=cfg, ctx=ctx,
                                state=(sst["h"], sst["c"], sst["n"],
                                       sst["m"]), decode=True)
            return h, (ms, dict(zip(("h", "c", "n", "m"), ss)))

        x, (ms, ss) = jax.lax.scan(
            group_body, x,
            ((params["mlstm_groups"], params["slstm_blocks"]),
             (cache["mlstm"], cache["slstm"])))
        new_cache = {"mlstm": ms, "slstm": ss}
    else:
        raise ValueError(fam)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"],
                        preferred_element_type=jnp.float32)
    return logits, new_cache
