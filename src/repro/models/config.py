"""Model configuration shared by the zoo, the configs/ registry, the
simulation-plane extractor and the launchers."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | audio | hybrid | ssm | vlm
    layers: int
    d_model: int
    heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // heads
    qkv_bias: bool = False
    num_experts: int = 1
    top_k: int = 1
    attn_window: int = 0        # 0 = full attention; >0 = sliding window
    attn_every: int = 0         # hybrid: attention block every N blocks
    ssm_state: int = 64
    ssm_headdim: int = 64
    slstm_every: int = 0        # xlstm: sLSTM block every N blocks
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    frontend: Optional[str] = None   # None | audio | vision  (stub inputs)
    frontend_tokens: int = 0         # vision: #patch embeddings prepended
    encoder_layers: int = 0          # audio enc-dec: encoder depth
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    moe_capacity_factor: float = 1.25
    # sequence-parallel strategy (see models/transformer.py + EXPERIMENTS.md
    # §Perf): "megatron" all-gathers activations at each TP sublayer;
    # "weightgather" (2D-FSDP) keeps activations L-sharded and gathers the
    # (data x model)-sharded weights per layer instead.
    sp_mode: str = "megatron"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding tables padded to a TP-friendly multiple of 256."""
        return -(-self.vocab // 256) * 256

    @property
    def decoder_layers(self) -> int:
        return self.layers - self.encoder_layers

    @property
    def d_inner(self) -> int:        # mamba2 / mLSTM expanded width
        return 2 * self.d_model

    @property
    def ssm_heads(self) -> int:
        return max(1, self.d_inner // self.ssm_headdim)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with O(1)-or-windowed state? (long_500k)."""
        return (self.family in ("ssm", "hybrid")
                or (self.attn_window > 0 and self.family != "audio"))

    def param_count(self) -> float:
        """Analytic parameter count (for MODEL_FLOPS roofline terms)."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.heads * hd) + 2 * d * (self.kv_heads * hd) \
            + (self.heads * hd) * d
        if self.num_experts > 1:
            ffn = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        else:
            ffn = 3 * d * self.d_ff if self.d_ff else 0
        ssm = d * (2 * self.d_inner + 2 * self.ssm_state + self.ssm_heads) \
            + self.d_inner * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "vlm"):
            body = self.layers * (attn + ffn)
        elif self.family == "audio":
            enc = self.encoder_layers * (attn + ffn)
            dec = self.decoder_layers * (2 * attn + ffn)   # self + cross
            body = enc + dec
        elif self.family == "hybrid":
            n_attn = self.layers // max(self.attn_every, 1)
            body = (self.layers - n_attn) * ssm + 1 * (attn + ffn)  # shared
        elif self.family == "ssm":
            n_s = self.layers // max(self.slstm_every or 8, 1)
            slstm = 4 * d * d + 4 * d
            body = (self.layers - n_s) * ssm + n_s * slstm
        else:
            raise ValueError(self.family)
        return float(body + emb)

    def active_param_count(self) -> float:
        """MoE: parameters touched per token (top-k experts)."""
        if self.num_experts <= 1:
            return self.param_count()
        d = self.d_model
        dense_ffn = self.num_experts * 3 * d * self.d_ff
        active_ffn = self.top_k * 3 * d * self.d_ff
        return self.param_count() - self.layers * (dense_ffn - active_ffn)
