"""Frontier-perturbation proposals between search rounds.

The initial screen only sees a hash-uniform sample of the space; to
escape it, each refinement round perturbs the current Pareto frontier —
every ±1-step neighbor of every frontier point along every axis — and
evaluates the most promising `n` of them. "Most promising" is decided by
a counter-keyed hash shuffle (deterministic, replayable), not an RNG:
neighborhoods are small enough that coverage matters more than ordering,
and determinism is what makes the whole search resumable.

When a neighborhood runs dry (frontier boxed into corners, everything
already evaluated), the proposer tops up with fresh deterministic samples
on a per-round salt so rounds never stall.
"""
from __future__ import annotations

from typing import List, Sequence

from .space import SearchPoint, SearchSpace, hash_u64

__all__ = ["propose"]


def propose(space: SearchSpace, parents: Sequence[SearchPoint], n: int, *,
            seed: int = 0, round_idx: int = 0,
            exclude: Sequence[str] = ()) -> List[SearchPoint]:
    """Up to `n` new candidate points derived from `parents`.

    Candidates = valid, unseen ±1-axis neighbors of the parents (first
    occurrence wins when parents share neighbors), ordered by a
    hash keyed on `(space, seed, round, label)`, truncated to `n`; the
    shortfall, if any, is filled with fresh `space.sample` draws salted
    by the round index. Pure function of its arguments — same frontier,
    same seed, same round ⇒ same proposals.
    """
    if n <= 0:
        return []
    seen = set(exclude)
    cand: List[tuple] = []
    for parent in parents:
        for nb in space.neighbors(parent):
            lab = space.label(nb)
            if lab in seen:
                continue
            seen.add(lab)
            if not space.is_valid(nb):
                continue
            cand.append((hash_u64(
                f"{space.name}:prop:{seed}:{round_idx}:{lab}"), lab, nb))
    cand.sort()
    out = [nb for _, _, nb in cand[:n]]
    if len(out) < n:
        out.extend(space.sample(n - len(out), seed=seed,
                                salt=1_000_000 + round_idx, exclude=seen))
    return out
