"""SearchDriver: autonomous multi-fidelity rounds over a SearchSpace.

Each round is compiled into an *ad-hoc Study* — the cohort as the design
axis, one fidelity — so every evaluation flows through the existing
machinery unchanged: `_sweep_batched` flavor groups (one vmapped kernel
per static flavor), the content-hash cell cache, and, with a farm
executor, the broker/worker fleet (warming the same shared cache in both
directions, since cells are keyed by config *content*, not by study or
round).

The schedule:

    round 0            screen: `screen` hash-sampled points at ladder[0]
    rounds 1..R        propose: promote ceil(n/η) by Pareto rank, perturb
                       that frontier (proposer), evaluate the new points
                       at ladder[0]
    rungs              for each higher fidelity: promote `rung_sizes[i]`
                       survivors of the previous fidelity and re-evaluate

Everything the schedule decides is recorded in a `SearchLog` whose
entries are pure functions of (space, seed, knobs) plus the evaluated
metrics — deterministic bit-for-bit, so `log.digest()` is the replay
identity: same seed ⇒ same digest, locally or through the farm, cold
cache or warm. Execution accounting (executed vs cache-hit cells) is
deliberately *outside* the log — it differs between a cold run and its
warm-cache resume while the search itself is identical.

Resume = determinism + the cell cache: a killed search re-run with the
same seed re-derives the same cohorts and finds the already-executed
cells in the cache, so only not-yet-run cells execute. The optional
checkpoint file records per-round progress (atomic write) for
inspection/accounting; it is evidence, not state the resume depends on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..api.study import Study, StudyResult
from ..faults import fs as _fs
from .halving import promote
from .proposer import propose
from .space import SearchPoint, SearchSpace

__all__ = ["SearchLog", "SearchResult", "SearchDriver", "FarmExecutor",
           "SEARCH_LOG_SCHEMA_VERSION"]

SEARCH_LOG_SCHEMA_VERSION = 1


class SearchLog:
    """Replayable record of a search: one entry per round.

    Entries hold only deterministic content — round kind, fidelity,
    cohort labels, promoted parents, the round's best row — so
    `digest()` is a seed-stable identity across reruns, farm/local
    execution and cold/warm caches.
    """

    def __init__(self, meta: Optional[Dict[str, object]] = None,
                 rounds: Optional[List[Dict[str, object]]] = None):
        self.meta: Dict[str, object] = dict(meta or {})
        self.rounds: List[Dict[str, object]] = list(rounds or [])

    def append(self, **entry) -> None:
        self.rounds.append(entry)

    def to_json(self) -> str:
        return json.dumps({"schema_version": SEARCH_LOG_SCHEMA_VERSION,
                           "meta": self.meta, "rounds": self.rounds},
                          sort_keys=True, indent=1)

    @classmethod
    def from_json(cls, s: str) -> "SearchLog":
        d = json.loads(s)
        if d.get("schema_version") != SEARCH_LOG_SCHEMA_VERSION:
            raise ValueError(
                f"search log schema_version {d.get('schema_version')!r} "
                f"!= supported {SEARCH_LOG_SCHEMA_VERSION}")
        return cls(meta=d.get("meta"), rounds=d.get("rounds"))

    def digest(self) -> str:
        """sha256 over the canonical JSON — the search's replay identity."""
        blob = json.dumps({"schema_version": SEARCH_LOG_SCHEMA_VERSION,
                           "meta": self.meta, "rounds": self.rounds},
                          sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()


@dataclasses.dataclass
class SearchResult:
    """What a search run produced.

    frame: every evaluated cell across all rounds and fidelities, one
    `StudyResult` (concat of the round frames; a design promoted up the
    ladder appears once per fidelity). winner: the best-`metric` row at
    the final rung's fidelity. spent_evals: evaluations the schedule
    *requested* (the budget currency); executed_cells/cache_hits split
    those into actually-run vs cache-served. exhaustive_cells: the valid
    size of the space — the cost exhaustion would have paid.
    """
    frame: StudyResult
    log: SearchLog
    winner: Dict[str, object]
    spent_evals: int
    executed_cells: int
    cache_hits: int
    exhaustive_cells: int


class FarmExecutor:
    """Round executor dispatching each ad-hoc Study to a `repro.farm`
    fleet. `pump`, when given, is called between status polls — in-process
    tests pass a closure stepping the broker and workers synchronously;
    against a live fleet leave it None and the executor just polls.

    Point the driver's cache at `self.cache_dir` (the farm's shared dedup
    cache) and warm cells flow both ways between local and farm rounds.
    """

    def __init__(self, root: str, *, pump: Optional[Callable[[], None]] = None,
                 poll_s: float = 0.05, timeout_s: float = 600.0):
        from ..farm.client import FarmClient
        from ..farm.queue import FarmDirs
        self.root = root
        self.pump = pump
        self.poll_s = poll_s
        self.timeout_s = timeout_s
        self.cache_dir = FarmDirs(root).cache_dir()
        os.makedirs(self.cache_dir, exist_ok=True)
        self._client = FarmClient(root)

    def __call__(self, study: Study) -> StudyResult:
        sid = self._client.submit(study)
        deadline = time.monotonic() + self.timeout_s
        # a fresh submission sits "queued" until the broker shards it
        while self._client.status(sid).get("state") not in (
                "done", "error", "canceled"):
            if self.pump is not None:
                self.pump()
            else:
                time.sleep(self.poll_s)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"farm round {study.name!r} ({sid}) still running "
                    f"after {self.timeout_s}s")
        return self._client.result(sid, timeout=self.timeout_s)


class SearchDriver:
    """Drives the screen → propose → promote schedule over a space.

    workloads: {name: ops} — the workload axis of every round study.
    ladder: fidelity per rung, cheapest first (ladder[0] is where the
    screen and all proposal rounds run). rung_sizes: cohort size for each
    ladder[1:] rung; defaults to continued halving of the last base-rung
    cohort. budget: hard cap on total requested evaluations — cohorts
    truncate to the remaining budget and the search stops when it hits 0.
    executor: callable(Study) -> StudyResult (None = `study.run()`
    locally; see `FarmExecutor`).
    """

    def __init__(self, space: SearchSpace, workloads: Dict[str, object], *,
                 seed: int = 0, metric: str = "edp",
                 objectives: Sequence[str] = ("total_cycles", "energy_pj"),
                 ladder: Sequence[str] = ("fast",), screen: int = 64,
                 eta: float = 4.0, explore_rounds: int = 1,
                 rung_sizes: Optional[Sequence[int]] = None,
                 budget: Optional[int] = None,
                 cache: Optional[str] = None,
                 checkpoint: Optional[str] = None,
                 executor: Optional[Callable[[Study], StudyResult]] = None):
        if screen < 1:
            raise ValueError(f"screen cohort must be >= 1, got {screen}")
        if eta <= 1:
            raise ValueError(f"eta must be > 1, got {eta}")
        if not ladder:
            raise ValueError("ladder needs at least one fidelity")
        if explore_rounds < 0:
            raise ValueError(f"explore_rounds must be >= 0, "
                             f"got {explore_rounds}")
        if rung_sizes is not None and len(rung_sizes) != len(ladder) - 1:
            raise ValueError(
                f"rung_sizes needs one entry per ladder[1:] rung "
                f"({len(ladder) - 1}), got {len(rung_sizes)}")
        self.space = space
        self.workloads = dict(workloads)
        self.seed = int(seed)
        self.metric = metric
        self.objectives = tuple(objectives)
        self.ladder = tuple(ladder)
        self.screen = int(screen)
        self.eta = float(eta)
        self.explore_rounds = int(explore_rounds)
        self.rung_sizes = (None if rung_sizes is None
                           else [int(k) for k in rung_sizes])
        self.budget = None if budget is None else int(budget)
        self.cache = cache
        self.checkpoint = checkpoint
        self.executor = executor

    # ---- internals ---------------------------------------------------------
    def _eval_cohort(self, round_idx: int, fidelity: str,
                     points: Sequence[SearchPoint]) -> StudyResult:
        study = Study(f"{self.space.name}-r{round_idx}-{fidelity}")
        study.designs({self.space.label(p): self.space.config(p)
                       for p in points})
        study.workloads(self.workloads)
        study.fidelity(fidelity)
        if self.cache is not None:
            study.cache(self.cache)
        if self.executor is not None:
            return self.executor(study)
        return study.run()

    def _checkpoint(self, log: SearchLog, spent: int, executed: int,
                    hits: int) -> None:
        if self.checkpoint is None:
            return
        _fs.atomic_write_json(
            self.checkpoint,
            {"schema_version": SEARCH_LOG_SCHEMA_VERSION,
             "space": self.space.name, "seed": self.seed,
             "rounds_done": len(log.rounds), "spent_evals": spent,
             "executed_cells": executed, "cache_hits": hits,
             "log_digest": log.digest(),
             "log": json.loads(log.to_json())},
            site="search.checkpoint", indent=None)

    # ---- the schedule ------------------------------------------------------
    def run(self) -> SearchResult:
        log = SearchLog(meta={
            "space": self.space.name, "seed": self.seed,
            "metric": self.metric, "objectives": list(self.objectives),
            "ladder": list(self.ladder), "screen": self.screen,
            "eta": self.eta, "explore_rounds": self.explore_rounds,
            "workloads": sorted(self.workloads),
        })
        frames: List[StudyResult] = []
        base_frames: List[StudyResult] = []
        evaluated: Dict[str, SearchPoint] = {}
        spent = executed = hits = 0
        budget_left = (math.inf if self.budget is None else self.budget)
        base_fid = self.ladder[0]

        def run_round(round_idx: int, kind: str, fid: str,
                      points: Sequence[SearchPoint],
                      parents: Sequence[str]) -> Optional[StudyResult]:
            nonlocal spent, executed, hits, budget_left
            points = list(points)[:int(min(budget_left, len(points)))]
            if not points:
                return None
            res = self._eval_cohort(round_idx, fid, points)
            frames.append(res)
            if fid == base_fid:
                base_frames.append(res)
            for p in points:
                evaluated.setdefault(self.space.label(p), p)
            spent += len(points)
            budget_left -= len(points)
            executed += res.executed_cells
            hits += res.cache_hits
            ok = res.ok()
            best = (ok.best(self.metric) if len(ok) else None)
            log.append(round=round_idx, kind=kind, fidelity=fid,
                       cohort=[self.space.label(p) for p in points],
                       parents=list(parents), best=best,
                       spent_evals=spent)
            self._checkpoint(log, spent, executed, hits)
            return res

        # round 0: the deterministic screen
        run_round(0, "screen", base_fid,
                  self.space.sample(self.screen, seed=self.seed, salt=0),
                  parents=[])

        # refinement rounds: perturb the Pareto frontier of everything
        # evaluated at the base fidelity so far
        last_cohort = self.screen
        for r in range(1, self.explore_rounds + 1):
            if budget_left <= 0 or not base_frames:
                break
            base = StudyResult.concat(base_frames)
            k = max(1, math.ceil(last_cohort / self.eta))
            parents = promote(base, k, metric=self.metric,
                              pareto=self.objectives)
            props = propose(self.space, [evaluated[l] for l in parents], k,
                            seed=self.seed, round_idx=r,
                            exclude=list(evaluated))
            if not props:
                break
            run_round(r, "propose", base_fid, props, parents=parents)
            last_cohort = k

        # fidelity rungs: promote survivors up the ladder
        sizes = self.rung_sizes
        if sizes is None:
            sizes, k = [], last_cohort
            for _ in self.ladder[1:]:
                k = max(1, math.ceil(k / self.eta))
                sizes.append(k)
        prev = (StudyResult.concat(base_frames) if base_frames else None)
        for i, fid in enumerate(self.ladder[1:]):
            if budget_left <= 0 or prev is None or not len(prev):
                break
            labels = promote(prev, sizes[i], metric=self.metric,
                             pareto=self.objectives)
            if not labels:
                break
            prev = run_round(self.explore_rounds + 1 + i, "rung", fid,
                             [evaluated[l] for l in labels],
                             parents=labels)

        if not frames:
            raise ValueError(
                f"search over {self.space.name!r} evaluated nothing "
                f"(budget={self.budget}, screen={self.screen})")
        frame = StudyResult.concat(frames)
        final_fid = str(frame["fidelity"][-1])
        final = frame.filter(fidelity=final_fid).ok()
        winner = final.best(self.metric)
        log.meta["winner"] = winner["design"]
        log.meta["winner_fidelity"] = final_fid
        self._checkpoint(log, spent, executed, hits)
        return SearchResult(frame=frame, log=log, winner=winner,
                            spent_evals=spent, executed_cells=executed,
                            cache_hits=hits,
                            exhaustive_cells=self.space.valid_size())
