"""Autonomous multi-fidelity design-space search (ROADMAP item 4).

Drives Study evaluations instead of cross-producting them: a declarative
`SearchSpace` (deterministic counter-keyed-hash sampling, no RNG state),
successive-halving promotion up the `fast` → `trace` → `cycle` fidelity
ladder by scalar metric or Pareto rank (`halving`), Pareto-frontier
perturbation between rounds (`proposer`), and a `SearchDriver` compiling
each round into an ad-hoc `Study` so every cell flows through the batched
sweep kernels, the content-hash cell cache and — via `FarmExecutor` — the
broker/worker fleet. `studies.search_edp` is the claims-gated flagship.
"""
from .driver import (FarmExecutor, SearchDriver, SearchLog,  # noqa: F401
                     SearchResult)
from .halving import promote, rung_sizes  # noqa: F401
from .proposer import propose  # noqa: F401
from .space import (Axis, SearchPoint, SearchSpace, choice,  # noqa: F401
                    int_log_range)
from .studies import SearchStudy, search_edp, table_v_space  # noqa: F401

__all__ = [
    "Axis", "SearchPoint", "SearchSpace", "choice", "int_log_range",
    "promote", "rung_sizes", "propose",
    "SearchDriver", "SearchLog", "SearchResult", "FarmExecutor",
    "SearchStudy", "search_edp", "table_v_space",
]
