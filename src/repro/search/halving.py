"""Successive-halving / Hyperband primitives: rung sizing + promotion.

The multi-fidelity schedule: screen a wide cohort at the cheapest
fidelity, promote the top 1/η fraction to the next rung, and so on up the
ladder (`fast` → `trace` → `cycle`). Promotion is either by a scalar
metric (lowest-k) or by Pareto rank over several objectives — rank
promotion keeps the *frontier endpoints* alive (the latency-optimal and
energy-optimal corners), not just the scalar elbow, which is what lets a
single search recover all three of the paper's Table-V verdicts.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["rung_sizes", "promote"]


def rung_sizes(n0: int, eta: float, rungs: int) -> List[int]:
    """Cohort size at each rung of a successive-halving bracket:
    `ceil(n0 / eta**i)`, never below 1. `rungs` includes the base rung,
    so `rung_sizes(64, 4, 3) == [64, 16, 4]`."""
    if n0 < 1:
        raise ValueError(f"initial cohort must be >= 1, got {n0}")
    if rungs < 1:
        raise ValueError(f"need >= 1 rung, got {rungs}")
    if eta <= 1:
        raise ValueError(f"eta must be > 1, got {eta}")
    return [max(1, math.ceil(n0 / eta ** i)) for i in range(rungs)]


def promote(frame, k: int, *, metric: str = "edp",
            pareto: Optional[Sequence[str]] = None) -> List[str]:
    """The `k` survivors of a rung, as design labels in promotion order.

    `pareto=None`: the k lowest-`metric` rows (NaN-safe `topk`; failed
    cells never promote). `pareto=(objectives...)`: Pareto-rank peeling —
    repeatedly take the non-dominated front of the remaining rows, order
    within a front by `metric`, and truncate the last front to land on
    exactly k. Rows are assumed unique per design label (one workload and
    fidelity per rung frame — the driver's invariant); duplicate labels
    promote once.

    Returns exactly `min(k, finite designs)` labels.
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    out: List[str] = []
    if k == 0 or not len(frame):
        return out
    if pareto is None:
        ranked = frame.topk(metric, len(frame))
        for lab in ranked["design"]:
            if lab not in out:
                out.append(str(lab))
                if len(out) == k:
                    break
        return out
    rem = frame
    while len(out) < k and len(rem):
        front = rem.pareto(*pareto)
        if not len(front):
            break  # only non-finite rows left — nothing can promote
        for lab in front.topk(metric, len(front))["design"]:
            if lab not in out:
                out.append(str(lab))
                if len(out) == k:
                    break
        mask = ~np.isin(rem["design"], list(set(front["design"])))
        rem = rem._subset(mask)
    return out
