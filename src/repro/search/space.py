"""Declarative design-space descriptions for the search layer.

A `SearchSpace` is a named product of `Axis` domains — each axis a finite
ordered set of values plus an `apply` transform folding the chosen value
into an `AcceleratorConfig` — with optional validity predicates pruning
combinations that make no physical sense (e.g. more layout banks than the
SRAM can hold).

Enumeration is lazy: a point is a mixed-radix index tuple, decoded on
demand, so a 10^5..10^6-cell space costs nothing to hold. Sampling is a
pure function of `(space name, seed, salt, counter)` through a
counter-keyed hash — there is no RNG object and no global state, which is
what makes every run replayable bit-for-bit and a killed search resumable
mid-round: the sample stream's prefix is always the same.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from ..core.accelerator import AcceleratorConfig

__all__ = ["Axis", "SearchPoint", "SearchSpace", "choice", "int_log_range"]


def hash_u64(key: str) -> int:
    """The search layer's only randomness source: 64 bits of a keyed
    blake2b digest. Deterministic across processes and platforms."""
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")


@dataclasses.dataclass(frozen=True)
class Axis:
    """One search dimension: name, ordered finite domain, config transform.

    `short` is the label prefix ("a" -> "a64"); it defaults to the axis
    name and may be "" for self-describing values like dataflows.
    """
    name: str
    values: Tuple
    apply: Callable[[AcceleratorConfig, object], AcceleratorConfig]
    short: Optional[str] = None

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"axis {self.name!r} has an empty domain")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"axis {self.name!r} has duplicate values")

    @property
    def tag(self) -> str:
        return self.name if self.short is None else self.short


def choice(name: str, values: Sequence,
           apply: Callable[[AcceleratorConfig, object], AcceleratorConfig],
           short: Optional[str] = None) -> Axis:
    """A categorical axis over an explicit value list."""
    return Axis(name, tuple(values), apply, short)


def int_log_range(name: str, lo: int, hi: int, steps: int,
                  apply: Callable[[AcceleratorConfig, object],
                                  AcceleratorConfig],
                  short: Optional[str] = None) -> Axis:
    """`steps` log-spaced integers spanning [lo, hi] (rounded, deduplicated,
    ascending) — near-continuous hardware sizes (SRAM KB, queue depths)."""
    if not (1 <= lo <= hi):
        raise ValueError(f"need 1 <= lo <= hi, got [{lo}, {hi}]")
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if steps == 1 or lo == hi:
        vals: Tuple[int, ...] = (int(lo),)
    else:
        ratio = hi / lo
        raw = [int(round(lo * ratio ** (i / (steps - 1))))
               for i in range(steps)]
        vals = tuple(sorted(set(raw)))
    return Axis(name, vals, apply, short)


@dataclasses.dataclass(frozen=True)
class SearchPoint:
    """One cell of the space: an index per axis (hashable, orderable)."""
    idx: Tuple[int, ...]


class SearchSpace:
    """A named product space over `Axis` domains with validity predicates.

    Predicates receive the point's `{axis name: value}` dict and return
    False to prune the combination; `valid_size()` is the exhaustive cell
    count the search budgets against.
    """

    def __init__(self, name: str, base: AcceleratorConfig,
                 axes: Sequence[Axis],
                 validity: Sequence[Callable[[Dict[str, object]], bool]] = ()):
        names = [a.name for a in axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axis names: {names}")
        if not axes:
            raise ValueError("a SearchSpace needs at least one axis")
        self.name = str(name)
        self.base = base
        self.axes: Tuple[Axis, ...] = tuple(axes)
        self.validity = tuple(validity)
        self._radix = tuple(len(a.values) for a in self.axes)
        self._valid_size: Optional[int] = None

    def __len__(self) -> int:
        n = 1
        for r in self._radix:
            n *= r
        return n

    # ---- points ------------------------------------------------------------
    def point(self, flat: int) -> SearchPoint:
        """Mixed-radix decode of a flat index into a SearchPoint."""
        if not (0 <= flat < len(self)):
            raise IndexError(f"flat index {flat} outside {len(self)}-cell "
                             f"space {self.name!r}")
        idx: List[int] = []
        for r in reversed(self._radix):
            idx.append(flat % r)
            flat //= r
        return SearchPoint(tuple(reversed(idx)))

    def points(self) -> Iterator[SearchPoint]:
        """Lazy enumeration of every point (valid or not)."""
        for flat in range(len(self)):
            yield self.point(flat)

    def values(self, point: SearchPoint) -> Dict[str, object]:
        return {a.name: a.values[i] for a, i in zip(self.axes, point.idx)}

    def is_valid(self, point: SearchPoint) -> bool:
        vals = self.values(point)
        return all(bool(p(vals)) for p in self.validity)

    def valid_size(self) -> int:
        """Exact count of valid cells — the exhaustive cost a search is
        measured against. Walks the whole space once (cheap at ~1e5-1e6
        cells) and caches the count."""
        if self._valid_size is None:
            if not self.validity:
                self._valid_size = len(self)
            else:
                self._valid_size = sum(
                    1 for p in self.points() if self.is_valid(p))
        return self._valid_size

    def config(self, point: SearchPoint) -> AcceleratorConfig:
        """Compile a point into a config: axis transforms applied in axis
        order over the base config."""
        cfg = self.base
        for a, i in zip(self.axes, point.idx):
            cfg = a.apply(cfg, a.values[i])
        return cfg

    def label(self, point: SearchPoint) -> str:
        """Stable human-readable identity, e.g. 'a64-s4096-ws-ch2-bw19.2'.
        Used as the Study design label; the cell cache keys on config
        *content*, so labels never affect cache identity."""
        return "-".join(f"{a.tag}{a.values[i]}"
                        for a, i in zip(self.axes, point.idx))

    # ---- deterministic sampling --------------------------------------------
    def sample(self, n: int, *, seed: int = 0, salt: int = 0,
               exclude: Sequence[str] = ()) -> List[SearchPoint]:
        """The first `n` valid, previously-unseen points of the
        deterministic stream keyed by `(name, seed, salt)`.

        Rejection sampling over counter-keyed hashes: counter i maps to
        flat index `hash(name:seed:salt:i) % len(space)`; invalid points
        and labels in `exclude` are skipped, duplicates are drawn once.
        Any prefix of the stream is reproducible, so a resumed search
        re-derives exactly the cohorts it already ran.
        """
        if n <= 0:
            return []
        out: List[SearchPoint] = []
        seen = set(exclude)
        total = len(self)
        # enough counter head-room to drain even a mostly-excluded space;
        # a space with no valid unseen points left simply returns short
        for counter in range(64 * total + 1024):
            if len(out) >= n:
                break
            flat = hash_u64(f"{self.name}:{seed}:{salt}:{counter}") % total
            p = self.point(flat)
            lab = self.label(p)
            if lab in seen:
                continue
            seen.add(lab)
            if not self.is_valid(p):
                continue
            out.append(p)
        return out

    def neighbors(self, point: SearchPoint) -> List[SearchPoint]:
        """±1-step moves along each axis — the proposer's neighborhood.
        Returns every in-bounds move (validity is the caller's filter,
        so the proposer can count pruned candidates if it wants)."""
        out: List[SearchPoint] = []
        for d, (a, i) in enumerate(zip(self.axes, point.idx)):
            for j in (i - 1, i + 1):
                if 0 <= j < len(a.values):
                    idx = list(point.idx)
                    idx[d] = j
                    out.append(SearchPoint(tuple(idx)))
        return out
