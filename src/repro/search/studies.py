"""Search-backed registry studies: the Table-V design-space search.

`studies.search_edp` recovers the paper's Table-V verdicts from a
~10^5-cell joint space (array x SRAM x dataflow x DRAM channels x DRAM
bandwidth x layout banks) while evaluating a few percent of it:

- at `fast` fidelity — the first-order model Table V itself is computed
  with — the searched frontier's EdP winner is a 64x64 cell, its latency
  endpoint a 128x128 cell and its energy endpoint a 32x32 cell;
- the `trace` rung then re-evaluates the promoted frontier with the
  cycle-accurate DRAM stall model, and the EdP verdict *flips* to 32x32:
  every array size becomes DRAM-bound on this workload, so the smallest
  (lowest-energy) array wins — the paper's core argument for end-to-end
  fidelity, machine-checked as a claim.

The whole search is a pure function of its seed: the claims gate both
the budget (≤5% of exhaustive) and bit-identical seeded replay.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Callable, Optional

from ..api.presets import get_preset
from ..api.study import Study, StudyResult, register_study
from ..core.accelerator import CoreConfig, LayoutConfig, MemoryConfig
from ..core.workloads import vit_linear
from .driver import SearchDriver
from .space import SearchSpace, choice, int_log_range

__all__ = ["SearchStudy", "table_v_space", "search_edp"]


class SearchStudy(Study):
    """A registry study whose `run()` drives a `SearchDriver` instead of
    executing a static cross-product.

    `plan()` raises: a search's cells are decided *from results*, round
    by round, so there is nothing to shard ahead of time — a search uses
    the farm by giving its driver a `FarmExecutor` for the per-round
    studies, not by being submitted as a farm job itself.

    `run()` executes the search twice — the second pass entirely from the
    warm cell cache — and records whether log digest and frame came back
    bit-identical (`meta["replay_identical"]`), which the seeded-replay
    claim gates on.
    """

    def __init__(self, name: str,
                 make_driver: Callable[[str], SearchDriver]):
        super().__init__(name)
        self._make_driver = make_driver

    def plan(self):
        raise ValueError(
            f"search study {self.name!r} has no static plan (rounds are "
            f"decided from results); call run(), and use a FarmExecutor "
            f"on the driver to fan rounds out to a fleet")

    def run(self, *, mesh=None, cache: Optional[str] = None) -> StudyResult:
        # mesh is accepted for Study-API compatibility; round studies run
        # on the default device set (give the driver an executor to
        # customize placement)
        cache_dir = cache if cache is not None else self._cache_dir
        tmp = None
        if cache_dir is None:
            tmp = tempfile.TemporaryDirectory(prefix="search-cache-")
            cache_dir = tmp.name
        try:
            sr = self._make_driver(cache_dir).run()
            sr2 = self._make_driver(cache_dir).run()
            replay_ok = (sr2.log.digest() == sr.log.digest()
                         and sr2.frame.equals(sr.frame))
        finally:
            if tmp is not None:
                tmp.cleanup()
        res = sr.frame
        res.executed_cells = sr.executed_cells
        res.cache_hits = sr.cache_hits
        res._claims = list(self._claims)
        res.meta.update({
            "search_log": sr.log.to_json(),
            "search_log_digest": sr.log.digest(),
            "winner": str(sr.winner["design"]),
            "spent_evals": float(sr.spent_evals),
            "exhaustive_cells": float(sr.exhaustive_cells),
            "replay_identical": float(replay_ok),
        })
        return res


def _apply_sram(cfg, kb):
    sram = int(kb) * 1024 // 3
    return cfg.with_(memory=dataclasses.replace(
        cfg.memory, ifmap_sram_bytes=sram, filter_sram_bytes=sram,
        ofmap_sram_bytes=sram))


def _apply_layout(cfg, banks):
    if not banks:
        return cfg.with_(layout=LayoutConfig())
    return cfg.with_(layout=LayoutConfig(enabled=True, num_banks=banks))


def table_v_space() -> SearchSpace:
    """The search_edp joint space: ~1.05e5 valid cells around the paper's
    Table-V corner (`get_preset("table-v-corner")`).

    Axes: array size {32, 64, 128} (the Table-V contenders), operand
    SRAM as 768 log-spaced KiB sizes in [512 KiB, 16 MiB] (SRAM sizing is
    near-continuous in KiB — this is where the volume honestly lives),
    all three dataflows, DRAM channels {1, 2} and per-channel bandwidth
    {9.6, 19.2} B/cycle (capped at the paper's provisioning — freeing
    DRAM would move the EdP optimum to 128x128 and the claims would no
    longer be Table V's), and the layout stage {off, 16, 32, 64 banks}.
    Validity prunes layout bank counts the SRAM cannot hold at >= 16 KiB
    per bank — a real constraint the sampler and proposer must respect.
    """
    base = get_preset("table-v-corner")
    axes = [
        choice("array", (32, 64, 128),
               lambda c, v: c.with_(cores=(CoreConfig(rows=v, cols=v),)),
               short="a"),
        int_log_range("sram_kb", 512, 16384, 768, _apply_sram, short="s"),
        choice("dataflow", ("ws", "os", "is"),
               lambda c, v: c.with_(dataflow=v), short=""),
        choice("channels", (1, 2),
               lambda c, v: c.with_(dram=dataclasses.replace(
                   c.dram, channels=v)), short="ch"),
        choice("bw", (9.6, 19.2),
               lambda c, v: c.with_(dram=dataclasses.replace(
                   c.dram, bandwidth_bytes_per_cycle=v)), short="bw"),
        choice("layout_banks", (0, 16, 32, 64), _apply_layout, short="lay"),
    ]
    validity = [lambda v: v["layout_banks"] == 0
                or v["sram_kb"] >= 16 * v["layout_banks"]]
    return SearchSpace("table-v", base, axes, validity)


def _array_of(label: str) -> int:
    # space labels lead with the array axis: "a64-s4096-ws-ch2-..."
    return int(str(label).split("-")[0][1:])


@register_study("search_edp")
def search_edp(smoke: bool = False) -> Study:
    """Autonomous Table-V search (ROADMAP item 4; see module docstring).

    smoke shrinks the workload to 2 transformer layers (per-layer shapes
    identical, so every winner claim is layer-count invariant) and the
    screen cohort — the space, ladder and claims are the full study's.
    """
    space = table_v_space()
    wl = vit_linear(768, 2 if smoke else 12, 3072, prefix="vitb")
    screen = 768 if smoke else 1536

    def make_driver(cache_dir: str) -> SearchDriver:
        return SearchDriver(
            space, {"vit-base": wl}, seed=0, metric="edp",
            objectives=("total_cycles", "energy_pj"),
            ladder=("fast", "trace"), screen=screen, eta=4.0,
            explore_rounds=2, rung_sizes=(12 if smoke else 16,),
            cache=cache_dir,
            checkpoint=os.path.join(cache_dir, "search.checkpoint.json"))

    s = SearchStudy("search_edp", make_driver)

    def fast(r: StudyResult) -> StudyResult:
        return r.filter(fidelity="fast").ok()

    s.claim("space_exceeds_1e5_cells",
            lambda r: r.meta["exhaustive_cells"] >= 1e5)
    s.claim("spent_at_most_5pct_of_exhaustive",
            lambda r: r.meta["spent_evals"]
            <= 0.05 * r.meta["exhaustive_cells"])
    s.claim("edp_winner_is_64x64",
            lambda r: _array_of(fast(r).best("edp")["design"]) == 64)
    s.claim("frontier_latency_endpoint_is_128x128",
            lambda r: _array_of(
                fast(r).pareto("total_cycles", "energy_pj")
                .best("total_cycles")["design"]) == 128)
    s.claim("frontier_energy_endpoint_is_32x32",
            lambda r: _array_of(
                fast(r).pareto("total_cycles", "energy_pj")
                .best("energy_pj")["design"]) == 32)
    s.claim("trace_rung_flips_edp_winner_to_32x32",
            lambda r: _array_of(r.filter(fidelity="trace").ok()
                                .best("edp")["design"]) == 32)
    s.claim("seeded_replay_bit_identical",
            lambda r: r.meta.get("replay_identical") == 1.0)
    return s
