"""Compatibility shims for older jax releases.

The model/launch planes are written against the current jax API
(`jax.set_mesh`, `jax.shard_map` with `check_vma`). On containers pinned to
an older jax (< 0.5) those names are missing; this module installs
equivalents once, at `repro` import time. No-ops on new jax.
"""
from __future__ import annotations

import functools

import jax


def _install() -> None:
    if not hasattr(jax, "set_mesh"):
        # jax.set_mesh(mesh) is used as a context manager; Mesh itself is
        # the context manager on old jax.
        jax.set_mesh = lambda mesh: mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      **kw):
            if check_vma is not None:       # renamed from check_rep
                kw.setdefault("check_rep", check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    _install_optimization_barrier_ad()


def _install_optimization_barrier_ad() -> None:
    """Backport the optimization_barrier differentiation rule (upstream in
    jax >= 0.4.38); models/transformer.py differentiates through the
    barrier inside its scanned layer body."""
    try:
        from jax._src import ad_util
        from jax._src.lax import lax as lax_internal
        from jax.interpreters import ad
    except ImportError:          # pragma: no cover - layout changed upstream
        return
    p = getattr(lax_internal, "optimization_barrier_p", None)
    if p is None or p in ad.primitive_jvps:
        return

    def _inst(x):
        return ad_util.instantiate(x) if isinstance(x, ad_util.Zero) else x

    def _jvp(primals, tangents):
        return p.bind(*primals), p.bind(*(_inst(t) for t in tangents))

    def _transpose(cts, *primals):
        return [_inst(ct) for ct in cts]

    ad.primitive_jvps[p] = _jvp
    ad.primitive_transposes[p] = _transpose


_install()
