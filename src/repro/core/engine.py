"""End-to-end simulation engine: op graph x AcceleratorConfig -> report.

Pipeline per GEMM op (paper Fig. 1, left to right):
  dataflow mapping -> multi-core partitioning -> compute cycles
  -> sparsity-compressed streaming (if enabled)
  -> SRAM traffic -> capacity-based DRAM traffic
  -> DRAM stalls (simple bandwidth overlap, or the cycle-accurate
     lax.scan model at `dram_fidelity='cycle'`)
  -> layout bank-conflict slowdown (if enabled)
  -> action counts -> energy / power / EdP.

Vector ops run on the SIMD unit. `simulate_network` loops ops in Python
(graphs are O(100) ops); `gemm_summary_traced` is the fully-traced variant
used by vmap/pjit DSE sweeps over thousands of accelerator configs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .accelerator import AcceleratorConfig, SparsityConfig
from . import dataflow as dfm
from .dram import simulate_dram, tile_prefetch_trace
from .energy import DEFAULT_ERT, ERT, action_counts, edp, energy_pj, power_w
from .layout import evaluate_layout
from .multicore import best_multicore
from .sparsity import sparse_compute_cycles, storage_report
from .topology import Op


@dataclasses.dataclass
class OpResult:
    name: str
    kind: str
    compute_cycles: float
    stall_cycles: float
    layout_extra_cycles: float
    total_cycles: float
    utilization: float
    macs: float
    sram_reads: float
    sram_writes: float
    dram_bytes: float
    energy_pj: float
    scheme: str = "single"
    dram_stats: Optional[Dict[str, float]] = None
    sparse_storage: Optional[Dict[str, float]] = None


@dataclasses.dataclass
class NetworkReport:
    ops: List[OpResult]
    total_cycles: float
    compute_cycles: float
    stall_cycles: float
    layout_extra_cycles: float
    dram_bytes: float
    energy_pj: float
    energy_breakdown: Dict[str, float]
    avg_power_w: float
    edp: float
    utilization: float

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["ops"] = [dataclasses.asdict(o) if not isinstance(o, dict) else o
                    for o in d["ops"]]
        return json.dumps(d, indent=1, default=float)

    def write_csv(self, path: str) -> None:
        cols = ["name", "kind", "compute_cycles", "stall_cycles",
                "layout_extra_cycles", "total_cycles", "utilization",
                "dram_bytes", "energy_pj"]
        with open(path, "w") as f:
            f.write(",".join(cols) + "\n")
            for o in self.ops:
                f.write(",".join(str(getattr(o, c)) for c in cols) + "\n")


_DRAM_REQ_CAP = 16384     # cycle-fidelity request cap per op (scaled beyond)


def simulate_op(cfg: AcceleratorConfig, op: Op, *,
                dram_fidelity: str = "fast",
                ert: ERT = DEFAULT_ERT) -> OpResult:
    core = cfg.cores[0]
    wb = cfg.memory.word_bytes

    if op.kind == "vector":
        cyc = float(dfm.simd_cycles(op.vector_elems, core.simd_lanes,
                                    core.simd_latency)) * op.count
        dram_b = op.vector_elems * wb * op.count
        counts = action_counts(cfg, cycles=cyc, macs=0.0, ifmap_reads=op.vector_elems,
                               filter_reads=0.0, ofmap_writes=op.vector_elems,
                               ofmap_reads=0.0, dram_bytes=dram_b)
        e = energy_pj(counts, ert)
        return OpResult(op.name, "vector", cyc, 0.0, 0.0, cyc, 0.0, 0.0,
                        op.vector_elems, op.vector_elems, dram_b, e["total"])

    M, N, K = op.M, op.N, op.K
    df = cfg.dataflow
    sp = cfg.sparsity
    if op.sparsity_nm is not None:
        sp = SparsityConfig(enabled=True, n=op.sparsity_nm[0],
                            m=op.sparsity_nm[1], row_wise=sp.row_wise,
                            representation=sp.representation)
    sparse_info = None
    if sp.enabled:
        comp = float(sparse_compute_cycles(df, M, N, K, core.rows, core.cols, sp))
        sparse_info = storage_report(M, K, sp, wb)
        scheme = "single"
        util = min(1.0, M * N * K / max(1.0, core.num_pes * comp * sp.m / max(sp.n, 1)))
    elif cfg.num_cores > 1:
        mc = best_multicore(cfg, M, N, K)
        comp, scheme = mc.cycles, f"{mc.scheme}({mc.Pr}x{mc.Pc})"
        util = min(1.0, M * N * K / max(1.0,
                   sum(c.num_pes for c in cfg.cores) * comp))
    else:
        comp = float(dfm.compute_cycles(df, M, N, K, core.rows, core.cols))
        scheme = "single"
        util = float(dfm.pe_utilization(df, M, N, K, core.rows, core.cols))

    sram = dfm.sram_traffic(df, M, N, K, core.rows, core.cols)
    dram = dfm.dram_traffic(df, M, N, K, core.rows, core.cols, cfg.memory)
    if sp.enabled and sparse_info is not None:
        shrink = sparse_info["total_bytes"] / max(sparse_info["original_bytes"], 1.0)
        dram["dram_filter"] = dram["dram_filter"] * shrink
        sram["filter_reads"] = sram["filter_reads"] * shrink
    dram_elems = float(dram["dram_ifmap"] + dram["dram_filter"]
                       + dram["dram_ofmap_writes"] + dram["dram_ofmap_reads"])
    dram_bytes = dram_elems * wb
    bw = cfg.dram.bandwidth_bytes_per_cycle * cfg.dram.channels

    dram_stats = None
    if dram_fidelity == "cycle":
        gran = 512
        n_req = max(1, int(dram_bytes) // gran)
        scale = max(1.0, n_req / _DRAM_REQ_CAP)
        n_sim = min(n_req, _DRAM_REQ_CAP)
        folds = max(1, int(np.ceil(n_sim / 32)))
        t, a, w = tile_prefetch_trace(n_sim * gran // folds, folds,
                                      comp / max(folds, 1) / scale, gran)
        res = simulate_dram(t, a, w, cfg.dram, gran)
        stall = float(res.stall_cycles) * scale
        dram_stats = dict(row_hits=int(res.row_hits), row_misses=int(res.row_misses),
                          row_conflicts=int(res.row_conflicts),
                          throughput_Bpc=float(res.throughput),
                          mean_latency=float(jnp.mean(res.latency)),
                          scaled_by=scale)
    else:
        stall = float(dfm.dram_stall_cycles_simple(dram_bytes / op.count if op.count
                                                   else dram_bytes, comp, bw))

    layout_extra = 0.0
    if cfg.layout.enabled:
        lr = evaluate_layout(cfg.layout, core.rows,
                             n_cycles=min(512, max(8, int(min(comp, 512)))),
                             lead_stride=1, elem_stride=max(1, N), word_bytes=wb)
        layout_extra = (lr.mean_slowdown - 1.0) * comp

    comp_total = comp * op.count
    stall_total = stall * op.count
    layout_total = layout_extra * op.count
    total = comp_total + stall_total + layout_total
    macs = op.macs
    counts = action_counts(
        cfg, cycles=comp_total, macs=macs,
        ifmap_reads=float(sram["ifmap_reads"]) * op.count,
        filter_reads=float(sram["filter_reads"]) * op.count,
        ofmap_writes=float(sram["ofmap_writes"]) * op.count,
        ofmap_reads=float(sram["ofmap_reads"]) * op.count,
        dram_bytes=dram_bytes * op.count,
        l2_reads=(dram_elems * op.count if cfg.memory.l2_sram_bytes else 0.0))
    e = energy_pj(counts, ert)
    return OpResult(op.name, "gemm", comp_total, stall_total, layout_total,
                    total, util, macs,
                    float(sram["ifmap_reads"] + sram["filter_reads"]
                          + sram["ofmap_reads"]) * op.count,
                    float(sram["ofmap_writes"]) * op.count,
                    dram_bytes * op.count, e["total"], scheme,
                    dram_stats, sparse_info)


def simulate_network(cfg: AcceleratorConfig, ops: Sequence[Op], *,
                     dram_fidelity: str = "fast",
                     ert: ERT = DEFAULT_ERT) -> NetworkReport:
    results = [simulate_op(cfg, o, dram_fidelity=dram_fidelity, ert=ert)
               for o in ops]
    total = sum(r.total_cycles for r in results)
    comp = sum(r.compute_cycles for r in results)
    stall = sum(r.stall_cycles for r in results)
    lay = sum(r.layout_extra_cycles for r in results)
    dram_b = sum(r.dram_bytes for r in results)
    e_total = sum(r.energy_pj for r in results)
    macs = sum(r.macs for r in results)
    pes = sum(c.num_pes for c in cfg.cores)
    breakdown: Dict[str, float] = {}
    return NetworkReport(
        ops=results, total_cycles=total, compute_cycles=comp,
        stall_cycles=stall, layout_extra_cycles=lay, dram_bytes=dram_b,
        energy_pj=e_total, energy_breakdown=breakdown,
        avg_power_w=power_w(e_total, total, cfg.clock_ghz),
        edp=edp(e_total, total),
        utilization=min(1.0, macs / max(1.0, pes * total)))


# --------------------------------------------------------------------------
# Traced path for DSE sweeps (vmap over array dims / GEMM dims; pjit-shardable)
# --------------------------------------------------------------------------

def gemm_summary_traced(dataflow: str, M, N, K, R, C, *,
                        sram_elems, bw_bytes_per_cycle, word_bytes=2):
    """Fully-traced single-core summary: every argument may be a jnp array.

    Used by examples/dse_sweep.py: vmap over (R, C) grids and (M, N, K)
    workloads, then pjit over the production mesh -> thousands of simulated
    designs per second. Mirrors dataflow.gemm_summary.
    """
    Sr, Sc, T = dfm.map_gemm(dataflow, M, N, K)
    fr, fc = dfm.cdiv(Sr, R), dfm.cdiv(Sc, C)
    comp = (2 * R + C + T - 2) * fr * fc
    util = (1.0 * M * N * K) / (1.0 * R * C * comp)
    WK, XK, O = 1.0 * M * K, 1.0 * K * N, 1.0 * M * N
    n_t = jnp.clip(sram_elems // jnp.maximum(K, 1), 1, N)
    m_t = jnp.clip(sram_elems // jnp.maximum(K, 1), 1, M)
    total_a = XK + WK * dfm.cdiv(N, n_t)
    total_b = WK + XK * dfm.cdiv(M, m_t)
    dram_elems = jnp.minimum(total_a, total_b) + O
    dram_bytes = dram_elems * word_bytes
    stall = jnp.maximum(0.0, dram_bytes / bw_bytes_per_cycle - comp)
    return dict(compute_cycles=comp, stall_cycles=stall,
                total_cycles=comp + stall, utilization=util,
                dram_bytes=dram_bytes)


def energy_traced(comp_cycles, macs, dram_bytes, R, C,
                  ert: ERT = DEFAULT_ERT):
    """Traced energy estimate for DSE (MAC + leak + DRAM dominate)."""
    pes = 1.0 * R * C
    util = jnp.clip(macs / jnp.maximum(1.0, pes * comp_cycles), 0.0, 1.0)
    e = (pes * comp_cycles * util * ert.mac_random
         + pes * comp_cycles * (1 - util) * ert.mac_gated
         + pes * comp_cycles * ert.pe_leak_per_cycle
         + 3.0 * macs * ert.spad_read
         + dram_bytes * ert.dram_per_byte)
    return e
