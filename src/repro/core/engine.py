"""End-to-end simulation engine: op graph x AcceleratorConfig -> report.

Thin wrappers over the shared stage pipeline in `core/stages.py`
(mapping -> partition -> sparsity -> sram -> dram -> layout -> energy);
see that module and DESIGN.md for the stage semantics. Vector ops run on
the SIMD unit. `simulate_network` loops ops in Python (graphs are O(100)
ops); `gemm_summary_traced` is the fully-traced variant used by vmap/pjit
DSE sweeps over thousands of accelerator configs — prefer the batched
`repro.api.Simulator.sweep` facade for new code.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp

from .accelerator import AcceleratorConfig
from . import stages as st
from .energy import DEFAULT_ERT, ERT, edp, power_w
from .workloads import Op

# Version stamp shared by every serialized result (NetworkReport.to_json,
# repro.api.study.StudyResult.to_json, the study on-disk cache). Bump when
# a column's meaning changes so stale caches / downstream parsers fail loud.
RESULT_SCHEMA_VERSION = 1

# Grouped CSV columns for the per-op energy breakdown (pJ).
_ENERGY_GROUPS = {
    "energy_mac_pj": ("mac_random", "mac_wire", "spad_read", "spad_write"),
    "energy_sram_pj": ("sram_read_random", "sram_read_repeat",
                       "sram_write_random", "sram_write_repeat",
                       "sram_idle_kib_cycles", "l2_read", "l2_write"),
    "energy_dram_pj": ("dram_bytes", "noc_byte_hops"),
    "energy_static_pj": ("mac_gated", "pe_leak"),
}

# The one grouped-energy column schema: NetworkReport.write_csv and
# StudyResult.to_csv both emit exactly these (in this order).
ENERGY_GROUP_COLUMNS = tuple(_ENERGY_GROUPS)


def energy_group_totals(by_action: Optional[Dict[str, float]]
                        ) -> Dict[str, float]:
    """Reduce an action -> pJ mapping onto the grouped energy columns."""
    return {g: sum((by_action or {}).get(a, 0.0) for a in acts)
            for g, acts in _ENERGY_GROUPS.items()}


def write_csv_table(path: str, header: Sequence[str],
                    rows: Sequence[Sequence]) -> None:
    """The shared CSV writer (NetworkReport.write_csv, StudyResult.to_csv).

    Floats are written with repr() so a read-back parses to the identical
    value (lossless round-trip); everything else with str(). Uses the
    stdlib csv module so labels/op names containing commas or quotes are
    escaped rather than corrupting the table.
    """
    import csv

    def fmt(v) -> str:
        if isinstance(v, float):         # incl. numpy scalars: cast so
            return repr(float(v))        # numpy-2 reprs don't leak in
        return str(v)

    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow([fmt(v) for v in r])


@dataclasses.dataclass
class OpResult:
    name: str
    kind: str
    compute_cycles: float
    stall_cycles: float
    layout_extra_cycles: float
    total_cycles: float
    utilization: float
    macs: float
    sram_reads: float
    sram_writes: float
    dram_bytes: float
    energy_pj: float
    scheme: str = "single"
    dram_stats: Optional[Dict[str, float]] = None
    sparse_storage: Optional[Dict[str, float]] = None
    energy_by_action: Optional[Dict[str, float]] = None
    noc_stall_cycles: float = 0.0       # routed-NoP queueing (repro.noc)
    noc_stats: Optional[Dict[str, float]] = None

    def energy_group(self, group: str) -> float:
        return energy_group_totals(self.energy_by_action)[group]


@dataclasses.dataclass
class NetworkReport:
    ops: List[OpResult]
    total_cycles: float
    compute_cycles: float
    stall_cycles: float
    layout_extra_cycles: float
    dram_bytes: float
    energy_pj: float
    energy_breakdown: Dict[str, float]
    avg_power_w: float
    edp: float
    utilization: float
    noc_stall_cycles: float = 0.0
    # resolved runtime replay-engine label of the DRAM stage that actually
    # ran ('' for the fast model): "xla", "pallas", "pallas:twin",
    # "pallas:interpret" or "reference" — never the unresolved request
    engine: str = ""

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["schema_version"] = RESULT_SCHEMA_VERSION
        d["ops"] = [dataclasses.asdict(o) if not isinstance(o, dict) else o
                    for o in d["ops"]]
        return json.dumps(d, indent=1, default=float)

    def write_csv(self, path: str) -> None:
        cols = ["name", "kind", "compute_cycles", "stall_cycles",
                "layout_extra_cycles", "total_cycles", "utilization",
                "dram_bytes", "energy_pj"]
        rows = [[getattr(o, c) for c in cols]
                + [o.energy_group(g) for g in ENERGY_GROUP_COLUMNS]
                for o in self.ops]
        write_csv_table(path, cols + list(ENERGY_GROUP_COLUMNS), rows)


def _result_from_ctx(ctx: st.OpContext, kind: str) -> OpResult:
    op = ctx.op
    return OpResult(
        op.name, kind, ctx.compute_total, ctx.stall_total, ctx.layout_total,
        ctx.total, ctx.util, op.macs if kind == "gemm" else 0.0,
        ctx.sram_reads, ctx.sram_writes, ctx.dram_bytes_total,
        ctx.energy_total, ctx.scheme, ctx.dram_stats, ctx.sparse_info,
        ctx.energy_by_action, noc_stall_cycles=ctx.noc_total,
        noc_stats=ctx.noc_stats)


def simulate_op(cfg: AcceleratorConfig, op: Op, *,
                dram_fidelity: str = "fast",
                ert: ERT = DEFAULT_ERT,
                pipeline: Optional[Sequence[st.Stage]] = None) -> OpResult:
    """Simulate one op through the stage pipeline.

    `pipeline` lets callers (the Simulator facade, tests) pass a prebuilt
    or customized stage list; by default it is built from `dram_fidelity`.
    """
    if op.kind == "vector":
        return _result_from_ctx(st.run_vector(cfg, op, ert), "vector")
    if pipeline is None:
        pipeline = st.build_pipeline(dram_fidelity)
    return _result_from_ctx(
        st.run_gemm_pipeline(cfg, op, pipeline, ert), "gemm")


def simulate_network(cfg: AcceleratorConfig, ops: Sequence[Op], *,
                     dram_fidelity: str = "fast",
                     ert: ERT = DEFAULT_ERT,
                     pipeline: Optional[Sequence[st.Stage]] = None
                     ) -> NetworkReport:
    if pipeline is None:
        pipeline = st.build_pipeline(dram_fidelity)
    results = [simulate_op(cfg, o, dram_fidelity=dram_fidelity, ert=ert,
                           pipeline=pipeline)
               for o in ops]
    total = sum(r.total_cycles for r in results)
    comp = sum(r.compute_cycles for r in results)
    stall = sum(r.stall_cycles for r in results)
    lay = sum(r.layout_extra_cycles for r in results)
    dram_b = sum(r.dram_bytes for r in results)
    e_total = sum(r.energy_pj for r in results)
    macs = sum(r.macs for r in results)
    pes = sum(c.num_pes for c in cfg.cores)
    breakdown: Dict[str, float] = {}
    for r in results:
        for k, v in (r.energy_by_action or {}).items():
            breakdown[k] = breakdown.get(k, 0.0) + float(v)
    return NetworkReport(
        ops=results, total_cycles=total, compute_cycles=comp,
        stall_cycles=stall, layout_extra_cycles=lay, dram_bytes=dram_b,
        energy_pj=e_total, energy_breakdown=breakdown,
        avg_power_w=power_w(e_total, total, cfg.clock_ghz),
        edp=edp(e_total, total),
        utilization=min(1.0, macs / max(1.0, pes * total)),
        noc_stall_cycles=sum(r.noc_stall_cycles for r in results),
        engine=st.pipeline_engine(pipeline))


# --------------------------------------------------------------------------
# Traced path for DSE sweeps (vmap over array dims / GEMM dims; pjit-shardable)
# --------------------------------------------------------------------------

def gemm_summary_traced(dataflow: str, M, N, K, R, C, *,
                        sram_elems, bw_bytes_per_cycle, word_bytes=2):
    """Fully-traced single-core summary: every argument may be a jnp array.

    Legacy entrypoint kept for vmap-over-(R, C)/(M, N, K) call sites; new
    code should use `repro.api.Simulator.sweep`, which runs the same traced
    stages (`core.stages.traced_gemm_stats`) over whole config grids.
    """
    mem = st.traced_memory(sram_elems, word_bytes)
    s = st.traced_gemm_stats(dataflow, M, N, K, R, C, mem,
                             bw_bytes_per_cycle)
    return dict(compute_cycles=s["compute_cycles"],
                stall_cycles=s["stall_cycles"],
                total_cycles=s["total_cycles"],
                utilization=s["utilization"],
                dram_bytes=s["dram_bytes"])


def energy_traced(comp_cycles, macs, dram_bytes, R, C,
                  ert: ERT = DEFAULT_ERT):
    """Traced energy estimate for DSE (MAC + leak + DRAM dominate)."""
    pes = 1.0 * R * C
    util = jnp.clip(macs / jnp.maximum(1.0, pes * comp_cycles), 0.0, 1.0)
    e = (pes * comp_cycles * util * ert.mac_random
         + pes * comp_cycles * (1 - util) * ert.mac_gated
         + pes * comp_cycles * ert.pe_leak_per_cycle
         + 3.0 * macs * ert.spad_read
         + dram_bytes * ert.dram_per_byte)
    return e
