"""Deprecated alias for :mod:`repro.core.workloads`.

Historically this module was called ``topology`` even though it holds
*workload operator graphs* (ResNet/ViT GEMM graphs, the LM extractor),
not an interconnect topology.  The routed interconnect now lives in
:mod:`repro.noc` (whose ``topology`` module really is about mesh/torus
coordinate maps), so the workload graphs moved to
``repro.core.workloads``.  Import from there; this shim re-exports the
old names and will be removed in a future PR.
"""
from __future__ import annotations

import warnings

from .workloads import *  # noqa: F401,F403
from .workloads import (PAPER_WORKLOADS, Op, alexnet, lm_ops,  # noqa: F401
                        rcnn, resnet18, resnet18_six_layers, resnet50,
                        total_macs, vit, vit_base, vit_base_linear,
                        vit_ffn_only, vit_large, vit_linear, vit_small)

warnings.warn(
    "repro.core.topology is deprecated: workload operator graphs moved to "
    "repro.core.workloads (the interconnect topology lives in "
    "repro.noc.topology)",
    DeprecationWarning,
    stacklevel=2,
)
