"""Dataflow mapping + runtime equations + SRAM/DRAM traffic model.

GEMM convention (paper Table II): O[M, N] = W[M, K] @ X[K, N] with
  M = output features (weight rows), N = tokens/pixels, K = reduction.

Mapping dims (Sr, Sc, T):
  input-stationary  (is): (K, N, M)   X stationary on the array
  weight-stationary (ws): (K, M, N)   W stationary on the array
  output-stationary (os): (M, N, K)   O stationary on the array

All functions accept Python ints or jnp arrays (vmap-friendly); ceil-div is
``-(-a // b)`` so tracing works.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from .accelerator import AcceleratorConfig, MemoryConfig


def cdiv(a, b):
    return -(-a // b)


def map_gemm(dataflow: str, M, N, K) -> Tuple:
    """(Sr, Sc, T) per paper Table II."""
    if dataflow == "is":
        return K, N, M
    if dataflow == "ws":
        return K, M, N
    if dataflow == "os":
        return M, N, K
    raise ValueError(f"unknown dataflow {dataflow!r}")


def unmap_gemm(dataflow: str, Sr, Sc, T) -> Tuple:
    """Inverse of `map_gemm`: mapping dims (Sr, Sc, T) -> (M, N, K).

    Used by the trace/contention path to turn a per-core share of the
    split dimensions back into a GEMM sub-problem."""
    if dataflow == "is":          # (Sr, Sc, T) = (K, N, M)
        return T, Sc, Sr
    if dataflow == "ws":          # (K, M, N)
        return Sc, T, Sr
    if dataflow == "os":          # (M, N, K)
        return Sr, Sc, T
    raise ValueError(f"unknown dataflow {dataflow!r}")


def fold_counts(Sr, Sc, R: int, C: int):
    return cdiv(Sr, R), cdiv(Sc, C)


def compute_cycles(dataflow: str, M, N, K, R: int, C: int):
    """Single-core compute cycles: (2R + C + T - 2) * ceil(Sr/R) * ceil(Sc/C).

    This is the SCALE-Sim v2 analytical runtime (paper Eq. 1 with Pr=Pc=1),
    validated cycle-accurate against the Pallas/ref wavefront simulators in
    kernels/systolic for single folds.
    """
    Sr, Sc, T = map_gemm(dataflow, M, N, K)
    fr, fc = fold_counts(Sr, Sc, R, C)
    return (2 * R + C + T - 2) * fr * fc


def pe_utilization(dataflow: str, M, N, K, R: int, C: int):
    """Useful MACs / (PEs * compute cycles)."""
    macs = 1.0 * M * N * K
    cyc = compute_cycles(dataflow, M, N, K, R, C)
    return macs / (1.0 * R * C * cyc)


def mapping_occupancy(dataflow: str, M, N, K, R: int, C: int):
    """Average fraction of the array occupied by the mapping (edge folds)."""
    Sr, Sc, T = map_gemm(dataflow, M, N, K)
    fr, fc = fold_counts(Sr, Sc, R, C)
    return (1.0 * Sr * Sc) / (1.0 * fr * R * fc * C)


def sram_traffic(dataflow: str, M, N, K, R: int, C: int) -> Dict[str, jnp.ndarray]:
    """Aggregate SRAM demand counts (elements), SCALE-Sim v2 semantics.

    - stationary operand: each element loaded once from its SRAM.
    - streaming input operand: re-streamed once per column-fold group.
    - psums: written once per row-fold, read back (accumulated) fr-1 times
      (zero for os, whose psums never leave the array until drain).
    Keys: ifmap_reads (X), filter_reads (W), ofmap_writes, ofmap_reads.
    """
    Sr, Sc, T = map_gemm(dataflow, M, N, K)
    fr, fc = fold_counts(Sr, Sc, R, C)
    WK = 1.0 * M * K
    XK = 1.0 * K * N
    O = 1.0 * M * N
    if dataflow == "ws":          # W stationary, X streams, psums accumulate
        filter_reads = WK
        ifmap_reads = fc * XK
        ofmap_writes = fr * O
        ofmap_reads = (fr - 1) * O
    elif dataflow == "is":        # X stationary, W streams
        ifmap_reads = XK
        filter_reads = fc * WK
        ofmap_writes = fr * O
        ofmap_reads = (fr - 1) * O
    else:                         # os: O stationary, both operands stream
        filter_reads = fc * WK
        ifmap_reads = fr * XK
        ofmap_writes = O
        ofmap_reads = 0.0 * O
    return dict(ifmap_reads=ifmap_reads, filter_reads=filter_reads,
                ofmap_writes=ofmap_writes, ofmap_reads=ofmap_reads)


def dram_traffic(dataflow: str, M, N, K, R: int, C: int,
                 mem: MemoryConfig) -> Dict[str, jnp.ndarray]:
    """Capacity-based DRAM traffic model (elements) over double-buffered SRAM.

    Considers the two canonical loop orders (keep X resident / keep W
    resident), tiling the non-resident operand by SRAM capacity, and takes the
    cheaper; adds psum spill traffic when the psum working set exceeds the
    ofmap SRAM. First-order but monotone in SRAM size, which is the behavior
    the paper's Fig. 5 exercises.
    """
    wb = mem.word_bytes
    WK = 1.0 * M * K
    XK = 1.0 * K * N
    O = 1.0 * M * N
    cap_if = jnp.maximum(1.0, mem.ifmap_sram_bytes / wb)   # elements
    cap_f = jnp.maximum(1.0, mem.filter_sram_bytes / wb)
    cap_o = jnp.maximum(1.0, mem.ofmap_sram_bytes / wb)

    # order A: X resident in tiles of n_t columns; W refetched per tile.
    n_t = jnp.clip(cap_if // jnp.maximum(K, 1), 1, N)
    total_a = XK + WK * cdiv(N, n_t)
    # order B: W resident in tiles of m_t rows; X refetched per tile.
    m_t = jnp.clip(cap_f // jnp.maximum(K, 1), 1, M)
    total_b = WK + XK * cdiv(M, m_t)

    a_better = total_a <= total_b
    dram_x = jnp.where(a_better, XK, XK * cdiv(M, m_t))
    dram_w = jnp.where(a_better, WK * cdiv(N, n_t), WK)

    # psum spill: ws/is accumulate across ceil(Sr/R) row folds; spills if the
    # live psum tile (C cols * T) exceeds the ofmap SRAM.
    Sr, Sc, T = map_gemm(dataflow, M, N, K)
    fr, _ = fold_counts(Sr, Sc, R, C)
    live_psum = 1.0 * C * T
    spills = jnp.where(
        (dataflow != "os") & (live_psum > cap_o), (fr - 1) * O, 0.0 * O)
    dram_o_writes = O + spills
    dram_o_reads = spills
    return dict(dram_ifmap=dram_x, dram_filter=dram_w,
                dram_ofmap_writes=dram_o_writes, dram_ofmap_reads=dram_o_reads)


def dram_stall_cycles_simple(total_bytes, compute_cycles_,
                             bw_bytes_per_cycle: float):
    """First-order memory-bound stall: double-buffered transfer vs compute."""
    xfer = total_bytes / bw_bytes_per_cycle
    return jnp.maximum(0.0, xfer - compute_cycles_)


def simd_cycles(elements, lanes: int, latency: float = 1.0):
    """Vector-unit cycles for pointwise/reduction ops (Sec. III-C)."""
    return cdiv(elements, lanes) * latency


def gemm_summary(cfg: AcceleratorConfig, M, N, K) -> Dict[str, jnp.ndarray]:
    """Single-core end-to-end summary for one GEMM (no DRAM cycle model)."""
    core = cfg.cores[0]
    R, C = core.rows, core.cols
    df = cfg.dataflow
    cyc = compute_cycles(df, M, N, K, R, C)
    sram = sram_traffic(df, M, N, K, R, C)
    dram = dram_traffic(df, M, N, K, R, C, cfg.memory)
    wb = cfg.memory.word_bytes
    dram_bytes = (dram["dram_ifmap"] + dram["dram_filter"]
                  + dram["dram_ofmap_writes"] + dram["dram_ofmap_reads"]) * wb
    bw = cfg.dram.bandwidth_bytes_per_cycle * cfg.dram.channels
    stall = dram_stall_cycles_simple(dram_bytes, cyc, bw)
    return dict(compute_cycles=cyc,
                utilization=pe_utilization(df, M, N, K, R, C),
                dram_bytes=dram_bytes,
                stall_cycles=stall,
                total_cycles=cyc + stall,
                **sram, **dram)
