"""N:M sparsity modeling (paper Sec. IV).

Sparsity lives on the weight operand W[M_rows, K]: each block of `m`
consecutive K-elements in a row holds `n` nonzeros. Layer-wise sparsity uses
one n for the whole layer; row-wise sparsity randomizes n per (row, block)
with n <= m/2 (paper constraint — density beyond m/2 negates the benefit).

Compute model: on a weight-stationary systolic array the compressed weight
stream only loads/streams nonzero reduction rows, so the effective reduction
dim K' shrinks. Columns advance in lockstep, so a fold's K' is the max over
the fold's columns of their nonzero counts (layer-wise: exactly K*n/m).

Storage model (paper Fig. 6): blocked ELLPACK = values + ceil(log2(m))-bit
metadata per value; CSR/CSC also reported for comparison.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .accelerator import SparsityConfig
from .dataflow import cdiv, map_gemm


def metadata_bits(m: int) -> int:
    return max(1, int(math.ceil(math.log2(m))))


def expected_rowwise_n(m: int) -> float:
    """Row-wise n ~ Uniform{1..m//2}: E[n] = (1 + m//2) / 2."""
    return (1 + m // 2) / 2.0


def effective_K(K, sp: SparsityConfig, cols_in_fold: int = 1):
    """Effective reduction length K' after N:M compression.

    Layer-wise: K' = ceil(K * n / m).
    Row-wise:   per-block fold length is the max over `cols_in_fold` iid
    Uniform{1..m//2} draws; E[max] = m/2 - sum_{j<m/2} (j/(m/2))^c  (exact for
    iid uniforms), applied per block of m.
    """
    if not sp.enabled:
        return K
    if not sp.row_wise:
        return cdiv(K * sp.n, sp.m)
    half = sp.m // 2
    c = max(1, cols_in_fold)
    # E[max of c iid Uniform{1..half}] = half - sum_{j=1}^{half-1} (j/half)^c
    emax = half - sum((j / half) ** c for j in range(1, half))
    blocks = cdiv(K, sp.m)
    return jnp.ceil(blocks * emax).astype(jnp.int32) if hasattr(K, "dtype") \
        else int(math.ceil(blocks * emax))


def sample_rowwise_counts(key, rows: int, K: int, m: int) -> jnp.ndarray:
    """(rows, K//m) int nonzero counts, Uniform{1..m//2} (trace fidelity)."""
    blocks = K // m
    half = max(1, m // 2)
    return jax.random.randint(key, (rows, blocks), 1, half + 1)


def sparse_compute_cycles(dataflow: str, M, N, K, R: int, C: int,
                          sp: SparsityConfig):
    """Compute cycles with compressed weight streaming (ws recommended)."""
    K_eff = effective_K(K, sp, cols_in_fold=C)
    Sr, Sc, T = map_gemm(dataflow, M, N, K_eff)
    return (2 * R + C + T - 2) * cdiv(Sr, R) * cdiv(Sc, C)


def storage_report(rows: int, K: int, sp: SparsityConfig,
                   word_bytes: int = 2) -> Dict[str, float]:
    """SPARSE_REPORT: original vs compressed filter storage in bytes."""
    dense = float(rows * K * word_bytes)
    if not sp.enabled:
        return dict(representation="dense", original_bytes=dense,
                    values_bytes=dense, metadata_bytes=0.0, total_bytes=dense)
    if sp.row_wise:
        nnz = rows * (K / sp.m) * expected_rowwise_n(sp.m)
    else:
        nnz = rows * K * sp.n / sp.m
    if sp.representation == "ellpack_block":
        meta = nnz * metadata_bits(sp.m) / 8.0
    elif sp.representation == "csr":
        idx_bytes = max(1, math.ceil(math.ceil(math.log2(max(K, 2))) / 8))
        meta = nnz * idx_bytes + (rows + 1) * 4.0
    elif sp.representation == "csc":
        idx_bytes = max(1, math.ceil(math.ceil(math.log2(max(rows, 2))) / 8))
        meta = nnz * idx_bytes + (K + 1) * 4.0
    else:
        raise ValueError(f"unknown representation {sp.representation!r}")
    values = nnz * word_bytes
    return dict(representation=sp.representation, original_bytes=dense,
                values_bytes=float(values), metadata_bytes=float(meta),
                total_bytes=float(values + meta))


def pack_ellpack_block(w: jnp.ndarray, m: int):
    """Reference blocked-ELLPACK packer (Fig. 6): (values, indices) per block.

    w: (rows, K). Returns values (rows, K//m, m//2... padded to max n) plus
    per-entry intra-block indices. Used by tests and the kernels' oracle.
    """
    rows, K = w.shape
    blocks = K // m
    wb = w[:, :blocks * m].reshape(rows, blocks, m)
    nz = wb != 0
    # stable order: nonzeros first, preserving index order
    order = jnp.argsort(~nz, axis=-1, stable=True)
    vals = jnp.take_along_axis(wb, order, axis=-1)
    idx = jnp.where(jnp.take_along_axis(nz, order, axis=-1), order, -1)
    counts = nz.sum(-1)
    keep = int(counts.max()) if counts.size else 0
    return vals[..., :keep], idx[..., :keep], counts
