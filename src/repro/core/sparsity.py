"""N:M sparsity modeling (paper Sec. IV).

Sparsity lives on the weight operand W[M_rows, K]: each block of `m`
consecutive K-elements in a row holds `n` nonzeros. Layer-wise sparsity uses
one n for the whole layer; row-wise sparsity randomizes n per (row, block)
with n <= m/2 (paper constraint — density beyond m/2 negates the benefit).

Compute model: on a weight-stationary systolic array the compressed weight
stream only loads/streams nonzero reduction rows, so the effective reduction
dim K' shrinks. Columns advance in lockstep, so a fold's K' is the max over
the fold's columns of their nonzero counts (layer-wise: exactly K*n/m).

Storage model (paper Fig. 6): blocked ELLPACK = values + ceil(log2(m))-bit
metadata per value; CSR/CSC also reported for comparison.

Every quantity has a *_model twin taking plain (possibly traced) arrays
instead of a SparsityConfig — `effective_K_model`, `storage_bytes_model`,
`sparse_compute_cycles_model` — with NO Python branching on config values:
`enabled`/`row_wise` are data selected with `jnp.where`, so the batched
sweep kernel (`repro.api.simulator`) vmaps them over mixed dense/sparse
design grids.  The eager config-taking entry points delegate to the same
models, which is what makes the batched sweep and the per-op oracle
pipeline agree bit-for-bit.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .accelerator import SparsityConfig
from .dataflow import cdiv, map_gemm

REPRESENTATIONS = ("ellpack_block", "csr", "csc")

# The fixed j-grid of the row-wise expected-max sum: supports m <= 2*cap
# (SparsityConfig validates row_wise m against this bound so the masked
# sum is always exact, never truncated).
ROWWISE_HALF_CAP = 64


def metadata_bits(m: int) -> int:
    return max(1, int(math.ceil(math.log2(m))))


def expected_rowwise_n(m: int) -> float:
    """Row-wise n ~ Uniform{1..m//2}: E[n] = (1 + m//2) / 2."""
    return (1 + m // 2) / 2.0


def effective_K_model(K, n, m, row_wise, cols_in_fold, enabled=True):
    """`effective_K` on plain arrays: every argument may be traced.

    Layer-wise: K' = ceil(K * n / m).
    Row-wise:   per-block fold length is the max over `cols_in_fold` iid
    Uniform{1..m//2} draws; E[max] = m/2 - sum_{j<m/2} (j/(m/2))^c (exact
    for iid uniforms), applied per block of m. The j-sum runs over a fixed
    `ROWWISE_HALF_CAP` grid masked to j < m//2 so it traces with m as data.
    """
    f32 = jnp.float32
    K = f32(1.0) * K
    n = f32(1.0) * n
    m = jnp.maximum(f32(1.0) * m, 1.0)
    lw = cdiv(K * n, m)
    half, c = jnp.broadcast_arrays(
        jnp.maximum(jnp.floor(m / 2.0), 1.0),
        jnp.maximum(1.0, f32(1.0) * cols_in_fold))
    j = jnp.arange(1, ROWWISE_HALF_CAP, dtype=jnp.float32)
    jb = j.reshape(j.shape + (1,) * half.ndim)       # sum axis leads
    terms = jnp.where(jb < half, (jb / half) ** c, 0.0)
    emax = half - jnp.sum(terms, axis=0)
    rw = jnp.ceil(cdiv(K, m) * emax)
    return jnp.where(enabled, jnp.where(row_wise, rw, lw), K)


def effective_K(K, sp: SparsityConfig, cols_in_fold: int = 1):
    """Effective reduction length K' after N:M compression (config form).

    Delegates to `effective_K_model` so the eager pipeline and the traced
    sweep kernel share one float32 implementation (bit-identical results).
    """
    if not sp.enabled:
        return K
    k_eff = effective_K_model(K, sp.n, sp.m, sp.row_wise, cols_in_fold)
    return k_eff if hasattr(K, "dtype") else int(k_eff)


def sample_rowwise_counts(key, rows: int, K: int, m: int) -> jnp.ndarray:
    """(rows, K//m) int nonzero counts, Uniform{1..m//2} (trace fidelity)."""
    blocks = K // m
    half = max(1, m // 2)
    return jax.random.randint(key, (rows, blocks), 1, half + 1)


def sparse_compute_cycles_model(dataflow: str, M, N, K, R, C,
                                n, m, row_wise, enabled=True):
    """Compute cycles with compressed weight streaming, on plain arrays.
    `dataflow` is static; everything else may be traced. Dense designs
    (enabled == 0) reduce exactly to `dataflow.compute_cycles`."""
    K_eff = effective_K_model(K, n, m, row_wise, cols_in_fold=C,
                              enabled=enabled)
    Sr, Sc, T = map_gemm(dataflow, M, N, K_eff)
    return (2 * R + C + T - 2) * cdiv(Sr, R) * cdiv(Sc, C)


def sparse_compute_cycles(dataflow: str, M, N, K, R: int, C: int,
                          sp: SparsityConfig):
    """Compute cycles with compressed weight streaming (ws recommended)."""
    return sparse_compute_cycles_model(dataflow, M, N, K, R, C, sp.n, sp.m,
                                       sp.row_wise, enabled=sp.enabled)


def storage_bytes_model(rows, K, n, m, row_wise, representation: str,
                        word_bytes, enabled=True):
    """`storage_report`'s byte math on plain arrays (representation and
    nothing else is static). Returns (original, values, metadata, total)
    with the dense fallback already selected where enabled == 0."""
    f32 = jnp.float32
    rows = f32(1.0) * rows
    K = f32(1.0) * K
    m = jnp.maximum(f32(1.0) * m, 1.0)
    dense = rows * K * word_bytes
    exp_n = (1.0 + jnp.floor(m / 2.0)) / 2.0         # E[Uniform{1..m//2}]
    nnz = jnp.where(row_wise, rows * (K / m) * exp_n, rows * K * n / m)
    if representation == "ellpack_block":
        bits = jnp.maximum(1.0, jnp.ceil(jnp.log2(m)))
        meta = nnz * bits / 8.0
    elif representation == "csr":
        idx_bytes = jnp.maximum(1.0, jnp.ceil(
            jnp.ceil(jnp.log2(jnp.maximum(K, 2.0))) / 8.0))
        meta = nnz * idx_bytes + (rows + 1.0) * 4.0
    elif representation == "csc":
        idx_bytes = jnp.maximum(1.0, jnp.ceil(
            jnp.ceil(jnp.log2(jnp.maximum(rows, 2.0))) / 8.0))
        meta = nnz * idx_bytes + (K + 1.0) * 4.0
    else:
        raise ValueError(f"unknown representation {representation!r}")
    values = nnz * word_bytes
    return (dense, jnp.where(enabled, values, dense),
            jnp.where(enabled, meta, 0.0),
            jnp.where(enabled, values + meta, dense))


def storage_report(rows: int, K: int, sp: SparsityConfig,
                   word_bytes: int = 2) -> Dict[str, float]:
    """SPARSE_REPORT: original vs compressed filter storage in bytes."""
    orig, values, meta, total = storage_bytes_model(
        rows, K, sp.n, sp.m, sp.row_wise, sp.representation, word_bytes,
        enabled=sp.enabled)
    return dict(representation=sp.representation if sp.enabled else "dense",
                original_bytes=float(orig), values_bytes=float(values),
                metadata_bytes=float(meta), total_bytes=float(total))


def pack_ellpack_block(w: jnp.ndarray, m: int):
    """Reference blocked-ELLPACK packer (Fig. 6): (values, indices) per block.

    w: (rows, K). Returns values (rows, K//m, m//2... padded to max n) plus
    per-entry intra-block indices. Used by tests and the kernels' oracle.
    """
    rows, K = w.shape
    blocks = K // m
    wb = w[:, :blocks * m].reshape(rows, blocks, m)
    nz = wb != 0
    # stable order: nonzeros first, preserving index order
    order = jnp.argsort(~nz, axis=-1, stable=True)
    vals = jnp.take_along_axis(wb, order, axis=-1)
    idx = jnp.where(jnp.take_along_axis(nz, order, axis=-1), order, -1)
    counts = nz.sum(-1)
    keep = int(counts.max()) if counts.size else 0
    return vals[..., :keep], idx[..., :keep], counts
