"""Energy & power modeling (paper Sec. VII) — Accelergy's ERT, embedded.

Two-stage structure mirrors the paper: (1) the simulator emits *action
counts* per component (MAC random/gated, per-PE scratchpad reads/writes, SRAM
random/repeat reads/writes, idle cycles, DRAM transfers); (2) an Energy
Reference Table (ERT) maps action -> pJ. Defaults are 65nm-class constants
calibrated (see tests/test_paper_claims.py) so the paper's Table V orderings
hold: leakage + idle energy grows with array size while dynamic MAC energy
tracks useful work, reproducing the 32x32-vs-128x128 energy flip and the
64x64 EdP optimum for ViT-base. Every entry is user-overridable, mirroring
Accelergy's user-supplied component tables.

Action definitions (Sec. VII-D/E):
  MAC_random   = #PEs * cycles * utilization
  MAC_gated    = #PEs * cycles * (1 - utilization)      (clock-gated)
  ifmap_spad   write = SRAM ifmap reads; read = MACs
  weight_spad  write = SRAM filter reads; read = MACs
  psum_spad    write = read = MACs
  SRAM_idle    = cycles * array_size - access_counts
  SRAM_random  = counts - repeat_counts; repeat split via row-buffer locality
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .accelerator import AcceleratorConfig


@dataclasses.dataclass(frozen=True)
class ERT:
    """Energy reference table, pJ per action (65nm-class defaults).

    `mac_wire_per_dim32` models operand-delivery (array NoC) energy that grows
    with array dimension — the Eyeriss-style wire cost that, together with
    leakage, makes big arrays less energy-efficient at low utilization
    (paper Table V). Effective per-MAC energy on an RxC array:
        mac_random + mac_wire_per_dim32 * (max(R, C) / 32).
    Constants are calibrated against the paper's Table V ratios in
    tests/test_paper_claims.py.
    """
    mac_random: float = 0.10         # 16-bit MAC @ 65nm, new operands
    mac_wire_per_dim32: float = 0.90  # operand delivery per MAC per 32 lanes
    mac_gated: float = 0.006         # clock-gated PE, per cycle (static only)
    pe_leak_per_cycle: float = 0.03   # per-PE leakage every cycle
    spad_read: float = 0.03          # per-PE register-file scratchpads
    spad_write: float = 0.045
    sram_read_random: float = 3.1    # L1 SRAM, per access (word)
    sram_read_repeat: float = 1.2    # same-row repeated access (>2x cheaper)
    sram_write_random: float = 3.5
    sram_write_repeat: float = 1.4
    sram_idle_per_cycle: float = 0.0005  # per KiB of SRAM per cycle
    l2_read: float = 6.0
    l2_write: float = 6.8
    dram_per_byte: float = 8.0       # ~64 pJ/bit HBM-class
    noc_per_byte_hop: float = 0.35

    def replace(self, **kw) -> "ERT":
        return dataclasses.replace(self, **kw)


DEFAULT_ERT = ERT()


def repeat_fraction(row_bytes: int = 64, word_bytes: int = 2) -> float:
    """Fraction of streaming SRAM accesses hitting the open row buffer
    (Sec. VII-C 'row size' knob): consecutive addresses within a row block
    are repeat-class; one access per block is random-class."""
    per_row = max(1, row_bytes // word_bytes)
    return 1.0 - 1.0 / per_row


def action_counts_raw(*, pes, dim32, sram_kib, word_bytes: int,
                      cycles, macs, ifmap_reads, filter_reads,
                      ofmap_writes, ofmap_reads, dram_bytes,
                      l2_reads=0.0, l2_writes=0.0, noc_byte_hops=0.0,
                      row_bytes: int = 64) -> Dict[str, float]:
    """Stage 1 core: simulator statistics -> Accelergy-style action counts.

    Config-derived scalars (`pes`, `dim32`, `sram_kib`) are explicit so the
    traced DSE path can pass jnp arrays; `action_counts` wraps this for a
    concrete AcceleratorConfig. Uses jnp min/max so every argument may be a
    traced array.
    """
    import jax.numpy as jnp
    util = jnp.clip(macs / jnp.maximum(1.0, pes * cycles), 0.0, 1.0)
    rf = repeat_fraction(row_bytes, word_bytes)
    sram_reads = ifmap_reads + filter_reads + ofmap_reads
    sram_writes = ofmap_writes
    return dict(
        mac_random=pes * cycles * util,
        mac_wire=pes * cycles * util * dim32,
        mac_gated=pes * cycles * (1.0 - util),
        pe_leak=pes * cycles,
        spad_read=3.0 * macs,                       # if/w/psum reads per MAC
        spad_write=ifmap_reads + filter_reads + macs,
        sram_read_random=sram_reads * (1 - rf),
        sram_read_repeat=sram_reads * rf,
        sram_write_random=sram_writes * (1 - rf),
        sram_write_repeat=sram_writes * rf,
        sram_idle_kib_cycles=cycles * sram_kib,
        l2_read=l2_reads, l2_write=l2_writes,
        dram_bytes=dram_bytes, noc_byte_hops=noc_byte_hops,
    )


def action_counts(cfg: AcceleratorConfig, *, cycles: float, macs: float,
                  ifmap_reads: float, filter_reads: float,
                  ofmap_writes: float, ofmap_reads: float,
                  dram_bytes: float, l2_reads: float = 0.0,
                  l2_writes: float = 0.0, noc_byte_hops: float = 0.0,
                  row_bytes: int = 64) -> Dict[str, float]:
    """Stage 1: simulator statistics -> Accelergy-style action counts."""
    pes = sum(c.num_pes for c in cfg.cores)
    dim32 = max(max(c.rows, c.cols) for c in cfg.cores) / 32.0
    sram_kib = (cfg.memory.ifmap_sram_bytes + cfg.memory.filter_sram_bytes
                + cfg.memory.ofmap_sram_bytes) / 1024.0
    return action_counts_raw(
        pes=pes, dim32=dim32, sram_kib=sram_kib,
        word_bytes=cfg.memory.word_bytes, cycles=cycles, macs=macs,
        ifmap_reads=ifmap_reads, filter_reads=filter_reads,
        ofmap_writes=ofmap_writes, ofmap_reads=ofmap_reads,
        dram_bytes=dram_bytes, l2_reads=l2_reads, l2_writes=l2_writes,
        noc_byte_hops=noc_byte_hops, row_bytes=row_bytes)


_ACTION_TO_ERT = dict(
    mac_random="mac_random", mac_wire="mac_wire_per_dim32",
    mac_gated="mac_gated", pe_leak="pe_leak_per_cycle",
    spad_read="spad_read", spad_write="spad_write",
    sram_read_random="sram_read_random", sram_read_repeat="sram_read_repeat",
    sram_write_random="sram_write_random", sram_write_repeat="sram_write_repeat",
    sram_idle_kib_cycles="sram_idle_per_cycle",
    l2_read="l2_read", l2_write="l2_write",
    dram_bytes="dram_per_byte", noc_byte_hops="noc_per_byte_hop",
)


def energy_pj(counts: Dict[str, float], ert: ERT = DEFAULT_ERT) -> Dict[str, float]:
    """Stage 2: action counts x ERT -> per-component pJ + total."""
    out = {k: counts[k] * getattr(ert, _ACTION_TO_ERT[k]) for k in counts}
    out["total"] = sum(out.values())
    return out


def power_w(total_pj: float, cycles: float, clock_ghz: float = 1.0) -> float:
    """Average power: pJ / ns = W * 1e-3 ... (pJ/cycle * GHz = mW)."""
    return total_pj / max(cycles, 1.0) * clock_ghz * 1e-3


def edp(total_pj: float, cycles: float) -> float:
    """Energy-delay product in mJ * cycles (paper Table V units)."""
    return total_pj * 1e-9 * cycles


def instantaneous_power_trace(active_pes: "jnp.ndarray", cfg: AcceleratorConfig,
                              ert: ERT = DEFAULT_ERT, clock_ghz: float = 1.0):
    """Per-cycle power trace in watts (paper Table I: 'Instantaneous +
    Average' power — v3's differentiator vs STONNE/Timeloop's averages).

    active_pes: (cycles,) active-PE counts — exactly what
    kernels/systolic.wavefront_activity / simulate_fold produce. Active PEs
    draw MAC + delivery energy; idle PEs draw gated + leakage energy.
    """
    import jax.numpy as jnp
    pes = sum(c.num_pes for c in cfg.cores)
    dim32 = max(max(c.rows, c.cols) for c in cfg.cores) / 32.0
    a = active_pes.astype(jnp.float32)
    pj_per_cycle = (a * (ert.mac_random + ert.mac_wire_per_dim32 * dim32
                         + 3 * ert.spad_read)
                    + (pes - a) * ert.mac_gated
                    + pes * ert.pe_leak_per_cycle)
    return pj_per_cycle * clock_ghz * 1e-3        # pJ/ns = W
