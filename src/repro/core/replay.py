"""Chunked bank-parallel DRAM replay (the trace-fidelity hot path).

`core.dram.simulate_dram` and `trace.contention.simulate_shared_dram`
originally replayed demand streams with a per-request `lax.scan` —
thousands of sequential steps, each a handful of dynamic `.at[fb]`
updates.  That serialization is what made trace-fidelity sweeps ~27x
slower than fast fidelity.  This module replays the same timing model in
fixed-size request chunks; inside a chunk everything is vectorized, and
the chunk loop carries only the true architectural state (per-bank
free time, per-channel bus time, in-flight rings, queue counters,
per-core shift) so chunk boundaries are invisible.

  order-only precompute (exact, hoisted out of the chunk scan)
    Row-buffer state is "last writer wins" per bank, so *everything
    about classification* — each request's previous-same-bank link, its
    row hit/empty/conflict class, and its access latency — depends only
    on stream order, never on timing.  In-chunk links are built in two
    exact levels (shifted compares + per-(bank, subblock) last
    occurrence); cross-chunk links come from a per-(bank, chunk)
    last-occurrence table prefixed over the chunk axis.  The whole
    stream is therefore classified in wide fused ops *before* the scan
    — no open-row carry remains — and the counters are bit-identical to
    the reference scan by construction.  Queue-slot indices, ring
    survivors, weighted channel prefixes and per-bank/per-channel last
    requests are likewise order-only and hoisted.

  chunk resolve (two exact closures + fixed point)
    Completion times obey
        done_i = max(max(issue_ok_i, bankdone_prev(i)) + lat_i,
                     done_prev_on_channel) + busy
    Per pass, the channel chain D_m = max(s_m, D_{m-1} + w_m) is closed
    exactly as a weighted max-plus prefix (D = W + cummax(s - W),
    W = cumsum(w), with the row-buffer lat of contiguous same-bank runs
    folded into the channel edge — a bank maps to exactly one channel,
    so bank chains live inside a channel's subsequence), and same-bank
    chains are closed by one masked (chunk, chunk) row reduction over
    the per-bank weighted prefix.  Queue backpressure `shift` is a
    per-core running max of (queue_head - t).  Each pass seeds the
    closures with the previous iterate (so bank-raised completions of
    other banks propagate down the channel chain), plus a pruned
    same-bank gather (links whose channel path already outweighs their
    lat are provably dominated and dropped) and intra-chunk queue heads
    when a queue is shorter than the chunk.

  fixed-point contract (identical under every chunked engine; see
  `kernels.replay.chunkmath.iterate_fixed_point`)
    Two statically-unrolled passes of the monotone closure operator;
    if the second pass still moved a completion by more than `tol`
    cycles (default 0.25) the iteration continues in a while_loop until
    converged, capped at `max_passes` total passes when given, else
    chunk + 2 (each pass finalizes at least the first not-yet-exact
    request, so the cap never binds).  `tol=0.0` reaches the exact
    fixed point under every engine — `simulate_shared_dram`'s
    private-channel decomposition invariant relies on that.

Bit-exactness: classification counts are exact.  Completion/stall times
agree with the reference scan up to f32 rounding (the closed-form
chains compute `s + W` where the scan repeatedly adds `busy`), which is
why the differential suite pins counts exactly and times to a tight
relative tolerance — and bit-for-bit when `busy` is exactly
representable.

Engines:
  "xla"       this scan driver: hoisted precompute + a `lax.scan` over
              chunks, tuned for XLA's strengths (take_along_axis
              gathers, log-step shift-reduce prefixes, no sorts or
              scatters).  Batch-native: leading batch dims (design
              grids, op batches) flow through the same ops, so a sweep
              replays a whole (designs, ops) stream batch in one scan.
              Default engine.
  "pallas"    the fused trace-replay megakernel
              (`kernels.replay.megakernel`): one `pallas_call`, streams
              flattened along the grid, the per-stream chunk loop and
              all architectural state resident in VMEM/registers, the
              chunk math expressed as masked one-hot contractions
              (`kernels.replay.chunkmath`).  Batch-native from day one.
              Off-TPU the compiled kernel is unavailable and dispatch
              *resolves* (never silently — see
              `resolve_engine_runtime`, whose label callers record in
              result metadata) to interpret mode (`interpret=True`: the
              literal kernel body on CPU, used by the differential
              suite) or to this module's XLA driver ("pallas:twin").
  "reference" the original per-request scan
              (`core.dram._reference_scan`), kept for differential
              testing and as the semantics oracle (1-D streams).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .accelerator import DramConfig
from .dram import row_buffer_latency

ENGINES = ("xla", "pallas", "reference")
# The chunked scan driver stays the default engine; "pallas" resolves to
# the megakernel on TPU (and to this driver off-TPU — recorded, never
# silent).  Set to "reference" to restore the legacy per-request scan.
DEFAULT_ENGINE = "xla"
DEFAULT_CHUNK = 64
# Fixed-point stopping threshold (cycles): a pass that moves no completion
# by more than this ends the iteration.  tol=0.0 = exact fixed point.
DEFAULT_TOL = 0.25
_SUB = 16                     # subblock size for the prev-bank summaries


def resolve_engine(engine: Optional[str]) -> str:
    eng = DEFAULT_ENGINE if engine is None else engine
    if eng not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    return eng


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_engine_runtime(engine: Optional[str],
                           interpret: Optional[bool] = None) -> str:
    """The engine that will actually execute on this backend.

    "pallas" is a *request*; what runs depends on the runtime:
      - on TPU: the compiled megakernel        -> "pallas"
      - off-TPU, interpret=True: the literal kernel body under the
        Pallas interpreter (slow; the differential suite uses this to
        execute the megakernel on CPU)          -> "pallas:interpret"
      - off-TPU otherwise: this module's XLA scan driver
                                               -> "pallas:twin"
    The label is recorded in `NetworkReport.engine` / Study frames so a
    fallback is never silent.  "xla" and "reference" resolve to
    themselves.
    """
    eng = resolve_engine(engine)
    if eng != "pallas":
        return eng
    if interpret is True:
        return "pallas:interpret" if _default_interpret() else "pallas"
    if _default_interpret():
        return "pallas:twin"
    return "pallas"


def _shifted(x: jnp.ndarray, k: int, fill) -> jnp.ndarray:
    """x shifted right by k along the last axis, filled with `fill`."""
    pad = [(0, 0)] * (x.ndim - 1) + [(k, 0)]
    return jnp.pad(x, pad, constant_values=fill)[..., :-k]


def _cummax(x: jnp.ndarray, *, exclusive: bool = False,
            fill=-jnp.inf) -> jnp.ndarray:
    """Running max along the last axis via log-step shift-reduce (fused
    pad/max chains instead of the generic associative-scan recursion)."""
    if exclusive:
        x = _shifted(x, 1, fill)
    n = x.shape[-1]
    k = 1
    while k < n:
        x = jnp.maximum(x, _shifted(x, k, fill))
        k *= 2
    return x


def _cumsum(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive running sum along the last axis (log-step doubling)."""
    n = x.shape[-1]
    fill = 0 if jnp.issubdtype(x.dtype, jnp.integer) else 0.0
    k = 1
    while k < n:
        x = x + _shifted(x, k, fill)
        k *= 2
    return x


def _rmax(x: jnp.ndarray) -> jnp.ndarray:
    """Max-reduce the last axis via an explicit halving tree.  XLA:CPU
    lowers plain row reductions to reduce-window, which benches ~2x
    slower than this form on the hot shapes; max is idempotent, so an
    odd length just overlaps the middle element."""
    n = x.shape[-1]
    while n > 1:
        h = (n + 1) // 2
        x = jnp.maximum(x[..., :h], x[..., n - h:n])
        n = h
    return x[..., 0]


def _take(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Batched gather along the last axis."""
    return jnp.take_along_axis(x, idx, axis=-1)


def _take_guard(x: jnp.ndarray, idx: jnp.ndarray, default) -> jnp.ndarray:
    """Gather along the last axis; idx < 0 yields `default`."""
    got = _take(x, jnp.maximum(idx, 0))
    return jnp.where(idx >= 0, got, default)


# --------------------------------------------------------------------------
# Order-only stream precompute (wide fused ops, outside the scan).
# Per-chunk inputs are (nc, ..., C): the leading chunk axis is just
# another batch dim for the in-chunk tables, and the axis the global
# classification prefixes over.
# --------------------------------------------------------------------------

def _precompute_stream(t, fb, ch, row, w, v, cid, row_flat, v_flat, *,
                       cfg: DramConfig, busy: float, n_cores: int,
                       n_qg: int):
    C = t.shape[-1]
    nc = t.shape[0]
    f32 = jnp.float32
    ch_n = cfg.channels
    n_banks = ch_n * cfg.banks_per_channel
    Qr, Qw = cfg.read_queue, cfg.write_queue
    i_idx = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), fb.shape)
    r_mask = v & ~w
    w_mask = v & w
    qg = ch if n_qg > 1 else jnp.zeros_like(fb)

    # ---- previous same-bank link, two exact levels ------------------------
    # near links (closer than a subblock) by shifted compares; the same
    # shifted masks also accumulate the near part of the bank-closure
    # prefix Vr (filled in after lat exists, via the saved masks)
    prev_near = jnp.full(fb.shape, -1, jnp.int32)
    near_hits = []
    for k in range(1, _SUB):
        hitk = (_shifted(fb, k, -1) == fb) & _shifted(v, k, False)
        near_hits.append(hitk)
        prev_near = jnp.maximum(prev_near,
                                jnp.where(hitk, i_idx - k, -1))
    # far: per-(bank, subblock) last occurrence, prefixed over subblocks
    nsub = -(-C // _SUB)
    pad_c = nsub * _SUB - C

    def _sb(x, fill, red):
        if pad_c:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad_c)],
                        constant_values=fill)
        x = x.reshape(x.shape[:-1] + (nsub, _SUB))
        return _rmax(x) if red is jnp.max else jnp.sum(x, axis=-1)

    bank_oh = (jnp.arange(n_banks)[:, None] == fb[..., None, :]) & \
        v[..., None, :]                                     # (nc,...,B,C)
    marked = jnp.where(bank_oh, i_idx[..., None, :], -1)
    last_sb = _sb(marked, -1, jnp.max)                      # (...,B,nsub)
    prev_sb = _cummax(last_sb, exclusive=True, fill=-1)
    last_b = _rmax(last_sb)                                 # (nc,...,B)
    sb_idx = i_idx // _SUB

    def _from_sb(tbl):
        """tbl (..., B, nsub) -> per-request value at (fb_i, subblock_i):
        one flat gather over the fused (bank, subblock) axis."""
        flat = tbl.reshape(tbl.shape[:-2] + (n_banks * nsub,))
        return _take(flat, fb * nsub + sb_idx)

    prev_far = _from_sb(prev_sb)
    prev_bank = jnp.maximum(prev_near, prev_far)
    intra = prev_bank >= 0

    # ---- global classification (no scan, no open-row carry) --------------
    # cross-chunk links: a bank's last request before this chunk is an
    # exclusive running max of its per-chunk last occurrence (as global
    # stream positions) over the chunk axis
    cidx = jnp.reshape(jnp.arange(nc, dtype=jnp.int32),
                       (nc,) + (1,) * (fb.ndim - 1))
    last_b_g = jnp.where(last_b >= 0, cidx * C + last_b, -1)

    def _shift_c(x, k):
        # shift down the leading chunk axis (log-step cummax building
        # block; lax.cummax lowers to slow reduce-window on CPU)
        padn = [(k, 0)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, padn, constant_values=-1)[:-k]

    before = _shift_c(last_b_g, 1)
    k = 1
    while k < nc:
        before = jnp.maximum(before, _shift_c(before, k))
        k *= 2
    cross = _take(before, fb)                               # (nc,...,C)
    gprev = jnp.where(intra, cidx * C + prev_bank, cross)
    gp = jnp.moveaxis(gprev, 0, -2).reshape(row_flat.shape)
    seen = jnp.where(gp >= 0, _take(row_flat, jnp.maximum(gp, 0)), -1)
    lat_flat, hit, empty = row_buffer_latency(cfg, seen, row_flat)
    hits = jnp.sum(hit & v_flat, axis=-1)
    misses = jnp.sum(empty & v_flat, axis=-1)
    conflicts = jnp.sum((~hit) & (~empty) & v_flat, axis=-1)
    batch = row_flat.shape[:-1]
    lat = jnp.moveaxis(
        lat_flat.astype(f32).reshape(batch + (nc, C)), -2, 0)
    lat_intra = jnp.where(intra, lat, 0.0)

    # bank-closure prefix Vr_i = sum of (lat + busy) over same-bank
    # intra-linked j <= i, with the same near/far split (offsets cancel
    # within a bank); first-per-bank requests carry no in-chunk edge
    w_bank = jnp.where(v & intra, lat_intra + busy, 0.0)
    v_near = w_bank
    sb_pos = i_idx % _SUB
    for k in range(1, _SUB):
        ok = near_hits[k - 1] & (sb_pos >= k)
        v_near = v_near + jnp.where(ok, _shifted(w_bank, k, 0.0), 0.0)
    wsb = _sb(jnp.where(bank_oh, w_bank[..., None, :], 0.0), 0.0, jnp.sum)
    Vfar_sb = _cumsum(wsb) - wsb                            # exclusive
    Vr = v_near + _from_sb(Vfar_sb)

    # channel segments (thin, stacked over the few channels): weighted
    # edge prefixes fold the lat of contiguous same-bank runs into the
    # channel chain
    chan_oh = (jnp.arange(ch_n)[:, None] == ch[..., None, :]) & \
        v[..., None, :]                                     # (...,ch_n,C)
    pin = _cummax(jnp.where(chan_oh, i_idx[..., None, :], -1),
                  exclusive=True, fill=-1)
    fb_pin = _take(fb, jnp.maximum(pin, 0).reshape(
        pin.shape[:-2] + (ch_n * C,))).reshape(pin.shape)
    linked = chan_oh & (pin >= 0) & (fb_pin == fb[..., None, :])
    we = jnp.where(chan_oh,
                   busy + jnp.where(linked, lat_intra[..., None, :], 0.0),
                   0.0)
    chan_W = _cumsum(we)                                    # (...,ch_n,C)
    chan_last = _rmax(jnp.where(chan_oh, i_idx[..., None, :], -1))
    flatW = chan_W.reshape(chan_W.shape[:-2] + (ch_n * C,))
    W_all = _take(flatW, ch * C + i_idx)

    # Bank links whose channel path already outweighs their lat can never
    # dominate (completions grow by >= W_i - W_p along the path): prune
    # them from the iterated gather.  Exact — only provably-dominated
    # max() terms go; what survives feeds the next pass's channel
    # closure so bank-raised completions propagate into channel chains.
    W_prev = jnp.where(intra, _take(W_all, jnp.maximum(prev_bank, 0)), 0.0)
    prev_link = jnp.where(intra & (lat_intra + busy > W_all - W_prev),
                          prev_bank, -1)

    # ---- in-flight-window direction indices per queue group ---------------
    rdx = jnp.zeros_like(fb)
    wdx = jnp.zeros_like(fb)
    nr, nw = [], []
    for g in range(n_qg):
        rm = r_mask & (qg == g)
        d = _cumsum(rm.astype(jnp.int32)) - rm
        rdx = jnp.where(rm, d, rdx)
        nr.append(jnp.sum(rm, axis=-1))
        wm = w_mask & (qg == g)
        d = _cumsum(wm.astype(jnp.int32)) - wm
        wdx = jnp.where(wm, d, wdx)
        nw.append(jnp.sum(wm, axis=-1))
    nr = jnp.stack(nr, axis=-1)                             # (..., n_qg)
    nw = jnp.stack(nw, axis=-1)

    # intra-chunk queue-head sources exist only when a queue is shorter
    # than the chunk (src = request of the read/write Q back)
    src = jnp.full(fb.shape, -1, jnp.int32)
    if Qr < C or Qw < C:
        same_g = qg[..., None, :] == qg[..., :, None]
        eq_r = (rdx[..., None, :] == (rdx[..., :, None] - Qr)) & \
            r_mask[..., None, :] & r_mask[..., :, None] & same_g
        eq_w = (wdx[..., None, :] == (wdx[..., :, None] - Qw)) & \
            w_mask[..., None, :] & w_mask[..., :, None] & same_g
        eq = jnp.where(w[..., :, None], eq_w, eq_r)
        src = _rmax(jnp.where(eq, i_idx[..., None, :], -1))

    # ring survivors: for residue s0 = d %% Q, the surviving writer is the
    # request with the largest direction index d >= n_dir - Q (if any);
    # the slot it lands in is (s0 + idx0) %% Q — a rotation applied at
    # scan time with the carried queue counter.
    def survivors(mask, dix, ndir, Q):
        if Q >= C:
            # every chunk request survives (dix < C <= Q) and residues
            # are the direction indices themselves, which are monotone
            # over the masked subsequence — so the map residue -> source
            # is a searchsorted over the mask's running count, done as a
            # branchless binary search (log C thin gathers; never
            # materializes the (C, C) equality map)
            cs = _cumsum(mask.astype(jnp.int32))
            q = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32),
                                 mask.shape)
            pos = jnp.zeros_like(q)     # running #{i : cs_i <= q}
            step = 1
            while step < C:
                step *= 2
            step //= 2
            while step >= 1:
                nxt = pos + step
                val = _take(cs, jnp.minimum(nxt, C) - 1)
                pos = jnp.where((nxt <= C) & (val <= q), nxt, pos)
                step //= 2
            got = jnp.where(cs[..., -1:] > q, pos, -1)
            padq = [(0, 0)] * (got.ndim - 1) + [(0, Q - C)]
            return jnp.pad(got, padq, constant_values=-1)
        surv = mask & (dix + Q >= _take(ndir, qg))
        oh = (jnp.arange(Q)[:, None] == (dix % Q)[..., None, :]) & \
            surv[..., None, :]                              # (..., Q, C)
        return _rmax(jnp.where(oh, i_idx[..., None, :], -1))

    ring_src_r = jnp.stack(
        [survivors(r_mask & (qg == g), rdx, nr, Qr)
         for g in range(n_qg)], axis=-2)                    # (..., n_qg, Q)
    ring_src_w = jnp.stack(
        [survivors(w_mask & (qg == g), wdx, nw, Qw)
         for g in range(n_qg)], axis=-2)

    core_mask = jnp.stack([v & (cid == s) for s in range(n_cores)],
                          axis=-2)                          # (..., cores, C)
    pre = dict(
        lat=lat, prev_link=prev_link, Vr=Vr, chan_oh=chan_oh,
        chan_W=chan_W, chan_last=chan_last, last_b=last_b, qg=qg,
        rdx=rdx, wdx=wdx, src=src, nr=nr, nw=nw, ring_src_r=ring_src_r,
        ring_src_w=ring_src_w, core_mask=core_mask)
    return pre, hits, misses, conflicts


# --------------------------------------------------------------------------
# One chunk: carry-dependent resolve (runs inside the scan; batch-native)
# --------------------------------------------------------------------------

def _chunk_step(carry, x, *, cfg: DramConfig, busy: float,
                max_passes: Optional[int], tol: float, n_cores: int,
                n_qg: int):
    from ..kernels.replay.chunkmath import iterate_fixed_point

    (bank_free, bus_free, ring_r, ring_w, ir, iw, shift) = carry
    t, fb, w, v, cid, pre = x
    C = t.shape[-1]
    Qr, Qw = cfg.read_queue, cfg.write_queue
    f32 = jnp.float32
    neg = f32(-jnp.inf)
    i_idx = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), fb.shape)

    lat = pre["lat"]
    qg = pre["qg"]
    ir_g = ir[..., 0:1] if n_qg == 1 else _take(ir, qg)
    iw_g = iw[..., 0:1] if n_qg == 1 else _take(iw, qg)
    sl_r = (pre["rdx"] + ir_g) % Qr
    sl_w = (pre["wdx"] + iw_g) % Qw
    flat_rr = ring_r.reshape(ring_r.shape[:-2] + (n_qg * Qr,))
    flat_rw = ring_w.reshape(ring_w.shape[:-2] + (n_qg * Qw,))
    head0 = jnp.where(w, _take(flat_rw, qg * Qw + sl_w),
                      _take(flat_rr, qg * Qr + sl_r))
    head_src = pre["src"]
    prev_link = pre["prev_link"]
    Vr = pre["Vr"]
    chan_oh, chan_W = pre["chan_oh"], pre["chan_W"]
    core_mask = pre["core_mask"]
    bank0 = _take(bank_free, fb)
    shift0 = shift[..., 0:1] if n_cores == 1 else _take(shift, cid)
    bus_W = bus_free[..., None] + chan_W
    # bank-closure mask: order-only, rebuilt per step (cheap broadcast
    # compares; materializing it in the hoisted precompute would stream
    # (chunks, C, C) tensors through memory instead)
    jlt = jnp.arange(C, dtype=jnp.int32)
    mbank = (fb[..., None, :] == fb[..., :, None]) & v[..., None, :] & \
        (jlt[None, :] <= jlt[:, None])
    intra_heads = Qr < C or Qw < C

    def _issue_ok(done):
        # queue backpressure: heads (and hence shift and issue gates)
        # depend on `done` only when a queue is shorter than the chunk —
        # on realistic configs this whole block is pass-invariant and
        # hoists out of the fixed-point iteration
        if intra_heads:
            head = jnp.maximum(head0, _take_guard(done, head_src, neg))
        else:
            head = head0
        g = jnp.where(v, head - t, neg)
        if n_cores == 1:
            ss = jnp.maximum(shift0,
                             _cummax(g, exclusive=True))
        else:
            gs = jnp.where(core_mask, g[..., None, :], neg)
            ss_c = jnp.maximum(shift[..., None],
                               _cummax(gs, exclusive=True))
            ss = _take(ss_c.reshape(ss_c.shape[:-2] + (n_cores * C,)),
                       cid * C + i_idx)
        return jnp.maximum(t + ss, head), g

    if not intra_heads:
        issue_ok0, g0 = _issue_ok(None)

    def one_pass(done):
        if intra_heads:
            issue_ok, _ = _issue_ok(done)
        else:
            issue_ok = issue_ok0
        bankp = jnp.maximum(bank0, _take_guard(done, prev_link, neg))
        # seed the closures with the previous iterate: completions grow
        # by at least the channel edge weights, so done_j + (W_i - W_j)
        # is a true lower bound — this is how bank-raised completions of
        # *other* banks propagate down the channel chain across passes
        s_src = jnp.maximum(jnp.maximum(issue_ok, bankp) + lat + busy,
                            done)
        # channel closure: weighted max-plus prefix, stacked over the
        # few channels (thin log-step scans; un-stacked by a masked sum
        # over the short channel axis — cheaper than a gather)
        gg = jnp.where(chan_oh, s_src[..., None, :] - chan_W, neg)
        u_c = jnp.maximum(_cummax(gg) + chan_W, bus_W)
        u = jnp.sum(jnp.where(chan_oh, u_c, 0.0), axis=-2)
        # bank closure: one masked (C, C) row reduction (banks are many,
        # so the matrix contraction beats a per-bank stacked scan)
        d = _rmax(jnp.where(mbank, jnp.where(v, u - Vr, neg)[
            ..., None, :], neg)) + Vr
        return jnp.where(v, d, 0.0)

    done = iterate_fixed_point(
        one_pass, jnp.zeros(t.shape, f32),
        cap=(C + 2) if max_passes is None else max_passes,
        tol=tol, use_cond=True)

    # ---- final derived state + carry update (gathers only) ---------------
    if intra_heads:
        _, g = _issue_ok(done)
    else:
        g = g0
    shift = jnp.maximum(
        shift, _rmax(jnp.where(core_mask, g[..., None, :], neg)))

    lb = pre["last_b"]
    bank_free = jnp.where(lb >= 0, _take(done, jnp.maximum(lb, 0)),
                          bank_free)

    lc = pre["chan_last"]
    bus_free = jnp.where(lc >= 0, _take(done, jnp.maximum(lc, 0)),
                         bus_free)

    # rings: rotate the carry-free survivor map by the carried counter
    def ring_update(ring, ring_src, idx0, Q):
        s0 = (jnp.arange(Q) - idx0[..., None]) % Q          # (..., n_qg, Q)
        srcs = jnp.take_along_axis(ring_src, s0, axis=-1)
        flat = srcs.reshape(srcs.shape[:-2] + (n_qg * Q,))
        got = _take_guard(done, flat, 0.0).reshape(srcs.shape)
        return jnp.where(srcs >= 0, got, ring)

    ring_r = ring_update(ring_r, pre["ring_src_r"], ir, Qr)
    ring_w = ring_update(ring_w, pre["ring_src_w"], iw, Qw)
    ir = ir + pre["nr"]
    iw = iw + pre["nw"]

    new_carry = (bank_free, bus_free, ring_r, ring_w, ir, iw, shift)
    return new_carry, (done, jnp.where(v, done - t, 0.0))


# --------------------------------------------------------------------------
# Stream-level driver: hoisted precompute + scan over chunks
# --------------------------------------------------------------------------

def replay_decoded(t_issue, flat_bank, ch, row, is_write, valid,
                   cfg: DramConfig, gran_bytes: int = 64, *,
                   engine: str = "xla", chunk: Optional[int] = None,
                   max_passes: Optional[int] = None,
                   tol: float = DEFAULT_TOL, n_cores: int = 1,
                   core_id=None, per_channel_queues: bool = False,
                   interpret: Optional[bool] = None):
    """Chunked replay of a pre-decoded request stream.

    Batch-native under every chunked engine: inputs may carry leading
    batch dimensions (`(..., n)`) and the replay processes the whole
    batch in one chunk scan ("xla") or one fused kernel launch
    ("pallas") — this is how `Simulator.sweep` replays a (designs, ops)
    stream batch without a vmap wrapper.  Pure traced function (safe
    under jit/vmap; `cfg`, `gran_bytes` and the keyword knobs must be
    static in a jitted caller).  Returns a dict with the raw
    per-request completion times `done` (undefined where ~valid —
    callers substitute their engine's no-op value), per-request
    round-trip `latency`, the per-core backpressure `shift` (shape
    (..., n_cores)), and the exact row hit/empty/conflict counters.

    per_channel_queues selects the shared-DRAM semantics (per-channel
    in-flight rings, per-core shift) of `simulate_shared_dram`; the
    default matches `simulate_dram`'s single global ring pair.  tol is
    the fixed-point stopping threshold in cycles (0.0 = iterate to the
    exact fixed point); max_passes caps the per-chunk pass count under
    both chunked engines (None = chunk + 2, enough for any stream).

    engine="pallas" dispatches per `resolve_engine_runtime`: the fused
    megakernel on TPU (or, with interpret=True, the literal kernel body
    under the Pallas interpreter), this driver otherwise.
    """
    n = t_issue.shape[-1]
    batch = t_issue.shape[:-1]
    C = DEFAULT_CHUNK if chunk is None else int(chunk)
    C = max(1, min(C, max(n, 1)))
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel
    Qr, Qw = cfg.read_queue, cfg.write_queue
    passes = None if max_passes is None else max(1, int(max_passes))
    n_qg = ch_n if per_channel_queues else 1
    busy = float(max(1.0, gran_bytes / cfg.bandwidth_bytes_per_cycle))
    f32 = jnp.float32

    if core_id is None:
        core_id = jnp.zeros(t_issue.shape, jnp.int32)

    if engine == "pallas":
        resolved = resolve_engine_runtime("pallas", interpret)
        if resolved != "pallas:twin":
            from ..kernels.replay.megakernel import replay_megakernel
            return replay_megakernel(
                t_issue, flat_bank, ch, row, is_write, valid, cfg,
                gran_bytes, chunk=C, max_passes=passes, tol=float(tol),
                n_cores=n_cores, core_id=core_id,
                per_channel_queues=per_channel_queues,
                interpret=(resolved == "pallas:interpret"))
        # fall through: the twin is this driver (same model, same
        # fixed-point contract; the megakernel's chunk math is
        # differentially pinned to it and to the reference oracle)

    pad = (-n) % C
    nc = (n + pad) // C

    def _flat(x, fill, dtype):
        x = jnp.broadcast_to(jnp.asarray(x).astype(dtype), batch + (n,))
        if pad:
            x = jnp.concatenate(
                [x, jnp.full(batch + (pad,), fill, dtype)], axis=-1)
        return x

    def _chunked(x):
        # (..., nc*C) -> (nc, ..., C): the chunk axis leads for the scan
        return jnp.moveaxis(x.reshape(batch + (nc, C)), -2, 0)

    rowf = _flat(row, 0, jnp.int32)
    vf = _flat(valid, False, bool)
    xs = tuple(_chunked(x) for x in (
        _flat(t_issue, 0.0, f32), _flat(flat_bank, 0, jnp.int32),
        _flat(ch, 0, jnp.int32), rowf,
        _flat(is_write, False, bool), vf,
        _flat(core_id, 0, jnp.int32)))

    pre, hits, misses, conflicts = _precompute_stream(
        *xs, rowf, vf, cfg=cfg, busy=busy, n_cores=n_cores, n_qg=n_qg)

    carry0 = (jnp.zeros(batch + (ch_n * bk_n,), f32),
              jnp.zeros(batch + (ch_n,), f32),
              jnp.zeros(batch + (n_qg, Qr), f32),
              jnp.zeros(batch + (n_qg, Qw), f32),
              jnp.zeros(batch + (n_qg,), jnp.int32),
              jnp.zeros(batch + (n_qg,), jnp.int32),
              jnp.zeros(batch + (n_cores,), f32))

    step = functools.partial(
        _chunk_step, cfg=cfg, busy=busy, max_passes=passes,
        tol=float(tol), n_cores=n_cores, n_qg=n_qg)
    carry, (done, rt) = jax.lax.scan(
        step, carry0, (xs[0], xs[1], xs[4], xs[5], xs[6], pre))

    def _unchunk(y):
        return jnp.moveaxis(y, 0, -2).reshape(batch + (nc * C,))[..., :n]

    return dict(done=_unchunk(done), latency=_unchunk(rt),
                shift=carry[6], hits=hits, misses=misses,
                conflicts=conflicts)
