"""Chunked bank-parallel DRAM replay (the trace-fidelity hot path).

`core.dram.simulate_dram` and `trace.contention.simulate_shared_dram`
originally replayed demand streams with a per-request `lax.scan` —
thousands of sequential steps, each a handful of dynamic `.at[fb]`
updates.  That serialization is what made trace-fidelity sweeps ~27x
slower than fast fidelity.  This module replays the same timing model in
fixed-size request chunks; inside a chunk everything is vectorized, and
the chunk scan carries only the true architectural state (per-bank
free/open-row, per-channel bus time, in-flight rings, queue counters,
per-core shift) so chunk boundaries are invisible.

The implementation is shaped by what a backend executes efficiently:
fused elementwise chains, `take_along_axis` gathers, and log-step
shift-reduce prefixes.  There are no sorts and no scatters on the hot
path, and every function is *batch-native* — leading batch dimensions
(design grids, op batches) flow through the same ops instead of a vmap
wrapper, so a sweep replays a whole (designs, ops) stream batch in one
scan.

  order-only precompute (exact, hoisted out of the chunk scan)
    Row-buffer state is "last writer wins" per bank, so each request's
    open-row comparison depends only on *stream order*.  The previous
    same-bank link is built in two exact levels: shifted compares find
    links closer than a subblock, and a per-(bank, subblock)
    last-occurrence summary (one masked reduce + a tiny prefix over
    subblocks) finds the rest — no (banks x chunk) prefix scans on the
    wide path.  Classification (hit / empty / conflict) follows from
    the links and is bit-identical to the reference scan by
    construction.  Queue-slot indices, ring survivors (request d is the
    last writer of slot (d + idx0) %% Q iff no later d' = d + kQ in the
    chunk), weighted channel prefixes and per-bank/per-channel last
    requests are likewise order-only and computed for the whole stream
    in wide fused ops *before* the scan.

  chunk resolve (two exact closures + fixed point)
    Completion times obey
        done_i = max(max(issue_ok_i, bankdone_prev(i)) + lat_i,
                     done_prev_on_channel) + busy
    Per pass, the channel chain D_m = max(s_m, D_{m-1} + w_m) is closed
    exactly as a weighted max-plus prefix (D = W + cummax(s - W),
    W = cumsum(w), with the row-buffer lat of contiguous same-bank runs
    folded into the channel edge — a bank maps to exactly one channel,
    so bank chains live inside a channel's subsequence), and same-bank
    chains are closed by one masked (chunk, chunk) row reduction over
    the per-bank weighted prefix.  Queue backpressure `shift` is a
    per-core running max of (queue_head - t).  Each pass seeds the
    closures with the previous iterate (so bank-raised completions of
    other banks propagate down the channel chain), plus a pruned
    same-bank gather (links whose channel path already outweighs their
    lat are provably dominated and dropped) and intra-chunk queue
    heads when a queue is shorter than the chunk.  The operator is
    monotone from below and each pass finalizes at least the first
    not-yet-exact request, so its least fixed point *is* the serial
    result.  Three passes are statically unrolled (realistic streams
    converge within them); if the third pass still moved a completion
    by more than `tol` cycles (default 0.25) a lax.cond escapes into a
    while_loop capped at chunk + 2 passes, so adversarial streams
    still reach the fixed point.

Bit-exactness: classification counts are exact.  Completion/stall times
agree with the reference scan up to f32 rounding (the closed-form
chains compute `s + W` where the scan repeatedly adds `busy`), which is
why the differential suite pins counts exactly and times to a tight
relative tolerance — and bit-for-bit when `busy` is exactly
representable.

Engines:
  "xla"       chunked replay, segmented closures (default; batch-native)
  "pallas"    same chunking, but the inner resolve runs as a Pallas
              kernel: the gathers/segment scans become masked (C, C)
              row-max contractions over VMEM-resident matrices
              (interpret-mode fallback off-TPU; 1-D streams — vmap for
              batches)
  "reference" the original per-request scan, kept for differential
              testing and as the semantics oracle (1-D streams)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .accelerator import DramConfig
from .dram import row_buffer_latency

ENGINES = ("xla", "pallas", "reference")
# The one-line default switch (ISSUE 3): the chunked engine is the default
# now that tests/test_replay.py's differential suite passes against the
# reference scan.  Set to "reference" to restore the legacy per-request scan.
DEFAULT_ENGINE = "xla"
DEFAULT_CHUNK = 64
# Fixed-point stopping threshold (cycles): a pass that moves no completion
# by more than this ends the iteration.  tol=0.0 = exact fixed point.
DEFAULT_TOL = 0.25
_SUB = 16                     # subblock size for the prev-bank summaries


def resolve_engine(engine: Optional[str]) -> str:
    eng = DEFAULT_ENGINE if engine is None else engine
    if eng not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    return eng


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _shifted(x: jnp.ndarray, k: int, fill) -> jnp.ndarray:
    """x shifted right by k along the last axis, filled with `fill`."""
    pad = [(0, 0)] * (x.ndim - 1) + [(k, 0)]
    return jnp.pad(x, pad, constant_values=fill)[..., :-k]


def _cummax(x: jnp.ndarray, *, exclusive: bool = False,
            fill=-jnp.inf) -> jnp.ndarray:
    """Running max along the last axis via log-step shift-reduce (fused
    pad/max chains instead of the generic associative-scan recursion)."""
    if exclusive:
        x = _shifted(x, 1, fill)
    n = x.shape[-1]
    k = 1
    while k < n:
        x = jnp.maximum(x, _shifted(x, k, fill))
        k *= 2
    return x


def _cumsum(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive running sum along the last axis (log-step doubling)."""
    n = x.shape[-1]
    fill = 0 if jnp.issubdtype(x.dtype, jnp.integer) else 0.0
    k = 1
    while k < n:
        x = x + _shifted(x, k, fill)
        k *= 2
    return x


def _take(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Batched gather along the last axis."""
    return jnp.take_along_axis(x, idx, axis=-1)


def _take_guard(x: jnp.ndarray, idx: jnp.ndarray, default) -> jnp.ndarray:
    """Gather along the last axis; idx < 0 yields `default`."""
    got = _take(x, jnp.maximum(idx, 0))
    return jnp.where(idx >= 0, got, default)


# --------------------------------------------------------------------------
# Pallas inner resolve: the closures as masked (C, C) row-max contractions
# in VMEM (bank-grouped gather + segmented scans as matrices).
# --------------------------------------------------------------------------

def _fixed_point_kernel(t_ref, lat_ref, head0_ref, bank0_ref, bus0_ref,
                        shift0_ref, w_ref, v_ref, ghead_ref, gprev_ref,
                        mbank_ref, mshift_ref, mchan_ref, done_ref, *,
                        busy: float, max_passes: int, tol: float):
    t = t_ref[...]
    lat = lat_ref[...]
    head0 = head0_ref[...]
    bank0 = bank0_ref[...]
    bus0 = bus0_ref[...]
    shift0 = shift0_ref[...]
    w = w_ref[...]                  # per-request channel edge weight
    v = v_ref[...]
    ghead = ghead_ref[...]          # one-hot: intra-chunk queue-head source
    gprev = gprev_ref[...]          # one-hot: unpruned previous same-bank
    mbank = mbank_ref[...]          # incl-lower & same-bank & valid
    mshift = mshift_ref[...]        # strict-lower & same-core & valid
    mchan = mchan_ref[...]          # incl-lower & same-channel & valid
    neg = jnp.float32(-jnp.inf)
    # segmented prefixes as masked row contractions
    W = jnp.sum(jnp.where(mchan, w[None, :], 0.0), axis=1)
    V = jnp.sum(jnp.where(mbank, lat[None, :] + busy, 0.0), axis=1)

    def rowmax(mask, x):
        return jnp.max(jnp.where(mask, x[None, :], neg), axis=1)

    def one_pass(done):
        head = jnp.maximum(head0, rowmax(ghead, done))
        g = jnp.where(v, head - t, neg)
        ss = jnp.maximum(shift0, rowmax(mshift, g))
        issue_ok = jnp.maximum(t + ss, head)
        bankp = jnp.maximum(bank0, rowmax(gprev, done))
        # seed with the previous iterate so cross-bank raises propagate
        # down the channel chain (see the xla one_pass)
        s = jnp.maximum(jnp.maximum(issue_ok, bankp) + lat + busy, done)
        # channel closure
        u = jnp.maximum(rowmax(mchan, jnp.where(v, s - W, neg)) + W,
                        bus0 + W)
        # bank closure
        d = rowmax(mbank, jnp.where(v, u - V, neg)) + V
        return jnp.where(v, d, 0.0)

    d0 = one_pass(jnp.zeros_like(t))
    d1 = one_pass(d0)

    def cond(s):
        return jnp.logical_and(s[2] < max_passes,
                               jnp.any(s[1] - s[0] > tol))

    def body(s):
        return (s[1], one_pass(s[1]), s[2] + 1)

    _, done, _ = jax.lax.while_loop(cond, body, (d0, d1, jnp.int32(2)))
    done_ref[...] = done


def _pallas_fixed_point(t, lat, head0, bank0, bus0, shift0, w, v, ghead,
                        gprev, mbank, mshift, mchan, *, busy: float,
                        max_passes: int, tol: float,
                        interpret: Optional[bool]):
    interpret = _default_interpret() if interpret is None else interpret
    C = t.shape[0]
    return pl.pallas_call(
        functools.partial(_fixed_point_kernel, busy=busy,
                          max_passes=max_passes, tol=tol),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(t, lat.astype(jnp.float32), head0, bank0, bus0, shift0, w, v,
      ghead, gprev, mbank, mshift, mchan)


# --------------------------------------------------------------------------
# Order-only stream precompute (wide fused ops, outside the scan).  All
# inputs are (..., C) with arbitrary leading batch dims (the chunk axis
# is just another batch dim here).
# --------------------------------------------------------------------------

def _precompute_chunk(t, fb, ch, row, w, v, cid, *, cfg: DramConfig,
                      busy: float, n_cores: int, n_qg: int):
    C = t.shape[-1]
    f32 = jnp.float32
    ch_n = cfg.channels
    n_banks = ch_n * cfg.banks_per_channel
    Qr, Qw = cfg.read_queue, cfg.write_queue
    i_idx = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), fb.shape)
    neg = f32(-jnp.inf)
    r_mask = v & ~w
    w_mask = v & w
    qg = ch if n_qg > 1 else jnp.zeros_like(fb)

    # ---- previous same-bank link, two exact levels ------------------------
    # near links (closer than a subblock) by shifted compares; the same
    # shifted masks also accumulate the near part of the bank-closure
    # prefix Vr (filled in after lat_intra exists, via the saved masks)
    prev_near = jnp.full(fb.shape, -1, jnp.int32)
    near_hits = []
    for k in range(1, _SUB):
        hitk = (_shifted(fb, k, -1) == fb) & _shifted(v, k, False)
        near_hits.append(hitk)
        prev_near = jnp.maximum(prev_near,
                                jnp.where(hitk, i_idx - k, -1))
    # far: per-(bank, subblock) last occurrence, prefixed over subblocks
    nsub = -(-C // _SUB)
    pad_c = nsub * _SUB - C

    def _sb(x, fill, red):
        if pad_c:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad_c)],
                        constant_values=fill)
        return red(x.reshape(x.shape[:-1] + (nsub, _SUB)), axis=-1)

    bank_oh = (jnp.arange(n_banks)[:, None] == fb[..., None, :]) & \
        v[..., None, :]                                     # (..., B, C)
    marked = jnp.where(bank_oh, i_idx[..., None, :], -1)
    last_sb = _sb(marked, -1, jnp.max)                      # (..., B, nsub)
    prev_sb = _cummax(last_sb, exclusive=True, fill=-1)
    last_b = jnp.max(last_sb, axis=-1)                      # (..., B)
    sb_idx = i_idx // _SUB

    def _from_sb(tbl):
        """tbl (..., B, nsub) -> per-request value at (fb_i, subblock_i):
        gather each request's bank row, then its subblock column."""
        rows = jnp.take_along_axis(
            tbl, jnp.broadcast_to(fb[..., :, None],
                                  fb.shape + (tbl.shape[-1],)), axis=-2)
        return jnp.take_along_axis(rows, sb_idx[..., None],
                                   axis=-1)[..., 0]

    prev_far = _from_sb(prev_sb)
    prev_bank = jnp.maximum(prev_near, prev_far)

    intra = prev_bank >= 0
    row_prev = _take(row, jnp.maximum(prev_bank, 0))
    # lat of intra-linked requests is order-only (first-per-bank requests
    # read the carried open row instead — classified inside the scan)
    lat_intra, _, _ = row_buffer_latency(cfg, row_prev, row)
    lat_intra = jnp.where(intra, lat_intra, 0).astype(f32)

    # bank-closure prefix Vr_i = sum of (lat + busy) over same-bank j <= i,
    # with the same near/far split (offsets cancel within a bank)
    w_bank = jnp.where(v & intra, lat_intra + busy, 0.0)
    v_near = w_bank
    sb_pos = i_idx % _SUB
    for k in range(1, _SUB):
        ok = near_hits[k - 1] & (sb_pos >= k)
        v_near = v_near + jnp.where(ok, _shifted(w_bank, k, 0.0), 0.0)
    wsb = _sb(jnp.where(bank_oh, w_bank[..., None, :], 0.0), 0.0, jnp.sum)
    Vfar_sb = _cumsum(wsb) - wsb                            # exclusive
    Vr = v_near + _from_sb(Vfar_sb)

    # channel segments (thin, stacked over the few channels): weighted
    # edge prefixes fold the lat of contiguous same-bank runs into the
    # channel chain
    chan_oh = (jnp.arange(ch_n)[:, None] == ch[..., None, :]) & \
        v[..., None, :]                                     # (..., ch_n, C)
    pin = _cummax(jnp.where(chan_oh, i_idx[..., None, :], -1),
                  exclusive=True, fill=-1)
    fb_pin = _take(fb, jnp.maximum(pin, 0).reshape(
        pin.shape[:-2] + (ch_n * C,))).reshape(pin.shape)
    linked = chan_oh & (pin >= 0) & (fb_pin == fb[..., None, :])
    we = jnp.where(chan_oh,
                   busy + jnp.where(linked, lat_intra[..., None, :], 0.0),
                   0.0)
    chan_W = _cumsum(we)                                    # (..., ch_n, C)
    chan_last = jnp.max(jnp.where(chan_oh, i_idx[..., None, :], -1),
                        axis=-1)                            # (..., ch_n)
    flatW = chan_W.reshape(chan_W.shape[:-2] + (ch_n * C,))
    W_all = _take(flatW, ch * C + i_idx)
    we_req = _take(we.reshape(we.shape[:-2] + (ch_n * C,)),
                   ch * C + i_idx)

    # Bank links whose channel path already outweighs their lat can never
    # dominate (completions grow by >= W_i - W_p along the path): prune
    # them from the iterated gather.  Exact — only provably-dominated
    # max() terms go; what survives feeds the next pass's channel
    # closure so bank-raised completions propagate into channel chains.
    W_prev = jnp.where(intra, _take(W_all, jnp.maximum(prev_bank, 0)), 0.0)
    prev_link = jnp.where(intra & (lat_intra + busy > W_all - W_prev),
                          prev_bank, -1)

    # ---- in-flight-window direction indices per queue group ---------------
    rdx = jnp.zeros_like(fb)
    wdx = jnp.zeros_like(fb)
    nr, nw = [], []
    for g in range(n_qg):
        rm = r_mask & (qg == g)
        d = _cumsum(rm.astype(jnp.int32)) - rm
        rdx = jnp.where(rm, d, rdx)
        nr.append(jnp.sum(rm, axis=-1))
        wm = w_mask & (qg == g)
        d = _cumsum(wm.astype(jnp.int32)) - wm
        wdx = jnp.where(wm, d, wdx)
        nw.append(jnp.sum(wm, axis=-1))
    nr = jnp.stack(nr, axis=-1)                             # (..., n_qg)
    nw = jnp.stack(nw, axis=-1)

    # intra-chunk queue-head sources exist only when a queue is shorter
    # than the chunk (src = request of the read/write Q back)
    src = jnp.full(fb.shape, -1, jnp.int32)
    if Qr < C or Qw < C:
        same_g = qg[..., None, :] == qg[..., :, None]
        eq_r = (rdx[..., None, :] == (rdx[..., :, None] - Qr)) & \
            r_mask[..., None, :] & r_mask[..., :, None] & same_g
        eq_w = (wdx[..., None, :] == (wdx[..., :, None] - Qw)) & \
            w_mask[..., None, :] & w_mask[..., :, None] & same_g
        eq = jnp.where(w[..., :, None], eq_w, eq_r)
        src = jnp.max(jnp.where(eq, i_idx[..., None, :], -1), axis=-1)

    # ring survivors: for residue s0 = d %% Q, the surviving writer is the
    # request with the largest direction index d >= n_dir - Q (if any);
    # the slot it lands in is (s0 + idx0) %% Q — a rotation applied at
    # scan time with the carried queue counter.
    def survivors(mask, dix, ndir, Q):
        if Q >= C:
            # every chunk request survives (dix < C <= Q) and residues
            # are the direction indices themselves: a (C, C) equality
            # map padded to Q slots, no occupancy test needed
            oh = (jnp.arange(C)[:, None] == dix[..., None, :]) & \
                mask[..., None, :]                          # (..., C, C)
            got = jnp.max(jnp.where(oh, i_idx[..., None, :], -1), axis=-1)
            padq = [(0, 0)] * (got.ndim - 1) + [(0, Q - C)]
            return jnp.pad(got, padq, constant_values=-1)
        surv = mask & (dix + Q >= _take(ndir, qg))
        oh = (jnp.arange(Q)[:, None] == (dix % Q)[..., None, :]) & \
            surv[..., None, :]                              # (..., Q, C)
        return jnp.max(jnp.where(oh, i_idx[..., None, :], -1), axis=-1)

    ring_src_r = jnp.stack(
        [survivors(r_mask & (qg == g), rdx, nr, Qr)
         for g in range(n_qg)], axis=-2)                    # (..., n_qg, Q)
    ring_src_w = jnp.stack(
        [survivors(w_mask & (qg == g), wdx, nw, Qw)
         for g in range(n_qg)], axis=-2)

    core_mask = jnp.stack([v & (cid == s) for s in range(n_cores)],
                          axis=-2)                          # (..., cores, C)
    return dict(
        intra=intra, row_prev=row_prev, prev_link=prev_link,
        Vr=Vr, we=we_req, chan_oh=chan_oh, chan_W=chan_W,
        last_b=last_b, chan_last=chan_last,
        qg=qg, rdx=rdx, wdx=wdx, src=src, nr=nr, nw=nw,
        ring_src_r=ring_src_r, ring_src_w=ring_src_w,
        core_mask=core_mask)


# --------------------------------------------------------------------------
# One chunk: carry-dependent resolve (runs inside the scan; batch-native)
# --------------------------------------------------------------------------

def _chunk_step(carry, x, *, cfg: DramConfig, busy: float, engine: str,
                max_passes: int, tol: float, n_cores: int, n_qg: int,
                interpret: Optional[bool]):
    (bank_free, open_row, bus_free, ring_r, ring_w, ir, iw, shift,
     hits, misses, conflicts) = carry
    t, fb, ch, row, w, v, cid, pre = x
    C = t.shape[-1]
    ch_n = cfg.channels
    Qr, Qw = cfg.read_queue, cfg.write_queue
    f32 = jnp.float32
    neg = f32(-jnp.inf)
    i_idx = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), fb.shape)

    # classification: intra links are precomputed; only first-per-bank
    # requests consult the carried open row
    seen = jnp.where(pre["intra"], pre["row_prev"], _take(open_row, fb))
    lat, hit, empty = row_buffer_latency(cfg, seen, row)
    lat = lat.astype(f32)

    qg = pre["qg"]
    ir_g = ir[..., 0:1] if n_qg == 1 else _take(ir, qg)
    iw_g = iw[..., 0:1] if n_qg == 1 else _take(iw, qg)
    sl_r = (pre["rdx"] + ir_g) % Qr
    sl_w = (pre["wdx"] + iw_g) % Qw
    flat_rr = ring_r.reshape(ring_r.shape[:-2] + (n_qg * Qr,))
    flat_rw = ring_w.reshape(ring_w.shape[:-2] + (n_qg * Qw,))
    head0 = jnp.where(w, _take(flat_rw, qg * Qw + sl_w),
                      _take(flat_rr, qg * Qr + sl_r))
    head_src = pre["src"]
    prev_link = pre["prev_link"]
    Vr = pre["Vr"]
    chan_oh, chan_W = pre["chan_oh"], pre["chan_W"]
    core_mask = pre["core_mask"]
    bank0 = _take(bank_free, fb)
    shift0 = shift[..., 0:1] if n_cores == 1 else _take(shift, cid)
    bus_W = bus_free[..., None] + chan_W
    # bank-closure mask: order-only, rebuilt per step (cheap broadcast
    # compares; materializing it in the hoisted precompute would stream
    # (chunks, C, C) tensors through memory instead)
    jlt = jnp.arange(C, dtype=jnp.int32)
    mbank = (fb[..., None, :] == fb[..., :, None]) & v[..., None, :] & \
        (jlt[None, :] <= jlt[:, None])
    intra_heads = Qr < C or Qw < C

    def one_pass(done):
        if intra_heads:
            head = jnp.maximum(head0, _take_guard(done, head_src, neg))
        else:
            head = head0
        g = jnp.where(v, head - t, neg)
        if n_cores == 1:
            ss = jnp.maximum(shift0,
                             _cummax(jnp.where(v, g, neg), exclusive=True))
        else:
            gs = jnp.where(core_mask, g[..., None, :], neg)
            ss_c = jnp.maximum(shift[..., None],
                               _cummax(gs, exclusive=True))
            ss = _take(ss_c.reshape(ss_c.shape[:-2] + (n_cores * C,)),
                       cid * C + i_idx)
        issue_ok = jnp.maximum(t + ss, head)
        bankp = jnp.maximum(bank0, _take_guard(done, prev_link, neg))
        # seed the closures with the previous iterate: completions grow
        # by at least the channel edge weights, so done_j + (W_i - W_j)
        # is a true lower bound — this is how bank-raised completions of
        # *other* banks propagate down the channel chain across passes
        s_src = jnp.maximum(jnp.maximum(issue_ok, bankp) + lat + busy,
                            done)
        # channel closure: weighted max-plus prefix, stacked over the
        # few channels (thin log-step scans; un-stacked by a masked sum
        # over the short channel axis — cheaper than a gather)
        gg = jnp.where(chan_oh, s_src[..., None, :] - chan_W, neg)
        u_c = jnp.maximum(_cummax(gg) + chan_W, bus_W)
        u = jnp.sum(jnp.where(chan_oh, u_c, 0.0), axis=-2)
        # bank closure: one masked (C, C) row reduction (banks are many,
        # so the matrix contraction beats a per-bank stacked scan)
        d = jnp.max(jnp.where(mbank, jnp.where(v, u - Vr, neg)[
            ..., None, :], neg), axis=-1) + Vr
        return jnp.where(v, d, 0.0)

    if engine == "pallas":
        ghead = jlt[None, :] == head_src[:, None]
        gprev = jlt[None, :] == prev_link[:, None]
        mchan_m = (ch[None, :] == ch[:, None]) & v[None, :] & \
            (jlt[None, :] <= jlt[:, None])
        mshift_m = (cid[None, :] == cid[:, None]) & v[None, :] & \
            (jlt[None, :] < jlt[:, None])
        done = _pallas_fixed_point(
            t, lat, head0, bank0, _take(bus_free, ch), shift0, pre["we"],
            v, ghead, gprev, mbank, mshift_m, mchan_m, busy=busy,
            max_passes=(C + 2) if max_passes is None else max_passes,
            tol=tol, interpret=interpret)
    elif max_passes is None:
        # adaptive: three statically-unrolled passes cover realistic
        # streams (the closures resolve whole chains per pass); if the
        # third pass still moved something by more than tol, fall into a
        # while_loop until the fixed point (monotone from below, so the
        # residual is bounded; capped at C + 2 passes).  The cond keeps
        # the expensive loop off the hot path — the scan body is
        # batch-native, so only the taken branch executes.
        d_prev = one_pass(jnp.zeros(t.shape, f32))
        for _ in range(2):
            d_prev = one_pass(d_prev)
        d_last = one_pass(d_prev)

        def slow(dd):
            def cond(s):
                return jnp.logical_and(s[2] < C + 2,
                                       jnp.any(s[1] - s[0] > tol))

            def body(s):
                return (s[1], one_pass(s[1]), s[2] + 1)

            _, dn, _ = jax.lax.while_loop(cond, body,
                                          (dd[0], dd[1], jnp.int32(4)))
            return dn

        done = jax.lax.cond(jnp.any(d_last - d_prev > tol), slow,
                            lambda dd: dd[1], (d_prev, d_last))
    else:
        # statically unrolled fixed pass count (opt-in fast path: a
        # data-dependent while_loop in the scan body costs extra on CPU
        # backends and defeats fusion)
        done = one_pass(jnp.zeros(t.shape, f32))
        for _ in range(max_passes - 1):
            done = one_pass(done)

    # ---- final derived state + carry update (gathers only) ---------------
    if intra_heads:
        head = jnp.maximum(head0, _take_guard(done, head_src, neg))
    else:
        head = head0
    g = jnp.where(v, head - t, neg)
    shift = jnp.maximum(
        shift, jnp.max(jnp.where(pre["core_mask"], g[..., None, :], neg),
                       axis=-1))

    hits = hits + jnp.sum(hit & v, axis=-1)
    misses = misses + jnp.sum(empty & v, axis=-1)
    conflicts = conflicts + jnp.sum((~hit) & (~empty) & v, axis=-1)

    lb = pre["last_b"]
    bank_free = jnp.where(lb >= 0, _take(done, jnp.maximum(lb, 0)),
                          bank_free)
    open_row = jnp.where(lb >= 0, _take(row, jnp.maximum(lb, 0)),
                         open_row)

    lc = pre["chan_last"]
    bus_free = jnp.where(lc >= 0, _take(done, jnp.maximum(lc, 0)),
                         bus_free)

    # rings: rotate the carry-free survivor map by the carried counter
    def ring_update(ring, ring_src, idx0, Q):
        s0 = (jnp.arange(Q) - idx0[..., None]) % Q          # (..., n_qg, Q)
        srcs = jnp.take_along_axis(ring_src, s0, axis=-1)
        flat = srcs.reshape(srcs.shape[:-2] + (n_qg * Q,))
        got = _take_guard(done, flat, 0.0).reshape(srcs.shape)
        return jnp.where(srcs >= 0, got, ring)

    ring_r = ring_update(ring_r, pre["ring_src_r"], ir, Qr)
    ring_w = ring_update(ring_w, pre["ring_src_w"], iw, Qw)
    ir = ir + pre["nr"]
    iw = iw + pre["nw"]

    new_carry = (bank_free, open_row, bus_free, ring_r, ring_w, ir, iw,
                 shift, hits, misses, conflicts)
    return new_carry, (done, jnp.where(v, done - t, 0.0))


# --------------------------------------------------------------------------
# Stream-level driver: hoisted precompute + scan over chunks
# --------------------------------------------------------------------------

def replay_decoded(t_issue, flat_bank, ch, row, is_write, valid,
                   cfg: DramConfig, gran_bytes: int = 64, *,
                   engine: str = "xla", chunk: Optional[int] = None,
                   max_passes: Optional[int] = None,
                   tol: float = DEFAULT_TOL, n_cores: int = 1,
                   core_id=None, per_channel_queues: bool = False,
                   interpret: Optional[bool] = None):
    """Chunked replay of a pre-decoded request stream.

    Batch-native: every input may carry leading batch dimensions
    (`(..., n)`) and the replay processes the whole batch in one chunk
    scan — this is how `Simulator.sweep` replays a (designs, ops) stream
    batch without a vmap wrapper.  Pure traced function (safe under
    jit/vmap; `cfg`, `gran_bytes` and the keyword knobs must be static
    in a jitted caller).  Returns a dict with the raw per-request
    completion times `done` (undefined where ~valid — callers
    substitute their engine's no-op value), per-request round-trip
    `latency`, the per-core backpressure `shift` (shape
    (..., n_cores)), and the exact row hit/empty/conflict counters.

    per_channel_queues selects the shared-DRAM semantics (per-channel
    in-flight rings, per-core shift) of `simulate_shared_dram`; the
    default matches `simulate_dram`'s single global ring pair.  tol is
    the fixed-point stopping threshold in cycles (0.0 = iterate to the
    exact fixed point).  The "pallas" engine expects 1-D streams.
    """
    n = t_issue.shape[-1]
    batch = t_issue.shape[:-1]
    C = DEFAULT_CHUNK if chunk is None else int(chunk)
    C = max(1, min(C, max(n, 1)))
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel
    Qr, Qw = cfg.read_queue, cfg.write_queue
    passes = None if max_passes is None else max(1, int(max_passes))
    n_qg = ch_n if per_channel_queues else 1
    busy = float(max(1.0, gran_bytes / cfg.bandwidth_bytes_per_cycle))
    f32 = jnp.float32

    if core_id is None:
        core_id = jnp.zeros(t_issue.shape, jnp.int32)

    pad = (-n) % C
    nc = (n + pad) // C

    def _prep(x, fill, dtype):
        x = jnp.broadcast_to(jnp.asarray(x, dtype), batch + (n,))
        if pad:
            x = jnp.concatenate(
                [x, jnp.full(batch + (pad,), fill, dtype)], axis=-1)
        # (..., nc, C) -> (nc, ..., C): the chunk axis leads for the scan
        return jnp.moveaxis(x.reshape(batch + (nc, C)), -2, 0)

    xs = (_prep(t_issue, 0.0, f32), _prep(flat_bank, 0, jnp.int32),
          _prep(ch, 0, jnp.int32), _prep(row, 0, jnp.int32),
          _prep(is_write, False, bool), _prep(valid, False, bool),
          _prep(core_id, 0, jnp.int32))

    pre = _precompute_chunk(*xs, cfg=cfg, busy=busy, n_cores=n_cores,
                            n_qg=n_qg)

    carry0 = (jnp.zeros(batch + (ch_n * bk_n,), f32),
              -jnp.ones(batch + (ch_n * bk_n,), jnp.int32),
              jnp.zeros(batch + (ch_n,), f32),
              jnp.zeros(batch + (n_qg, Qr), f32),
              jnp.zeros(batch + (n_qg, Qw), f32),
              jnp.zeros(batch + (n_qg,), jnp.int32),
              jnp.zeros(batch + (n_qg,), jnp.int32),
              jnp.zeros(batch + (n_cores,), f32),
              jnp.zeros(batch, jnp.int32), jnp.zeros(batch, jnp.int32),
              jnp.zeros(batch, jnp.int32))

    step = functools.partial(
        _chunk_step, cfg=cfg, busy=busy, engine=engine,
        max_passes=passes, tol=float(tol), n_cores=n_cores, n_qg=n_qg,
        interpret=interpret)
    carry, (done, rt) = jax.lax.scan(step, carry0, xs + (pre,))

    def _unchunk(y):
        return jnp.moveaxis(y, 0, -2).reshape(batch + (nc * C,))[..., :n]

    return dict(done=_unchunk(done), latency=_unchunk(rt),
                shift=carry[7], hits=carry[8], misses=carry[9],
                conflicts=carry[10])
