"""Multi tensor-core engine: heterogeneous cores, shared L2, non-uniform split.

Paper Sec. III-C/III-D: cores may differ in systolic dims and SIMD units, and
MCM-style packages have non-uniform NoP latency to main memory. Workload is
split so per-core (compute + NoP) finish times equalize: with per-unit-work
rate a_i = cycles per unit of the split dim on core i and fixed NoP offset
b_i = nop_hops * cycles_per_hop * tiles, solve

    a_i * s_i + b_i = theta,  sum_i s_i = S
    => theta = (S + sum(b_i / a_i)) / sum(1 / a_i),  s_i = (theta - b_i) / a_i

then integerize s_i (floor + distribute remainder) and the makespan is
max_i(a_i * s_i + b_i). Uniform grids with zero hops reduce exactly to the
partition.py equations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .accelerator import AcceleratorConfig, CoreConfig
from .dataflow import cdiv, map_gemm
from .partition import partition_footprint


@dataclasses.dataclass(frozen=True)
class MultiCoreResult:
    cycles: float                 # makespan over cores (compute + NoP)
    per_core_cycles: Tuple[float, ...]
    per_core_share: Tuple[int, ...]
    scheme: str
    Pr: int
    Pc: int
    l2_fit: bool                  # partitions fit the shared L2
    l2_spill_elems: float         # unique elements beyond L2 capacity
    footprint_l1: float
    footprint_l2: float
    reduce_elems: float


def _core_rate(core: CoreConfig, split: str, scheme: str, dataflow: str,
               Sr: int, Sc: int, T: int, Pr: int, Pc: int) -> float:
    """Cycles per unit of the split dimension on this core (a_i)."""
    R, C = core.rows, core.cols
    if scheme == "spatial":
        # split Sr: cycles(s) = (2R+C+T-2) * ceil(s/R) * ceil(Sc/(Pc*C))
        return (2 * R + C + T - 2) * cdiv(Sc, Pc * C) / R
    if scheme == "st1":
        return (2 * R + C + cdiv(T, Pc) - 2) * cdiv(Sc, C) / R
    # st2: split Sc
    return (2 * R + C + cdiv(T, Pr) - 2) * cdiv(Sr, R) / C


def nonuniform_split(total: int, rates: Sequence[float],
                     offsets: Sequence[float]) -> List[int]:
    """Equalize a_i*s_i + b_i; integer shares summing to `total` (each >= 0)."""
    a = np.asarray(rates, dtype=np.float64)
    b = np.asarray(offsets, dtype=np.float64)
    inv = 1.0 / a
    theta = (total + float(np.sum(b * inv))) / float(np.sum(inv))
    s = np.maximum(0.0, (theta - b) * inv)
    scale = total / max(s.sum(), 1e-9)
    s = s * scale
    shares = np.floor(s).astype(int)
    rem = total - int(shares.sum())
    # give remaining units to cores with the largest fractional part
    order = np.argsort(-(s - shares))
    for i in range(rem):
        shares[order[i % len(shares)]] += 1
    return [int(x) for x in shares]


def simulate_multicore(cfg: AcceleratorConfig, M: int, N: int, K: int,
                       scheme: str = "spatial") -> MultiCoreResult:
    """Partition one GEMM over the core grid and return the makespan."""
    df = cfg.dataflow
    Sr, Sc, T = map_gemm(df, M, N, K)
    Pr, Pc = cfg.mesh_rows, cfg.mesh_cols
    cores = cfg.cores

    # --- per-core workload shares along the split dimension -----------------
    if scheme in ("spatial", "st1"):
        split_total, ngroups = Sr, Pr
    else:
        split_total, ngroups = Sc, Pc
    # group cores along the split axis; each group shares the split dim.
    grid = np.array(range(Pr * Pc)).reshape(Pr, Pc)
    groups = grid if scheme in ("spatial", "st1") else grid.T  # rows = groups
    per_core_cyc = np.zeros(Pr * Pc)
    shares_out = np.zeros(Pr * Pc, dtype=int)

    # rate/offset per group-row (use the first core of the group for the
    # secondary dims; heterogeneity enters through each member's own rate)
    rates, offsets = [], []
    for g in range(ngroups):
        core = cores[groups[g][0]]
        rates.append(_core_rate(core, "", scheme, df, Sr, Sc, T, Pr, Pc))
        offsets.append(core.nop_hops * cfg.nop_cycles_per_hop)
    shares = nonuniform_split(split_total, rates, offsets)

    for g in range(ngroups):
        for idx in groups[g]:
            core = cores[idx]
            R, C = core.rows, core.cols
            s = shares[g]
            if scheme == "spatial":
                cyc = (2 * R + C + T - 2) * cdiv(s, R) * cdiv(Sc, Pc * C)
            elif scheme == "st1":
                cyc = (2 * R + C + cdiv(T, Pc) - 2) * cdiv(s, R) * cdiv(Sc, C)
            else:
                cyc = (2 * R + C + cdiv(T, Pr) - 2) * cdiv(Sr, R) * cdiv(s, C)
            per_core_cyc[idx] = cyc + core.nop_hops * cfg.nop_cycles_per_hop
            shares_out[idx] = s

    # --- shared L2 capacity check (Sec. III-B) ------------------------------
    fp_l1 = partition_footprint(scheme, df, Sr, Sc, T, Pr, Pc, dedup=False)
    fp_l2 = partition_footprint(scheme, df, Sr, Sc, T, Pr, Pc, dedup=True)
    wb = cfg.memory.word_bytes
    l2_cap_elems = cfg.memory.l2_sram_bytes / wb if cfg.memory.l2_sram_bytes else 0.0
    l2_need = float(fp_l2["stream_in"] + fp_l2["stationary"])  # operand partitions
    l2_fit = (l2_cap_elems == 0.0) or (l2_need <= l2_cap_elems)
    spill = 0.0 if l2_fit else l2_need - l2_cap_elems

    return MultiCoreResult(
        cycles=float(per_core_cyc.max()),
        per_core_cycles=tuple(float(c) for c in per_core_cyc),
        per_core_share=tuple(int(s) for s in shares_out),
        scheme=scheme, Pr=Pr, Pc=Pc,
        l2_fit=bool(l2_fit), l2_spill_elems=float(spill),
        footprint_l1=float(fp_l1["total"]), footprint_l2=float(fp_l2["total"]),
        reduce_elems=float(fp_l1["reduce_elems"]))


def simulate_multicore_contention(cfg: AcceleratorConfig, M: int, N: int,
                                  K: int, scheme: str = "spatial",
                                  private_channels: bool = False,
                                  spec=None):
    """Shared-DRAM contention for one partitioned GEMM: per-core demand
    traces (from `repro.trace`) merged through the shared channels, vs
    each core alone on the memory system. Returns a
    `repro.trace.ContentionResult` with per-core stall inflation.

    private_channels: pin core c's bursts to channel c — the contention
    path then decomposes exactly into the isolated model (tested).
    """
    from ..trace.contention import multicore_contention
    return multicore_contention(cfg, M, N, K, scheme=scheme,
                                private_channels=private_channels, spec=spec)


def contention_summary(cfg: AcceleratorConfig, M: int, N: int, K: int,
                       scheme: str = "spatial",
                       private_channels: bool = False,
                       spec=None) -> Dict[str, float]:
    """`simulate_multicore_contention` flattened to a metric dict — the
    cell evaluator of the `multicore_contention` named study
    (`repro.api.study`). Infinite stall inflations (cores that only stall
    under contention) are reported as a count, not a column value, so the
    frame stays JSON/CSV-safe."""
    r = simulate_multicore_contention(cfg, M, N, K, scheme,
                                      private_channels, spec)
    finite = [x for x in r.stall_inflation if np.isfinite(x)]
    return dict(
        channels=float(cfg.dram.channels),
        cores=float(cfg.num_cores),
        makespan_isolated=float(r.makespan_isolated),
        makespan_shared=float(r.makespan_shared),
        contention_slowdown=float(r.makespan_shared
                                  / max(r.makespan_isolated, 1e-9)),
        max_stall_inflation=float(max(finite)) if finite else 1.0,
        cores_stalled_only_shared=float(len(r.stall_inflation)
                                        - len(finite)),
        row_hits=float(r.row_hits), row_misses=float(r.row_misses),
        row_conflicts=float(r.row_conflicts))


def best_multicore(cfg: AcceleratorConfig, M: int, N: int, K: int,
                   objective: str = "cycles") -> MultiCoreResult:
    results = [simulate_multicore(cfg, M, N, K, s)
               for s in ("spatial", "st1", "st2")]
    if objective == "cycles":
        return min(results, key=lambda r: (r.cycles, r.footprint_l1))
    return min(results, key=lambda r: (r.footprint_l1, r.cycles))
