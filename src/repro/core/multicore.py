"""Multi tensor-core engine: heterogeneous cores, shared L2, non-uniform split.

Paper Sec. III-C/III-D: cores may differ in systolic dims and SIMD units, and
MCM-style packages have non-uniform NoP latency to main memory. Workload is
split so per-core (compute + NoP) finish times equalize: with per-unit-work
rate a_i = cycles per unit of the split dim on core i and fixed NoP offset
b_i = nop_hops * cycles_per_hop * tiles, solve

    a_i * s_i + b_i = theta,  sum_i s_i = S
    => theta = (S + sum(b_i / a_i)) / sum(1 / a_i),  s_i = (theta - b_i) / a_i

then integerize s_i (floor + distribute remainder) and the makespan is
max_i(a_i * s_i + b_i). Uniform grids with zero hops reduce exactly to the
partition.py equations.

The solve lives in `multicore_model` / `best_multicore_cycles_model` —
pure-jnp, no Python branching on data, with the core grid shape (Pr, Pc)
and scheme static — so the batched sweep kernel evaluates the whole
spatio-temporal partition *inside* jit/vmap, grouped by core count the
way it groups by dataflow. The eager `simulate_multicore` delegates to
the same model, which keeps the per-op oracle and the batched sweep
bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .accelerator import AcceleratorConfig, CoreConfig
from .dataflow import cdiv, map_gemm
from .partition import SCHEMES, partition_footprint


@dataclasses.dataclass(frozen=True)
class MultiCoreResult:
    cycles: float                 # makespan over cores (compute + NoP)
    per_core_cycles: Tuple[float, ...]
    per_core_share: Tuple[int, ...]
    scheme: str
    Pr: int
    Pc: int
    l2_fit: bool                  # partitions fit the shared L2
    l2_spill_elems: float         # unique elements beyond L2 capacity
    footprint_l1: float
    footprint_l2: float
    reduce_elems: float


def _scheme_rate(scheme: str, R, C, Sr, Sc, T, Pr: int, Pc: int):
    """Cycles per unit of the split dimension on one core (a_i). `scheme`,
    `Pr`, `Pc` static; everything else may be traced arrays."""
    if scheme == "spatial":
        # split Sr: cycles(s) = (2R+C+T-2) * ceil(s/R) * ceil(Sc/(Pc*C))
        return (2 * R + C + T - 2) * cdiv(Sc, Pc * C) / R
    if scheme == "st1":
        return (2 * R + C + cdiv(T, Pc) - 2) * cdiv(Sc, C) / R
    # st2: split Sc
    return (2 * R + C + cdiv(T, Pr) - 2) * cdiv(Sr, R) / C


def _scheme_cycles(scheme: str, R, C, s, Sr, Sc, T, Pr: int, Pc: int):
    """Exact (integer-share) cycles of one core given its split share s."""
    if scheme == "spatial":
        return (2 * R + C + T - 2) * cdiv(s, R) * cdiv(Sc, Pc * C)
    if scheme == "st1":
        return (2 * R + C + cdiv(T, Pc) - 2) * cdiv(s, R) * cdiv(Sc, C)
    return (2 * R + C + cdiv(T, Pr) - 2) * cdiv(Sr, R) * cdiv(s, C)


def split_shares_model(total, a, b):
    """`nonuniform_split` on arrays: group axis 0, any broadcast batch
    behind it. Integerization gives the remainder to the largest
    fractional parts (stable argsort: ties break to the lowest index).

    Float32 (so the batched sweep kernel and the eager oracle share one
    bit-identical implementation): shares sum to `total` exactly for
    split dims within f32's integer range (2^24); beyond it, rounding
    residue is folded into the largest-fraction group, keeping the sum
    within an ulp of `total` (relative ~1e-7) instead of silently
    dropping split units.
    """
    inv = 1.0 / a
    theta = (total + jnp.sum(b * inv, axis=0)) / jnp.sum(inv, axis=0)
    s = jnp.maximum(0.0, (theta - b) * inv)
    scale = total / jnp.maximum(jnp.sum(s, axis=0), 1e-9)
    s = s * scale
    fl = jnp.floor(s)
    rem = total - jnp.sum(fl, axis=0)
    order = jnp.argsort(-(s - fl), axis=0)
    rank = jnp.argsort(order, axis=0)
    shares = fl + (rank < rem)
    resid = total - jnp.sum(shares, axis=0)   # 0 whenever rem <= groups
    return shares + jnp.where(rank == 0, resid, 0.0)


def nonuniform_split(total: int, rates: Sequence[float],
                     offsets: Sequence[float]) -> List[int]:
    """Equalize a_i*s_i + b_i; integer shares summing to `total` (each >= 0)."""
    f32 = jnp.float32
    shares = split_shares_model(f32(total),
                                jnp.asarray(rates, f32),
                                jnp.asarray(offsets, f32))
    return [int(x) for x in np.asarray(shares)]


def multicore_model(dataflow: str, scheme: str, M, N, K, rows, cols, hops,
                    nop_cycles_per_hop, Pr: int, Pc: int):
    """One partition scheme evaluated fully traced.

    rows/cols/hops: per-core geometry with the core axis LAST,
    shape (num_cores,) per design (num_cores = Pr*Pc, static). M/N/K and
    `nop_cycles_per_hop` may be traced arrays broadcastable against each
    other. Returns (makespan, per_core_cycles stacked on axis 0, group
    shares stacked on axis 0) — float32, matching `simulate_multicore`
    bit-for-bit (which delegates here).
    """
    f32 = jnp.float32
    Sr, Sc, T = map_gemm(dataflow, f32(1.0) * M, f32(1.0) * N, f32(1.0) * K)
    grid = np.arange(Pr * Pc).reshape(Pr, Pc)
    groups = grid if scheme in ("spatial", "st1") else grid.T  # rows = groups
    total = Sr if scheme in ("spatial", "st1") else Sc

    # static index maps over the core axis (no per-core Python loop: the
    # traced graph stays O(1) in core count, which is what lets 1024-4096
    # core pods trace in one kernel)
    g_first = groups[:, 0]                                # (G,) first core/group
    core_group = np.empty(Pr * Pc, dtype=np.int64)        # core -> its group
    core_group[groups.ravel()] = np.repeat(np.arange(groups.shape[0]),
                                           groups.shape[1])

    # common batch shape of the per-core geometry's leading dims and the
    # GEMM/nop operands; per-core arrays become (cores, *batch) so the
    # core axis broadcasts cleanly against op/design axes
    nop = f32(1.0) * nop_cycles_per_hop
    batch = jnp.broadcast_shapes(jnp.shape(rows)[:-1], jnp.shape(Sr),
                                 jnp.shape(Sc), jnp.shape(T),
                                 jnp.shape(nop))

    def lead(x, k):                       # (..., k) -> (k, *batch)
        return jnp.moveaxis(jnp.broadcast_to(x, batch + (k,)), -1, 0)

    G = groups.shape[0]
    a = f32(1.0) * _scheme_rate(
        scheme, lead(rows[..., g_first], G), lead(cols[..., g_first], G),
        Sr, Sc, T, Pr, Pc)
    b = f32(1.0) * lead(hops[..., g_first], G) * nop
    a, b = jnp.broadcast_arrays(a, b)
    shares = split_shares_model(total, a, b)          # (groups, *batch)

    cyc = _scheme_cycles(scheme, lead(rows, Pr * Pc), lead(cols, Pr * Pc),
                         shares[core_group], Sr, Sc, T, Pr, Pc)
    per_core = cyc + lead(hops, Pr * Pc) * nop
    per_core = jnp.broadcast_to(
        per_core, (Pr * Pc,) + jnp.shape(per_core)[1:])
    return jnp.max(per_core, axis=0), per_core, shares


def best_multicore_cycles_model(dataflow: str, M, N, K, rows, cols, hops,
                                nop_cycles_per_hop, Pr: int, Pc: int):
    """Makespan of the best scheme (min cycles, footprint tie-break) —
    the traced twin of `best_multicore(...).cycles`, evaluated inside the
    sweep kernel. Scheme order matches `best_multicore` so exact ties
    resolve identically."""
    f32 = jnp.float32
    Sr, Sc, T = map_gemm(dataflow, f32(1.0) * M, f32(1.0) * N, f32(1.0) * K)
    best_c = best_f = None
    for scheme in SCHEMES:
        c, _, _ = multicore_model(dataflow, scheme, M, N, K, rows, cols,
                                  hops, nop_cycles_per_hop, Pr, Pc)
        fp = partition_footprint(scheme, dataflow, Sr, Sc, T, Pr, Pc)
        f = f32(1.0) * fp["total"] + 0.0 * c
        if best_c is None:
            best_c, best_f = c, f
        else:
            better = (c < best_c) | ((c == best_c) & (f < best_f))
            best_c = jnp.where(better, c, best_c)
            best_f = jnp.where(better, f, best_f)
    return best_c


def effective_nop_hops(cfg: AcceleratorConfig) -> np.ndarray:
    """Per-core NoP hops to main memory: routed when the NoC plane is
    enabled (dimension-ordered routes to the MC at core 0, repro.noc),
    else the per-core `nop_hops` config fields (legacy offsets)."""
    if cfg.noc.enabled and cfg.num_cores > 1:
        from ..noc.topology import routed_hop_counts
        return np.asarray(routed_hop_counts(
            cfg.noc.topology, cfg.mesh_rows, cfg.mesh_cols), dtype=np.float64)
    return np.asarray([c.nop_hops for c in cfg.cores], dtype=np.float64)


def simulate_multicore(cfg: AcceleratorConfig, M: int, N: int, K: int,
                       scheme: str = "spatial") -> MultiCoreResult:
    """Partition one GEMM over the core grid and return the makespan."""
    df = cfg.dataflow
    Sr, Sc, T = map_gemm(df, M, N, K)
    Pr, Pc = cfg.mesh_rows, cfg.mesh_cols
    cores = cfg.cores

    # the share solve + per-core cycles run through the traced model so
    # the eager oracle and the batched sweep kernel are bit-identical
    f32 = jnp.float32
    rows = jnp.asarray([c.rows for c in cores], f32)
    cols = jnp.asarray([c.cols for c in cores], f32)
    hops = jnp.asarray(effective_nop_hops(cfg), f32)
    _, per_core, shares = multicore_model(
        df, scheme, M, N, K, rows, cols, hops, cfg.nop_cycles_per_hop,
        Pr, Pc)
    per_core_cyc = np.asarray(per_core, np.float64)
    grid = np.arange(Pr * Pc).reshape(Pr, Pc)
    groups = grid if scheme in ("spatial", "st1") else grid.T
    shares_np = np.asarray(shares)
    shares_out = np.zeros(Pr * Pc, dtype=int)
    for g in range(groups.shape[0]):
        for idx in groups[g]:
            shares_out[idx] = int(shares_np[g])

    # --- shared L2 capacity check (Sec. III-B) ------------------------------
    fp_l1 = partition_footprint(scheme, df, Sr, Sc, T, Pr, Pc, dedup=False)
    fp_l2 = partition_footprint(scheme, df, Sr, Sc, T, Pr, Pc, dedup=True)
    wb = cfg.memory.word_bytes
    l2_cap_elems = cfg.memory.l2_sram_bytes / wb if cfg.memory.l2_sram_bytes else 0.0
    l2_need = float(fp_l2["stream_in"] + fp_l2["stationary"])  # operand partitions
    l2_fit = (l2_cap_elems == 0.0) or (l2_need <= l2_cap_elems)
    spill = 0.0 if l2_fit else l2_need - l2_cap_elems

    return MultiCoreResult(
        cycles=float(per_core_cyc.max()),
        per_core_cycles=tuple(float(c) for c in per_core_cyc),
        per_core_share=tuple(int(s) for s in shares_out),
        scheme=scheme, Pr=Pr, Pc=Pc,
        l2_fit=bool(l2_fit), l2_spill_elems=float(spill),
        footprint_l1=float(fp_l1["total"]), footprint_l2=float(fp_l2["total"]),
        reduce_elems=float(fp_l1["reduce_elems"]))


def simulate_multicore_contention(cfg: AcceleratorConfig, M: int, N: int,
                                  K: int, scheme: str = "spatial",
                                  private_channels: bool = False,
                                  spec=None):
    """Shared-DRAM contention for one partitioned GEMM: per-core demand
    traces (from `repro.trace`) merged through the shared channels, vs
    each core alone on the memory system. Returns a
    `repro.trace.ContentionResult` with per-core stall inflation.

    private_channels: pin core c's bursts to channel c — the contention
    path then decomposes exactly into the isolated model (tested).
    """
    from ..trace.contention import multicore_contention
    return multicore_contention(cfg, M, N, K, scheme=scheme,
                                private_channels=private_channels, spec=spec)


def contention_summary(cfg: AcceleratorConfig, M: int, N: int, K: int,
                       scheme: str = "spatial",
                       private_channels: bool = False,
                       spec=None) -> Dict[str, float]:
    """`simulate_multicore_contention` flattened to a metric dict — the
    cell evaluator of the `multicore_contention` named study
    (`repro.api.study`). Infinite stall inflations (cores that only stall
    under contention) are reported as a count, not a column value, so the
    frame stays JSON/CSV-safe."""
    r = simulate_multicore_contention(cfg, M, N, K, scheme,
                                      private_channels, spec)
    finite = [x for x in r.stall_inflation if np.isfinite(x)]
    return dict(
        channels=float(cfg.dram.channels),
        cores=float(cfg.num_cores),
        makespan_isolated=float(r.makespan_isolated),
        makespan_shared=float(r.makespan_shared),
        contention_slowdown=float(r.makespan_shared
                                  / max(r.makespan_isolated, 1e-9)),
        max_stall_inflation=float(max(finite)) if finite else 1.0,
        cores_stalled_only_shared=float(len(r.stall_inflation)
                                        - len(finite)),
        row_hits=float(r.row_hits), row_misses=float(r.row_misses),
        row_conflicts=float(r.row_conflicts))


def best_multicore(cfg: AcceleratorConfig, M: int, N: int, K: int,
                   objective: str = "cycles") -> MultiCoreResult:
    results = [simulate_multicore(cfg, M, N, K, s)
               for s in ("spatial", "st1", "st2")]
    if objective == "cycles":
        return min(results, key=lambda r: (r.cycles, r.footprint_l1))
    return min(results, key=lambda r: (r.footprint_l1, r.cycles))
