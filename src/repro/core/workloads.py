"""Workload operator graphs for the simulation plane.

Two sources:
  1. The paper's own CNN/ViT workloads (GEMM-ified, M = filters,
     N = ofmap pixels, K = im2col window) — used to reproduce the paper's
     tables/figures.
  2. An extractor that turns any assigned LM architecture config
     (repro/configs) x shape cell into a layer-wise GEMM + vector-op graph
     for train / prefill / decode.

`Op.count` multiplies identical GEMMs (e.g. per-head attention GEMMs, layer
repeats); `Op.kind == 'vector'` ops run on the SIMD unit (Sec. III-C).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    M: int = 0
    N: int = 0
    K: int = 0
    count: float = 1.0
    kind: str = "gemm"              # gemm | vector
    vector_elems: float = 0.0
    sparsity_nm: Optional[Tuple[int, int]] = None  # per-layer N:M override

    @property
    def macs(self) -> float:
        return self.count * self.M * self.N * self.K


def _g(name, M, N, K, count=1.0, nm=None) -> Op:
    return Op(name, int(M), int(N), int(K), count, sparsity_nm=nm)


def _v(name, elems, count=1.0) -> Op:
    return Op(name, kind="vector", vector_elems=float(elems), count=count)


# --------------------------------------------------------------------------
# Paper workloads (GEMM-ified CNNs; M = filters, N = ofmap px, K = window)
# --------------------------------------------------------------------------

def resnet18() -> List[Op]:
    ops = [_g("conv1", 64, 112 * 112, 147)]
    ops += [_g(f"conv2_{i}", 64, 56 * 56, 576) for i in range(4)]
    ops += [_g("conv3_0", 128, 28 * 28, 576), _g("conv3_sc", 128, 28 * 28, 64)]
    ops += [_g(f"conv3_{i}", 128, 28 * 28, 1152) for i in range(1, 4)]
    ops += [_g("conv4_0", 256, 14 * 14, 1152), _g("conv4_sc", 256, 14 * 14, 128)]
    ops += [_g(f"conv4_{i}", 256, 14 * 14, 2304) for i in range(1, 4)]
    ops += [_g("conv5_0", 512, 7 * 7, 2304), _g("conv5_sc", 512, 7 * 7, 256)]
    ops += [_g(f"conv5_{i}", 512, 7 * 7, 4608) for i in range(1, 4)]
    ops += [_g("fc", 1000, 1, 512)]
    return ops


def resnet18_six_layers() -> List[Op]:
    """Six-layer subset for the WS-vs-OS DRAM study (Sec. IX-B): the early,
    activation-heavy layers (large N) where WS wins on compute cycles but
    loses once DRAM stalls are modeled."""
    return resnet18()[:6]


def alexnet() -> List[Op]:
    return [
        _g("conv1", 96, 55 * 55, 363), _g("conv2", 256, 27 * 27, 2400),
        _g("conv3", 384, 13 * 13, 2304), _g("conv4", 384, 13 * 13, 3456),
        _g("conv5", 256, 13 * 13, 3456), _g("fc6", 4096, 1, 9216),
        _g("fc7", 4096, 1, 4096), _g("fc8", 1000, 1, 4096),
    ]


def resnet50() -> List[Op]:
    ops = [_g("conv1", 64, 112 * 112, 147)]
    spec = [(56 * 56, 64, 256, 3), (28 * 28, 128, 512, 4),
            (14 * 14, 256, 1024, 6), (7 * 7, 512, 2048, 3)]
    cin = 64
    for n, mid, out, blocks in spec:
        for b in range(blocks):
            ops += [_g(f"b{out}_{b}_1x1a", mid, n, cin),
                    _g(f"b{out}_{b}_3x3", mid, n, mid * 9),
                    _g(f"b{out}_{b}_1x1b", out, n, mid)]
            if b == 0:
                ops.append(_g(f"b{out}_sc", out, n, cin))
            cin = out
    ops.append(_g("fc", 1000, 1, 2048))
    return ops


def vit(d: int, layers: int, heads: int, d_ff: int, tokens: int = 197,
        prefix: str = "vit") -> List[Op]:
    hd = d // heads
    ops: List[Op] = [_g(f"{prefix}_embed", d, tokens, 3 * 16 * 16)]
    for l in range(layers):
        ops += [
            _g(f"{prefix}_{l}_qkv", 3 * d, tokens, d),
            _g(f"{prefix}_{l}_scores", tokens, tokens, hd, count=heads),
            _v(f"{prefix}_{l}_softmax", heads * tokens * tokens),
            _g(f"{prefix}_{l}_attnv", hd, tokens, tokens, count=heads),
            _g(f"{prefix}_{l}_proj", d, tokens, d),
            _g(f"{prefix}_{l}_mlp1", d_ff, tokens, d),
            _v(f"{prefix}_{l}_gelu", d_ff * tokens),
            _g(f"{prefix}_{l}_mlp2", d, tokens, d_ff),
            _v(f"{prefix}_{l}_ln", 2 * tokens * d),
        ]
    ops.append(_g(f"{prefix}_head", 1000, 1, d))
    return ops


def vit_base() -> List[Op]:
    return vit(768, 12, 12, 3072, prefix="vitb")


def vit_small() -> List[Op]:
    return vit(384, 12, 6, 1536, prefix="vits")


def vit_large() -> List[Op]:
    return vit(1024, 24, 16, 4096, prefix="vitl")


def vit_linear(d: int, layers: int, d_ff: int, tokens: int = 197,
               prefix: str = "vit") -> List[Op]:
    """Linear layers only (qkv/proj/mlp) — SCALE-Sim GEMM-topology style,
    used for the paper's Table V latency/energy/EdP reproduction."""
    ops: List[Op] = []
    for l in range(layers):
        ops += [_g(f"{prefix}_{l}_qkv", 3 * d, tokens, d),
                _g(f"{prefix}_{l}_proj", d, tokens, d),
                _g(f"{prefix}_{l}_mlp1", d_ff, tokens, d),
                _g(f"{prefix}_{l}_mlp2", d, tokens, d_ff)]
    return ops


def vit_base_linear() -> List[Op]:
    return vit_linear(768, 12, 3072, prefix="vitb")


def vit_ffn_only(d: int = 768, d_ff: int = 3072, tokens: int = 197,
                 layers: int = 12) -> List[Op]:
    """Feed-forward layers of ViTs (paper Fig. 8 workload)."""
    ops = []
    for l in range(layers):
        ops += [_g(f"ff{l}_1", d_ff, tokens, d), _g(f"ff{l}_2", d, tokens, d_ff)]
    return ops


def rcnn() -> List[Op]:
    """Fast-RCNN-style: VGG16 backbone + per-RoI heads (GEMM-ified)."""
    cfg = [(64, 224 * 224, 27), (64, 224 * 224, 576),
           (128, 112 * 112, 576), (128, 112 * 112, 1152),
           (256, 56 * 56, 1152), (256, 56 * 56, 2304), (256, 56 * 56, 2304),
           (512, 28 * 28, 2304), (512, 28 * 28, 4608), (512, 28 * 28, 4608),
           (512, 14 * 14, 4608), (512, 14 * 14, 4608), (512, 14 * 14, 4608)]
    ops = [_g(f"vgg{i}", m, n, k) for i, (m, n, k) in enumerate(cfg)]
    ops += [_g("fc6", 4096, 128, 25088), _g("fc7", 4096, 128, 4096),
            _g("cls", 21, 128, 4096), _g("bbox", 84, 128, 4096)]
    return ops


PAPER_WORKLOADS = dict(resnet18=resnet18, alexnet=alexnet, resnet50=resnet50,
                       vit_base=vit_base, vit_small=vit_small,
                       vit_large=vit_large, rcnn=rcnn)


# --------------------------------------------------------------------------
# LM architecture extractor (assigned archs x shape cells)
# --------------------------------------------------------------------------

def lm_ops(cfg, *, seq: int, batch: int, mode: str = "train",
           cache_len: Optional[int] = None) -> List[Op]:
    """Operator graph for one step of an assigned LM architecture.

    cfg: repro.configs ModelConfig. mode: train | prefill | decode.
    Training multiplies forward GEMMs by 3 (fwd + ~2x bwd, standard
    GEMM-count accounting); decode uses N = batch (one token each) and
    attention GEMVs against a cache of `cache_len`.
    """
    mult = 3.0 if mode == "train" else 1.0
    d, L = cfg.d_model, cfg.layers
    hd = cfg.head_dim
    nq, nkv = cfg.heads, cfg.kv_heads
    ops: List[Op] = []
    if mode == "decode":
        n_tok = batch                       # one new token per sequence
        ctx = cache_len or seq
    else:
        n_tok = batch * seq
        ctx = seq
    window = getattr(cfg, "attn_window", 0) or 0
    eff_ctx = min(ctx, window) if window else ctx

    def attn_block(tag, cross_ctx=None):
        kv_ctx = cross_ctx if cross_ctx is not None else eff_ctx
        ops.append(_g(f"{tag}_q", nq * hd, n_tok, d, count=mult))
        ops.append(_g(f"{tag}_kv", 2 * nkv * hd, n_tok if cross_ctx is None
                      else cross_ctx * batch // max(batch, 1), d, count=mult))
        if mode == "decode":
            ops.append(_g(f"{tag}_scores", kv_ctx, 1, hd, count=mult * batch * nq))
            ops.append(_g(f"{tag}_ctxv", hd, 1, kv_ctx, count=mult * batch * nq))
        else:
            sc = min(seq, eff_ctx) if cross_ctx is None else cross_ctx
            ops.append(_g(f"{tag}_scores", sc, seq, hd, count=mult * batch * nq))
            ops.append(_g(f"{tag}_ctxv", hd, seq, sc, count=mult * batch * nq))
        ops.append(_v(f"{tag}_softmax", n_tok * nq * kv_ctx, count=mult))
        ops.append(_g(f"{tag}_o", d, n_tok, nq * hd, count=mult))
        ops.append(_v(f"{tag}_norm", 2 * n_tok * d, count=mult))

    def ffn_block(tag):
        if cfg.num_experts > 1:
            ops.append(_g(f"{tag}_router", cfg.num_experts, n_tok, d, count=mult))
            act = cfg.top_k
            ops.append(_g(f"{tag}_moe_up", 2 * cfg.d_ff, n_tok, d, count=mult * act))
            ops.append(_v(f"{tag}_moe_act", act * n_tok * cfg.d_ff, count=mult))
            ops.append(_g(f"{tag}_moe_down", d, n_tok, cfg.d_ff, count=mult * act))
        elif cfg.d_ff > 0:
            ops.append(_g(f"{tag}_ffn_up", 2 * cfg.d_ff, n_tok, d, count=mult))
            ops.append(_v(f"{tag}_ffn_act", n_tok * cfg.d_ff, count=mult))
            ops.append(_g(f"{tag}_ffn_down", d, n_tok, cfg.d_ff, count=mult))

    def ssm_block(tag):
        di = 2 * d
        st = getattr(cfg, "ssm_state", 64)
        chunk = min(256, max(1, seq if mode != "decode" else 1))
        ops.append(_g(f"{tag}_inproj", 2 * di + 2 * st, n_tok, d, count=mult))
        if mode == "decode":
            ops.append(_v(f"{tag}_state_update", batch * di * st, count=mult))
        else:
            ops.append(_g(f"{tag}_intra", chunk, seq, st,
                          count=mult * batch * max(1, di // 64)))
            ops.append(_g(f"{tag}_state", st, di, chunk,
                          count=mult * batch * (seq // max(chunk, 1))))
        ops.append(_g(f"{tag}_outproj", d, n_tok, di, count=mult))
        ops.append(_v(f"{tag}_norm", 2 * n_tok * d, count=mult))

    family = cfg.family
    for l in range(L):
        tag = f"L{l}"
        if family in ("dense", "moe", "vlm"):
            attn_block(tag)
            ffn_block(tag)
        elif family == "audio":                     # whisper enc-dec
            if l < L // 2:
                attn_block(f"{tag}_enc")
                ffn_block(f"{tag}_enc")
            else:
                attn_block(f"{tag}_dec")
                attn_block(f"{tag}_xattn", cross_ctx=min(seq, eff_ctx))
                ffn_block(f"{tag}_dec")
        elif family == "hybrid":                    # zamba2
            if (l + 1) % cfg.attn_every == 0:
                attn_block(tag)
            else:
                ssm_block(tag)
            ffn_block(tag)
        elif family == "ssm":                       # xlstm
            if (l + 1) % 8 == 0:
                ops.append(_g(f"{tag}_slstm", 4 * d, n_tok, d, count=mult))
                ops.append(_v(f"{tag}_slstm_gates", 4 * n_tok * d, count=mult))
            else:
                ssm_block(tag)
        else:
            raise ValueError(f"unknown family {family!r}")
    # embedding + unembedding (vocab GEMM)
    if mode != "decode":
        ops.append(_g("unembed", cfg.vocab, n_tok, d, count=mult))
    else:
        ops.append(_g("unembed", cfg.vocab, batch, d, count=1.0))
    return ops


def total_macs(ops: Sequence[Op]) -> float:
    return sum(o.macs for o in ops if o.kind == "gemm")
