# The paper's primary contribution: SCALE-Sim v3 as a JAX-native,
# vectorizable cycle-accurate simulator. See DESIGN.md for the mapping.
# The stage-pipeline facade over this layer lives in `repro.api`.
from .accelerator import (AcceleratorConfig, CoreConfig, DramConfig,
                          LayoutConfig, MemoryConfig, NocConfig,
                          SparsityConfig, tpu_like_config)
from .dataflow import (compute_cycles, dram_traffic, gemm_summary, map_gemm,
                       pe_utilization, sram_traffic, unmap_gemm)
from .dram import (DramResult, decode_requests, linear_trace,
                   replay_requests, simulate_dram, strided_trace,
                   tile_prefetch_trace)
from .replay import DEFAULT_CHUNK, DEFAULT_ENGINE, ENGINES, resolve_engine
from .energy import (DEFAULT_ERT, ERT, action_counts, action_counts_raw,
                     edp, energy_pj, power_w)
from .engine import (NetworkReport, OpResult, gemm_summary_traced,
                     simulate_network, simulate_op)
from .stages import (FIDELITIES, OpContext, Stage, build_pipeline,
                     traced_gemm_stats)
from .layout import (evaluate_layout, flat_ids, operand_linear_index,
                     slowdown_per_cycle)
from .multicore import (best_multicore, simulate_multicore,
                        simulate_multicore_contention)
from .partition import (best_plan, enumerate_plans, partition_cycles,
                        partition_footprint)
from .sparsity import (effective_K, pack_ellpack_block, sparse_compute_cycles,
                       storage_report)
from .workloads import PAPER_WORKLOADS, Op, lm_ops, total_macs
