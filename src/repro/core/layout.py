"""On-chip multi-bank data-layout modeling (paper Sec. VI).

The multi-bank SRAM is a 2D array: a "line" aggregates the same row index
across banks; each bank offers `ports_per_bank` concurrent line accesses per
cycle. A data layout assigns each tensor element a (line_id, col_id) via
nested-loop dimension orders; bank_id = col_id // bandwidth_per_bank.

Per-cycle slowdown (paper eq.): the bank needing the most distinct lines
relative to its ports sets the cycle's latency:

    slowdown = max_i ceil(distinct_lines(bank_i) / ports(bank_i))

`slowdown_per_cycle` is the vectorized oracle; kernels/conflict provides the
Pallas TPU kernel computing the same quantity.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .accelerator import LayoutConfig


def chw_ids(c, h, w, H: int, W: int, cfg: LayoutConfig,
            word_bytes: int = 2) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Paper's (line_id, col_id, bank_id) for a CxHxW tensor layout."""
    c1, h1, w1 = cfg.c1_step, cfg.h1_step, cfg.w1_step
    line = (c // c1) * (-(-H // h1)) * (-(-W // w1)) \
        + (h // h1) * (-(-W // w1)) + (w // w1)
    col = (w % w1) * h1 * c1 + (h % h1) * c1 + (c % c1)
    bpb = max(1, cfg.line_bytes // word_bytes)   # elements per bank line
    bank = (col // bpb) % cfg.num_banks
    return line, col, bank


def flat_ids(flat_index, cfg: LayoutConfig, word_bytes: int = 2):
    """Row-major layout for 2D operand matrices: contiguous elements fill a
    line across banks, then move to the next line."""
    bpb = max(1, cfg.line_bytes // word_bytes)
    elems_per_line = bpb * cfg.num_banks
    line = flat_index // elems_per_line
    col = flat_index % elems_per_line
    bank = col // bpb
    return line, col, bank


@partial(jax.jit, static_argnames=("num_banks", "ports"))
def slowdown_per_cycle(line: jnp.ndarray, bank: jnp.ndarray,
                       num_banks: int, ports: int = 1) -> jnp.ndarray:
    """(cycles, k) line/bank ids -> per-cycle slowdown (>= 1).

    Distinct (bank, line) pairs per cycle are counted by sorting each cycle's
    keys and marking boundaries; per-bank distinct counts come from a one-hot
    segment sum. Matches kernels/conflict (Pallas) bit-exactly.
    """
    # int32-safe composite key: bank * (max_line + 1) + line
    stride = jnp.max(line) + 1
    key = bank.astype(jnp.int32) * stride + line.astype(jnp.int32)
    key = jnp.sort(key, axis=1)
    new = jnp.concatenate(
        [jnp.ones_like(key[:, :1], bool), key[:, 1:] != key[:, :-1]], axis=1)
    b = (key // stride).astype(jnp.int32)
    onehot = jax.nn.one_hot(b, num_banks, dtype=jnp.int32)
    counts = jnp.einsum("ck,ckb->cb", new.astype(jnp.int32), onehot)
    per_bank = -(-counts // ports)
    return jnp.maximum(1, per_bank.max(axis=1))


def streaming_access_pattern(R: int, n_cycles: int, lead_stride: int,
                             elem_stride: int = 1) -> jnp.ndarray:
    """Flat element indices accessed per cycle by a streaming operand port:
    cycle t reads R elements {t*lead_stride + r*elem_stride}."""
    t = jnp.arange(n_cycles)[:, None]
    r = jnp.arange(R)[None, :]
    return t * lead_stride + r * elem_stride


# Fixed per-op analysis window of the streaming slowdown model: every
# op is analyzed over at most this many cycles (the oracle previously
# sized the window to the op; a static window + validity mask keeps the
# model jit/vmap-safe with `comp` as traced data).
STREAM_WINDOW_CYCLES = 512


def _distinct_slowdown(line, bank, num_banks: int, ports: int):
    """Per-cycle slowdown from (cycles, k) line/bank ids — the same
    quantity as `slowdown_per_cycle`, computed with a scatter-add instead
    of a one-hot einsum so large vmapped batches don't materialize a
    (cycles, k, banks) intermediate. line/bank may be traced floats."""
    line = line.astype(jnp.int32)
    bank = bank.astype(jnp.int32)
    stride = jnp.max(line) + 1
    key = jnp.sort(bank * stride + line, axis=1)
    new = jnp.concatenate(
        [jnp.ones_like(key[:, :1], bool), key[:, 1:] != key[:, :-1]], axis=1)
    b = key // stride
    cyc = jnp.broadcast_to(jnp.arange(key.shape[0])[:, None], key.shape)
    counts = jnp.zeros((key.shape[0], num_banks), jnp.int32)
    counts = counts.at[cyc, b].add(new.astype(jnp.int32))
    per_bank = -(-counts // ports)
    return jnp.maximum(1, per_bank.max(axis=1))


def streaming_layout_extra(cfg: LayoutConfig, R, comp, elem_stride,
                           word_bytes: int = 2, *, r_cap: int = None,
                           lead_stride: int = 1):
    """Extra cycles a systolic streaming pattern loses to bank conflicts.

    The traced twin of the LayoutStage model, shared by the per-op oracle
    pipeline and the batched sweep kernel so both paths agree bit-for-bit:
    `R`, `comp` and `elem_stride` may be traced scalars; the LayoutConfig
    fields, `r_cap` (static bound on R — rows beyond R are masked by
    duplicating the r=0 access, which adds no distinct (bank, line) pair)
    and the `STREAM_WINDOW_CYCLES` window are static. Cycles past
    clip(floor(comp), 8, window) are masked out of the mean, reproducing
    the op-sized window of the eager model exactly.
    """
    if r_cap is None:
        r_cap = int(R)
    n_cyc = STREAM_WINDOW_CYCLES
    t = jnp.arange(n_cyc, dtype=jnp.int32)
    r = jnp.arange(r_cap, dtype=jnp.int32)
    # integer index grid: element offsets stay exact past f32's 2^24
    # (large-vocab GEMMs stream with strides in the 100k+ range)
    stride = jnp.asarray(elem_stride, jnp.int32)
    idx = t[:, None] * int(lead_stride) + r[None, :] * stride
    line, _, bank = flat_ids(idx, cfg, word_bytes)
    rvalid = r[None, :] < R
    line = jnp.where(rvalid, line, line[:, :1])
    bank = jnp.where(rvalid, bank, bank[:, :1])
    sd = _distinct_slowdown(line, bank, cfg.num_banks, cfg.ports_per_bank)
    n_valid = jnp.clip(jnp.floor(jnp.minimum(1.0 * comp, n_cyc)), 8, n_cyc)
    mean_sd = jnp.sum(jnp.where(t < n_valid, sd, 0)) / n_valid
    return (mean_sd - 1.0) * comp


DRAM_LAYOUTS = ("row", "col", "tiled", "strided")


def operand_linear_index(row, col, rows, cols, order: str = "row",
                         tile_r: int = 32, tile_c: int = 32):
    """DRAM-side storage layout: operand element (row, col) of a
    rows x cols matrix -> linear element offset within its region.

    - 'row':   row-major (C order) — a streaming walk down a column is
               strided by `cols` elements (row-buffer hostile for large
               matrices).
    - 'col':   column-major (Fortran order) — the same walk is contiguous.
    - 'tiled': tile_r x tile_c blocks laid out row-major, row-major inside
               each block — the blocked layouts SCALE-Sim's trace studies
               compare against.

    All arguments may be traced jnp arrays except `order`/`tile_r`/`tile_c`
    (static), so `repro.trace` generators stay vmappable. ('strided' is
    synthesized directly from the stream position in the generator, not
    from coordinates, so it is not handled here.)
    """
    if order == "row":
        return row * cols + col
    if order == "col":
        return col * rows + row
    if order == "tiled":
        tiles_per_row = -(-cols // tile_c)
        tile_id = (row // tile_r) * tiles_per_row + (col // tile_c)
        return (tile_id * (tile_r * tile_c)
                + (row % tile_r) * tile_c + (col % tile_c))
    raise ValueError(f"unknown DRAM layout order {order!r}; "
                     f"known: {DRAM_LAYOUTS}")


@dataclasses.dataclass(frozen=True)
class LayoutResult:
    mean_slowdown: float
    max_slowdown: float
    extra_cycles: float


def evaluate_layout(cfg: LayoutConfig, R: int, n_cycles: int,
                    lead_stride: int, elem_stride: int = 1,
                    word_bytes: int = 2) -> LayoutResult:
    """Slowdown of a systolic streaming pattern under a flat layout.

    lead_stride/elem_stride describe how consecutive cycles / array rows map
    to operand addresses (dataflow-dependent): e.g. ws streams a column of X
    per cycle (elem_stride = N, lead_stride = 1 for row-major K x N).
    """
    idx = streaming_access_pattern(R, n_cycles, lead_stride, elem_stride)
    line, _, bank = flat_ids(idx, cfg, word_bytes)
    sd = slowdown_per_cycle(line, bank, cfg.num_banks, cfg.ports_per_bank)
    return LayoutResult(mean_slowdown=float(sd.mean()),
                        max_slowdown=float(sd.max()),
                        extra_cycles=float((sd - 1).sum()))
