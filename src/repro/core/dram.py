"""Cycle-accurate main-memory timing model (paper Sec. V) — Ramulator, in JAX.

The timing model reproduces the statistics the paper gets from Ramulator:
per-request round-trip latency, row-buffer hits / misses (empty row) /
conflicts, per-channel throughput, and — via finite read/write request
queues — the accelerator stall cycles that the queues' backpressure
creates (Sec. V-A2/V-A3).

Two replay engines implement the model (see `core.replay`):
  - the chunked bank-parallel engine (default; `engine="xla"` or
    `engine="pallas"`), which resolves requests in vectorized chunks, and
  - the original per-request `lax.scan` (`engine="reference"`), retained
    as the semantics oracle for differential testing.

Address mapping (documented; DDR-style interleave):
  burst index  b   = addr // burst_bytes
  channel          = b % channels
  within-channel r = b // channels
  bank             = (r // (row_bytes // burst_bytes)) % banks
  row              = r // ((row_bytes // burst_bytes) * banks)

Timing per request on its (channel, bank):
  ready = max(issue_ok, bank_free, bus_free[channel])
  row hit -> tCAS; empty row -> tRCD+tCAS; conflict -> tRP+tRCD+tCAS
  done  = ready + lat + busy   (busy = gran_bytes / per-channel bandwidth)

Finite queues: a request cannot issue until the request Q-back *in its
direction* has completed (in-flight window, mirroring the AXI-style window
the paper validates against). Backpressure accumulates into a `shift`:
every later request (and the compute stream) is delayed by it — this is
the "systolic array waits on the scratchpad" stall.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .accelerator import DramConfig

_ADDR_LIMIT = 2 ** 31


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DramResult:
    latency: jnp.ndarray          # per-request round-trip (cycles)
    complete: jnp.ndarray         # per-request completion time
    stall_cycles: jnp.ndarray     # scalar: queue backpressure + tail wait
    row_hits: jnp.ndarray
    row_misses: jnp.ndarray       # empty-row activations
    row_conflicts: jnp.ndarray
    total_cycles: jnp.ndarray     # end-to-end (incl. compute overlap window)
    bytes_moved: jnp.ndarray
    throughput: jnp.ndarray       # bytes / cycle over the busy window


def _addr_dtype():
    """int64 burst-index math when the jax config allows it, int32 else."""
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def check_addresses(addr) -> None:
    """Loud int32 address-space guard: every byte address must sit in
    [0, 2^31).  A negative address is the tell-tale of silent int32 wrap
    upstream.  Value checks only work on concrete arrays — under jit/vmap
    tracing this is a no-op (the eager entry points run it before
    tracing, which is where streams are built in practice)."""
    if isinstance(addr, jax.core.Tracer):
        return
    a = jnp.asarray(addr)
    if a.size == 0:
        return
    lo, hi = int(jnp.min(a)), int(jnp.max(a))
    if lo < 0 or hi >= _ADDR_LIMIT:
        raise ValueError(
            f"request addresses span [{lo}, {hi}], outside the int32 trace "
            f"address space [0, 2^31). A negative bound means the address "
            f"arithmetic wrapped upstream; shrink the stream's address "
            f"span (e.g. fewer cores / smaller regions) or enable "
            f"jax_enable_x64 for wider trace construction.")


def decode_requests(addr: jnp.ndarray, cfg: DramConfig):
    """Byte address -> (flat_bank, channel, row) under the interleaved
    channel/bank/row decode. Shared by every DRAM replay in the repo (this
    module's `simulate_dram` and `repro.trace.contention`'s shared-channel
    model) — change the decode here and both models follow.  Concrete
    (non-traced) addresses are guarded against int32 overflow loudly."""
    check_addresses(addr)
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel
    bursts_per_row = max(1, cfg.row_bytes // cfg.burst_bytes)
    b = jnp.asarray(addr).astype(_addr_dtype()) // cfg.burst_bytes
    ch = (b % ch_n).astype(jnp.int32)
    r = b // ch_n
    bank = ((r // bursts_per_row) % bk_n).astype(jnp.int32)
    row = (r // (bursts_per_row * bk_n)).astype(jnp.int32)
    return ch * bk_n + bank, ch, row


def row_buffer_latency(cfg: DramConfig, open_row_val, rw):
    """(latency, hit, empty) of one access against a bank's open row —
    the tCAS / tRCD+tCAS / tRP+tRCD+tCAS selection shared by both engines."""
    hit = open_row_val == rw
    empty = open_row_val < 0
    lat = jnp.where(hit, cfg.tCAS,
                    jnp.where(empty, cfg.tRCD + cfg.tCAS,
                              cfg.tRP + cfg.tRCD + cfg.tCAS))
    return lat, hit, empty


def _finalize(t_issue, valid, done, rt, shift, hits, misses, conflicts,
              cfg: DramConfig, gran_bytes: int, busy) -> DramResult:
    """Aggregate per-request completions into a DramResult (shared by the
    reference scan and the chunked replay so both report identically).
    Batch-native: inputs may carry leading batch dims before the request
    axis; aggregates reduce over the last axis only."""
    ti = t_issue.astype(jnp.float32)
    last = jnp.max(jnp.where(valid, done, 0.0), axis=-1)
    first = jnp.min(jnp.where(valid, ti, jnp.inf), axis=-1)
    span = jnp.maximum(1.0, last - first)
    nominal = cfg.tRCD + cfg.tCAS + busy
    last_issue = jnp.max(jnp.where(valid, ti, 0.0), axis=-1)
    tail = jnp.maximum(0.0, last - (last_issue + shift + nominal))
    bytes_moved = jnp.sum(valid, axis=-1).astype(jnp.float32) * gran_bytes
    return DramResult(
        latency=rt, complete=done,
        stall_cycles=shift + tail,
        row_hits=hits, row_misses=misses, row_conflicts=conflicts,
        total_cycles=last, bytes_moved=bytes_moved,
        throughput=bytes_moved / span)


def _reference_scan(t_issue, flat_bank, ch, row, is_write, valid,
                    cfg: DramConfig, busy):
    """The original per-request scan (engine='reference'); the semantics
    oracle the chunked engine is differential-tested against."""
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel
    Qr, Qw = cfg.read_queue, cfg.write_queue

    def step(carry, x):
        (bank_free, open_row, bus_free, ring_r, ring_w, ir, iw, shift,
         hits, misses, conflicts) = carry
        t, fb, c, rw, w, v = x
        t_eff = t + shift
        # finite in-flight window per direction
        head_r = ring_r[ir % Qr]
        head_w = ring_w[iw % Qw]
        issue_ok = jnp.maximum(t_eff, jnp.where(w, head_w, head_r))
        ready = jnp.maximum(issue_ok, bank_free[fb])
        lat, hit, empty = row_buffer_latency(cfg, open_row[fb], rw)
        # RAS/CAS latency pipelines across banks; only the data burst
        # serializes on the channel bus.
        done = jnp.maximum(ready + lat, bus_free[c]) + busy
        bank_free = jnp.where(v, bank_free.at[fb].set(done), bank_free)
        bus_free = jnp.where(v, bus_free.at[c].set(done), bus_free)
        open_row = jnp.where(v, open_row.at[fb].set(rw), open_row)
        ring_r = jnp.where(v & ~w, ring_r.at[ir % Qr].set(done), ring_r)
        ring_w = jnp.where(v & w, ring_w.at[iw % Qw].set(done), ring_w)
        ir = ir + jnp.where(v & ~w, 1, 0)
        iw = iw + jnp.where(v & w, 1, 0)
        # queue-full backpressure shifts everything downstream
        shift = shift + jnp.where(v, jnp.maximum(0.0, issue_ok - t_eff), 0.0)
        hits += hit & v
        misses += empty & v
        conflicts += (~hit) & (~empty) & v
        return ((bank_free, open_row, bus_free, ring_r, ring_w, ir, iw, shift,
                 hits, misses, conflicts),
                (jnp.where(v, done, t), jnp.where(v, done - t, 0.0)))

    carry0 = (jnp.zeros(ch_n * bk_n), -jnp.ones(ch_n * bk_n, jnp.int32),
              jnp.zeros(ch_n), jnp.zeros(Qr), jnp.zeros(Qw),
              jnp.int32(0), jnp.int32(0), jnp.float32(0.0),
              jnp.int32(0), jnp.int32(0), jnp.int32(0))
    xs = (t_issue.astype(jnp.float32), flat_bank, ch, row, is_write, valid)
    carry, (done, rt) = jax.lax.scan(step, carry0, xs)
    (_, _, _, _, _, _, _, shift, hits, misses, conflicts) = carry
    return done, rt, shift, hits, misses, conflicts


def replay_requests(t_issue, flat_bank, ch, row, is_write, valid,
                    cfg: DramConfig, gran_bytes: int = 64,
                    engine: Optional[str] = None,
                    chunk: Optional[int] = None) -> DramResult:
    """Run the timing model over a *pre-decoded* request stream.

    This is the decode-hoisted entry point: `Simulator.sweep`'s batched
    trace path decodes the whole (designs, ops, cap) address batch in one
    call and replays the decoded streams here, instead of re-deriving
    bank/channel/row inside every per-design closure.  Pure traced
    function; `cfg`/`gran_bytes`/`engine`/`chunk` must be static under an
    outer jit.  The chunked engines are batch-native: leading batch dims
    on the request arrays replay as one batch ("reference"/"pallas" are
    per-stream — vmap them for batches).
    """
    from . import replay as rp
    engine = rp.resolve_engine(engine)
    if valid is None:
        valid = jnp.ones(t_issue.shape, dtype=bool)
    ti = t_issue.astype(jnp.float32)
    busy = jnp.maximum(1.0, gran_bytes / cfg.bandwidth_bytes_per_cycle)
    if engine == "reference":
        done, rt, shift, hits, misses, conflicts = _reference_scan(
            ti, flat_bank, ch, row, is_write, valid, cfg, busy)
    else:
        out = rp.replay_decoded(ti, flat_bank, ch, row, is_write, valid,
                                cfg, gran_bytes, engine=engine, chunk=chunk)
        done = jnp.where(valid, out["done"], ti)
        rt = out["latency"]
        shift = out["shift"][..., 0]
        hits, misses, conflicts = out["hits"], out["misses"], out["conflicts"]
    return _finalize(ti, valid, done, rt, shift, hits, misses, conflicts,
                     cfg, gran_bytes, busy)


@partial(jax.jit, static_argnames=("cfg", "gran_bytes", "engine", "chunk"))
def _simulate_dram(t_issue, addr, is_write, cfg, gran_bytes, valid, engine,
                   chunk):
    flat_bank, ch, row = decode_requests(addr, cfg)
    return replay_requests(t_issue, flat_bank, ch, row, is_write, valid,
                           cfg, gran_bytes, engine=engine, chunk=chunk)


def simulate_dram(t_issue: jnp.ndarray, addr: jnp.ndarray,
                  is_write: jnp.ndarray, cfg: DramConfig,
                  gran_bytes: int = 64,
                  valid: jnp.ndarray = None,
                  engine: Optional[str] = None,
                  chunk: Optional[int] = None) -> DramResult:
    """Run the timing model over a request stream (sorted by t_issue).

    gran_bytes: bytes moved per request (trace fidelity uses burst_bytes;
    fast fidelity coarsens to larger transfers with bandwidth-equivalent
    bus occupancy).

    valid: optional bool mask. Invalid entries are no-ops: they leave the
    bank/bus/queue state untouched, contribute zero latency and zero
    bytes. This is what lets `repro.trace` generators emit fixed-shape
    (vmappable) request buffers whose live length is a traced value.

    engine: None -> `replay.DEFAULT_ENGINE`; "xla" | "pallas" select the
    chunked bank-parallel replay (see `core.replay`), "reference" the
    original per-request scan.  chunk: requests per chunk step for the
    chunked engines (default `replay.DEFAULT_CHUNK`).
    """
    from . import replay as rp
    engine = rp.resolve_engine(engine)
    check_addresses(addr)      # loud guard before tracing hides the values
    return _simulate_dram(t_issue, addr, is_write, cfg, gran_bytes, valid,
                          engine, chunk)


def linear_trace(n_requests: int, start_addr: int = 0, gran_bytes: int = 64,
                 t0: float = 0.0, issue_gap: float = 1.0,
                 write_every: int = 0) -> Tuple[jnp.ndarray, ...]:
    """Streaming (prefetch-like) trace: consecutive addresses, steady issue."""
    i = jnp.arange(n_requests, dtype=_addr_dtype())
    t = t0 + issue_gap * i.astype(jnp.float32)
    addr = start_addr + i * gran_bytes
    w = (i % write_every == write_every - 1) if write_every else jnp.zeros_like(i, bool)
    return t, addr, w


def strided_trace(n_requests: int, stride_bytes: int, gran_bytes: int = 64,
                  t0: float = 0.0, issue_gap: float = 1.0):
    """Row-conflict-heavy trace: large strides thrash row buffers."""
    i = jnp.arange(n_requests, dtype=_addr_dtype())
    t = t0 + issue_gap * i.astype(jnp.float32)
    addr = i * stride_bytes
    return t, addr, jnp.zeros_like(i, dtype=bool)


def tile_prefetch_trace(tile_bytes: int, n_tiles: int, compute_per_tile: float,
                        gran_bytes: int = 512, base: int = 0,
                        ofmap_fraction: float = 0.25):
    """Engine integration (fast fidelity): double-buffered per-fold prefetch.

    Each tile issues tile_bytes/gran requests at the start of its overlap
    window (one window per fold of `compute_per_tile` cycles); a trailing
    ofmap_fraction of requests are writes.
    """
    per = max(1, int(tile_bytes) // gran_bytes)
    i = jnp.arange(per * n_tiles, dtype=_addr_dtype())
    tile = i // per
    # the whole next-tile prefetch is posted at the window start (true
    # double-buffer behavior): small queues block the producer immediately,
    # large queues absorb the burst and overlap it with compute (Fig. 10).
    t = tile.astype(jnp.float32) * compute_per_tile
    addr = base + i * gran_bytes
    w = (i % per) >= int(per * (1 - ofmap_fraction))
    return t, addr, w
