"""Cycle-accurate main-memory timing model (paper Sec. V) — Ramulator, in JAX.

A `lax.scan` over a demand-request stream reproduces the statistics the paper
gets from Ramulator: per-request round-trip latency, row-buffer hits / misses
(empty row) / conflicts, per-channel throughput, and — via finite read/write
request queues — the accelerator stall cycles that the queues' backpressure
creates (Sec. V-A2/V-A3).

Address mapping (documented; DDR-style interleave):
  burst index  b   = addr // burst_bytes
  channel          = b % channels
  within-channel r = b // channels
  bank             = (r // (row_bytes // burst_bytes)) % banks
  row              = r // ((row_bytes // burst_bytes) * banks)

Timing per request on its (channel, bank):
  ready = max(issue_ok, bank_free, bus_free[channel])
  row hit -> tCAS; empty row -> tRCD+tCAS; conflict -> tRP+tRCD+tCAS
  done  = ready + lat + busy   (busy = gran_bytes / per-channel bandwidth)

Finite queues: a request cannot issue until the request Q-back *in its
direction* has completed (in-flight window, mirroring the AXI-style window the
paper validates against). Backpressure accumulates into a `shift` carried
through the scan: every later request (and the compute stream) is delayed by
it — this is the "systolic array waits on the scratchpad" stall.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .accelerator import DramConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DramResult:
    latency: jnp.ndarray          # per-request round-trip (cycles)
    complete: jnp.ndarray         # per-request completion time
    stall_cycles: jnp.ndarray     # scalar: queue backpressure + tail wait
    row_hits: jnp.ndarray
    row_misses: jnp.ndarray       # empty-row activations
    row_conflicts: jnp.ndarray
    total_cycles: jnp.ndarray     # end-to-end (incl. compute overlap window)
    bytes_moved: jnp.ndarray
    throughput: jnp.ndarray       # bytes / cycle over the busy window


def decode_requests(addr: jnp.ndarray, cfg: DramConfig):
    """Byte address -> (flat_bank, channel, row) under the interleaved
    channel/bank/row decode. Shared by every DRAM scan in the repo (this
    module's `simulate_dram` and `repro.trace.contention`'s shared-channel
    scan) — change the decode here and both models follow."""
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel
    bursts_per_row = max(1, cfg.row_bytes // cfg.burst_bytes)
    b = addr // cfg.burst_bytes
    ch = (b % ch_n).astype(jnp.int32)
    r = b // ch_n
    bank = ((r // bursts_per_row) % bk_n).astype(jnp.int32)
    row = (r // (bursts_per_row * bk_n)).astype(jnp.int32)
    return ch * bk_n + bank, ch, row


def row_buffer_latency(cfg: DramConfig, open_row_val, rw):
    """(latency, hit, empty) of one access against a bank's open row —
    the tCAS / tRCD+tCAS / tRP+tRCD+tCAS selection shared by both scans."""
    hit = open_row_val == rw
    empty = open_row_val < 0
    lat = jnp.where(hit, cfg.tCAS,
                    jnp.where(empty, cfg.tRCD + cfg.tCAS,
                              cfg.tRP + cfg.tRCD + cfg.tCAS))
    return lat, hit, empty


@partial(jax.jit, static_argnames=("cfg", "gran_bytes"))
def simulate_dram(t_issue: jnp.ndarray, addr: jnp.ndarray,
                  is_write: jnp.ndarray, cfg: DramConfig,
                  gran_bytes: int = 64,
                  valid: jnp.ndarray = None) -> DramResult:
    """Run the timing model over a request stream (sorted by t_issue).

    gran_bytes: bytes moved per request (trace fidelity uses burst_bytes;
    fast fidelity coarsens to larger transfers with bandwidth-equivalent
    bus occupancy).

    valid: optional bool mask. Invalid entries are no-ops: they leave the
    bank/bus/queue state untouched, contribute zero latency and zero
    bytes. This is what lets `repro.trace` generators emit fixed-shape
    (vmappable) request buffers whose live length is a traced value.
    """
    n = t_issue.shape[0]
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    ch_n, bk_n = cfg.channels, cfg.banks_per_channel
    busy = jnp.maximum(1.0, gran_bytes / cfg.bandwidth_bytes_per_cycle)
    flat_bank, ch, row = decode_requests(addr, cfg)

    Qr, Qw = cfg.read_queue, cfg.write_queue

    def step(carry, x):
        (bank_free, open_row, bus_free, ring_r, ring_w, ir, iw, shift,
         hits, misses, conflicts) = carry
        t, fb, c, rw, w, v = x
        t_eff = t + shift
        # finite in-flight window per direction
        head_r = ring_r[ir % Qr]
        head_w = ring_w[iw % Qw]
        issue_ok = jnp.maximum(t_eff, jnp.where(w, head_w, head_r))
        ready = jnp.maximum(issue_ok, bank_free[fb])
        lat, hit, empty = row_buffer_latency(cfg, open_row[fb], rw)
        # RAS/CAS latency pipelines across banks; only the data burst
        # serializes on the channel bus.
        done = jnp.maximum(ready + lat, bus_free[c]) + busy
        bank_free = jnp.where(v, bank_free.at[fb].set(done), bank_free)
        bus_free = jnp.where(v, bus_free.at[c].set(done), bus_free)
        open_row = jnp.where(v, open_row.at[fb].set(rw), open_row)
        ring_r = jnp.where(v & ~w, ring_r.at[ir % Qr].set(done), ring_r)
        ring_w = jnp.where(v & w, ring_w.at[iw % Qw].set(done), ring_w)
        ir = ir + jnp.where(v & ~w, 1, 0)
        iw = iw + jnp.where(v & w, 1, 0)
        # queue-full backpressure shifts everything downstream
        shift = shift + jnp.where(v, jnp.maximum(0.0, issue_ok - t_eff), 0.0)
        hits += hit & v
        misses += empty & v
        conflicts += (~hit) & (~empty) & v
        return ((bank_free, open_row, bus_free, ring_r, ring_w, ir, iw, shift,
                 hits, misses, conflicts),
                (jnp.where(v, done, t), jnp.where(v, done - t, 0.0)))

    carry0 = (jnp.zeros(ch_n * bk_n), -jnp.ones(ch_n * bk_n, jnp.int32),
              jnp.zeros(ch_n), jnp.zeros(Qr), jnp.zeros(Qw),
              jnp.int32(0), jnp.int32(0), jnp.float32(0.0),
              jnp.int32(0), jnp.int32(0), jnp.int32(0))
    xs = (t_issue.astype(jnp.float32), flat_bank, ch, row, is_write, valid)
    carry, (done, rt) = jax.lax.scan(step, carry0, xs)
    (_, _, _, _, _, _, _, shift, hits, misses, conflicts) = carry

    ti = t_issue.astype(jnp.float32)
    last = jnp.max(jnp.where(valid, done, 0.0))
    first = jnp.min(jnp.where(valid, ti, jnp.inf))
    span = jnp.maximum(1.0, last - first)
    nominal = cfg.tRCD + cfg.tCAS + busy
    last_issue = jnp.max(jnp.where(valid, ti, 0.0))
    tail = jnp.maximum(0.0, last - (last_issue + shift + nominal))
    bytes_moved = jnp.sum(valid).astype(jnp.float32) * gran_bytes
    return DramResult(
        latency=rt, complete=done,
        stall_cycles=shift + tail,
        row_hits=hits, row_misses=misses, row_conflicts=conflicts,
        total_cycles=last, bytes_moved=bytes_moved,
        throughput=bytes_moved / span)


def linear_trace(n_requests: int, start_addr: int = 0, gran_bytes: int = 64,
                 t0: float = 0.0, issue_gap: float = 1.0,
                 write_every: int = 0) -> Tuple[jnp.ndarray, ...]:
    """Streaming (prefetch-like) trace: consecutive addresses, steady issue."""
    i = jnp.arange(n_requests)
    t = t0 + issue_gap * i.astype(jnp.float32)
    addr = start_addr + i * gran_bytes
    w = (i % write_every == write_every - 1) if write_every else jnp.zeros_like(i, bool)
    return t, addr, w


def strided_trace(n_requests: int, stride_bytes: int, gran_bytes: int = 64,
                  t0: float = 0.0, issue_gap: float = 1.0):
    """Row-conflict-heavy trace: large strides thrash row buffers."""
    i = jnp.arange(n_requests)
    t = t0 + issue_gap * i.astype(jnp.float32)
    addr = i * stride_bytes
    return t, addr, jnp.zeros_like(i, dtype=bool)


def tile_prefetch_trace(tile_bytes: int, n_tiles: int, compute_per_tile: float,
                        gran_bytes: int = 512, base: int = 0,
                        ofmap_fraction: float = 0.25):
    """Engine integration (fast fidelity): double-buffered per-fold prefetch.

    Each tile issues tile_bytes/gran requests at the start of its overlap
    window (one window per fold of `compute_per_tile` cycles); a trailing
    ofmap_fraction of requests are writes.
    """
    per = max(1, int(tile_bytes) // gran_bytes)
    i = jnp.arange(per * n_tiles)
    tile = i // per
    # the whole next-tile prefetch is posted at the window start (true
    # double-buffer behavior): small queues block the producer immediately,
    # large queues absorb the burst and overlap it with compute (Fig. 10).
    t = tile.astype(jnp.float32) * compute_per_tile
    addr = base + i * gran_bytes
    w = (i % per) >= int(per * (1 - ofmap_fraction))
    return t, addr, w
