"""Accelerator configuration dataclasses for the SCALE-Sim v3 simulation plane.

Mirrors the knobs of the paper's config file: systolic array shape, on-chip
double-buffered SRAM sizes, dataflow, multi-core topology (incl. heterogeneous
cores and shared L2), sparsity section, DRAM (Ramulator-like) section, data
layout section and energy (Accelergy-like) section.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

Dataflow = str  # 'ws' | 'is' | 'os'
DATAFLOWS = ("ws", "is", "os")


@dataclasses.dataclass(frozen=True)
class CoreConfig:
    """One tensor core: a systolic array + a SIMD/vector unit.

    Follows TPU naming (Sec. III-C): a TensorCore = MXU(s) + vector unit.
    """
    rows: int = 32
    cols: int = 32
    simd_lanes: int = 128           # vector unit width (elements/cycle)
    simd_latency: float = 1.0       # cycles per vector op per lane-batch
    nop_hops: int = 0               # NoP hops to main memory (Sec. III-D)

    def __post_init__(self):
        if self.rows < 1 or self.cols < 1:
            raise ValueError(
                f"array shape must be >= 1x1, got {self.rows}x{self.cols}")
        if self.nop_hops < 0:
            # a negative hop count silently *reduced* multicore cycles in the
            # theta-equalization split; fail loudly like the int32 address
            # guard in trace/contention.py
            raise ValueError(f"nop_hops must be >= 0, got {self.nop_hops}")

    @property
    def num_pes(self) -> int:
        return self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    """Double-buffered on-chip SRAMs (bytes) + shared L2 (Sec. III-B)."""
    ifmap_sram_bytes: int = 1 << 20      # L1 input operand SRAM per core
    filter_sram_bytes: int = 1 << 20     # L1 weight operand SRAM per core
    ofmap_sram_bytes: int = 1 << 20      # L1 output SRAM per core
    l2_sram_bytes: int = 0               # shared L2 (0 = disabled)
    word_bytes: int = 2                  # element size (bf16 default)


@dataclasses.dataclass(frozen=True)
class DramConfig:
    """Main-memory interface (Sec. V). A Ramulator-like timing model.

    Timings are in accelerator cycles (we fold the DRAM/accel clock ratio in).
    Defaults approximate DDR4-2400 per channel seen from a 1 GHz accelerator.
    """
    channels: int = 2
    banks_per_channel: int = 16
    row_bytes: int = 2048                # row-buffer size
    tRCD: int = 14                       # activate -> column
    tRP: int = 14                        # precharge
    tCAS: int = 14                       # column access
    burst_bytes: int = 64                # bytes per burst transaction
    tBURST: int = 4                      # cycles a burst occupies the bus
    read_queue: int = 128                # finite request queues (Sec. V-A2)
    write_queue: int = 128
    bandwidth_bytes_per_cycle: float = 19.2  # peak per channel (2400MT/s*8B/1GHz)

    def __post_init__(self):
        for field in ("channels", "banks_per_channel", "row_bytes",
                      "burst_bytes", "read_queue", "write_queue"):
            if getattr(self, field) < 1:
                raise ValueError(
                    f"dram {field} must be >= 1, "
                    f"got {getattr(self, field)}")
        for field in ("tRCD", "tRP", "tCAS", "tBURST"):
            if getattr(self, field) < 1:
                raise ValueError(
                    f"dram timing {field} must be a positive cycle "
                    f"count, got {getattr(self, field)}")
        if self.bandwidth_bytes_per_cycle <= 0:
            raise ValueError(
                "dram bandwidth_bytes_per_cycle must be > 0, got "
                f"{self.bandwidth_bytes_per_cycle}")


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Sparsity section (Sec. IV-B). ratio = N:M on the weight operand."""
    enabled: bool = False
    n: int = 2
    m: int = 4
    row_wise: bool = False               # OptimizedMapping knob
    representation: str = "ellpack_block"  # ellpack_block | csr | csc
    seed: int = 0

    def __post_init__(self):
        if self.enabled:
            if not (1 <= self.n <= self.m):
                raise ValueError(f"invalid N:M = {self.n}:{self.m}")
            if self.row_wise and self.n > self.m // 2:
                raise ValueError(
                    f"row-wise sparsity requires N <= M/2, got {self.n}:{self.m}")
            if self.row_wise and self.m > 128:
                # core.sparsity.ROWWISE_HALF_CAP bounds the expected-max
                # j-grid; beyond it the traced model would silently truncate
                raise ValueError(
                    f"row-wise sparsity supports M <= 128, got M={self.m}")


@dataclasses.dataclass(frozen=True)
class LayoutConfig:
    """On-chip data layout section (Sec. VI)."""
    enabled: bool = False
    num_banks: int = 32
    ports_per_bank: int = 1
    line_bytes: int = 64                 # bandwidth_per_bank * word_bytes
    # nested-loop order steps (intra-line), see layout.py
    c1_step: int = 8
    h1_step: int = 2
    w1_step: int = 4


NOC_TOPOLOGIES = ("mesh", "torus", "ring")


@dataclasses.dataclass(frozen=True)
class NocConfig:
    """Routed NoC/NoP interconnect section (repro.noc).

    When enabled, per-core `nop_hops` are *derived* from dimension-ordered
    routes to the memory controller at core (0, 0) instead of taken from the
    config, and a flit/credit link model adds contention stalls on top of the
    zero-load `hops * nop_cycles_per_hop` latency.  `topology` is a static
    kernel flavor (it fixes the routing tree); the link parameters are traced
    data, so a sweep over link bandwidth / buffer depth stays one kernel.
    """
    enabled: bool = False
    topology: str = "mesh"                     # mesh | torus | ring
    link_bandwidth_bytes_per_cycle: float = 32.0
    flit_bytes: int = 32
    buffer_flits: int = 8                      # credit depth per link buffer

    def __post_init__(self):
        if self.topology not in NOC_TOPOLOGIES:
            raise ValueError(
                f"noc topology must be one of {NOC_TOPOLOGIES}, "
                f"got {self.topology!r}")
        # link parameters are validated even when disabled: a config
        # built with flit_bytes=0 must fail loudly at construction, not
        # divide-by-zero later when someone flips `enabled` on a
        # dataclasses.replace()'d copy
        if self.link_bandwidth_bytes_per_cycle <= 0:
            raise ValueError(
                "link_bandwidth_bytes_per_cycle must be > 0, got "
                f"{self.link_bandwidth_bytes_per_cycle}")
        if self.flit_bytes < 1:
            raise ValueError(f"flit_bytes must be >= 1, got {self.flit_bytes}")
        if self.buffer_flits < 1:
            raise ValueError(
                f"buffer_flits must be >= 1, got {self.buffer_flits}")


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Top-level config = cores + memories + dram + sparsity + layout."""
    cores: Tuple[CoreConfig, ...] = (CoreConfig(),)
    mesh_rows: int = 1                   # core grid: Pr_max
    mesh_cols: int = 1                   # core grid: Pc_max
    dataflow: Dataflow = "ws"
    memory: MemoryConfig = MemoryConfig()
    dram: DramConfig = DramConfig()
    sparsity: SparsityConfig = SparsityConfig()
    layout: LayoutConfig = LayoutConfig()
    noc: NocConfig = NocConfig()
    clock_ghz: float = 1.0
    nop_cycles_per_hop: float = 2.0      # NoP latency per hop per tile transfer

    def __post_init__(self):
        if self.dataflow not in DATAFLOWS:
            raise ValueError(f"dataflow must be one of {DATAFLOWS}")
        if self.nop_cycles_per_hop < 0:
            raise ValueError(
                f"nop_cycles_per_hop must be >= 0, got {self.nop_cycles_per_hop}")
        n = self.mesh_rows * self.mesh_cols
        if len(self.cores) == 1 and n > 1:
            # homogeneous grid: replicate the single prototype core
            object.__setattr__(self, "cores", tuple(self.cores * n))
        if len(self.cores) != n:
            raise ValueError(
                f"need {n} cores for a {self.mesh_rows}x{self.mesh_cols} grid, "
                f"got {len(self.cores)}")

    @property
    def num_cores(self) -> int:
        return self.mesh_rows * self.mesh_cols

    @property
    def homogeneous(self) -> bool:
        return all(c == self.cores[0] for c in self.cores)

    def with_(self, **kw) -> "AcceleratorConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        """Plain nested-dict form (JSON/YAML-safe). Inverse of `from_dict`."""
        d = dataclasses.asdict(self)
        d["cores"] = list(d["cores"])       # tuple -> list for JSON
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "AcceleratorConfig":
        """Build a config from `to_dict` output (or any compatible mapping;
        missing sections fall back to defaults, unknown keys are an error)."""
        d = dict(d)
        sections = dict(memory=MemoryConfig, dram=DramConfig,
                        sparsity=SparsityConfig, layout=LayoutConfig,
                        noc=NocConfig)
        kw: dict = {}
        cores = d.pop("cores", None)
        if cores is not None:
            kw["cores"] = tuple(
                c if isinstance(c, CoreConfig) else CoreConfig(**c)
                for c in cores)
        for name, typ in sections.items():
            if name in d:
                v = d.pop(name)
                kw[name] = v if isinstance(v, typ) else typ(**v)
        kw.update(d)
        return cls(**kw)


def near_square_grid(cores: int) -> Tuple[int, int]:
    """Factor a core count into the most-square (Pr, Pc) mesh."""
    import math
    if cores < 1:
        raise ValueError(f"core count must be >= 1, got {cores}")
    pr = int(math.sqrt(cores))
    while cores % pr:
        pr -= 1
    return pr, cores // pr


def tpu_like_config(array: int = 128, cores: int = 1, dataflow: str = "ws",
                    sram_mb: float = 8.0) -> AcceleratorConfig:
    """A TPU-like single/multi tensor-core configuration (Sec. V-C1)."""
    pr, pc = near_square_grid(cores)
    sram = int(sram_mb * (1 << 20) / 3)
    return AcceleratorConfig(
        cores=(CoreConfig(rows=array, cols=array),),
        mesh_rows=pr, mesh_cols=pc, dataflow=dataflow,
        memory=MemoryConfig(ifmap_sram_bytes=sram, filter_sram_bytes=sram,
                            ofmap_sram_bytes=sram,
                            l2_sram_bytes=4 * sram if cores > 1 else 0),
    )
