"""The simulation pipeline as explicit, pluggable stages.

One GEMM op flows through (paper Fig. 1, left to right):

    mapping -> partition -> sparsity -> sram -> dram -> layout -> energy

Each stage is a small object with `apply(ctx)` mutating an `OpContext`;
`build_pipeline(fidelity)` selects concrete stages (today fidelity switches
the DRAM stage between the first-order bandwidth-overlap model and the
cycle-accurate lax.scan model; new fidelities or subsystems plug in here
rather than forking the engine). `repro.core.engine.simulate_op`,
`simulate_network` and the traced DSE path are all thin wrappers over this
module, so there is exactly one copy of the mapping/traffic math.

The traced twins (`traced_gemm_stats`, `traced_vector_stats`,
`traced_energy_counts`) run the *same* dataflow/energy functions on jnp
arrays, which is what lets `repro.api.Simulator.sweep` vmap/pjit thousands
of design points per call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .accelerator import (AcceleratorConfig, LayoutConfig, MemoryConfig,
                          SparsityConfig)
from . import dataflow as dfm
from .dram import simulate_dram, tile_prefetch_trace
from .energy import DEFAULT_ERT, ERT, action_counts, action_counts_raw, energy_pj
from .layout import streaming_layout_extra
from .multicore import best_multicore, best_multicore_cycles_model
from .sparsity import (sparse_compute_cycles, sparse_compute_cycles_model,
                       storage_bytes_model, storage_report)
from .workloads import Op

FIDELITIES = ("fast", "cycle", "trace")

_DRAM_REQ_CAP = 16384     # cycle-fidelity request cap per op (scaled beyond)


@dataclasses.dataclass
class OpContext:
    """Mutable working state threaded through the stage pipeline.

    Per-instance quantities (comp, stall, traffic) are for ONE instance of
    the op; the energy/finalize stage multiplies by `op.count`.
    """
    cfg: AcceleratorConfig
    op: Op
    ert: ERT
    sp: SparsityConfig
    # mapping / partition / sparsity
    comp: float = 0.0
    scheme: str = "single"
    util: float = 0.0
    sparse_info: Optional[Dict[str, float]] = None
    filter_shrink: float = 1.0
    # traffic
    sram: Optional[Dict[str, float]] = None
    dram: Optional[Dict[str, float]] = None
    dram_elems: float = 0.0
    dram_bytes: float = 0.0           # per instance
    stall: float = 0.0
    dram_stats: Optional[Dict[str, float]] = None
    layout_extra: float = 0.0
    noc_extra: float = 0.0            # per instance (repro.noc NocStage)
    noc_stats: Optional[Dict[str, float]] = None
    # finalized totals (x op.count)
    compute_total: float = 0.0
    stall_total: float = 0.0
    noc_total: float = 0.0
    layout_total: float = 0.0
    total: float = 0.0
    sram_reads: float = 0.0
    sram_writes: float = 0.0
    dram_bytes_total: float = 0.0
    energy_total: float = 0.0
    energy_by_action: Optional[Dict[str, float]] = None


class Stage:
    """A pipeline stage. Subclasses set `name` and implement `apply`."""
    name = "stage"

    def apply(self, ctx: OpContext) -> None:
        raise NotImplementedError


class CoreStage(Stage):
    """A stage whose model depends on one core's geometry. `core_index`
    selects the core a heterogeneous mesh is analyzed through; every
    core-dependent stage in one pipeline shares the same index so the
    report describes an actual core, not a mix."""

    def __init__(self, core_index: int = 0):
        self.core_index = core_index

    def core(self, ctx: OpContext):
        return ctx.cfg.cores[self.core_index]


class MappingStage(CoreStage):
    """Single-core dataflow mapping: analytical compute cycles + PE
    utilization (SCALE-Sim v2 runtime equations)."""
    name = "mapping"

    def apply(self, ctx: OpContext) -> None:
        op, core, df = ctx.op, self.core(ctx), ctx.cfg.dataflow
        ctx.comp = float(dfm.compute_cycles(df, op.M, op.N, op.K,
                                            core.rows, core.cols))
        ctx.scheme = "single"
        ctx.util = float(dfm.pe_utilization(df, op.M, op.N, op.K,
                                            core.rows, core.cols))


class PartitionStage(Stage):
    """Multi-core partitioning: pick the best spatial/spatio-temporal
    split over the core grid (skipped for single-core or sparse runs,
    matching the paper's feature composition)."""
    name = "partition"

    def apply(self, ctx: OpContext) -> None:
        if ctx.sp.enabled or ctx.cfg.num_cores <= 1:
            return
        op = ctx.op
        mc = best_multicore(ctx.cfg, op.M, op.N, op.K)
        ctx.comp = mc.cycles
        ctx.scheme = f"{mc.scheme}({mc.Pr}x{mc.Pc})"
        ctx.util = min(1.0, op.M * op.N * op.K / max(
            1.0, sum(c.num_pes for c in ctx.cfg.cores) * mc.cycles))


class SparsityStage(CoreStage):
    """N:M weight sparsity: compressed-stream compute cycles + storage
    report; records the filter-traffic shrink applied downstream."""
    name = "sparsity"

    def apply(self, ctx: OpContext) -> None:
        if not ctx.sp.enabled:
            return
        op, core, cfg = ctx.op, self.core(ctx), ctx.cfg
        ctx.comp = float(sparse_compute_cycles(
            cfg.dataflow, op.M, op.N, op.K, core.rows, core.cols, ctx.sp))
        ctx.sparse_info = storage_report(op.M, op.K, ctx.sp,
                                         cfg.memory.word_bytes)
        ctx.scheme = "single"
        ctx.util = min(1.0, op.M * op.N * op.K / max(
            1.0, core.num_pes * ctx.comp * ctx.sp.m / max(ctx.sp.n, 1)))
        ctx.filter_shrink = (ctx.sparse_info["total_bytes"]
                             / max(ctx.sparse_info["original_bytes"], 1.0))


class SramStage(CoreStage):
    """Aggregate SRAM demand counts; sparse filters stream compressed."""
    name = "sram"

    def apply(self, ctx: OpContext) -> None:
        op, core, cfg = ctx.op, self.core(ctx), ctx.cfg
        sram = dfm.sram_traffic(cfg.dataflow, op.M, op.N, op.K,
                                core.rows, core.cols)
        if ctx.filter_shrink != 1.0:
            sram["filter_reads"] = sram["filter_reads"] * ctx.filter_shrink
        ctx.sram = sram


class DramStage(CoreStage):
    """Capacity-based DRAM traffic shared by all fidelities; subclasses
    supply the stall model. The analyzed core comes from `core_index` —
    heterogeneous meshes model a specific member instead of silently
    modeling core 0."""
    name = "dram"

    def apply(self, ctx: OpContext) -> None:
        op, cfg = ctx.op, ctx.cfg
        core = self.core(ctx)
        dram = dfm.dram_traffic(cfg.dataflow, op.M, op.N, op.K,
                                core.rows, core.cols, cfg.memory)
        if ctx.filter_shrink != 1.0:
            dram["dram_filter"] = dram["dram_filter"] * ctx.filter_shrink
        ctx.dram = dram
        ctx.dram_elems = float(dram["dram_ifmap"] + dram["dram_filter"]
                               + dram["dram_ofmap_writes"]
                               + dram["dram_ofmap_reads"])
        ctx.dram_bytes = ctx.dram_elems * cfg.memory.word_bytes
        self.stalls(ctx)

    def stalls(self, ctx: OpContext) -> None:
        raise NotImplementedError


class FastDramStage(DramStage):
    """First-order stall: double-buffered transfer time vs compute.

    Operates on per-instance bytes; `op.count` scaling happens once in the
    finalize stage (the old engine divided by count here as well, silently
    double-discounting stalls for repeated ops)."""
    name = "dram[fast]"

    def stalls(self, ctx: OpContext) -> None:
        bw = ctx.cfg.dram.bandwidth_bytes_per_cycle * ctx.cfg.dram.channels
        ctx.stall = float(dfm.dram_stall_cycles_simple(
            ctx.dram_bytes, ctx.comp, bw))


class CycleDramStage(DramStage):
    """Cycle-accurate (Ramulator-like) DRAM: tile-prefetch trace through
    banked channels with finite queues, folded + scaled beyond the
    request cap. `engine` selects the replay engine (core.replay)."""
    name = "dram[cycle]"

    def __init__(self, core_index: int = 0, engine: Optional[str] = None):
        super().__init__(core_index)
        self.engine = engine

    def stalls(self, ctx: OpContext) -> None:
        cfg = ctx.cfg
        gran = 512
        n_req = max(1, int(ctx.dram_bytes) // gran)
        scale = max(1.0, n_req / _DRAM_REQ_CAP)
        n_sim = min(n_req, _DRAM_REQ_CAP)
        folds = max(1, int(np.ceil(n_sim / 32)))
        t, a, w = tile_prefetch_trace(n_sim * gran // folds, folds,
                                      ctx.comp / max(folds, 1) / scale, gran)
        res = simulate_dram(t, a, w, cfg.dram, gran, engine=self.engine)
        ctx.stall = float(res.stall_cycles) * scale
        ctx.dram_stats = dict(
            row_hits=int(res.row_hits), row_misses=int(res.row_misses),
            row_conflicts=int(res.row_conflicts),
            throughput_Bpc=float(res.throughput),
            mean_latency=float(jnp.mean(res.latency)),
            scaled_by=scale)


class TraceDramStage(DramStage):
    """Trace fidelity: the demand-request stream is synthesized from the
    mapping itself (`repro.trace` — tile schedule, double-buffered
    prefetch deadlines, per-dataflow operand walks, layout-aware
    addresses) and replayed through the cycle-accurate DRAM scan. Unlike
    `CycleDramStage`'s synthetic linear prefetch, row-buffer statistics
    here respond to dataflow, tiling and layout."""
    name = "dram[trace]"

    def __init__(self, core_index: int = 0, spec=None,
                 engine: Optional[str] = None):
        super().__init__(core_index)
        if spec is None:
            from ..trace.generator import DEFAULT_SPEC
            spec = DEFAULT_SPEC
        self.spec = spec
        self.engine = engine

    def stalls(self, ctx: OpContext) -> None:
        from ..trace.generator import gemm_trace_stats
        op, cfg = ctx.op, ctx.cfg
        core = self.core(ctx)
        dram = ctx.dram
        res = gemm_trace_stats(
            cfg.dataflow, op.M, op.N, op.K, core.rows, core.cols, ctx.comp,
            dram["dram_ifmap"], dram["dram_filter"],
            dram["dram_ofmap_writes"], dram["dram_ofmap_reads"],
            cfg.dram, cfg.memory.word_bytes, self.spec,
            engine=self.engine)
        ctx.stall = float(res["stall_cycles"])
        ctx.dram_stats = dict(
            row_hits=int(res["row_hits"]), row_misses=int(res["row_misses"]),
            row_conflicts=int(res["row_conflicts"]),
            row_hit_rate=float(res["row_hit_rate"]),
            throughput_Bpc=float(res["throughput_Bpc"]),
            mean_latency=float(res["mean_latency"]),
            scaled_by=float(res["scaled_by"]))


class LayoutStage(CoreStage):
    """On-chip bank-conflict slowdown on the streaming operand. Runs the
    shared static-shape model (`layout.streaming_layout_extra`) so the
    batched sweep kernel reproduces this stage bit-for-bit."""
    name = "layout"

    def apply(self, ctx: OpContext) -> None:
        cfg, op = ctx.cfg, ctx.op
        if not cfg.layout.enabled:
            return
        core = self.core(ctx)
        ctx.layout_extra = float(streaming_layout_extra(
            cfg.layout, core.rows, ctx.comp, max(1, op.N),
            cfg.memory.word_bytes, r_cap=core.rows))


class EnergyStage(Stage):
    """Finalize: x op.count, action counts, ERT energy lookup."""
    name = "energy"

    def apply(self, ctx: OpContext) -> None:
        op, cfg = ctx.op, ctx.cfg
        ctx.compute_total = ctx.comp * op.count
        ctx.stall_total = ctx.stall * op.count
        ctx.noc_total = ctx.noc_extra * op.count
        ctx.layout_total = ctx.layout_extra * op.count
        ctx.total = (ctx.compute_total + ctx.stall_total + ctx.noc_total
                     + ctx.layout_total)
        sram = ctx.sram
        ctx.sram_reads = float(sram["ifmap_reads"] + sram["filter_reads"]
                               + sram["ofmap_reads"]) * op.count
        ctx.sram_writes = float(sram["ofmap_writes"]) * op.count
        ctx.dram_bytes_total = ctx.dram_bytes * op.count
        counts = action_counts(
            cfg, cycles=ctx.compute_total, macs=op.macs,
            ifmap_reads=float(sram["ifmap_reads"]) * op.count,
            filter_reads=float(sram["filter_reads"]) * op.count,
            ofmap_writes=float(sram["ofmap_writes"]) * op.count,
            ofmap_reads=float(sram["ofmap_reads"]) * op.count,
            dram_bytes=ctx.dram_bytes_total,
            l2_reads=(ctx.dram_elems * op.count
                      if cfg.memory.l2_sram_bytes else 0.0))
        e = energy_pj(counts, ctx.ert)
        ctx.energy_total = float(e["total"])
        ctx.energy_by_action = {k: float(v) for k, v in e.items()
                                if k != "total"}


def build_pipeline(fidelity: str = "fast", *, core_index: int = 0,
                   trace_spec=None,
                   engine: Optional[str] = None) -> Tuple[Stage, ...]:
    """The canonical GEMM pipeline for a fidelity level.

    core_index: the core whose geometry every core-dependent stage
    (mapping, sparsity, sram, dram, layout) analyzes — heterogeneous
    meshes model one consistent member. trace_spec: optional
    `repro.trace.TraceSpec` for the trace fidelity. engine: DRAM replay
    engine for the cycle/trace stages (`core.replay.ENGINES`;
    None = default, i.e. the chunked bank-parallel replay).
    """
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                         f"got {fidelity!r}")
    if fidelity == "cycle":
        dram: DramStage = CycleDramStage(core_index, engine)
    elif fidelity == "trace":
        dram = TraceDramStage(core_index, trace_spec, engine)
    else:
        dram = FastDramStage(core_index)
    from ..noc.stage import NocStage    # lazy: noc depends on core.stages
    return (MappingStage(core_index), PartitionStage(),
            SparsityStage(core_index), SramStage(core_index),
            NocStage(core_index), dram, LayoutStage(core_index),
            EnergyStage())


def pipeline_engine(pipeline: Sequence[Stage]) -> str:
    """Resolved runtime replay-engine label of a pipeline's DRAM stage.

    '' for the fast model (it replays nothing); otherwise the label
    `core.replay.resolve_engine_runtime` gives for the stage's engine —
    including the off-TPU resolution of "pallas" to "pallas:twin" /
    "pallas:interpret", so reports record what actually ran, never the
    requested name.
    """
    from . import replay as _rp
    for s in pipeline:
        if isinstance(s, (CycleDramStage, TraceDramStage)):
            return _rp.resolve_engine_runtime(s.engine)
    return ""


def resolve_sparsity(cfg: AcceleratorConfig, op: Op) -> SparsityConfig:
    """Per-op N:M override (layer-wise sparsity ratios)."""
    sp = cfg.sparsity
    if op.sparsity_nm is not None:
        sp = SparsityConfig(enabled=True, n=op.sparsity_nm[0],
                            m=op.sparsity_nm[1], row_wise=sp.row_wise,
                            representation=sp.representation)
    return sp


def run_gemm_pipeline(cfg: AcceleratorConfig, op: Op,
                      pipeline: Sequence[Stage],
                      ert: ERT = DEFAULT_ERT) -> OpContext:
    ctx = OpContext(cfg=cfg, op=op, ert=ert, sp=resolve_sparsity(cfg, op))
    for stage in pipeline:
        stage.apply(ctx)
    return ctx


def run_vector(cfg: AcceleratorConfig, op: Op,
               ert: ERT = DEFAULT_ERT) -> OpContext:
    """Vector ops bypass the array pipeline and run on the SIMD unit.

    Like the gemm path, every component — cycles, traffic, action counts —
    scales linearly with `op.count`.
    """
    core = cfg.cores[0]
    wb = cfg.memory.word_bytes
    ctx = OpContext(cfg=cfg, op=op, ert=ert, sp=cfg.sparsity)
    cyc = float(dfm.simd_cycles(op.vector_elems, core.simd_lanes,
                                core.simd_latency)) * op.count
    elems = op.vector_elems * op.count
    ctx.comp = cyc
    ctx.compute_total = cyc
    ctx.total = cyc
    ctx.sram_reads = elems
    ctx.sram_writes = elems
    ctx.dram_bytes_total = elems * wb
    counts = action_counts(cfg, cycles=cyc, macs=0.0,
                           ifmap_reads=elems, filter_reads=0.0,
                           ofmap_writes=elems, ofmap_reads=0.0,
                           dram_bytes=ctx.dram_bytes_total)
    e = energy_pj(counts, ert)
    ctx.energy_total = float(e["total"])
    ctx.energy_by_action = {k: float(v) for k, v in e.items()
                            if k != "total"}
    return ctx


# --------------------------------------------------------------------------
# Traced twins: the same stage math on jnp arrays (vmap/pjit-safe).
# --------------------------------------------------------------------------

_NO_SPILL_BYTES = 1 << 62     # "infinite" psum SRAM: legacy traced semantics


def traced_memory(sram_elems, word_bytes=2, *, ifmap_elems=None,
                  filter_elems=None, ofmap_elems=None,
                  l2_bytes=0) -> MemoryConfig:
    """A MemoryConfig whose fields may be traced arrays. With only
    `sram_elems`, reproduces the legacy traced model: both operand SRAMs
    sized to sram_elems, psums never spill."""
    wb = word_bytes
    return MemoryConfig(
        ifmap_sram_bytes=(ifmap_elems if ifmap_elems is not None
                          else sram_elems) * wb,
        filter_sram_bytes=(filter_elems if filter_elems is not None
                           else sram_elems) * wb,
        ofmap_sram_bytes=(ofmap_elems * wb if ofmap_elems is not None
                          else _NO_SPILL_BYTES),
        l2_sram_bytes=l2_bytes, word_bytes=wb)


def traced_gemm_stats(dataflow: str, M, N, K, R, C, mem: MemoryConfig,
                      bw_bytes_per_cycle) -> Dict[str, jnp.ndarray]:
    """mapping + sram + dram(fast) stages, fully traced. Every argument
    except `dataflow` may be a jnp array; `mem` fields may be arrays."""
    comp = dfm.compute_cycles(dataflow, M, N, K, R, C)
    util = dfm.pe_utilization(dataflow, M, N, K, R, C)
    sram = dfm.sram_traffic(dataflow, M, N, K, R, C)
    dram = dfm.dram_traffic(dataflow, M, N, K, R, C, mem)
    dram_elems = (dram["dram_ifmap"] + dram["dram_filter"]
                  + dram["dram_ofmap_writes"] + dram["dram_ofmap_reads"])
    dram_bytes = dram_elems * mem.word_bytes
    stall = dfm.dram_stall_cycles_simple(dram_bytes, comp,
                                         bw_bytes_per_cycle)
    return dict(compute_cycles=comp, stall_cycles=stall,
                total_cycles=comp + stall, utilization=util,
                dram_bytes=dram_bytes, dram_elems=dram_elems, **sram)


def traced_vector_stats(elems, lanes, latency, word_bytes) -> Dict[str, jnp.ndarray]:
    """SIMD sidecar, traced (per instance; callers scale by count)."""
    cyc = dfm.simd_cycles(elems, lanes, latency)
    return dict(compute_cycles=cyc, dram_bytes=elems * word_bytes)


def traced_energy_counts(*, R, C, mem: MemoryConfig, cycles, macs,
                         ifmap_reads, filter_reads, ofmap_writes,
                         ofmap_reads, dram_bytes, l2_reads=0.0,
                         row_bytes: int = 64, pes=None,
                         dim32=None) -> Dict[str, jnp.ndarray]:
    """The energy stage's action counts with array-valued config fields;
    identical formulas to `energy.action_counts` (shared core). `mem` must
    carry real SRAM sizes (not the no-spill sentinel). pes/dim32 default
    to the single-core R x C values; multi-core designs pass the summed
    PE count and the mesh-wide max dimension (what `action_counts` derives
    from a concrete config)."""
    sram_kib = (mem.ifmap_sram_bytes + mem.filter_sram_bytes
                + mem.ofmap_sram_bytes) / 1024.0
    if pes is None:
        pes = R * C
    if dim32 is None:
        dim32 = jnp.maximum(R, C) / 32.0
    return action_counts_raw(
        pes=pes, dim32=dim32, sram_kib=sram_kib,
        word_bytes=mem.word_bytes, cycles=cycles, macs=macs,
        ifmap_reads=ifmap_reads, filter_reads=filter_reads,
        ofmap_writes=ofmap_writes, ofmap_reads=ofmap_reads,
        dram_bytes=dram_bytes, l2_reads=l2_reads, row_bytes=row_bytes)


# --------------------------------------------------------------------------
# The full-pipeline traced twin: mapping -> partition -> sparsity -> sram ->
# dram[fast] -> layout with every feature expressed as data (jnp.where) or
# a static kernel-flavor parameter — what lets `repro.api` batch arbitrary
# mixed dense/sparse/layout/multicore design grids in one jit/vmap.
# --------------------------------------------------------------------------

def traced_comp_traffic(dataflow: str, M, N, K, R, C, mem: MemoryConfig, *,
                        sparsity: Optional[Dict] = None,
                        multicore: Optional[Dict] = None):
    """Effective compute cycles + (shrunk) SRAM/DRAM traffic, traced.

    Mirrors the stage pipeline's feature composition exactly: the
    partition stage overrides single-core compute when the design has
    multiple cores, and the sparsity stage overrides both (paper
    semantics: sparse runs use the single-core compressed stream).

    sparsity:  {'en', 'n', 'm', 'rw'} traced arrays (en/rw are 0/1
               selectors — no Python branching on them) plus the static
               'representation' string.
    multicore: {'rows', 'cols', 'hops'} per-core arrays (core axis last,
               length Pr*Pc), traced 'nop' cycles-per-hop, and static
               'Pr'/'Pc' grid shape.

    Returns (comp, sram dict, dram dict, filter_shrink).
    """
    comp = dfm.compute_cycles(dataflow, M, N, K, R, C)
    if multicore is not None:
        comp = best_multicore_cycles_model(
            dataflow, M, N, K, multicore["rows"], multicore["cols"],
            multicore["hops"], multicore["nop"], multicore["Pr"],
            multicore["Pc"])
    shrink = jnp.float32(1.0)
    if sparsity is not None:
        en, n, m, rw = (sparsity["en"], sparsity["n"], sparsity["m"],
                        sparsity["rw"])
        comp_sp = sparse_compute_cycles_model(dataflow, M, N, K, R, C,
                                              n, m, rw, enabled=en)
        comp = jnp.where(en, comp_sp, comp)
        orig, _, _, total = storage_bytes_model(
            M, K, n, m, rw, sparsity["representation"], mem.word_bytes,
            enabled=en)
        shrink = total / jnp.maximum(orig, 1.0)
    sram = dfm.sram_traffic(dataflow, M, N, K, R, C)
    sram = dict(sram, filter_reads=sram["filter_reads"] * shrink)
    dram = dfm.dram_traffic(dataflow, M, N, K, R, C, mem)
    dram = dict(dram, dram_filter=dram["dram_filter"] * shrink)
    return comp, sram, dram, shrink


def traced_op_stats(dataflow: str, M, N, K, R, C, mem: MemoryConfig,
                    bw_bytes_per_cycle, *,
                    sparsity: Optional[Dict] = None,
                    multicore: Optional[Dict] = None,
                    layout: Optional[Dict] = None) -> Dict[str, jnp.ndarray]:
    """Traced twin of the full fast-fidelity gemm pipeline (per op
    instance; callers scale by count). `layout`: {'cfg': LayoutConfig
    (static), 'r_cap': static bound on R}, or None to skip the layout
    stage — layout on/off is a static kernel flavor (the Study plan
    groups enabled and disabled cells separately, so disabled groups pay
    nothing). See `traced_comp_traffic` for the sparsity/multicore
    parameter shapes."""
    import jax
    comp, sram, dram, shrink = traced_comp_traffic(
        dataflow, M, N, K, R, C, mem, sparsity=sparsity,
        multicore=multicore)
    dram_elems = (dram["dram_ifmap"] + dram["dram_filter"]
                  + dram["dram_ofmap_writes"] + dram["dram_ofmap_reads"])
    dram_bytes = dram_elems * mem.word_bytes
    stall = dfm.dram_stall_cycles_simple(dram_bytes, comp,
                                         bw_bytes_per_cycle)
    extra = jnp.zeros_like(comp)
    if layout is not None:
        lcfg, r_cap = layout["cfg"], layout["r_cap"]
        stride = jnp.maximum(1.0, jnp.float32(1.0) * N)

        def one_op(comp_, stride_):
            return streaming_layout_extra(lcfg, R, comp_, stride_,
                                          mem.word_bytes, r_cap=r_cap)

        extra = (one_op(comp, stride) if jnp.ndim(comp) == 0
                 else jax.vmap(one_op)(comp, jnp.broadcast_to(
                     stride, jnp.shape(comp))))
    return dict(compute_cycles=comp, stall_cycles=stall,
                layout_extra_cycles=extra, dram_bytes=dram_bytes,
                dram_elems=dram_elems, filter_shrink=shrink, **sram)
