"""Spatial and spatio-temporal multi-core partitioning (paper Sec. III-A).

Schemes for a Pr x Pc core grid over mapping dims (Sr, Sc, T):

  spatial (Eq. 1): split Sr over Pr, Sc over Pc
      cycles = (2R + C + T - 2) * ceil(Sr/(Pr*R)) * ceil(Sc/(Pc*C))
  st1     (Eq. 2): split Sr over Pr, T over Pc
      cycles = (2R + C + ceil(T/Pc) - 2) * ceil(Sr/(Pr*R)) * ceil(Sc/C)
  st2     (Eq. 3): split Sc over Pc, T over Pr
      cycles = (2R + C + ceil(T/Pr) - 2) * ceil(Sr/R) * ceil(Sc/(Pc*C))

Memory footprints count L1-resident elements summed over cores; `dedup=True`
models the shared L2 (Sec. III-B) which stores each unique element once.
Temporal splits of a reduction dim (os dataflow: T = K) additionally require
cross-core psum reduction, reported as `reduce_elems`.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Tuple

import jax.numpy as jnp

from .dataflow import cdiv, map_gemm

SCHEMES = ("spatial", "st1", "st2")


def partition_cycles(scheme: str, R: int, C: int, Sr, Sc, T, Pr: int, Pc: int):
    if scheme == "spatial":
        return (2 * R + C + T - 2) * cdiv(Sr, Pr * R) * cdiv(Sc, Pc * C)
    if scheme == "st1":
        return (2 * R + C + cdiv(T, Pc) - 2) * cdiv(Sr, Pr * R) * cdiv(Sc, C)
    if scheme == "st2":
        return (2 * R + C + cdiv(T, Pr) - 2) * cdiv(Sr, R) * cdiv(Sc, Pc * C)
    raise ValueError(f"unknown scheme {scheme!r}")


def partition_footprint(scheme: str, dataflow: str, Sr, Sc, T,
                        Pr: int, Pc: int, dedup: bool = False) -> Dict:
    """L1 footprint (elements) summed over all cores + psum reduction traffic.

    Mapping-space operand shapes: stationary (Sr x Sc), streamed-in (Sr x T),
    streamed-out (Sc x T).
    """
    stat = 1.0 * Sr * Sc
    op_in = 1.0 * Sr * T
    op_out = 1.0 * Sc * T
    reduce_elems = 0.0
    if scheme == "spatial":
        f_stat, f_in, f_out = stat, Pc * op_in, Pr * op_out
    elif scheme == "st1":                      # Sr spatial, T temporal
        f_stat, f_in, f_out = Pc * stat, op_in, Pr * op_out
        if dataflow == "os":                   # T = K: psums reduced over Pc
            reduce_elems = (Pc - 1) * stat
    else:                                      # st2: Sc spatial, T temporal
        f_stat, f_in, f_out = Pr * stat, Pc * op_in, op_out
        if dataflow == "os":
            reduce_elems = (Pr - 1) * stat
    if dedup:                                  # shared L2 holds each once
        f_stat, f_in, f_out = stat, op_in, op_out
    return dict(stationary=f_stat, stream_in=f_in, stream_out=f_out,
                total=f_stat + f_in + f_out, reduce_elems=reduce_elems)


def factor_pairs(n: int) -> List[Tuple[int, int]]:
    return [(p, n // p) for p in range(1, n + 1) if n % p == 0]


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    scheme: str
    Pr: int
    Pc: int
    cycles: float
    footprint: float          # no-L2 (L1-replicated) footprint, elements
    footprint_l2: float       # with shared-L2 dedup
    reduce_elems: float


def enumerate_plans(dataflow: str, M, N, K, R: int, C: int,
                    num_cores: int) -> List[PartitionPlan]:
    Sr, Sc, T = map_gemm(dataflow, M, N, K)
    plans = []
    for scheme in SCHEMES:
        for Pr, Pc in factor_pairs(num_cores):
            cyc = partition_cycles(scheme, R, C, Sr, Sc, T, Pr, Pc)
            fp = partition_footprint(scheme, dataflow, Sr, Sc, T, Pr, Pc)
            fp2 = partition_footprint(scheme, dataflow, Sr, Sc, T, Pr, Pc,
                                      dedup=True)
            plans.append(PartitionPlan(scheme, Pr, Pc, float(cyc),
                                       float(fp["total"]), float(fp2["total"]),
                                       float(fp["reduce_elems"])))
    return plans


def best_plan(dataflow: str, M, N, K, R: int, C: int, num_cores: int,
              objective: str = "cycles") -> PartitionPlan:
    """objective: 'cycles' (tiebreak footprint) or 'footprint' (tiebreak cycles)."""
    plans = enumerate_plans(dataflow, M, N, K, R, C, num_cores)
    if objective == "cycles":
        return min(plans, key=lambda p: (p.cycles, p.footprint))
    if objective == "footprint":
        return min(plans, key=lambda p: (p.footprint, p.cycles))
    raise ValueError(objective)
