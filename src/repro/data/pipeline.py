"""Deterministic, resumable, shardable synthetic LM data pipeline.

Every batch is a pure function of (seed, step): restart from a checkpoint at
step k reproduces the identical stream with no iterator state to persist —
the property that makes checkpoint/restart exact at 1000-node scale. Batches
are generated host-side per data shard (each host materializes only its
shard rows) and carry a loss mask.

The token stream is a mixture of Zipf-distributed ids with Markov-ish
repetition so a real model exhibits a decreasing loss curve (examples/
train_lm.py) rather than memorizing uniform noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.3


class SyntheticLMDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish categorical over a capped alphabet (cheap + heavy-tailed)
        alpha = min(cfg.vocab, 4096)
        w = 1.0 / np.arange(1, alpha + 1) ** cfg.zipf_a
        self._probs = w / w.sum()
        self._alpha = alpha

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        """Rows [shard::num_shards] of the global batch for `step`."""
        cfg = self.cfg
        rows = cfg.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard]))
        base = rng.choice(self._alpha, size=(rows, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # Markov repetition: with prob repeat_p, copy the previous token
        rep = rng.random((rows, cfg.seq_len)) < cfg.repeat_p
        toks = base.copy()
        for t in range(1, cfg.seq_len + 1):
            toks[:, t] = np.where(rep[:, t - 1], toks[:, t - 1], toks[:, t])
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "loss_mask": np.ones((rows, cfg.seq_len), np.float32),
        }

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self.batch_at(step, 0, 1)


def make_batch_specs(cfg, *, seq: int, batch: int, mode: str = "train"
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one step's inputs (dry-run path).

    cfg: ModelConfig. Frontends are stubs: audio provides precomputed frame
    embeddings, vlm provides patch embeddings (DESIGN.md §4).
    """
    i32 = jnp.int32
    dt = jnp.dtype(cfg.param_dtype)
    sds = jax.ShapeDtypeStruct
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if mode in ("train", "prefill"):
        out["tokens"] = sds((batch, seq), i32)
        if mode == "train":
            out["labels"] = sds((batch, seq), i32)
            out["loss_mask"] = sds((batch, seq), jnp.float32)
        if cfg.family == "audio":
            out["frames"] = sds((batch, seq, cfg.d_model), dt)
        if cfg.family == "vlm":
            out["patches"] = sds((batch, cfg.frontend_tokens, cfg.d_model), dt)
    elif mode == "decode":
        out["token"] = sds((batch, 1), i32)
    else:
        raise ValueError(mode)
    return out
