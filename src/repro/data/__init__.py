from .pipeline import DataConfig, SyntheticLMDataset, make_batch_specs
