"""repro: SCALE-Sim v3 reproduction — a JAX-native, vectorizable
cycle-accurate systolic accelerator simulator plus the workload plane
(models/launchers) it analyzes end to end.

Public simulation API lives in `repro.api` (Simulator facade); the lower
stage/engine layer in `repro.core`. See DESIGN.md for the map.
"""
from . import compat  # noqa: F401  (installs jax API shims on old jax)
