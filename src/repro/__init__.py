"""repro: SCALE-Sim v3 reproduction — a JAX-native, vectorizable
cycle-accurate systolic accelerator simulator plus the workload plane
(models/launchers) it analyzes end to end.

Public simulation API lives in `repro.api` (Simulator facade); the lower
stage/engine layer in `repro.core`. See DESIGN.md for the map.
"""
from . import compat  # noqa: F401  (installs jax API shims on old jax)

# Trace toolchain at the top level: the legacy synthetic generators from
# core.dram plus the dataflow-aware repro.trace subsystem.
from .core.dram import (linear_trace, strided_trace,  # noqa: E402,F401
                        tile_prefetch_trace)
from .trace import (TraceSpec, gemm_request_stream,  # noqa: E402,F401
                    gemm_trace_stats, multicore_contention, trace_op,
                    trace_op_stats)

__all__ = [
    "TraceSpec", "gemm_request_stream", "gemm_trace_stats", "linear_trace",
    "multicore_contention", "strided_trace", "tile_prefetch_trace",
    "trace_op", "trace_op_stats",
]
