"""Bounded retries with exponential backoff + deterministic jitter.

The farm's transient-I/O hardening: every durable write in the
claim/execute/write-result path retries through here, so an injected
(or real) ENOSPC/EIO burst degrades to a short stall instead of a lost
shard. Jitter comes from a module-level seeded RNG — retry timing never
perturbs a fault schedule's decision sequence (the plan has its own
RNG), and backoff sequences are reproducible across runs.
"""
from __future__ import annotations

import random
import time
from typing import Callable, Sequence, Tuple, Type, TypeVar

__all__ = ["backoff_delays", "with_retries"]

T = TypeVar("T")

# deterministic jitter source, independent of any FaultPlan RNG
_JITTER = random.Random(0x5eed)

DEFAULT_RETRIES = 5
DEFAULT_BASE = 0.002          # seconds; doubles per attempt
DEFAULT_FACTOR = 2.0


def backoff_delays(retries: int = DEFAULT_RETRIES,
                   base: float = DEFAULT_BASE,
                   factor: float = DEFAULT_FACTOR,
                   rng: random.Random = _JITTER) -> Sequence[float]:
    """Exponential backoff schedule with multiplicative jitter in
    [0.5, 1.5) — bounded, monotone in expectation, never zero."""
    return [base * (factor ** k) * (0.5 + rng.random())
            for k in range(retries)]


def with_retries(fn: Callable[[], T], *,
                 retries: int = DEFAULT_RETRIES,
                 base: float = DEFAULT_BASE,
                 factor: float = DEFAULT_FACTOR,
                 retry_on: Tuple[Type[BaseException], ...] = (OSError,),
                 sleep: Callable[[float], None] = time.sleep) -> T:
    """Call `fn`; on a `retry_on` exception, back off and retry up to
    `retries` times. The final failure re-raises the last exception —
    callers decide whether a persistently-failing write is fatal (a
    shard result) or best-effort (a cache entry, a heartbeat)."""
    delays = backoff_delays(retries, base, factor)
    for delay in delays:
        try:
            return fn()
        except retry_on:
            sleep(delay)
    return fn()
