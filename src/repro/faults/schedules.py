"""The three CI-gated chaos schedules (and their registry).

Each factory returns a seeded `FaultPlan` whose rules are **bounded**
(`times` caps everywhere): a chaos run is guaranteed to stop injecting,
so termination reduces to the farm's own liveness — which is what the
soak gates. Under every schedule the farm run of a study must terminate
AND produce a frame bit-identical per column to the fault-free local
`Study.run()` (at-least-once delivery + idempotent folding + a shared
dedup cache make re-execution invisible in the output).

    worker-kills   workers die right after claiming and right before
                   acking; lease expiry requeues, duplicates fold once
    torn-writes    ENOSPC/EIO bursts on put/result/cache/heartbeat
                   writes plus torn result and status files; retries +
                   reader-side recovery (result-patience re-enqueue,
                   manifest status rebuild, cache-miss degradation)
    lease-storms   the lease clock jumps forward so healthy in-flight
                   shards requeue while their owner is still finishing;
                   idempotent per-shard folding keeps exactly one result
"""
from __future__ import annotations

from typing import Callable, Dict, List

from .plan import FaultPlan, FaultRule

__all__ = ["CHAOS_SCHEDULES", "chaos_schedule"]


def worker_kills(seed: int = 0) -> FaultPlan:
    return FaultPlan(seed, {
        # claimed-kills (no result yet) force a lease-expiry requeue
        # and full re-execution; pre-ack kills leave a durable result
        # plus an orphan lease the broker must retire
        "worker.claimed": FaultRule("crash", p=0.6, times=3),
        "worker.pre_ack": FaultRule("crash", p=0.35, times=2),
    })


def torn_writes(seed: int = 0) -> FaultPlan:
    return FaultPlan(seed, {
        "spool.put": [FaultRule("os_error", p=0.4, times=4),
                      FaultRule("torn", p=0.3, times=2)],
        "worker.result": [FaultRule("os_error", p=0.4, times=4),
                          FaultRule("torn", p=0.5, times=2)],
        "broker.status": FaultRule("torn", p=0.3, times=3),
        "cache.store": [FaultRule("corrupt", p=0.4, times=3),
                        FaultRule("os_error", p=0.4, times=3)],
        "worker.heartbeat": FaultRule("os_error", p=0.5, times=4),
    })


def lease_storms(seed: int = 0) -> FaultPlan:
    return FaultPlan(seed, {
        # every clock read during a storm window sees a huge skew, so
        # all claimed shards look stale at once and requeue mid-flight
        "clock": FaultRule("skew", skew=1e7, p=0.5, times=6),
        # a claimed-kill guarantees at least one shard is alive only as
        # a lease when the storm hits — it must requeue to complete
        "worker.claimed": FaultRule("crash", p=0.4, times=2),
        "worker.pre_ack": FaultRule("crash", p=0.3, times=1),
    })


CHAOS_SCHEDULES: Dict[str, Callable[[int], FaultPlan]] = {
    "worker-kills": worker_kills,
    "torn-writes": torn_writes,
    "lease-storms": lease_storms,
}


def chaos_schedule(name: str, seed: int = 0) -> FaultPlan:
    if name not in CHAOS_SCHEDULES:
        raise KeyError(f"unknown chaos schedule {name!r}; "
                       f"available: {sorted(CHAOS_SCHEDULES)}")
    return CHAOS_SCHEDULES[name](seed)


def schedule_names() -> List[str]:
    return sorted(CHAOS_SCHEDULES)
