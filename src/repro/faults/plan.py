"""Deterministic, seeded fault plans: the chaos plane's schedule object.

A `FaultPlan` is a seeded RNG plus a set of named **injection sites** —
the filesystem/process seams the farm and the Study executor already
route through (`repro.faults.fs`). Production code never imports this
module's internals; it calls the `fs` shims, which consult the active
plan (if any) and otherwise cost one global-`None` check.

Determinism contract: a plan owns one `random.Random(seed)` consumed in
decision order, so the same seed driving the same call sequence replays
the exact same fault schedule — which is what lets the chaos soak and
the synchronous farm tests assert *bit-identical* outcomes under faults
rather than merely "it didn't crash".

Sites wired in this repo (see DESIGN.md "Failure semantics" for the
full site x fault x expected-behavior matrix)::

    spool.put          FileSpool.put staging write + replace
    worker.result      shard result file write
    worker.claimed     crash point right after a shard claim
    worker.pre_ack     crash point after the result write, before ack
    worker.heartbeat   heartbeat writes
    broker.status      per-study status.json writes
    broker.manifest    per-study manifest.json writes
    broker.spec        spec.json writes
    broker.quarantine  broker-written quarantine shard results
    cache.store        Study cell-cache writes (study.py::_cache_store)
    clock              lease clock reads (FileSpool stale-claim ages)

Fault kinds:

    os_error   the op raises a transient ``OSError`` (disk-full, EIO)
    torn       a write lands truncated (reader sees invalid JSON)
    corrupt    a write lands as garbage bytes (valid file, junk content)
    crash      ``InjectedCrash`` is raised — simulated process death
    skew       ``fs.now()`` returns ``time.time() + skew`` (lease storms)

Activation: ``with plan.active(): ...`` for in-process (synchronous
tests, the chaos driver), or the ``REPRO_FAULTS`` environment variable
(``plan.to_json()``) for real multi-process fleets — each subprocess
builds its own plan from the env, seeded independently deterministic.
"""
from __future__ import annotations

import contextlib
import dataclasses
import errno
import fnmatch
import json
import os
import random
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

__all__ = ["FAULT_KINDS", "FaultPlan", "FaultRule", "InjectedCrash",
           "active_plan", "deactivate", "install"]

FAULT_KINDS = ("os_error", "torn", "corrupt", "crash", "skew")

ENV_VAR = "REPRO_FAULTS"


class InjectedCrash(BaseException):
    """Simulated process death at a crash point.

    Deliberately a ``BaseException`` (like ``KeyboardInterrupt``): the
    worker's and Study executor's ``except Exception`` guards must NOT
    absorb a simulated kill — the whole point is that the process dies
    mid-protocol and the farm's lease/requeue machinery recovers.
    """


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injectable fault at a site (or site glob pattern).

    p:      probability per eligible call (drawn from the plan's RNG —
            every eligible call consumes exactly one draw, pass or fail,
            so schedules replay deterministically).
    times:  cap on total injections for this rule (None = unlimited).
            Bounded rules are what make chaos runs provably terminate.
    after:  skip the first `after` matching calls (hit the Nth write).
    err:    errno for `os_error` faults.
    skew:   seconds added to `fs.now()` for `skew` faults.
    """
    kind: str
    p: float = 1.0
    times: Optional[int] = None
    after: int = 0
    err: int = errno.ENOSPC
    skew: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], "
                             f"got {self.p}")
        if self.times is not None and self.times < 0:
            raise ValueError(f"times must be >= 0, got {self.times}")


RulesLike = Dict[str, Union[FaultRule, Sequence[FaultRule]]]


class _RuleState:
    __slots__ = ("calls", "fired")

    def __init__(self):
        self.calls = 0
        self.fired = 0


class FaultPlan:
    def __init__(self, seed: int = 0, rules: Optional[RulesLike] = None):
        self.seed = int(seed)
        self.rules: List[Tuple[str, FaultRule]] = []
        for pattern, rs in (rules or {}).items():
            if isinstance(rs, FaultRule):
                rs = [rs]
            for r in rs:
                self.rules.append((str(pattern), r))
        self._rng = random.Random(self.seed)
        self._state = [_RuleState() for _ in self.rules]
        self._injected: Dict[str, int] = {}
        self._lock = threading.Lock()

    # ---- the decision procedure -------------------------------------------
    def decide(self, site: str,
               kinds: Optional[Sequence[str]] = None
               ) -> Optional[FaultRule]:
        """First rule that matches `site` (glob patterns allowed), is
        within its `after`/`times` window, and wins its probability
        draw. At most one rule fires per call."""
        with self._lock:
            for (pattern, rule), state in zip(self.rules, self._state):
                if kinds is not None and rule.kind not in kinds:
                    continue
                if not fnmatch.fnmatchcase(site, pattern):
                    continue
                state.calls += 1
                if state.calls <= rule.after:
                    continue
                if rule.times is not None and state.fired >= rule.times:
                    continue
                if self._rng.random() >= rule.p:
                    continue
                state.fired += 1
                key = f"{site}:{rule.kind}"
                self._injected[key] = self._injected.get(key, 0) + 1
                return rule
        return None

    # ---- activation ---------------------------------------------------------
    @contextlib.contextmanager
    def active(self):
        """Install this plan as the process-wide active plan."""
        install(self)
        try:
            yield self
        finally:
            deactivate()

    # ---- introspection ------------------------------------------------------
    def report(self) -> dict:
        """What actually fired: the chaos soak's per-schedule artifact."""
        with self._lock:
            return {"seed": self.seed,
                    "rules": len(self.rules),
                    "injected": dict(sorted(self._injected.items())),
                    "total_injected": sum(self._injected.values())}

    # ---- wire format (REPRO_FAULTS) -----------------------------------------
    def to_json(self) -> str:
        rules: Dict[str, List[dict]] = {}
        for pattern, r in self.rules:
            rules.setdefault(pattern, []).append(dataclasses.asdict(r))
        return json.dumps({"seed": self.seed, "rules": rules})

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        rules: RulesLike = {
            pattern: [FaultRule(**r) for r in rs]
            for pattern, rs in d.get("rules", {}).items()}
        return cls(seed=int(d.get("seed", 0)), rules=rules)


# ---- the process-wide active plan ---------------------------------------------

_ACTIVE: Optional[FaultPlan] = None
_ENV_CHECKED = False


def install(plan: FaultPlan) -> None:
    global _ACTIVE
    _ACTIVE = plan


def deactivate() -> None:
    global _ACTIVE, _ENV_CHECKED
    _ACTIVE = None
    _ENV_CHECKED = True      # an explicit deactivate wins over the env


def active_plan() -> Optional[FaultPlan]:
    """The installed plan; on first call, `REPRO_FAULTS` (a
    `FaultPlan.to_json()` payload) is honored so worker *subprocesses*
    of a real fleet inherit the chaos schedule."""
    global _ACTIVE, _ENV_CHECKED
    if _ACTIVE is None and not _ENV_CHECKED:
        _ENV_CHECKED = True
        env = os.environ.get(ENV_VAR)
        if env:
            try:
                _ACTIVE = FaultPlan.from_json(env)
            except (ValueError, TypeError, KeyError):
                _ACTIVE = None       # a bad env schedule is no schedule
    return _ACTIVE
