"""repro.faults: deterministic fault injection + the farm's hardening.

A seeded `FaultPlan` names injection sites on the filesystem/process
seams the run-farm and the Study executor already use (`repro.faults.fs`
shims — no monkeypatching), so the same schedule replays exactly. The
`repro.farm chaos` subcommand drives three CI-gated schedules
(worker-kills, torn-writes, lease-storms) and requires the resulting
frames to be bit-identical to a fault-free local `Study.run()` — the
at-least-once + idempotent-fold claim, machine-checked.
"""
from .plan import (FAULT_KINDS, FaultPlan, FaultRule, InjectedCrash,
                   active_plan, deactivate, install)
from .retry import backoff_delays, with_retries
from .schedules import CHAOS_SCHEDULES, chaos_schedule

__all__ = ["CHAOS_SCHEDULES", "FAULT_KINDS", "FaultPlan", "FaultRule",
           "InjectedCrash", "active_plan", "backoff_delays",
           "chaos_schedule", "deactivate", "install", "with_retries"]
