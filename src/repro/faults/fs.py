"""Filesystem/clock shims: the seams the fault plane injects through.

Production code (`farm/queue.py`, `farm/broker.py`, `farm/worker.py`,
`api/study.py::_cache_store`) routes its durable writes and lease-clock
reads through these functions instead of calling `os`/`time` directly —
no monkeypatching anywhere. With no active `FaultPlan` every shim is a
single global-`None` check away from the real syscall, so the hot path
cost is nil; with a plan installed, each call consults the plan's
seeded schedule and may raise a transient `OSError`, land a torn or
garbage write, simulate a process kill (`InjectedCrash`), or skew the
clock.

`atomic_write_json` is the one durable-write primitive the whole farm
uses: temp file + `os.replace`, transient `OSError`s retried with
backoff + jitter (`repro.faults.retry`). Torn/corrupt faults are
deliberately NOT retried — they model silent corruption that the
*reader-side* hardening (tolerant parsers, broker re-fold/re-enqueue
recovery) must absorb, and the chaos soak exercises exactly that.
"""
from __future__ import annotations

import json
import os
import time
import uuid
from typing import Optional

from . import plan as _plan
from .retry import with_retries

__all__ = ["atomic_write_json", "crash_point", "now", "replace",
           "utime", "write_text"]


def _decide(site: str, kinds) -> Optional[_plan.FaultRule]:
    p = _plan.active_plan()
    return p.decide(site, kinds) if p is not None else None


# ---- crash points -------------------------------------------------------------

def crash_point(site: str) -> None:
    """Raise `InjectedCrash` if the active plan schedules a kill here.
    A no-op without a plan (and for sites the plan doesn't name)."""
    rule = _decide(site, ("crash",))
    if rule is not None:
        raise _plan.InjectedCrash(site)


# ---- the lease clock ----------------------------------------------------------

def now(site: str = "clock") -> float:
    """`time.time()`, plus any scheduled skew — the only clock the
    spool's lease-age computations read, so a `skew` rule turns every
    claimed shard stale at once (a lease storm)."""
    rule = _decide(site, ("skew",))
    return time.time() + (rule.skew if rule is not None else 0.0)


# ---- primitive ops ------------------------------------------------------------

def write_text(path: str, text: str, *, site: str) -> None:
    """Write `text` to `path`, subject to os_error/torn/corrupt faults.
    A torn write lands a truncated prefix; a corrupt write lands junk
    bytes — both *succeed* from the writer's point of view."""
    rule = _decide(site, ("os_error", "torn", "corrupt"))
    if rule is not None and rule.kind == "os_error":
        raise OSError(rule.err, os.strerror(rule.err), path)
    if rule is not None and rule.kind == "torn":
        text = text[:max(1, len(text) // 3)]
    elif rule is not None and rule.kind == "corrupt":
        text = '{"__corrupt__": tr'
    with open(path, "w") as f:
        f.write(text)


def replace(src: str, dst: str, *, site: str) -> None:
    rule = _decide(site, ("os_error",))
    if rule is not None:
        raise OSError(rule.err, os.strerror(rule.err), dst)
    os.replace(src, dst)


def utime(path: str, *, site: str) -> None:
    rule = _decide(site, ("os_error",))
    if rule is not None:
        raise OSError(rule.err, os.strerror(rule.err), path)
    os.utime(path)


# ---- the durable-write primitive ----------------------------------------------

def atomic_write_json(path: str, obj, *, site: str = "fs.write",
                      indent: Optional[int] = 1,
                      retries: int = 5) -> None:
    """Temp-file + `os.replace` JSON write with bounded retries.

    Readers see all-or-nothing (modulo injected torn/corrupt faults,
    which model post-write media corruption and are recovered on the
    read side). A crash fault at `site` fires before any bytes land —
    the caller's protocol must tolerate "wrote nothing, died"."""
    crash_point(site)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    text = json.dumps(obj, indent=indent)

    def _write() -> None:
        tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:6]}"
        try:
            write_text(tmp, text, site=site)
            replace(tmp, path, site=site)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    with_retries(_write, retries=retries)
