"""mixtral-8x7b [moe]: 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=32000, 8 experts top-2, sliding-window attention 4096
[arXiv:2401.04088]."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="mixtral-8x7b", family="moe", layers=32, d_model=4096,
    heads=32, kv_heads=8, d_ff=14336, vocab=32000,
    num_experts=8, top_k=2, attn_window=4096, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=64, heads=4, kv_heads=2, d_ff=128, vocab=512,
    num_experts=4, top_k=2, attn_window=32)
