"""internvl2-1b [vlm]: Qwen2-0.5B-shaped LM backbone: 24L, d_model=896,
14H (GQA kv=2), d_ff=4864, vocab=151655 [arXiv:2404.16821]. InternViT
frontend is a STUB: input_specs provide 256 precomputed patch embeddings
prepended to the text sequence."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="internvl2-1b", family="vlm", layers=24, d_model=896,
    heads=14, kv_heads=2, d_ff=4864, vocab=151655, qkv_bias=True,
    frontend="vision", frontend_tokens=256, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=56, heads=7, kv_heads=1, d_ff=112, vocab=512,
    frontend_tokens=16)
