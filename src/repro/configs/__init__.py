"""Architecture registry: one module per assigned architecture.

Each module exports ARCH (exact published config) and SMOKE (reduced config
of the same family for CPU tests). `get_config(id)` / `list_archs()` are the
public API; shape cells live in `shapes.py`.
"""
from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig
from .shapes import SHAPES, cell_mode, runnable_cells, skip_reason

_ARCH_MODULES = [
    "whisper_base", "mixtral_8x7b", "granite_moe_3b_a800m", "yi_34b",
    "qwen2_72b", "qwen2_1_5b", "glm4_9b", "zamba2_7b", "xlstm_1_3b",
    "internvl2_1b",
]

_IDS = {m.replace("_", "-"): m for m in _ARCH_MODULES}
# canonical ids as assigned
_CANON = {
    "whisper-base": "whisper_base",
    "mixtral-8x7b": "mixtral_8x7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "yi-34b": "yi_34b",
    "qwen2-72b": "qwen2_72b",
    "qwen2-1.5b": "qwen2_1_5b",
    "glm4-9b": "glm4_9b",
    "zamba2-7b": "zamba2_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-1b": "internvl2_1b",
}


def list_archs() -> List[str]:
    return list(_CANON.keys())


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod_name = _CANON.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.SMOKE if smoke else mod.ARCH
