"""zamba2-7b [hybrid]: 81 blocks, d_model=3584, Mamba2 backbone
(ssm_state=64) with a SHARED full-attention block (32H, kv=32 i.e. MHA,
d_ff=14336 MLP) applied every 6th block [arXiv:2411.15242]. For long_500k
the shared block uses a 4096 sliding window (DESIGN.md adaptation)."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="zamba2-7b", family="hybrid", layers=81, d_model=3584,
    heads=32, kv_heads=32, d_ff=14336, vocab=32000,
    attn_every=6, attn_window=4096, ssm_state=64, ssm_headdim=64,
    rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    ARCH, layers=7, d_model=64, heads=4, kv_heads=4, d_ff=128, vocab=512,
    attn_every=3, attn_window=32, ssm_state=16, ssm_headdim=32)
