"""yi-34b [dense]: 60L, d_model=7168, 56H (GQA kv=8), d_ff=20480,
vocab=64000, llama-arch [arXiv:2403.04652]."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="yi-34b", family="dense", layers=60, d_model=7168,
    heads=56, kv_heads=8, d_ff=20480, vocab=64000, rope_theta=5e6,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=56, heads=7, kv_heads=1, d_ff=128, vocab=512)
