"""glm4-9b [dense]: 40L, d_model=4096, 32H (GQA kv=2), d_ff=13696,
vocab=151552, RoPE [hf:THUDM/glm-4-9b]."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="glm4-9b", family="dense", layers=40, d_model=4096,
    heads=32, kv_heads=2, d_ff=13696, vocab=151552, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=64, heads=4, kv_heads=1, d_ff=128, vocab=512)
