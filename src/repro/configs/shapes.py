"""Assigned input-shape cells (seq_len x global_batch) and skip policy.

  train_4k    : train_step,   seq 4096,   batch 256
  prefill_32k : prefill_step, seq 32768,  batch 32
  decode_32k  : decode_step,  1 new token, 32k KV cache, batch 128
  long_500k   : decode_step,  524288 context, batch 1 — sub-quadratic archs
                only (SSM / hybrid / windowed attention); full-attention
                archs are skipped per DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}


def cell_mode(shape_id: str) -> str:
    return SHAPES[shape_id]["mode"]


def skip_reason(cfg, shape_id: str) -> Optional[str]:
    if shape_id == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 500k-context decode has no "
                "sub-quadratic state; skipped per DESIGN.md")
    return None


def runnable_cells(cfg) -> List[str]:
    return [s for s in SHAPES if skip_reason(cfg, s) is None]
