"""granite-moe-3b-a800m [moe]: 32L, d_model=1536, 24H (GQA kv=8),
per-expert d_ff=512, vocab=49155, MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base]. The assignment's structured
field says 40e top-8 (matching the hf config); the prose "32 experts" is
inconsistent with both and ignored (DESIGN.md §4)."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="granite-moe-3b-a800m", family="moe", layers=32, d_model=1536,
    heads=24, kv_heads=8, d_ff=512, vocab=49155,
    num_experts=40, top_k=8, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=48, heads=4, kv_heads=2, d_ff=32, vocab=512,
    num_experts=8, top_k=4)
