"""whisper-base [audio]: enc-dec, 6L encoder + 6L decoder (spec: 6L),
d_model=512, 8H (kv=8), d_ff=2048, vocab=51865 [arXiv:2212.04356].
Conv audio frontend is a STUB: input_specs provide precomputed frame
embeddings (B, L, d_model)."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="whisper-base", family="audio", layers=12, encoder_layers=6,
    d_model=512, heads=8, kv_heads=8, d_ff=2048, vocab=51865,
    rope_theta=1e4, frontend="audio",
)

SMOKE = dataclasses.replace(
    ARCH, layers=4, encoder_layers=2, d_model=64, heads=4, kv_heads=4,
    d_ff=128, vocab=512)
