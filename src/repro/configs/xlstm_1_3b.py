"""xlstm-1.3b [ssm]: 48 blocks, d_model=2048, 4H (kv=4), d_ff=0 (blocks
carry their own 2x up-projection), vocab=50304; mLSTM blocks with an sLSTM
block every 8th [arXiv:2405.04517]."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="xlstm-1.3b", family="ssm", layers=48, d_model=2048,
    heads=4, kv_heads=4, d_ff=0, vocab=50304, slstm_every=8,
    head_dim=512,
)

SMOKE = dataclasses.replace(
    ARCH, layers=8, d_model=64, heads=2, kv_heads=2, vocab=512,
    slstm_every=4, head_dim=32)
