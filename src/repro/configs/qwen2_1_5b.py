"""qwen2-1.5b [dense]: 28L, d_model=1536, 12H (GQA kv=2), d_ff=8960,
vocab=151936, QKV bias [arXiv:2407.10671]. 12 heads are not divisible by
TP=16 -> attention uses the sequence-sharded fallback (models/attention.py)."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="qwen2-1.5b", family="dense", layers=28, d_model=1536,
    heads=12, kv_heads=2, d_ff=8960, vocab=151936, qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=48, heads=6, kv_heads=2, d_ff=96, vocab=512)
