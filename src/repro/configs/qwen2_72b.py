"""qwen2-72b [dense]: 80L, d_model=8192, 64H (GQA kv=8), d_ff=29568,
vocab=152064, QKV bias [arXiv:2407.10671]."""
import dataclasses
from ..models.config import ModelConfig

ARCH = ModelConfig(
    arch_id="qwen2-72b", family="dense", layers=80, d_model=8192,
    heads=64, kv_heads=8, d_ff=29568, vocab=152064, qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    ARCH, layers=2, d_model=64, heads=4, kv_heads=2, d_ff=128, vocab=512)
