"""Elastic remesh planning: map a (possibly shrunken) device fleet to a
mesh shape + per-device batch + gradient accumulation that preserves the
global batch size.

Policy (paper-scale training): keep tensor parallelism as wide as the fleet
allows (shrink TP last, halving), spread the rest over data parallelism,
and absorb lost data parallelism with gradient accumulation so the global
batch -- and therefore the training trajectory -- is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dp: int
    tp: int
    per_device_batch: int
    grad_accum: int

    @property
    def global_batch(self) -> int:
        return self.per_device_batch * self.dp * self.grad_accum


def plan_elastic_remesh(n_devices: int, *, global_batch: int, tp: int = 1,
                        prefer_pod: Optional[int] = None,
                        max_per_device_batch: int = 8) -> ElasticPlan:
    """Plan a mesh for `n_devices` that keeps `global_batch` intact.

    prefer_pod: split the data axis into (pod, data) when the pod count
    divides the data parallelism (multi-pod meshes, launch/mesh.py).
    """
    if n_devices < 1:
        raise ValueError("need at least one device")
    tp_eff = max(1, min(int(tp), n_devices))
    while n_devices % tp_eff:
        tp_eff //= 2
    dp = n_devices // tp_eff

    per_seq = max(1, -(-global_batch // dp))       # batch rows per DP rank
    accum = max(1, -(-per_seq // max_per_device_batch))
    pdb = max(1, -(-per_seq // accum))

    if prefer_pod and prefer_pod > 1 and dp % prefer_pod == 0 \
            and dp > prefer_pod:
        shape: Tuple[int, ...] = (prefer_pod, dp // prefer_pod, tp_eff)
        axes: Tuple[str, ...] = ("pod", "data", "model")
    else:
        shape = (dp, tp_eff)
        axes = ("data", "model")
    return ElasticPlan(mesh_shape=shape, mesh_axes=axes, dp=dp, tp=tp_eff,
                       per_device_batch=pdb, grad_accum=accum)
