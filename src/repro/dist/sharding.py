"""Mesh context: one object naming the mesh axes and the logical->physical
axis rules used by every model, launcher and test.

Axis conventions (launch/mesh.py):
  single pod : (data, model)
  multi-pod  : (pod, data, model)   -- "pod" is an outer data-parallel axis

Logical parameter axes (models/params.ParamDef.logical):
  "fsdp"   -> the FSDP weight-shard axis ("data")
  "tp"     -> the tensor-parallel axis ("model")
  "batch"  -> all data-parallel axes (("pod", "data") when multi-pod)
  "kv_len" -> cache length sharded over the model axis (decode caches)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Everything the model stack needs to know about the device mesh."""
    mesh: jax.sharding.Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp_axis: Optional[str] = "data"
    tp_axis: Optional[str] = "model"

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.mesh.axis_names

    @property
    def dp(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis] if self.tp_axis else 1

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def make_mesh_ctx(mesh: jax.sharding.Mesh) -> MeshCtx:
    """Build a MeshCtx from a mesh created by launch/mesh.py (or any mesh
    using the data/model[/pod] naming convention)."""
    names = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    return MeshCtx(
        mesh=mesh,
        dp_axes=dp_axes or (names[0],),
        fsdp_axis="data" if "data" in names else None,
        tp_axis="model" if "model" in names else None,
    )


def logical_to_spec(ctx: MeshCtx, *logical: Optional[str]) -> Tuple[AxisEntry, ...]:
    """Map logical axis names to physical mesh axes (one entry per dim).

    Unknown names map to None (replicated) so new logical axes degrade
    gracefully instead of crashing the launchers.
    """
    rules = {
        "fsdp": ctx.fsdp_axis,
        "tp": ctx.tp_axis,
        "batch": ctx.dp_axes if len(ctx.dp_axes) > 1 else
                 (ctx.dp_axes[0] if ctx.dp_axes else None),
        "kv_len": ctx.tp_axis,
    }
    return tuple(rules.get(a) if a is not None else None for a in logical)
