"""Straggler detection over per-host step times (paper-scale training runs
lose whole pods to one slow host; the trainer remeshes around it).

Hosts report wall-clock step durations via `record`; a host is a straggler
once its last `patience` samples all exceed `threshold` x the median of the
per-host means. A single-host run can never flag itself (its own median).
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List


class StragglerDetector:
    def __init__(self, threshold: float = 3.0, patience: int = 2,
                 window: int = 16):
        if threshold <= 1.0:
            raise ValueError("threshold must be > 1.0")
        self.threshold = float(threshold)
        self.patience = int(patience)
        self.window = int(window)
        self._samples: Dict[int, Deque[float]] = {}

    def record(self, host: int, seconds: float) -> None:
        self._samples.setdefault(
            int(host), collections.deque(maxlen=self.window)).append(
                float(seconds))

    def _median_of_means(self) -> float:
        means = sorted(sum(s) / len(s) for s in self._samples.values() if s)
        if not means:
            return 0.0
        mid = len(means) // 2
        if len(means) % 2:
            return means[mid]
        return 0.5 * (means[mid - 1] + means[mid])

    def stragglers(self) -> List[int]:
        med = self._median_of_means()
        if med <= 0.0:
            return []
        out = []
        for host, s in sorted(self._samples.items()):
            if len(s) < self.patience:
                continue
            recent = list(s)[-self.patience:]
            if all(x > self.threshold * med for x in recent):
                out.append(host)
        return out

    def reset(self, host: int = None) -> None:
        if host is None:
            self._samples.clear()
        else:
            self._samples.pop(int(host), None)
