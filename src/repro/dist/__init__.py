"""Distributed substrates: mesh context + logical-axis sharding rules,
straggler detection, and elastic remesh planning.

`sharding.MeshCtx` is the one object the model stack consumes: it names the
mesh axes once (data/model, optionally pod) and turns logical parameter axes
("fsdp", "tp", "batch", "kv_len") into concrete PartitionSpecs.
"""
from .elastic import ElasticPlan, plan_elastic_remesh
from .sharding import MeshCtx, logical_to_spec, make_mesh_ctx
from .straggler import StragglerDetector

__all__ = [
    "ElasticPlan", "MeshCtx", "StragglerDetector", "logical_to_spec",
    "make_mesh_ctx", "plan_elastic_remesh",
]
