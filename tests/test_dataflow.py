import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accelerator as acc
from repro.core import dataflow as dfm


def test_mapping_table():
    # paper Table II
    assert dfm.map_gemm("is", 1, 2, 3) == (3, 2, 1)
    assert dfm.map_gemm("ws", 1, 2, 3) == (3, 1, 2)
    assert dfm.map_gemm("os", 1, 2, 3) == (1, 2, 3)


def test_compute_cycles_single_fold():
    # one fold: (2R + C + T - 2)
    assert dfm.compute_cycles("ws", 16, 10, 8, 16, 16) == 2 * 16 + 16 + 10 - 2


def test_compute_cycles_matches_kernel_model():
    from repro.kernels.systolic import total_cycles_ws
    M, N, K, R, C = 32, 100, 64, 16, 16
    folds = -(-K // R) * (-(-M // C))
    per_fold = total_cycles_ws(N, R, C)
    assert dfm.compute_cycles("ws", M, N, K, R, C) == per_fold * folds


def test_utilization_bounds():
    for df in ("ws", "is", "os"):
        u = float(dfm.pe_utilization(df, 64, 128, 256, 32, 32))
        assert 0.0 < u <= 1.0


def test_sram_traffic_ws_semantics():
    t = dfm.sram_traffic("ws", 64, 128, 256, 32, 32)
    assert t["filter_reads"] == 64 * 256                 # stationary once
    assert t["ifmap_reads"] == (64 // 32) * 256 * 128    # restream per c-fold
    fr = 256 // 32
    assert t["ofmap_writes"] == fr * 64 * 128
    assert t["ofmap_reads"] == (fr - 1) * 64 * 128


def test_os_psums_stay_on_array():
    t = dfm.sram_traffic("os", 64, 128, 256, 32, 32)
    assert t["ofmap_writes"] == 64 * 128
    assert t["ofmap_reads"] == 0


def test_dram_traffic_monotone_in_sram():
    small = acc.MemoryConfig(ifmap_sram_bytes=1 << 12,
                             filter_sram_bytes=1 << 12,
                             ofmap_sram_bytes=1 << 12)
    big = acc.MemoryConfig(ifmap_sram_bytes=1 << 24,
                           filter_sram_bytes=1 << 24,
                           ofmap_sram_bytes=1 << 24)
    M, N, K = 512, 4096, 1024
    d_small = dfm.dram_traffic("ws", M, N, K, 32, 32, small)
    d_big = dfm.dram_traffic("ws", M, N, K, 32, 32, big)
    tot = lambda d: float(sum(jnp.asarray(v) for v in d.values()))
    assert tot(d_big) <= tot(d_small)
    # big SRAM: every unique element fetched once
    assert tot(d_big) == M * K + K * N + M * N


def test_gemm_summary_runs():
    cfg = acc.tpu_like_config(array=32)
    s = dfm.gemm_summary(cfg, 64, 128, 256)
    assert float(s["total_cycles"]) >= float(s["compute_cycles"])
