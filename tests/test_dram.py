import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import DramConfig
from repro.core.dram import (linear_trace, simulate_dram, strided_trace,
                             tile_prefetch_trace)


def test_roundtrip_latency_positive_and_causal():
    t, a, w = linear_trace(512)
    res = simulate_dram(t, a, w, DramConfig())
    lat = np.asarray(res.latency)
    assert (lat > 0).all()
    comp = np.asarray(res.complete)
    assert (comp >= np.asarray(t)).all()


def test_row_hits_on_streaming():
    """Consecutive addresses hit the open row buffer most of the time."""
    t, a, w = linear_trace(2048)
    res = simulate_dram(t, a, w, DramConfig(channels=1))
    assert int(res.row_hits) > 0.9 * 2048


def test_strided_causes_conflicts():
    t, a, w = strided_trace(1024, stride_bytes=1 << 16)
    res = simulate_dram(t, a, w, DramConfig(channels=1, banks_per_channel=4))
    lin = simulate_dram(*linear_trace(1024), DramConfig(channels=1,
                                                        banks_per_channel=4))
    assert int(res.row_conflicts) > int(lin.row_conflicts)
    assert float(np.mean(np.asarray(res.latency))) > \
        float(np.mean(np.asarray(lin.latency)))


def test_channel_scaling_fig9():
    """Fig. 9: throughput scales with channels for streaming traffic."""
    t, a, w = linear_trace(4096, issue_gap=0.25)
    th = []
    for ch in (1, 2, 4, 8):
        th.append(float(simulate_dram(t, a, w, DramConfig(channels=ch)
                                      ).throughput))
    assert th[1] > 1.6 * th[0]
    assert th[2] > 1.6 * th[1]
    assert th[3] > 1.5 * th[2]


def test_queue_size_fig10():
    """Fig. 10: bigger request queues absorb prefetch bursts -> fewer
    stalls; the 32 -> 128 step is the big one."""
    t, a, w = tile_prefetch_trace(tile_bytes=20 * 1024, n_tiles=64,
                                  compute_per_tile=400, gran_bytes=64)
    tot = {}
    for q in (32, 128, 512):
        res = simulate_dram(t, a, w, DramConfig(channels=2, read_queue=q,
                                                write_queue=q))
        tot[q] = float(res.total_cycles)
    assert tot[32] > tot[128] >= tot[512]


def test_conservation_bytes():
    t, a, w = linear_trace(100, gran_bytes=64)
    res = simulate_dram(t, a, w, DramConfig(), gran_bytes=64)
    assert float(res.bytes_moved) == 100 * 64


def test_dram_config_rejects_nonsense_fields():
    """Nonsensical DRAM parameters fail loudly at construction — a
    zero timing or queue depth would otherwise surface as a hang or a
    silent divide-by-zero deep inside the cycle model."""
    with pytest.raises(ValueError, match="channels"):
        DramConfig(channels=0)
    with pytest.raises(ValueError, match="banks_per_channel"):
        DramConfig(banks_per_channel=-1)
    with pytest.raises(ValueError, match="row_bytes"):
        DramConfig(row_bytes=0)
    with pytest.raises(ValueError, match="burst_bytes"):
        DramConfig(burst_bytes=0)
    for timing in ("tRCD", "tRP", "tCAS", "tBURST"):
        with pytest.raises(ValueError, match=timing):
            DramConfig(**{timing: 0})
        with pytest.raises(ValueError, match=timing):
            DramConfig(**{timing: -3})
    with pytest.raises(ValueError, match="read_queue"):
        DramConfig(read_queue=0)
    with pytest.raises(ValueError, match="write_queue"):
        DramConfig(write_queue=0)
    with pytest.raises(ValueError, match="bandwidth"):
        DramConfig(bandwidth_bytes_per_cycle=0.0)
    DramConfig()  # defaults stay valid
