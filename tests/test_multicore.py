import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig, CoreConfig, MemoryConfig
from repro.core.multicore import (best_multicore, nonuniform_split,
                                  simulate_multicore)
from repro.core.partition import partition_cycles
from repro.core.dataflow import map_gemm


def _cfg(cores, rows=2, cols=2):
    return AcceleratorConfig(cores=tuple(cores), mesh_rows=rows,
                             mesh_cols=cols)


def test_uniform_matches_partition_equations():
    cfg = AcceleratorConfig(cores=(CoreConfig(rows=32, cols=32),),
                            mesh_rows=2, mesh_cols=2)
    M, N, K = 512, 1024, 2048
    Sr, Sc, T = map_gemm("ws", M, N, K)
    r = simulate_multicore(cfg, M, N, K, "spatial")
    assert r.cycles == partition_cycles("spatial", 32, 32, Sr, Sc, T, 2, 2)


def test_nonuniform_split_equalizes():
    shares = nonuniform_split(1000, rates=[1.0, 1.0, 2.0], offsets=[0, 0, 0])
    assert sum(shares) == 1000
    assert shares[2] < shares[0]                 # slower core gets less


def test_nop_offset_shifts_work():
    near = nonuniform_split(1000, [1.0, 1.0], [0.0, 0.0])
    far = nonuniform_split(1000, [1.0, 1.0], [0.0, 500.0])
    assert far[1] < near[1]                      # farther core gets less


def test_nonuniform_split_large_totals_conserve_work():
    """Shares sum exactly to the split total within f32's integer range,
    and within an ulp (not hundreds of lost units) beyond it."""
    shares = nonuniform_split(10_000_000, [1.0, 1.0, 2.0], [0.0, 0.0, 0.0])
    assert sum(shares) == 10_000_000
    big = 100_000_000
    shares = nonuniform_split(big, [1.0, 1.0, 2.0], [0.0, 0.0, 0.0])
    assert abs(sum(shares) - big) <= 16            # f32 ulp at 1e8
    assert all(s >= 0 for s in shares)


def test_heterogeneous_cores_balanced():
    cores = [CoreConfig(rows=64, cols=64), CoreConfig(rows=16, cols=16)]
    cfg = AcceleratorConfig(cores=tuple(cores), mesh_rows=2, mesh_cols=1)
    r = simulate_multicore(cfg, 512, 2048, 4096, "spatial")
    # the big core takes more of the split dimension
    assert r.per_core_share[0] > r.per_core_share[1]
    spread = max(r.per_core_cycles) / max(min(r.per_core_cycles), 1)
    assert spread < 4.5                          # roughly balanced makespan


def test_more_cores_not_slower():
    M, N, K = 1024, 4096, 4096
    c1 = AcceleratorConfig(cores=(CoreConfig(32, 32),))
    c16 = AcceleratorConfig(cores=(CoreConfig(32, 32),), mesh_rows=4,
                            mesh_cols=4)
    r1 = best_multicore(c1, M, N, K)
    r16 = best_multicore(c16, M, N, K)
    assert r16.cycles < r1.cycles


def test_l2_capacity_check():
    mem = MemoryConfig(l2_sram_bytes=1 << 10)
    cfg = AcceleratorConfig(cores=(CoreConfig(32, 32),), mesh_rows=2,
                            mesh_cols=2, memory=mem)
    r = simulate_multicore(cfg, 2048, 2048, 2048, "spatial")
    assert not r.l2_fit and r.l2_spill_elems > 0


# ---- traceable multicore (ISSUE 5) -----------------------------------------

def test_traced_model_matches_simulate_multicore_bitexact():
    """`multicore_model` / `best_multicore_cycles_model` ARE the oracle:
    `simulate_multicore` delegates to them, so per-scheme cycles and the
    best-scheme makespan agree exactly, heterogeneous cores included."""
    import jax.numpy as jnp
    from repro.core.multicore import (best_multicore_cycles_model,
                                      multicore_model)
    cases = [
        (AcceleratorConfig(cores=(CoreConfig(32, 32),), mesh_rows=2,
                           mesh_cols=2), (512, 1024, 2048)),
        (AcceleratorConfig(cores=(CoreConfig(64, 64), CoreConfig(16, 16)),
                           mesh_rows=2, mesh_cols=1), (512, 2048, 4096)),
        (AcceleratorConfig(cores=tuple(CoreConfig(32, 32, nop_hops=h)
                                       for h in (0, 1, 1, 2)),
                           mesh_rows=2, mesh_cols=2, dataflow="os"),
         (300, 700, 900)),
    ]
    for cfg, (M, N, K) in cases:
        rows = jnp.asarray([c.rows for c in cfg.cores], jnp.float32)
        cols = jnp.asarray([c.cols for c in cfg.cores], jnp.float32)
        hops = jnp.asarray([c.nop_hops for c in cfg.cores], jnp.float32)
        for scheme in ("spatial", "st1", "st2"):
            r = simulate_multicore(cfg, M, N, K, scheme)
            mk, per_core, _ = multicore_model(
                cfg.dataflow, scheme, M, N, K, rows, cols, hops,
                cfg.nop_cycles_per_hop, cfg.mesh_rows, cfg.mesh_cols)
            assert r.cycles == float(mk)
            assert list(np.asarray(per_core)) == list(r.per_core_cycles)
        best = best_multicore(cfg, M, N, K)
        bm = best_multicore_cycles_model(
            cfg.dataflow, M, N, K, rows, cols, hops,
            cfg.nop_cycles_per_hop, cfg.mesh_rows, cfg.mesh_cols)
        assert best.cycles == float(bm)


def test_grouped_sweep_equals_looped_simulate_multicore():
    """A per-core-count batched Study over multi-core designs reproduces
    a python loop of `best_multicore` per design (the partition stage's
    oracle) on a gemm-only workload."""
    import pytest
    from repro.api import Study
    from repro.api.presets import get_preset, with_cores
    from repro.core.workloads import Op
    ops = [Op("g", 512, 768, 1024), Op("h", 256, 512, 2048, count=2.0)]
    designs = {}
    for arr in (16, 32):
        for cores in (4, 16):
            designs[f"{arr}x{arr}-{cores}c"] = with_cores(
                get_preset("tpu-like", array=arr), cores)
    res = Study().designs(designs).workloads({"w": ops}) \
                 .fidelity("fast").run()
    assert res.fraction_batched == 1.0
    for label, cfg in designs.items():
        want = sum(best_multicore(cfg, o.M, o.N, o.K).cycles * o.count
                   for o in ops)
        got = float(res.filter(design=label)["compute_cycles"][0])
        assert got == pytest.approx(want, rel=1e-6), label


def test_contention_shared_never_beats_isolated_after_refactor():
    """The shared-DRAM contention path still reports shared >= isolated
    per core after the traceable-partition refactor."""
    from repro.api.presets import get_preset
    from repro.core.multicore import contention_summary
    from repro.trace import TraceSpec
    s = contention_summary(get_preset("mcm-4x32", channels=2),
                           256, 512, 512, spec=TraceSpec(cap=1024))
    assert s["makespan_shared"] >= s["makespan_isolated"] - 1e-6
    assert s["contention_slowdown"] >= 1.0 - 1e-9
    assert s["cores"] == 4.0
