import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig, CoreConfig, MemoryConfig
from repro.core.multicore import (best_multicore, nonuniform_split,
                                  simulate_multicore)
from repro.core.partition import partition_cycles
from repro.core.dataflow import map_gemm


def _cfg(cores, rows=2, cols=2):
    return AcceleratorConfig(cores=tuple(cores), mesh_rows=rows,
                             mesh_cols=cols)


def test_uniform_matches_partition_equations():
    cfg = AcceleratorConfig(cores=(CoreConfig(rows=32, cols=32),),
                            mesh_rows=2, mesh_cols=2)
    M, N, K = 512, 1024, 2048
    Sr, Sc, T = map_gemm("ws", M, N, K)
    r = simulate_multicore(cfg, M, N, K, "spatial")
    assert r.cycles == partition_cycles("spatial", 32, 32, Sr, Sc, T, 2, 2)


def test_nonuniform_split_equalizes():
    shares = nonuniform_split(1000, rates=[1.0, 1.0, 2.0], offsets=[0, 0, 0])
    assert sum(shares) == 1000
    assert shares[2] < shares[0]                 # slower core gets less


def test_nop_offset_shifts_work():
    near = nonuniform_split(1000, [1.0, 1.0], [0.0, 0.0])
    far = nonuniform_split(1000, [1.0, 1.0], [0.0, 500.0])
    assert far[1] < near[1]                      # farther core gets less


def test_heterogeneous_cores_balanced():
    cores = [CoreConfig(rows=64, cols=64), CoreConfig(rows=16, cols=16)]
    cfg = AcceleratorConfig(cores=tuple(cores), mesh_rows=2, mesh_cols=1)
    r = simulate_multicore(cfg, 512, 2048, 4096, "spatial")
    # the big core takes more of the split dimension
    assert r.per_core_share[0] > r.per_core_share[1]
    spread = max(r.per_core_cycles) / max(min(r.per_core_cycles), 1)
    assert spread < 4.5                          # roughly balanced makespan


def test_more_cores_not_slower():
    M, N, K = 1024, 4096, 4096
    c1 = AcceleratorConfig(cores=(CoreConfig(32, 32),))
    c16 = AcceleratorConfig(cores=(CoreConfig(32, 32),), mesh_rows=4,
                            mesh_cols=4)
    r1 = best_multicore(c1, M, N, K)
    r16 = best_multicore(c16, M, N, K)
    assert r16.cycles < r1.cycles


def test_l2_capacity_check():
    mem = MemoryConfig(l2_sram_bytes=1 << 10)
    cfg = AcceleratorConfig(cores=(CoreConfig(32, 32),), mesh_rows=2,
                            mesh_cols=2, memory=mem)
    r = simulate_multicore(cfg, 2048, 2048, 2048, "spatial")
    assert not r.l2_fit and r.l2_spill_elems > 0
