import pytest

from repro.core.dataflow import cdiv, map_gemm
from repro.core.partition import (best_plan, enumerate_plans,
                                  partition_cycles, partition_footprint)


def test_equations_match_paper():
    R = C = 32
    Sr, Sc, T = 1000, 5000, 10000
    Pr, Pc = 4, 4
    # Eq. 1
    assert partition_cycles("spatial", R, C, Sr, Sc, T, Pr, Pc) == \
        (2 * R + C + T - 2) * cdiv(Sr, Pr * R) * cdiv(Sc, Pc * C)
    # Eq. 2
    assert partition_cycles("st1", R, C, Sr, Sc, T, Pr, Pc) == \
        (2 * R + C + cdiv(T, Pc) - 2) * cdiv(Sr, Pr * R) * cdiv(Sc, C)
    # Eq. 3
    assert partition_cycles("st2", R, C, Sr, Sc, T, Pr, Pc) == \
        (2 * R + C + cdiv(T, Pr) - 2) * cdiv(Sr, R) * cdiv(Sc, Pc * C)


def test_single_core_reduces_to_v2():
    R = C = 16
    Sr, Sc, T = 100, 200, 300
    for scheme in ("spatial", "st1", "st2"):
        assert partition_cycles(scheme, R, C, Sr, Sc, T, 1, 1) == \
            (2 * R + C + T - 2) * cdiv(Sr, R) * cdiv(Sc, C)


def test_footprint_l2_dedup_never_bigger():
    for scheme in ("spatial", "st1", "st2"):
        f1 = partition_footprint(scheme, "ws", 512, 512, 1024, 4, 4)
        f2 = partition_footprint(scheme, "ws", 512, 512, 1024, 4, 4,
                                 dedup=True)
        assert f2["total"] <= f1["total"]


def test_os_temporal_split_needs_reduction():
    f = partition_footprint("st1", "os", 512, 512, 1024, 4, 4)
    assert f["reduce_elems"] > 0
    f2 = partition_footprint("spatial", "os", 512, 512, 1024, 4, 4)
    assert f2["reduce_elems"] == 0


def _true_st(p):
    """ST plan with an actual temporal split (Pc=1 st1 degenerates)."""
    return (p.scheme == "st1" and p.Pc > 1) or (p.scheme == "st2" and p.Pr > 1)


def test_spatiotemporal_wins_cycles_on_skinny_gemm():
    """Paper Fig. 3a: ST beats spatial outright when both spatial dims are
    exhausted (Sr, Sc small) — only a temporal split of T uses all cores."""
    plans = enumerate_plans("ws", 32, 8192, 256, 32, 32, 16)
    best_st = min((p for p in plans if _true_st(p)), key=lambda p: p.cycles)
    spatial_best = min((p for p in plans if p.scheme == "spatial"),
                       key=lambda p: p.cycles)
    assert best_st.cycles < 0.7 * spatial_best.cycles


def test_spatiotemporal_wins_footprint_at_equal_cycles():
    """Paper Fig. 3a (reading): among compute-optimal points, ST schemes
    reach near-equal cycles with a much smaller (no-L2) footprint because
    the streamed operand is not duplicated across core columns."""
    plans = enumerate_plans("ws", 1024, 8192, 1024, 32, 32, 16)
    spatial_best = min((p for p in plans if p.scheme == "spatial"),
                       key=lambda p: (p.cycles, p.footprint))
    st_near = [p for p in plans if _true_st(p)
               and p.cycles < 1.05 * spatial_best.cycles]
    st_best = min(st_near, key=lambda p: p.footprint)
    assert st_best.footprint < 0.75 * spatial_best.footprint


def test_spatial_usually_wins_footprint():
    """Paper Fig. 3b: spatial partitioning usually minimizes footprint."""
    wins = 0
    cases = [(1000, 5000, 10000), (5000, 5000, 5000), (10000, 1000, 5000)]
    for (M, N, K) in cases:
        p = best_plan("ws", M, N, K, 32, 32, 16, objective="footprint")
        if p.scheme == "spatial":
            wins += 1
    assert wins >= 2


def test_best_plan_objectives():
    pc = best_plan("ws", 1000, 5000, 10000, 32, 32, 64, "cycles")
    pf = best_plan("ws", 1000, 5000, 10000, 32, 32, 64, "footprint")
    assert pc.cycles <= pf.cycles
    assert pf.footprint <= pc.footprint
