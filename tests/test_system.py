"""End-to-end behaviour: training reduces loss; checkpoint/restart resumes
exactly; the simulation plane consumes workload-plane architectures."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import simulate_network, tpu_like_config
from repro.core.workloads import lm_ops
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models.zoo import ModelBundle
from repro.optim import adamw_init


def _tiny_bundle():
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen2-1.5b", smoke=True),
                              layers=2, d_model=64, heads=4, kv_heads=2,
                              d_ff=128, vocab=256)
    return ModelBundle(cfg)


def test_training_reduces_loss():
    b = _tiny_bundle()
    params = b.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(b.train_step(None, lr=5e-3), donate_argnums=(0, 1))
    ds = SyntheticLMDataset(DataConfig(vocab=b.cfg.vocab, seq_len=64,
                                       global_batch=8, seed=1))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.global_batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_restart_exact(tmp_path):
    b = _tiny_bundle()
    params = b.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(b.train_step(None, lr=1e-3))
    ds = SyntheticLMDataset(DataConfig(vocab=b.cfg.vocab, seq_len=32,
                                       global_batch=4, seed=2))
    mgr = CheckpointManager(str(tmp_path))

    p, o = params, opt
    for i in range(6):
        if i == 3:
            mgr.save(3, {"p": p, "o": o}, blocking=True)
        batch = {k: jnp.asarray(v) for k, v in ds.global_batch_at(i).items()}
        p, o, _ = step(p, o, batch)
    ref = jax.tree.leaves(p)[0]

    # restart from step 3, replay the same stream (deterministic pipeline)
    state = mgr.restore({"p": params, "o": opt})
    p2, o2 = state["p"], state["o"]
    for i in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in ds.global_batch_at(i).items()}
        p2, o2, _ = step(p2, o2, batch)
    got = jax.tree.leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(got, np.float32), atol=1e-6)


def test_simulation_plane_consumes_every_arch():
    """Workload plane -> operator graphs -> cycle-accurate reports."""
    from repro.configs import list_archs
    cfg = tpu_like_config(array=64)
    for arch in list_archs():
        ops = lm_ops(get_config(arch), seq=256, batch=1, mode="prefill")
        rep = simulate_network(cfg, ops)
        assert rep.total_cycles > 0 and rep.energy_pj > 0, arch


def test_train_driver_cli_runs():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)   # defensive: never inherit fake-device flags
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-1.5b",
         "--smoke", "--steps", "6", "--batch", "2", "--seq", "32",
         "--ckpt-every", "0", "--ckpt-dir", "/tmp/repro_test_ckpt"],
        capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "done." in out.stdout


def test_serve_driver_cli_runs():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-1.5b",
         "--smoke", "--requests", "2", "--batch", "2", "--prompt-len", "16",
         "--gen-len", "4"],
        capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "served" in out.stdout
