"""repro.faults unit contracts: deterministic schedules, the fs shims,
retry/backoff, and the spool's torn-write hardening.

The chaos *end-to-end* soaks live in test_chaos.py; this module pins the
plane's local semantics — same seed => same schedule, bounded rules stop
firing, torn/corrupt writes land the documented bytes, `atomic_write_json`
retries transient OSErrors, and `FileSpool.put` never publishes a torn
staging file as a poison message.
"""
import errno
import json
import os

import pytest

from repro.farm.queue import FileSpool
from repro.faults import (CHAOS_SCHEDULES, FaultPlan, FaultRule,
                          InjectedCrash, active_plan, backoff_delays,
                          chaos_schedule, with_retries)
from repro.faults import fs as ffs
from repro.faults.plan import ENV_VAR


# ---- FaultPlan decision procedure ------------------------------------------

def _schedule(plan, site, kinds, n):
    return [plan.decide(site, kinds) is not None for _ in range(n)]


def test_same_seed_replays_identical_schedule():
    mk = lambda: FaultPlan(7, {"x": FaultRule("os_error", p=0.5)})
    a = _schedule(mk(), "x", ("os_error",), 64)
    b = _schedule(mk(), "x", ("os_error",), 64)
    assert a == b
    assert any(a) and not all(a)       # p=0.5 actually branches
    c = _schedule(FaultPlan(8, {"x": FaultRule("os_error", p=0.5)}),
                  "x", ("os_error",), 64)
    assert a != c                      # different seed, different schedule


def test_times_caps_total_injections():
    plan = FaultPlan(0, {"x": FaultRule("crash", p=1.0, times=3)})
    fired = _schedule(plan, "x", ("crash",), 10)
    assert sum(fired) == 3 and fired[:3] == [True] * 3


def test_after_skips_the_first_calls():
    plan = FaultPlan(0, {"x": FaultRule("torn", p=1.0, after=2, times=1)})
    fired = _schedule(plan, "x", ("torn",), 5)
    assert fired == [False, False, True, False, False]


def test_site_globs_and_kind_filter():
    plan = FaultPlan(0, {"worker.*": FaultRule("crash", p=1.0)})
    assert plan.decide("worker.claimed", ("crash",)) is not None
    assert plan.decide("broker.status", ("crash",)) is None
    # a crash-only rule is invisible to a write-kind query
    assert plan.decide("worker.result", ("os_error", "torn")) is None


def test_report_counts_what_fired():
    plan = FaultPlan(0, {"x": FaultRule("os_error", p=1.0, times=2)})
    _schedule(plan, "x", ("os_error",), 5)
    rep = plan.report()
    assert rep["injected"] == {"x:os_error": 2}
    assert rep["total_injected"] == 2 and rep["seed"] == 0


def test_rule_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultRule("meltdown")
    with pytest.raises(ValueError, match="probability"):
        FaultRule("torn", p=1.5)
    with pytest.raises(ValueError, match="times"):
        FaultRule("torn", times=-1)


def test_json_round_trip_and_env_activation(monkeypatch):
    plan = FaultPlan(3, {"spool.put": [FaultRule("torn", p=0.5, times=2)],
                         "clock": FaultRule("skew", skew=100.0)})
    back = FaultPlan.from_json(plan.to_json())
    assert back.seed == 3 and back.rules == plan.rules
    # env activation: a worker subprocess builds its plan from REPRO_FAULTS
    monkeypatch.setenv(ENV_VAR, plan.to_json())
    monkeypatch.setattr("repro.faults.plan._ACTIVE", None)
    monkeypatch.setattr("repro.faults.plan._ENV_CHECKED", False)
    got = active_plan()
    assert got is not None and got.seed == 3
    monkeypatch.setattr("repro.faults.plan._ACTIVE", None)
    monkeypatch.setattr("repro.faults.plan._ENV_CHECKED", True)
    assert active_plan() is None


def test_bad_env_schedule_is_no_schedule(monkeypatch):
    monkeypatch.setenv(ENV_VAR, "{not json")
    monkeypatch.setattr("repro.faults.plan._ACTIVE", None)
    monkeypatch.setattr("repro.faults.plan._ENV_CHECKED", False)
    assert active_plan() is None


# ---- fs shims ---------------------------------------------------------------

def test_shims_are_passthrough_without_a_plan(tmp_path):
    p = tmp_path / "a.json"
    ffs.write_text(str(p), '{"v": 1}', site="anything")
    assert json.load(open(p)) == {"v": 1}
    ffs.crash_point("worker.claimed")          # no-op
    assert abs(ffs.now() - __import__("time").time()) < 5.0


def test_torn_and_corrupt_writes_land_unparseable_bytes(tmp_path):
    plan = FaultPlan(0, {"t": FaultRule("torn", p=1.0, times=1),
                         "c": FaultRule("corrupt", p=1.0, times=1)})
    text = json.dumps({"k": list(range(50))})
    with plan.active():
        ffs.write_text(str(tmp_path / "t.json"), text, site="t")
        ffs.write_text(str(tmp_path / "c.json"), text, site="c")
    torn = open(tmp_path / "t.json").read()
    assert torn == text[:len(torn)] and 0 < len(torn) < len(text)
    for name in ("t.json", "c.json"):
        with pytest.raises(ValueError):
            json.load(open(tmp_path / name))


def test_crash_point_is_base_exception():
    plan = FaultPlan(0, {"x": FaultRule("crash", p=1.0, times=1)})
    with plan.active():
        with pytest.raises(InjectedCrash):
            try:
                ffs.crash_point("x")
            except Exception:  # noqa: BLE001 — the guard under test
                pytest.fail("InjectedCrash must not be an Exception: "
                            "except-Exception guards would absorb kills")


def test_clock_skew_applies_per_scheduled_read():
    plan = FaultPlan(0, {"clock": FaultRule("skew", skew=1e6, p=1.0,
                                            times=1)})
    import time as _t
    with plan.active():
        assert ffs.now() - _t.time() > 9e5       # skewed once
        assert abs(ffs.now() - _t.time()) < 5.0  # budget spent


def test_atomic_write_json_retries_transient_errors(tmp_path):
    p = tmp_path / "out.json"
    plan = FaultPlan(0, {"s": FaultRule("os_error", p=1.0, times=3)})
    with plan.active():
        ffs.atomic_write_json(str(p), {"ok": 1}, site="s")
    assert json.load(open(p)) == {"ok": 1}
    assert plan.report()["injected"] == {"s:os_error": 3}
    assert not [n for n in os.listdir(tmp_path) if ".tmp." in n]


def test_atomic_write_json_exhausts_retries_loudly(tmp_path):
    plan = FaultPlan(0, {"s": FaultRule("os_error", p=1.0)})  # unbounded
    with plan.active():
        with pytest.raises(OSError) as ei:
            ffs.atomic_write_json(str(tmp_path / "x.json"), {}, site="s",
                                  retries=2)
    assert ei.value.errno == errno.ENOSPC
    assert not os.path.exists(tmp_path / "x.json")


# ---- retry/backoff ----------------------------------------------------------

def test_backoff_delays_grow_with_bounded_jitter():
    import random
    d = backoff_delays(retries=5, base=0.01, factor=2.0,
                       rng=random.Random(0))
    assert len(d) == 5
    for i, x in enumerate(d):
        nominal = 0.01 * 2.0 ** i
        assert 0.5 * nominal <= x < 1.5 * nominal
    assert d == backoff_delays(retries=5, base=0.01, factor=2.0,
                               rng=random.Random(0))


def test_with_retries_passes_through_and_reraises():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError(errno.EIO, "eio")
        return "ok"

    assert with_retries(flaky, sleep=lambda s: None) == "ok"
    assert len(calls) == 3
    with pytest.raises(ValueError):   # non-retryable passes straight out
        with_retries(lambda: (_ for _ in ()).throw(ValueError("x")),
                     sleep=lambda s: None)


# ---- spool put hardening ----------------------------------------------------

def test_spool_put_survives_torn_staging_write(tmp_path):
    """A torn staging write must never publish a poison message: put
    detects it on read-back, retries, and the published item parses."""
    sp = FileSpool(str(tmp_path))
    plan = FaultPlan(0, {"spool.put": FaultRule("torn", p=1.0, times=2)})
    with plan.active():
        item_id = sp.put("t", {"study_id": "s", "cells": list(range(40))})
    assert plan.report()["injected"] == {"spool.put:torn": 2}
    got = sp.claim("t", "w")
    assert got is not None and got.item_id == item_id
    assert got.payload["cells"] == list(range(40))


def test_spool_claim_drops_wrong_shape_payloads(tmp_path):
    sp = FileSpool(str(tmp_path))
    sp.put("t", {"ok": True})
    # hand-plant a non-dict JSON file in pending/ (valid JSON, wrong shape)
    pending = os.path.join(str(tmp_path), "t", "pending")
    with open(os.path.join(pending, "p0000-0-zz.json"), "w") as f:
        f.write("[1, 2, 3]")
    got = sp.claim("t", "w")
    assert got is not None and got.payload == {"ok": True}
    assert sp.depth("t") == 0        # the poison file was consumed too


# ---- schedule registry ------------------------------------------------------

def test_chaos_schedule_registry():
    assert set(CHAOS_SCHEDULES) == {"worker-kills", "torn-writes",
                                    "lease-storms"}
    for name in CHAOS_SCHEDULES:
        plan = chaos_schedule(name, 5)
        assert plan.seed == 5
        # every rule bounded: chaos runs provably stop injecting
        assert all(r.times is not None for _, r in plan.rules)
    with pytest.raises(KeyError):
        chaos_schedule("surprise")
