"""Reproduction of the paper's headline claims (Sec. IX-B, Table V/VI,
Figs. 5/9/10/15). Quantitative ratios are checked in bands since our
ViT GEMM-ification differs from the (unpublished) SCALE-Sim topology files;
EXPERIMENTS.md records exact values."""
import pytest

from repro.core import simulate_network, tpu_like_config
from repro.core.accelerator import DramConfig, SparsityConfig
from repro.core.dram import simulate_dram, tile_prefetch_trace, linear_trace
from repro.core.workloads import resnet18, resnet18_six_layers


@pytest.fixture(scope="module")
def vitb():
    """Table V through the Study layer: arrays x ViT-base in one
    `Study.run()` — the single execution path for paper comparisons."""
    from repro.api import studies
    return studies.edp_array_size().run()


@pytest.fixture(scope="module")
def flip():
    """Sec. IX-B dataflow study: {ws, os} x {fast, trace} in one run."""
    from repro.api import studies
    return studies.dataflow_dram_flip().run()


def test_latency_scales_with_array(vitb):
    """Table V: 128x128 is much faster than 32x32 on latency alone
    (paper: 6.53x; ours: ~4x with our GEMM-ification)."""
    cyc = {r["design"]: r["total_cycles"] for r in vitb.rows()}
    assert 3.0 < cyc["32"] / cyc["128"] < 9.0


def test_energy_flip_table5(vitb):
    """Table V: 32x32 is ~2.86x more energy-efficient than 128x128."""
    e = {r["design"]: r["energy_pj"] for r in vitb.rows()}
    assert 2.3 < e["128"] / e["32"] < 3.4
    assert e["32"] < e["64"] < e["128"]


def test_edp_optimum_64(vitb):
    """Table V (text): 64x64 wins EdP for ViT-base."""
    edp = {r["design"]: r["edp"] for r in vitb.rows()}
    assert edp["64"] < edp["128"] < edp["32"]


def test_edp_array_size_claims(vitb):
    """The named study's machine-checkable claims all hold."""
    assert vitb.check_claims() == {
        "latency_winner_is_128": True,
        "energy_winner_is_32": True,
        "edp_winner_64_between_extremes": True,
        "energy_ratio_128_vs_32_in_band": True,
    }


def test_ws_os_flip_with_dram(flip):
    """Sec. IX-B: WS beats OS on compute cycles (~21%), OS beats WS on
    total execution once DRAM stalls are modeled (~30%)."""
    fast = flip.filter(fidelity="fast")
    comp = {r["design"]: r["compute_cycles"] for r in fast.rows()}
    tot = {r["design"]: r["total_cycles"] for r in fast.rows()}
    assert 0.05 < 1 - comp["ws"] / comp["os"] < 0.4   # WS fewer compute
    assert 1 - tot["os"] / tot["ws"] > 0.2            # OS wins with stalls


def test_ws_os_flip_with_generated_traces(flip):
    """ISSUE 2 acceptance: with cycle-accurate stalls driven by
    dataflow-generated demand traces (fidelity='trace'), OS shows lower
    end-to-end execution than WS on the ResNet18 six-layer workload,
    while WS keeps fewer compute cycles — the paper's headline DRAM
    claim, now sensitive to the *address stream* each dataflow emits."""
    trace = flip.filter(fidelity="trace")
    comp = {r["design"]: r["compute_cycles"] for r in trace.rows()}
    tot = {r["design"]: r["total_cycles"] for r in trace.rows()}
    assert comp["ws"] < comp["os"]
    assert tot["os"] < tot["ws"]
    assert flip.claims_ok()
    # and the trace machinery actually exercises the row-buffer model
    from repro.api import Simulator
    cfg = tpu_like_config(array=32, dataflow="ws", sram_mb=0.4)
    stats = Simulator(cfg, fidelity="trace").run_op(
        resnet18_six_layers()[0]).dram_stats
    assert stats["row_hits"] + stats["row_misses"] + \
        stats["row_conflicts"] > 0


def test_sparsity_cycles_vs_sram_fig5():
    """Fig. 5: sparser -> fewer total cycles; more SRAM -> fewer stalls."""
    base = {}
    for nm in (None, (2, 4), (1, 4)):
        cfg = tpu_like_config(array=32, sram_mb=0.5)
        if nm:
            cfg = cfg.with_(sparsity=SparsityConfig(enabled=True, n=nm[0],
                                                    m=nm[1]))
        base[nm] = simulate_network(cfg, resnet18()).total_cycles
    assert base[(1, 4)] < base[(2, 4)] < base[None]
    small = simulate_network(tpu_like_config(array=32, sram_mb=0.25),
                             resnet18()).total_cycles
    big = simulate_network(tpu_like_config(array=32, sram_mb=4.0),
                           resnet18()).total_cycles
    assert big < small


def test_dram_channels_fig9():
    t, a, w = linear_trace(4096, issue_gap=0.25)
    th1 = float(simulate_dram(t, a, w, DramConfig(channels=1)).throughput)
    th8 = float(simulate_dram(t, a, w, DramConfig(channels=8)).throughput)
    assert th8 > 5 * th1


def test_queue_sweep_fig10():
    t, a, w = tile_prefetch_trace(tile_bytes=20 * 1024, n_tiles=64,
                                  compute_per_tile=400, gran_bytes=64)
    tot = {}
    for q in (32, 128, 512):
        tot[q] = float(simulate_dram(
            t, a, w, DramConfig(channels=2, read_queue=q,
                                write_queue=q)).total_cycles)
    # big first step, smaller second step — same shape as the paper
    assert tot[32] > tot[128] >= tot[512]
    assert (tot[32] - tot[128]) > (tot[128] - tot[512])


def test_multicore_iso_compute_table6():
    """Table VI: iso-compute 128x128 vs 16x 32x32: the multi-core config
    narrows the ws/is latency gap."""
    from repro.core.workloads import vit_base_linear
    gaps = {}
    for cores, arr in ((1, 128), (16, 32)):
        lat = {}
        for df in ("ws", "is"):
            cfg = tpu_like_config(array=arr, cores=cores, dataflow=df)
            lat[df] = simulate_network(cfg, vit_base_linear()).compute_cycles
        gaps[cores] = lat["is"] / lat["ws"]
    # paper: 1.87x (single) -> 1.14x (multi). Our GEMM-ification flips
    # which dataflow wins (M=features vs M=tokens convention), so we assert
    # the claim itself: multi-core partitioning NARROWS the dataflow gap.
    assert abs(1 - gaps[16]) < 0.5 * abs(1 - gaps[1])


def test_energy_fig15_os_wins():
    """Fig. 15: OS dataflow spends the least energy in most configs
    (psums never leave the array)."""
    from repro.core.workloads import resnet18
    wins = 0
    for arr in (32, 64):
        e = {}
        for df in ("ws", "is", "os"):
            cfg = tpu_like_config(array=arr, dataflow=df)
            e[df] = simulate_network(cfg, resnet18()).energy_pj
        if e["os"] <= min(e["ws"], e["is"]) * 1.02:
            wins += 1
    assert wins >= 1
