"""The Study layer (repro.api.study): cross-product plan compilation,
batched execution parity, the columnar frame ops, serialization + cache,
and the named-study registry. The two paper studies' claims are covered
in tests/test_paper_claims.py on the same fixtures."""
import json

import numpy as np
import pytest

from repro.api import (Simulator, Study, StudyResult, get_study,
                       list_studies, preset_grid, register_study, studies)
from repro.core.workloads import Op

OPS_A = [Op("a", 256, 1024, 512), Op("b", 512, 197, 768, count=3.0),
         Op("v", kind="vector", vector_elems=8192.0, count=2.0)]
OPS_B = [Op("c", 128, 512, 256), Op("d", 384, 64, 384)]


# ---- plan + batched execution ---------------------------------------------

def test_cross_product_parity_with_simulator_loop():
    """designs x workloads x fidelity frame matches a python loop of
    `Simulator.run` per cell to <= 1e-3."""
    grid = preset_grid(array=[16, 32], sram_mb=[0.5, 2.0])
    res = (Study().designs(grid)
           .workloads({"wa": OPS_A, "wb": OPS_B})
           .fidelity("fast").run())
    assert len(res) == len(grid) * 2
    assert (res["batched"] == 1.0).all()
    for row_i in range(len(res)):
        row = res.row(row_i)
        # row order: workload-major, design fastest (one fidelity)
        cfg = grid[row_i % len(grid)]
        assert row["workload"] == ("wa" if row_i < len(grid) else "wb")
        rep = Simulator(cfg).run(OPS_A if row["workload"] == "wa" else OPS_B)
        assert row["total_cycles"] == pytest.approx(rep.total_cycles,
                                                    rel=1e-3)
        assert row["energy_pj"] == pytest.approx(rep.energy_pj, rel=1e-3)
        assert row["edp"] == pytest.approx(rep.edp, rel=1e-3)
        # grouped energy columns (shared schema) sum to the total
        groups = sum(row[g] for g in ("energy_mac_pj", "energy_sram_pj",
                                      "energy_dram_pj", "energy_static_pj"))
        assert groups == pytest.approx(row["energy_pj"], rel=1e-3)


def test_plan_batches_all_traceable_cells():
    """Acceptance: a designs x workloads x {fast, trace} study executes
    through the batched path — traceable cells never hit the per-cell
    python loop."""
    grid = preset_grid(array=[16, 32], dataflow=["ws", "os"])
    study = (Study().designs(grid)
             .workloads({"wa": OPS_A[:2], "wb": OPS_B})
             .fidelity("fast", "trace"))
    plan = study.plan()
    assert len(plan) == 4 * 2 * 2
    assert not plan.fallback and plan.n_batched == len(plan)
    # groups are keyed by (workload, fidelity, dataflow[, dram])
    assert all(len(g.cells) == 2 for g in plan.groups)
    res = study.run()
    assert (res["batched"] == 1.0).all()
    # trace rows exist and differ from fast rows (different stall model)
    tr, fa = res.filter(fidelity="trace"), res.filter(fidelity="fast")
    assert not np.allclose(tr["stall_cycles"], fa["stall_cycles"])


def test_sparse_cells_batch_and_oracle_stays_reachable():
    """ISSUE 5: sparse cells run through the vmapped kernel (batched ==
    1.0, matching the engine <= 1e-3); the per-op oracle is kept alive
    behind force_fallback for the differential parity suite."""
    from repro.core.accelerator import SparsityConfig
    grid = preset_grid(array=[16])
    sparse = grid[0].with_(sparsity=SparsityConfig(enabled=True, n=2, m=4))
    mk = lambda: (Study().designs({"dense": grid[0], "sparse": sparse})
                  .workloads({"wa": OPS_A[:2]}).fidelity("fast"))
    res = mk().run()
    assert res.fraction_batched == 1.0
    rep = Simulator(sparse).run(OPS_A[:2])
    assert res.filter(design="sparse")["total_cycles"][0] == \
        pytest.approx(rep.total_cycles, rel=1e-3)
    oracle = mk().options(force_fallback=True).run()
    assert oracle.fraction_batched == 0.0
    assert oracle.filter(design="sparse")["total_cycles"][0] == \
        pytest.approx(rep.total_cycles, rel=1e-6)
    # 'cycle' fidelity still runs per-op (no traced DRAM scan twin)
    plan = (Study().designs({"d": grid[0]}).workloads({"wa": OPS_A[:2]})
            .fidelity("cycle").plan())
    assert plan.fallback and not plan.groups


def test_sharded_vs_unsharded_equality():
    import jax
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    grid = preset_grid(array=[8, 16, 32], sram_mb=[1.0])
    mk = lambda: (Study().designs(grid).workloads({"wa": OPS_A[:1]})
                  .fidelity("fast"))
    plain = mk().run()
    shard = mk().run(mesh=mesh)
    for k in ("total_cycles", "energy_pj", "stall_cycles", "utilization"):
        assert np.allclose(plain[k], shard[k], rtol=1e-6)


# ---- frame ops on a known 3-design fixture --------------------------------

@pytest.fixture()
def fixture_frame():
    cols = {
        "design": np.array(["a", "b", "c"], dtype=object),
        "workload": np.array(["w", "w", "w"], dtype=object),
        "fidelity": np.array(["fast", "fast", "fast"], dtype=object),
        # a: fast+hungry, b: balanced, c: slow+frugal; b best EdP,
        # all three pareto-optimal on (cycles, energy)
        "total_cycles": np.array([1e6, 2e6, 8e6]),
        "energy_pj": np.array([9e9, 2e9, 1e9]),
        "edp": np.array([9e6, 4e6, 8e6]),
        "batched": np.ones(3),
    }
    axes = {"design": ["a", "b", "c"], "workload": ["w"],
            "fidelity": ["fast"]}
    return StudyResult(cols, axes)


def test_best_argbest_aliases(fixture_frame):
    f = fixture_frame
    assert f.best("latency")["design"] == "a"
    assert f.best("energy")["design"] == "c"
    assert f.best("edp")["design"] == "b"
    assert f.argbest("edp") == 1
    by = f.best("edp", by="design")
    assert set(by) == {"a", "b", "c"} and by["a"]["edp"] == 9e6


def test_pareto_front(fixture_frame):
    front = fixture_frame.pareto("total_cycles", "energy_pj")
    assert sorted(front["design"]) == ["a", "b", "c"]
    # dominate c with a strictly-better row -> c drops off the front
    dominated = fixture_frame._subset(np.array([True, True, True]))
    dominated.columns["total_cycles"] = np.array([1e6, 2e6, 8e6])
    dominated.columns["energy_pj"] = np.array([9e9, 0.5e9, 1e9])
    assert sorted(dominated.pareto("total_cycles",
                                   "energy_pj")["design"]) == ["a", "b"]


def test_filter_group_compare(fixture_frame):
    f = fixture_frame
    assert len(f.filter(design="a")) == 1
    assert len(f.filter(design=["a", "c"])) == 2
    assert len(f.filter(lambda r: r["total_cycles"] < 3e6)) == 2
    assert set(f.group("design")) == {"a", "b", "c"}
    ratios = f.compare("total_cycles", axis="design", baseline="a")
    assert ratios["b"][0] == pytest.approx(2.0)
    assert ratios["c"][0] == pytest.approx(8.0)
    with pytest.raises(KeyError):
        f.compare("total_cycles", axis="design", baseline="zzz")


def test_topk_is_stable_sorted_and_nan_safe(fixture_frame):
    f = fixture_frame
    assert list(f.topk("edp", 2)["design"]) == ["b", "c"]
    # k past the frame clamps; result is sorted ascending
    top = f.topk("edp", 99)
    assert list(top["design"]) == ["b", "c", "a"]
    assert list(top["edp"]) == sorted(f["edp"])
    assert len(f.topk("edp", 0)) == 0
    with pytest.raises(ValueError):
        f.topk("edp", -1)
    # NaN rows (failed cells) never place, even with k >= len
    g = f._subset(np.array([True, True, True]))
    g.columns["edp"] = np.array([9e6, np.nan, 8e6])
    assert list(g.topk("edp", 3)["design"]) == ["c", "a"]
    # ties keep original row order (stable sort)
    h = f._subset(np.array([True, True, True]))
    h.columns["edp"] = np.array([5e6, 5e6, 1e6])
    assert list(h.topk("edp", 3)["design"]) == ["c", "a", "b"]


def test_concat_unions_columns_and_nan_fills(fixture_frame):
    other = StudyResult(
        {
            "design": np.array(["d"], dtype=object),
            "workload": np.array(["w"], dtype=object),
            "fidelity": np.array(["trace"], dtype=object),
            "total_cycles": np.array([4e6]),
            "energy_pj": np.array([3e9]),
            "edp": np.array([6e6]),
            # a metric fixture_frame does not have
            "dram_stall_cycles": np.array([1e5]),
        },
        {"design": ["d"], "workload": ["w"], "fidelity": ["trace"]},
        executed_cells=1, cache_hits=2)
    fixture_frame.executed_cells = 3
    cat = StudyResult.concat([fixture_frame, other])
    assert len(cat) == 4
    # column union in first-seen order, missing metrics NaN-filled
    assert cat.column_names()[:len(fixture_frame.column_names())] == \
        fixture_frame.column_names()
    assert "dram_stall_cycles" in cat.columns
    assert np.isnan(cat["dram_stall_cycles"][:3]).all()
    assert cat["dram_stall_cycles"][3] == 1e5
    # fixture_frame lacks "batched"? no — other lacks it: NaN-filled
    assert np.isnan(cat["batched"][3])
    # axis vocabularies merge first-seen
    assert cat.axes["design"] == ["a", "b", "c", "d"]
    assert cat.axes["fidelity"] == ["fast", "trace"]
    # accounting sums; claims/meta never propagate
    assert cat.executed_cells == 4 and cat.cache_hits == 2
    assert cat._claims == [] and cat.meta == {}
    # NaN-safe consumers ignore the fill
    assert cat.best("edp")["design"] == "b"
    with pytest.raises(ValueError):
        StudyResult.concat([])


def test_concat_checks_schema_version_and_axis_columns(fixture_frame):
    alien = fixture_frame._subset(np.array([True, False, False]))
    alien.schema_version = 999  # a frame from a foreign/future schema
    with pytest.raises(ValueError, match="schema_version"):
        StudyResult.concat([fixture_frame, alien])
    # axis columns must exist in every frame — no NaN fill for axes
    noaxis = StudyResult(
        {"design": np.array(["e"], dtype=object),
         "workload": np.array(["w"], dtype=object),
         "edp": np.array([1.0])},
        {"design": ["e"], "workload": ["w"]})
    with pytest.raises(ValueError, match="fidelity"):
        StudyResult.concat([fixture_frame, noaxis])


def test_concat_and_topk_roundtrip_csv_json(tmp_path, fixture_frame):
    other = fixture_frame._subset(np.array([True, True, False]))
    other.columns["design"] = np.array(["x", "y"], dtype=object)
    other.axes["design"] = ["x", "y"]
    other.columns["fidelity"] = np.array(["trace", "trace"], dtype=object)
    other.axes["fidelity"] = ["trace"]
    cat = StudyResult.concat([fixture_frame, other])
    assert cat.equals(StudyResult.from_json(cat.to_json()))
    p = tmp_path / "cat.csv"
    cat.to_csv(str(p))
    back = StudyResult.from_csv(str(p))
    for k in cat.columns:
        assert np.array_equal(back.columns[k], cat.columns[k]), k
    # NaN survives the trip too
    cat.columns["edp"][0] = np.nan
    cat.to_csv(str(p))
    nback = StudyResult.from_csv(str(p))
    assert np.isnan(nback["edp"][0])
    assert nback.equals(StudyResult.from_json(cat.to_json()))
    # and topk subframes serialize like any frame
    top = cat.topk("total_cycles", 2)
    assert top.equals(StudyResult.from_json(top.to_json()))


# ---- serialization + cache -------------------------------------------------

def test_csv_json_roundtrip_and_schema(tmp_path):
    res = (Study().designs(preset_grid(array=[16, 32]))
           .workloads({"wa": OPS_A[:2]}).fidelity("fast").run())
    # JSON round-trip carries the shared schema version
    d = json.loads(res.to_json())
    from repro.core.engine import RESULT_SCHEMA_VERSION
    assert d["schema_version"] == RESULT_SCHEMA_VERSION
    assert res.equals(StudyResult.from_json(res.to_json()))
    # CSV round-trip is lossless (repr floats via the shared writer)
    p = tmp_path / "frame.csv"
    res.to_csv(str(p))
    back = StudyResult.from_csv(str(p))
    for k in res.columns:
        assert np.array_equal(back.columns[k], res.columns[k]), k
    # a deserialized frame has no claims: claims_ok is loud, not True
    with pytest.raises(ValueError):
        back.claims_ok()
    # claims are scoped to the full frame — subframes don't carry them
    with pytest.raises(ValueError):
        res.filter(design=res.axes["design"][0]).claims_ok()
    # NetworkReport shares the version stamp and group columns
    rep = Simulator("paper-32").run(OPS_A[:2])
    rd = json.loads(rep.to_json())
    assert rd["schema_version"] == RESULT_SCHEMA_VERSION
    rep.write_csv(str(tmp_path / "rep.csv"))
    header = (tmp_path / "rep.csv").read_text().splitlines()[0].split(",")
    for g in ("energy_mac_pj", "energy_sram_pj", "energy_dram_pj",
              "energy_static_pj"):
        assert g in header and g in res.columns


def test_cache_hits_return_identical_frame(tmp_path):
    cache = str(tmp_path / "cells")
    mk = lambda: (Study("cached").designs(preset_grid(array=[16, 32]))
                  .workloads({"wa": OPS_A[:2]}).fidelity("fast")
                  .cache(cache))
    first = mk().run()
    assert first.executed_cells == 2 and first.cache_hits == 0
    import os
    mtimes = {f: os.path.getmtime(os.path.join(cache, f))
              for f in os.listdir(cache)}
    second = mk().run()
    assert second.executed_cells == 0 and second.cache_hits == 2
    assert first.equals(second)
    # pure hits must not rewrite the cache files
    assert mtimes == {f: os.path.getmtime(os.path.join(cache, f))
                      for f in os.listdir(cache)}
    # a changed cell (new design) re-executes only the new cell
    third = (Study("cached")
             .designs(preset_grid(array=[16, 32, 64]))
             .workloads({"wa": OPS_A[:2]}).fidelity("fast")
             .cache(cache).run())
    assert third.executed_cells == 1 and third.cache_hits == 2
    assert np.array_equal(third["total_cycles"][:2], first["total_cycles"])


# ---- named studies / registry ---------------------------------------------

def test_registry_and_namespace():
    assert {"edp_array_size", "dataflow_dram_flip",
            "multicore_contention"} <= set(list_studies())
    assert isinstance(get_study("edp_array_size", smoke=True), Study)
    with pytest.raises(KeyError):
        get_study("no-such-study")
    with pytest.raises(AttributeError):
        studies.no_such_study
    with pytest.raises(ValueError):
        register_study("edp_array_size")(lambda: None)


def test_contention_study_claims():
    """The multi-core contention study (custom evaluator over
    `simulate_multicore_contention`): shared DRAM never beats isolation
    and extra channels relieve the shared makespan."""
    from repro.trace import TraceSpec
    res = studies.multicore_contention(
        channels=(1, 4), gemm=(256, 512, 512),
        spec=TraceSpec(cap=1024)).run()
    assert res.claims_ok(), res.check_claims()
    assert (res["batched"] == 0.0).all()      # custom evaluator: per-cell
    assert "makespan_shared" in res.columns and "channels" in res.columns


def test_preset_grid_preset_and_dataflow_axes():
    grid = preset_grid(preset=["paper-32", "edge-8"],
                       dataflow=["ws", "os"])
    assert len(grid) == 4
    assert [(c.cores[0].rows, c.dataflow) for c in grid] == \
        [(32, "ws"), (32, "os"), (8, "ws"), (8, "os")]
    # factory kwargs still cross as before
    grid = preset_grid(array=[8, 16], sram_mb=[1.0], dataflow=["ws", "os"])
    assert len(grid) == 4 and grid[1].dataflow == "os"


def test_filter_predicate_on_empty_frame(fixture_frame):
    empty = fixture_frame.filter(design="nonexistent")
    assert len(empty) == 0
    assert len(empty.filter(lambda r: r["total_cycles"] < 1e6)) == 0


def test_csv_roundtrip_with_comma_in_label(tmp_path):
    res = (Study().designs({"a,b": "paper-32"})
           .workloads({"w,1": OPS_A[:1]}).fidelity("fast").run())
    p = tmp_path / "comma.csv"
    res.to_csv(str(p))
    back = StudyResult.from_csv(str(p))
    assert back["design"][0] == "a,b" and back["workload"][0] == "w,1"
    assert np.array_equal(back["total_cycles"], res["total_cycles"])


def test_run_cache_kwarg_does_not_stick(tmp_path):
    study = (Study().designs(preset_grid(array=[16]))
             .workloads({"w": OPS_A[:1]}).fidelity("fast"))
    study.run(cache=str(tmp_path / "once"))
    assert study._cache_dir is None
    again = study.run()                       # no cache dir -> no hits
    assert again.cache_hits == 0 and again.executed_cells == 1


def test_cache_tolerates_corrupt_and_truncated_files(tmp_path):
    """ISSUE 6 satellite: a torn/garbage cache file is a miss, never a
    crash — concurrent farm writers (and interrupted single-user runs)
    leave partial files behind on pre-atomic layouts."""
    import os
    cache = str(tmp_path / "cells")
    mk = lambda: (Study("robust").designs(preset_grid(array=[16, 32]))
                  .workloads({"wa": OPS_A[:2]}).fidelity("fast")
                  .cache(cache))
    first = mk().run()
    files = sorted(os.listdir(cache))
    assert files and not [f for f in files if ".tmp." in f], \
        "atomic store must not leave temp litter"
    # corrupt one cell every way a torn write or stray file could
    victim = os.path.join(cache, files[0])
    for garbage in ("", "{\"schema_version\":", "[1, 2, 3]", "null",
                    '{"schema_version": "v0-bogus", "metrics": {}}',
                    '{"metrics": "not-a-dict"}'):
        with open(victim, "w") as f:
            f.write(garbage)
        again = mk().run()
        # the corrupt cell re-executes (miss), the other still hits
        assert again.executed_cells == 1 and again.cache_hits == 1
        assert again.equals(first), garbage
    # the re-run healed the cache in place
    final = mk().run()
    assert final.executed_cells == 0 and final.cache_hits == 2


def test_cache_store_is_atomic_rename(tmp_path, monkeypatch):
    """_cache_store never exposes a partially-written file under the
    final name: the content appears via os.replace only."""
    import os
    seen = []
    real_replace = os.replace

    def spying_replace(src, dst):
        # at replace time the temp file is complete and parseable
        with open(src) as f:
            json.load(f)
        seen.append(os.path.basename(dst))
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", spying_replace)
    cache = str(tmp_path / "cells")
    (Study("atomic").designs(preset_grid(array=[16]))
     .workloads({"wa": OPS_A[:1]}).fidelity("fast").cache(cache).run())
    assert len(seen) == 1 and seen[0].endswith(".json")


def test_distinct_evaluators_never_share_cache(tmp_path):
    cache = str(tmp_path / "cells")

    def mk(fn):
        return (Study().designs(preset_grid(array=[16]))
                .workloads({"w": OPS_A[:1]}).fidelity("fast")
                .evaluator(fn).cache(cache))

    first = mk(lambda c, o, f: {"m": 1.0}).run()
    second = mk(lambda c, o, f: {"m": 2.0}).run()   # same qualname
    assert first.executed_cells == 1 and second.executed_cells == 1
    assert second["m"][0] == 2.0


def test_empty_sweep_still_returns_empty_result():
    res = Simulator().sweep([], OPS_A[:1])
    assert len(res) == 0 and res.batched
    assert res.total_cycles.shape == (0,)


def test_csv_writer_accepts_numpy_scalars(tmp_path):
    from repro.core.engine import write_csv_table
    p = tmp_path / "np.csv"
    write_csv_table(str(p), ["x"], [[np.float64(1.5)]])
    assert p.read_text().splitlines()[1] == "1.5"


def test_study_validation_errors():
    with pytest.raises(ValueError):
        Study().workloads({"w": OPS_A}).run()          # no designs
    with pytest.raises(ValueError):
        Study().designs(preset_grid(array=[16])).run()  # no workloads
    with pytest.raises(ValueError):
        Study().fidelity("nope")
    with pytest.raises(TypeError):
        Study().workloads(42)
    with pytest.raises(KeyError):
        (Study().designs(preset_grid(array=[16]))
         .workloads({"w": OPS_A[:1]}).metrics("not_a_metric").run())


# ---- failure semantics (ISSUE 8) ------------------------------------------

def test_evaluator_exception_degrades_to_failed_cell():
    """One sick cell must not poison the study: its row gets
    cell_status 1.0 + NaN metrics, the rest stay healthy."""
    def ev(cfg, ops, fid):
        if cfg.cores[0].rows == 16:
            raise RuntimeError("sick cell")
        return {"m": float(cfg.cores[0].rows), "edp": 1.0}

    res = (Study("sick").designs(preset_grid(array=[8, 16, 32]))
           .workloads({"w": OPS_B[:1]}).fidelity("fast")
           .evaluator(ev).run())
    assert len(res) == 3
    assert res.failed_cells == [1]
    assert res["cell_status"][1] == 1.0 and np.isnan(res["m"][1])
    ok = res.ok()
    assert len(ok) == 2 and (ok["cell_status"] == 0.0).all()
    assert res.argbest("m") == 0          # NaN row never wins
    assert res.best("m")["design"] == res["design"][0]


def test_non_finite_canonical_metrics_flag_cell_failed():
    """NaN anywhere fails a cell; Inf fails only canonical metric
    columns — a custom evaluator column may legitimately be Inf."""
    def ev(cfg, ops, fid):
        r = cfg.cores[0].rows
        if r == 8:
            return {"edp": float("nan")}
        if r == 16:
            return {"total_cycles": float("inf"), "edp": 1.0}
        return {"edp": 2.0, "stall_inflation": float("inf")}

    res = (Study("nonfinite").designs(preset_grid(array=[8, 16, 32]))
           .workloads({"w": OPS_B[:1]}).fidelity("fast")
           .evaluator(ev).run())
    assert res.failed_cells == [0, 1]
    assert res["cell_status"][2] == 0.0
    assert res["stall_inflation"][2] == float("inf")


def test_argbest_all_failed_raises_loudly():
    def ev(cfg, ops, fid):
        return {"m": float("nan")}
    res = (Study("allbad").designs(preset_grid(array=[8, 16]))
           .workloads({"w": OPS_B[:1]}).fidelity("fast")
           .evaluator(ev).run())
    assert res.failed_cells == [0, 1]
    with pytest.raises(ValueError, match="no finite"):
        res.argbest("m")


def test_pareto_excludes_failed_rows():
    """NaN compares false against everything: without the finite mask a
    failed cell would always survive as 'non-dominated'."""
    cols = {
        "design": np.array(["d0", "d1", "d2"], dtype=object),
        "workload": np.array(["w", "w", "w"], dtype=object),
        "fidelity": np.array(["fast"] * 3, dtype=object),
        "a": np.array([1.0, np.nan, 2.0]),
        "b": np.array([2.0, np.nan, 1.0]),
        "cell_status": np.array([0.0, 1.0, 0.0]),
    }
    res = StudyResult(cols, {"design": ["d0", "d1", "d2"],
                             "workload": ["w"], "fidelity": ["fast"]})
    front = res.pareto("a", "b")
    assert sorted(front["design"]) == ["d0", "d2"]


def test_failed_cells_never_cached(tmp_path):
    """A transient failure must re-execute next run — caching a failed
    cell would make it permanent."""
    cache = str(tmp_path / "cells")
    attempt = {"n": 0}

    def ev(cfg, ops, fid):
        if cfg.cores[0].rows == 16:
            attempt["n"] += 1
            if attempt["n"] == 1:
                raise RuntimeError("transient")
        return {"m": float(cfg.cores[0].rows)}

    mk = lambda: (Study("retry").designs(preset_grid(array=[8, 16, 32]))
                  .workloads({"w": OPS_B[:1]}).fidelity("fast")
                  .evaluator(ev).cache(cache))
    first = mk().run()
    assert first.failed_cells == [1] and first.executed_cells == 2
    second = mk().run()             # healthy cells hit, sick cell retries
    assert second.failed_cells == [] and not np.isnan(second["m"]).any()
    assert second.cache_hits == 2 and second.executed_cells == 1


def test_checkpoint_resume_after_midrun_crash(tmp_path):
    """Cells checkpoint to the cache as they complete: a run killed
    mid-study resumes from its last completed cell."""
    from repro.faults import InjectedCrash
    cache = str(tmp_path / "cells")
    calls = []

    def ev(cfg, ops, fid):
        calls.append(cfg.cores[0].rows)
        if len(calls) == 3:
            raise InjectedCrash("kill -9 mid-study")
        return {"m": float(cfg.cores[0].rows)}

    mk = lambda: (Study("ckpt").designs(preset_grid(array=[8, 16, 32, 64]))
                  .workloads({"w": OPS_B[:1]}).fidelity("fast")
                  .evaluator(ev).cache(cache))
    with pytest.raises(InjectedCrash):
        mk().run()
    assert len(calls) == 3          # two completed + the killed one
    res = mk().run()                # resumes: only 2 cells re-execute
    assert res.cache_hits == 2 and res.executed_cells == 2
    assert res.failed_cells == []
    assert list(res["m"]) == [8.0, 16.0, 32.0, 64.0]
