import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AcceleratorConfig, CoreConfig, simulate_network,
                        simulate_op, tpu_like_config)
from repro.core.accelerator import LayoutConfig, SparsityConfig
from repro.core.engine import energy_traced, gemm_summary_traced
from repro.core.workloads import Op, lm_ops, resnet18, total_macs
from repro.configs import get_config


def test_network_report_totals():
    cfg = tpu_like_config(array=32)
    rep = simulate_network(cfg, resnet18())
    assert rep.total_cycles == pytest.approx(
        sum(o.total_cycles for o in rep.ops))
    assert rep.energy_pj > 0 and 0 < rep.utilization <= 1


def test_vector_ops_on_simd():
    cfg = tpu_like_config(array=32)
    r = simulate_op(cfg, Op("softmax", kind="vector", vector_elems=12800))
    assert r.kind == "vector"
    assert r.compute_cycles == pytest.approx(12800 / 128)


def test_sparsity_flows_through_engine():
    cfg = tpu_like_config(array=32).with_(
        sparsity=SparsityConfig(enabled=True, n=2, m=4))
    dense = simulate_network(tpu_like_config(array=32), resnet18()[:4])
    sp = simulate_network(cfg, resnet18()[:4])
    assert sp.compute_cycles < dense.compute_cycles
    assert sp.ops[0].sparse_storage["total_bytes"] < \
        sp.ops[0].sparse_storage["original_bytes"]


def test_layout_slows_down():
    lc = LayoutConfig(enabled=True, num_banks=2, line_bytes=32)
    cfg = tpu_like_config(array=32).with_(layout=lc)
    base = simulate_network(tpu_like_config(array=32), resnet18()[:3])
    lay = simulate_network(cfg, resnet18()[:3])
    assert lay.total_cycles >= base.total_cycles


def test_count_scales_stalls_linearly():
    """Regression: `count=k` must scale ALL cycle components exactly k-fold.
    The old engine divided dram_bytes by count before the stall model even
    though traffic is already per-instance, double-discounting DRAM stalls
    for repeated ops (attention heads, layer repeats)."""
    cfg = tpu_like_config(array=32, sram_mb=0.25)
    r1 = simulate_op(cfg, Op("g", 256, 4096, 2048, count=1.0))
    r4 = simulate_op(cfg, Op("g", 256, 4096, 2048, count=4.0))
    assert r1.stall_cycles > 0                    # memory-bound on purpose
    assert r4.stall_cycles == pytest.approx(4 * r1.stall_cycles)
    assert r4.compute_cycles == pytest.approx(4 * r1.compute_cycles)
    assert r4.total_cycles == pytest.approx(4 * r1.total_cycles)
    assert r4.dram_bytes == pytest.approx(4 * r1.dram_bytes)


def test_dram_cycle_fidelity():
    cfg = tpu_like_config(array=32)
    r = simulate_op(cfg, resnet18()[0], dram_fidelity="cycle")
    assert r.dram_stats is not None
    assert r.dram_stats["row_hits"] > 0


def test_lm_extractor_all_archs():
    for arch in ("qwen2-1.5b", "mixtral-8x7b", "zamba2-7b", "xlstm-1.3b",
                 "whisper-base", "internvl2-1b"):
        cfg = get_config(arch)
        ops = lm_ops(cfg, seq=512, batch=2, mode="train")
        assert total_macs(ops) > 0
        dec = lm_ops(cfg, seq=512, batch=2, mode="decode", cache_len=512)
        assert total_macs(dec) < total_macs(ops)


def test_moe_extractor_counts_active_only():
    cfg = get_config("mixtral-8x7b")
    ops = lm_ops(cfg, seq=128, batch=1, mode="prefill")
    moe = [o for o in ops if "moe_up" in o.name][0]
    assert moe.count == cfg.top_k                  # not num_experts


def test_traced_path_vmaps():
    Ms = jnp.array([64, 128, 256])
    f = jax.vmap(lambda m: gemm_summary_traced(
        "ws", m, 1024, 512, 32, 32, sram_elems=1 << 18,
        bw_bytes_per_cycle=38.4)["total_cycles"])
    out = f(Ms)
    assert out.shape == (3,) and bool((out[1:] >= out[:-1]).all())


def test_traced_matches_engine_compute():
    from repro.core.dataflow import compute_cycles
    t = gemm_summary_traced("ws", 512, 4096, 1024, 32, 32,
                            sram_elems=1 << 30, bw_bytes_per_cycle=1e9)
    assert int(t["compute_cycles"]) == int(
        compute_cycles("ws", 512, 4096, 1024, 32, 32))


def test_energy_traced_positive():
    e = energy_traced(1e6, 1e9, 1e8, 32, 32)
    assert float(e) > 0
