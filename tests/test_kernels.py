"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conflict import (conflict_slowdown,
                                    conflict_slowdown_reference)
from repro.kernels.systolic import (batched_fold_activity, simulate_fold,
                                    systolic_matmul, systolic_ws_reference,
                                    total_cycles_ws,
                                    wavefront_activity_reference)

SHAPES = [(16, 8, 8), (37, 16, 8), (64, 32, 16), (100, 32, 32)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("T,R,C", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_fold_matches_cycle_accurate_oracle(T, R, C, dt):
    key = jax.random.PRNGKey(T * 31 + R)
    x = jax.random.normal(key, (T, R), dt)
    w = jax.random.normal(jax.random.fold_in(key, 1), (R, C), dt)
    sim = simulate_fold(x, w, interpret=True)
    out_ref, act_ref = systolic_ws_reference(x, w)
    np.testing.assert_allclose(np.asarray(sim.out, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=2e-2, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(sim.active[R:]),
                                  np.asarray(act_ref))
    assert sim.cycles == total_cycles_ws(T, R, C)
    assert 0 < float(sim.utilization) <= 1.0


@pytest.mark.parametrize("T,R,C", SHAPES)
def test_wavefront_closed_form(T, R, C):
    ref = wavefront_activity_reference(T, R, C)
    # total active-PE-cycles == total MACs
    assert int(ref.sum()) == T * R * C
    assert int(ref.max()) <= R * C


def test_matmul_kernel_blocked_shapes():
    key = jax.random.PRNGKey(0)
    for (T, R, C) in [(256, 64, 256), (300, 32, 130)]:
        x = jax.random.normal(key, (T, R), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (R, C), jnp.float32)
        got = systolic_matmul(x, w, blk_t=128, blk_c=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)


def test_batched_fold_activity():
    Ts = jnp.array([16, 32, 64])
    out = batched_fold_activity(Ts, R=8, C=8, n_cycles=64 + 8 + 8 - 2,
                                interpret=True)
    for i, t in enumerate([16, 32, 64]):
        ref = wavefront_activity_reference(t, 8, 8)
        np.testing.assert_array_equal(np.asarray(out[i][:ref.shape[0]]),
                                      np.asarray(ref))


@pytest.mark.parametrize("cycles,k,banks,ports", [
    (64, 16, 8, 1), (96, 48, 16, 2), (128, 24, 4, 1), (32, 64, 32, 4)])
def test_conflict_kernel_sweep(cycles, k, banks, ports):
    key = jax.random.PRNGKey(cycles + k)
    line = jax.random.randint(key, (cycles, k), 0, 11)
    bank = jax.random.randint(jax.random.fold_in(key, 1), (cycles, k),
                              0, banks)
    got = conflict_slowdown(line, bank, num_banks=banks, ports=ports,
                            interpret=True)
    want = conflict_slowdown_reference(line, bank, num_banks=banks,
                                       ports=ports)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rows,K,m", [(16, 32, 4), (64, 64, 8), (33, 48, 4)])
def test_ellpack_pack_kernel(rows, K, m):
    from repro.kernels.ellpack import ellpack_pack, ellpack_pack_reference
    key = jax.random.PRNGKey(rows + K)
    w = jax.random.normal(key, (rows, K))
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4, (rows, K))
    w = jnp.where(mask, w, 0.0)
    wb = w.reshape(rows, K // m, m)
    nz = wb != 0
    rank = jnp.cumsum(nz, -1) - nz
    w = jnp.where(rank < m // 2, wb, 0.0).reshape(rows, K)   # N <= M/2
    v, i = ellpack_pack(w, m=m, interpret=True)
    vr, ir = ellpack_pack_reference(w, m=m)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    # every stored value is nonzero or padding; indices are intra-block
    assert int(i.max()) < m
