import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import SparsityConfig
from repro.core.sparsity import (effective_K, expected_rowwise_n,
                                 metadata_bits, pack_ellpack_block,
                                 sparse_compute_cycles, storage_report)


def test_nm_constraint_enforced():
    with pytest.raises(ValueError):
        SparsityConfig(enabled=True, n=3, m=4, row_wise=True)  # N > M/2
    SparsityConfig(enabled=True, n=2, m=4, row_wise=True)       # ok
    SparsityConfig(enabled=True, n=3, m=4, row_wise=False)      # layer-wise ok


def test_effective_k_layerwise():
    sp = SparsityConfig(enabled=True, n=2, m=4)
    assert effective_K(1024, sp) == 512
    sp14 = SparsityConfig(enabled=True, n=1, m=4)
    assert effective_K(1024, sp14) == 256


def test_2to4_exactly_halves_compute():
    """Ampere 2:4 validation (paper Sec. VIII): 2x compute reduction."""
    dense = sparse_compute_cycles("ws", 512, 4096, 1024, 32, 32,
                                  SparsityConfig())
    sp = sparse_compute_cycles("ws", 512, 4096, 1024, 32, 32,
                               SparsityConfig(enabled=True, n=2, m=4))
    # streaming term dominates at T=4096: ratio within fold rounding of 2x
    assert 1.8 < float(dense) / float(sp) <= 2.05


def test_sparser_never_slower():
    prev = None
    for n in (4, 3, 2, 1):
        c = float(sparse_compute_cycles(
            "ws", 512, 512, 2048, 32, 32,
            SparsityConfig(enabled=(n < 4), n=n, m=4)))
        if prev is not None:
            assert c <= prev
        prev = c


def test_storage_report_fig7():
    """Fig. 7: storage (values + metadata) shrinks with sparsity."""
    rows, K = 512, 4608
    dense = storage_report(rows, K, SparsityConfig())["total_bytes"]
    last = dense
    for n in (3, 2, 1):
        sp = SparsityConfig(enabled=True, n=n, m=4)
        r = storage_report(rows, K, sp)
        assert r["metadata_bytes"] > 0
        assert r["total_bytes"] < last
        last = r["total_bytes"]
    # metadata bits per value = log2(M)
    assert metadata_bits(4) == 2
    assert metadata_bits(32) == 5


def test_storage_representations():
    rows, K = 256, 1024
    sp_ell = SparsityConfig(enabled=True, n=2, m=4)
    sp_csr = SparsityConfig(enabled=True, n=2, m=4, representation="csr")
    sp_csc = SparsityConfig(enabled=True, n=2, m=4, representation="csc")
    e = storage_report(rows, K, sp_ell)
    c = storage_report(rows, K, sp_csr)
    cc = storage_report(rows, K, sp_csc)
    # blocked ELLPACK metadata (2 bits/val) beats CSR byte indices
    assert e["metadata_bytes"] < c["metadata_bytes"]
    assert abs(c["values_bytes"] - cc["values_bytes"]) < 1e-6


def test_rowwise_expectation():
    assert expected_rowwise_n(4) == 1.5          # Uniform{1, 2}
    sp = SparsityConfig(enabled=True, n=1, m=8, row_wise=True)
    k_eff = effective_K(1024, sp, cols_in_fold=32)
    # lockstep max over 32 columns approaches M/2 per block
    assert 1024 * (4 / 8) * 0.8 < float(k_eff) <= 1024 * (4 / 8)


def test_pack_ellpack_roundtrip():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 16))
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4, (8, 16))
    w = jnp.where(mask, w, 0.0)
    vals, idx, counts = pack_ellpack_block(w, m=4)
    # every nonzero is represented at its claimed index
    wb = np.asarray(w).reshape(8, 4, 4)
    for r in range(8):
        for b in range(4):
            got = {int(i): float(v) for v, i in
                   zip(np.asarray(vals[r, b]), np.asarray(idx[r, b]))
                   if i >= 0}
            want = {j: wb[r, b, j] for j in range(4) if wb[r, b, j] != 0}
            assert got.keys() == want.keys()
            for j in want:
                assert abs(got[j] - want[j]) < 1e-6
