import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import SparsityConfig
from repro.core.sparsity import (effective_K, effective_K_model,
                                 expected_rowwise_n, metadata_bits,
                                 pack_ellpack_block, sparse_compute_cycles,
                                 storage_report)


def test_nm_constraint_enforced():
    with pytest.raises(ValueError):
        SparsityConfig(enabled=True, n=3, m=4, row_wise=True)  # N > M/2
    SparsityConfig(enabled=True, n=2, m=4, row_wise=True)       # ok
    SparsityConfig(enabled=True, n=3, m=4, row_wise=False)      # layer-wise ok


def test_effective_k_layerwise():
    sp = SparsityConfig(enabled=True, n=2, m=4)
    assert effective_K(1024, sp) == 512
    sp14 = SparsityConfig(enabled=True, n=1, m=4)
    assert effective_K(1024, sp14) == 256


def test_2to4_exactly_halves_compute():
    """Ampere 2:4 validation (paper Sec. VIII): 2x compute reduction."""
    dense = sparse_compute_cycles("ws", 512, 4096, 1024, 32, 32,
                                  SparsityConfig())
    sp = sparse_compute_cycles("ws", 512, 4096, 1024, 32, 32,
                               SparsityConfig(enabled=True, n=2, m=4))
    # streaming term dominates at T=4096: ratio within fold rounding of 2x
    assert 1.8 < float(dense) / float(sp) <= 2.05


def test_sparser_never_slower():
    prev = None
    for n in (4, 3, 2, 1):
        c = float(sparse_compute_cycles(
            "ws", 512, 512, 2048, 32, 32,
            SparsityConfig(enabled=(n < 4), n=n, m=4)))
        if prev is not None:
            assert c <= prev
        prev = c


def test_storage_report_fig7():
    """Fig. 7: storage (values + metadata) shrinks with sparsity."""
    rows, K = 512, 4608
    dense = storage_report(rows, K, SparsityConfig())["total_bytes"]
    last = dense
    for n in (3, 2, 1):
        sp = SparsityConfig(enabled=True, n=n, m=4)
        r = storage_report(rows, K, sp)
        assert r["metadata_bytes"] > 0
        assert r["total_bytes"] < last
        last = r["total_bytes"]
    # metadata bits per value = log2(M)
    assert metadata_bits(4) == 2
    assert metadata_bits(32) == 5


def test_storage_representations():
    rows, K = 256, 1024
    sp_ell = SparsityConfig(enabled=True, n=2, m=4)
    sp_csr = SparsityConfig(enabled=True, n=2, m=4, representation="csr")
    sp_csc = SparsityConfig(enabled=True, n=2, m=4, representation="csc")
    e = storage_report(rows, K, sp_ell)
    c = storage_report(rows, K, sp_csr)
    cc = storage_report(rows, K, sp_csc)
    # blocked ELLPACK metadata (2 bits/val) beats CSR byte indices
    assert e["metadata_bytes"] < c["metadata_bytes"]
    assert abs(c["values_bytes"] - cc["values_bytes"]) < 1e-6


def test_rowwise_expectation():
    assert expected_rowwise_n(4) == 1.5          # Uniform{1, 2}
    sp = SparsityConfig(enabled=True, n=1, m=8, row_wise=True)
    k_eff = effective_K(1024, sp, cols_in_fold=32)
    # lockstep max over 32 columns approaches M/2 per block
    assert 1024 * (4 / 8) * 0.8 < float(k_eff) <= 1024 * (4 / 8)


# ---- sparsity invariants (ISSUE 5 property tests) --------------------------

def test_effective_k_monotone_in_n():
    """K' is monotone nondecreasing in n for every (K, m, cols) — denser
    blocks can never shorten the compressed reduction."""
    for K in (64, 777, 4096):
        for m in (4, 8, 16):
            for cols in (1, 32):
                ks = [int(effective_K(
                    K, SparsityConfig(enabled=True, n=n, m=m), cols))
                    for n in range(1, m + 1)]
                assert ks == sorted(ks), (K, m, cols, ks)
                assert all(1 <= k <= K for k in ks)


def test_effective_k_dense_parity_at_n_eq_m():
    """n == m is dense: K' == K exactly and the compressed-stream compute
    cycles equal the dense mapping for every dataflow."""
    from repro.core.dataflow import compute_cycles
    for m in (4, 8):
        sp = SparsityConfig(enabled=True, n=m, m=m)
        for K in (512, 1000):
            assert int(effective_K(K, sp, 32)) == K
        for df in ("ws", "os", "is"):
            dense = compute_cycles(df, 384, 512, 1024, 32, 32)
            sparse = sparse_compute_cycles(df, 384, 512, 1024, 32, 32, sp)
            assert float(sparse) == float(dense)


def test_rowwise_expected_k_bounded():
    """Row-wise expected-K sits between layer-wise n=1 and layer-wise
    n=m/2 (the lockstep max of Uniform{1..m/2} draws can neither beat a
    single nonzero per block nor exceed m/2 per block), and below dense."""
    for m in (4, 8, 16):
        for K in (512, 4096):
            for cols in (1, 8, 64):
                rw = int(effective_K(
                    K, SparsityConfig(enabled=True, n=1, m=m,
                                      row_wise=True), cols))
                lo = int(effective_K(
                    K, SparsityConfig(enabled=True, n=1, m=m), cols))
                hi = int(effective_K(
                    K, SparsityConfig(enabled=True, n=m // 2, m=m), cols))
                assert lo <= rw <= hi <= K, (m, K, cols, lo, rw, hi)


def test_rowwise_expected_k_monotone_in_cols():
    """More lockstep columns -> larger expected fold max -> larger K'."""
    sp = SparsityConfig(enabled=True, n=2, m=8, row_wise=True)
    ks = [int(effective_K(4096, sp, c)) for c in (1, 2, 8, 32, 128)]
    assert ks == sorted(ks)


def test_metadata_storage_conservation_across_representations():
    """ELLPACK/CSR/CSC carry the same nonzeros (values bytes identical);
    totals = values + metadata; every sparse total beats dense for 2:4;
    row-wise nnz follows the Uniform{1..m/2} expectation exactly."""
    rows, K, wb = 512, 4096, 2
    reps = ("ellpack_block", "csr", "csc")
    for row_wise in (False, True):
        outs = [storage_report(
            rows, K, SparsityConfig(enabled=True, n=2, m=8,
                                    row_wise=row_wise, representation=r),
            wb) for r in reps]
        vals = {o["values_bytes"] for o in outs}
        assert len(vals) == 1                       # nnz conserved
        if row_wise:
            nnz = rows * (K / 8) * expected_rowwise_n(8)
        else:
            nnz = rows * K * 2 / 8
        assert outs[0]["values_bytes"] == pytest.approx(nnz * wb, rel=1e-6)
        for o in outs:
            assert o["total_bytes"] == pytest.approx(
                o["values_bytes"] + o["metadata_bytes"], rel=1e-6)
            assert o["metadata_bytes"] > 0
            assert o["total_bytes"] < o["original_bytes"]
        # ELLPACK block metadata (log2(m) bits/value) is the cheapest
        assert outs[0]["metadata_bytes"] == min(o["metadata_bytes"]
                                                for o in outs)


def test_effective_k_model_vmaps_over_mixed_grid():
    """The traced model batches dense + layer-wise + row-wise cells in
    one vmap and matches the eager per-config path exactly."""
    cfgs = [SparsityConfig(),
            SparsityConfig(enabled=True, n=2, m=4),
            SparsityConfig(enabled=True, n=1, m=4),
            SparsityConfig(enabled=True, n=2, m=8, row_wise=True)]
    K, cols = 4096, 32
    batched = jax.vmap(
        lambda en, n, m, rw: effective_K_model(1.0 * K, n, m, rw,
                                               1.0 * cols, enabled=en))(
        jnp.array([1.0 * c.enabled for c in cfgs]),
        jnp.array([1.0 * c.n for c in cfgs]),
        jnp.array([1.0 * c.m for c in cfgs]),
        jnp.array([1.0 * c.row_wise for c in cfgs]))
    eager = [effective_K(K, c, cols) for c in cfgs]
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(eager))


def test_pack_ellpack_roundtrip():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 16))
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4, (8, 16))
    w = jnp.where(mask, w, 0.0)
    vals, idx, counts = pack_ellpack_block(w, m=4)
    # every nonzero is represented at its claimed index
    wb = np.asarray(w).reshape(8, 4, 4)
    for r in range(8):
        for b in range(4):
            got = {int(i): float(v) for v, i in
                   zip(np.asarray(vals[r, b]), np.asarray(idx[r, b]))
                   if i >= 0}
            want = {j: wb[r, b, j] for j in range(4) if wb[r, b, j] != 0}
            assert got.keys() == want.keys()
            for j in want:
                assert abs(got[j] - want[j]) < 1e-6
