"""Dry-run cell logic that doesn't need 512 devices: cell enumeration,
skip policy, MODEL_FLOPS accounting, cache sizing, artifact sanity."""
import glob
import json
import os

import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, runnable_cells, skip_reason

# importing the dryrun module sets XLA_FLAGS=...512 (required to precede
# jax init when *running* dry-runs); scrub it so sibling tests' subprocesses
# don't inherit 512 fake devices.
_prev = os.environ.get("XLA_FLAGS")
from repro.launch.dryrun import cell_list, model_flops  # noqa: E402
if _prev is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _prev

from repro.models.zoo import ModelBundle  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def test_cell_enumeration():
    cells = cell_list()
    assert len(cells) == 66                       # 33 runnable pairs x 2 meshes
    archs = {c[0] for c in cells}
    assert len(archs) == 10


def test_long_context_skip_policy():
    # sub-quadratic archs run long_500k
    for a in ("mixtral-8x7b", "zamba2-7b", "xlstm-1.3b"):
        assert skip_reason(get_config(a), "long_500k") is None
    # pure full-attention archs skip it
    for a in ("qwen2-72b", "yi-34b", "glm4-9b", "whisper-base",
              "internvl2-1b", "granite-moe-3b-a800m", "qwen2-1.5b"):
        assert skip_reason(get_config(a), "long_500k") is not None
    assert all(len(runnable_cells(get_config(a))) >= 3 for a in list_archs())


def test_model_flops_accounting():
    cfg = get_config("qwen2-72b")
    n = cfg.active_param_count()
    assert model_flops(cfg, seq=4096, batch=256, mode="train") == \
        pytest.approx(6 * n * 4096 * 256)
    assert model_flops(cfg, seq=32768, batch=128, mode="decode") == \
        pytest.approx(2 * n * 128)
    # MoE uses ACTIVE params
    moe = get_config("mixtral-8x7b")
    assert moe.active_param_count() < 0.4 * moe.param_count()
    assert model_flops(moe, seq=1, batch=1, mode="prefill") == \
        pytest.approx(2 * moe.active_param_count())


def test_windowed_cache_is_bounded():
    """long_500k is O(window) for SWA archs: mixtral's cache allocates the
    4096-slot ring regardless of the 524288-token context."""
    b = ModelBundle(get_config("mixtral-8x7b"))
    sds = b.cache_sds(batch=1, cache_len=524288)
    k = sds["k"]
    assert k.shape[2] == 4096                     # window, not 524288
    # ssm archs carry O(1) state
    bx = ModelBundle(get_config("xlstm-1.3b"))
    leaves = bx.cache_sds(batch=1, cache_len=524288)
    total = sum(int(s.size) * s.dtype.itemsize
                for s in __import__("jax").tree.leaves(leaves))
    assert total < 2 * 2 ** 30                    # < 2 GiB of state


@pytest.mark.skipif(not glob.glob(os.path.join(ART_DIR, "*.json")),
                    reason="dry-run artifacts not generated")
def test_dryrun_artifacts_complete_and_sane():
    cells = [json.load(open(p))
             for p in glob.glob(os.path.join(ART_DIR, "*.json"))]
    ok = [c for c in cells if c.get("ok")]
    assert len(ok) == 66
    for c in ok:
        t = c["terms"]
        assert all(v >= 0 for v in t.values())
        assert c["hlo_flops_per_device"] > 0
        assert c["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert 0 < c["useful_flops_ratio"] < 5
        # multipod halves per-chip compute vs pod (weak scaling)
    by = {(c["arch"], c["shape"], c["mesh"]): c for c in ok}
    for (a, s, m), c in by.items():
        # weak scaling holds where the batch spreads over the pod axis
        # (batch-1 long-context and tiny decode steps replicate by design)
        if m == "pod" and (a, s, "multipod") in by and \
                s in ("train_4k", "prefill_32k"):
            mp = by[(a, s, "multipod")]
            ratio = c["terms"]["compute_s"] / max(mp["terms"]["compute_s"],
                                                  1e-12)
            assert 1.2 < ratio < 3.5, (a, s, ratio)
