"""Multi-device sharding correctness: runs subprocesses with 8 fake host
devices (device count locks at first jax init, so these can't share the main
test process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.zoo import ModelBundle
        from repro.configs import get_config
        from repro.dist.sharding import make_mesh_ctx
        from repro.optim import adamw_init

        cfg = get_config("qwen2-72b", smoke=True)
        b = ModelBundle(cfg)
        params = b.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        B, L = 4, 32
        batch = {"tokens": jnp.ones((B, L), jnp.int32),
                 "labels": jnp.ones((B, L), jnp.int32),
                 "loss_mask": jnp.ones((B, L), jnp.float32)}
        ref_loss = float(jax.jit(b.loss_fn(None))(params, batch))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = make_mesh_ctx(mesh)
        with jax.set_mesh(mesh):
            sharded = jax.jit(b.loss_fn(ctx))
            got = float(sharded(params, batch))
        assert abs(got - ref_loss) < 5e-2, (got, ref_loss)
        print("loss match:", got, ref_loss)
    """))


def test_sharded_moe_matches_local():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.zoo import ModelBundle
        from repro.configs import get_config
        from repro.dist.sharding import make_mesh_ctx

        cfg = get_config("mixtral-8x7b", smoke=True)
        b = ModelBundle(cfg)
        params = b.init(jax.random.PRNGKey(1))
        B, L = 4, 32
        batch = {"tokens": (jnp.arange(B * L, dtype=jnp.int32).reshape(B, L)
                            % cfg.vocab),
                 "labels": jnp.ones((B, L), jnp.int32),
                 "loss_mask": jnp.ones((B, L), jnp.float32)}
        ref = float(jax.jit(b.loss_fn(None))(params, batch))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = make_mesh_ctx(mesh)
        with jax.set_mesh(mesh):
            got = float(jax.jit(b.loss_fn(ctx))(params, batch))
        # MoE capacity differs between 1-shard and 8-shard dispatch
        # (per-shard capacity rounding); tolerance reflects that.
        assert abs(got - ref) / ref < 0.05, (got, ref)
        print("moe loss:", got, ref)
    """))


def test_multipod_mesh_axes():
    print(_run("""
        import jax
        from repro.launch.mesh import make_production_mesh
        # 8 fake devices can't build 512; verify the axis logic via shape math
        from repro.dist.sharding import make_mesh_ctx
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = make_mesh_ctx(mesh)
        assert ctx.multi_pod and ctx.dp == 4 and ctx.tp == 2
        assert ctx.dp_axes == ("pod", "data")
        print("multipod ctx ok")
    """))


def test_elastic_restore_across_mesh_shapes(tmp_path):
    print(_run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager

        mgr = CheckpointManager({str(tmp_path)!r})
        mesh_a = jax.make_mesh((8,), ("data",))
        tree = {{"w": jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh_a, P("data", None)))}}
        mgr.save(1, tree, blocking=True)

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        sh = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
        out = mgr.restore(tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(64).reshape(8, 8))
        assert out["w"].sharding.spec == P("model", "data")
        print("elastic restore ok")
    """))
