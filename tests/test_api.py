"""The unified `repro.api` surface: Simulator facade parity with the
engine, config serde + presets, and the batched sweep path."""
import json

import numpy as np
import pytest

from repro.api import (Simulator, as_config, get_preset, list_presets,
                       preset_grid, register_preset)
from repro.core import (AcceleratorConfig, simulate_network, simulate_op,
                        tpu_like_config)
from repro.core.accelerator import LayoutConfig, SparsityConfig
from repro.core.workloads import Op, resnet18


# ---- facade parity ---------------------------------------------------------

def test_simulator_fast_matches_engine():
    wl = resnet18()
    rep = Simulator("paper-32").run(wl)
    old = simulate_network(tpu_like_config(array=32), wl)
    assert rep.total_cycles == pytest.approx(old.total_cycles)
    assert rep.energy_pj == pytest.approx(old.energy_pj)
    assert rep.stall_cycles == pytest.approx(old.stall_cycles)
    assert [o.total_cycles for o in rep.ops] == \
        pytest.approx([o.total_cycles for o in old.ops])


def test_simulator_cycle_matches_engine():
    wl = resnet18()[:2]
    rep = Simulator("paper-32", fidelity="cycle").run(wl)
    old = simulate_network(tpu_like_config(array=32), wl,
                           dram_fidelity="cycle")
    assert rep.total_cycles == pytest.approx(old.total_cycles)
    assert rep.ops[0].dram_stats is not None


def test_simulator_feature_configs_compose():
    sp = Simulator("paper-32").with_(
        sparsity=SparsityConfig(enabled=True, n=2, m=4))
    lay = Simulator("paper-32").with_(layout=LayoutConfig(enabled=True))
    base = Simulator("paper-32").run(resnet18()[:3])
    assert sp.run(resnet18()[:3]).compute_cycles < base.compute_cycles
    assert lay.run(resnet18()[:3]).total_cycles >= base.total_cycles


def test_workload_by_name_and_stage_names():
    sim = Simulator("paper-32")
    assert sim.run("resnet18").total_cycles > 0
    names = sim.stage_names()
    assert names[0] == "mapping" and names[-1] == "energy"
    assert "dram[fast]" in names
    assert "dram[cycle]" in Simulator(fidelity="cycle").stage_names()
    with pytest.raises(ValueError):
        Simulator(fidelity="nope")
    with pytest.raises(KeyError):
        sim.run("not_a_workload")


# ---- config serde + presets ------------------------------------------------

def test_config_dict_roundtrip_json_safe():
    for name in ("paper-32", "multicore-16x32", "edge-8"):
        cfg = get_preset(name)
        d = json.loads(json.dumps(cfg.to_dict()))   # through real JSON
        assert AcceleratorConfig.from_dict(d) == cfg


def test_from_dict_partial_and_as_config():
    cfg = AcceleratorConfig.from_dict(
        {"dataflow": "os", "cores": [{"rows": 16, "cols": 16}]})
    assert cfg.dataflow == "os" and cfg.cores[0].num_pes == 256
    assert as_config("paper-64").cores[0].rows == 64
    assert as_config(cfg) is cfg
    assert as_config(cfg.to_dict()) == cfg
    with pytest.raises(TypeError):
        as_config(42)


def test_preset_registry():
    assert {"paper-32", "tpu-like", "edge-8"} <= set(list_presets())
    assert get_preset("tpu-like", array=8).cores[0].rows == 8
    with pytest.raises(KeyError):
        get_preset("no-such-accelerator")
    with pytest.raises(ValueError):
        register_preset("paper-32")(lambda: None)
    grid = preset_grid(array=[8, 16], sram_mb=[1.0, 2.0])
    assert len(grid) == 4 and grid[0].cores[0].rows == 8


# ---- batched sweep ---------------------------------------------------------

OPS = [Op("a", 256, 1024, 512), Op("b", 512, 197, 768, count=3.0),
       Op("v", kind="vector", vector_elems=8192.0, count=2.0)]


def test_sweep_smoke_2x2_grid():
    grid = preset_grid(array=[16, 32], sram_mb=[0.5, 2.0])
    res = Simulator().sweep(grid, OPS)
    assert res.batched and len(res) == 4
    for i, cfg in enumerate(grid):
        rep = simulate_network(cfg, OPS)
        assert res.total_cycles[i] == pytest.approx(rep.total_cycles,
                                                    rel=1e-3)
        assert res.energy_pj[i] == pytest.approx(rep.energy_pj, rel=1e-3)
        assert res.dram_bytes[i] == pytest.approx(rep.dram_bytes, rel=1e-3)
        assert res.utilization[i] == pytest.approx(rep.utilization,
                                                   rel=1e-3, abs=1e-6)
    assert res.edp.shape == (4,)
    assert res.best("latency") is grid[res.argbest("latency")]


def test_sweep_64_points_single_batched_call():
    """Acceptance: a >= 64-point grid in one vmapped call, per-point results
    within 1e-3 of loop-of-simulate_op."""
    grid = preset_grid(array=[8, 16, 32, 64],
                       sram_mb=[0.25, 0.5, 1.0, 4.0],
                       dataflow=["ws", "os", "is", "ws"])
    assert len(grid) == 64
    res = Simulator().sweep(grid, OPS)
    assert res.batched
    for i in (0, 7, 21, 42, 63):
        rep = simulate_network(grid[i], OPS)
        assert res.total_cycles[i] == pytest.approx(rep.total_cycles,
                                                    rel=1e-3)
        assert res.energy_pj[i] == pytest.approx(rep.energy_pj, rel=1e-3)


def test_sweep_trace_fidelity_batched():
    """ISSUE 2 acceptance: trace-fidelity points run through the batched
    (vmapped) path for traceable configs — no per-op Python fallback —
    and match the per-op engine."""
    grid = preset_grid(array=[16, 32], sram_mb=[0.5, 2.0])
    res = Simulator(fidelity="trace").sweep(grid, OPS[:2])
    assert res.batched and len(res) == 4
    for i in (0, 3):
        rep = simulate_network(grid[i], OPS[:2], dram_fidelity="trace")
        assert res.total_cycles[i] == pytest.approx(rep.total_cycles,
                                                    rel=1e-3)
        assert res.stall_cycles[i] == pytest.approx(rep.stall_cycles,
                                                    rel=1e-3, abs=1.0)
    # generated-trace stalls differ from the first-order model
    fast = Simulator(fidelity="fast").sweep(grid, OPS[:2])
    assert not np.allclose(res.stall_cycles, fast.stall_cycles)


def test_core_index_selects_heterogeneous_core():
    """The facade models the selected core's geometry in every
    core-dependent stage — not a silent cores[0] mix. (Compute cycles
    are partition-stage territory on a multi-core mesh; SRAM and DRAM
    traffic expose the per-core geometry directly.)"""
    from repro.core.accelerator import CoreConfig, MemoryConfig
    from repro.core.stages import CoreStage
    cfg = AcceleratorConfig(
        cores=(CoreConfig(rows=32, cols=32), CoreConfig(rows=8, cols=8)),
        mesh_rows=2, mesh_cols=1,
        memory=MemoryConfig(ifmap_sram_bytes=1 << 13,
                            filter_sram_bytes=1 << 13,
                            ofmap_sram_bytes=1 << 13))
    op = Op("g", 256, 256, 256)
    sim1 = Simulator(cfg, core_index=1)
    assert all(s.core_index == 1 for s in sim1.pipeline
               if isinstance(s, CoreStage))
    r0 = Simulator(cfg, core_index=0).run_op(op)
    r1 = sim1.run_op(op)
    assert r0.dram_bytes != r1.dram_bytes
    assert r0.sram_reads != r1.sram_reads


def test_trace_stage_names_and_spec():
    sim = Simulator("paper-32", fidelity="trace")
    assert "dram[trace]" in sim.stage_names()
    assert sim.trace_spec is not None
    assert sim.with_(dataflow="os").trace_spec == sim.trace_spec


def test_sweep_mixed_grid_batches_sparse_cells():
    """ISSUE 5: sparsity no longer ejects a cell from the batched path —
    a mixed dense/sparse grid sweeps fully vmapped and matches the
    per-op engine; the oracle stays reachable behind force_fallback."""
    grid = preset_grid(array=[16, 32])
    sparse = grid[0].with_(sparsity=SparsityConfig(enabled=True, n=2, m=4))
    res = Simulator().sweep(grid + [sparse], OPS[:2])
    assert res.batched
    rep = simulate_network(sparse, OPS[:2])
    assert res.total_cycles[2] == pytest.approx(rep.total_cycles, rel=1e-3)
    assert res.total_cycles[2] < res.total_cycles[0]
    oracle = Simulator().sweep(grid + [sparse], OPS[:2],
                               force_fallback=True)
    assert not oracle.batched
    assert oracle.total_cycles[2] == pytest.approx(rep.total_cycles,
                                                   rel=1e-6)


def test_sweep_sharded_over_host_mesh():
    import jax
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    grid = preset_grid(array=[8, 16, 32], sram_mb=[1.0])   # pads to size
    res = Simulator().sweep(grid, OPS[:1], mesh=mesh)
    rep = simulate_network(grid[1], OPS[:1])
    assert res.total_cycles[1] == pytest.approx(rep.total_cycles, rel=1e-3)


# ---- energy breakdown (NetworkReport contract) -----------------------------

def test_energy_breakdown_populated_and_in_csv(tmp_path):
    rep = Simulator("paper-32").run(resnet18()[:4])
    assert rep.energy_breakdown                       # non-empty
    assert sum(rep.energy_breakdown.values()) == \
        pytest.approx(rep.energy_pj, rel=1e-6)
    assert all(v >= 0 for v in rep.energy_breakdown.values())
    p = tmp_path / "rep.csv"
    rep.write_csv(str(p))
    header, first = p.read_text().splitlines()[:2]
    assert "energy_mac_pj" in header and "energy_dram_pj" in header
    row = dict(zip(header.split(","), first.split(",")))
    groups = sum(float(row[k]) for k in ("energy_mac_pj", "energy_sram_pj",
                                         "energy_dram_pj",
                                         "energy_static_pj"))
    assert groups == pytest.approx(float(row["energy_pj"]), rel=1e-3)
