"""Loop-aware HLO cost parser vs unrolled ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlocost import HloCost


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_scan_flops_multiplied_by_trip_count():
    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = HloCost(_compile(scanned, xs, ws).as_text()).totals()
    assert t["flops"] == pytest.approx(2 * 128 * 256 * 256 * 10, rel=0.01)


def test_grad_flops_counted():
    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def train(x, w):
        def loss(w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=4)
            return jnp.sum(h * h)
        return jax.grad(loss)(w)

    t = HloCost(_compile(train, xs, ws).as_text()).totals()
    # fwd 4 dots + bwd 2 dots/layer = 12 dot-equivalents
    assert t["flops"] == pytest.approx(2 * 128 * 256 * 256 * 12, rel=0.05)


def test_single_matmul_bytes_reasonable():
    xs = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    t = HloCost(_compile(lambda a, b: a @ b, xs, xs).as_text()).totals()
    expect = 3 * 512 * 512 * 4
    assert expect <= t["bytes"] if "bytes" in t else True
    assert t["hbm_bytes"] == pytest.approx(expect, rel=0.2)


def test_no_collectives_on_single_device():
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = HloCost(_compile(lambda a: a @ a, xs).as_text()).totals()
    assert t["collective_bytes"] == 0.0
