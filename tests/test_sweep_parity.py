"""Differential parity + full-coverage batching (ISSUE 5 tentpole).

Randomized design grids mixing dense / layer-wise N:M / row-wise N:M
sparsity, data-layout modeling and multi-core partitioning must (a) run
entirely through the batched jit+vmap sweep kernels
(`fraction_batched == 1.0`) and (b) agree with the per-op engine oracle —
kept alive behind `force_fallback=` purely for this suite — to <= 1e-3
per metric column. Cache hits must replay bit-identical frames.
"""
import numpy as np
import pytest

from repro.api import Simulator, Study, preset_grid
from repro.api.presets import as_sparsity, get_preset, with_cores
from repro.core.accelerator import LayoutConfig, SparsityConfig
from repro.core.workloads import Op

PARITY_COLUMNS = ("total_cycles", "compute_cycles", "stall_cycles",
                  "dram_bytes", "energy_pj", "utilization", "edp",
                  "energy_mac_pj", "energy_sram_pj", "energy_dram_pj",
                  "energy_static_pj")

# the last gemm carries a per-op N:M override (exercises
# stages.resolve_sparsity in both paths); (1, 4) stays legal when the
# design's SparsityConfig is row-wise (N <= M/2)
OPS = [Op("a", 256, 1024, 512), Op("b", 512, 197, 768, count=3.0),
       Op("v", kind="vector", vector_elems=8192.0, count=2.0),
       Op("c", 384, 256, 1024, sparsity_nm=(1, 4))]

SPARSITIES = (None, "2:4", "1:4", "2:8", "1:4-rw", "2:8-rw")


def _mixed_designs(seed: int, n: int, arrays=(8, 16, 32),
                   core_counts=(1, 4)):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        cfg = get_preset("tpu-like", array=int(rng.choice(arrays)),
                         sram_mb=float(rng.choice([0.25, 1.0])))
        cfg = cfg.with_(dataflow=str(rng.choice(["ws", "os", "is"])))
        cores = int(rng.choice(core_counts))
        if cores > 1:
            cfg = with_cores(cfg, cores)
        sp = SPARSITIES[int(rng.integers(len(SPARSITIES)))]
        if sp is not None:
            cfg = cfg.with_(sparsity=as_sparsity(sp))
        if rng.random() < 0.5:
            cfg = cfg.with_(layout=LayoutConfig(enabled=True))
        out[f"d{i}-{cores}c-{sp}"] = cfg
    return out


def _assert_parity(batched, oracle, columns=PARITY_COLUMNS, tol=1e-3):
    assert len(batched) == len(oracle)
    for col in columns:
        a = np.asarray(batched[col], float)
        b = np.asarray(oracle[col], float)
        rel = np.abs(a - b) / np.maximum(np.abs(b), 1.0)
        i = int(rel.argmax()) if len(rel) else 0
        assert rel.max(initial=0.0) <= tol, \
            (col, batched.row(i)["design"], a[i], b[i], float(rel.max()))


@pytest.mark.parametrize("seed", [0, 1])
def test_randomized_mixed_grid_parity_fast(seed):
    designs = _mixed_designs(seed, n=14)
    mk = lambda: (Study().designs(designs)
                  .workloads({"w": OPS, "w2": OPS[:2]}).fidelity("fast"))
    res = mk().run()
    assert res.fraction_batched == 1.0
    oracle = mk().options(force_fallback=True).run()
    assert oracle.fraction_batched == 0.0
    _assert_parity(res, oracle)


def test_randomized_mixed_grid_parity_trace():
    from repro.trace import TraceSpec
    designs = _mixed_designs(7, n=6, arrays=(16, 32))
    spec = TraceSpec(cap=1024)
    mk = lambda: (Study().designs(designs).workloads({"w": OPS[:2]})
                  .fidelity("trace").options(trace_spec=spec))
    res = mk().run()
    assert res.fraction_batched == 1.0
    oracle = mk().options(force_fallback=True).run()
    _assert_parity(res, oracle)
    # the generated-trace stalls genuinely differ from the fast model
    fast = (Study().designs(designs).workloads({"w": OPS[:2]})
            .fidelity("fast").run())
    assert not np.allclose(res["stall_cycles"], fast["stall_cycles"])


def test_acceptance_grid_dense_sparse_cores_layout():
    """The ISSUE 5 acceptance grid: {dense, 2:4 layer-wise, row-wise} x
    {1, 4} cores x layout on/off — fraction_batched == 1.0 from Study,
    batched metrics match the per-op oracle <= 1e-3."""
    grid = preset_grid(array=[32], sparsity=[None, "2:4", "1:4-rw"],
                       cores=[1, 4])
    designs = {}
    for i, c in enumerate(grid):
        for lay in (False, True):
            designs[f"g{i}{'-lay' if lay else ''}"] = c.with_(
                layout=LayoutConfig(enabled=lay))
    assert len(designs) == 12
    mk = lambda: Study().designs(designs).workloads({"w": OPS}) \
                        .fidelity("fast")
    res = mk().run()
    assert res.fraction_batched == 1.0
    _assert_parity(res, mk().options(force_fallback=True).run())


def test_cache_hits_bit_identical_on_mixed_grid(tmp_path):
    designs = _mixed_designs(3, n=6)
    cache = str(tmp_path / "cells")
    mk = lambda: (Study("parity-cache").designs(designs)
                  .workloads({"w": OPS[:2]}).fidelity("fast").cache(cache))
    first = mk().run()
    second = mk().run()
    assert second.cache_hits == len(first) and second.executed_cells == 0
    assert first.equals(second)            # bit-identical, every column
    # the oracle never aliases batched cells in the cache
    oracle = mk().options(force_fallback=True).run()
    assert oracle.cache_hits == 0


def test_sweep_facade_mixed_grid_fraction_batched():
    grid = preset_grid(array=[16], sparsity=[None, "2:4"], cores=[1, 4])
    res = Simulator().sweep(grid, OPS[:2])
    assert res.batched and len(res) == 4
    oracle = Simulator().sweep(grid, OPS[:2], force_fallback=True)
    assert not oracle.batched
    rel = np.abs(res.total_cycles - oracle.total_cycles) \
        / np.maximum(oracle.total_cycles, 1.0)
    assert rel.max() <= 1e-3


def test_invalid_per_op_override_raises_in_both_paths():
    """An Op.sparsity_nm override that cannot form a valid SparsityConfig
    with a design's row_wise flag must raise in the batched path exactly
    like the per-op oracle (no silent wrong answers)."""
    cfg = get_preset("tpu-like", array=16).with_(
        sparsity=as_sparsity("2:8-rw"))
    ops = [Op("g", 128, 128, 256, sparsity_nm=(3, 4))]   # 3 > 4//2
    mk = lambda **kw: (Study().designs({"d": cfg}).workloads({"w": ops})
                       .fidelity("fast").options(**kw))
    with pytest.raises(ValueError):
        mk().run()
    with pytest.raises(ValueError):
        mk(force_fallback=True).run()


def test_sparse_speedup_study_claims():
    from repro.api import studies
    res = studies.sparse_speedup(smoke=True).run()
    assert res.claims_ok(), res.check_claims()
    assert res.fraction_batched == 1.0
