"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.zoo import ModelBundle

ARCHS = list_archs()


def _batch(cfg, B=2, L=32):
    b = {"tokens": jnp.ones((B, L), jnp.int32),
         "labels": jnp.ones((B, L), jnp.int32),
         "loss_mask": jnp.ones((B, L), jnp.float32)}
    if cfg.family == "audio":
        b["frames"] = jnp.zeros((B, L, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        b["patches"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                 jnp.bfloat16)
    return b


@pytest.fixture(scope="module")
def bundles():
    return {a: ModelBundle(get_config(a, smoke=True)) for a in ARCHS}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(bundles, arch):
    b = bundles[arch]
    params = b.init(jax.random.PRNGKey(0))
    loss = jax.jit(b.loss_fn(None))(params, _batch(b.cfg))
    assert np.isfinite(float(loss))
    # untrained loss should be near ln(vocab)
    assert abs(float(loss) - np.log(b.cfg.vocab)) < 1.5


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_changes_params(bundles, arch):
    from repro.optim import adamw_init
    b = bundles[arch]
    params = b.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    p2, o2, m = jax.jit(b.train_step(None, lr=1e-2))(params, opt,
                                                     _batch(b.cfg))
    assert np.isfinite(float(m["loss"]))
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(p2)[0]
    assert not np.array_equal(np.asarray(before, np.float32),
                              np.asarray(after, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(bundles, arch):
    b = bundles[arch]
    cfg = b.cfg
    params = b.init(jax.random.PRNGKey(0))
    B, L = 2, 32
    pf = {k: v for k, v in _batch(cfg, B, L).items()
          if k in ("tokens", "frames", "patches")}
    logits, cache = jax.jit(b.prefill_step(None))(params, pf)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    cache0 = b.init_cache(batch=B, cache_len=L)
    lg, c1 = jax.jit(b.decode_step(None))(params, cache0,
                                          jnp.ones((B, 1), jnp.int32),
                                          jnp.int32(0))
    assert lg.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    # cache got written somewhere
    changed = any(not np.array_equal(np.asarray(a, np.float32),
                                     np.asarray(z, np.float32))
                  for a, z in zip(jax.tree.leaves(c1),
                                  jax.tree.leaves(cache0)))
    assert changed


def test_exact_configs_match_assignment():
    """The full (non-smoke) configs carry the assigned numbers."""
    spec = {
        "whisper-base": dict(d_model=512, heads=8, kv_heads=8, d_ff=2048,
                             vocab=51865),
        "mixtral-8x7b": dict(layers=32, d_model=4096, heads=32, kv_heads=8,
                             d_ff=14336, vocab=32000, num_experts=8, top_k=2),
        "granite-moe-3b-a800m": dict(layers=32, d_model=1536, heads=24,
                                     kv_heads=8, d_ff=512, vocab=49155,
                                     num_experts=40, top_k=8),
        "yi-34b": dict(layers=60, d_model=7168, heads=56, kv_heads=8,
                       d_ff=20480, vocab=64000),
        "qwen2-72b": dict(layers=80, d_model=8192, heads=64, kv_heads=8,
                          d_ff=29568, vocab=152064, qkv_bias=True),
        "qwen2-1.5b": dict(layers=28, d_model=1536, heads=12, kv_heads=2,
                           d_ff=8960, vocab=151936, qkv_bias=True),
        "glm4-9b": dict(layers=40, d_model=4096, heads=32, kv_heads=2,
                        d_ff=13696, vocab=151552),
        "zamba2-7b": dict(layers=81, d_model=3584, heads=32, kv_heads=32,
                          d_ff=14336, vocab=32000, ssm_state=64),
        "xlstm-1.3b": dict(layers=48, d_model=2048, heads=4, kv_heads=4,
                           d_ff=0, vocab=50304),
        "internvl2-1b": dict(layers=24, d_model=896, heads=14, kv_heads=2,
                             d_ff=4864, vocab=151655),
    }
    for arch, want in spec.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_expected_range():
    """Full configs: analytic parameter counts are the advertised sizes."""
    expect = {"qwen2-72b": (65e9, 85e9), "yi-34b": (30e9, 38e9),
              "mixtral-8x7b": (42e9, 50e9), "glm4-9b": (8e9, 12e9),
              "qwen2-1.5b": (1.2e9, 2.1e9), "xlstm-1.3b": (1.0e9, 1.8e9),
              "zamba2-7b": (5.5e9, 9e9), "internvl2-1b": (0.4e9, 1.2e9),
              "granite-moe-3b-a800m": (2.5e9, 4.2e9),
              "whisper-base": (0.05e9, 0.12e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
    # MoE active counts
    g = get_config("granite-moe-3b-a800m")
    assert g.active_param_count() < 0.5 * g.param_count()
