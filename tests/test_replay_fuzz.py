"""ISSUE 10 differential fuzz suite: the fused replay megakernel.

Every engine form of the chunked replay — the XLA driver ("xla"), the
megakernel's off-TPU twin (engine="pallas" resolving to "pallas:twin")
and the literal Pallas kernel in interpret mode — is fuzzed against the
per-request reference scan: row hit/miss/conflict counts must be
bit-exact (classification is order-only and shared), completion times
within 1e-3 relative (the closures re-associate f32 accumulation).

Streams are randomized plus the known-adversarial shapes: same-bank
conflict chains, queue-saturating bursts (in-flight ring wrap), and
chunk-boundary cases (n not a multiple of the chunk, single-chunk,
chunk > n).  Ranks: 1-D, batched leading dims, and vmap.

Also pinned here: the engine-resolution contract — "pallas" must
dispatch to the megakernel or its documented twin and be *recorded* as
such, never silently alias an "xla" `_SWEEP_FN_CACHE` entry — and the
unified fixed-point contract (`max_passes`/`tol` mean the same thing
under every engine; `simulate_shared_dram`'s private-channel
decomposition invariant holds at `max_passes=64` on all of them).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Simulator, preset_grid
from repro.core import replay
from repro.core.accelerator import DramConfig
from repro.core.dram import decode_requests
from repro.core.replay import replay_decoded, resolve_engine_runtime
from repro.core.workloads import Op
from repro.kernels.replay import replay_megakernel
from repro.trace.contention import simulate_shared_dram

RTOL = 1e-3


def _decode(addr, cfg):
    return decode_requests(jnp.asarray(addr), cfg)


def fuzz_stream(seed, n, *, span=1 << 22, p_write=0.3, p_valid=0.9,
                burst=None):
    """Random mixed read/write stream; `burst` pins all requests into a
    `burst`-bank address window (queue/bank pressure)."""
    rng = np.random.default_rng(seed)
    t = np.sort(rng.uniform(0.0, 3.0 * n, n)).astype(np.float32)
    if burst is not None:
        addr = (rng.integers(0, burst, n) * 64).astype(np.int64)
    else:
        addr = ((rng.integers(0, span, n) // 64) * 64).astype(np.int64)
    w = rng.random(n) < p_write
    v = rng.random(n) < p_valid
    return jnp.asarray(t), jnp.asarray(addr), jnp.asarray(w), jnp.asarray(v)


def run_reference(t, addr, w, v, cfg):
    fb, ch, row = _decode(addr, cfg)
    return replay_decoded(t, fb, ch, row, w, v, cfg, engine="reference")


def run_engine(t, addr, w, v, cfg, engine, *, interpret=False, chunk=None,
               tol=0.0):
    fb, ch, row = _decode(addr, cfg)
    if interpret:
        # the literal Pallas kernel body, interpreted on CPU
        return replay_megakernel(t, fb, ch, row, w.astype(jnp.int32),
                                 v.astype(jnp.int32), cfg, chunk=chunk,
                                 tol=tol, interpret=True)
    return replay_decoded(t, fb, ch, row, w, v, cfg, engine=engine,
                          chunk=chunk, tol=tol)


def assert_replay_matches(ref, out, v):
    for k in ("hits", "misses", "conflicts"):
        assert int(out[k]) == int(ref[k]), k          # bit-exact counts
    vm = np.asarray(v, bool)
    a, b = np.asarray(ref["done"]), np.asarray(out["done"])
    np.testing.assert_allclose(np.where(vm, b, 0.0), np.where(vm, a, 0.0),
                               rtol=RTOL, atol=5e-2)


ALL_FORMS = [("xla", False), ("pallas", False), ("pallas", True)]


@pytest.mark.parametrize("engine,interpret", ALL_FORMS)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fuzz_random_streams(engine, interpret, seed):
    n = 160 if interpret else 512
    t, a, w, v = fuzz_stream(seed, n)
    cfg = DramConfig()
    ref = run_reference(t, a, w, v, cfg)
    out = run_engine(t, a, w, v, cfg, engine, interpret=interpret)
    assert_replay_matches(ref, out, v)


@pytest.mark.parametrize("engine,interpret", ALL_FORMS)
def test_fuzz_same_bank_chain(engine, interpret):
    """Alternating rows in one bank: an unbroken conflict chain."""
    n = 128 if interpret else 384
    t = jnp.arange(n, dtype=jnp.float32) * 0.5
    a = (jnp.arange(n) % 2) * (1 << 21)
    w = jnp.zeros((n,), bool)
    v = jnp.ones((n,), bool)
    cfg = DramConfig(channels=1, banks_per_channel=1)
    ref = run_reference(t, a, w, v, cfg)
    assert int(ref["conflicts"]) > n // 2
    out = run_engine(t, a, w, v, cfg, engine, interpret=interpret)
    assert_replay_matches(ref, out, v)


@pytest.mark.parametrize("engine,interpret", ALL_FORMS)
def test_fuzz_queue_saturation(engine, interpret):
    """Tiny in-flight rings + a same-window burst: every request beyond
    the queue depth must wait on a ring head, and the backpressure shift
    accumulates — the worst case for the intra-chunk head search."""
    n = 160 if interpret else 512
    t, a, w, v = fuzz_stream(7, n, burst=4, p_valid=1.0)
    t = t * 0.01                       # arrivals far faster than service
    cfg = DramConfig(read_queue=4, write_queue=2)
    ref = run_reference(t, a, w, v, cfg)
    out = run_engine(t, a, w, v, cfg, engine, interpret=interpret)
    assert float(ref["shift"][0]) > 0.0      # queues actually pushed back
    assert_replay_matches(ref, out, v)


@pytest.mark.parametrize("engine,interpret", ALL_FORMS)
@pytest.mark.parametrize("n,chunk", [(96, 32), (97, 32), (31, 32),
                                     (64, 64), (65, 64)])
def test_fuzz_chunk_boundaries(engine, interpret, n, chunk):
    """Streams that end mid-chunk, fit one chunk, or underfill it."""
    t, a, w, v = fuzz_stream(n * 1000 + chunk, n)
    cfg = DramConfig()
    ref = run_reference(t, a, w, v, cfg)
    out = run_engine(t, a, w, v, cfg, engine, interpret=interpret,
                     chunk=chunk)
    assert_replay_matches(ref, out, v)


@pytest.mark.parametrize("engine,interpret", ALL_FORMS)
def test_fuzz_batched_and_vmapped_ranks(engine, interpret):
    """(B, n) batched and vmapped runs must equal the per-stream runs."""
    n, B = (128 if interpret else 256), 3
    cfg = DramConfig()
    streams = [fuzz_stream(10 + i, n) for i in range(B)]
    t = jnp.stack([s[0] for s in streams])
    a = jnp.stack([s[1] for s in streams])
    w = jnp.stack([s[2] for s in streams])
    v = jnp.stack([s[3] for s in streams])
    fb, ch, row = _decode(a, cfg)

    if interpret:
        run = lambda *xs: replay_megakernel(
            xs[0], xs[1], xs[2], xs[3], xs[4].astype(jnp.int32),
            xs[5].astype(jnp.int32), cfg, tol=0.0, interpret=True)
    else:
        run = lambda *xs: replay_decoded(*xs, cfg, engine=engine, tol=0.0)

    batched = run(t, fb, ch, row, w, v)
    for i in range(B):
        ref = run_reference(*streams[i], cfg)
        assert_replay_matches(
            ref, {k: batched[k][i] for k in batched}, v[i])
    if not interpret:     # interpret-mode pallas_call doesn't vmap on CPU
        vm = jax.vmap(lambda *xs: run(*xs)["done"])(t, fb, ch, row, w, v)
        np.testing.assert_allclose(np.asarray(vm),
                                   np.asarray(batched["done"]),
                                   rtol=RTOL, atol=5e-2)


# ---- engine resolution / cache identity -----------------------------------

def test_resolve_engine_runtime_labels():
    on_tpu = jax.default_backend() == "tpu"
    got = resolve_engine_runtime("pallas")
    assert got == ("pallas" if on_tpu else "pallas:twin")
    assert resolve_engine_runtime("pallas", interpret=True) == \
        ("pallas" if on_tpu else "pallas:interpret")
    assert resolve_engine_runtime("xla") == "xla"
    assert resolve_engine_runtime(None) == replay.DEFAULT_ENGINE


def test_pallas_sweep_never_aliases_xla_cache():
    """A 'pallas' batched sweep must get its own compiled kernel entry
    and surface the resolved engine — never silently run as 'xla'."""
    from repro.api.simulator import _SWEEP_FN_CACHE
    grid = preset_grid(array=[8, 16], sram_mb=[0.5], dataflow=["ws"])
    op = [Op("g", 128, 256, 128)]
    rx = Simulator("paper-32", fidelity="trace", engine="xla").sweep(
        grid, op)
    before = {k for k in _SWEEP_FN_CACHE if k[5] == "xla"}
    rp = Simulator("paper-32", fidelity="trace", engine="pallas").sweep(
        grid, op)
    assert rx.batched and rp.batched
    assert rx.engine == "xla"
    assert rp.engine == resolve_engine_runtime("pallas")
    assert rp.engine != "xla"
    # the pallas sweep created its own cache entries; the xla ones are
    # untouched (no aliasing in either direction)
    assert {k for k in _SWEEP_FN_CACHE if k[5] == "xla"} == before
    assert any(k[5] == rp.engine for k in _SWEEP_FN_CACHE)
    # same math off-TPU (the twin IS the driver) / same model on TPU
    np.testing.assert_allclose(rp.stall_cycles, rx.stall_cycles,
                               rtol=RTOL)


def test_network_report_records_resolved_engine():
    op = [Op("g", 128, 256, 128)]
    rep = Simulator("paper-32", fidelity="trace", engine="pallas").run(op)
    assert rep.engine == resolve_engine_runtime("pallas")
    fast = Simulator("paper-32").run(op)
    assert fast.engine == ""       # the fast model replays nothing


# ---- unified fixed-point contract -----------------------------------------

@pytest.mark.parametrize("engine", ["xla", "pallas", "reference"])
def test_shared_dram_private_channel_invariant_all_engines(engine):
    """Disjoint channel pinning decomposes exactly into isolated runs —
    under every engine, with the analysis-path contract (max_passes=64,
    tol=0.0) that `multicore_contention` relies on."""
    cfg = DramConfig(channels=2, banks_per_channel=4)
    n = 256
    rng = np.random.default_rng(3)
    kw = dict(max_passes=64, tol=0.0) if engine != "reference" else {}

    def one_core(core, channel):
        t = np.sort(rng.uniform(0, 200.0, n)).astype(np.float32)
        b = rng.integers(0, 1 << 14, n)
        addr = (b * cfg.channels + channel) * cfg.burst_bytes
        w = rng.random(n) < 0.3
        return (jnp.asarray(t), jnp.asarray(addr), jnp.asarray(w),
                jnp.full((n,), core, jnp.int32))

    cores = [one_core(0, 0), one_core(1, 1)]
    iso = [simulate_shared_dram(t, a, w, jnp.zeros((n,), jnp.int32),
                                jnp.ones((n,), bool), 1, cfg,
                                engine=engine, **kw)
           for t, a, w, _ in cores]

    t = jnp.concatenate([c[0] for c in cores])
    a = jnp.concatenate([c[1] for c in cores])
    w = jnp.concatenate([c[2] for c in cores])
    cid = jnp.concatenate([c[3] for c in cores])
    order = jnp.argsort(t)
    shared = simulate_shared_dram(t[order], a[order], w[order], cid[order],
                                  jnp.ones((2 * n,), bool), 2, cfg,
                                  engine=engine, **kw)
    for i in range(2):
        assert float(shared.per_core_stall[i]) == pytest.approx(
            float(iso[i].per_core_stall[0]), rel=1e-5, abs=1e-2)


@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_max_passes_cap_and_tol_semantics_match(engine):
    """max_passes=1 (single relaxation pass) must underestimate the
    resolved fixed point the same way on every chunked engine form, and
    tol=0.0 must reach the exact fixed point (more passes change
    nothing)."""
    t, a, w, v = fuzz_stream(5, 256, burst=2, p_valid=1.0)
    cfg = DramConfig(channels=1, banks_per_channel=1)
    fb, ch, row = _decode(a, cfg)
    one = replay_decoded(t, fb, ch, row, w, v, cfg, engine=engine,
                         max_passes=1, tol=0.0)
    full = replay_decoded(t, fb, ch, row, w, v, cfg, engine=engine,
                          tol=0.0)
    capped = replay_decoded(t, fb, ch, row, w, v, cfg, engine=engine,
                            max_passes=512, tol=0.0)
    assert float(jnp.max(full["done"])) >= float(jnp.max(one["done"]))
    np.testing.assert_allclose(np.asarray(capped["done"]),
                               np.asarray(full["done"]), rtol=1e-6)
