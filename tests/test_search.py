"""The search layer (repro.search): deterministic space sampling,
successive-halving promotion, frontier proposals, and the SearchDriver's
invariants — seeded replay, resume-from-cache accounting, budget
enforcement, and farm-vs-local bit-identity. The flagship search_edp
claims run in CI (`python -m repro.api --study search_edp --smoke`); here
we cover the machinery on a tiny fast space."""
import dataclasses
import math

import numpy as np
import pytest

from repro.api import StudyResult, get_preset, get_study
from repro.core.accelerator import CoreConfig
from repro.core.workloads import Op
from repro.search import (FarmExecutor, SearchDriver, SearchLog,
                          SearchSpace, choice, int_log_range, promote,
                          propose, rung_sizes)

OPS = [Op("g", 64, 64, 64)]


def _apply_sram(cfg, kb):
    sram = int(kb) * 1024 // 3
    return cfg.with_(memory=dataclasses.replace(
        cfg.memory, ifmap_sram_bytes=sram, filter_sram_bytes=sram,
        ofmap_sram_bytes=sram))


def tiny_space(name="tiny"):
    base = get_preset("edge-8")
    axes = [
        choice("array", (8, 16),
               lambda c, v: c.with_(cores=(CoreConfig(rows=v, cols=v),)),
               short="a"),
        int_log_range("sram_kb", 48, 384, 8, _apply_sram, short="s"),
        choice("dataflow", ("ws", "os"),
               lambda c, v: c.with_(dataflow=v), short=""),
    ]
    validity = [lambda v: not (v["array"] == 16 and v["sram_kb"] < 96)]
    return SearchSpace(name, base, axes, validity)


def mk_driver(space, cache, **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("metric", "edp")
    kw.setdefault("ladder", ("fast",))
    kw.setdefault("screen", 8)
    kw.setdefault("eta", 4.0)
    kw.setdefault("explore_rounds", 2)
    return SearchDriver(space, {"g64": OPS}, cache=cache, **kw)


# ---- space -----------------------------------------------------------------

def test_space_sampling_is_deterministic_and_valid():
    sp = tiny_space()
    a = sp.sample(6, seed=0)
    b = sp.sample(6, seed=0)
    assert [sp.label(p) for p in a] == [sp.label(p) for p in b]
    assert all(sp.is_valid(p) for p in a)
    assert len({sp.label(p) for p in a}) == 6
    # a different seed draws a different prefix
    c = sp.sample(6, seed=1)
    assert [sp.label(p) for p in a] != [sp.label(p) for p in c]
    # exclusion removes exactly the excluded labels from the stream
    d = sp.sample(6, seed=0, exclude=[sp.label(a[0])])
    assert sp.label(a[0]) not in {sp.label(p) for p in d}


def test_space_valid_size_neighbors_and_exhaustion():
    sp = tiny_space()
    brute = sum(1 for p in sp.points() if sp.is_valid(p))
    assert sp.valid_size() == brute < len(sp)
    # neighbors: ±1 per axis, in bounds
    p = sp.sample(1, seed=3)[0]
    for nb in sp.neighbors(p):
        assert sum(i != j for i, j in zip(p.idx, nb.idx)) == 1
        assert all(0 <= i < len(a.values)
                   for i, a in zip(nb.idx, sp.axes))
    # asking for more points than exist returns every valid point once
    everything = sp.sample(10 * len(sp), seed=0)
    assert len(everything) == sp.valid_size()


def test_config_compiles_axis_values():
    sp = tiny_space()
    p = sp.sample(1, seed=7)[0]
    vals = sp.values(p)
    cfg = sp.config(p)
    assert cfg.cores[0].rows == vals["array"]
    assert cfg.dataflow == vals["dataflow"]
    assert cfg.memory.ifmap_sram_bytes == vals["sram_kb"] * 1024 // 3


# ---- halving ---------------------------------------------------------------

@pytest.fixture()
def rung_frame():
    # a: fast+hungry, b: balanced (best edp), c: slow+frugal — all three
    # pareto-optimal; d dominated by b; e failed (NaN)
    cols = {
        "design": np.array(list("abcde"), dtype=object),
        "workload": np.array(["w"] * 5, dtype=object),
        "fidelity": np.array(["fast"] * 5, dtype=object),
        "total_cycles": np.array([1e6, 2e6, 8e6, 3e6, np.nan]),
        "energy_pj": np.array([9e9, 2e9, 1e9, 3e9, np.nan]),
        "edp": np.array([9e6, 4e6, 8e6, 9e6, np.nan]),
        "cell_status": np.array([0, 0, 0, 0, 1.0]),
    }
    axes = {"design": list("abcde"), "workload": ["w"],
            "fidelity": ["fast"]}
    return StudyResult(cols, axes)


def test_rung_sizes_are_ceil_halving():
    assert rung_sizes(64, 4, 3) == [64, 16, 4]
    assert rung_sizes(9, 3, 4) == [9, 3, 1, 1]
    assert rung_sizes(10, 4, 2) == [10, math.ceil(10 / 4)]
    with pytest.raises(ValueError):
        rung_sizes(0, 4, 2)
    with pytest.raises(ValueError):
        rung_sizes(8, 1, 2)


def test_promote_exact_counts_and_nan_safety(rung_frame):
    # scalar promotion: exactly k, ordered by metric, NaN never promotes
    assert promote(rung_frame, 2, metric="edp") == ["b", "c"]
    assert promote(rung_frame, 10, metric="edp") == ["b", "c", "a", "d"]
    # pareto-rank promotion keeps frontier endpoints alive before the
    # dominated row, even when their scalar metric is worse
    objs = ("total_cycles", "energy_pj")
    assert promote(rung_frame, 3, pareto=objs) == ["b", "c", "a"]
    assert promote(rung_frame, 4, pareto=objs) == ["b", "c", "a", "d"]
    assert promote(rung_frame, 0, pareto=objs) == []


def test_proposer_is_deterministic_and_tops_up():
    sp = tiny_space()
    parents = sp.sample(2, seed=0)
    labels = [sp.label(p) for p in parents]
    a = propose(sp, parents, 4, seed=0, round_idx=1, exclude=labels)
    b = propose(sp, parents, 4, seed=0, round_idx=1, exclude=labels)
    assert [sp.label(p) for p in a] == [sp.label(p) for p in b]
    assert len(a) == 4
    got = {sp.label(p) for p in a}
    assert not (got & set(labels))
    # asking for more than the neighborhoods hold fills from sampling
    big = propose(sp, parents, 20, seed=0, round_idx=1, exclude=labels)
    assert len(big) == 20
    assert len({sp.label(p) for p in big}) == 20


# ---- driver invariants -----------------------------------------------------

def test_same_seed_same_winner_log_and_frame(tmp_path):
    sp = tiny_space()
    r1 = mk_driver(sp, str(tmp_path / "c1")).run()
    r2 = mk_driver(sp, str(tmp_path / "c2")).run()
    assert r1.log.digest() == r2.log.digest()
    assert r1.frame.equals(r2.frame)
    assert r1.winner == r2.winner
    # the eval sequence (cohort order per round) is part of the log
    assert [e["cohort"] for e in r1.log.rounds] == \
        [e["cohort"] for e in r2.log.rounds]
    # a different seed screens a different cohort
    r3 = mk_driver(sp, str(tmp_path / "c3"), seed=1).run()
    assert r3.log.rounds[0]["cohort"] != r1.log.rounds[0]["cohort"]
    # log JSON round-trips with a stable digest
    assert SearchLog.from_json(r1.log.to_json()).digest() == \
        r1.log.digest()


def test_killed_search_resumes_executing_only_new_cells(tmp_path):
    sp = tiny_space()
    cache = str(tmp_path / "shared")
    # "killed" after the screen round: budget stops the search there
    part = mk_driver(sp, cache, budget=8).run()
    assert part.spent_evals == 8
    assert part.executed_cells == 8 and part.cache_hits == 0
    # resumed full search: the screen's 8 cells come from the cache,
    # only genuinely new cells execute
    full = mk_driver(sp, cache).run()
    assert full.cache_hits == 8
    assert full.executed_cells == full.spent_evals - 8
    # and the resumed run is bit-identical to a cold full run
    cold = mk_driver(sp, str(tmp_path / "cold")).run()
    assert full.frame.equals(cold.frame)
    assert full.log.digest() == cold.log.digest()


def test_budget_is_a_hard_cap(tmp_path):
    sp = tiny_space()
    res = mk_driver(sp, str(tmp_path / "c"), budget=5).run()
    assert res.spent_evals == 5
    assert len(res.frame) == 5
    assert res.log.rounds[-1]["spent_evals"] == 5


def test_driver_promotes_ceil_n_over_eta_and_rung_sizes(tmp_path):
    sp = tiny_space()
    res = mk_driver(sp, str(tmp_path / "c"), screen=8, eta=4.0,
                    explore_rounds=1, ladder=("fast", "trace"),
                    rung_sizes=(3,)).run()
    kinds = [(e["kind"], e["fidelity"], len(e["cohort"]),
              len(e["parents"])) for e in res.log.rounds]
    # screen 8 -> propose from ceil(8/4)=2 parents -> trace rung of 3
    assert kinds[0] == ("screen", "fast", 8, 0)
    assert kinds[1] == ("propose", "fast", 2, 2)
    assert kinds[2] == ("rung", "trace", 3, 3)
    # the trace rung re-evaluates designs already measured at fast
    trace = res.frame.filter(fidelity="trace")
    fast_designs = set(res.frame.filter(fidelity="fast")["design"])
    assert set(trace["design"]) <= fast_designs
    assert res.winner["fidelity"] == "trace"


def test_cycle_rung_runs_per_op(tmp_path):
    sp = tiny_space("tiny-cycle")
    res = mk_driver(sp, str(tmp_path / "c"), screen=4, explore_rounds=0,
                    ladder=("fast", "cycle"), rung_sizes=(1,)).run()
    cyc = res.frame.filter(fidelity="cycle")
    assert len(cyc) == 1
    assert (cyc["batched"] == 0.0).all()          # per-op engine path
    assert np.isfinite(cyc["total_cycles"]).all()


def test_farm_executed_search_matches_local_bitwise(tmp_path):
    from repro.farm import Broker, FarmClient, Worker
    sp = tiny_space()
    local = mk_driver(sp, str(tmp_path / "local"),
                      explore_rounds=1).run()

    root = str(tmp_path / "farm")
    broker = Broker(root, max_shard_cells=4)
    workers = [Worker(root, f"w{i}") for i in range(2)]

    def pump():
        for w in workers:
            w.step()
        broker.step()

    ex = FarmExecutor(root, pump=pump)
    farm = SearchDriver(sp, {"g64": OPS}, seed=0, metric="edp",
                        ladder=("fast",), screen=8, eta=4.0,
                        explore_rounds=1, cache=ex.cache_dir,
                        executor=ex).run()
    assert farm.log.digest() == local.log.digest()
    assert list(farm.frame.columns) == list(local.frame.columns)
    for k in farm.frame.columns:
        assert np.array_equal(farm.frame[k], local.frame[k]), k
    # the farm's shared dedup cache was warmed by the rounds
    assert farm.executed_cells == local.executed_cells


def test_checkpoint_records_progress(tmp_path):
    import json
    sp = tiny_space()
    ckpt = tmp_path / "ckpt.json"
    res = mk_driver(sp, str(tmp_path / "c"), explore_rounds=1,
                    checkpoint=str(ckpt)).run()
    d = json.loads(ckpt.read_text())
    assert d["rounds_done"] == len(res.log.rounds)
    assert d["spent_evals"] == res.spent_evals
    assert d["log_digest"] == res.log.digest()


# ---- the registry study ----------------------------------------------------

def test_search_edp_is_registered_with_claims():
    s = get_study("search_edp", smoke=True)
    names = [n for n, _ in s._claims]
    assert "edp_winner_is_64x64" in names
    assert "seeded_replay_bit_identical" in names
    # a search study has no static plan to shard
    with pytest.raises(ValueError):
        s.plan()


def test_table_v_space_contains_the_corner_and_exceeds_1e5():
    from repro.search import table_v_space
    sp = table_v_space()
    assert sp.valid_size() >= 100_000
    labels = {a.name for a in sp.axes}
    assert {"array", "sram_kb", "dataflow", "channels", "bw",
            "layout_banks"} == labels
    arrays = dict(zip([a.name for a in sp.axes],
                      [a.values for a in sp.axes]))["array"]
    assert arrays == (32, 64, 128)
