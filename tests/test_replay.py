"""ISSUE 3 differential suite: chunked bank-parallel replay vs reference.

The chunked engines ("xla", "pallas") must reproduce the retained
per-request reference scan: row hit/empty/conflict counts exactly
(classification is order-only and shared), completion/stall/total times
to a tight relative tolerance (the closed-form closures re-associate the
f32 `busy` accumulation), and bit-exactly when the timing constants are
exactly representable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Simulator, preset_grid
from repro.core import replay
from repro.core.accelerator import DramConfig
from repro.core.dram import (decode_requests, linear_trace, replay_requests,
                             simulate_dram, strided_trace,
                             tile_prefetch_trace)
from repro.core.workloads import Op
from repro.trace.contention import simulate_shared_dram

ENGINES = ("xla", "pallas")
RTOL = 1e-3            # acceptance tolerance on stall/total cycles


def assert_matches(ref, new, rtol=RTOL):
    # classification is exact by construction
    for k in ("row_hits", "row_misses", "row_conflicts"):
        assert int(getattr(new, k)) == int(getattr(ref, k)), k
    assert float(new.bytes_moved) == float(ref.bytes_moved)
    np.testing.assert_allclose(float(new.stall_cycles),
                               float(ref.stall_cycles), rtol=rtol, atol=5e-2)
    np.testing.assert_allclose(float(new.total_cycles),
                               float(ref.total_cycles), rtol=rtol, atol=5e-2)
    np.testing.assert_allclose(np.asarray(new.complete),
                               np.asarray(ref.complete), rtol=rtol, atol=5e-2)
    np.testing.assert_allclose(np.asarray(new.latency),
                               np.asarray(ref.latency), rtol=rtol, atol=5e-2)


def random_stream(seed, n=768, span=1 << 22, p_write=0.3, p_valid=0.9):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    t = jnp.sort(jax.random.uniform(ks[0], (n,)) * 4.0 * n)
    addr = (jax.random.randint(ks[1], (n,), 0, span) // 64) * 64
    w = jax.random.bernoulli(ks[2], p_write, (n,))
    valid = jax.random.bernoulli(ks[3], p_valid, (n,))
    return t, addr, w, valid


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_streams_match_reference(engine, seed):
    """Randomized mixed read/write streams with valid masks."""
    t, a, w, valid = random_stream(seed)
    cfg = DramConfig()
    ref = simulate_dram(t, a, w, cfg, valid=valid, engine="reference")
    new = simulate_dram(t, a, w, cfg, valid=valid, engine=engine)
    assert_matches(ref, new)


@pytest.mark.parametrize("engine", ENGINES)
def test_adversarial_same_bank_bursts(engine):
    """Alternating rows in a single bank: an unbroken row-conflict chain
    (the worst case for naive chunk relaxation — the bank closure must
    resolve the whole chain)."""
    n = 512
    t = jnp.arange(n, dtype=jnp.float32) * 0.5
    a = (jnp.arange(n) % 2) * (1 << 21)       # two rows, same bank
    w = jnp.zeros((n,), bool)
    cfg = DramConfig(channels=1, banks_per_channel=1)
    ref = simulate_dram(t, a, w, cfg, engine="reference")
    new = simulate_dram(t, a, w, cfg, engine=engine)
    assert int(ref.row_conflicts) > n // 2    # the chain is real
    assert_matches(ref, new)


@pytest.mark.parametrize("engine", ENGINES)
def test_adversarial_alternating_banks(engine):
    """Two banks alternating within one channel: every same-bank link
    skips a request, so nothing is contiguous and the closures + pruned
    gather must still converge."""
    n = 512
    t = jnp.arange(n, dtype=jnp.float32) * 0.5
    a = (jnp.arange(n) % 2) * (1 << 17) + (jnp.arange(n) // 2 % 2) * (1 << 21)
    w = jnp.zeros((n,), bool)
    cfg = DramConfig(channels=1, banks_per_channel=4)
    assert_matches(simulate_dram(t, a, w, cfg, engine="reference"),
                   simulate_dram(t, a, w, cfg, engine=engine))


@pytest.mark.parametrize("engine", ENGINES)
def test_queue_saturating_bursts(engine):
    """Whole-tile prefetch bursts against tiny in-flight windows: the
    backpressure shift dominates and intra-chunk queue-head chains appear
    (queues shorter than the chunk)."""
    t, a, w = tile_prefetch_trace(tile_bytes=20 * 1024, n_tiles=48,
                                  compute_per_tile=400, gran_bytes=64)
    cfg = DramConfig(channels=2, read_queue=8, write_queue=4)
    ref = simulate_dram(t, a, w, cfg, engine="reference")
    new = simulate_dram(t, a, w, cfg, engine=engine)
    assert float(ref.stall_cycles) > 1e4      # saturated, not idle
    assert_matches(ref, new)


def test_bit_exact_when_busy_is_representable():
    """With bandwidth such that the bus occupancy is an exact f32 (and
    integer DRAM timings), the closed-form closures commit the same
    rounding as the serial scan: results are bit-identical."""
    t, a, w = tile_prefetch_trace(tile_bytes=20 * 1024, n_tiles=64,
                                  compute_per_tile=400, gran_bytes=64)
    cfg = DramConfig(channels=2, read_queue=8, write_queue=4,
                     bandwidth_bytes_per_cycle=16.0)   # busy = 4.0 exact
    ref = simulate_dram(t, a, w, cfg, engine="reference")
    new = simulate_dram(t, a, w, cfg, engine="xla")
    assert np.array_equal(np.asarray(ref.complete), np.asarray(new.complete))
    assert float(ref.stall_cycles) == float(new.stall_cycles)


def test_chunk_boundaries_are_invisible():
    """The same stream replayed with different chunk sizes agrees (the
    scan carry is exactly the reference state)."""
    t, a, w, valid = random_stream(7)
    cfg = DramConfig()
    ref = simulate_dram(t, a, w, cfg, valid=valid, engine="reference")
    for chunk in (32, 64, 128):
        assert_matches(ref, simulate_dram(t, a, w, cfg, valid=valid,
                                          engine="xla", chunk=chunk))


def test_streaming_and_strided_statistics():
    """The qualitative row-buffer contracts survive the new engine."""
    res = simulate_dram(*linear_trace(2048), DramConfig(channels=1),
                        engine="xla")
    assert int(res.row_hits) > 0.9 * 2048
    st = simulate_dram(*strided_trace(1024, stride_bytes=1 << 16),
                       DramConfig(channels=1, banks_per_channel=4),
                       engine="xla")
    assert int(st.row_conflicts) > int(res.row_conflicts)


def test_vmap_over_designs():
    """The replay stays vmappable over a leading design axis (and agrees
    with per-stream reference runs)."""
    t0, a0, w0, v0 = random_stream(3, n=512)
    t1, a1, w1, v1 = random_stream(4, n=512)
    cfg = DramConfig()
    f = jax.vmap(lambda t, a, w, v:
                 simulate_dram(t, a, w, cfg, valid=v,
                               engine="xla").stall_cycles)
    got = np.asarray(f(jnp.stack([t0, t1]), jnp.stack([a0, a1]),
                       jnp.stack([w0, w1]), jnp.stack([v0, v1])))
    for i, (t, a, w, v) in enumerate([(t0, a0, w0, v0), (t1, a1, w1, v1)]):
        ref = simulate_dram(t, a, w, cfg, valid=v, engine="reference")
        np.testing.assert_allclose(got[i], float(ref.stall_cycles),
                                   rtol=RTOL, atol=5e-2)


def test_batch_native_replay_requests():
    """`replay_requests` is batch-native: a (2, n) decoded batch replays
    in one scan and matches per-stream runs (the decode-hoisted entry
    `Simulator.sweep` uses)."""
    streams = [random_stream(s, n=512) for s in (5, 6)]
    cfg = DramConfig()
    fb, ch, row = [], [], []
    for t, a, w, v in streams:
        f, c, r = decode_requests(a, cfg)
        fb.append(f), ch.append(c), row.append(r)
    batched = replay_requests(
        jnp.stack([s[0] for s in streams]), jnp.stack(fb), jnp.stack(ch),
        jnp.stack(row), jnp.stack([s[2] for s in streams]),
        jnp.stack([s[3] for s in streams]), cfg, 64, engine="xla")
    assert batched.stall_cycles.shape == (2,)
    for i, (t, a, w, v) in enumerate(streams):
        ref = simulate_dram(t, a, w, cfg, valid=v, engine="reference")
        np.testing.assert_allclose(float(batched.stall_cycles[i]),
                                   float(ref.stall_cycles),
                                   rtol=RTOL, atol=5e-2)
        assert int(batched.row_hits[i]) + int(batched.row_misses[i]) + \
            int(batched.row_conflicts[i]) == int(ref.row_hits) + \
            int(ref.row_misses) + int(ref.row_conflicts)


@pytest.mark.parametrize("engine", ENGINES)
def test_shared_dram_matches_reference(engine):
    """Merged multi-core stream: per-channel queues + per-core shift."""
    n = 600
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    t = jnp.sort(jax.random.uniform(ks[0], (n,)) * 1000)
    a = (jax.random.randint(ks[1], (n,), 0, 1 << 20) // 64) * 64
    w = jax.random.bernoulli(ks[2], 0.3, (n,))
    cid = jax.random.randint(ks[3], (n,), 0, 4)
    valid = jax.random.bernoulli(ks[4], 0.9, (n,))
    cfg = DramConfig(channels=2, read_queue=8, write_queue=4)
    ref = simulate_shared_dram(t, a, w, cid, valid, 4, cfg,
                               engine="reference")
    new = simulate_shared_dram(t, a, w, cid, valid, 4, cfg, engine=engine)
    assert int(new.row_hits) == int(ref.row_hits)
    assert int(new.row_misses) == int(ref.row_misses)
    assert int(new.row_conflicts) == int(ref.row_conflicts)
    np.testing.assert_allclose(np.asarray(new.per_core_stall),
                               np.asarray(ref.per_core_stall),
                               rtol=RTOL, atol=5e-2)
    np.testing.assert_allclose(float(new.total_cycles),
                               float(ref.total_cycles), rtol=RTOL)


def test_shared_dram_private_channel_decomposition():
    """With each core pinned to its own channel the merged replay must
    decompose into the isolated per-core runs on the new engine (the
    contention invariant, exercised directly on `simulate_shared_dram`)."""
    n = 256
    cfg = DramConfig(channels=2)
    t0 = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (n,)) * 800)
    t1 = jnp.sort(jax.random.uniform(jax.random.PRNGKey(1), (n,)) * 800)
    # channel pinning: burst index b -> b * channels + core
    b0 = jnp.arange(n) * 3 % 512
    b1 = jnp.arange(n) * 7 % 512
    a0 = (b0 * 2 + 0) * cfg.burst_bytes
    a1 = (b1 * 2 + 1) * cfg.burst_bytes
    w = jnp.zeros((n,), bool)
    ones = jnp.ones((n,), bool)

    def run(t, a, cid, nc):
        order = jnp.argsort(t)
        return simulate_shared_dram(
            t[order], a[order], w, cid[order], ones, nc, cfg,
            engine="xla", tol=0.0)

    iso0 = run(t0, a0, jnp.zeros((n,), jnp.int32), 1)
    iso1 = run(t1, a1, jnp.zeros((n,), jnp.int32), 1)
    tm = jnp.concatenate([t0, t1])
    am = jnp.concatenate([a0, a1])
    cm = jnp.concatenate([jnp.zeros((n,), jnp.int32),
                          jnp.ones((n,), jnp.int32)])
    order = jnp.argsort(tm)
    merged = simulate_shared_dram(
        tm[order], am[order], jnp.zeros((2 * n,), bool), cm[order],
        jnp.ones((2 * n,), bool), 2, cfg, engine="xla", tol=0.0)
    np.testing.assert_allclose(
        float(merged.per_core_stall[0]), float(iso0.per_core_stall[0]),
        rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(
        float(merged.per_core_stall[1]), float(iso1.per_core_stall[0]),
        rtol=1e-5, atol=1e-2)


# ---- engine plumb-through ---------------------------------------------------

def test_default_engine_is_chunked():
    """ISSUE 3 satellite: the chunked engine is the default."""
    assert replay.DEFAULT_ENGINE == "xla"
    assert replay.resolve_engine(None) == "xla"


def test_engine_validation():
    with pytest.raises(ValueError):
        replay.resolve_engine("turbo")
    with pytest.raises(ValueError):
        Simulator("paper-32", fidelity="trace", engine="turbo")


def test_simulator_engine_plumbs_to_stages():
    sim = Simulator("paper-32", fidelity="trace", engine="reference")
    assert sim.engine == "reference"
    assert any(getattr(s, "engine", None) == "reference"
               for s in sim.pipeline)
    assert sim.with_(dataflow="os").engine == "reference"
    assert Simulator.from_preset("paper-32", fidelity="trace").engine == "xla"


def test_trace_sweep_engines_agree():
    """The batched (decode-hoisted, stream-deduped) sweep on the chunked
    engine matches the reference engine's sweep."""
    grid = preset_grid(array=[8, 16], sram_mb=[0.5], dataflow=["ws"]) * 2
    ops = [Op("g", 96, 192, 128), Op("g", 64, 64, 256)]
    fast = Simulator("paper-32", fidelity="trace").sweep(grid, ops)
    ref = Simulator("paper-32", fidelity="trace",
                    engine="reference").sweep(grid, ops)
    assert fast.batched and ref.batched
    np.testing.assert_allclose(fast.stall_cycles, ref.stall_cycles,
                               rtol=RTOL, atol=1.0)
    np.testing.assert_allclose(fast.total_cycles, ref.total_cycles,
                               rtol=RTOL)


# ---- int32 address-space guard (ISSUE 3 satellite) --------------------------

def test_decode_guard_rejects_oversized_addresses():
    cfg = DramConfig()
    with pytest.raises(ValueError, match="int32"):
        decode_requests(jnp.asarray([0.0, 2.0 ** 31]), cfg)


def test_decode_guard_rejects_wrapped_addresses():
    """Negative addresses are the tell-tale of silent int32 overflow."""
    cfg = DramConfig()
    with pytest.raises(ValueError, match="wrapped"):
        simulate_dram(jnp.zeros((2,)), jnp.asarray([-64, 0], jnp.int32),
                      jnp.zeros((2,), bool), cfg)
