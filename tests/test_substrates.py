"""data pipeline, optimizer, checkpoint, dist utilities."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.dist.elastic import plan_elastic_remesh
from repro.dist.straggler import StragglerDetector
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)
from repro.optim.compress import compress_decompress, int8_compress


# ---- data -----------------------------------------------------------------

def test_data_deterministic_and_resumable():
    ds = SyntheticLMDataset(DataConfig(vocab=1000, seq_len=64,
                                       global_batch=8))
    a = ds.global_batch_at(7)
    b = ds.global_batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.global_batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_sharding_partitions_batch():
    ds = SyntheticLMDataset(DataConfig(vocab=1000, seq_len=16,
                                       global_batch=8))
    shards = [ds.batch_at(3, s, 4) for s in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # shards differ from one another
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(DataConfig(vocab=1000, seq_len=32,
                                       global_batch=2))
    b = ds.global_batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---- optimizer --------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(grads, opt, params, lr=0.05,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)
    assert float(gn) == pytest.approx(100.0 * np.sqrt(10), rel=1e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(jnp.int32(100))) < 1e-5


def test_int8_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,))}
    deq, resid = compress_decompress(g)
    err1 = float(jnp.abs(deq["w"] - g["w"]).max())
    assert err1 < 0.05                       # 8-bit quantization error
    # error feedback: residual carries the lost mass
    deq2, _ = compress_decompress(g, resid)
    two_step = (np.asarray(deq["w"]) + np.asarray(deq2["w"])) / 2
    assert np.abs(two_step - np.asarray(g["w"])).max() < err1 + 1e-6


# ---- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(8, dtype=jnp.float32), "b": {"c": jnp.ones((2, 2))}}
    for step in (1, 2, 3):
        mgr.save(step, tree, blocking=True)
    assert mgr.all_steps() == [2, 3]          # retention GC
    out = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(8))


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.zeros((128, 128))}
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(4)}, blocking=True)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---- dist utilities ---------------------------------------------------------

def test_elastic_plan_keeps_global_batch():
    p512 = plan_elastic_remesh(512, global_batch=256, tp=16, prefer_pod=2)
    p256 = plan_elastic_remesh(256, global_batch=256, tp=16)
    p128 = plan_elastic_remesh(128, global_batch=256, tp=16)
    for p, ndev in ((p512, 512), (p256, 256), (p128, 128)):
        dp = ndev // 16
        assert p.per_device_batch * dp * p.grad_accum >= 256
    assert p512.mesh_shape == (2, 16, 16)
    assert p128.grad_accum >= p256.grad_accum


def test_elastic_degrades_tp_last():
    p = plan_elastic_remesh(8, global_batch=64, tp=16)
    assert p.mesh_shape[-1] <= 8              # TP shrank to fit


def test_straggler_detector():
    det = StragglerDetector(threshold=3.0, patience=2)
    for step in range(5):
        for h in range(8):
            det.record(h, 1.0 + (5.0 if h == 3 else 0.0))
        out = det.stragglers()
    assert out == [3]
