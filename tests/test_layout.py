import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import LayoutConfig
from repro.core.layout import (chw_ids, evaluate_layout, flat_ids,
                               slowdown_per_cycle, streaming_access_pattern)


def test_paper_equations_chw():
    cfg = LayoutConfig(enabled=True, c1_step=8, h1_step=2, w1_step=4,
                       num_banks=8, line_bytes=16)
    C, H, W = 16, 8, 8
    c = jnp.arange(C)[:, None, None] * jnp.ones((1, H, W), jnp.int32)
    h = jnp.arange(H)[None, :, None] * jnp.ones((C, 1, W), jnp.int32)
    w = jnp.arange(W)[None, None, :] * jnp.ones((C, H, 1), jnp.int32)
    line, col, bank = chw_ids(c, h, w, H, W, cfg)
    # line id formula at a known point
    c0, h0, w0 = 9, 3, 5
    expect_line = (c0 // 8) * (-(-H // 2)) * (-(-W // 4)) \
        + (h0 // 2) * (-(-W // 4)) + (w0 // 4)
    assert int(line[c0, h0, w0]) == expect_line
    expect_col = (w0 % 4) * 2 * 8 + (h0 % 2) * 8 + (c0 % 8)
    assert int(col[c0, h0, w0]) == expect_col


def test_slowdown_equation():
    # 4 accesses to the same bank, different lines, 1 port -> slowdown 4
    line = jnp.array([[0, 1, 2, 3]])
    bank = jnp.zeros((1, 4), jnp.int32)
    sd = slowdown_per_cycle(line, bank, num_banks=4, ports=1)
    assert int(sd[0]) == 4
    # same line 4x -> one distinct line -> slowdown 1
    sd2 = slowdown_per_cycle(jnp.zeros((1, 4), jnp.int32), bank, 4, 1)
    assert int(sd2[0]) == 1
    # 2 ports halve it
    sd3 = slowdown_per_cycle(line, bank, num_banks=4, ports=2)
    assert int(sd3[0]) == 2


def test_more_banks_fewer_conflicts_fig12():
    """Figs. 12-13: at fixed total bandwidth, more banks -> less slowdown."""
    means = []
    for banks in (2, 4, 8, 16):
        cfg = LayoutConfig(enabled=True, num_banks=banks,
                           line_bytes=512 // banks)
        r = evaluate_layout(cfg, R=32, n_cycles=128, lead_stride=1,
                            elem_stride=197)
        means.append(r.mean_slowdown)
    assert all(means[i] >= means[i + 1] for i in range(len(means) - 1))
    assert means[0] > 2 * means[-1]


def test_contiguous_access_no_slowdown():
    cfg = LayoutConfig(enabled=True, num_banks=32, line_bytes=64)
    # one element per cycle: can never conflict
    r = evaluate_layout(cfg, R=1, n_cycles=64, lead_stride=1, elem_stride=1)
    assert r.mean_slowdown == 1.0


def test_kernel_matches_oracle():
    from repro.kernels.conflict import (conflict_slowdown,
                                        conflict_slowdown_reference)
    key = jax.random.PRNGKey(3)
    line = jax.random.randint(key, (96, 48), 0, 13)
    bank = jax.random.randint(jax.random.fold_in(key, 1), (96, 48), 0, 16)
    k = conflict_slowdown(line, bank, num_banks=16, ports=2, interpret=True)
    r = conflict_slowdown_reference(line, bank, num_banks=16, ports=2)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
