"""Hypothesis property tests on system invariants."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import accelerator as acc
from repro.core import dataflow as dfm
from repro.core.partition import enumerate_plans, partition_cycles
from repro.core.sparsity import effective_K, storage_report
from repro.core.energy import action_counts, energy_pj
from repro.core.layout import slowdown_per_cycle

dims = st.integers(min_value=1, max_value=2048)
arr = st.sampled_from([8, 16, 32, 64, 128])
dfs = st.sampled_from(["ws", "is", "os"])


@settings(max_examples=60, deadline=None)
@given(dfs, dims, dims, dims, arr, arr)
def test_cycles_lower_bound(df, M, N, K, R, C):
    """Compute cycles always cover the pure streaming lower bound and the
    utilization never exceeds 1."""
    cyc = int(dfm.compute_cycles(df, M, N, K, R, C))
    Sr, Sc, T = dfm.map_gemm(df, M, N, K)
    assert cyc >= T
    assert M * N * K <= R * C * cyc


@settings(max_examples=60, deadline=None)
@given(dfs, dims, dims, dims, arr, arr)
def test_bigger_array_never_more_cycles(df, M, N, K, R, C):
    c1 = int(dfm.compute_cycles(df, M, N, K, R, C))
    c2 = int(dfm.compute_cycles(df, M, N, K, 2 * R, 2 * C))
    Sr, Sc, _ = dfm.map_gemm(df, M, N, K)
    f2 = int(dfm.cdiv(Sr, 2 * R) * dfm.cdiv(Sc, 2 * C))
    # provable: c2 = (2R'+C'+T-2)f2 <= c1 + (2R'+C'-(2R+C))f2 with f2<=f1
    assert c2 <= c1 + (2 * R + C) * f2


@settings(max_examples=40, deadline=None)
@given(dfs, dims, dims, dims, st.sampled_from([4, 16, 64]))
def test_partition_cycles_divide_work(df, M, N, K, cores):
    """Any partitioning plan on n cores is at least 1/n of single-core
    cycles (no super-linear speedup) and never slower than ~1 core."""
    Sr, Sc, T = dfm.map_gemm(df, M, N, K)
    single = partition_cycles("spatial", 32, 32, Sr, Sc, T, 1, 1)
    for p in enumerate_plans(df, M, N, K, 32, 32, cores):
        assert p.cycles >= single / cores * 0.9


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 64).filter(lambda m: m % 2 == 0),
       st.integers(64, 4096))
def test_sparsity_storage_monotone(m, K):
    K = (K // m) * m or m
    rows = 64
    prev = None
    for n in range(1, m // 2 + 1):
        sp = acc.SparsityConfig(enabled=True, n=n, m=m)
        tot = storage_report(rows, K, sp)["total_bytes"]
        if prev is not None:
            assert tot >= prev
        prev = tot
    dense = storage_report(rows, K, acc.SparsityConfig())["total_bytes"]
    assert prev <= dense * (0.5 + math.ceil(math.log2(m)) / 16 + 0.01)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(2, 32), st.integers(64, 2048))
def test_effective_k_bounds(n, m, K):
    if n > m:
        return
    sp = acc.SparsityConfig(enabled=True, n=min(n, m), m=m)
    ke = int(effective_K(K, sp))
    assert 0 < ke <= K + m


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64))
def test_layout_slowdown_at_least_one(num_banks, k):
    line = jnp.zeros((4, k), jnp.int32)
    bank = jnp.zeros((4, k), jnp.int32)
    sd = slowdown_per_cycle(line, bank, num_banks=num_banks, ports=1)
    assert int(sd.min()) >= 1


@settings(max_examples=30, deadline=None)
@given(st.floats(1e3, 1e9), st.floats(0, 1e12))
def test_energy_nonnegative_and_monotone_in_macs(cycles, macs):
    cfg = acc.tpu_like_config(array=32)
    c = action_counts(cfg, cycles=cycles, macs=macs, ifmap_reads=0.0,
                      filter_reads=0.0, ofmap_writes=0.0, ofmap_reads=0.0,
                      dram_bytes=0.0)
    e = energy_pj(c)
    assert e["total"] >= 0
    c2 = action_counts(cfg, cycles=cycles, macs=macs * 2, ifmap_reads=0.0,
                       filter_reads=0.0, ofmap_writes=0.0, ofmap_reads=0.0,
                       dram_bytes=0.0)
    assert energy_pj(c2)["total"] >= e["total"] * 0.99


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 512), st.integers(0, 3))
def test_dram_latency_at_least_cas(n_req, seed):
    from repro.core.dram import linear_trace, simulate_dram
    cfg = acc.DramConfig()
    t, a, w = linear_trace(n_req, start_addr=seed * 4096)
    res = simulate_dram(t, a, w, cfg)
    assert float(np.asarray(res.latency).min()) >= cfg.tCAS
