"""repro.trace: dataflow-aware demand-trace generation + shared-DRAM
contention. Covers the ISSUE-2 contracts: byte conservation against
`dram_traffic`, layout/stride sensitivity of row-buffer statistics,
OS-vs-WS write-stream shape, vmappability, and the valid-mask semantics
of `simulate_dram`."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dram_traffic, simulate_dram, tpu_like_config
from repro.core.accelerator import (AcceleratorConfig, CoreConfig,
                                    DramConfig, MemoryConfig)
from repro.core.dataflow import map_gemm, unmap_gemm
from repro.core.dram import linear_trace
from repro.core.multicore import simulate_multicore_contention
from repro.core.workloads import Op
from repro.trace import (TraceSpec, gemm_trace_stats, trace_op,
                         trace_op_stats)

SPEC = TraceSpec(cap=2048)


def _cfg(df="ws", sram_mb=0.5):
    return tpu_like_config(array=32, dataflow=df, sram_mb=sram_mb)


# ---- conservation ----------------------------------------------------------

@pytest.mark.parametrize("df", ["ws", "is", "os"])
def test_request_byte_conservation(df):
    """sum(valid) * gran * scale == dram_traffic byte total, exactly."""
    cfg = _cfg(df)
    op = Op("g", 384, 1500, 640)
    t, a, w, v, scale = trace_op(cfg, op, SPEC)
    dram = dram_traffic(df, op.M, op.N, op.K, 32, 32, cfg.memory)
    expect = float(sum(dram.values())) * cfg.memory.word_bytes
    got = float(jnp.sum(v)) * SPEC.gran_bytes * float(scale)
    assert got == pytest.approx(expect, rel=1e-5)


def test_stream_sorted_and_fixed_shape():
    t, a, w, v, scale = trace_op(_cfg(), Op("g", 256, 512, 256), SPEC)
    assert t.shape == a.shape == w.shape == v.shape == (SPEC.cap,)
    tv = np.asarray(t)[np.asarray(v)]
    assert (np.diff(tv) >= 0).all()
    assert a.dtype == jnp.int32 and (np.asarray(a) >= 0).all()


# ---- layout / stride sensitivity -------------------------------------------

def test_layouts_change_row_buffer_behavior():
    """Row/column-major and tiled layouts must produce genuinely
    different row-buffer statistics for the same dataflow walk."""
    cfg = _cfg("ws")
    op = Op("g", 384, 1500, 640)
    rates = {lay: float(trace_op_stats(cfg, op,
                                       TraceSpec(cap=2048, layout=lay)
                                       )["row_hit_rate"])
             for lay in ("row", "col", "tiled")}
    assert len({round(r, 4) for r in rates.values()}) == 3
    # ws streams X down columns: column-major storage is the friendly one
    assert rates["col"] > rates["row"]


def test_layout_sensitivity_survives_compression():
    """LM-scale ops compress the stream by ~1e6; the contiguous-run
    sampling must keep layout-driven row-buffer ordering (col-major stays
    row-local, row-major thrashes) instead of collapsing to f32 rounding
    artifacts of the huge stream positions."""
    cfg = _cfg("ws")
    op = Op("g", 4096, 32768, 8192)
    rates = {lay: float(trace_op_stats(cfg, op,
                                       TraceSpec(cap=2048, layout=lay)
                                       )["row_hit_rate"])
             for lay in ("row", "col")}
    assert rates["col"] > rates["row"] + 0.05


def test_row_hit_rate_monotone_in_stride():
    cfg = _cfg("ws")
    op = Op("g", 384, 1500, 640)
    rates = [float(trace_op_stats(
        cfg, op, TraceSpec(cap=2048, layout="strided", stride_elems=s)
        )["row_hit_rate"]) for s in (1, 4, 16, 64)]
    assert all(a >= b - 1e-9 for a, b in zip(rates, rates[1:]))
    assert rates[0] > rates[-1]                 # and strictly falls overall


# ---- dataflow-dependent stream shape ---------------------------------------

def test_write_stream_shape_os_vs_ws():
    """OS drains the stationary output in per-tile bursts; WS writes back
    psums interleaved with the stream — the write issue-time shapes must
    differ."""
    burst = {}
    for df in ("ws", "os"):
        cfg = _cfg(df, sram_mb=0.25)
        t, a, w, v, _ = trace_op(cfg, Op("g", 128, 512, 256), SPEC)
        wt = np.asarray(t)[np.asarray(w & v)]
        assert wt.size > 100                    # both have real write streams
        burst[df] = wt.size / np.unique(wt).size   # writes per issue slot
    assert burst["os"] > 2 * burst["ws"]        # OS drains in tile bursts


def test_dataflows_produce_different_address_streams():
    op = Op("g", 384, 1500, 640)
    addrs = {df: np.asarray(trace_op(_cfg(df), op, SPEC)[1])
             for df in ("ws", "os")}
    assert not np.array_equal(addrs["ws"], addrs["os"])


# ---- vmappability (the sweep-batching contract) ----------------------------

def test_generator_vmaps_over_gemm_dims():
    cfg = _cfg("ws")
    spec = TraceSpec(cap=512)
    mem = cfg.memory

    def stats(M, N, K):
        dr = dram_traffic("ws", M, N, K, 32, 32, mem)
        comp = (2 * 32 + 32 + N - 2) * 1.0       # ws: T = N (single fold ok)
        return gemm_trace_stats("ws", M, N, K, 32, 32, comp,
                                dr["dram_ifmap"], dr["dram_filter"],
                                dr["dram_ofmap_writes"],
                                dr["dram_ofmap_reads"], cfg.dram,
                                mem.word_bytes, spec)

    M = jnp.asarray([128.0, 256.0, 384.0])
    N = jnp.asarray([512.0, 1024.0, 197.0])
    K = jnp.asarray([256.0, 640.0, 768.0])
    out = jax.vmap(stats)(M, N, K)
    assert out["stall_cycles"].shape == (3,)
    assert bool(jnp.all(jnp.isfinite(out["stall_cycles"])))
    assert bool(jnp.all(out["stall_cycles"] >= 0))


# ---- simulate_dram valid mask ----------------------------------------------

def test_simulate_dram_valid_mask_matches_unpadded():
    t, a, w = linear_trace(512, issue_gap=0.5)
    cfg = DramConfig(channels=2)
    full = simulate_dram(t, a, w, cfg)
    pad = 256
    tp = jnp.concatenate([t, jnp.full((pad,), 1e12)])
    ap = jnp.concatenate([a, jnp.zeros((pad,), a.dtype)])
    wp = jnp.concatenate([w, jnp.zeros((pad,), bool)])
    vp = jnp.arange(512 + pad) < 512
    masked = simulate_dram(tp, ap, wp, cfg, valid=vp)
    assert float(masked.stall_cycles) == pytest.approx(
        float(full.stall_cycles), abs=1e-3)
    assert int(masked.row_hits) == int(full.row_hits)
    assert int(masked.row_conflicts) == int(full.row_conflicts)
    assert float(masked.bytes_moved) == pytest.approx(
        float(full.bytes_moved))


# ---- mapping inverses -------------------------------------------------------

@pytest.mark.parametrize("df", ["ws", "is", "os"])
def test_unmap_gemm_inverts_map_gemm(df):
    M, N, K = 384, 1500, 640
    assert unmap_gemm(df, *map_gemm(df, M, N, K)) == (M, N, K)


# ---- multi-core shared-DRAM contention -------------------------------------

_MEM = MemoryConfig(ifmap_sram_bytes=1 << 17, filter_sram_bytes=1 << 17,
                    ofmap_sram_bytes=1 << 17)


def _mesh_cfg(channels):
    return AcceleratorConfig(cores=(CoreConfig(rows=32, cols=32),),
                             mesh_rows=2, mesh_cols=1, memory=_MEM,
                             dram=DramConfig(channels=channels))


def test_contention_shared_channels_inflates_stalls():
    r = simulate_multicore_contention(_mesh_cfg(2), 512, 2048, 1024,
                                      spec=TraceSpec(cap=1024))
    for iso, shr in zip(r.per_core_stall_isolated, r.per_core_stall_shared):
        assert shr >= iso - 1e-6
    assert sum(r.per_core_stall_shared) > 1.05 * sum(
        r.per_core_stall_isolated)
    assert all(f >= 1.0 for f in r.stall_inflation)
    assert r.makespan_shared >= r.makespan_isolated


def test_contention_private_channels_equals_isolated():
    r = simulate_multicore_contention(_mesh_cfg(2), 512, 2048, 1024,
                                      private_channels=True,
                                      spec=TraceSpec(cap=1024))
    for iso, shr in zip(r.per_core_stall_isolated, r.per_core_stall_shared):
        assert shr == pytest.approx(iso, rel=1e-6)
    assert r.makespan_shared == pytest.approx(r.makespan_isolated, rel=1e-6)


def test_contention_nop_offsets_respected():
    cores = (CoreConfig(rows=32, cols=32, nop_hops=0),
             CoreConfig(rows=32, cols=32, nop_hops=4))
    cfg = AcceleratorConfig(cores=cores, mesh_rows=2, mesh_cols=1,
                            memory=_MEM, dram=DramConfig(channels=2))
    r = simulate_multicore_contention(cfg, 512, 2048, 1024,
                                      spec=TraceSpec(cap=1024))
    assert len(r.per_core_stall_shared) == 2
    assert r.row_hits + r.row_misses + r.row_conflicts > 0


def test_trace_spec_rejects_nonsense_fields():
    """TraceSpec is the static (hashable) half of the trace kernels —
    a zero cap or unknown layout must fail at construction, not as a
    shape error inside a jitted sweep."""
    with pytest.raises(ValueError, match="cap"):
        TraceSpec(cap=0)
    with pytest.raises(ValueError, match="gran_bytes"):
        TraceSpec(gran_bytes=0)
    with pytest.raises(ValueError, match="layout"):
        TraceSpec(layout="diagonal")
    with pytest.raises(ValueError, match="tile"):
        TraceSpec(tile_r=0)
    with pytest.raises(ValueError, match="tile"):
        TraceSpec(tile_c=-2)
    with pytest.raises(ValueError, match="stride_elems"):
        TraceSpec(stride_elems=0)
    TraceSpec()  # defaults stay valid
