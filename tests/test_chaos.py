"""End-to-end chaos: the farm under seeded fault schedules (ISSUE 8).

Synchronous deterministic drivers (no subprocesses, no sleeps beyond
lease aging): a `FaultPlan` is active while broker.step()/worker.step()
run by hand, `InjectedCrash` kills a worker mid-protocol and the driver
respawns a fresh one — the in-process equivalent of kill -9 + supervisor
restart. The acceptance bar everywhere is *bit-identity*: the frame
produced under faults equals the fault-free local run, column for
column.

Also pinned here: the broker's recovery machinery on its own — shard
quarantine past the attempts budget, corrupt-status rebuild from the
manifest, and torn-result patience -> re-enqueue.
"""
import json
import os

import numpy as np
import pytest

from repro.api import Study, preset_grid
from repro.core.workloads import Op
from repro.farm import Broker, FarmClient, Worker
from repro.farm.queue import SHARDS_TOPIC, FarmDirs, FileSpool
from repro.faults import (CHAOS_SCHEDULES, FaultPlan, FaultRule,
                          InjectedCrash)

OPS = [Op("a", 256, 1024, 512), Op("b", 128, 512, 256)]


def mk_study(name="chaostest"):
    return (Study(name).designs(preset_grid(array=[8, 16]))
            .workloads({"wa": OPS[:1], "wb": OPS[1:]}).fidelity("fast"))


def chaos_drive(root, sid, *, n_workers=2, max_rounds=400,
                lease_seconds=0.0, max_shard_attempts=8):
    """Broker + worker pool stepped round-robin under the active plan;
    InjectedCrash respawns the worker. Returns (broker, final state)."""
    broker = Broker(root, max_shard_cells=2, lease_seconds=lease_seconds,
                    max_shard_attempts=max_shard_attempts)
    client = FarmClient(root)
    workers = [Worker(root, f"cw{i}") for i in range(n_workers)]
    kills = 0
    for _ in range(max_rounds):
        broker.step()
        for i, w in enumerate(workers):
            try:
                while w.step():
                    pass
            except InjectedCrash:
                kills += 1
                workers[i] = Worker(root, f"cw{i}r{kills}")
            except OSError:
                pass
        state = client.status(sid).get("state")
        if state in ("done", "canceled", "error"):
            broker.step()
            return broker, client.status(sid).get("state")
    raise AssertionError(
        f"chaos farm did not settle: {client.status(sid)}")


@pytest.mark.parametrize("schedule", sorted(CHAOS_SCHEDULES))
def test_schedule_terminates_bit_identical(tmp_path, schedule):
    local = mk_study().run()
    root = str(tmp_path / "farm")
    plan = CHAOS_SCHEDULES[schedule](seed=0)
    with plan.active():
        client = FarmClient(root)
        sid = client.submit(mk_study())
        _, state = chaos_drive(root, sid)
        assert state == "done"
        res = client.result(sid, timeout=5)
    assert res.equals(local)
    for k in local.columns:
        assert np.array_equal(res[k], local[k]), k
    assert not res.failed_cells


def test_worker_kills_schedule_actually_requeues(tmp_path):
    """The kill schedule must exercise re-delivery, not just survive it."""
    root = str(tmp_path / "farm")
    plan = CHAOS_SCHEDULES["worker-kills"](seed=0)
    with plan.active():
        client = FarmClient(root)
        sid = client.submit(mk_study())
        broker, state = chaos_drive(root, sid)
    assert state == "done"
    rep = plan.report()
    assert rep["total_injected"] > 0
    assert broker.metrics()["requeued_shards"] > 0
    assert broker.metrics()["quarantined_shards"] == 0


def test_same_seed_same_fault_schedule_same_frame(tmp_path):
    frames, reports = [], []
    for run in ("a", "b"):
        root = str(tmp_path / run)
        plan = CHAOS_SCHEDULES["torn-writes"](seed=7)
        with plan.active():
            client = FarmClient(root)
            sid = client.submit(mk_study())
            _, state = chaos_drive(root, sid)
            assert state == "done"
            frames.append(client.result(sid, timeout=5))
        reports.append(plan.report()["injected"])
    assert frames[0].equals(frames[1])
    assert reports[0] == reports[1]      # the schedule itself replayed


# ---- quarantine: the poison-shard budget ------------------------------------

def test_poison_shard_quarantined_into_failed_cells(tmp_path):
    """A shard that can never complete (its worker dies on every claim)
    burns its attempts budget and degrades to failed cells — the study
    completes instead of requeue-looping forever."""
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    sid = client.submit(mk_study())
    plan = FaultPlan(0, {"worker.claimed": FaultRule("crash", p=1.0)})
    with plan.active():
        broker, state = chaos_drive(root, sid, max_shard_attempts=3)
    assert state == "done"
    assert broker.metrics()["quarantined_shards"] >= 1
    res = client.result(sid, timeout=5)
    assert len(res) == 4
    # every claim died, so every shard quarantined: all cells failed
    failed = res.failed_cells
    assert failed == [0, 1, 2, 3] and len(res.ok()) == 0
    assert all(res["cell_status"][i] == 1.0 for i in failed)
    st = client.status(sid)
    assert st["cells_failed"] == len(failed)
    assert st["cells_done"] == 4         # quarantined cells count done


# ---- broker recovery machinery ----------------------------------------------

def test_corrupt_status_rebuilt_from_manifest(tmp_path):
    """kill -9 the broker, corrupt its status.json: a successor rebuilds
    from the manifest and the study converges to the same frame."""
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    local = mk_study().run()
    sid = client.submit(mk_study())
    Broker(root, max_shard_cells=2).step()          # ingest, then "crash"
    dirs = FarmDirs(root)
    with open(dirs.status_path(sid), "w") as f:
        f.write('{"study_id": "x", "state": "runn')  # torn mid-write
    broker2 = Broker(root, max_shard_cells=2)       # fresh process
    st = client.status(sid)
    assert st.get("state") == "running" and "recovered_at" in st
    workers = [Worker(root, "w0")]
    for _ in range(50):
        if client.status(sid).get("state") != "running":
            break
        for w in workers:
            w.step()
        broker2.step()
    assert client.status(sid)["state"] == "done"
    assert client.result(sid, timeout=5).equals(local)


def test_done_status_torn_after_the_fact_is_self_healed(tmp_path):
    """Status is only written on change — a torn write landing on the
    terminal transition must be repaired by the live broker's sweep,
    or the study stays unobservable forever."""
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    sid = client.submit(mk_study())
    broker = Broker(root, max_shard_cells=2)
    workers = [Worker(root, "w0")]
    broker.step()
    while client.status(sid).get("state") == "running":
        if not workers[0].step():
            broker.step()
    assert client.status(sid)["state"] == "done"
    dirs = FarmDirs(root)
    with open(dirs.status_path(sid), "w") as f:
        f.write('{"study_id"')                       # torn terminal write
    assert client.status(sid).get("state") == "queued"  # unreadable
    broker.step()                                    # self-heal sweep
    assert client.status(sid)["state"] == "done"


def test_unreadable_result_patience_then_reenqueue(tmp_path):
    """A result file that stays unparseable is tolerated for
    `result_patience` passes (mid-write race), then deleted; the
    reconcile pass re-enqueues the shard from the manifest and a
    healthy worker completes the study."""
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    local = mk_study().run()
    sid = client.submit(mk_study())
    broker = Broker(root, max_shard_cells=2, result_patience=2)
    broker.step()
    # consume one shard as a sick worker: claim, write a torn result, ack
    spool, dirs = FileSpool(root), FarmDirs(root)
    item = spool.claim(SHARDS_TOPIC, "sick")
    assert item is not None
    shard = int(item.payload["shard"])
    os.makedirs(dirs.results_dir(sid), exist_ok=True)
    with open(dirs.shard_result_path(sid, shard), "w") as f:
        f.write('{"study_id": "torn')
    spool.ack(item)
    # healthy worker drains the rest; broker waits out its patience,
    # deletes the torn file, reconciles, and re-delivers the shard
    w = Worker(root, "healthy")
    for _ in range(30):
        if client.status(sid).get("state") != "running":
            break
        while w.step():
            pass
        broker.step()
    assert client.status(sid)["state"] == "done"
    assert client.result(sid, timeout=5).equals(local)
    att = client.status(sid).get("attempts", {})
    assert att.get(str(shard), 0) >= 1


def test_error_shard_requeued_within_budget(tmp_path):
    """A worker-reported shard error is a failed attempt: the broker
    re-enqueues it (bounded), and a healthy retry completes the study —
    the old behavior poisoned the whole study on first error."""
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    local = mk_study().run()
    sid = client.submit(mk_study())
    broker = Broker(root, max_shard_cells=2)
    broker.step()
    spool, dirs = FileSpool(root), FarmDirs(root)
    item = spool.claim(SHARDS_TOPIC, "sick")
    shard = int(item.payload["shard"])
    os.makedirs(dirs.results_dir(sid), exist_ok=True)
    with open(dirs.shard_result_path(sid, shard), "w") as f:
        json.dump({"study_id": sid, "shard": shard, "worker": "sick",
                   "error": "RuntimeError: transient"}, f)
    spool.ack(item)
    w = Worker(root, "healthy")
    for _ in range(30):
        if client.status(sid).get("state") != "running":
            break
        while w.step():
            pass
        broker.step()
    assert client.status(sid)["state"] == "done"
    assert client.result(sid, timeout=5).equals(local)
