import pytest

from repro.core.accelerator import tpu_like_config
from repro.core.energy import (DEFAULT_ERT, action_counts, edp, energy_pj,
                               power_w, repeat_fraction)


def _counts(cfg, cycles=1e6, macs=5e8):
    return action_counts(cfg, cycles=cycles, macs=macs, ifmap_reads=1e6,
                         filter_reads=1e6, ofmap_writes=1e5, ofmap_reads=0.0,
                         dram_bytes=1e7)


def test_mac_action_split():
    """Sec. VII-E: MAC_random = PEs*cycles*util; gated = rest."""
    cfg = tpu_like_config(array=32)
    c = _counts(cfg, cycles=1e6, macs=5e8)
    pes = 1024
    util = 5e8 / (pes * 1e6)
    assert abs(c["mac_random"] - pes * 1e6 * util) < 1
    assert abs(c["mac_gated"] - pes * 1e6 * (1 - util)) < 1


def test_repeat_fraction_knob():
    assert repeat_fraction(64, 2) == 1 - 1 / 32
    assert repeat_fraction(2, 2) == 0.0


def test_energy_positive_and_additive():
    cfg = tpu_like_config(array=32)
    e = energy_pj(_counts(cfg))
    assert e["total"] > 0
    assert abs(sum(v for k, v in e.items() if k != "total")
               - e["total"]) < 1e-6


def test_repeat_access_cheaper():
    assert DEFAULT_ERT.sram_read_repeat < DEFAULT_ERT.sram_read_random / 2


def test_power_and_edp_units():
    # 1e9 pJ over 1e6 cycles @ 1 GHz = 1000 pJ/ns = 1 W
    assert power_w(1e9, 1e6, clock_ghz=1.0) == pytest.approx(1.0)
    # EdP in mJ*cycles: 1e9 pJ = 1 mJ over 1e6 cycles
    assert edp(1e9, 1e6) == pytest.approx(1e6)


def test_idle_energy_grows_with_array():
    small = tpu_like_config(array=32)
    big = tpu_like_config(array=128)
    macs = 1e9
    e_s = energy_pj(action_counts(small, cycles=1e6, macs=macs,
                                  ifmap_reads=0, filter_reads=0,
                                  ofmap_writes=0, ofmap_reads=0,
                                  dram_bytes=0))
    e_b = energy_pj(action_counts(big, cycles=1e6, macs=macs,
                                  ifmap_reads=0, filter_reads=0,
                                  ofmap_writes=0, ofmap_reads=0,
                                  dram_bytes=0))
    # same work, same cycles, 16x PEs: leakage + gating dominate
    assert e_b["pe_leak"] > 10 * e_s["pe_leak"]
    assert e_b["total"] > e_s["total"]


def test_instantaneous_power_trace():
    """Paper Table I: instantaneous power from the cycle-accurate activity
    trace; peaks at full occupancy, floors at leakage+gating when idle."""
    import jax
    import jax.numpy as jnp
    from repro.core.energy import instantaneous_power_trace
    from repro.kernels.systolic import simulate_fold

    cfg = tpu_like_config(array=16)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 16), jnp.float32)
    w = jax.random.normal(key, (16, 16), jnp.float32)
    sim = simulate_fold(x, w, interpret=True)
    p = instantaneous_power_trace(sim.active, cfg)
    assert p.shape[0] == sim.cycles
    assert float(p.min()) > 0                     # leakage floor
    assert float(p.max()) == pytest.approx(
        float(instantaneous_power_trace(jnp.array([256]), cfg)[0]))
    # average of the trace == average-power path on the same counts
    avg = float(p.mean())
    assert 0 < avg < float(p.max())
