"""Tests for the routed NoC/NoP plane (repro.noc).

Covers the ISSUE-7 contract: flit conservation per link, credit
non-negativity under backpressure, exact zero-load parity with the legacy
hop-offset multicore model, batched-vs-eager differential parity on
randomized mesh/torus grids, and vmap over mixed topologies.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.accelerator import (AcceleratorConfig, CoreConfig,
                                    NocConfig, tpu_like_config)
from repro.core.engine import simulate_network
from repro.core.workloads import Op
from repro.noc.router import (eager_noc_delay, link_loads, noc_delay_model,
                              windowed_link_sim)
from repro.noc.topology import (link_fanin, parent_links, route_pairs,
                                routed_hop_counts, subtree_sizes)
from repro.noc.traffic import allreduce_cycles

TOPOS = ("mesh", "torus", "ring")
GRIDS = ((2, 2), (1, 8), (4, 4), (3, 5), (4, 8))


def _noc_cfg(pr, pc, noc=None, hops=None):
    base = tpu_like_config(array=32)
    n = pr * pc
    proto = base.cores[0]
    cores = tuple(dataclasses.replace(proto, nop_hops=int(h))
                  for h in (hops if hops is not None else [0] * n))
    return dataclasses.replace(base, cores=cores, mesh_rows=pr, mesh_cols=pc,
                               noc=noc or NocConfig())


# --- topology: routing tables ------------------------------------------------

def test_routed_hops_mesh_2x2_matches_mcm_offsets():
    # the mcm-4x32 preset's hand-set (0, 1, 1, 2) offsets ARE the XY
    # routed distances on a 2x2 mesh
    assert routed_hop_counts("mesh", 2, 2).tolist() == [0, 1, 1, 2]


@pytest.mark.parametrize("topology", TOPOS)
@pytest.mark.parametrize("pr,pc", GRIDS)
def test_routes_form_tree_and_hops_match_metric(topology, pr, pc):
    n = pr * pc
    parent = parent_links(topology, pr, pc)
    hops = routed_hop_counts(topology, pr, pc)
    assert parent[0] == 0 and hops[0] == 0
    # every route reaches the MC, and each hop decrements the count by 1
    for u in range(1, n):
        v, steps = u, 0
        while v != 0:
            assert hops[v] == hops[parent[v]] + 1
            v = int(parent[v])
            steps += 1
            assert steps <= n, "route cycles"
        assert steps == hops[u]
    # closed-form distance metric
    i = np.arange(n)
    r, c = np.divmod(i, pc)
    want = {"mesh": r + c,
            "torus": np.minimum(r, pr - r) + np.minimum(c, pc - c),
            "ring": np.minimum(i, n - i)}[topology]
    np.testing.assert_array_equal(hops, want)


@pytest.mark.parametrize("topology", TOPOS)
@pytest.mark.parametrize("pr,pc", GRIDS)
def test_flit_conservation_per_link(topology, pr, pc):
    """load[l] = flits injected at l + sum of loads of l's child links."""
    n = pr * pc
    rng = np.random.default_rng(hash((topology, pr, pc)) % (1 << 32))
    flits = rng.uniform(0.0, 100.0, n)
    flits[0] = 0.0                       # the MC core injects nothing
    load = link_loads(topology, pr, pc, flits, xp=np)
    parent = parent_links(topology, pr, pc)
    child_sum = np.zeros(n)
    np.add.at(child_sum, parent[1:], load[1:])
    for l in range(1, n):
        assert load[l] == pytest.approx(flits[l] + child_sum[l])
    # link l carries exactly its subtree's injections
    sizes = subtree_sizes(topology, pr, pc)
    uniform = link_loads(topology, pr, pc, np.full(n, 3.0), xp=np)
    np.testing.assert_allclose(uniform[1:], 3.0 * sizes[1:])
    assert load[0] == 0.0


# --- windowed reference simulation: credit invariants ------------------------

@pytest.mark.parametrize("topology", ("mesh", "torus"))
def test_windowed_sim_credit_invariants(topology):
    pr, pc = 4, 4
    n = pr * pc
    rng = np.random.default_rng(7)
    flits = rng.uniform(10.0, 50.0, n)
    flits[0] = 0.0
    B = 4
    sim = windowed_link_sim(topology, pr, pc, flits, cap_per_window=3.0,
                            buffer_flits=B, windows=400)
    # credit non-negativity: occupancy never exceeds the buffer depth
    assert (sim["credits"] >= -1e-9).all()
    assert (sim["occupancy"] <= B + 1e-9).all()
    # end-to-end flit conservation: everything injected eventually sinks
    assert sim["source_left"][-1] == pytest.approx(0.0, abs=1e-9)
    assert sim["sink_served"][-1] == pytest.approx(flits[1:].sum())
    # in-flight accounting per window: injected = sunk + queued + backlog
    total = flits[1:].sum()
    inflight = sim["occupancy"].sum(axis=1)
    np.testing.assert_allclose(
        sim["sink_served"] + inflight + sim["source_left"], total)


def test_windowed_sim_backpressure_slows_drain():
    """Shallower buffers cannot drain faster (credit backpressure)."""
    pr, pc = 4, 4
    flits = np.full(pr * pc, 40.0)
    flits[0] = 0.0

    def done_at(buffer_flits):
        sim = windowed_link_sim("mesh", pr, pc, flits, cap_per_window=4.0,
                                buffer_flits=buffer_flits, windows=600)
        return int(np.argmax(sim["sink_served"]
                             >= flits[1:].sum() - 1e-9))

    assert done_at(2) >= done_at(16)


# --- zero-load contract ------------------------------------------------------

def _zero_load_noc(topology="mesh"):
    return NocConfig(enabled=True, topology=topology,
                     link_bandwidth_bytes_per_cycle=1e9, flit_bytes=32,
                     buffer_flits=1 << 20)


def test_zero_load_extra_is_exactly_zero():
    n = 16
    flits = np.full(n, 1000.0)
    stats = eager_noc_delay("mesh", 4, 4, flits, 1e9, 32, 1 << 20, 2.0,
                            100.0)
    assert stats["stall"] == 0.0
    assert (stats["extra"] == 0.0).all()


def test_zero_load_eager_matches_legacy_hop_offsets_bitwise():
    """Routed NoC at zero load == legacy nop_hops cycles, bit-for-bit."""
    pr, pc = 4, 4
    ops = [Op("g0", 384, 256, 512), Op("g1", 512, 128, 256)]
    legacy = _noc_cfg(pr, pc, hops=routed_hop_counts("mesh", pr, pc))
    routed = _noc_cfg(pr, pc, noc=_zero_load_noc())
    a = simulate_network(legacy, ops)
    b = simulate_network(routed, ops)
    assert b.total_cycles == a.total_cycles
    assert b.noc_stall_cycles == 0.0
    for ra, rb in zip(a.ops, b.ops):
        assert rb.compute_cycles == ra.compute_cycles
        assert rb.total_cycles == ra.total_cycles


def test_zero_load_batched_matches_legacy_exactly():
    from repro.api.study import Study
    pr, pc = 4, 4
    ops = [Op("g0", 384, 256, 512)]
    designs = {
        "legacy": _noc_cfg(pr, pc, hops=routed_hop_counts("mesh", pr, pc)),
        "routed": _noc_cfg(pr, pc, noc=_zero_load_noc()),
    }
    r = (Study().designs(designs).workloads({"w": ops}).fidelity("fast")
         .run())
    assert r.fraction_batched == 1.0
    t = {str(d): float(v) for d, v in zip(r["design"], r["total_cycles"])}
    assert t["routed"] == t["legacy"]
    assert float(r.filter(design="routed")["noc_stall_cycles"][0]) == 0.0


# --- batched vs eager differential parity ------------------------------------

@pytest.mark.parametrize("topology", ("mesh", "torus"))
@pytest.mark.parametrize("cores", (4, 16))
def test_batched_matches_eager_oracle(topology, cores):
    from repro.api.study import Study
    pr = {4: 2, 16: 4}[cores]
    pc = cores // pr
    rng = np.random.default_rng(cores + len(topology))
    ops = [Op("g0", 256, 256, 512), Op("g1", 512, 128, 384)]
    designs = {}
    for i in range(4):
        noc = NocConfig(enabled=True, topology=topology,
                        link_bandwidth_bytes_per_cycle=float(
                            rng.choice([1.0, 4.0, 32.0, 256.0])),
                        flit_bytes=int(rng.choice([16, 32, 64])),
                        buffer_flits=int(rng.choice([2, 8, 64])))
        designs[f"d{i}"] = _noc_cfg(pr, pc, noc=noc)

    def frame(ff):
        return (Study().designs(designs).workloads({"w": ops})
                .fidelity("fast").options(force_fallback=ff).run())

    batched, eager = frame(False), frame(True)
    assert batched.fraction_batched == 1.0
    assert eager.fraction_batched == 0.0
    for m in ("total_cycles", "noc_stall_cycles", "noc_link_util",
              "allreduce_cycles"):
        a = np.asarray(batched[m], dtype=float)
        b = np.asarray(eager[m], dtype=float)
        rel = np.abs(a - b) / np.maximum(np.abs(b), 1.0)
        assert rel.max() <= 1e-3, (m, a, b)


def test_vmap_over_mixed_topologies_stays_batched():
    """mesh + torus + ring designs in one study: one kernel per topology
    flavor, every cell batched."""
    from repro.api.study import Study
    ops = [Op("g0", 256, 256, 512)]
    designs = {
        t: _noc_cfg(4, 4, noc=NocConfig(
            enabled=True, topology=t, link_bandwidth_bytes_per_cycle=8.0))
        for t in TOPOS}
    r = (Study().designs(designs).workloads({"w": ops}).fidelity("fast")
         .run())
    assert r.fraction_batched == 1.0
    assert len(r) == 3
    # under congestion the mesh is the worst of the three: its column-0
    # bottleneck link carries a 12-core subtree on a 4x4 grid, vs 8 for
    # the ring's longest arc (and the torus halves the mesh's arcs)
    t = {str(d): float(v) for d, v in zip(r["design"], r["total_cycles"])}
    assert t["mesh"] >= t["ring"]
    assert t["mesh"] >= t["torus"]


# --- traffic: collectives ----------------------------------------------------

def test_allreduce_torus_beats_mesh_at_fixed_budget():
    for pr, pc in ((4, 4), (8, 8)):
        mesh = float(allreduce_cycles("mesh", pr, pc, 1 << 22, 8.0, 32, 8,
                                      2.0))
        torus = float(allreduce_cycles("torus", pr, pc, 1 << 22, 8.0, 32, 8,
                                       2.0))
        assert torus < mesh


def test_allreduce_single_core_is_free():
    assert float(allreduce_cycles("mesh", 1, 1, 1 << 20, 8.0, 32, 8,
                                  2.0)) == 0.0


# --- config validation (satellite: negative nop fields fail loudly) ----------

def test_negative_nop_hops_rejected():
    with pytest.raises(ValueError, match="nop_hops"):
        CoreConfig(nop_hops=-1)


def test_negative_nop_cycles_per_hop_rejected():
    with pytest.raises(ValueError, match="nop_cycles_per_hop"):
        AcceleratorConfig(nop_cycles_per_hop=-0.5)


def test_noc_config_validation():
    with pytest.raises(ValueError, match="topology"):
        NocConfig(topology="hypercube")
    with pytest.raises(ValueError, match="link_bandwidth"):
        NocConfig(enabled=True, link_bandwidth_bytes_per_cycle=0.0)
    with pytest.raises(ValueError, match="buffer_flits"):
        NocConfig(enabled=True, buffer_flits=0)
    # link fields are validated even while disabled: a bad value must
    # not lie dormant until someone replace()s enabled=True
    with pytest.raises(ValueError, match="flit_bytes"):
        NocConfig(enabled=False, flit_bytes=0)
    with pytest.raises(ValueError, match="link_bandwidth"):
        NocConfig(enabled=False, link_bandwidth_bytes_per_cycle=-1.0)
    NocConfig(enabled=False)


def test_noc_config_survives_dict_round_trip():
    cfg = _noc_cfg(2, 2, noc=NocConfig(enabled=True, topology="torus",
                                       link_bandwidth_bytes_per_cycle=8.0))
    back = AcceleratorConfig.from_dict(cfg.to_dict())
    assert back.noc == cfg.noc
