"""The run-farm (repro.farm): spool atomics, broker scheduling, worker
execution, client reassembly — driven synchronously (no threads, no
sleeps): tests call broker.step()/worker.step() by hand, so every
interleaving in here is deterministic.

Acceptance (ISSUE 6): farm frames bit-identical to a local Study.run(),
zero executed cells on a pre-warmed shared cache across two concurrent
submissions, dead-worker shard re-queue, and cancellation."""
import json
import os

import numpy as np
import pytest

from repro.api import Study, preset_grid, studies
from repro.api.study import StudyResult
from repro.core.workloads import Op
from repro.farm import Broker, FarmClient, Worker
from repro.farm.queue import SHARDS_TOPIC, FileSpool

OPS_A = [Op("a", 256, 1024, 512), Op("b", 512, 197, 768, count=3.0)]
OPS_B = [Op("c", 128, 512, 256)]


def mk_study(name="farmtest"):
    """2 designs x 2 workloads = 4 cells in 2 batched groups."""
    return (Study(name).designs(preset_grid(array=[8, 16]))
            .workloads({"wa": OPS_A, "wb": OPS_B}).fidelity("fast"))


def drive(broker, workers, client, sid, max_rounds=50):
    """Synchronous farm: alternate worker/broker steps to completion."""
    broker.step()
    for _ in range(max_rounds):
        if client.status(sid).get("state") != "running":
            return
        for w in workers:
            w.step()
        broker.step()
    raise AssertionError(f"farm did not settle: {client.status(sid)}")


@pytest.fixture()
def farm(tmp_path):
    root = str(tmp_path / "farm")
    return (FarmClient(root), Broker(root, max_shard_cells=2),
            [Worker(root, "w0"), Worker(root, "w1")])


# ---- the file spool ---------------------------------------------------------

def test_spool_put_claim_ack_priority_order(tmp_path):
    sp = FileSpool(str(tmp_path))
    sp.put("t", {"x": 2}, priority=200)
    sp.put("t", {"x": 0}, priority=50)
    sp.put("t", {"x": 1}, priority=50)          # FIFO within a priority
    assert sp.depth("t") == 3
    got = [sp.claim("t", "me").payload["x"] for _ in range(3)]
    assert got == [0, 1, 2]
    assert sp.claim("t", "me") is None
    # claimed items are leased, not gone, until acked
    assert len(sp.claimed_items("t")) == 3


def test_spool_claim_is_exclusive_and_requeue_restores(tmp_path):
    sp = FileSpool(str(tmp_path))
    sp.put("t", {"x": 1})
    a = sp.claim("t", "w0")
    assert a is not None and sp.claim("t", "w1") is None
    # the owner died: lease expiry moves it back, the other worker wins
    assert sp.requeue_stale("t", lease_seconds=0.0) == [a.item_id]
    b = sp.claim("t", "w1")
    assert b is not None and b.payload == {"x": 1}
    sp.ack(b)
    assert sp.requeue_stale("t", lease_seconds=0.0) == []
    assert sp.depth("t") == 0


def test_spool_drop_pending_and_poison(tmp_path):
    sp = FileSpool(str(tmp_path))
    sp.put("t", {"sid": "a"})
    sp.put("t", {"sid": "b"})
    assert sp.drop_pending("t", lambda p: p["sid"] == "a") == 1
    # a torn/corrupt pending file is dropped by claim, not fatal
    _, pending, _ = sp._dirs("t")
    with open(os.path.join(pending, "p0000-0-bad.json"), "w") as f:
        f.write("{not json")
    got = sp.claim("t", "me")
    assert got is not None and got.payload == {"sid": "b"}


# ---- study spec wire format -------------------------------------------------

def test_inline_spec_roundtrip_preserves_plan_and_cell_hashes():
    s = (mk_study().fidelity("fast", "trace")
         .options(core_index=0, force_fallback=False))
    spec = json.loads(json.dumps(s.to_spec()))   # through real JSON
    back = Study.from_spec(spec)
    p0, p1 = s.plan(), back.plan()
    assert [(c.design, c.workload, c.fidelity) for c in p0.cells] == \
        [(c.design, c.workload, c.fidelity) for c in p1.cells]
    # shared-cache identity across processes: hashes must match exactly
    assert [s._cell_hash(c) for c in p0.cells] == \
        [back._cell_hash(c) for c in p1.cells]


def test_registry_spec_keeps_claims_and_evaluator():
    s = studies.edp_array_size(smoke=True)
    spec = json.loads(json.dumps(s.to_spec()))
    assert spec["ref"] == {"study": "edp_array_size",
                           "kwargs": {"smoke": True}}
    back = Study.from_spec(spec)
    assert [n for n, _ in back._claims] == [n for n, _ in s._claims]
    # evaluator studies only serialize by reference
    ev = studies.multicore_contention(channels=(1, 2))
    assert Study.from_spec(ev.to_spec())._evaluator is not None
    with pytest.raises(ValueError):
        mk_study().evaluator(lambda c, o, f: {"m": 1.0}).to_spec()


def test_spec_rejects_bad_payloads():
    with pytest.raises(ValueError):
        Study.from_spec({"kind": "nope"})
    spec = mk_study().to_spec()
    spec["schema_version"] = "v0-bogus"
    with pytest.raises(ValueError):
        Study.from_spec(spec)


# ---- end-to-end: bit-identity ------------------------------------------------

def test_farm_frame_bit_identical_to_local_run(farm):
    client, broker, workers = farm
    local = mk_study().run()
    sid = client.submit(mk_study())
    drive(broker, workers, client, sid)
    st = client.status(sid)
    # max_shard_cells=2 with 2x 2-cell groups -> both workers got work
    assert st["shards_total"] >= 2
    res = client.result(sid, timeout=5)
    assert res.equals(local)
    for k in res.columns:
        assert np.array_equal(res[k], local[k]), k
    assert res.executed_cells == len(local) and res.cache_hits == 0
    done_workers = {w.worker_id for w in workers if w.shards_done}
    assert len(done_workers) == 2, "both workers should process shards"


def test_registry_study_claims_survive_farm_roundtrip(farm):
    client, broker, workers = farm
    sid = client.submit(studies.edp_array_size(smoke=True))
    drive(broker, workers, client, sid)
    res = client.result(sid, timeout=5)
    assert res.claims_ok(), res.check_claims()
    local = studies.edp_array_size(smoke=True).run()
    assert res.equals(local)


# ---- the fleet-shared dedup cache ---------------------------------------------

def test_prewarmed_cache_executes_zero_cells_across_submissions(farm):
    client, broker, workers = farm
    # warm the farm cache with a plain local run — single-process caches
    # carry straight over to the fleet
    mk_study().run(cache=broker.dirs.cache_dir())
    for sid in [client.submit(mk_study()), client.submit(mk_study())]:
        drive(broker, workers, client, sid)
        res = client.result(sid, timeout=5)
        assert res.executed_cells == 0
        assert res.cache_hits == len(res) == 4
    m = broker.metrics()
    assert sum(w.get("cache_hits", 0)
               for w in m["workers"].values()) == 8


def test_cold_farm_then_warm_local_run(farm):
    """Dedup flows both ways: a farm-executed study warms the cache for
    a later single-process run."""
    client, broker, workers = farm
    sid = client.submit(mk_study())
    drive(broker, workers, client, sid)
    res = client.result(sid, timeout=5)
    local = mk_study().run(cache=broker.dirs.cache_dir())
    assert local.executed_cells == 0 and local.cache_hits == 4
    assert local.equals(res)


# ---- failure paths --------------------------------------------------------------

def test_killed_worker_shard_requeued_and_study_completes(tmp_path):
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    broker = Broker(root, max_shard_cells=2, lease_seconds=0.0)
    local = mk_study().run()
    sid = client.submit(mk_study())
    broker.step()
    # a worker claims a shard and dies before writing any result
    spool = FileSpool(root)
    dead = spool.claim(SHARDS_TOPIC, "dead-worker")
    assert dead is not None
    # lease (0s) expires on the broker's next pass -> shard re-queued
    out = broker.step()
    assert out["requeued"] == 1
    survivor = Worker(root, "survivor")
    while client.status(sid).get("state") == "running":
        if not survivor.step():
            broker.step()
    res = client.result(sid, timeout=5)
    assert res.equals(local)
    assert broker.metrics()["requeued_shards"] == 1


def test_lease_expiry_race_folds_exactly_one_result(tmp_path):
    """The at-least-once race: a shard is requeued while its original
    owner is still finishing. The slow owner's late duplicate result
    and stale ack must be absorbed — exactly one folded result, frame
    identical to the fault-free run."""
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    broker = Broker(root, max_shard_cells=2, lease_seconds=0.0)
    local = mk_study().run()
    sid = client.submit(mk_study())
    broker.step()
    spool = FileSpool(root)
    slow = spool.claim(SHARDS_TOPIC, "slow-worker")   # A claims, stalls
    assert slow is not None
    out = broker.step()                      # lease expired -> requeued
    assert out["requeued"] == 1
    fast = Worker(root, "fast-worker")       # B does all the work
    while client.status(sid).get("state") == "running":
        if not fast.step():
            broker.step()
    assert client.status(sid)["state"] == "done"
    assert client.status(sid)["cells_done"] == 4
    # A wakes up: writes its duplicate result (same bytes, different
    # worker id) and acks the long-requeued claim
    shard = int(slow.payload["shard"])
    path = broker.dirs.shard_result_path(sid, shard)
    dup = json.load(open(path))
    dup["worker"] = "slow-worker"
    with open(path + ".tmp", "w") as f:
        json.dump(dup, f)
    os.replace(path + ".tmp", path)
    spool.ack(slow)                          # stale ack: no-op
    broker.step()
    st = client.status(sid)
    assert st["state"] == "done" and st["cells_done"] == 4  # folded once
    assert client.result(sid, timeout=5).equals(local)


def test_requeue_stale_reads_the_fault_clock(tmp_path):
    """Lease ages come from faults.fs.now(): an injected clock skew
    turns a fresh claim stale at once (the lease-storm mechanism)."""
    from repro.faults import FaultPlan, FaultRule
    sp = FileSpool(str(tmp_path))
    sp.put("t", {"x": 1})
    a = sp.claim("t", "w0")
    assert sp.requeue_stale("t", lease_seconds=3600.0) == []
    plan = FaultPlan(0, {"clock": FaultRule("skew", skew=1e6, p=1.0)})
    with plan.active():
        assert sp.requeue_stale("t", lease_seconds=3600.0) == [a.item_id]
    b = sp.claim("t", "w1")
    assert b is not None and b.payload == {"x": 1}


def test_cancellation_drops_pending_shards(farm):
    client, broker, workers = farm
    sid = client.submit(mk_study())
    broker.step()                                  # ingest + shard
    assert broker.spool.depth(SHARDS_TOPIC) >= 2
    client.cancel(sid)
    broker.step()                                  # apply the cancel
    assert client.status(sid)["state"] == "canceled"
    assert broker.spool.depth(SHARDS_TOPIC) == 0
    assert not workers[0].step(), "no work left for workers"
    with pytest.raises(RuntimeError, match="canceled"):
        client.result(sid, timeout=1)


def test_cancel_before_ingest_drops_the_job(farm):
    client, broker, workers = farm
    sid = client.submit(mk_study(), study_id="early-cancel")
    client.cancel(sid)
    broker.step()   # cancel parks a canceled status; ingest sees it
    broker.step()
    assert client.status(sid)["state"] == "canceled"
    assert broker.spool.depth(SHARDS_TOPIC) == 0


def test_bad_spec_marks_study_error(farm):
    client, broker, workers = farm
    spec = mk_study().to_spec()
    spec["workloads"] = {}                         # invalid: no workloads
    sid = client.submit(spec)
    broker.step()
    assert client.status(sid)["state"] == "error"
    with pytest.raises(RuntimeError, match="failed"):
        client.result(sid, timeout=1)


# ---- streaming + scheduling ------------------------------------------------------

def test_partial_frames_stream_in_plan_order(farm):
    client, broker, workers = farm
    sid = client.submit(mk_study())
    broker.step()
    assert client.partial_result(sid) is not None
    assert len(client.partial_result(sid)) == 0
    workers[0].step()                              # one shard done
    broker.step()
    part = client.partial_result(sid)
    assert 0 < len(part) < 4
    assert isinstance(part, StudyResult)
    # partial rows are a prefix-consistent subset of the final frame
    drive(broker, workers, client, sid)
    full = client.result(sid, timeout=5)
    rows = {tuple(r[a] for a in ("design", "workload", "fidelity")):
            r["total_cycles"] for r in full.rows()}
    for r in part.rows():
        key = tuple(r[a] for a in ("design", "workload", "fidelity"))
        assert rows[key] == r["total_cycles"]


def test_priority_orders_shard_claims(farm):
    client, broker, workers = farm
    slow = client.submit(mk_study("background"), priority=500)
    urgent = client.submit(mk_study("urgent"), priority=1)
    broker.step()
    w = workers[0]
    w.step()                                       # claims urgent first
    broker.step()
    assert client.status(urgent)["cells_done"] > 0
    assert client.status(slow)["cells_done"] == 0
    drive(broker, workers, client, urgent)
    drive(broker, workers, client, slow)
    assert client.result(slow, timeout=5).equals(
        client.result(urgent, timeout=5))


def test_broker_restart_resumes_inflight_study(tmp_path):
    root = str(tmp_path / "farm")
    client = FarmClient(root)
    sid = client.submit(mk_study())
    Broker(root, max_shard_cells=2).step()         # ingest, then "crash"
    broker2 = Broker(root, max_shard_cells=2)      # fresh process
    workers = [Worker(root, "w0")]
    drive(broker2, workers, client, sid)
    assert client.result(sid, timeout=5).equals(mk_study().run())


def test_worker_mesh_mode_matches_plain(farm):
    client, broker, _ = farm
    local = mk_study().run()
    sid = client.submit(mk_study())
    meshed = Worker(broker.dirs.root, "meshed", use_mesh=True)
    drive(broker, [meshed], client, sid)
    res = client.result(sid, timeout=5)
    for k in ("total_cycles", "energy_pj", "stall_cycles"):
        assert np.allclose(res[k], local[k], rtol=1e-6)
