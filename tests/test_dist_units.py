"""Direct unit tests for the repro.dist pieces the run-farm leans on:
`StragglerDetector` (median-of-means threshold, patience, windowing,
reset) and `plan_elastic_remesh` (fleet grows/shrinks, TP divisibility,
global-batch preservation). Before the farm these were only exercised
incidentally through launch/ smoke paths."""
import pytest

from repro.dist import StragglerDetector, plan_elastic_remesh


# ---- StragglerDetector ------------------------------------------------------

def feed(det, host, value, n):
    for _ in range(n):
        det.record(host, value)


def test_threshold_must_exceed_one():
    with pytest.raises(ValueError):
        StragglerDetector(threshold=1.0)
    with pytest.raises(ValueError):
        StragglerDetector(threshold=0.5)


def test_single_host_never_flags_itself():
    det = StragglerDetector(threshold=3.0, patience=2)
    feed(det, 0, 100.0, 8)          # slow in absolute terms, but the
    assert det.stragglers() == []   # median IS its own mean


def test_median_of_means_flags_the_slow_host():
    det = StragglerDetector(threshold=3.0, patience=2)
    feed(det, 0, 1.0, 4)
    feed(det, 1, 1.0, 4)
    feed(det, 2, 10.0, 4)           # median of (1, 1, 10) = 1
    assert det.stragglers() == [2]


def test_even_host_count_averages_the_middle_means():
    det = StragglerDetector(threshold=3.0, patience=1)
    for host, v in enumerate((1.0, 3.0, 3.0, 100.0)):
        det.record(host, v)
    # median of means = (3 + 3) / 2 = 3; only 100 > 3 * 3
    assert det.stragglers() == [3]


def test_patience_requires_consecutive_slow_samples():
    det = StragglerDetector(threshold=3.0, patience=2)
    feed(det, 0, 1.0, 8)
    feed(det, 1, 1.0, 2)
    det.record(1, 50.0)             # one bad step: not yet a straggler
    assert det.stragglers() == []
    det.record(1, 50.0)             # second consecutive: flagged
    assert det.stragglers() == [1]
    det.record(1, 1.0)              # a good step clears the streak
    assert det.stragglers() == []


def test_window_forgets_ancient_history():
    det = StragglerDetector(threshold=2.0, patience=2, window=4)
    feed(det, 0, 1.0, 8)
    feed(det, 1, 1.0, 8)
    feed(det, 2, 100.0, 2)          # flagged...
    assert det.stragglers() == [2]
    feed(det, 2, 1.0, 4)            # ...then recovers: window rolls over
    assert det.stragglers() == []


def test_reset_one_host_and_all():
    det = StragglerDetector(threshold=3.0, patience=1)
    feed(det, 0, 1.0, 4)
    feed(det, 1, 1.0, 4)
    feed(det, 2, 10.0, 4)
    assert det.stragglers() == [2]
    det.reset(2)
    assert det.stragglers() == []
    feed(det, 2, 10.0, 4)
    det.reset()
    assert det.stragglers() == [] and det._samples == {}


# ---- plan_elastic_remesh ------------------------------------------------------

def test_plain_data_parallel_plan():
    p = plan_elastic_remesh(8, global_batch=16)
    assert (p.dp, p.tp) == (8, 1)
    assert p.mesh_shape == (8, 1) and p.mesh_axes == ("data", "model")
    assert p.per_device_batch == 2 and p.grad_accum == 1
    assert p.global_batch == 16


def test_tp_halves_until_it_divides_the_fleet():
    p = plan_elastic_remesh(6, global_batch=12, tp=4)
    assert p.tp == 2 and p.dp == 3          # 4 -> 2 divides 6
    assert p.global_batch >= 12
    p = plan_elastic_remesh(8, global_batch=8, tp=4)
    assert p.tp == 4 and p.dp == 2


def test_fleet_shrink_absorbed_by_grad_accum():
    """Workers leave (8 -> 2 devices): the global batch — and so the
    training trajectory / farm shard total — is preserved."""
    big = plan_elastic_remesh(8, global_batch=64, max_per_device_batch=8)
    small = plan_elastic_remesh(2, global_batch=64, max_per_device_batch=8)
    assert big.global_batch == small.global_batch == 64
    assert small.grad_accum > big.grad_accum
    assert small.per_device_batch <= 8


def test_fleet_grow_keeps_batch_and_caps_pdb():
    for n in (1, 2, 3, 4, 8, 16):
        p = plan_elastic_remesh(n, global_batch=32,
                                max_per_device_batch=4)
        assert p.global_batch >= 32, n      # ceil division never loses rows
        assert 1 <= p.per_device_batch <= 4
        assert p.dp * p.tp <= n


def test_prefer_pod_splits_the_data_axis():
    p = plan_elastic_remesh(16, global_batch=16, tp=2, prefer_pod=4)
    assert p.mesh_shape == (4, 2, 2)
    assert p.mesh_axes == ("pod", "data", "model")
    # pod count not dividing dp: fall back to the flat mesh
    p = plan_elastic_remesh(16, global_batch=16, tp=2, prefer_pod=3)
    assert p.mesh_axes == ("data", "model")


def test_rejects_empty_fleet():
    with pytest.raises(ValueError):
        plan_elastic_remesh(0, global_batch=8)


def test_farm_shard_sizing_contract():
    """The broker's use: cells-per-shard = per_device_batch, capped by
    max_shard_cells, with >= n_workers slices of any big-enough group."""
    for n_workers in (1, 2, 4):
        for n_cells in (1, 3, 8, 16, 33):
            p = plan_elastic_remesh(n_workers, global_batch=n_cells,
                                    max_per_device_batch=8)
            size = max(1, p.per_device_batch)
            n_shards = -(-n_cells // size)
            assert size <= 8
            if n_cells >= n_workers:
                assert n_shards >= min(n_workers, n_cells)
