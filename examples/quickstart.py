"""Quickstart: simulate one GEMM and one full network on modeled silicon
through the unified `Simulator` facade (see DESIGN.md).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Simulator
from repro.core.accelerator import SparsityConfig
from repro.core.workloads import Op


def main():
    # 1. one GEMM on a 32x32 weight-stationary array (the "paper-32" preset)
    sim = Simulator("paper-32")
    r = sim.run_op(Op("gemm", 512, 4096, 1024))
    print("GEMM 512x4096x1024 on 32x32 WS "
          f"(stages: {' -> '.join(sim.stage_names())}):")
    print(f"  compute={r.compute_cycles:.3e} cyc  "
          f"stalls={r.stall_cycles:.3e}  "
          f"util={r.utilization:.2f}  "
          f"dram={r.dram_bytes/1e6:.1f} MB")

    # 2. the same GEMM with 2:4 weight sparsity (swap one stage input,
    #    same pipeline)
    sp = sim.with_(sparsity=SparsityConfig(enabled=True, n=2, m=4))
    r = sp.run_op(Op("gemm24", 512, 4096, 1024))
    print(f"  with 2:4 sparsity: compute={r.compute_cycles:.3e} cyc, "
          f"filter storage {r.sparse_storage['original_bytes']/1e6:.2f} -> "
          f"{r.sparse_storage['total_bytes']/1e6:.2f} MB")

    # 3. a whole network with energy/EdP + per-action breakdown
    rep = sim.run("resnet18")
    print("\nResNet-18 end-to-end on 32x32 WS:")
    print(f"  cycles={rep.total_cycles:.3e} (stalls {rep.stall_cycles:.2e})")
    print(f"  energy={rep.energy_pj*1e-9:.2f} mJ  "
          f"power={rep.avg_power_w:.2f} W  EdP={rep.edp:.3e}")
    top = sorted(rep.energy_breakdown.items(), key=lambda kv: -kv[1])[:3]
    print("  top energy actions: "
          + ", ".join(f"{k}={v*1e-9:.2f}mJ" for k, v in top))

    # 4. cycle-accurate DRAM fidelity: same facade, different pipeline
    cyc = Simulator("paper-32", fidelity="cycle")
    r = cyc.run_op(Op("conv1", 64, 112 * 112, 147))
    print(f"\ncycle-fidelity DRAM: stalls={r.stall_cycles:.3e}, "
          f"row hits={r.dram_stats['row_hits']}")

    # 5. per-layer CSV (now includes the grouped energy breakdown)
    rep.write_csv("/tmp/quickstart_report.csv")
    print("\nper-layer report -> /tmp/quickstart_report.csv")


if __name__ == "__main__":
    main()
