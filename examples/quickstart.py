"""Quickstart: simulate one GEMM and one full network on modeled silicon.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (gemm_summary, simulate_network, simulate_op,
                        tpu_like_config)
from repro.core.accelerator import SparsityConfig
from repro.core.topology import Op, resnet18


def main():
    # 1. one GEMM on a 32x32 weight-stationary array
    cfg = tpu_like_config(array=32, dataflow="ws")
    s = gemm_summary(cfg, M=512, N=4096, K=1024)
    print("GEMM 512x4096x1024 on 32x32 WS:")
    print(f"  compute={float(s['compute_cycles']):.3e} cyc  "
          f"stalls={float(s['stall_cycles']):.3e}  "
          f"util={float(s['utilization']):.2f}  "
          f"dram={float(s['dram_bytes'])/1e6:.1f} MB")

    # 2. the same GEMM with 2:4 weight sparsity
    sp = cfg.with_(sparsity=SparsityConfig(enabled=True, n=2, m=4))
    r = simulate_op(sp, Op("gemm24", 512, 4096, 1024))
    print(f"  with 2:4 sparsity: compute={r.compute_cycles:.3e} cyc, "
          f"filter storage {r.sparse_storage['original_bytes']/1e6:.2f} -> "
          f"{r.sparse_storage['total_bytes']/1e6:.2f} MB")

    # 3. a whole network with energy/EdP
    rep = simulate_network(cfg, resnet18())
    print("\nResNet-18 end-to-end on 32x32 WS:")
    print(f"  cycles={rep.total_cycles:.3e} (stalls {rep.stall_cycles:.2e})")
    print(f"  energy={rep.energy_pj*1e-9:.2f} mJ  "
          f"power={rep.avg_power_w:.2f} W  EdP={rep.edp:.3e}")

    # 4. per-layer CSV
    rep.write_csv("/tmp/quickstart_report.csv")
    print("\nper-layer report -> /tmp/quickstart_report.csv")


if __name__ == "__main__":
    main()
