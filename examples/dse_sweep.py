"""Design-space exploration on the Study API — the reason SCALE-Sim v3
exists: a designs x workload cross-product compiled into batched
jitted/vmapped sweep kernels, optionally sharded over a device mesh
(`--shard`), reduced to a columnar frame.

    PYTHONPATH=src python examples/dse_sweep.py --arch qwen2-1.5b
"""
import argparse

from repro.api import Study, preset_grid
from repro.configs import get_config
from repro.core.workloads import lm_ops, total_macs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--sram-mb", type=float, nargs="+", default=[0.5, 2.0, 8.0])
    ap.add_argument("--fidelity", nargs="+", default=["fast"],
                    help="one or more of fast/trace — extra frame rows per level")
    ap.add_argument("--shard", action="store_true",
                    help="shard each batched group over this host's devices")
    ap.add_argument("--cache", help="on-disk cell cache directory")
    args = ap.parse_args()

    ops = [o for o in lm_ops(get_config(args.arch), seq=args.seq, batch=1,
                             mode="prefill") if o.kind == "gemm"]
    print(f"{args.arch}: {len(ops)} GEMMs, "
          f"{total_macs(ops) / 1e12:.2f} TMACs per prefill step")

    mesh = None
    if args.shard:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()

    study = (Study(f"dse-{args.arch}")
             .designs(preset_grid(array=[8, 16, 32, 64, 128],
                                  sram_mb=args.sram_mb))
             .workloads({args.arch: ops})
             .fidelity(*args.fidelity))
    if args.cache:
        study.cache(args.cache)
    res = study.run(mesh=mesh)

    print(res.summary())
    for obj in ("latency", "energy", "edp"):
        rows = res.best(obj, by="fidelity")
        for fid, row in rows.items():
            print(f"best {obj} @ {fid}: {row['design']} "
                  f"({row['total_cycles']:.3e} cyc, "
                  f"{row['energy_pj'] * 1e-9:.2f} mJ)")
    print("pareto front:",
          [r["design"] for r in res.pareto("total_cycles", "energy_pj").rows()])


if __name__ == "__main__":
    main()
