"""Design-space exploration — the reason SCALE-Sim v3 exists.

Sweeps (array size x dataflow x SRAM) for an assigned LM architecture's
operator graph and reports the latency-, energy- and EdP-optimal designs.
The inner sweep is the traced/vmap fast path: thousands of designs in one
jit (and pjit-shardable across a pod for workload-scale DSE).

    PYTHONPATH=src python examples/dse_sweep.py --arch qwen2-1.5b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import simulate_network, tpu_like_config
from repro.core.engine import energy_traced, gemm_summary_traced
from repro.core.topology import lm_ops, total_macs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    ops = [o for o in lm_ops(cfg, seq=args.seq, batch=args.batch,
                             mode="prefill") if o.kind == "gemm"]
    M = jnp.array([o.M for o in ops])
    N = jnp.array([o.N for o in ops])
    K = jnp.array([o.K for o in ops])
    cnt = jnp.array([o.count for o in ops])
    print(f"{args.arch}: {len(ops)} GEMMs, "
          f"{total_macs(ops) / 1e12:.2f} TMACs per prefill step")

    arrays = jnp.array([8, 16, 32, 64, 128, 256])

    @jax.jit
    def sweep(arrays):
        def one_design(a):
            s = gemm_summary_traced("ws", M, N, K, a, a,
                                    sram_elems=1 << 20,
                                    bw_bytes_per_cycle=76.8)
            cyc = jnp.sum(s["total_cycles"] * cnt)
            e = jnp.sum(energy_traced(s["compute_cycles"] * cnt,
                                      M * N * K * cnt,
                                      s["dram_bytes"] * cnt, a, a))
            return cyc, e
        return jax.vmap(one_design)(arrays)

    cyc, e = jax.block_until_ready(sweep(arrays))
    edp = np.asarray(cyc) * np.asarray(e)
    print(f"{'array':>6} {'cycles':>12} {'energy mJ':>10} {'EdP':>12}")
    for i, a in enumerate(np.asarray(arrays)):
        print(f"{a:>4}x{a:<4} {float(cyc[i]):>12.3e} "
              f"{float(e[i]) * 1e-9:>10.2f} {float(edp[i]):>12.3e}")
    best = dict(latency=int(arrays[np.argmin(cyc)]),
                energy=int(arrays[np.argmin(np.asarray(e))]),
                edp=int(arrays[np.argmin(edp)]))
    print(f"\noptimal design: latency -> {best['latency']}^2, "
          f"energy -> {best['energy']}^2, EdP -> {best['edp']}^2")

    # cross-check the EdP winner with the full (cycle-fidelity) engine
    full = simulate_network(tpu_like_config(array=best['edp']), ops[:40])
    print(f"full-engine check @ {best['edp']}^2: "
          f"{full.total_cycles:.3e} cyc, {full.energy_pj * 1e-9:.2f} mJ")


if __name__ == "__main__":
    main()
