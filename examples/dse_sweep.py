"""Design-space exploration — the reason SCALE-Sim v3 exists.

Sweeps (array size x SRAM) for an assigned LM architecture's operator
graph through `Simulator.sweep`: the whole grid runs as one jitted/vmapped
call over the traced stage pipeline, shardable across a device mesh
(`--shard`) for workload-scale DSE — thousands of designs per second.

    PYTHONPATH=src python examples/dse_sweep.py --arch qwen2-1.5b
"""
import argparse

import numpy as np

from repro.api import Simulator, preset_grid
from repro.configs import get_config
from repro.core.topology import lm_ops, total_macs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--sram-mb", type=float, nargs="+",
                    default=[0.5, 2.0, 8.0])
    ap.add_argument("--shard", action="store_true",
                    help="shard the design axis over this host's devices")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    ops = [o for o in lm_ops(cfg, seq=args.seq, batch=args.batch,
                             mode="prefill") if o.kind == "gemm"]
    print(f"{args.arch}: {len(ops)} GEMMs, "
          f"{total_macs(ops) / 1e12:.2f} TMACs per prefill step")

    arrays = [8, 16, 32, 64, 128, 256]
    grid = preset_grid(array=arrays, sram_mb=args.sram_mb)

    mesh = None
    if args.shard:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        print(f"sharding {len(grid)} designs over {mesh.size} devices")

    res = Simulator().sweep(grid, ops, mesh=mesh)

    print(f"{'design':>14} {'cycles':>12} {'energy mJ':>10} {'EdP':>12}")
    for i, c in enumerate(res.configs):
        a, mb = c.cores[0].rows, c.memory.ifmap_sram_bytes * 3 / (1 << 20)
        print(f"{a:>4}x{a:<4}@{mb:4.1f}MB {res.total_cycles[i]:>12.3e} "
              f"{res.energy_pj[i] * 1e-9:>10.2f} {res.edp[i]:>12.3e}")

    best = {obj: res.best(obj).cores[0].rows
            for obj in ("latency", "energy", "edp")}
    print(f"\noptimal design: latency -> {best['latency']}^2, "
          f"energy -> {best['energy']}^2, EdP -> {best['edp']}^2")

    # cross-check the EdP winner with the cycle-fidelity DRAM pipeline
    # (an independent stall model: if the fast path is badly wrong about
    # memory-boundedness, these disagree)
    full = Simulator(res.best("edp"), fidelity="cycle").run(ops[:10])
    fast = Simulator(res.best("edp"), fidelity="fast").run(ops[:10])
    print(f"cycle-fidelity check @ {best['edp']}^2 (first 10 GEMMs): "
          f"{full.total_cycles:.3e} cyc vs fast {fast.total_cycles:.3e}")
    sanity = full.total_cycles > 0 and np.isfinite(res.edp).all()
    print("sweep sane:", bool(sanity))


if __name__ == "__main__":
    main()
